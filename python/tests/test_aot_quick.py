"""End-to-end AOT smoke: a --quick --skip-models build into a temp dir
produces a parseable manifest, a valid lexicon, corpus files, goldens,
and a loadable regressor bundle."""

import json
import subprocess
import sys
from pathlib import Path

import pytest

PY_ROOT = Path(__file__).resolve().parent.parent


@pytest.fixture(scope="module")
def quick_artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts_quick")
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--quick", "--skip-models"],
        cwd=PY_ROOT,
        check=True,
        capture_output=True,
    )
    return out


def test_manifest_parses(quick_artifacts):
    m = json.loads((quick_artifacts / "manifest.json").read_text())
    assert m["vocab_size"] == 2048
    assert m["quick"] is True
    assert m["feature_names"][-1] == "input_len"
    assert set(m["corpus"]["train"]) == set(m["corpus"]["test"])


def test_lexicon_and_vocab(quick_artifacts):
    lex = json.loads((quick_artifacts / "lexicon.json").read_text())
    assert len(lex["vocab"]) == 2048
    assert lex["vocab"][:4] == ["<pad>", "<bos>", "<eos>", "<unk>"]
    assert "bat" in lex["homonyms"]


def test_corpus_files_exist_and_parse(quick_artifacts):
    m = json.loads((quick_artifacts / "manifest.json").read_text())
    for rel in list(m["corpus"]["train"].values()) + [m["corpus"]["observation"]]:
        lines = (quick_artifacts / rel).read_text().strip().splitlines()
        assert lines
        rec = json.loads(lines[0])
        assert {"text", "type", "lens", "features"} <= set(rec)


def test_goldens_exist(quick_artifacts):
    m = json.loads((quick_artifacts / "manifest.json").read_text())
    lines = (quick_artifacts / m["goldens"]["textproc"]).read_text().strip().splitlines()
    assert len(lines) > 100


def test_regressor_bundle_round_trips(quick_artifacts):
    from compile.bundle import read_bundle

    tensors = dict(read_bundle(quick_artifacts / "regressor.bin"))
    m = json.loads((quick_artifacts / "manifest.json").read_text())
    assert set(m["regressor"]["param_names"]) == set(tensors)
    sizes = m["regressor"]["layer_sizes"]
    assert tensors["w0"].shape == (sizes[0], sizes[1])
    assert tensors[f"w{len(sizes) - 2}"].shape[-1] == 1
