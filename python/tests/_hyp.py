"""Hypothesis compatibility shim.

Property tests import ``given``/``settings``/``st`` from here instead of
from ``hypothesis`` directly. When hypothesis is installed the real
objects pass through untouched; when it is absent the decorated tests are
collected but skipped with a clear reason, and plain unit tests in the
same module keep running — ``pytest -q`` must never fail collection over
an optional dev dependency.
"""

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    HAVE_HYPOTHESIS = False

    _SKIP = pytest.mark.skip(reason="hypothesis not installed; property test skipped")

    def given(*_args, **_kwargs):
        def decorate(fn):
            return _SKIP(fn)

        return decorate

    def settings(*_args, **_kwargs):
        def decorate(fn):
            return fn

        return decorate

    class _AnyStrategy:
        """Accepts any strategy-constructor call; values are never drawn."""

        def __getattr__(self, _name):
            def build(*_args, **_kwargs):
                return None

            return build

    st = _AnyStrategy()
