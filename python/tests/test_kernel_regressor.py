"""L1 regressor kernel vs oracle + training sanity."""

import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from compile import regressor
from compile.kernels.ref import regressor_mlp_ref
from compile.kernels.regressor import regressor_mlp


def _params(rng, sizes):
    out = []
    for a, b in zip(sizes[:-1], sizes[1:]):
        w = jnp.asarray((rng.normal(size=(a, b)) * 0.1).astype(np.float32))
        bias = jnp.asarray((rng.normal(size=(b,)) * 0.1).astype(np.float32))
        out.append((w, bias))
    return out


@settings(max_examples=20, deadline=None)
@given(
    b=st.sampled_from([1, 3, 16, 64]),
    hidden=st.sampled_from([(8,), (16, 32), (100, 200, 200, 100)]),
    seed=st.integers(0, 2**31 - 1),
)
def test_regressor_kernel_matches_ref(b, hidden, seed):
    rng = np.random.default_rng(seed)
    sizes = (7,) + hidden + (1,)
    params = _params(rng, sizes)
    feats = jnp.asarray(rng.normal(size=(b, 7)).astype(np.float32))
    got = regressor_mlp(feats, params)
    want = regressor_mlp_ref(feats, params)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_training_reduces_loss():
    rng = np.random.default_rng(0)
    n = 512
    feats = rng.uniform(0, 10, size=(n, regressor.LAYER_SIZES[0])).astype(np.float32)
    # linear-ish ground truth the MLP must be able to fit
    w = rng.uniform(0.5, 2.0, size=(regressor.LAYER_SIZES[0],)).astype(np.float32)
    targets = feats @ w + 5.0
    params, history = regressor.train(feats, targets, seed=0, epochs=30)
    assert history[-1] < history[0] * 0.2, history[:3] + history[-3:]


def test_predict_shape_and_finite():
    params = regressor.init_regressor(0)
    feats = jnp.asarray(np.random.default_rng(0).uniform(0, 10, size=(5, 7)).astype(np.float32))
    pred = np.asarray(regressor.predict(params, feats))
    assert pred.shape == (5,)
    assert np.all(np.isfinite(pred))
