"""scripts/gauntlet_report.py: the `rtlm gauntlet` comparison-table
renderer and CI gate, exercised end-to-end through a subprocess with
JSON fixtures (the same way the CI gauntlet-gate step invokes it)."""

import json
import os
import subprocess
import sys

SCRIPT = os.path.join(os.path.dirname(__file__), "..", "..", "scripts", "gauntlet_report.py")


def run_report(tmp_path, report):
    path = tmp_path / "gauntlet.json"
    path.write_text(json.dumps(report))
    return subprocess.run(
        [sys.executable, SCRIPT, str(path)],
        capture_output=True,
        text=True,
    )


def slo_row(klass, n, met, shed=0):
    return {
        "class": klass,
        "n": n,
        "met": met,
        "shed": shed,
        "attainment": met / n if n else 0.0,
    }


def cell(scenario="nominal", policy="RT-LM", **extra):
    base = {
        "scenario": scenario,
        "policy": policy,
        "n_tasks": 48,
        "mean_response": 1.25,
        "p95_response": 3.5,
        "p99_response": 4.25,
        "p95_ttft": 0.75,
        "makespan": 30.0,
        "miss_rate": 0.1,
        "shed_rate": 0.0,
        "lanes": ["gpu", "cpu"],
        "lane_tasks": [40, 8],
        "slo": [slo_row("interactive", 24, 20), slo_row("batch", 24, 24)],
    }
    base.update(extra)
    return base


def report(cells):
    return {"n": 48, "seed": 7, "time_scale": 25.0, "policies": [], "scenarios": [], "cells": cells}


def test_clean_report_renders_matrix_and_exits_zero(tmp_path):
    proc = run_report(
        tmp_path,
        report(
            [
                cell("nominal", "FIFO"),
                cell("nominal", "RT-LM", wire={"clean": True, "failures": []}),
                cell("flash", "RT-LM", shed_rate=0.25, slo=[slo_row("interactive", 24, 12, 6)]),
            ]
        ),
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    out = proc.stdout
    # matrix rows in report order, with the wire verdict surfaced
    assert out.index("| nominal | FIFO |") < out.index("| nominal | RT-LM |")
    assert "ok (wire)" in out
    # attainment renders as percentages: 20/24 interactive, 24/24 batch
    assert "83%" in out and "100%" in out
    # the flash cell's shed rate and per-class table both render
    assert "25%" in out
    assert "| flash | RT-LM | interactive | 24 | 12 | 6 | 50% |" in out
    assert "All 3 cells clean." in out


def test_error_cell_fails_but_renders_the_rest(tmp_path):
    proc = run_report(
        tmp_path,
        report(
            [
                cell("nominal", "FIFO"),
                {"scenario": "edge-cpu", "policy": "RT-LM", "error": "building cell: boom"},
            ]
        ),
    )
    assert proc.returncode == 1
    assert "| nominal | FIFO |" in proc.stdout
    assert "ERROR: building cell: boom" in proc.stdout
    assert "edge-cpu/RT-LM: building cell: boom" in proc.stdout


def test_zero_nominal_interactive_attainment_fails(tmp_path):
    bad = cell("nominal", "RT-LM", slo=[slo_row("interactive", 24, 0), slo_row("batch", 24, 24)])
    proc = run_report(tmp_path, report([bad]))
    assert proc.returncode == 1
    assert "zero interactive attainment" in proc.stdout
    # the same attainment is tolerated off the nominal scenario
    ok = cell("flash", "RT-LM", slo=[slo_row("interactive", 24, 0), slo_row("batch", 24, 24)])
    assert run_report(tmp_path, report([ok])).returncode == 0


def test_wire_divergence_fails(tmp_path):
    bad = cell("nominal", "RT-LM", wire={"clean": False, "failures": ["gpu batches 5 != 6"]})
    proc = run_report(tmp_path, report([bad]))
    assert proc.returncode == 1
    assert "WIRE FAIL (1)" in proc.stdout
    assert "wire parity diverged" in proc.stdout


def test_malformed_cells_render_without_crashing(tmp_path):
    proc = run_report(
        tmp_path,
        report(
            [
                cell("nominal", "FIFO"),
                "not a cell",
                {"scenario": "diurnal"},  # missing everything else
                cell("heavytail", "RT-LM", slo=["junk", slo_row("batch", 24, 24)]),
            ]
        ),
    )
    # the string cell is a problem; the partial dict renders with dashes
    assert proc.returncode == 1
    out = proc.stdout
    assert "MALFORMED" in out
    assert "| diurnal | ?? |" in out
    assert "| heavytail | RT-LM | batch | 24 | 24 | 0 | 100% |" in out
    assert "| nominal | FIFO |" in out


def test_empty_report_exits_nonzero(tmp_path):
    proc = run_report(tmp_path, report([]))
    assert proc.returncode == 1
    assert "no cells" in proc.stderr
