"""Tokenizer / PoS-lite / vocab unit tests (the rust mirror contract)."""

from _hyp import given, settings, st

from compile import lexicon
from compile.common import UNK_ID, VOCAB_SIZE
from compile.textproc import Vocab, build_vocab, pos_tag, tokenize


def test_tokenize_basic():
    assert tokenize("I love pizza.") == ["i", "love", "pizza", "."]
    assert tokenize("what?  really!") == ["what", "?", "really", "!"]
    assert tokenize("") == []


def test_tokenize_splits_punctuation_order():
    # trailing punctuation must come out in string order
    assert tokenize("ok?!") == ["ok", "?", "!"]
    assert tokenize('"quoted"') == ['"', "quoted", '"']


def test_tokenize_lowercases():
    assert tokenize("John SAW") == ["john", "saw"]


@settings(max_examples=50, deadline=None)
@given(st.text(alphabet=st.characters(codec="ascii"), max_size=80))
def test_tokenize_never_crashes_and_no_empty_tokens(s):
    toks = tokenize(s)
    assert all(t for t in toks)


def test_pos_tag_lexicon_hits():
    toks = ["what", "in", "the", "and", "i", "is", "good", "very"]
    tags = pos_tag(toks)
    assert tags == [
        lexicon.TAG_WH,
        lexicon.TAG_ADP,
        lexicon.TAG_DET,
        lexicon.TAG_CONJ,
        lexicon.TAG_PRON,
        lexicon.TAG_VERB,
        lexicon.TAG_ADJ,
        lexicon.TAG_ADV,
    ]


def test_pos_tag_suffix_rules():
    assert pos_tag(["quickly"]) == [lexicon.TAG_ADV]
    assert pos_tag(["jumping"]) == [lexicon.TAG_VERB]
    assert pos_tag(["education"]) == [lexicon.TAG_NOUN]
    assert pos_tag(["marvelous"]) == [lexicon.TAG_ADJ]
    assert pos_tag(["zebra"]) == [lexicon.TAG_NOUN]  # default
    assert pos_tag(["?"]) == [lexicon.TAG_PUNCT]


def test_vocab_size_and_round_trip():
    v = Vocab()
    assert len(v.id_to_word) == VOCAB_SIZE
    ids = v.encode("i love pizza .")
    assert UNK_ID not in ids  # all corpus words must be in-vocab
    assert v.decode(ids) == "i love pizza ."


def test_vocab_specials():
    v = build_vocab()
    assert v[:4] == ["<pad>", "<bos>", "<eos>", "<unk>"]


def test_all_corpus_words_in_vocab():
    v = Vocab()
    for w in lexicon.all_words():
        assert w in v.word_to_id, w


def test_encode_truncates():
    v = Vocab()
    ids = v.encode("a " * 200, max_len=64)
    assert len(ids) == 64
