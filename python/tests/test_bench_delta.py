"""scripts/bench_delta.py: snapshot diffing, table rendering, and the
score_sweep speedup table, exercised end-to-end through a subprocess
with JSON fixtures (the same way the CI bench-delta job invokes it)."""

import json
import os
import subprocess
import sys

SCRIPT = os.path.join(os.path.dirname(__file__), "..", "..", "scripts", "bench_delta.py")


def run_delta(tmp_path, a, b, labels=("A", "B")):
    pa, pb = tmp_path / "a.json", tmp_path / "b.json"
    pa.write_text(json.dumps(a))
    pb.write_text(json.dumps(b))
    proc = subprocess.run(
        [sys.executable, SCRIPT, str(pa), str(pb), "--labels", *labels],
        capture_output=True,
        text=True,
    )
    assert proc.returncode == 0, proc.stderr
    return proc.stdout


def snapshot(results=None, **extra):
    base = {
        "bench": "hotpath",
        "unit": "seconds_per_iter",
        "artifacts": False,
        "pjrt": False,
        "results": results or {},
    }
    base.update(extra)
    return base


def test_common_benchmarks_sorted_by_delta(tmp_path):
    a = snapshot({"fast": 1e-6, "slow": 1e-3})
    b = snapshot({"fast": 2e-6, "slow": 1.05e-3})
    out = run_delta(tmp_path, a, b)
    # fast moved +100%, slow +5% -> fast tops the table
    assert out.index("| fast |") < out.index("| slow |")
    assert "+100.0%" in out


def test_score_sweep_renders_speedup_table(tmp_path):
    sweep = {
        "short": {"tokens": 5, "legacy": 2e-6, "fast": 4e-7},
        "median": {"tokens": 13, "legacy": 5e-6, "fast": 1e-6},
        "long": {"tokens": 60, "legacy": 2e-5, "fast": 4e-6},
    }
    a = snapshot({"score legacy (short)": 2e-6})
    b = snapshot({"score legacy (short)": 2e-6}, score_sweep=sweep)
    out = run_delta(tmp_path, a, b, labels=("base", "pr"))
    assert "Admission scoring cost" in out
    # rows sorted by token count: short, median, long
    assert out.index("| short |") < out.index("| median |") < out.index("| long |")
    # legacy/fast = 5x for every row here
    assert "5.0x" in out
    # scores/sec of the fast path: 1 / 4e-7 = 2,500,000
    assert "2,500,000" in out
    # the A snapshot has no sweep: its columns render as "-"
    assert "| - |" in out


def test_score_sweep_absent_skips_table(tmp_path):
    out = run_delta(tmp_path, snapshot({"x": 1.0}), snapshot({"x": 1.0}))
    assert "Admission scoring cost" not in out


def test_score_sweep_malformed_entries_skipped(tmp_path):
    sweep = {
        "good": {"tokens": 7, "legacy": 1e-6, "fast": 5e-7},
        "bad": {"tokens": "??"},
        "worse": None,
    }
    out = run_delta(tmp_path, snapshot(), snapshot(score_sweep=sweep))
    assert "| good |" in out
    assert "| bad |" not in out
    assert "| worse |" not in out


def test_depth_sweep_still_renders(tmp_path):
    sweep = {"1000": {"indexed": 1e-6, "keyed": 1e-3}}
    out = run_delta(tmp_path, snapshot(), snapshot(pop_depth_sweep=sweep))
    assert "Pop cost vs queue depth" in out
    assert "1000x" in out
