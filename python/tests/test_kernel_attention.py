"""L1 decode/prefill attention kernels vs the pure-jnp oracle.

Hypothesis sweeps the shape space (batch, heads, cache length, head dim)
and the valid-length vectors; assert_allclose against ref.py is the core
correctness signal for the attention hot path.
"""

import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from compile.kernels import ref
from compile.kernels.attention import decode_attention, prefill_attention

SETTINGS = dict(max_examples=25, deadline=None)


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


@settings(**SETTINGS)
@given(
    b=st.integers(1, 8),
    h=st.integers(1, 6),
    s=st.sampled_from([1, 4, 16, 33, 64]),
    dh=st.sampled_from([4, 16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_decode_attention_matches_ref(b, h, s, dh, seed):
    rng = np.random.default_rng(seed)
    q = _rand(rng, b, h, dh)
    k = _rand(rng, b, h, s, dh)
    v = _rand(rng, b, h, s, dh)
    lengths = jnp.asarray(rng.integers(1, s + 1, size=(b,)).astype(np.int32))
    got = decode_attention(q, k, v, lengths)
    want = ref.decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(
    b=st.integers(1, 4),
    h=st.integers(1, 4),
    s=st.sampled_from([2, 8, 16, 32]),
    dh=st.sampled_from([8, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_prefill_attention_matches_ref(b, h, s, dh, seed):
    rng = np.random.default_rng(seed)
    q = _rand(rng, b, h, s, dh)
    k = _rand(rng, b, h, s, dh)
    v = _rand(rng, b, h, s, dh)
    lengths = jnp.asarray(rng.integers(1, s + 1, size=(b,)).astype(np.int32))
    got = prefill_attention(q, k, v, lengths)
    want = ref.prefill_attention_ref(q, k, v, lengths)
    # compare only valid (unpadded, causal-visible) query rows
    for bi in range(b):
        n = int(lengths[bi])
        np.testing.assert_allclose(
            np.asarray(got)[bi, :, :n], np.asarray(want)[bi, :, :n], rtol=1e-5, atol=1e-5
        )


def test_decode_attention_length_one_attends_only_first():
    rng = np.random.default_rng(0)
    b, h, s, dh = 2, 2, 8, 4
    q = _rand(rng, b, h, dh)
    k = _rand(rng, b, h, s, dh)
    v = _rand(rng, b, h, s, dh)
    lengths = jnp.asarray(np.array([1, 1], np.int32))
    got = np.asarray(decode_attention(q, k, v, lengths))
    # with a single valid slot, output == v[:, :, 0, :] exactly
    np.testing.assert_allclose(got, np.asarray(v)[:, :, 0, :], rtol=1e-6, atol=1e-6)


def test_decode_attention_ignores_entries_past_length():
    rng = np.random.default_rng(1)
    b, h, s, dh = 1, 2, 16, 8
    q = _rand(rng, b, h, dh)
    k = _rand(rng, b, h, s, dh)
    v = _rand(rng, b, h, s, dh)
    lengths = jnp.asarray(np.array([5], np.int32))
    base = np.asarray(decode_attention(q, k, v, lengths))
    # poison the invalid tail; the result must not change
    k2 = k.at[:, :, 5:, :].set(1e9)
    v2 = v.at[:, :, 5:, :].set(-1e9)
    poisoned = np.asarray(decode_attention(q, k2, v2, lengths))
    np.testing.assert_allclose(base, poisoned, rtol=1e-6, atol=1e-6)


def test_prefill_attention_causality():
    """Changing future tokens must not change earlier outputs."""
    rng = np.random.default_rng(2)
    b, h, s, dh = 1, 2, 8, 8
    q = _rand(rng, b, h, s, dh)
    k = _rand(rng, b, h, s, dh)
    v = _rand(rng, b, h, s, dh)
    lengths = jnp.asarray(np.array([s], np.int32))
    base = np.asarray(prefill_attention(q, k, v, lengths))
    k2 = k.at[:, :, 5:, :].add(7.0)
    v2 = v.at[:, :, 5:, :].add(-3.0)
    mod = np.asarray(prefill_attention(q, k2, v2, lengths))
    np.testing.assert_allclose(base[:, :, :5], mod[:, :, :5], rtol=1e-6, atol=1e-6)
