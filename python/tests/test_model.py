"""L2 model: shapes, prefill/decode consistency, cache semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.common import MODEL_CONFIGS, SEQ_MAX, VOCAB_SIZE

CFG = MODEL_CONFIGS["t5"]  # smallest variant keeps tests fast


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, 42)


def test_param_names_match_shapes(params):
    names = model.param_names(CFG)
    shapes = model.param_shapes(CFG)
    assert len(names) == len(params)
    for name, p in zip(names, params):
        assert tuple(p.shape) == shapes[name], name


def test_param_count_is_reasonable(params):
    total = sum(int(np.prod(p.shape)) for p in params)
    # embedding + 3 layers of d=192 — roughly 2.3M params
    assert 1_000_000 < total < 10_000_000


def test_prefill_shapes(params):
    b, s = 2, 16
    tokens = jnp.zeros((b, s), jnp.int32)
    lengths = jnp.asarray([3, 16], jnp.int32)
    logits, ck, cv = model.prefill(CFG, params, tokens, lengths)
    assert logits.shape == (b, VOCAB_SIZE)
    assert ck.shape == (CFG.n_layers, b, CFG.n_heads, SEQ_MAX, CFG.head_dim)
    assert cv.shape == ck.shape


def test_decode_shapes(params):
    b = 4
    ck = jnp.zeros((CFG.n_layers, b, CFG.n_heads, SEQ_MAX, CFG.head_dim), jnp.float32)
    logits, ck2, cv2 = model.decode_step(
        CFG, params, ck, ck, jnp.zeros((b,), jnp.int32), jnp.zeros((b,), jnp.int32)
    )
    assert logits.shape == (b, VOCAB_SIZE)
    assert ck2.shape == ck.shape


def test_prefill_matches_sequential_decode(params):
    """The core autoregressive invariant: prefill(t[0..n]) last-token
    logits == decode_step applied token by token."""
    rng = np.random.default_rng(7)
    b, s = 2, 8
    tokens = jnp.asarray(rng.integers(4, VOCAB_SIZE, size=(b, s)).astype(np.int32))
    lengths = jnp.asarray([5, 8], jnp.int32)
    logits_p, _, _ = jax.jit(lambda p, t, l: model.prefill(CFG, p, t, l))(params, tokens, lengths)

    ck = jnp.zeros((CFG.n_layers, b, CFG.n_heads, SEQ_MAX, CFG.head_dim), jnp.float32)
    cv = jnp.zeros_like(ck)
    dec = jax.jit(lambda p, ck, cv, pos, t: model.decode_step(CFG, p, ck, cv, pos, t))
    last = [None] * b
    for i in range(int(lengths.max())):
        pos = jnp.full((b,), i, jnp.int32)
        logits_d, ck, cv = dec(params, ck, cv, pos, tokens[:, i])
        for bi in range(b):
            if i == int(lengths[bi]) - 1:
                last[bi] = np.asarray(logits_d[bi])
    for bi in range(b):
        np.testing.assert_allclose(last[bi], np.asarray(logits_p[bi]), rtol=1e-3, atol=1e-3)


def test_decode_deterministic(params):
    b = 2
    ck = jnp.zeros((CFG.n_layers, b, CFG.n_heads, SEQ_MAX, CFG.head_dim), jnp.float32)
    pos = jnp.zeros((b,), jnp.int32)
    toks = jnp.asarray([10, 20], jnp.int32)
    l1, _, _ = model.decode_step(CFG, params, ck, ck, pos, toks)
    l2, _, _ = model.decode_step(CFG, params, ck, ck, pos, toks)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_decode_rows_independent(params):
    """Row b's logits must not depend on other rows in the batch."""
    ck = jnp.zeros((CFG.n_layers, 2, CFG.n_heads, SEQ_MAX, CFG.head_dim), jnp.float32)
    pos = jnp.zeros((2,), jnp.int32)
    l_pair, _, _ = model.decode_step(CFG, params, ck, ck, pos, jnp.asarray([10, 20], jnp.int32))
    ck1 = jnp.zeros((CFG.n_layers, 1, CFG.n_heads, SEQ_MAX, CFG.head_dim), jnp.float32)
    l_solo, _, _ = model.decode_step(
        CFG, params, ck1, ck1, jnp.zeros((1,), jnp.int32), jnp.asarray([10], jnp.int32)
    )
    np.testing.assert_allclose(np.asarray(l_pair)[0], np.asarray(l_solo)[0], rtol=1e-4, atol=1e-4)


def test_all_configs_init():
    for name, cfg in MODEL_CONFIGS.items():
        params = model.init_params(cfg, 1)
        assert len(params) == len(model.param_names(cfg)), name
        assert cfg.d_model % cfg.n_heads == 0, name
