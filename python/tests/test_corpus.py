"""Corpus generator: determinism, mixtures, length-model calibration."""

import numpy as np

from compile import corpus
from compile.common import (
    DATASET_NAMES,
    LENGTH_MODEL,
    MAX_OUTPUT_LEN,
    MIN_OUTPUT_LEN,
    MODEL_CONFIGS,
    UNCERTAINTY_TYPES,
)


def test_generate_split_deterministic():
    a = corpus.generate_split("personachat", 50, seed=3)
    b = corpus.generate_split("personachat", 50, seed=3)
    assert a == b


def test_generate_split_seed_sensitivity():
    a = corpus.generate_split("personachat", 50, seed=3)
    b = corpus.generate_split("personachat", 50, seed=4)
    assert a != b


def test_record_schema():
    recs = corpus.generate_split("convai2", 20, seed=0)
    for r in recs:
        assert set(r) == {"text", "type", "input_len", "base_len", "lens"}
        assert r["type"] in UNCERTAINTY_TYPES
        assert MIN_OUTPUT_LEN <= r["base_len"] <= MAX_OUTPUT_LEN
        assert set(r["lens"]) == set(MODEL_CONFIGS)
        for v in r["lens"].values():
            assert MIN_OUTPUT_LEN <= v <= MAX_OUTPUT_LEN


def test_observation_set_covers_all_types():
    obs = corpus.generate_observation_set(10, seed=0)
    types = {r["type"] for r in obs}
    assert types == set(UNCERTAINTY_TYPES)
    assert len(obs) == 10 * len(UNCERTAINTY_TYPES)


def test_length_ordering_matches_fig1a():
    """Fig. 1a: plain < structural/syntactic < semantic < vague/multipart/open."""
    obs = corpus.generate_observation_set(300, seed=1)
    means = {}
    for utype in UNCERTAINTY_TYPES:
        lens = [r["base_len"] for r in obs if r["type"] == utype]
        means[utype] = float(np.mean(lens))
    assert means["plain"] < means["structural"]
    assert means["plain"] < means["syntactic"]
    assert means["structural"] < means["semantic"]
    assert means["syntactic"] < means["semantic"]
    assert means["semantic"] < means["vague"]
    assert means["vague"] < means["open"]


def test_dataset_mixtures_differ():
    splits = {ds: corpus.generate_split(ds, 400, seed=9) for ds in DATASET_NAMES}
    plain_frac = {
        ds: sum(1 for r in recs if r["type"] == "plain") / len(recs)
        for ds, recs in splits.items()
    }
    assert plain_frac["personachat"] > plain_frac["empathetic_dialogues"]


def test_model_lengths_track_gamma():
    """blenderbot (gamma=1.1) must produce longer outputs than bart (0.85)."""
    recs = corpus.generate_split("blended_skill_talk", 500, seed=2)
    bb = np.mean([r["lens"]["blenderbot"] for r in recs])
    bart = np.mean([r["lens"]["bart"] for r in recs])
    assert bb > bart + 2.0


def test_input_length_contributes():
    import random

    rng = random.Random(0)
    short = np.mean([corpus.base_length("plain", 4, rng) for _ in range(300)])
    long = np.mean([corpus.base_length("plain", 40, rng) for _ in range(300)])
    assert long > short + 8.0
