"""L1 FFN and layernorm kernels vs oracles (hypothesis shape sweeps)."""

import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from compile.kernels import ref
from compile.kernels.ffn import _row_tile, ffn
from compile.kernels.layernorm import layernorm_residual

SETTINGS = dict(max_examples=25, deadline=None)


@settings(**SETTINGS)
@given(
    n=st.sampled_from([1, 2, 3, 8, 48, 96, 128, 256, 384]),
    d=st.sampled_from([16, 64, 192]),
    f=st.sampled_from([32, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_ffn_matches_ref(n, d, f, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    w1 = jnp.asarray((rng.normal(size=(d, f)) * 0.05).astype(np.float32))
    b1 = jnp.asarray(rng.normal(size=(f,)).astype(np.float32))
    w2 = jnp.asarray((rng.normal(size=(f, d)) * 0.05).astype(np.float32))
    b2 = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
    got = ffn(x, w1, b1, w2, b2)
    want = ref.ffn_ref(x, w1, b1, w2, b2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(
    n=st.sampled_from([1, 2, 5, 16, 48, 128, 512]),
    d=st.sampled_from([8, 64, 320]),
    seed=st.integers(0, 2**31 - 1),
)
def test_layernorm_residual_matches_ref(n, d, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    res = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    g = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
    got = layernorm_residual(x, res, g, b)
    want = ref.layernorm_residual_ref(x, res, g, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_row_tile_divides():
    for n in range(1, 600):
        t = _row_tile(n)
        assert n % t == 0
        assert 1 <= t <= 128


def test_layernorm_zero_residual_is_plain_ln():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4, 32)).astype(np.float32))
    z = jnp.zeros_like(x)
    g = jnp.ones((32,), jnp.float32)
    b = jnp.zeros((32,), jnp.float32)
    got = np.asarray(layernorm_residual(x, z, g, b))
    assert np.allclose(got.mean(axis=-1), 0.0, atol=1e-5)
    assert np.allclose(got.std(axis=-1), 1.0, atol=1e-3)
