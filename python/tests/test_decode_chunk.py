"""decode_chunk (K steps in-graph) must equal K sequential decode_steps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.common import MODEL_CONFIGS, SEQ_MAX

CFG = MODEL_CONFIGS["t5"]


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, 42)


def _empty_cache(b):
    shape = (CFG.n_layers, b, CFG.n_heads, SEQ_MAX, CFG.head_dim)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


@pytest.mark.parametrize("k", [1, 3, 8])
def test_chunk_equals_sequential(params, k):
    b = 2
    ck, cv = _empty_cache(b)
    pos = jnp.asarray([3, 5], jnp.int32)
    toks = jnp.asarray([10, 20], jnp.int32)

    ck1, cv1, p1, t1 = ck, cv, pos, toks
    seq_out = []
    for _ in range(k):
        logits, ck1, cv1 = model.decode_step(CFG, params, ck1, cv1, p1, t1)
        t1 = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        p1 = jnp.minimum(p1 + 1, SEQ_MAX - 1)
        seq_out.append(np.asarray(t1))
    seq_out = np.stack(seq_out, axis=1)

    chunk_out, ck2, cv2, p2 = jax.jit(lambda *a: model.decode_chunk(CFG, k, *a))(
        params, ck, cv, pos, toks
    )
    np.testing.assert_array_equal(np.asarray(chunk_out), seq_out)
    np.testing.assert_allclose(np.asarray(ck1), np.asarray(ck2), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(cv1), np.asarray(cv2), rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))


def test_chunk_positions_clamp_at_seq_max(params):
    b = 1
    ck, cv = _empty_cache(b)
    pos = jnp.asarray([SEQ_MAX - 2], jnp.int32)
    toks = jnp.asarray([10], jnp.int32)
    _, _, _, p2 = model.decode_chunk(CFG, 6, params, ck, cv, pos, toks)
    assert int(p2[0]) == SEQ_MAX - 1


def test_chunk_output_shape(params):
    b, k = 4, 5
    ck, cv = _empty_cache(b)
    out, _, _, _ = model.decode_chunk(
        CFG, k, params, ck, cv, jnp.zeros((b,), jnp.int32), jnp.zeros((b,), jnp.int32)
    )
    assert out.shape == (b, k)
    assert out.dtype == jnp.int32
