"""Tensor-bundle round trip (the rust reader's contract)."""

import numpy as np
import pytest

from compile.bundle import MAGIC, read_bundle, write_bundle


def test_round_trip(tmp_path, rng):
    tensors = [
        ("a", rng.normal(size=(3, 4)).astype(np.float32)),
        ("b.nested/name", np.arange(7, dtype=np.int32)),
        ("scalarish", np.ones((1,), np.float32)),
        ("big", rng.normal(size=(64, 128)).astype(np.float32)),
    ]
    path = tmp_path / "t.bin"
    write_bundle(path, tensors)
    back = read_bundle(path)
    assert [n for n, _ in back] == [n for n, _ in tensors]
    for (_, want), (_, got) in zip(tensors, back):
        assert want.dtype == got.dtype
        np.testing.assert_array_equal(want, got)


def test_magic_checked(tmp_path):
    path = tmp_path / "bad.bin"
    path.write_bytes(b"NOTMAGIC" + b"\x00" * 16)
    with pytest.raises(ValueError):
        read_bundle(path)


def test_rejects_f64(tmp_path):
    with pytest.raises(ValueError):
        write_bundle(tmp_path / "x.bin", [("x", np.ones((2,), np.float64))])


def test_empty_bundle(tmp_path):
    path = tmp_path / "e.bin"
    write_bundle(path, [])
    assert read_bundle(path) == []
    assert path.read_bytes()[:8] == MAGIC
