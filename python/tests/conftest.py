import os
import sys

# Make `compile.*` importable when pytest runs from the repo root.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
