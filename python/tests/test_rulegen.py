"""RULEGEN scorers: each uncertainty type must light up its own scorer."""

import numpy as np
from _hyp import given, settings, st

from compile import corpus, rulegen
from compile.common import FEATURE_NAMES, N_FEATURES, UNCERTAINTY_TYPES


def test_feature_vector_shape():
    f = rulegen.features("tell me about the history of art .")
    assert len(f) == N_FEATURES
    assert all(isinstance(x, float) for x in f)


def test_paper_examples_fire_expected_rules():
    # Table I's example sentences, scored by their own category.
    cases = {
        "structural": "John saw a boy in the park with a telescope.",
        "syntactic": "Rice flies like sand.",
        "semantic": "What's the best way to deal with bats?",
        "vague": "Tell me about the history of art.",
        "open": "What are the causes and consequences of poverty in developing countries?",
        "multipart": "How do cats and dogs differ in behavior, diet, and social interaction?",
    }
    idx = {name: i for i, name in enumerate(FEATURE_NAMES)}
    for utype, text in cases.items():
        feats = rulegen.features(text)
        assert feats[idx[utype]] > 0.0, (utype, feats)


def test_plain_sentences_score_low():
    f = rulegen.features("i love pizza .")
    assert sum(f[:6]) <= 2.0, f


def test_scores_nonnegative_on_generated_corpus():
    import random

    rng = random.Random(0)
    for utype in UNCERTAINTY_TYPES:
        for _ in range(50):
            text = corpus.GENERATORS[utype](rng)
            feats = rulegen.features(text)
            assert all(x >= 0.0 for x in feats), (utype, text, feats)


def test_generated_type_scores_higher_on_average():
    """Across the corpus, each non-plain generator must on average score
    higher on its own rule than plain sentences do."""
    import random

    rng = random.Random(1)
    idx = {name: i for i, name in enumerate(FEATURE_NAMES)}
    plain_scores = np.zeros(6)
    n = 100
    for _ in range(n):
        plain_scores += np.asarray(rulegen.features(corpus.GENERATORS["plain"](rng))[:6])
    plain_scores /= n
    for utype in ("structural", "syntactic", "semantic", "vague", "open", "multipart"):
        own = 0.0
        for _ in range(n):
            own += rulegen.features(corpus.GENERATORS[utype](rng))[idx[utype]]
        own /= n
        assert own > plain_scores[idx[utype]] + 1.0, (utype, own, plain_scores)


def test_single_rule_fallback_is_input_length():
    text = "zebra zebra zebra"
    feats = rulegen.features(text)
    assert sum(feats[:6]) == 0.0
    assert rulegen.single_rule_score(text) == 3.0


@settings(max_examples=50, deadline=None)
@given(st.text(alphabet=st.characters(codec="ascii"), max_size=120))
def test_features_total_function(s):
    feats = rulegen.features(s)
    assert len(feats) == N_FEATURES
    assert all(np.isfinite(x) and x >= 0.0 for x in feats)
