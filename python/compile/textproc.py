"""Tokenizer, vocabulary, and PoS-lite tagger (python build-path half).

The rust runtime carries an exact mirror in ``rust/src/textgen``; the two
are cross-checked through golden files emitted by ``aot.py``. Keep every
rule here dead simple and deterministic — any change must be replicated in
rust and will invalidate the goldens.
"""

from . import lexicon
from .common import BOS_ID, EOS_ID, N_SPECIAL, PAD_ID, UNK_ID, VOCAB_SIZE

_PUNCT = ".,!?;:\"()"


def tokenize(text: str):
    """Lowercase, split on whitespace, split off punctuation as tokens.

    ``"Let's talk, OK?"`` -> ``["let's", "talk", ",", "ok", "?"]``
    """
    out = []
    for raw in text.lower().split():
        # strip leading punctuation
        start = 0
        while start < len(raw) and raw[start] in _PUNCT:
            out.append(raw[start])
            start += 1
        end = len(raw)
        trailing = []
        while end > start and raw[end - 1] in _PUNCT:
            trailing.append(raw[end - 1])
            end -= 1
        if end > start:
            out.append(raw[start:end])
        out.extend(reversed(trailing))
    return out


_POS_LEX = lexicon.pos_lexicon()

# (suffix, tag) checked in order; first match wins.
_SUFFIX_RULES = (
    ("ly", lexicon.TAG_ADV),
    ("ing", lexicon.TAG_VERB),
    ("ed", lexicon.TAG_VERB),
    ("ize", lexicon.TAG_VERB),
    ("tion", lexicon.TAG_NOUN),
    ("ness", lexicon.TAG_NOUN),
    ("ity", lexicon.TAG_NOUN),
    ("ment", lexicon.TAG_NOUN),
    ("ous", lexicon.TAG_ADJ),
    ("ful", lexicon.TAG_ADJ),
    ("ive", lexicon.TAG_ADJ),
    ("ical", lexicon.TAG_ADJ),
)


def pos_tag(tokens):
    """Tag each token: lexicon lookup, then suffix heuristics, else NOUN."""
    tags = []
    for tok in tokens:
        if tok and tok[0] in _PUNCT:
            tags.append(lexicon.TAG_PUNCT)
            continue
        tag = _POS_LEX.get(tok)
        if tag is None:
            for suffix, t in _SUFFIX_RULES:
                if len(tok) > len(suffix) + 1 and tok.endswith(suffix):
                    tag = t
                    break
        tags.append(tag or lexicon.TAG_NOUN)
    return tags


def build_vocab():
    """id -> word list of size VOCAB_SIZE.

    Slots 0..3 are special tokens; known words follow in sorted order;
    the tail is padded with synthetic filler words so the LM has a full
    vocabulary to sample from.
    """
    words = lexicon.all_words()
    vocab = ["<pad>", "<bos>", "<eos>", "<unk>"]
    vocab.extend(words)
    i = 0
    while len(vocab) < VOCAB_SIZE:
        vocab.append(f"tok{i}")
        i += 1
    if len(vocab) > VOCAB_SIZE:
        raise ValueError(f"lexicon too large: {len(vocab)} > {VOCAB_SIZE}")
    return vocab


class Vocab:
    def __init__(self):
        self.id_to_word = build_vocab()
        self.word_to_id = {w: i for i, w in enumerate(self.id_to_word)}

    def encode(self, text: str, max_len=None):
        ids = [self.word_to_id.get(t, UNK_ID) for t in tokenize(text)]
        if max_len is not None:
            ids = ids[:max_len]
        return ids

    def decode(self, ids):
        words = []
        for i in ids:
            if i in (PAD_ID, BOS_ID, EOS_ID):
                continue
            words.append(self.id_to_word[i] if 0 <= i < len(self.id_to_word) else "<unk>")
        return " ".join(words)
