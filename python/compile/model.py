"""L2 — the JAX transformer LM (decoder-only) built on the L1 kernels.

Five configurations (``common.MODEL_CONFIGS``) stand in for the paper's
five HuggingFace LMs. Two entrypoints are AOT-lowered per (batch, seq)
bucket:

- ``prefill``: consume the padded prompt batch, build the KV cache, and
  return the logits at each row's last real token.
- ``decode_step``: one autoregressive step over the KV cache for every
  row in the batch.

Weights are *parameters* of the lowered computation (never baked
constants): the rust runtime feeds them from ``weights.bin`` in the
canonical order given by ``param_names`` (recorded in the manifest).
"""

import jax
import jax.numpy as jnp
import numpy as np

from .common import SEQ_MAX, VOCAB_SIZE, ModelConfig
from .kernels.attention import decode_attention, prefill_attention
from .kernels.ffn import ffn
from .kernels.layernorm import layernorm_residual


def param_names(cfg: ModelConfig):
    """Canonical parameter order (must match init_params and weights.bin)."""
    names = ["tok_emb", "pos_emb"]
    for l in range(cfg.n_layers):
        names += [
            f"layer{l}.ln1_g",
            f"layer{l}.ln1_b",
            f"layer{l}.wq",
            f"layer{l}.wk",
            f"layer{l}.wv",
            f"layer{l}.wo",
            f"layer{l}.ln2_g",
            f"layer{l}.ln2_b",
            f"layer{l}.w1",
            f"layer{l}.b1",
            f"layer{l}.w2",
            f"layer{l}.b2",
        ]
    names += ["lnf_g", "lnf_b"]
    return names


def param_shapes(cfg: ModelConfig):
    """name -> shape, following param_names order."""
    d, f = cfg.d_model, cfg.d_ff
    shapes = {"tok_emb": (VOCAB_SIZE, d), "pos_emb": (SEQ_MAX, d)}
    for l in range(cfg.n_layers):
        shapes[f"layer{l}.ln1_g"] = (d,)
        shapes[f"layer{l}.ln1_b"] = (d,)
        shapes[f"layer{l}.wq"] = (d, d)
        shapes[f"layer{l}.wk"] = (d, d)
        shapes[f"layer{l}.wv"] = (d, d)
        shapes[f"layer{l}.wo"] = (d, d)
        shapes[f"layer{l}.ln2_g"] = (d,)
        shapes[f"layer{l}.ln2_b"] = (d,)
        shapes[f"layer{l}.w1"] = (d, f)
        shapes[f"layer{l}.b1"] = (f,)
        shapes[f"layer{l}.w2"] = (f, d)
        shapes[f"layer{l}.b2"] = (d,)
    shapes["lnf_g"] = (d,)
    shapes["lnf_b"] = (d,)
    return shapes


def init_params(cfg: ModelConfig, seed: int):
    """Seeded random init, returned as a list in param_names order."""
    rng = np.random.default_rng(seed)
    shapes = param_shapes(cfg)
    params = []
    for name in param_names(cfg):
        shape = shapes[name]
        if name.endswith("_g"):
            arr = np.ones(shape, np.float32)
        elif name.endswith(("_b", ".b1", ".b2")):
            arr = np.zeros(shape, np.float32)
        else:
            scale = 0.02 if "emb" in name else 1.0 / np.sqrt(shape[0])
            arr = (rng.standard_normal(shape) * scale).astype(np.float32)
        params.append(jnp.asarray(arr))
    return params


def _unpack(cfg: ModelConfig, params):
    """list -> (tok_emb, pos_emb, layers[...], lnf_g, lnf_b)."""
    tok_emb, pos_emb = params[0], params[1]
    layers = []
    i = 2
    for _ in range(cfg.n_layers):
        layers.append(params[i : i + 12])
        i += 12
    lnf_g, lnf_b = params[i], params[i + 1]
    return tok_emb, pos_emb, layers, lnf_g, lnf_b


def _split_heads(x, n_heads):
    b, s, d = x.shape
    return x.reshape(b, s, n_heads, d // n_heads).transpose(0, 2, 1, 3)  # [B,H,S,Dh]


def prefill(cfg: ModelConfig, params, tokens, lengths):
    """Prompt batch -> (last-token logits, KV cache).

    tokens: [B, S] int32 (padded with PAD); lengths: [B] int32.
    returns: logits [B, V], cache_k/cache_v [L, B, H, SEQ_MAX, Dh].
    """
    tok_emb, pos_emb, layers, lnf_g, lnf_b = _unpack(cfg, params)
    b, s = tokens.shape
    h, dh = cfg.n_heads, cfg.head_dim

    x = tok_emb[tokens] + pos_emb[:s][None, :, :]  # [B,S,D]

    cache_k = jnp.zeros((cfg.n_layers, b, h, SEQ_MAX, dh), jnp.float32)
    cache_v = jnp.zeros((cfg.n_layers, b, h, SEQ_MAX, dh), jnp.float32)

    for l, lp in enumerate(layers):
        ln1_g, ln1_b, wq, wk, wv, wo, ln2_g, ln2_b, w1, b1, w2, b2 = lp
        flat = x.reshape(b * s, cfg.d_model)
        normed = layernorm_residual(flat, jnp.zeros_like(flat), ln1_g, ln1_b)
        normed = normed.reshape(b, s, cfg.d_model)
        q = _split_heads(normed @ wq, h)
        k = _split_heads(normed @ wk, h)
        v = _split_heads(normed @ wv, h)
        attn = prefill_attention(q, k, v, lengths)  # [B,H,S,Dh]
        attn = attn.transpose(0, 2, 1, 3).reshape(b, s, cfg.d_model)
        x = x + attn @ wo
        cache_k = cache_k.at[l, :, :, :s, :].set(k)
        cache_v = cache_v.at[l, :, :, :s, :].set(v)

        flat = x.reshape(b * s, cfg.d_model)
        normed = layernorm_residual(flat, jnp.zeros_like(flat), ln2_g, ln2_b)
        x = (flat + ffn(normed, w1, b1, w2, b2)).reshape(b, s, cfg.d_model)

    flat = x.reshape(b * s, cfg.d_model)
    x = layernorm_residual(flat, jnp.zeros_like(flat), lnf_g, lnf_b).reshape(b, s, cfg.d_model)

    last = jnp.clip(lengths - 1, 0, s - 1)
    x_last = x[jnp.arange(b), last]  # [B,D]
    logits = x_last @ tok_emb.T  # tied head
    return logits, cache_k, cache_v


def decode_step(cfg: ModelConfig, params, cache_k, cache_v, pos, tokens):
    """One autoregressive step.

    pos: [B] int32 — the cache slot to write (current sequence length).
    tokens: [B] int32 — the previously generated token per row.
    returns: logits [B, V], updated cache_k, cache_v.
    """
    tok_emb, pos_emb, layers, lnf_g, lnf_b = _unpack(cfg, params)
    b = tokens.shape[0]
    h, dh = cfg.n_heads, cfg.head_dim

    x = tok_emb[tokens] + pos_emb[pos]  # [B,D]
    rows = jnp.arange(b)

    for l, lp in enumerate(layers):
        ln1_g, ln1_b, wq, wk, wv, wo, ln2_g, ln2_b, w1, b1, w2, b2 = lp
        normed = layernorm_residual(x, jnp.zeros_like(x), ln1_g, ln1_b)
        q = (normed @ wq).reshape(b, h, dh)
        k = (normed @ wk).reshape(b, h, dh)
        v = (normed @ wv).reshape(b, h, dh)
        cache_k = cache_k.at[l, rows, :, pos, :].set(k)
        cache_v = cache_v.at[l, rows, :, pos, :].set(v)
        attn = decode_attention(q, cache_k[l], cache_v[l], pos + 1)  # [B,H,Dh]
        x = x + attn.reshape(b, cfg.d_model) @ wo

        normed = layernorm_residual(x, jnp.zeros_like(x), ln2_g, ln2_b)
        x = x + ffn(normed, w1, b1, w2, b2)

    x = layernorm_residual(x, jnp.zeros_like(x), lnf_g, lnf_b)
    logits = x @ tok_emb.T
    return logits, cache_k, cache_v


def decode_chunk(cfg: ModelConfig, k: int, params, cache_k, cache_v, pos, tokens):
    """K autoregressive steps in one lowered computation.

    Greedy sampling happens in-graph (`argmax` feeds the next step), so
    the KV cache never leaves the device between the K steps — the
    host<->device round trip is paid once per chunk instead of once per
    token. This is the L2-level perf optimization recorded in
    EXPERIMENTS.md §Perf.

    returns: tokens_out [B, K], cache_k, cache_v, new_pos.
    """

    def body(carry, _):
        ck, cv, p, toks = carry
        logits, ck, cv = decode_step(cfg, params, ck, cv, p, toks)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        p = jnp.minimum(p + 1, SEQ_MAX - 1)
        return (ck, cv, p, nxt), nxt

    (cache_k, cache_v, pos, _), outs = jax.lax.scan(
        body, (cache_k, cache_v, pos, tokens), None, length=k
    )
    return outs.T, cache_k, cache_v, pos
