"""Pallas fused transformer FFN kernel (L1): gelu(x@w1+b1)@w2+b2.

Row-tiled: the grid walks tiles of input rows; both weight matrices are
staged whole into VMEM (they fit comfortably for every model variant —
see DESIGN.md §Kernel-roofline), so each grid step performs two
MXU-shaped matmuls and the GELU without touching HBM in between. This is
exactly the fusion the paper's GPU implementation gets from a fused
epilogue; on TPU it is the natural VMEM-resident schedule.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ffn_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    x = x_ref[...]  # [R, D]
    h = jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32) + b1_ref[...]
    h = jax.nn.gelu(h, approximate=True)
    o = jnp.dot(h, w2_ref[...], preferred_element_type=jnp.float32) + b2_ref[...]
    o_ref[...] = o.astype(o_ref.dtype)


def _row_tile(n: int) -> int:
    """Largest power-of-two tile <= 128 that divides n."""
    tile = min(n, 128)
    while n % tile != 0:
        tile //= 2
    return max(tile, 1)


@functools.partial(jax.named_call, name="ffn")
def ffn(x, w1, b1, w2, b2):
    """x: [N, D] -> [N, D] (see ref.ffn_ref)."""
    n, d = x.shape
    f = w1.shape[1]
    tile = _row_tile(n)
    return pl.pallas_call(
        _ffn_kernel,
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        grid=(n // tile,),
        in_specs=[
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
            pl.BlockSpec((d, f), lambda i: (0, 0)),
            pl.BlockSpec((f,), lambda i: (0,)),
            pl.BlockSpec((f, d), lambda i: (0, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile, d), lambda i: (i, 0)),
        interpret=True,
    )(x, w1, b1, w2, b2)
