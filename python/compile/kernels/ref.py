"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each ``*_ref`` mirrors the semantics of the corresponding kernel in this
package; ``python/tests`` sweeps shapes/dtypes with hypothesis and asserts
``allclose`` between the two. The L2 model may call either implementation
(``model.py`` uses the kernels; tests use these)."""

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k_cache, v_cache, lengths):
    """Single-step attention over a KV cache.

    q: [B, H, Dh] query for the current position.
    k_cache/v_cache: [B, H, S, Dh] with valid entries in [0, lengths[b]).
    lengths: [B] int32 number of valid cache slots per row.
    returns: [B, H, Dh].
    """
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    scores = jnp.einsum("bhd,bhsd->bhs", q, k_cache) * scale
    s = k_cache.shape[2]
    mask = jnp.arange(s)[None, None, :] < lengths[:, None, None]
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhs,bhsd->bhd", probs, v_cache)


def prefill_attention_ref(q, k, v, lengths):
    """Causal self-attention over padded prefill inputs.

    q/k/v: [B, H, S, Dh]; positions >= lengths[b] are padding.
    returns: [B, H, S, Dh] (padding query rows are computed but ignored by
    callers).
    """
    dh = q.shape[-1]
    s = q.shape[2]
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    causal = jnp.arange(s)[:, None] >= jnp.arange(s)[None, :]
    valid = jnp.arange(s)[None, None, None, :] < lengths[:, None, None, None]
    mask = causal[None, None, :, :] & valid
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def ffn_ref(x, w1, b1, w2, b2):
    """Fused transformer FFN: gelu(x @ w1 + b1) @ w2 + b2.

    x: [N, D]; w1: [D, F]; b1: [F]; w2: [F, D]; b2: [D].
    """
    h = jax.nn.gelu(x @ w1 + b1, approximate=True)
    return h @ w2 + b2


def layernorm_residual_ref(x, res, gamma, beta, eps=1e-5):
    """LayerNorm(x + res) * gamma + beta over the last axis."""
    y = x + res
    mean = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(y - mean), axis=-1, keepdims=True)
    return (y - mean) * jax.lax.rsqrt(var + eps) * gamma + beta


def regressor_mlp_ref(feats, params):
    """LW uncertainty regressor: ReLU MLP, linear scalar head.

    feats: [B, F_in] normalised features.
    params: [(w, b), ...] with the last layer mapping to 1 unit.
    returns: [B] predicted output lengths.
    """
    h = feats
    for i, (w, b) in enumerate(params):
        h = h @ w + b
        if i + 1 < len(params):
            h = jax.nn.relu(h)
    return h[:, 0]
