"""Pallas fused residual-add + LayerNorm kernel (L1).

Fuses the residual add into the normalisation so the intermediate
``x + res`` tensor never round-trips to HBM — the standard fusion for
transformer blocks. Row-tiled like the FFN kernel.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-5


def _ln_kernel(x_ref, res_ref, g_ref, b_ref, o_ref):
    y = x_ref[...] + res_ref[...]  # [R, D]
    mean = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(y - mean), axis=-1, keepdims=True)
    normed = (y - mean) * jax.lax.rsqrt(var + EPS)
    o_ref[...] = (normed * g_ref[...] + b_ref[...]).astype(o_ref.dtype)


def _row_tile(n: int) -> int:
    tile = min(n, 128)
    while n % tile != 0:
        tile //= 2
    return max(tile, 1)


@functools.partial(jax.named_call, name="layernorm_residual")
def layernorm_residual(x, res, gamma, beta):
    """LayerNorm(x + res) * gamma + beta; x/res: [N, D]."""
    n, d = x.shape
    tile = _row_tile(n)
    return pl.pallas_call(
        _ln_kernel,
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        grid=(n // tile,),
        in_specs=[
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile, d), lambda i: (i, 0)),
        interpret=True,
    )(x, res, gamma, beta)
