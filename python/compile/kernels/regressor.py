"""Pallas kernel for the LW uncertainty regressor (L1).

The whole MLP ([7 -> 100 -> 200 -> 200 -> 100 -> 1], ReLU) runs in one
grid step with every weight resident in VMEM (~130 KB total) — the model
is small enough that a single fused kernel is the optimal schedule; the
paper reports the same observation (Table VII: prioritisation cost is
dominated by feature extraction, not the MLP).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _regressor_kernel(*refs):
    # refs = (feats, w0, b0, w1, b1, ..., out)
    f_ref = refs[0]
    o_ref = refs[-1]
    weight_refs = refs[1:-1]
    h = f_ref[...]
    n_layers = len(weight_refs) // 2
    for i in range(n_layers):
        w = weight_refs[2 * i][...]
        b = weight_refs[2 * i + 1][...]
        h = jnp.dot(h, w, preferred_element_type=jnp.float32) + b
        if i + 1 < n_layers:
            h = jnp.maximum(h, 0.0)
    o_ref[...] = h[:, 0].astype(o_ref.dtype)


@functools.partial(jax.named_call, name="regressor_mlp")
def regressor_mlp(feats, params):
    """feats: [B, F_in]; params: [(w, b), ...] -> [B] predictions."""
    b = feats.shape[0]
    flat = []
    specs = [pl.BlockSpec(feats.shape, lambda: (0,) * 2)]
    for w, bias in params:
        flat.extend([w, bias])
        specs.append(pl.BlockSpec(w.shape, lambda: (0, 0)))
        specs.append(pl.BlockSpec(bias.shape, lambda: (0,)))
    return pl.pallas_call(
        _regressor_kernel,
        out_shape=jax.ShapeDtypeStruct((b,), feats.dtype),
        grid=(),
        in_specs=specs,
        out_specs=pl.BlockSpec((b,), lambda: (0,)),
        interpret=True,
    )(feats, *flat)
