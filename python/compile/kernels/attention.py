"""Pallas attention kernels (L1).

TPU-idiomatic structure: the grid walks batch rows (decode) or
(batch, head) pairs (prefill), each step staging one query/cache block
from HBM into VMEM via BlockSpec. The softmax is computed in fp32 inside
the block (numerically-stable max-subtraction), and the contraction is a
single MXU-shaped matmul per block.

``interpret=True`` is mandatory on this image: real TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute. The BlockSpecs are
still the real HBM↔VMEM schedule a TPU build would use (see DESIGN.md
§Kernel-roofline for the VMEM/MXU estimates).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_attn_kernel(q_ref, k_ref, v_ref, len_ref, o_ref):
    """One batch row: q [H, Dh] against cache [H, S, Dh]."""
    q = q_ref[0]  # [H, Dh]
    k = k_ref[0]  # [H, S, Dh]
    v = v_ref[0]  # [H, S, Dh]
    n_valid = len_ref[0]  # scalar int32
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))

    # scores[h, s] = q[h, :] . k[h, s, :]
    scores = jnp.einsum("hd,hsd->hs", q, k, preferred_element_type=jnp.float32)
    scores = scores * scale
    s = k.shape[1]
    positions = jax.lax.broadcasted_iota(jnp.int32, (1, s), 1)
    scores = jnp.where(positions < n_valid, scores, NEG_INF)

    # stable softmax in fp32
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)

    o_ref[0] = jnp.einsum("hs,hsd->hd", p, v, preferred_element_type=jnp.float32).astype(o_ref.dtype)


@functools.partial(jax.named_call, name="decode_attention")
def decode_attention(q, k_cache, v_cache, lengths):
    """Single-step attention over a KV cache (see ref.decode_attention_ref).

    q: [B, H, Dh]; k_cache/v_cache: [B, H, S, Dh]; lengths: [B] int32.
    """
    b, h, dh = q.shape
    s = k_cache.shape[2]
    return pl.pallas_call(
        _decode_attn_kernel,
        out_shape=jax.ShapeDtypeStruct((b, h, dh), q.dtype),
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, h, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, h, s, dh), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, h, s, dh), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1, h, dh), lambda i: (i, 0, 0)),
        interpret=True,
    )(q, k_cache, v_cache, lengths)


def _prefill_attn_kernel(q_ref, k_ref, v_ref, len_ref, o_ref):
    """One (batch, head) pair: causal attention over the full block."""
    q = q_ref[0, 0]  # [S, Dh]
    k = k_ref[0, 0]  # [S, Dh]
    v = v_ref[0, 0]  # [S, Dh]
    n_valid = len_ref[0]
    s, dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))

    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    rows = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
    mask = (cols <= rows) & (cols < n_valid)
    scores = jnp.where(mask, scores, NEG_INF)

    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)

    o_ref[0, 0] = jnp.dot(p, v, preferred_element_type=jnp.float32).astype(o_ref.dtype)


@functools.partial(jax.named_call, name="prefill_attention")
def prefill_attention(q, k, v, lengths):
    """Causal self-attention over padded prefill inputs.

    q/k/v: [B, H, S, Dh]; lengths: [B] int32.
    """
    b, h, s, dh = q.shape
    return pl.pallas_call(
        _prefill_attn_kernel,
        out_shape=jax.ShapeDtypeStruct((b, h, s, dh), q.dtype),
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((1, 1, s, dh), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, s, dh), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, s, dh), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((1, 1, s, dh), lambda i, j: (i, j, 0, 0)),
        interpret=True,
    )(q, k, v, lengths)
