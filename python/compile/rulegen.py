"""RULEGEN — the six rule-based linguistic-uncertainty scorers (Sec. III-B).

Each scorer measures the intensity of one uncertainty source from tokens +
PoS-lite tags (the paper's Listing 1 does the same with spaCy + regexes).
Scores are plain floats built from integer counts, so the rust mirror
(``rust/src/uncertainty/rules``) can reproduce them bit-exactly; the
goldens emitted by ``aot.py`` assert that.

The full feature vector for the LW regressor is the six scores plus the
input length (see common.FEATURE_NAMES).
"""

from . import lexicon
from .common import MAX_INPUT_LEN
from .textproc import pos_tag, tokenize


def _contains_phrase(tokens, phrase):
    n = len(phrase)
    for i in range(len(tokens) - n + 1):
        if tuple(tokens[i : i + n]) == phrase:
            return True
    return False


def structural_score(tokens, tags):
    """PP-attachment chains + relative clauses -> parse-structure ambiguity.

    "John saw a boy in the park with a telescope": every prepositional
    phrase beyond the first adds an attachment choice.
    """
    n_pp = sum(1 for t in tags if t == lexicon.TAG_ADP)
    n_rel = 0
    for i, tok in enumerate(tokens):
        if tok in lexicon.RELATIVIZERS and i > 0 and tags[i - 1] == lexicon.TAG_NOUN:
            n_rel += 1
    return 4.0 * max(0, n_pp - 1) + 2.0 * n_rel


def syntactic_score(tokens, tags):
    """Noun/verb-ambiguous words ("Rice flies like sand")."""
    n_ambig = sum(1 for t in tokens if t in lexicon.NV_AMBIGUOUS)
    score = 3.0 * n_ambig
    if n_ambig > 0 and not any(t == lexicon.TAG_VERB for t in tags):
        # no unambiguous verb anchors the parse
        score += 2.0
    return score


def semantic_score(tokens, tags):
    """Homonyms weighted by sense count ("bats", "trunk", "monitor")."""
    score = 0.0
    for t in tokens:
        senses = lexicon.HOMONYMS.get(t)
        if senses is not None:
            score += 3.0 * (senses - 1)
    return score


def vague_score(tokens, tags):
    """Broad topics and 'tell me about'-style prompts (paper Listing 1)."""
    score = 0.0
    for phrase in lexicon.VAGUE_PHRASES:
        if _contains_phrase(tokens, phrase):
            score += 5.0
    score += 4.0 * sum(1 for t in tokens if t in lexicon.VAGUE_TOPICS)
    score += 2.0 * sum(1 for t in tokens if t in ("general", "overall", "broad"))
    return score


def open_score(tokens, tags):
    """Open-ended questions lacking a single definitive answer."""
    score = 0.0
    if tokens and tokens[0] in ("what", "why", "how"):
        score += 3.0
        if "of" in tokens:
            score += 2.0
    score += 3.0 * sum(1 for t in tokens if t in lexicon.OPEN_MARKERS)
    if _contains_phrase(tokens, ("do", "you", "think")):
        score += 3.0
    return score


def multipart_score(tokens, tags):
    """Multiple sub-questions/topics demanding compound answers."""
    n_comma = sum(1 for t in tokens if t == ",")
    n_q = sum(1 for t in tokens if t == "?")
    is_question = n_q > 0 or (tokens and tokens[0] in lexicon.WH_WORDS)
    n_and = sum(1 for t in tokens if t == "and") if is_question else 0
    n_marker = sum(1 for t in tokens if t in lexicon.MULTIPART_MARKERS)
    return 2.0 * n_comma + 2.0 * n_and + 4.0 * max(0, n_q - 1) + 3.0 * n_marker


SCORERS = (
    structural_score,
    syntactic_score,
    semantic_score,
    vague_score,
    open_score,
    multipart_score,
)


def rule_scores(text: str):
    """Six raw rule scores for an input text."""
    tokens = tokenize(text)
    tags = pos_tag(tokens)
    return [scorer(tokens, tags) for scorer in SCORERS]


def features(text: str):
    """Full (unnormalised) feature vector: six scores + input length."""
    tokens = tokenize(text)
    tags = pos_tag(tokens)
    feats = [scorer(tokens, tags) for scorer in SCORERS]
    feats.append(float(min(len(tokens), MAX_INPUT_LEN)))
    return feats


def single_rule_score(text: str):
    """The paper's 'single rule' heuristic (Fig. 2b): the dominant rule
    score, falling back to input length when no pattern fires."""
    feats = features(text)
    best = max(feats[:6])
    return best if best > 0.0 else feats[6]
