"""AOT build: python runs ONCE here, never on the request path.

``python -m compile.aot --out-dir ../artifacts`` produces everything the
rust coordinator needs:

- ``lexicon.json``        word lists + vocab + tagger rules (rust mirror input)
- ``corpus/*.jsonl``      synthetic train/test splits + Fig.1a observation set
- ``goldens/*.jsonl``     tokenizer/PoS/RULEGEN cross-checks for the rust tests
- ``regressor.bin``       trained LW-regressor weights (tensor bundle)
- ``regressor_b*.hlo.txt``  LW regressor forward, AOT-lowered per batch bucket
- ``models/<name>/weights.bin`` + ``prefill_b*_s*.hlo.txt`` / ``decode_b*.hlo.txt``
- ``manifest.json``       the contract: shapes, param order, file map, coefficients

HLO *text* is the interchange format (not ``.serialize()``): jax >= 0.5
emits protos with 64-bit instruction ids that the crate's xla_extension
0.5.1 rejects; the text parser reassigns ids (see /opt/xla-example).
"""

import argparse
import zlib
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import corpus, lexicon, model, regressor, rulegen
from .bundle import write_bundle
from .common import (
    BOS_ID,
    DATASET_NAMES,
    DECODE_BATCH_BUCKETS,
    EOS_ID,
    FEATURE_NAMES,
    FEATURE_SCALES,
    LENGTH_INPUT_COEF,
    LENGTH_MODEL,
    LENGTH_NOISE_STD,
    MAX_INPUT_LEN,
    MAX_OUTPUT_LEN,
    MIN_OUTPUT_LEN,
    MODEL_CONFIGS,
    N_FEATURES,
    OBSERVATION_PER_TYPE,
    PAD_ID,
    PREFILL_BATCH_BUCKETS,
    PREFILL_SEQ_BUCKETS,
    REGRESSOR_BATCH_BUCKETS,
    REGRESSOR_HIDDEN,
    SEED,
    SEQ_MAX,
    TEST_PER_DATASET,
    TRAIN_PER_DATASET,
    UNCERTAINTY_TYPES,
    UNK_ID,
    VOCAB_SIZE,
)
from .kernels.regressor import regressor_mlp
from .textproc import Vocab, pos_tag, tokenize, _SUFFIX_RULES

# in-graph decode chunk length (perf: cache round-trips once per K tokens)
CHUNK_K = 8


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the rust-loadable form)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def write_jsonl(path, records):
    with open(path, "w") as f:
        for rec in records:
            f.write(json.dumps(rec, sort_keys=True) + "\n")


# ---------------------------------------------------------------------------
# Stages
# ---------------------------------------------------------------------------


def export_lexicon(out_dir):
    vocab = Vocab()
    data = {
        "vocab": vocab.id_to_word,
        "pos_lexicon": lexicon.pos_lexicon(),
        "suffix_rules": [[s, t] for s, t in _SUFFIX_RULES],
        "nv_ambiguous": list(lexicon.NV_AMBIGUOUS),
        "homonyms": lexicon.HOMONYMS,
        "vague_topics": list(lexicon.VAGUE_TOPICS),
        "vague_phrases": [list(p) for p in lexicon.VAGUE_PHRASES],
        "open_markers": list(lexicon.OPEN_MARKERS),
        "multipart_markers": list(lexicon.MULTIPART_MARKERS),
        "relativizers": list(lexicon.RELATIVIZERS),
        "wh_words": list(lexicon.WH_WORDS),
        "vague_adjectives": ["general", "overall", "broad"],
        "open_wh_starters": ["what", "why", "how"],
    }
    with open(os.path.join(out_dir, "lexicon.json"), "w") as f:
        json.dump(data, f, sort_keys=True)
    return vocab


def _with_features(records):
    for rec in records:
        rec["features"] = rulegen.features(rec["text"])
    return records


def build_corpus(out_dir, quick=False):
    cdir = os.path.join(out_dir, "corpus")
    os.makedirs(cdir, exist_ok=True)
    n_train = 100 if quick else TRAIN_PER_DATASET
    n_test = 50 if quick else TEST_PER_DATASET
    n_obs = 50 if quick else OBSERVATION_PER_TYPE

    files = {"train": {}, "test": {}}
    train_records = []
    for i, ds in enumerate(DATASET_NAMES):
        tr = _with_features(corpus.generate_split(ds, n_train, SEED + 11 * i))
        te = _with_features(corpus.generate_split(ds, n_test, SEED + 11 * i + 5))
        write_jsonl(os.path.join(cdir, f"train_{ds}.jsonl"), tr)
        write_jsonl(os.path.join(cdir, f"test_{ds}.jsonl"), te)
        files["train"][ds] = f"corpus/train_{ds}.jsonl"
        files["test"][ds] = f"corpus/test_{ds}.jsonl"
        train_records.extend(tr)

    obs = _with_features(corpus.generate_observation_set(n_obs, SEED + 999))
    write_jsonl(os.path.join(cdir, "observation.jsonl"), obs)
    files["observation"] = "corpus/observation.jsonl"
    return files, train_records


def build_goldens(out_dir, vocab):
    gdir = os.path.join(out_dir, "goldens")
    os.makedirs(gdir, exist_ok=True)

    # A sample covering every generator plus hand-written edge cases.
    samples = [
        "Can you tell me the history of art?",
        "John saw a boy in the park with a telescope.",
        "Rice flies like sand.",
        "What's the best way to deal with bats?",
        "What are the causes and consequences of poverty in developing countries?",
        "How do cats and dogs differ in behavior, diet, and social interaction?",
        "I love pizza.",
        "",
        "  multiple   spaces  and, punctuation!! here?",
        "tell me about the philosophy of time .",
    ]
    import random as _random

    rng = _random.Random(SEED + 777)
    for utype in UNCERTAINTY_TYPES:
        for _ in range(30):
            samples.append(corpus.GENERATORS[utype](rng))

    records = []
    for text in samples:
        toks = tokenize(text)
        records.append(
            {
                "text": text,
                "tokens": toks,
                "tags": pos_tag(toks),
                "ids": vocab.encode(text),
                "features": rulegen.features(text),
            }
        )
    write_jsonl(os.path.join(gdir, "textproc_golden.jsonl"), records)
    return {"textproc": "goldens/textproc_golden.jsonl"}


def train_regressor_stage(out_dir, train_records, quick=False):
    feats = np.asarray([r["features"] for r in train_records], np.float32)
    # Target: mean output length across the five LMs (the paper's Fig. 2
    # correlates against the cross-LM average output length).
    targets = np.asarray(
        [np.mean(list(r["lens"].values())) for r in train_records], np.float32
    )
    epochs = 10 if quick else 100
    t0 = time.time()
    params, history = regressor.train(feats, targets, seed=SEED & 0xFFFF, epochs=epochs)
    train_secs = time.time() - t0

    tensors = []
    param_names = []
    for i, (w, b) in enumerate(params):
        tensors.append((f"w{i}", np.asarray(w)))
        tensors.append((f"b{i}", np.asarray(b)))
        param_names += [f"w{i}", f"b{i}"]
    write_bundle(os.path.join(out_dir, "regressor.bin"), tensors)

    # Fit the 'weighted rule' linear model (Fig. 2c baseline) on the same
    # split: output_len ~ w . features + c, via least squares.
    a = np.concatenate([feats, np.ones((feats.shape[0], 1), np.float32)], axis=1)
    coef, *_ = np.linalg.lstsq(a, targets, rcond=None)

    # Lower the regressor forward per batch bucket, weights as parameters.
    def fwd(params_flat, raw_feats):
        ps = [(params_flat[2 * i], params_flat[2 * i + 1]) for i in range(len(params_flat) // 2)]
        normed = raw_feats / jnp.asarray(FEATURE_SCALES, jnp.float32)
        return (regressor_mlp(normed, ps),)

    hlo_files = {}
    for b in REGRESSOR_BATCH_BUCKETS:
        specs = [jax.ShapeDtypeStruct(np.asarray(t).shape, jnp.float32) for _, t in tensors]
        feat_spec = jax.ShapeDtypeStruct((b, N_FEATURES), jnp.float32)
        lowered = jax.jit(fwd).lower(specs, feat_spec)
        path = f"regressor_b{b}.hlo.txt"
        with open(os.path.join(out_dir, path), "w") as f:
            f.write(to_hlo_text(lowered))
        hlo_files[str(b)] = path

    final_loss = history[-1] if history else float("nan")
    return {
        "weights": "regressor.bin",
        "param_names": param_names,
        "layer_sizes": list(regressor.LAYER_SIZES),
        "hlo": hlo_files,
        "train_seconds": train_secs,
        "train_epochs": epochs,
        "final_train_mse": final_loss,
        "weighted_rule": {"coef": coef[:-1].tolist(), "intercept": float(coef[-1])},
    }


def build_model_stage(out_dir, name, quick=False):
    cfg = MODEL_CONFIGS[name]
    mdir = os.path.join(out_dir, "models", name)
    os.makedirs(mdir, exist_ok=True)

    name_seed = zlib.crc32(name.encode()) & 0xFFFF
    params = model.init_params(cfg, SEED ^ name_seed)
    names = model.param_names(cfg)
    write_bundle(
        os.path.join(mdir, "weights.bin"),
        [(n, np.asarray(p)) for n, p in zip(names, params)],
    )

    param_specs = [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in params]
    entry = {
        "config": {
            "n_layers": cfg.n_layers,
            "d_model": cfg.d_model,
            "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff,
        },
        "eta": cfg.eta,
        "phi": cfg.phi,
        "gamma": cfg.gamma,
        "delta": cfg.delta,
        "weights": f"models/{name}/weights.bin",
        "param_names": names,
        "prefill": {},
        "decode": {},
    }

    prefill_bs = PREFILL_BATCH_BUCKETS[:2] if quick else PREFILL_BATCH_BUCKETS
    prefill_ss = PREFILL_SEQ_BUCKETS[:1] if quick else PREFILL_SEQ_BUCKETS
    decode_bs = DECODE_BATCH_BUCKETS[:2] if quick else DECODE_BATCH_BUCKETS

    pf = functools.partial(model.prefill, cfg)
    for b in prefill_bs:
        for s in prefill_ss:
            t0 = time.time()
            lowered = jax.jit(pf).lower(
                param_specs,
                jax.ShapeDtypeStruct((b, s), jnp.int32),
                jax.ShapeDtypeStruct((b,), jnp.int32),
            )
            rel = f"models/{name}/prefill_b{b}_s{s}.hlo.txt"
            with open(os.path.join(out_dir, rel), "w") as f:
                f.write(to_hlo_text(lowered))
            entry["prefill"][f"{b},{s}"] = rel
            print(f"  prefill b={b} s={s}: {time.time()-t0:.1f}s")

    dc = functools.partial(model.decode_step, cfg)
    for b in decode_bs:
        t0 = time.time()
        cache = jax.ShapeDtypeStruct(
            (cfg.n_layers, b, cfg.n_heads, SEQ_MAX, cfg.head_dim), jnp.float32
        )
        lowered = jax.jit(dc).lower(
            param_specs,
            cache,
            cache,
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
        )
        rel = f"models/{name}/decode_b{b}.hlo.txt"
        with open(os.path.join(out_dir, rel), "w") as f:
            f.write(to_hlo_text(lowered))
        entry["decode"][str(b)] = rel
        print(f"  decode b={b}: {time.time()-t0:.1f}s")

    # multi-token chunks: K steps in-graph, cache stays on-device
    entry["decode_chunk"] = {}
    entry["chunk_k"] = CHUNK_K
    dchunk = functools.partial(model.decode_chunk, cfg, CHUNK_K)
    for b in decode_bs:
        t0 = time.time()
        cache = jax.ShapeDtypeStruct(
            (cfg.n_layers, b, cfg.n_heads, SEQ_MAX, cfg.head_dim), jnp.float32
        )
        lowered = jax.jit(dchunk).lower(
            param_specs,
            cache,
            cache,
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
        )
        rel = f"models/{name}/decode_chunk_b{b}_k{CHUNK_K}.hlo.txt"
        with open(os.path.join(out_dir, rel), "w") as f:
            f.write(to_hlo_text(lowered))
        entry["decode_chunk"][str(b)] = rel
        print(f"  decode_chunk b={b} k={CHUNK_K}: {time.time()-t0:.1f}s")

    return entry


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--models", nargs="*", default=list(MODEL_CONFIGS))
    ap.add_argument("--quick", action="store_true", help="small corpus / few buckets (tests only)")
    ap.add_argument("--skip-models", action="store_true", help="corpus + regressor only")
    args = ap.parse_args()

    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)
    t_start = time.time()

    print("[1/5] lexicon + vocab")
    vocab = export_lexicon(out_dir)

    print("[2/5] corpus")
    corpus_files, train_records = build_corpus(out_dir, quick=args.quick)

    print("[3/5] goldens")
    golden_files = build_goldens(out_dir, vocab)

    print("[4/5] LW regressor (train + lower)")
    regressor_entry = train_regressor_stage(out_dir, train_records, quick=args.quick)
    print(f"  final train MSE: {regressor_entry['final_train_mse']:.2f}")

    models_entry = {}
    if not args.skip_models:
        for i, name in enumerate(args.models):
            print(f"[5/5] model {name} ({i+1}/{len(args.models)})")
            models_entry[name] = build_model_stage(out_dir, name, quick=args.quick)

    manifest = {
        "version": 1,
        "seed": SEED,
        "vocab_size": VOCAB_SIZE,
        "pad_id": PAD_ID,
        "bos_id": BOS_ID,
        "eos_id": EOS_ID,
        "unk_id": UNK_ID,
        "seq_max": SEQ_MAX,
        "max_input_len": MAX_INPUT_LEN,
        "max_output_len": MAX_OUTPUT_LEN,
        "min_output_len": MIN_OUTPUT_LEN,
        "feature_names": list(FEATURE_NAMES),
        "feature_scales": list(FEATURE_SCALES),
        "uncertainty_types": list(UNCERTAINTY_TYPES),
        "length_model": {k: list(v) for k, v in LENGTH_MODEL.items()},
        "length_input_coef": LENGTH_INPUT_COEF,
        "length_noise_std": LENGTH_NOISE_STD,
        "regressor_hidden": list(REGRESSOR_HIDDEN),
        "buckets": {
            "prefill_batch": list(PREFILL_BATCH_BUCKETS),
            "prefill_seq": list(PREFILL_SEQ_BUCKETS),
            "decode_batch": list(DECODE_BATCH_BUCKETS),
            "regressor_batch": list(REGRESSOR_BATCH_BUCKETS),
        },
        "corpus": corpus_files,
        "goldens": golden_files,
        "regressor": regressor_entry,
        "models": models_entry,
        "lexicon": "lexicon.json",
        "quick": args.quick,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, sort_keys=True, indent=1)

    print(f"artifacts written to {out_dir} in {time.time()-t_start:.0f}s")


if __name__ == "__main__":
    main()
