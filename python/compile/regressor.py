"""The LW uncertainty regressor (Sec. III-B "Lightweight model").

A [7 -> 100 -> 200 -> 200 -> 100 -> 1] ReLU MLP mapping normalised RULEGEN
features to the predicted output length. Training is pure JAX (Adam,
hand-rolled — optax is not available offline), mirroring Algorithm 1's
offline-profiling phase: minimise MSE against the LM output lengths on
the training split.
"""

import jax
import jax.numpy as jnp
import numpy as np

from .common import FEATURE_SCALES, N_FEATURES, REGRESSOR_HIDDEN
from .kernels.ref import regressor_mlp_ref

LAYER_SIZES = (N_FEATURES,) + REGRESSOR_HIDDEN + (1,)


def init_regressor(seed: int):
    """[(w, b), ...] with He init, in layer order."""
    rng = np.random.default_rng(seed)
    params = []
    for fan_in, fan_out in zip(LAYER_SIZES[:-1], LAYER_SIZES[1:]):
        w = (rng.standard_normal((fan_in, fan_out)) * np.sqrt(2.0 / fan_in)).astype(np.float32)
        b = np.zeros((fan_out,), np.float32)
        params.append((jnp.asarray(w), jnp.asarray(b)))
    return params


def normalize_features(feats):
    """feats: [..., N_FEATURES] raw RULEGEN features -> normalised."""
    return feats / jnp.asarray(FEATURE_SCALES, jnp.float32)


def predict(params, raw_feats):
    """raw (unnormalised) features [B, F] -> predicted lengths [B]."""
    return regressor_mlp_ref(normalize_features(raw_feats), params)


def _loss(params, x, y):
    pred = regressor_mlp_ref(x, params)
    return jnp.mean(jnp.square(pred - y))


def train(features, targets, seed=0, epochs=100, batch_size=256, lr=1e-3):
    """Adam training loop. features: [N, F] raw; targets: [N] lengths.

    Returns (params, history) where history is the per-epoch train loss.
    """
    x = normalize_features(jnp.asarray(features, jnp.float32))
    y = jnp.asarray(targets, jnp.float32)
    params = init_regressor(seed)

    flat = []
    for w, b in params:
        flat += [w, b]

    def unflatten(flat):
        return [(flat[2 * i], flat[2 * i + 1]) for i in range(len(flat) // 2)]

    m = [jnp.zeros_like(p) for p in flat]
    v = [jnp.zeros_like(p) for p in flat]
    b1, b2, eps = 0.9, 0.999, 1e-8

    grad_fn = jax.jit(jax.value_and_grad(lambda fl, xb, yb: _loss(unflatten(fl), xb, yb)))

    @jax.jit
    def adam_step(flat, m, v, grads, t):
        new_flat, new_m, new_v = [], [], []
        for p, mi, vi, g in zip(flat, m, v, grads):
            mi = b1 * mi + (1 - b1) * g
            vi = b2 * vi + (1 - b2) * jnp.square(g)
            mhat = mi / (1 - b1**t)
            vhat = vi / (1 - b2**t)
            new_flat.append(p - lr * mhat / (jnp.sqrt(vhat) + eps))
            new_m.append(mi)
            new_v.append(vi)
        return new_flat, new_m, new_v

    n = x.shape[0]
    rng = np.random.default_rng(seed)
    history = []
    t = 0
    for _ in range(epochs):
        order = rng.permutation(n)
        epoch_loss = 0.0
        n_batches = 0
        for start in range(0, n, batch_size):
            idx = order[start : start + batch_size]
            t += 1
            loss, grads = grad_fn(flat, x[idx], y[idx])
            flat, m, v = adam_step(flat, m, v, grads, t)
            epoch_loss += float(loss)
            n_batches += 1
        history.append(epoch_loss / max(n_batches, 1))
    return unflatten(flat), history
