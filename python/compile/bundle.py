"""Tensor-bundle binary format (``*.bin``) shared with the rust runtime.

Layout (little-endian):

    magic   8 bytes  b"RTLMTB01"
    count   u32      number of tensors
    per tensor:
        name_len  u16
        name      name_len bytes (utf-8)
        dtype     u8   (0 = f32, 1 = i32)
        ndim      u8
        dims      ndim * u32
        data      prod(dims) * 4 bytes raw

The rust reader lives in ``rust/src/runtime/bundle.rs``; keep the two in
lockstep.
"""

import struct

import numpy as np

MAGIC = b"RTLMTB01"
DTYPE_F32 = 0
DTYPE_I32 = 1


def write_bundle(path, tensors):
    """tensors: list of (name, np.ndarray with dtype float32 or int32)."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors:
            arr = np.ascontiguousarray(arr)
            if arr.dtype == np.float32:
                dt = DTYPE_F32
            elif arr.dtype == np.int32:
                dt = DTYPE_I32
            else:
                raise ValueError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", dt, arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes())


def read_bundle(path):
    """Inverse of write_bundle -> list of (name, np.ndarray)."""
    out = []
    with open(path, "rb") as f:
        if f.read(8) != MAGIC:
            raise ValueError("bad magic")
        (count,) = struct.unpack("<I", f.read(4))
        for _ in range(count):
            (name_len,) = struct.unpack("<H", f.read(2))
            name = f.read(name_len).decode("utf-8")
            dt, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            n = 1
            for d in dims:
                n *= d
            dtype = np.float32 if dt == DTYPE_F32 else np.int32
            arr = np.frombuffer(f.read(4 * n), dtype=dtype).reshape(dims)
            out.append((name, arr))
    return out
