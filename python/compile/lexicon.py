"""Lexicons shared by the corpus generator, the rule scorers (RULEGEN) and
the PoS-lite tagger.

The paper uses spaCy for tokenisation/PoS tagging inside RULEGEN
(Listing 1). spaCy is not available offline, so RT-LM substitutes a
deterministic lexicon + suffix-heuristic tagger; this module is the single
source of truth for its word lists. ``aot.py`` exports everything here to
``artifacts/lexicon.json`` so the rust runtime mirror
(``rust/src/textgen``) stays byte-identical with the python build path.
"""

# --- PoS-lite tag inventory -------------------------------------------------

TAG_NOUN = "NOUN"
TAG_VERB = "VERB"
TAG_ADJ = "ADJ"
TAG_ADV = "ADV"
TAG_PRON = "PRON"
TAG_DET = "DET"
TAG_ADP = "ADP"  # prepositions
TAG_CONJ = "CONJ"
TAG_WH = "WH"
TAG_PUNCT = "PUNCT"
TAG_OTHER = "OTHER"

WH_WORDS = ("what", "why", "how", "who", "whom", "whose", "which", "when", "where")

PREPOSITIONS = (
    "in", "on", "at", "with", "by", "for", "from", "to", "of", "about",
    "into", "over", "under", "between", "through", "during", "against",
    "across", "behind", "beyond", "near", "without", "within",
)

DETERMINERS = ("the", "a", "an", "this", "that", "these", "those", "some", "any", "each", "every", "no")

CONJUNCTIONS = ("and", "or", "but", "nor", "so", "yet", "both", "either", "neither")

PRONOUNS = (
    "i", "you", "he", "she", "it", "we", "they", "me", "him", "her", "us",
    "them", "my", "your", "his", "its", "our", "their", "myself", "yourself",
)

COMMON_VERBS = (
    "is", "am", "are", "was", "were", "be", "been", "being", "do", "does",
    "did", "have", "has", "had", "can", "could", "will", "would", "shall",
    "should", "may", "might", "must", "saw", "see", "seen", "tell", "told",
    "say", "said", "think", "thought", "know", "knew", "want", "wanted",
    "go", "went", "gone", "get", "got", "make", "made", "take", "took",
    "eat", "ate", "love", "loved", "hate", "talk", "talked", "deal", "ask",
    "asked", "describe", "explain", "compare", "differ", "feel", "felt",
    "give", "gave", "find", "found", "help", "look", "looked", "come",
    "came", "work", "worked", "live", "lived", "enjoy", "enjoyed",
)

COMMON_ADJECTIVES = (
    "good", "bad", "big", "small", "new", "old", "long", "short", "best",
    "worst", "favorite", "great", "nice", "happy", "sad", "young", "broad",
    "general", "overall", "main", "major", "common", "different", "similar",
    "important", "interesting", "difficult", "easy", "beautiful", "strange",
)

COMMON_ADVERBS = ("very", "really", "quite", "always", "never", "often", "sometimes", "usually", "also", "too", "not")

# --- Ambiguity lexicons -----------------------------------------------------

# Words that read as noun OR verb (syntactic / part-of-speech ambiguity).
NV_AMBIGUOUS = (
    "flies", "like", "watch", "play", "run", "walk", "duck", "rose", "saw",
    "park", "bear", "train", "fly", "ship", "point", "light", "fire",
    "cook", "dance", "plant", "hide", "wave", "stick", "ring", "swing",
)

# Homonyms with their (approximate) sense counts — semantic ambiguity.
HOMONYMS = {
    "bat": 3,
    "bats": 3,
    "trunk": 4,
    "monitor": 3,
    "bank": 3,
    "spring": 4,
    "crane": 3,
    "pitcher": 2,
    "bark": 3,
    "seal": 3,
    "bolt": 3,
    "match": 3,
    "mouse": 2,
    "key": 3,
    "note": 3,
    "club": 3,
    "scale": 4,
    "organ": 2,
    "palm": 2,
    "ruler": 2,
    "letter": 2,
    "wave": 2,
    "right": 3,
    "kind": 2,
    "mine": 2,
    "bright": 2,
}

# Broad/vague topic nouns (vague expressions, Listing 1 style).
VAGUE_TOPICS = (
    "history", "art", "culture", "life", "society", "science", "future",
    "nature", "technology", "philosophy", "music", "politics", "economy",
    "education", "world", "universe", "humanity", "progress", "freedom",
    "happiness", "knowledge", "reality", "time", "existence",
)

# Trigger phrases for vague expressions (token sequences).
VAGUE_PHRASES = (
    ("tell", "me", "about"),
    ("what", "do", "you", "think", "about"),
    ("talk", "about"),
    ("describe",),
    ("explain",),
)

# Open-endedness markers.
OPEN_MARKERS = (
    "causes", "consequences", "effects", "impact", "implications",
    "meaning", "purpose", "significance", "origins", "reasons",
)

# Multi-part / enumeration markers.
MULTIPART_MARKERS = ("both", "respectively", "differ", "compare", "aspects", "ways")

# Relativizers used by the structural-ambiguity scorer.
RELATIVIZERS = ("that", "which", "who")

# --- Corpus-generation word pools -------------------------------------------

PLAIN_SUBJECTS = ("i", "you", "we", "they", "he", "she", "my friend", "my sister", "my brother", "the teacher")
PLAIN_VERBS = ("like", "love", "enjoy", "want", "have", "see", "know", "remember", "need", "prefer")
PLAIN_OBJECTS = (
    "pizza", "coffee", "books", "movies", "music", "dogs", "cats", "games",
    "tea", "flowers", "sports", "cooking", "reading", "hiking", "puzzles",
    "gardens", "photos", "trains", "bikes", "stories",
)

CONCRETE_NOUNS = (
    "boy", "girl", "man", "woman", "dog", "cat", "bird", "telescope",
    "telescope", "hat", "book", "ball", "kite", "camera", "umbrella",
    "ladder", "basket", "bench", "boat", "lamp", "jacket", "drum",
)

PLACES = ("park", "garden", "street", "house", "school", "office", "market", "beach", "forest", "station")

COUNTRY_TOPICS = (
    "developing countries", "modern cities", "rural areas", "small towns",
    "coastal regions", "big families", "old villages", "global markets",
)

COMPARE_PAIRS = (
    ("cats", "dogs"),
    ("trains", "planes"),
    ("books", "movies"),
    ("coffee", "tea"),
    ("summer", "winter"),
    ("cities", "villages"),
    ("phones", "laptops"),
    ("rivers", "lakes"),
)

COMPARE_ASPECTS = (
    "behavior", "diet", "social interaction", "cost", "speed", "comfort",
    "culture", "climate", "size", "history", "noise", "taste",
)

FILLER_WORDS = (
    "maybe", "perhaps", "honestly", "actually", "basically", "certainly",
    "probably", "apparently", "definitely", "surely",
)

# Words used by corpus templates that no other pool covers (the vocab
# must contain every word any generator can emit — tested by
# `vocab_covers_corpus`).
TEMPLATE_WORDS = (
    "fast", "interaction", "next", "poverty", "rice", "sand", "shapes",
    "social", "such", "terms", "watched", "water", "way", "what's",
    "yesterday", "more", "like", "lot", "up",
)


def pos_lexicon():
    """word -> tag map for the PoS-lite tagger (first match wins)."""
    lex = {}
    for w in WH_WORDS:
        lex[w] = TAG_WH
    for w in PREPOSITIONS:
        lex.setdefault(w, TAG_ADP)
    for w in DETERMINERS:
        lex.setdefault(w, TAG_DET)
    for w in CONJUNCTIONS:
        lex.setdefault(w, TAG_CONJ)
    for w in PRONOUNS:
        lex.setdefault(w, TAG_PRON)
    for w in COMMON_VERBS:
        lex.setdefault(w, TAG_VERB)
    for w in COMMON_ADJECTIVES:
        lex.setdefault(w, TAG_ADJ)
    for w in COMMON_ADVERBS:
        lex.setdefault(w, TAG_ADV)
    return lex


def all_words():
    """Every word any generator or lexicon can emit (vocabulary seed).

    Multi-word pool entries (e.g. "social interaction") are split so the
    vocabulary holds individual tokens.
    """
    words = set()
    for pool in (
        WH_WORDS, PREPOSITIONS, DETERMINERS, CONJUNCTIONS, PRONOUNS,
        COMMON_VERBS, COMMON_ADJECTIVES, COMMON_ADVERBS, NV_AMBIGUOUS,
        VAGUE_TOPICS, OPEN_MARKERS, MULTIPART_MARKERS, RELATIVIZERS,
        CONCRETE_NOUNS, PLACES, PLAIN_VERBS, PLAIN_OBJECTS, COMPARE_ASPECTS,
        FILLER_WORDS, TEMPLATE_WORDS, PLAIN_SUBJECTS, COUNTRY_TOPICS,
    ):
        for entry in pool:
            words.update(entry.split())
    words.update(HOMONYMS)
    for phrase in VAGUE_PHRASES:
        words.update(phrase)
    for a, b in COMPARE_PAIRS:
        words.update(a.split())
        words.update(b.split())
    words.update([",", "?", ".", "!", "'s", "s"])
    return sorted(words)
