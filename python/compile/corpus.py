"""Synthetic dialogue corpus generator.

Substitute for the paper's four HuggingFace benchmark datasets (Blended
Skill Talk, PersonaChat, ConvAI2, Empathetic Dialogues), which are not
available offline. Each generated utterance carries:

- a primary uncertainty type and template-generated text whose RULEGEN
  features genuinely reflect that type,
- a ground-truth *base* output length drawn from the per-type length model
  (calibrated to the relative ordering in the paper's Fig. 1a),
- per-LM actual output lengths (round(gamma_f * base + delta_f) + noise),
  mirroring that the five LMs respond with systematically different
  verbosity.

The LM decode loop then generates exactly that many real tokens (the
"length oracle") — see DESIGN.md §Substitutions.
"""

import random

from . import lexicon
from .common import (
    DATASET_MIXTURES,
    LENGTH_INPUT_COEF,
    LENGTH_MODEL,
    LENGTH_NOISE_STD,
    MAX_OUTPUT_LEN,
    MIN_OUTPUT_LEN,
    MODEL_CONFIGS,
    UNCERTAINTY_TYPES,
)
from .textproc import tokenize

# ---------------------------------------------------------------------------
# Utterance templates per uncertainty type
# ---------------------------------------------------------------------------


def _gen_plain(rng):
    subj = rng.choice(lexicon.PLAIN_SUBJECTS)
    verb = rng.choice(lexicon.PLAIN_VERBS)
    obj = rng.choice(lexicon.PLAIN_OBJECTS)
    forms = [
        f"{subj} {verb} {obj} .",
        f"{subj} really {verb} {obj} .",
        f"{subj} {verb} {obj} and {rng.choice(lexicon.PLAIN_OBJECTS)} .",
        f"do you {verb} {obj} ?",
    ]
    return rng.choice(forms)


def _gen_structural(rng):
    subj = rng.choice(lexicon.PLAIN_SUBJECTS)
    n1 = rng.choice(lexicon.CONCRETE_NOUNS)
    place = rng.choice(lexicon.PLACES)
    n2 = rng.choice(lexicon.CONCRETE_NOUNS)
    forms = [
        f"{subj} saw a {n1} in the {place} with a {n2} .",
        f"{subj} saw the {n1} near the {place} with a {n2} on the bench .",
        f"{subj} watched a {n1} by the {place} with a {n2} from the {rng.choice(lexicon.PLACES)} .",
    ]
    return rng.choice(forms)


def _gen_syntactic(rng):
    w1 = rng.choice(lexicon.NV_AMBIGUOUS)
    w2 = rng.choice(lexicon.NV_AMBIGUOUS)
    n = rng.choice(lexicon.CONCRETE_NOUNS)
    forms = [
        f"rice {w1} like sand .",
        f"{n} {w1} {w2} fast .",
        f"the {w1} {w2} near water .",
        f"{w1} {w2} can {rng.choice(lexicon.NV_AMBIGUOUS)} .",
    ]
    return rng.choice(forms)


def _gen_semantic(rng):
    h = rng.choice(list(lexicon.HOMONYMS))
    h2 = rng.choice(list(lexicon.HOMONYMS))
    forms = [
        f"what's the best way to deal with {h} ?",
        f"i found a {h} next to the {h2} yesterday .",
        f"can you help me with the {h} ?",
        f"the {h} was right by the {h2} .",
    ]
    return rng.choice(forms)


def _gen_vague(rng):
    topic = rng.choice(lexicon.VAGUE_TOPICS)
    topic2 = rng.choice(lexicon.VAGUE_TOPICS)
    forms = [
        f"tell me about the {topic} of {topic2} .",
        f"what do you think about {topic} ?",
        f"describe the {topic} of {topic2} in general .",
        f"tell me about {topic} .",
    ]
    return rng.choice(forms)


def _gen_open(rng):
    topic = rng.choice(lexicon.VAGUE_TOPICS)
    marker = rng.choice(lexicon.OPEN_MARKERS)
    where = rng.choice(lexicon.COUNTRY_TOPICS)
    forms = [
        f"what are the {marker} and {rng.choice(lexicon.OPEN_MARKERS)} of poverty in {where} ?",
        f"why does {topic} have such {marker} for {where} ?",
        f"what is the {marker} of {topic} ?",
        f"how do you think {topic} shapes the {marker} of {where} ?",
    ]
    return rng.choice(forms)


def _gen_multipart(rng):
    a, b = rng.choice(lexicon.COMPARE_PAIRS)
    aspects = rng.sample(list(lexicon.COMPARE_ASPECTS), 3)
    forms = [
        f"how do {a} and {b} differ in {aspects[0]} , {aspects[1]} , and {aspects[2]} ?",
        f"compare {a} and {b} in terms of {aspects[0]} and {aspects[1]} ?",
        f"what are {a} like , and how do they compare with {b} in {aspects[0]} ?",
    ]
    return rng.choice(forms)


GENERATORS = {
    "plain": _gen_plain,
    "structural": _gen_structural,
    "syntactic": _gen_syntactic,
    "semantic": _gen_semantic,
    "vague": _gen_vague,
    "open": _gen_open,
    "multipart": _gen_multipart,
}


# ---------------------------------------------------------------------------
# Ground-truth length model
# ---------------------------------------------------------------------------


def base_length(utype: str, input_len: int, rng) -> int:
    mean, std = LENGTH_MODEL[utype]
    raw = rng.gauss(mean, std) + LENGTH_INPUT_COEF * input_len
    return int(max(MIN_OUTPUT_LEN, min(MAX_OUTPUT_LEN, round(raw))))


def model_lengths(base: int, rng):
    """Per-LM actual output length derived from the base length."""
    lens = {}
    for name, cfg in MODEL_CONFIGS.items():
        raw = cfg.gamma * base + cfg.delta + rng.gauss(0.0, LENGTH_NOISE_STD)
        lens[name] = int(max(MIN_OUTPUT_LEN, min(MAX_OUTPUT_LEN, round(raw))))
    return lens


def make_utterance(utype: str, rng):
    """One corpus record (dict ready for JSONL)."""
    text = GENERATORS[utype](rng)
    input_len = len(tokenize(text))
    base = base_length(utype, input_len, rng)
    return {
        "text": text,
        "type": utype,
        "input_len": input_len,
        "base_len": base,
        "lens": model_lengths(base, rng),
    }


def generate_split(dataset: str, n: int, seed: int):
    """n utterances sampled from the dataset's type mixture."""
    rng = random.Random(seed)
    mixture = DATASET_MIXTURES[dataset]
    types = list(mixture)
    weights = [mixture[t] for t in types]
    out = []
    for _ in range(n):
        utype = rng.choices(types, weights=weights, k=1)[0]
        out.append(make_utterance(utype, rng))
    return out


def generate_observation_set(n_per_type: int, seed: int):
    """Fig. 1a study corpus: n utterances for each uncertainty type."""
    rng = random.Random(seed)
    out = []
    for utype in UNCERTAINTY_TYPES:
        for _ in range(n_per_type):
            out.append(make_utterance(utype, rng))
    return out
