"""Shared build-time constants for the RT-LM reproduction.

Everything the rust runtime needs to agree on (model shapes, bucket sets,
vocabulary layout, feature scales) is defined here once and exported into
``artifacts/manifest.json`` by ``aot.py``.
"""

from dataclasses import dataclass, field

# ---------------------------------------------------------------------------
# Global sequence / vocab layout
# ---------------------------------------------------------------------------

VOCAB_SIZE = 2048
PAD_ID = 0
BOS_ID = 1
EOS_ID = 2
UNK_ID = 3
N_SPECIAL = 4

MAX_INPUT_LEN = 64  # tokens, inputs longer than this are truncated
MAX_OUTPUT_LEN = 96  # tokens, the length oracle clamps here
SEQ_MAX = 176  # KV-cache capacity: input + output + slack

# Static shape buckets compiled ahead of time. The rust runtime pads a
# request (or batch) up to the nearest bucket.
PREFILL_SEQ_BUCKETS = (16, 32, 64)
PREFILL_BATCH_BUCKETS = (1, 4, 8)
DECODE_BATCH_BUCKETS = (1, 2, 4, 8, 16, 32)
REGRESSOR_BATCH_BUCKETS = (1, 16)

# ---------------------------------------------------------------------------
# Model variants (stand-ins for the paper's five HuggingFace LMs)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    """Decoder-only transformer configuration for one LM variant."""

    name: str
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    # Length-oracle calibration: actual output length for this LM is
    # round(gamma * base_len + delta) + noise, mirroring that the paper's
    # five LMs generate systematically different lengths (Fig. 1a).
    gamma: float
    delta: float
    # Paper's scheduling coefficients (Sec. V-A): eta projects output
    # tokens to seconds, phi projects input tokens to the priority point.
    eta: float
    phi: float

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


# Sizes are chosen so the per-token latency ordering matches the paper's
# eta coefficients (blenderbot slowest, godel/t5 fastest).
MODEL_CONFIGS = {
    "dialogpt": ModelConfig("dialogpt", 4, 256, 4, 1024, 1.00, 0.0, 0.05, 0.08),
    "godel": ModelConfig("godel", 3, 256, 4, 1024, 1.05, 1.0, 0.04, 0.10),
    "blenderbot": ModelConfig("blenderbot", 6, 320, 5, 1280, 1.10, 2.0, 0.10, 0.13),
    "bart": ModelConfig("bart", 4, 256, 4, 1280, 0.85, -1.0, 0.05, 0.08),
    "t5": ModelConfig("t5", 3, 192, 3, 768, 0.90, 0.0, 0.04, 0.07),
}

MODEL_NAMES = tuple(MODEL_CONFIGS)

# ---------------------------------------------------------------------------
# Uncertainty quantification
# ---------------------------------------------------------------------------

UNCERTAINTY_TYPES = (
    "plain",
    "structural",
    "syntactic",
    "semantic",
    "vague",
    "open",
    "multipart",
)

# Feature vector layout fed to the LW regressor: six rule scores plus the
# input length (the paper substitutes input length as the score for
# pattern-free sentences; we expose it as an explicit seventh feature).
FEATURE_NAMES = (
    "structural",
    "syntactic",
    "semantic",
    "vague",
    "open",
    "multipart",
    "input_len",
)
N_FEATURES = len(FEATURE_NAMES)

# Fixed normalisation scales applied before the MLP (features / scale).
FEATURE_SCALES = (10.0, 10.0, 10.0, 10.0, 10.0, 10.0, float(MAX_INPUT_LEN))

# LW regressor hidden sizes (paper Sec. V-A: [100, 200, 200, 100]).
REGRESSOR_HIDDEN = (100, 200, 200, 100)

# Ground-truth length model per uncertainty type: (mean, std) of the base
# output length before the input-length contribution. Ordering follows
# Fig. 1a: plain < structural ~ syntactic < semantic < vague < multipart
# < open.
LENGTH_MODEL = {
    "plain": (12.0, 3.0),
    "structural": (22.0, 5.0),
    "syntactic": (20.0, 5.0),
    "semantic": (30.0, 7.0),
    "vague": (38.0, 6.0),
    "open": (42.0, 7.0),
    "multipart": (40.0, 6.0),
}
# Additional contribution of the input length to the output length.
LENGTH_INPUT_COEF = 0.35
LENGTH_NOISE_STD = 3.0
MIN_OUTPUT_LEN = 4

# ---------------------------------------------------------------------------
# Benchmark dataset mixtures (synthetic stand-ins for the four HF corpora)
# ---------------------------------------------------------------------------

# type -> sampling weight per dataset flavour.
DATASET_MIXTURES = {
    "blended_skill_talk": {
        "plain": 0.30,
        "structural": 0.12,
        "syntactic": 0.10,
        "semantic": 0.12,
        "vague": 0.12,
        "open": 0.12,
        "multipart": 0.12,
    },
    "personachat": {
        "plain": 0.45,
        "structural": 0.10,
        "syntactic": 0.08,
        "semantic": 0.10,
        "vague": 0.10,
        "open": 0.09,
        "multipart": 0.08,
    },
    "convai2": {
        "plain": 0.40,
        "structural": 0.10,
        "syntactic": 0.10,
        "semantic": 0.10,
        "vague": 0.10,
        "open": 0.10,
        "multipart": 0.10,
    },
    "empathetic_dialogues": {
        "plain": 0.25,
        "structural": 0.08,
        "syntactic": 0.07,
        "semantic": 0.10,
        "vague": 0.15,
        "open": 0.25,
        "multipart": 0.10,
    },
}

DATASET_NAMES = tuple(DATASET_MIXTURES)

TRAIN_PER_DATASET = 1000
TEST_PER_DATASET = 400
OBSERVATION_PER_TYPE = 1000  # Fig. 1a study size

SEED = 0x52544C4D  # "RTLM"
