//! Quickstart: score utterances for uncertainty, then serve a small
//! batch through a real LM session with the full RT-LM scheduler.
//!
//!     make artifacts && cargo run --release --example quickstart

use std::sync::Arc;

use anyhow::Result;

use rtlm::config::{Manifest, SchedParams};
use rtlm::model::{session::encode_prompt, LmSession};
use rtlm::runtime::ArtifactStore;
use rtlm::scheduler::{Lane, PolicyKind, Task};
use rtlm::uncertainty::Estimator;

fn main() -> Result<()> {
    let store = Arc::new(ArtifactStore::open(&Manifest::default_root())?);
    let m = &store.manifest;
    let estimator = Estimator::new(
        store.lexicon.clone(),
        store.regressor.clone(),
        m.max_input_len,
        m.min_output_len as f64,
        m.max_output_len as f64,
    );

    // 1) Application level: quantify uncertainty (Eq. 1).
    let utterances = [
        "I love pizza.",
        "John saw a boy in the park with a telescope.",
        "Tell me about the history of art.",
        "What are the causes and consequences of poverty in developing countries?",
        "How do cats and dogs differ in behavior, diet, and social interaction?",
    ];
    println!("=== uncertainty scores (predicted output tokens) ===");
    let mut tasks = Vec::new();
    for (i, text) in utterances.iter().enumerate() {
        let (u, feats) = estimator.score_with_features(text)?;
        println!("u = {u:5.1}  {text}");
        tasks.push(Task {
            id: i as u64,
            text: text.to_string(),
            prompt: encode_prompt(&store, text),
            arrival: 0.0,
            priority_point: 2.0 + 0.08 * feats[6],
            uncertainty: u,
            true_len: (u.round() as usize).clamp(m.min_output_len, m.max_output_len),
            input_len: feats[6] as usize,
            utype: "quickstart".into(),
            malicious: false,
            deferrals: 0,
        });
    }

    // 2) System level: schedule with UASCHED (UP + consolidation).
    let params = SchedParams { batch_size: 4, ..Default::default() };
    let mut policy = PolicyKind::RtLm.build(&params, 0.05, f64::INFINITY);
    for task in tasks {
        policy.push(task);
    }

    // 3) Execute batches on a real PJRT session.
    let model = "t5";
    println!("\n=== serving on {model} (real PJRT execution) ===");
    let session = LmSession::new(store.clone(), model)?;
    let session = Arc::new(session);
    while let Some(batch) = policy.pop_batch(Lane::Gpu, 0.0, true) {
        let texts: Vec<_> = batch.tasks.iter().map(|t| t.text.clone()).collect();
        let report = rtlm::executor::execute_gpu(&session, &batch)?;
        println!(
            "batch of {} in {:.0} ms ({} decode steps):",
            report.task_ids.len(),
            report.infer_secs * 1e3,
            report.steps
        );
        for (text, out) in texts.iter().zip(&report.outputs) {
            println!("  [{} tokens] {} -> {}", out.len(), text, store.vocab.decode(out));
        }
    }
    Ok(())
}
