//! Quickstart: score utterances for uncertainty, schedule them with the
//! full RT-LM policy, then execute — on a real PJRT session when a
//! backend is available, else against the calibrated latency model.
//!
//!     make artifacts && cargo run --release --example quickstart

use std::sync::Arc;

use anyhow::Result;

use rtlm::config::{Manifest, SchedParams};
use rtlm::model::{session::encode_prompt, LmSession};
use rtlm::runtime::ArtifactStore;
use rtlm::scheduler::{Batch, LaneId, LaneSet, PolicyKind, Task, WHOLE_BATCH};
use rtlm::sim::LatencyModel;
use rtlm::uncertainty::Estimator;

fn main() -> Result<()> {
    let store = Arc::new(ArtifactStore::open(&Manifest::default_root())?);
    let m = &store.manifest;
    let estimator = Estimator::new(
        store.lexicon.clone(),
        store.regressor.clone(),
        m.max_input_len,
        m.min_output_len as f64,
        m.max_output_len as f64,
    );

    // 1) Application level: quantify uncertainty (Eq. 1).
    let utterances = [
        "I love pizza.",
        "John saw a boy in the park with a telescope.",
        "Tell me about the history of art.",
        "What are the causes and consequences of poverty in developing countries?",
        "How do cats and dogs differ in behavior, diet, and social interaction?",
    ];
    println!("=== uncertainty scores (predicted output tokens) ===");
    let mut tasks = Vec::new();
    for (i, text) in utterances.iter().enumerate() {
        let (u, feats) = estimator.score_with_features(text)?;
        println!("u = {u:5.1}  {text}");
        tasks.push(Task {
            id: i as u64,
            text: text.to_string(),
            prompt: encode_prompt(&store, text),
            arrival: 0.0,
            priority_point: 2.0 + 0.08 * feats[6],
            uncertainty: u,
            true_len: (u.round() as usize).clamp(m.min_output_len, m.max_output_len),
            input_len: feats[6] as usize,
            utype: "quickstart".into(),
            malicious: false,
            deferrals: 0,
            slo: rtlm::scheduler::SloClass::Standard,
        });
    }

    // 2) System level: schedule with UASCHED (UP + consolidation).
    let params = SchedParams { batch_size: 4, ..Default::default() };
    let lanes = LaneSet::two_lane("t5", f64::INFINITY);
    let mut policy = PolicyKind::RtLm.build(&params, 0.05, &lanes);
    for task in tasks {
        policy.push(task);
    }
    let mut batches: Vec<Batch> = Vec::new();
    while let Some(batch) = policy.pop(LaneId::GPU, 0.0, true, WHOLE_BATCH) {
        batches.push(batch);
    }
    println!("\n=== UASCHED batch plan (C = {}) ===", params.batch_size);
    for (i, batch) in batches.iter().enumerate() {
        let us: Vec<String> =
            batch.tasks.iter().map(|t| format!("{:.0}", t.uncertainty)).collect();
        println!("batch {i}: {} tasks, uncertainties [{}]", batch.tasks.len(), us.join(", "));
    }

    // 3) Execute: real PJRT session when available, calibrated latency
    // model otherwise (the in-tree xla stub has no backend).
    let model = "t5";
    match LmSession::new(store.clone(), model) {
        Ok(session) => {
            println!("\n=== serving on {model} (real PJRT execution) ===");
            let session = Arc::new(session);
            for batch in &batches {
                let report = rtlm::executor::execute_gpu(&session, batch)?;
                println!(
                    "batch of {} in {:.0} ms ({} decode steps):",
                    report.task_ids.len(),
                    report.infer_secs * 1e3,
                    report.steps
                );
                for (task, out) in batch.tasks.iter().zip(&report.outputs) {
                    println!(
                        "  [{} tokens] {} -> {}",
                        out.len(),
                        task.text,
                        store.vocab.decode(out)
                    );
                }
            }
        }
        Err(e) => {
            println!("\n=== {model} serving preview (no PJRT backend: {e:#}) ===");
            let lat = LatencyModel::load_or_analytic(m)?;
            let dev = rtlm::config::DeviceProfile::edge_server();
            let entry = m.model(model)?;
            for (i, batch) in batches.iter().enumerate() {
                let secs = lat.gpu_batch_secs(entry, batch, &dev);
                println!(
                    "batch {i}: {} tasks, modeled accelerator-lane time {:.0} ms",
                    batch.tasks.len(),
                    secs * 1e3
                );
            }
        }
    }
    Ok(())
}
