//! End-to-end serving driver (the repository's headline validation run):
//! load a real LM artifact, replay a Poisson arrival trace against the
//! full RT-LM scheduler **with real PJRT execution on every request**,
//! and compare latency/throughput against the FIFO baseline.
//!
//!     make artifacts && cargo run --release --example e2e_serving
//!
//! Options (env): RTLM_E2E_N (tasks, default 40), RTLM_E2E_MODEL
//! (default t5), RTLM_E2E_SCALE (arrival compression, default 12).
//! Results are recorded in EXPERIMENTS.md.

use std::sync::Arc;

use anyhow::Result;

use rtlm::config::{Manifest, SchedParams};
use rtlm::metrics::table::fmt_f;
use rtlm::metrics::{Samples, Table};
use rtlm::runtime::ArtifactStore;
use rtlm::scheduler::{LaneSet, PolicyKind};
use rtlm::server::engine::{encode_prompts, serve_from_root, ServeOptions};
use rtlm::sim::LatencyModel;
use rtlm::uncertainty::Estimator;
use rtlm::workload::subsets::{self, Variance};
use rtlm::workload::{corpus, ArrivalTrace, TaskFactory};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> Result<()> {
    let root = Manifest::default_root();
    let store = Arc::new(ArtifactStore::open(&root)?);
    let m = &store.manifest;
    let n = env_usize("RTLM_E2E_N", 40);
    let model_name = std::env::var("RTLM_E2E_MODEL").unwrap_or_else(|_| "t5".into());
    let time_scale = env_f64("RTLM_E2E_SCALE", 1.0);
    // ~65% of t5's calibrated service capacity: loaded but feasible
    let beta = env_f64("RTLM_E2E_BETA", 240.0);
    let seed = 7u64;

    let estimator = Estimator::new(
        store.lexicon.clone(),
        store.regressor.clone(),
        m.max_input_len,
        m.min_output_len as f64,
        m.max_output_len as f64,
    );

    // workload: normal-variance subset of the test corpus, Poisson trace
    let items = corpus::load_many(m.corpus_test.values())?;
    let scores: Vec<f64> = items
        .iter()
        .map(|i| estimator.score_features(&i.features))
        .collect::<Result<_>>()?;
    let variance = match std::env::var("RTLM_E2E_VARIANCE").as_deref() {
        Ok("small") => Variance::Small,
        Ok("normal") => Variance::Normal,
        _ => Variance::Large,
    };
    let chosen = subsets::select(&items, &scores, variance, n, seed);
    let trace = ArrivalTrace::poisson_fixed(n, beta, seed);
    let model = m.model(&model_name)?.clone();
    let mut factory = TaskFactory::new(estimator, 2.0);

    // offline decisions (Algorithm 1): C_f from calibration, tau from train
    // scores. Real mode uses k=0.98 (not the paper's 0.9): both lanes share
    // this machine's cores, so offloading adds no *extra* capacity the way
    // the paper's idle CPU did — quarantine only the truly extreme tail.
    let lat = LatencyModel::load_or_analytic(m)?;
    let params = SchedParams {
        batch_size: rtlm::bench_harness::scenarios::optimal_batch(&lat, &model_name),
        k: env_f64("RTLM_E2E_K", 0.98),
        // flat small-batch cost on CPU-PJRT: split only egregious mixes
        lambda: env_f64("RTLM_E2E_LAMBDA", 2.5),
        ..Default::default()
    };
    let mut train_scores = Samples::from_vec(scores);
    let tau = train_scores.quantile(params.k);

    println!(
        "e2e: model={model_name} n={n} beta={beta}/min scale={time_scale}x C_f={} tau={:.1}",
        params.batch_size, tau
    );

    let mut table = Table::new(
        "e2e real serving — RT-LM vs FIFO (real PJRT execution)",
        &["policy", "mean s", "p50 s", "p95 s", "max s", "thr/min", "gpu b.", "cpu b.", "sched us/task"],
    );
    let lanes = LaneSet::two_lane(&model_name, tau);
    for kind in [PolicyKind::Fifo, PolicyKind::RtLm] {
        let mut tasks = factory.build_all(&chosen, &trace, &model, false)?;
        encode_prompts(&store, &mut tasks);
        let mut policy = kind.build(&params, model.eta, &lanes);
        let opts = ServeOptions { time_scale, verbose: false, ..Default::default() };
        let report = serve_from_root(&root, &lanes, tasks, &mut *policy, &params, &opts)?;
        let mut s = report.response_times();
        table.row(vec![
            kind.label().into(),
            fmt_f(s.mean(), 3),
            fmt_f(s.p50(), 3),
            fmt_f(s.p95(), 3),
            fmt_f(s.max(), 3),
            fmt_f(report.throughput_per_min(), 1),
            report.n_batches.first().copied().unwrap_or(0).to_string(),
            report.n_batches.get(1).copied().unwrap_or(0).to_string(),
            fmt_f(report.sched_secs / report.outcomes.len().max(1) as f64 * 1e6, 1),
        ]);
    }
    table.print();
    println!("(paper claim: RT-LM reduces response time and raises throughput vs FIFO)");
    Ok(())
}
