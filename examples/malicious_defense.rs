//! Malicious-workload defense demo (paper Sec. V-G): adversarially
//! crafted inputs inflate LM output lengths; RT-LM's strategic
//! offloading quarantines them on the CPU lane while FIFO lets them
//! stall every batch.
//!
//!     cargo run --release --example malicious_defense

use std::sync::Arc;

use anyhow::Result;

use rtlm::bench_harness::scenarios::ExperimentCtx;
use rtlm::config::{DeviceProfile, Manifest};
use rtlm::metrics::table::fmt_f;
use rtlm::metrics::Table;
use rtlm::runtime::ArtifactStore;
use rtlm::scheduler::PolicyKind;
use rtlm::workload::{malicious, ArrivalTrace, TaskFactory};

fn main() -> Result<()> {
    let store = Arc::new(ArtifactStore::open(&Manifest::default_root())?);
    let ctx = ExperimentCtx::new(store, 300, 11)?;
    let model = ctx.model("dialogpt")?.clone();
    let dev = DeviceProfile::edge_server();

    // show the attack itself
    let mut rng = rtlm::util::rng::Pcg64::new(1);
    let items = ctx.all_test_items();
    let victim = &items[0];
    let crafted = malicious::craft(victim, ctx.manifest().max_output_len, &mut rng);
    println!("original : {} (true len {})", victim.text, victim.base_len);
    println!("crafted  : {} (true len {})", crafted.text, crafted.base_len);
    println!(
        "u-score  : {:.1} -> {:.1}\n",
        ctx.estimator.score(&victim.text)?,
        ctx.estimator.score(&crafted.text)?
    );

    let mut factory = TaskFactory::new(ctx.estimator.clone(), 2.0);
    let base: Vec<_> = items.into_iter().take(ctx.n_tasks).collect();

    let mut table = Table::new(
        "response time under attack (dialogpt, edge server, simulated)",
        &["malicious %", "FIFO mean s", "RT-LM mean s", "RT-LM offloaded"],
    );
    for pct in [0usize, 20, 40, 60, 80, 100] {
        let (crafted_items, _) = malicious::inject(
            &base,
            pct as f64 / 100.0,
            ctx.manifest().max_output_len,
            99 + pct as u64,
        );
        let step = ArrivalTrace::sweep_step_for(crafted_items.len(), 10, 150);
        let trace =
            ArrivalTrace::poisson_sweep_scaled(crafted_items.len(), 10, 150, step, 17);
        let tasks = factory.build_all(&crafted_items, &trace, &model, true)?;
        let fifo = ctx.run_policy(&model, tasks.clone(), PolicyKind::Fifo, &dev);
        let rtlm = ctx.run_policy(&model, tasks, PolicyKind::RtLm, &dev);
        let offloaded = rtlm
            .outcomes
            .iter()
            .filter(|o| o.lane == rtlm::scheduler::LaneId::CPU)
            .count();
        table.row(vec![
            pct.to_string(),
            fmt_f(fifo.mean_response(), 2),
            fmt_f(rtlm.mean_response(), 2),
            offloaded.to_string(),
        ]);
    }
    table.print();
    println!("(paper Fig. 14: FIFO degrades sharply past 30%; RT-LM stays steady)");
    Ok(())
}
