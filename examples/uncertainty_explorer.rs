//! Uncertainty-quantification explorer: walks the paper's application
//! level (Sec. III-B) — the six RULEGEN scorers, the single/weighted
//! rule baselines and the LW regressor — over the benchmark corpus and
//! prints how well each heuristic predicts output length (Fig. 2).
//!
//!     cargo run --release --example uncertainty_explorer [utterance..]

use std::sync::Arc;

use anyhow::Result;

use rtlm::bench_harness::scenarios::ExperimentCtx;
use rtlm::config::Manifest;
use rtlm::metrics::summary::pearson;
use rtlm::metrics::table::fmt_f;
use rtlm::metrics::Table;
use rtlm::runtime::ArtifactStore;
use rtlm::uncertainty::single_rule_score;

fn main() -> Result<()> {
    let store = Arc::new(ArtifactStore::open(&Manifest::default_root())?);
    let ctx = ExperimentCtx::new(store.clone(), 200, 5)?;
    let m = ctx.manifest();

    // interactive: score user-provided utterances
    let args: Vec<String> = std::env::args().skip(1).collect();
    if !args.is_empty() {
        let text = args.join(" ");
        let (u, feats) = ctx.estimator.score_with_features(&text)?;
        println!("text: {text}");
        for (name, v) in m.feature_names.iter().zip(feats.iter()) {
            println!("  {name:<12} {v:>7.2}");
        }
        println!("LW prediction: {u:.1} tokens");
        return Ok(());
    }

    // corpus study: heuristic quality per uncertainty type
    let items = ctx.all_test_items();
    let mut table = Table::new(
        "per-type mean LW prediction vs mean true output length",
        &["type", "n", "mean true len", "mean LW pred", "bias"],
    );
    for utype in &m.uncertainty_types {
        let of_type: Vec<_> = items.iter().filter(|i| &i.utype == utype).collect();
        if of_type.is_empty() {
            continue;
        }
        let true_mean: f64 =
            of_type.iter().map(|i| i.mean_len()).sum::<f64>() / of_type.len() as f64;
        let pred_mean: f64 = of_type
            .iter()
            .map(|i| ctx.estimator.score_features(&i.features).unwrap())
            .sum::<f64>()
            / of_type.len() as f64;
        table.row(vec![
            utype.clone(),
            of_type.len().to_string(),
            fmt_f(true_mean, 1),
            fmt_f(pred_mean, 1),
            format!("{:+.1}", pred_mean - true_mean),
        ]);
    }
    table.print();

    let truth: Vec<f64> = items.iter().map(|i| i.mean_len()).collect();
    let lw: Vec<f64> = items
        .iter()
        .map(|i| ctx.estimator.score_features(&i.features).unwrap())
        .collect();
    let input_len: Vec<f64> = items.iter().map(|i| i.input_len as f64).collect();
    let single: Vec<f64> = items
        .iter()
        .map(|i| single_rule_score(ctx.estimator.lexicon(), &i.text, m.max_input_len))
        .collect();
    println!("\ncorrelation with true output length (Fig. 2 summary):");
    println!("  input length : r = {}", fmt_f(pearson(&input_len, &truth), 3));
    println!("  single rule  : r = {}", fmt_f(pearson(&single, &truth), 3));
    println!("  LW model     : r = {}", fmt_f(pearson(&lw, &truth), 3));
    println!("\n(tip: pass an utterance as arguments to score it interactively)");
    Ok(())
}
