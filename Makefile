# RT-LM build/test driver.
#
#   make artifacts        full AOT build: corpus + regressor + 5 LM variants
#   make artifacts-quick  small corpus, fewer buckets (fast; tests still run)
#   make verify           tier-1 gate: cargo build/test + python tests
#   make bench            hotpath micro-benchmarks -> BENCH_hotpath.json
#   make clean-artifacts  remove the generated artifacts directory

PYTHON   ?= python3
CARGO    ?= cargo
ARTIFACTS ?= artifacts

.PHONY: artifacts artifacts-quick verify test bench clean-artifacts

# The manifest is the last file aot.py writes, so its presence means the
# whole artifact set is complete.
$(ARTIFACTS)/manifest.json:
	cd python && $(PYTHON) -m compile.aot --out-dir ../$(ARTIFACTS)

artifacts: $(ARTIFACTS)/manifest.json

artifacts-quick:
	cd python && $(PYTHON) -m compile.aot --out-dir ../$(ARTIFACTS) --quick

verify:
	$(CARGO) build --release
	$(CARGO) test -q
	cd python && $(PYTHON) -m pytest -q tests

test: verify

bench:
	$(CARGO) bench --bench hotpath

clean-artifacts:
	rm -rf $(ARTIFACTS)
