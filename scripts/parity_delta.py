#!/usr/bin/env python3
"""Render an `rtlm bench --wire` parity report as a markdown summary.

Usage:
    parity_delta.py parity.json

The input is the structured JSON `rtlm bench --wire --parity-out` writes
(`bench_harness::replay::parity_json`): per cell, the exact-match fields
(per-lane batch, task, decode-step and preemption counts on both
backends) and the toleranced response-time / TTFT statistics, plus any
rendered failures. Step-mode cells (`--sched step`) report batch counts
as join groups, which are not asserted — the step counters are their
exact-match discriminator.

Prints a per-cell verdict table, a per-lane count diff table, and every
failure verbatim. Exit code is 1 when any cell is not clean, so the CI
`parity gate` step fails even if the rust gate was bypassed — but the
primary gate is `rtlm bench --wire` itself, which exits nonzero on any
parity failure.
"""

import argparse
import json
import sys


def fmt_pair(sim: float, wire: float) -> str:
    return f"{sim:.2f} / {wire:.2f}"


def stat(cell: dict, name: str) -> dict | None:
    for entry in cell.get("stats", []):
        if entry.get("name") == name:
            return entry
    return None


def rel_err(entry: dict | None) -> str:
    if entry is None:
        return "-"
    scale = max(abs(entry.get("sim", 0.0)), abs(entry.get("wire", 0.0)))
    if scale <= 0:
        return "0.0%"
    return f"{abs(entry['sim'] - entry['wire']) / scale:.1%}"


def lane_counts(cell: dict, key: str) -> dict:
    return dict(zip(cell.get("lanes", []), cell.get(key, [])))


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("report", help="parity JSON from rtlm bench --wire --parity-out")
    args = ap.parse_args()

    with open(args.report) as fh:
        report = json.load(fh)
    cells = report.get("cells", [])
    n_fail = sum(1 for c in cells if not c.get("clean", False))

    print(
        f"### Sim-vs-wire parity ({len(cells)} cells, time-scale "
        f"{report.get('time_scale', '?')}x, tol ±{report.get('rel_tol', '?')} rel "
        f"+ {report.get('abs_secs', '?')} s abs)\n"
    )
    print(
        "| cell | policy | n | mean RT (sim/wire s) | Δ | p95 (sim/wire s) | Δ "
        "| ttft p95 (sim/wire s) | Δ | preempted (sim/wire) | status |"
    )
    print("|---|---|---:|---:|---:|---:|---:|---:|---:|---:|---|")
    for cell in cells:
        mean, p95 = stat(cell, "mean_response"), stat(cell, "p95_response")
        ttft = stat(cell, "p95_ttft")
        verdict = "✅ ok" if cell.get("clean") else f"❌ {len(cell.get('failures', []))} failures"
        mean_pair = fmt_pair(mean["sim"], mean["wire"]) if mean else "-"
        p95_pair = fmt_pair(p95["sim"], p95["wire"]) if p95 else "-"
        ttft_pair = fmt_pair(ttft["sim"], ttft["wire"]) if ttft else "-"
        preempt = f"{cell.get('sim_preempted', 0):.0f} / {cell.get('wire_preempted', 0):.0f}"
        print(
            f"| {cell.get('label', '?')} | {cell.get('policy', '?')} "
            f"| {cell.get('n_tasks', 0):.0f} | {mean_pair} | {rel_err(mean)} "
            f"| {p95_pair} | {rel_err(p95)} | {ttft_pair} | {rel_err(ttft)} "
            f"| {preempt} | {verdict} |"
        )

    print("\n### Per-lane counts (exact-match gate; steps gate step-mode cells)\n")
    print("| cell | lane | batches sim | batches wire | tasks sim | tasks wire "
          "| steps sim | steps wire |")
    print("|---|---|---:|---:|---:|---:|---:|---:|")
    for cell in cells:
        sim_b = lane_counts(cell, "sim_batches")
        wire_b = lane_counts(cell, "wire_batches")
        sim_t = lane_counts(cell, "sim_lane_tasks")
        wire_t = lane_counts(cell, "wire_lane_tasks")
        sim_s = lane_counts(cell, "sim_steps")
        wire_s = lane_counts(cell, "wire_steps")
        for lane in cell.get("lanes", []):
            mark = "" if sim_b.get(lane) == wire_b.get(lane) else " ⚠️"
            step_mark = "" if sim_s.get(lane) == wire_s.get(lane) else " ⚠️"
            print(
                f"| {cell.get('label', '?')} | {lane} | {sim_b.get(lane, 0):.0f} "
                f"| {wire_b.get(lane, 0):.0f}{mark} | {sim_t.get(lane, 0):.0f} "
                f"| {wire_t.get(lane, 0):.0f} | {sim_s.get(lane, 0):.0f} "
                f"| {wire_s.get(lane, 0):.0f}{step_mark} |"
            )

    failures = [(c.get("label", "?"), f) for c in cells for f in c.get("failures", [])]
    if failures:
        print("\n### Failures\n")
        for label, failure in failures:
            print(f"- `{label}`: {failure}")
        print(f"\n**{n_fail} of {len(cells)} cells diverged.**")
        return 1
    print(f"\nAll {len(cells)} cells parity-clean.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
