#!/usr/bin/env python3
"""Render an `rtlm gauntlet` JSON report as a markdown summary.

Usage:
    gauntlet_report.py gauntlet.json

The input is the deterministic report `rtlm gauntlet --out` writes
(`bench_harness::gauntlet::gauntlet_json`): one cell per policy ×
scenario pair, each carrying virtual-clock response/TTFT statistics,
the shed rate, per-SLO-class attainment rows, and (for wire-replayed
cells) the sim-vs-wire parity verdict.

Prints the comparison matrix plus a per-class attainment table, then
gates: exit code is 1 when the report has no cells, when any cell
carries an `error`, when any wire-replayed cell diverged, or when an
interactive class under the `nominal` scenario attained zero — the
canary for SLO plumbing silently breaking. Malformed cells (not a
dict, missing fields) are rendered as `??` rows and counted as errors
rather than crashing the renderer, so a truncated report still shows
whatever survived.
"""

import argparse
import json
import sys


def fmt_f(value, digits: int = 2) -> str:
    try:
        return f"{float(value):.{digits}f}"
    except (TypeError, ValueError):
        return "-"


def fmt_pct(value) -> str:
    try:
        return f"{float(value):.0%}"
    except (TypeError, ValueError):
        return "-"


def attainment(cell: dict, klass: str):
    for row in cell.get("slo", []):
        if isinstance(row, dict) and row.get("class") == klass:
            return row.get("attainment")
    return None


def cell_status(cell: dict) -> str:
    if cell.get("error") is not None:
        return f"ERROR: {cell['error']}"
    wire = cell.get("wire")
    if wire is None:
        return "ok"
    if wire.get("clean"):
        return "ok (wire)"
    return f"WIRE FAIL ({len(wire.get('failures', []))})"


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("report", help="gauntlet JSON from rtlm gauntlet --out")
    args = ap.parse_args()

    with open(args.report) as fh:
        report = json.load(fh)
    cells = report.get("cells", [])
    if not cells:
        print("gauntlet report has no cells", file=sys.stderr)
        return 1

    problems: list[str] = []
    print(
        f"### Scenario gauntlet ({len(cells)} cells, n={report.get('n', '?')} "
        f"tasks/cell, seed {report.get('seed', '?')}; virtual-clock metrics)\n"
    )
    print(
        "| scenario | policy | n | mean s | p95 s | p99 s | ttft p95 s | shed "
        "| int att | batch att | status |"
    )
    print("|---|---|---:|---:|---:|---:|---:|---:|---:|---:|---|")
    for cell in cells:
        if not isinstance(cell, dict):
            problems.append(f"malformed cell (not an object): {cell!r}")
            print("| ?? | ?? | - | - | - | - | - | - | - | - | MALFORMED |")
            continue
        scenario = cell.get("scenario", "??")
        policy = cell.get("policy", "??")
        status = cell_status(cell)
        if cell.get("error") is not None:
            problems.append(f"{scenario}/{policy}: {cell['error']}")
        elif cell.get("wire") is not None and not cell["wire"].get("clean"):
            fails = cell["wire"].get("failures", [])
            problems.append(f"{scenario}/{policy}: wire parity diverged ({len(fails)} failures)")
        int_att = attainment(cell, "interactive")
        if scenario == "nominal" and cell.get("error") is None:
            # the gate's canary: interactive traffic must attain under
            # nominal (under-capacity) load, whatever the policy
            if int_att is None:
                problems.append(f"{scenario}/{policy}: no interactive SLO row")
            elif not int_att > 0.0:
                problems.append(f"{scenario}/{policy}: zero interactive attainment")
        print(
            f"| {scenario} | {policy} | {fmt_f(cell.get('n_tasks'), 0)} "
            f"| {fmt_f(cell.get('mean_response'))} | {fmt_f(cell.get('p95_response'))} "
            f"| {fmt_f(cell.get('p99_response'))} | {fmt_f(cell.get('p95_ttft'))} "
            f"| {fmt_pct(cell.get('shed_rate'))} | {fmt_pct(int_att)} "
            f"| {fmt_pct(attainment(cell, 'batch'))} | {status} |"
        )

    print("\n### Per-class attainment (met / total; shed counts as a violation)\n")
    print("| scenario | policy | class | n | met | shed | attainment |")
    print("|---|---|---|---:|---:|---:|---:|")
    for cell in cells:
        if not isinstance(cell, dict) or cell.get("error") is not None:
            continue
        for row in cell.get("slo", []):
            if not isinstance(row, dict):
                continue
            print(
                f"| {cell.get('scenario', '??')} | {cell.get('policy', '??')} "
                f"| {row.get('class', '??')} | {fmt_f(row.get('n'), 0)} "
                f"| {fmt_f(row.get('met'), 0)} | {fmt_f(row.get('shed'), 0)} "
                f"| {fmt_pct(row.get('attainment'))} |"
            )

    if problems:
        print("\n### Problems\n")
        for problem in problems:
            print(f"- {problem}")
        print(f"\n**{len(problems)} problem(s) across {len(cells)} cells.**")
        return 1
    print(f"\nAll {len(cells)} cells clean.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
