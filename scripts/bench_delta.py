#!/usr/bin/env python3
"""Compare two BENCH_hotpath.json snapshots and print a markdown delta table.

Usage:
    bench_delta.py A.json B.json [--labels A-name B-name]

The snapshots are the hotpath bench's output: ``{"bench": "hotpath",
"unit": "seconds_per_iter", "artifacts": bool, "pjrt": bool,
"results": {name: seconds}}``. Benchmarks present in both snapshots are
printed sorted by the largest relative delta (B vs A), so the biggest
hot-path movement tops the table; benchmarks present in only one
snapshot (e.g. PJRT benches that need artifacts) are listed separately.

Exit code is always 0 — this is a visibility tool for the CI job
summary, not a gate; the gating happens in the test and load steps.
"""

import argparse
import json
import sys


def fmt_secs(secs: float) -> str:
    if secs < 1e-6:
        return f"{secs * 1e9:.1f} ns"
    if secs < 1e-3:
        return f"{secs * 1e6:.1f} us"
    if secs < 1.0:
        return f"{secs * 1e3:.2f} ms"
    return f"{secs:.3f} s"


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("snapshot_a")
    ap.add_argument("snapshot_b")
    ap.add_argument(
        "--labels",
        nargs=2,
        default=("A", "B"),
        metavar=("A_NAME", "B_NAME"),
        help="column labels for the two snapshots",
    )
    args = ap.parse_args()

    with open(args.snapshot_a) as fh:
        a = json.load(fh)
    with open(args.snapshot_b) as fh:
        b = json.load(fh)
    la, lb = args.labels
    ra, rb = a.get("results", {}), b.get("results", {})

    print(f"### Hot-path bench delta ({lb} vs {la})\n")
    print(
        f"unit: {a.get('unit', '?')} | {la}: artifacts={a.get('artifacts')}, "
        f"pjrt={a.get('pjrt')} | {lb}: artifacts={b.get('artifacts')}, "
        f"pjrt={b.get('pjrt')}\n"
    )

    common = sorted(set(ra) & set(rb))
    if common:

        def rel_delta(name: str) -> float:
            if ra[name] <= 0:
                return float("inf") if rb[name] > 0 else 0.0
            return rb[name] / ra[name] - 1.0

        common.sort(key=lambda name: -abs(rel_delta(name)))
        print(f"| benchmark | {la} | {lb} | delta |")
        print("|---|---:|---:|---:|")
        for name in common:
            delta = rel_delta(name)
            print(
                f"| {name} | {fmt_secs(ra[name])} | {fmt_secs(rb[name])} "
                f"| {delta:+.1%} |"
            )
    else:
        print("_no common benchmarks between the two snapshots_")

    only_a = sorted(set(ra) - set(rb))
    only_b = sorted(set(rb) - set(ra))
    if only_a:
        print(f"\nonly in {la}: " + ", ".join(only_a))
    if only_b:
        print(f"\nonly in {lb}: " + ", ".join(only_b))
    return 0


if __name__ == "__main__":
    sys.exit(main())
