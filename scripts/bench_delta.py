#!/usr/bin/env python3
"""Compare two BENCH_hotpath.json snapshots and print a markdown delta table.

Usage:
    bench_delta.py A.json B.json [--labels A-name B-name]

The snapshots are the hotpath bench's output: ``{"bench": "hotpath",
"unit": "seconds_per_iter", "artifacts": bool, "pjrt": bool,
"results": {name: seconds}, "batches": {name: {lane: count}}}``.
Benchmarks present in both snapshots are printed sorted by the largest
relative delta (B vs A), so the biggest hot-path movement tops the
table; benchmarks present in only one snapshot (e.g. PJRT benches that
need artifacts) are listed separately.

A second per-lane batch table is rendered from the ``batches`` map, and
a third table from the ``pop_depth_sweep`` map (``{depth: {"indexed":
secs, "keyed": secs}}``) — per-pop cost of the indexed UP queue vs the
historical keyed full re-sort at queue depths 10^3..10^6, with the
keyed/indexed speedup and the indexed series' growth per 10x depth (the
sub-linearity evidence). A fourth table renders the ``score_sweep``
map (``{label: {"tokens": n, "legacy": secs, "fast": secs}}``) —
admission-time RULEGEN scoring cost for short/median/long prompts,
legacy allocating pipeline vs the interned single-pass fast path, with
the speedup and the fast path's scores/sec. Older snapshots are handled
gracefully: a missing ``batches``/``pop_depth_sweep``/``score_sweep``
key skips its table, and legacy two-field reports carrying flat
``n_batches_gpu``/``n_batches_cpu`` counts are rendered as a gpu/cpu
row.

Exit code is always 0 — this is a visibility tool for the CI job
summary, not a gate; the gating happens in the test and load steps.
"""

import argparse
import json
import sys


def fmt_secs(secs: float) -> str:
    if secs < 1e-6:
        return f"{secs * 1e9:.1f} ns"
    if secs < 1e-3:
        return f"{secs * 1e6:.1f} us"
    if secs < 1.0:
        return f"{secs * 1e3:.2f} ms"
    return f"{secs:.3f} s"


def lane_batches(snapshot: dict) -> dict:
    """Per-lane batch counts of a snapshot, in every format we've shipped.

    New snapshots carry ``{"batches": {bench: {lane: count}}}``; legacy
    two-field reports carried flat ``n_batches_gpu``/``n_batches_cpu``
    integers at the top level. Returns ``{bench: {lane: count}}`` (the
    legacy form maps to a single ``"(report)"`` pseudo-bench); empty
    when the snapshot predates per-lane accounting entirely.
    """
    batches = snapshot.get("batches")
    if isinstance(batches, dict) and batches:
        return {
            bench: lanes
            for bench, lanes in batches.items()
            if isinstance(lanes, dict) and lanes
        }
    legacy = {}
    for key, lane in (("n_batches_gpu", "gpu"), ("n_batches_cpu", "cpu")):
        if isinstance(snapshot.get(key), (int, float)):
            legacy[lane] = snapshot[key]
    return {"(report)": legacy} if legacy else {}


def print_lane_table(a: dict, b: dict, la: str, lb: str) -> None:
    ba, bb = lane_batches(a), lane_batches(b)
    if not ba and not bb:
        return
    print("\n### Per-lane dispatched batches\n")
    print(f"| benchmark | lane | {la} | {lb} |")
    print("|---|---|---:|---:|")
    for bench in sorted(set(ba) | set(bb)):
        lanes_a, lanes_b = ba.get(bench, {}), bb.get(bench, {})
        for lane in sorted(set(lanes_a) | set(lanes_b)):
            fmt = lambda v: "-" if v is None else f"{v:.0f}"
            print(
                f"| {bench} | {lane} | {fmt(lanes_a.get(lane))} "
                f"| {fmt(lanes_b.get(lane))} |"
            )


def depth_sweep(snapshot: dict) -> dict:
    """``{depth: (indexed_secs, keyed_secs)}`` from ``pop_depth_sweep``."""
    sweep = snapshot.get("pop_depth_sweep")
    if not isinstance(sweep, dict):
        return {}
    out = {}
    for depth, series in sweep.items():
        try:
            out[int(depth)] = (float(series["indexed"]), float(series["keyed"]))
        except (KeyError, TypeError, ValueError):
            continue
    return out


def print_depth_sweep(a: dict, b: dict, la: str, lb: str) -> None:
    sa, sb = depth_sweep(a), depth_sweep(b)
    if not sa and not sb:
        return
    print("\n### Pop cost vs queue depth (indexed UpQueue vs keyed full-sort)\n")
    print(
        f"| depth | indexed {la} | indexed {lb} | keyed {la} | keyed {lb} "
        f"| keyed/indexed ({lb}) | indexed growth |"
    )
    print("|---:|---:|---:|---:|---:|---:|---:|")
    fmt = lambda v: "-" if v is None else fmt_secs(v)
    prev = None
    for depth in sorted(set(sa) | set(sb)):
        ia, ka = sa.get(depth, (None, None))
        ib, kb = sb.get(depth, (None, None))
        speedup = "-" if not ib or kb is None else f"{kb / ib:.0f}x"
        growth = "-" if not prev or ib is None else f"{ib / prev:.2f}x per 10x depth"
        print(
            f"| {depth} | {fmt(ia)} | {fmt(ib)} | {fmt(ka)} | {fmt(kb)} "
            f"| {speedup} | {growth} |"
        )
        if ib is not None:
            prev = ib


def score_sweep(snapshot: dict) -> dict:
    """``{label: (tokens, legacy_secs, fast_secs)}`` from ``score_sweep``."""
    sweep = snapshot.get("score_sweep")
    if not isinstance(sweep, dict):
        return {}
    out = {}
    for label, series in sweep.items():
        try:
            out[str(label)] = (
                int(series["tokens"]),
                float(series["legacy"]),
                float(series["fast"]),
            )
        except (KeyError, TypeError, ValueError):
            continue
    return out


def print_score_sweep(a: dict, b: dict, la: str, lb: str) -> None:
    sa, sb = score_sweep(a), score_sweep(b)
    if not sa and not sb:
        return
    print("\n### Admission scoring cost (legacy pipeline vs interned fast path)\n")
    print(
        f"| prompt | tokens | legacy {la} | legacy {lb} | fast {la} | fast {lb} "
        f"| speedup ({lb}) | fast scores/s ({lb}) |"
    )
    print("|---|---:|---:|---:|---:|---:|---:|---:|")
    fmt = lambda v: "-" if v is None else fmt_secs(v)
    # sort by prompt length so the table reads short -> long
    tokens_of = lambda label: (sa.get(label) or sb.get(label))[0]
    for label in sorted(set(sa) | set(sb), key=tokens_of):
        ta, la_legacy, la_fast = sa.get(label, (None, None, None))
        tb, lb_legacy, lb_fast = sb.get(label, (None, None, None))
        tokens = tb if tb is not None else ta
        speedup = "-" if not lb_fast or lb_legacy is None else f"{lb_legacy / lb_fast:.1f}x"
        rate = "-" if not lb_fast else f"{1.0 / lb_fast:,.0f}"
        print(
            f"| {label} | {tokens} | {fmt(la_legacy)} | {fmt(lb_legacy)} "
            f"| {fmt(la_fast)} | {fmt(lb_fast)} | {speedup} | {rate} |"
        )


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("snapshot_a")
    ap.add_argument("snapshot_b")
    ap.add_argument(
        "--labels",
        nargs=2,
        default=("A", "B"),
        metavar=("A_NAME", "B_NAME"),
        help="column labels for the two snapshots",
    )
    args = ap.parse_args()

    with open(args.snapshot_a) as fh:
        a = json.load(fh)
    with open(args.snapshot_b) as fh:
        b = json.load(fh)
    la, lb = args.labels
    ra, rb = a.get("results", {}), b.get("results", {})

    print(f"### Hot-path bench delta ({lb} vs {la})\n")
    print(
        f"unit: {a.get('unit', '?')} | {la}: artifacts={a.get('artifacts')}, "
        f"pjrt={a.get('pjrt')} | {lb}: artifacts={b.get('artifacts')}, "
        f"pjrt={b.get('pjrt')}\n"
    )

    common = sorted(set(ra) & set(rb))
    if common:

        def rel_delta(name: str) -> float:
            if ra[name] <= 0:
                return float("inf") if rb[name] > 0 else 0.0
            return rb[name] / ra[name] - 1.0

        common.sort(key=lambda name: -abs(rel_delta(name)))
        print(f"| benchmark | {la} | {lb} | delta |")
        print("|---|---:|---:|---:|")
        for name in common:
            delta = rel_delta(name)
            print(
                f"| {name} | {fmt_secs(ra[name])} | {fmt_secs(rb[name])} "
                f"| {delta:+.1%} |"
            )
    else:
        print("_no common benchmarks between the two snapshots_")

    only_a = sorted(set(ra) - set(rb))
    only_b = sorted(set(rb) - set(ra))
    if only_a:
        print(f"\nonly in {la}: " + ", ".join(only_a))
    if only_b:
        print(f"\nonly in {lb}: " + ", ".join(only_b))

    print_lane_table(a, b, la, lb)
    print_depth_sweep(a, b, la, lb)
    print_score_sweep(a, b, la, lb)
    return 0


if __name__ == "__main__":
    sys.exit(main())
