//! `cargo bench --bench hotpath`
//!
//! Micro-benchmarks of the L3 hot paths (criterion is not in the offline
//! crate set; this is a manual median-of-N harness with warmup):
//! RULEGEN feature extraction, LW regressor inference, UP priority
//! computation, scheduler push/pop, consolidation, and the simulator
//! engine itself.

use std::sync::Arc;
use std::time::Instant;

use rtlm::config::{DeviceProfile, Manifest, SchedParams};
use rtlm::runtime::ArtifactStore;
use rtlm::scheduler::{up_priority, Lane, PolicyKind, Task};
use rtlm::sim::{run_sim, LatencyModel};
use rtlm::uncertainty::{rules, Estimator};
use rtlm::util::rng::Pcg64;

/// median-of-samples timing: returns (median secs/iter, iters run).
fn bench<F: FnMut()>(name: &str, iters_per_sample: usize, mut f: F) {
    // warmup
    for _ in 0..iters_per_sample.min(100) {
        f();
    }
    let mut samples = Vec::with_capacity(15);
    for _ in 0..15 {
        let t0 = Instant::now();
        for _ in 0..iters_per_sample {
            f();
        }
        samples.push(t0.elapsed().as_secs_f64() / iters_per_sample as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let unit = if median < 1e-6 {
        format!("{:8.1} ns", median * 1e9)
    } else if median < 1e-3 {
        format!("{:8.2} us", median * 1e6)
    } else {
        format!("{:8.3} ms", median * 1e3)
    };
    println!("{name:<44} {unit}/iter  (median of 15x{iters_per_sample})");
}

fn mk_task(rng: &mut Pcg64, id: u64) -> Task {
    Task {
        id,
        text: String::new(),
        prompt: vec![],
        arrival: rng.f64() * 30.0,
        priority_point: rng.f64() * 30.0 + 2.0,
        uncertainty: 4.0 + rng.f64() * 92.0,
        true_len: 4 + rng.range_usize(0, 92),
        input_len: 4 + rng.range_usize(0, 40),
        utype: "plain".into(),
        malicious: false,
        deferrals: 0,
    }
}

fn main() {
    let root = Manifest::default_root();
    if !root.join("manifest.json").exists() {
        eprintln!("no artifacts at {} — run `make artifacts` first", root.display());
        std::process::exit(0);
    }
    let store = Arc::new(ArtifactStore::open(&root).expect("open artifacts"));
    let m = store.manifest.clone();
    let estimator = Estimator::new(
        store.lexicon.clone(),
        store.regressor.clone(),
        m.max_input_len,
        m.min_output_len as f64,
        m.max_output_len as f64,
    );

    println!("== L3 hot-path micro-benchmarks ==");

    let text = "What are the causes and consequences of poverty in developing countries?";
    bench("rulegen features (tokenize+tag+6 scorers)", 2000, || {
        std::hint::black_box(rules::features(&store.lexicon, text, m.max_input_len));
    });

    let feats = rules::features(&store.lexicon, text, m.max_input_len);
    bench("LW regressor predict (native)", 2000, || {
        std::hint::black_box(store.regressor.predict(&feats).unwrap());
    });

    bench("estimator score (features+regressor)", 2000, || {
        std::hint::black_box(estimator.score(text).unwrap());
    });

    let params = SchedParams::default();
    let mut rng = Pcg64::new(1);
    let task = mk_task(&mut rng, 0);
    bench("UP priority (Eq. 3)", 100_000, || {
        std::hint::black_box(up_priority(&task, &params, 0.05, 0.0));
    });

    // scheduler push+drain at queue depth ~200
    let tasks: Vec<Task> = (0..200).map(|i| mk_task(&mut rng, i)).collect();
    bench("UASCHED push+drain 200 tasks", 20, || {
        let p = SchedParams { batch_size: 16, ..Default::default() };
        let mut policy = PolicyKind::RtLm.build(&p, 0.05, 60.0);
        for t in tasks.iter().cloned() {
            policy.push(t);
        }
        while policy.queue_len() > 0 {
            std::hint::black_box(policy.pop_batch(Lane::Gpu, 0.0, true));
            std::hint::black_box(policy.pop_batch(Lane::Cpu, 0.0, true));
        }
    });

    // full simulator run, 400 tasks
    let lat = LatencyModel::load_or_analytic(&m).expect("latency model");
    let model = m.model("dialogpt").expect("model").clone();
    let dev = DeviceProfile::edge_server();
    let sim_tasks: Vec<Task> = (0..400).map(|i| mk_task(&mut rng, i)).collect();
    bench("sim engine 400 tasks (RT-LM)", 5, || {
        let p = SchedParams { batch_size: 16, ..Default::default() };
        let mut policy = PolicyKind::RtLm.build(&p, model.eta, 60.0);
        std::hint::black_box(run_sim(
            sim_tasks.clone(),
            &mut *policy,
            &lat,
            &model,
            &dev,
            &p,
        ));
    });

    bench("sim engine 400 tasks (FIFO)", 5, || {
        let p = SchedParams { batch_size: 16, ..Default::default() };
        let mut policy = PolicyKind::Fifo.build(&p, model.eta, f64::INFINITY);
        std::hint::black_box(run_sim(
            sim_tasks.clone(),
            &mut *policy,
            &lat,
            &model,
            &dev,
            &p,
        ));
    });

    println!("\n== L1/L2 PJRT execution (real artifacts) ==");
    let session = rtlm::model::LmSession::new(store.clone(), "t5").expect("session");
    for b in [1usize, 8, 32] {
        let secs = session.time_decode_step(b, 5).expect("time");
        println!("t5 decode step b={b:<3} {:8.2} ms ({:.1} tok/s)", secs * 1e3, b as f64 / secs);
    }
    let secs = session.time_prefill((8, 64), 5).expect("time");
    println!("t5 prefill b=8 s=64 {:8.2} ms", secs * 1e3);

    // end-to-end generate: chunked vs single-step (the §Perf comparison)
    let prompts: Vec<Vec<i32>> = (0..8)
        .map(|i| store.vocab.encode(&format!("tell me about the history of art {i} ."), Some(64)))
        .collect();
    let lens = vec![48usize; 8];
    std::env::set_var("RTLM_USE_CHUNKS", "1");
    let t0 = Instant::now();
    let g = session.generate(&prompts, &lens).expect("gen");
    let chunked_secs = t0.elapsed().as_secs_f64();
    std::env::remove_var("RTLM_USE_CHUNKS");
    let mut single = rtlm::model::LmSession::new(store.clone(), "t5").expect("session");
    single.entry.chunk_k = 0;
    let t0 = Instant::now();
    let g2 = single.generate(&prompts, &lens).expect("gen");
    let single_secs = t0.elapsed().as_secs_f64();
    assert_eq!(g.tokens, g2.tokens);
    println!(
        "t5 generate 8x48 tokens: chunked {:.0} ms ({:.1} ms/tok) vs single-step {:.0} ms ({:.1} ms/tok) -> {:.2}x",
        chunked_secs * 1e3,
        chunked_secs * 1e3 / 48.0,
        single_secs * 1e3,
        single_secs * 1e3 / 48.0,
        single_secs / chunked_secs
    );
}
