//! `cargo bench --bench hotpath`
//!
//! Micro-benchmarks of the L3 hot paths (criterion is not in the offline
//! crate set; this is a manual median-of-N harness with warmup):
//! RULEGEN feature extraction, LW regressor inference, UP priority
//! computation, scheduler push/pop, consolidation, and the simulator
//! engine itself.
//!
//! Always runs to completion: pure-logic benches use hand-built fixtures
//! when `make artifacts` has not run, artifact benches join in when it
//! has, and PJRT benches join in when a real backend exists. A snapshot
//! is written to `BENCH_hotpath.json` (override with `RTLM_BENCH_OUT`)
//! so the perf trajectory is diffable across commits.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use rtlm::config::{DeviceProfile, Manifest, ModelEntry, SchedParams};
use rtlm::runtime::bundle::{Bundle, Tensor};
use rtlm::runtime::ArtifactStore;
use rtlm::scheduler::{up_priority, LaneId, LaneSet, PolicyKind, Task, UpQueue, WHOLE_BATCH};
use rtlm::sim::{run_sim, Calibration, LatencyModel};
use rtlm::textgen::{Lexicon, ScoreScratch};
use rtlm::uncertainty::{rules, Estimator, Regressor};
use rtlm::util::json::{obj, Json};
use rtlm::util::rng::Pcg64;

/// median-of-samples timing; records (name -> median secs/iter).
struct Harness {
    results: Vec<(String, f64)>,
    /// Per-lane dispatched-batch counts of the sim benches
    /// (bench name -> lane name -> batches), for the per-lane table in
    /// `scripts/bench_delta.py`.
    batches: Vec<(String, Vec<(String, usize)>)>,
}

impl Harness {
    fn bench<F: FnMut()>(&mut self, name: &str, iters_per_sample: usize, mut f: F) {
        // warmup
        for _ in 0..iters_per_sample.min(100) {
            f();
        }
        let mut samples = Vec::with_capacity(15);
        for _ in 0..15 {
            let t0 = Instant::now();
            for _ in 0..iters_per_sample {
                f();
            }
            samples.push(t0.elapsed().as_secs_f64() / iters_per_sample as f64);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = samples[samples.len() / 2];
        let unit = if median < 1e-6 {
            format!("{:8.1} ns", median * 1e9)
        } else if median < 1e-3 {
            format!("{:8.2} us", median * 1e6)
        } else {
            format!("{:8.3} ms", median * 1e3)
        };
        println!("{name:<44} {unit}/iter  (median of 15x{iters_per_sample})");
        self.results.push((name.to_string(), median));
    }

    fn record(&mut self, name: &str, secs: f64) {
        self.results.push((name.to_string(), secs));
    }

    fn record_batches(&mut self, name: &str, lanes: &[String], counts: &[usize]) {
        let row = lanes.iter().cloned().zip(counts.iter().copied()).collect();
        self.batches.push((name.to_string(), row));
    }
}

fn mk_task(rng: &mut Pcg64, id: u64) -> Task {
    Task {
        id,
        text: String::new(),
        prompt: vec![],
        arrival: rng.f64() * 30.0,
        priority_point: rng.f64() * 30.0 + 2.0,
        uncertainty: 4.0 + rng.f64() * 92.0,
        true_len: 4 + rng.range_usize(0, 92),
        input_len: 4 + rng.range_usize(0, 40),
        utype: "plain".into(),
        malicious: false,
        deferrals: 0,
        slo: rtlm::scheduler::SloClass::Standard,
    }
}

/// Artifact-free estimator for the scoring sweep: a lexicon that
/// exercises every rule list plus a small regressor, so the sweep (and
/// its legacy-vs-fast speedup) is measured on every CI run, not just
/// artifact builds.
fn stub_estimator() -> Estimator {
    let json = r#"{
        "vocab": ["<pad>", "<bos>", "<eos>", "<unk>"],
        "pos_lexicon": {
            "in": "ADP", "with": "ADP", "of": "ADP", "on": "ADP",
            "saw": "VERB", "is": "VERB", "do": "VERB", "differ": "VERB",
            "the": "DET", "a": "DET", "and": "CONJ", "what": "WH",
            "park": "NOUN", "history": "NOUN", "time": "NOUN"
        },
        "suffix_rules": [["ly", "ADV"], ["ing", "VERB"], ["tion", "NOUN"], ["ous", "ADJ"]],
        "homonyms": {"bank": 3, "bats": 2, "scale": 4},
        "nv_ambiguous": ["saw", "duck", "watch"],
        "vague_topics": ["history", "art", "poverty"],
        "vague_phrases": [["tell", "me", "about"], ["what", "do", "you", "think", "about"]],
        "open_markers": ["causes", "consequences", "best"],
        "multipart_markers": ["both", "also"],
        "relativizers": ["that", "which", "who"],
        "wh_words": ["what", "why", "how", "who"],
        "vague_adjectives": ["general", "various", "different"],
        "open_wh_starters": ["what", "why", "how"]
    }"#;
    let lex = Lexicon::from_json(&Json::parse(json).expect("lexicon json")).expect("lexicon");
    let bundle = Bundle::from_tensors(vec![
        Tensor::f32("w0", vec![7, 1], vec![0.2, 0.4, 0.3, 0.5, 0.6, 0.35, 24.0]),
        Tensor::f32("b0", vec![1], vec![4.0]),
    ]);
    let scales = vec![10.0, 10.0, 10.0, 10.0, 10.0, 10.0, 64.0];
    let reg = Regressor::from_bundle(&bundle, &scales).expect("regressor");
    Estimator::new(Arc::new(lex), Arc::new(reg), 64, 4.0, 96.0)
}

/// Stand-in model entry for the artifact-free path.
fn synthetic_model() -> ModelEntry {
    ModelEntry::stub("synthetic", 0.05, 0.08)
}

fn synthetic_latency() -> LatencyModel {
    let mut c = Calibration::default();
    c.decode.insert(
        "synthetic".into(),
        BTreeMap::from([(1, 0.010), (4, 0.016), (16, 0.032), (32, 0.055)]),
    );
    c.prefill.insert(
        "synthetic".into(),
        BTreeMap::from([((1, 16), 0.02), ((8, 64), 0.08)]),
    );
    LatencyModel::from_calibration(&c)
}

fn main() {
    let mut h = Harness { results: Vec::new(), batches: Vec::new() };
    let root = Manifest::default_root();
    let store = if root.join("manifest.json").exists() {
        match ArtifactStore::open(&root) {
            Ok(s) => Some(Arc::new(s)),
            Err(e) => {
                eprintln!("artifacts at {} unreadable ({e:#}); pure-logic benches only", root.display());
                None
            }
        }
    } else {
        eprintln!("no artifacts at {} — pure-logic benches only (run `make artifacts` for the full set)", root.display());
        None
    };

    println!("== L3 hot-path micro-benchmarks ==");

    // --- artifact-dependent application-level benches ----------------------
    let text = "What are the causes and consequences of poverty in developing countries?";
    if let Some(store) = &store {
        let m = store.manifest.clone();
        let estimator = Estimator::new(
            store.lexicon.clone(),
            store.regressor.clone(),
            m.max_input_len,
            m.min_output_len as f64,
            m.max_output_len as f64,
        );
        let lexicon = store.lexicon.clone();
        let max_input_len = m.max_input_len;
        h.bench("rulegen features (tokenize+tag+6 scorers)", 2000, || {
            std::hint::black_box(rules::features(&lexicon, text, max_input_len));
        });
        let feats = rules::features(&store.lexicon, text, m.max_input_len);
        let regressor = store.regressor.clone();
        h.bench("LW regressor predict (native)", 2000, || {
            std::hint::black_box(regressor.predict(&feats).unwrap());
        });
        h.bench("estimator score (features+regressor)", 2000, || {
            std::hint::black_box(estimator.score(text).unwrap());
        });
    }

    // --- scoring sweep: legacy vs interned fast path (always runs) ----------
    // Short/median/long prompts through the same estimator: the legacy
    // allocating pipeline vs the single-pass scratch fast path. Medians
    // land in the `score_sweep` snapshot map, which
    // `scripts/bench_delta.py` renders as a speedup table.
    let sweep_estimator = match &store {
        Some(store) => {
            let m = &store.manifest;
            Estimator::new(
                store.lexicon.clone(),
                store.regressor.clone(),
                m.max_input_len,
                m.min_output_len as f64,
                m.max_output_len as f64,
            )
        }
        None => stub_estimator(),
    };
    let long_text = "Tell me about the history of art, and what do you think about         the causes and consequences of poverty in developing countries? How do         general topics, various ideas, and different questions differ in theory,         in practice, and in application? What is the best way to think about both?";
    let mut score_sweep: Vec<(String, usize, f64, f64)> = Vec::new();
    let mut scratch = ScoreScratch::new();
    for (label, prompt) in [("short", "What time is it?"), ("median", text), ("long", long_text)] {
        // sanity gate: never time a fast path that diverged
        let (legacy_u, legacy_f) =
            sweep_estimator.score_with_features(prompt).expect("legacy score");
        let (fast_u, fast_f) = sweep_estimator
            .score_with_features_scratch(prompt, &mut scratch)
            .expect("fast score");
        assert_eq!(legacy_u.to_bits(), fast_u.to_bits(), "fast path diverged on '{label}'");
        assert_eq!(legacy_f.map(f64::to_bits), fast_f.map(f64::to_bits));
        let n_tokens = scratch.token_count();

        let iters = if n_tokens > 30 { 1000 } else { 2000 };
        h.bench(&format!("score legacy ({label})"), iters, || {
            std::hint::black_box(sweep_estimator.score(prompt).unwrap());
        });
        let legacy = h.results.last().unwrap().1;
        h.bench(&format!("score fast ({label})"), iters, || {
            std::hint::black_box(sweep_estimator.score_scratch(prompt, &mut scratch).unwrap());
        });
        let fast = h.results.last().unwrap().1;
        score_sweep.push((label.to_string(), n_tokens, legacy, fast));
    }

    // --- pure scheduling logic (always runs) --------------------------------
    let params = SchedParams::default();
    let mut rng = Pcg64::new(1);
    let task = mk_task(&mut rng, 0);
    h.bench("UP priority (Eq. 3)", 100_000, || {
        std::hint::black_box(up_priority(&task, &params, 0.05, 0.0));
    });

    // priority-sort strategies at queue depth 512: the comparator-based
    // sort re-evaluates up_priority ~2·n·log n times; the keyed sort
    // (what UASCHED::sort_queue now does) computes each key once. Both
    // are benched so the before/after of the hot-path fix stays visible
    // in BENCH_hotpath.json.
    let sort_tasks: Vec<Task> = (0..512).map(|i| mk_task(&mut rng, i)).collect();
    h.bench("sort 512 by UP priority (comparator, old)", 50, || {
        let mut q: Vec<&Task> = sort_tasks.iter().collect();
        q.sort_by(|a, b| {
            up_priority(b, &params, 0.05, 1.0)
                .total_cmp(&up_priority(a, &params, 0.05, 1.0))
                .then(a.arrival.total_cmp(&b.arrival))
        });
        std::hint::black_box(q);
    });
    h.bench("sort 512 by UP priority (keyed, new)", 50, || {
        let mut keyed: Vec<(f64, &Task)> = sort_tasks
            .iter()
            .map(|t| (up_priority(t, &params, 0.05, 1.0), t))
            .collect();
        keyed.sort_by(|a, b| {
            b.0.total_cmp(&a.0).then(a.1.arrival.total_cmp(&b.1.arrival))
        });
        std::hint::black_box(keyed);
    });

    // scheduler push+drain at queue depth ~200
    let tasks: Vec<Task> = (0..200).map(|i| mk_task(&mut rng, i)).collect();
    h.bench("UASCHED push+drain 200 tasks", 20, || {
        let p = SchedParams { batch_size: 16, ..Default::default() };
        let mut policy = PolicyKind::RtLm.build(&p, 0.05, &LaneSet::two_lane("synthetic", 60.0));
        for t in tasks.iter().cloned() {
            policy.push(t);
        }
        while policy.queue_len() > 0 {
            std::hint::black_box(policy.pop(LaneId::GPU, 0.0, true, WHOLE_BATCH));
            std::hint::black_box(policy.pop(LaneId::CPU, 0.0, true, WHOLE_BATCH));
        }
    });

    // --- pop cost vs queue depth: indexed UpQueue vs keyed full sort --------
    // The million-task series: per-pop cost of the indexed bucket queue
    // must stay near-flat as depth grows 10^3 -> 10^6 while the
    // historical keyed full resort grows n log n. The indexed bench
    // pops batches of 16 in exact oracle order without reinserting
    // (depth drifts a few percent across the samples — the median
    // doesn't care); the keyed bench rebuilds keys and re-sorts the
    // whole backlog per pop, exactly what `UaSched::sort_queue` used to
    // do on every dispatch. `scripts/bench_delta.py` renders this
    // series as its own table.
    let mut depth_sweep: Vec<(usize, f64, f64)> = Vec::new();
    for depth in [1_000usize, 10_000, 100_000, 1_000_000] {
        let mut rng = Pcg64::new(0xD0 + depth as u64);
        let tasks: Vec<Task> = (0..depth as u64).map(|i| mk_task(&mut rng, i)).collect();

        let mut q = UpQueue::new(params.clone(), 0.05);
        for (i, t) in tasks.iter().enumerate() {
            q.insert(t.clone(), i as u64);
        }
        // drain at most a few percent of the queue across all samples
        // so depth stays representative
        let iters = (depth / 7_680).max(1);
        h.bench(&format!("indexed pop16 @ depth {depth}"), iters, || {
            std::hint::black_box(q.pop_top(0.0, 16));
        });
        let indexed = h.results.last().unwrap().1;

        let keyed_iters = (200_000 / depth).max(1);
        h.bench(&format!("keyed full-sort pop16 @ depth {depth}"), keyed_iters, || {
            let mut keyed: Vec<(f64, &Task)> = tasks
                .iter()
                .map(|t| (up_priority(t, &params, 0.05, 0.0), t))
                .collect();
            keyed.sort_by(|a, b| {
                b.0.total_cmp(&a.0).then(a.1.arrival.total_cmp(&b.1.arrival))
            });
            std::hint::black_box(&keyed[..16.min(keyed.len())]);
        });
        let keyed = h.results.last().unwrap().1;
        depth_sweep.push((depth, indexed, keyed));
    }

    // full simulator run, 400 tasks (calibrated model when artifacts
    // exist, hand-built fixture otherwise; model and latency model must
    // come from the same source or lookups fall through to defaults)
    let (lat, model) = match &store {
        Some(store) => match store.manifest.model("dialogpt") {
            Ok(entry) => (
                LatencyModel::load_or_analytic(&store.manifest).expect("latency model"),
                entry.clone(),
            ),
            Err(_) => (synthetic_latency(), synthetic_model()),
        },
        None => (synthetic_latency(), synthetic_model()),
    };
    let dev = DeviceProfile::edge_server();
    let sim_tasks: Vec<Task> = (0..400).map(|i| mk_task(&mut rng, i)).collect();
    let two_lane = LaneSet::two_lane(&model.name, 60.0);
    h.bench("sim engine 400 tasks (RT-LM)", 5, || {
        let p = SchedParams { batch_size: 16, ..Default::default() };
        let mut policy = PolicyKind::RtLm.build(&p, model.eta, &two_lane);
        std::hint::black_box(run_sim(sim_tasks.clone(), &mut *policy, &lat, &model, &dev, &p));
    });

    h.bench("sim engine 400 tasks (FIFO)", 5, || {
        let p = SchedParams { batch_size: 16, ..Default::default() };
        let mut policy =
            PolicyKind::Fifo.build(&p, model.eta, &LaneSet::two_lane(&model.name, f64::INFINITY));
        std::hint::black_box(run_sim(sim_tasks.clone(), &mut *policy, &lat, &model, &dev, &p));
    });

    // per-lane batch counts of one representative run, for the
    // bench-delta per-lane table
    {
        let p = SchedParams { batch_size: 16, ..Default::default() };
        let mut policy = PolicyKind::RtLm.build(&p, model.eta, &two_lane);
        let r = run_sim(sim_tasks.clone(), &mut *policy, &lat, &model, &dev, &p);
        h.record_batches("sim engine 400 tasks (RT-LM)", &r.lanes, &r.n_batches);
    }

    // --- PJRT execution benches (artifacts + real backend only) -------------
    let mut pjrt = false;
    if let Some(store) = &store {
        match rtlm::model::LmSession::new(store.clone(), "t5") {
            Ok(session) => {
                pjrt = true;
                println!("\n== L1/L2 PJRT execution (real artifacts) ==");
                for b in [1usize, 8, 32] {
                    let secs = session.time_decode_step(b, 5).expect("time");
                    println!(
                        "t5 decode step b={b:<3} {:8.2} ms ({:.1} tok/s)",
                        secs * 1e3,
                        b as f64 / secs
                    );
                    h.record(&format!("t5 decode step b={b}"), secs);
                }
                let secs = session.time_prefill((8, 64), 5).expect("time");
                println!("t5 prefill b=8 s=64 {:8.2} ms", secs * 1e3);
                h.record("t5 prefill b=8 s=64", secs);

                // end-to-end generate: chunked vs single-step (§Perf)
                let prompts: Vec<Vec<i32>> = (0..8)
                    .map(|i| {
                        store
                            .vocab
                            .encode(&format!("tell me about the history of art {i} ."), Some(64))
                    })
                    .collect();
                let lens = vec![48usize; 8];
                std::env::set_var("RTLM_USE_CHUNKS", "1");
                let t0 = Instant::now();
                let g = session.generate(&prompts, &lens).expect("gen");
                let chunked_secs = t0.elapsed().as_secs_f64();
                std::env::remove_var("RTLM_USE_CHUNKS");
                let mut single =
                    rtlm::model::LmSession::new(store.clone(), "t5").expect("session");
                single.entry.chunk_k = 0;
                let t0 = Instant::now();
                let g2 = single.generate(&prompts, &lens).expect("gen");
                let single_secs = t0.elapsed().as_secs_f64();
                assert_eq!(g.tokens, g2.tokens);
                println!(
                    "t5 generate 8x48 tokens: chunked {:.0} ms ({:.1} ms/tok) vs single-step {:.0} ms ({:.1} ms/tok) -> {:.2}x",
                    chunked_secs * 1e3,
                    chunked_secs * 1e3 / 48.0,
                    single_secs * 1e3,
                    single_secs * 1e3 / 48.0,
                    single_secs / chunked_secs
                );
                h.record("t5 generate 8x48 chunked", chunked_secs);
                h.record("t5 generate 8x48 single-step", single_secs);
            }
            Err(e) => {
                eprintln!("\nPJRT benches skipped: {e:#}");
            }
        }
    }

    // --- snapshot ------------------------------------------------------------
    let out_path = std::env::var("RTLM_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_hotpath.json".to_string());
    let entries: Vec<(String, Json)> = h
        .results
        .iter()
        .map(|(name, secs)| (name.clone(), Json::Num(*secs)))
        .collect();
    let batch_entries: Vec<(String, Json)> = h
        .batches
        .iter()
        .map(|(name, rows)| {
            let lanes: Vec<(String, Json)> = rows
                .iter()
                .map(|(lane, count)| (lane.clone(), Json::Num(*count as f64)))
                .collect();
            (name.clone(), Json::Obj(lanes.into_iter().collect()))
        })
        .collect();
    // pop-cost-vs-depth series: numeric-string keys sort ascending in
    // the BTreeMap ("1000" < "10000" < ... lexicographically)
    let sweep_entries: Vec<(String, Json)> = depth_sweep
        .iter()
        .map(|(depth, indexed, keyed)| {
            (
                depth.to_string(),
                obj(vec![
                    ("indexed", Json::Num(*indexed)),
                    ("keyed", Json::Num(*keyed)),
                ]),
            )
        })
        .collect();
    // legacy-vs-fast scoring medians keyed by prompt label, with the
    // token count so the delta table can sort by prompt length
    let score_entries: Vec<(String, Json)> = score_sweep
        .iter()
        .map(|(label, tokens, legacy, fast)| {
            (
                label.clone(),
                obj(vec![
                    ("tokens", Json::Num(*tokens as f64)),
                    ("legacy", Json::Num(*legacy)),
                    ("fast", Json::Num(*fast)),
                ]),
            )
        })
        .collect();
    let snapshot = obj(vec![
        ("bench", Json::Str("hotpath".into())),
        ("unit", Json::Str("seconds_per_iter".into())),
        ("artifacts", Json::Bool(store.is_some())),
        ("pjrt", Json::Bool(pjrt)),
        (
            "results",
            Json::Obj(entries.into_iter().collect()),
        ),
        (
            "batches",
            Json::Obj(batch_entries.into_iter().collect()),
        ),
        (
            "pop_depth_sweep",
            Json::Obj(sweep_entries.into_iter().collect()),
        ),
        (
            "score_sweep",
            Json::Obj(score_entries.into_iter().collect()),
        ),
    ]);
    std::fs::write(&out_path, format!("{snapshot}\n")).expect("write bench snapshot");
    println!("\nsnapshot written to {out_path}");
}
