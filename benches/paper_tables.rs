//! `cargo bench --bench paper_tables [-- <experiment>]`
//!
//! Regenerates every table and figure of the paper's evaluation section
//! (Fig. 1-3, 4-6, 8-14, Tables III, IV, VI, VII) from the calibrated
//! simulator + real corpus/regressor artifacts. Run a single experiment
//! by name, or everything with no argument / 'all'.

use std::sync::Arc;

use rtlm::bench_harness::scenarios::{run_experiment, ExperimentCtx};
use rtlm::config::Manifest;
use rtlm::runtime::ArtifactStore;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
    let exp = args.first().map(String::as_str).unwrap_or("all");

    let root = Manifest::default_root();
    if !root.join("manifest.json").exists() {
        eprintln!("no artifacts at {} — run `make artifacts` first", root.display());
        std::process::exit(0); // don't fail `cargo bench` on fresh clones
    }
    let store = Arc::new(ArtifactStore::open(&root).expect("open artifacts"));
    let n = std::env::var("RTLM_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(400);
    let ctx = ExperimentCtx::new(store, n, 7).expect("experiment context");
    let t0 = std::time::Instant::now();
    if let Err(e) = run_experiment(&ctx, exp) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
    eprintln!("\n[paper_tables: '{exp}' regenerated in {:.1}s]", t0.elapsed().as_secs_f64());
}
