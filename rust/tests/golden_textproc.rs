//! Cross-language contract tests: the rust tokenizer / PoS tagger /
//! vocabulary / RULEGEN scorers must agree *exactly* with the python
//! build path, verified against goldens emitted by `aot.py`.
//!
//! Requires `make artifacts` (skipped with a clear message otherwise).

use std::path::PathBuf;

use rtlm::config::Manifest;
use rtlm::textgen::pos::pos_tag;
use rtlm::textgen::{tokenize, tokenize_into, Lexicon, ScoreScratch, Tag, Vocab};
use rtlm::uncertainty::{fastpath, rules};
use rtlm::util::json::read_jsonl;

fn artifacts_root() -> Option<PathBuf> {
    let root = std::env::var("RTLM_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"));
    if root.join("manifest.json").exists() {
        Some(root)
    } else {
        eprintln!("skipping: no artifacts at {} (run `make artifacts`)", root.display());
        None
    }
}

#[test]
fn goldens_match_python_exactly() {
    let Some(root) = artifacts_root() else { return };
    let manifest = Manifest::load(&root).expect("manifest");
    let lexicon = Lexicon::load(&manifest.lexicon).expect("lexicon");
    let vocab = Vocab::from_lexicon(&lexicon, manifest.vocab_size).expect("vocab");
    let goldens = read_jsonl(&manifest.golden_textproc).expect("goldens");
    assert!(goldens.len() > 100, "suspiciously few goldens: {}", goldens.len());

    let mut scratch = ScoreScratch::new();
    for (i, rec) in goldens.iter().enumerate() {
        let text = rec.get("text").as_str().expect("text");

        // tokenizer
        let want_tokens: Vec<&str> = rec
            .get("tokens")
            .as_arr()
            .expect("tokens")
            .iter()
            .map(|t| t.as_str().unwrap())
            .collect();
        let got_tokens = tokenize(text);
        assert_eq!(got_tokens, want_tokens, "golden {i} tokens for {text:?}");

        // scratch tokenizer (the fast path's byte-span variant)
        tokenize_into(text, &mut scratch);
        let got_spans: Vec<&str> = scratch.tokens().collect();
        assert_eq!(got_spans, want_tokens, "golden {i} span tokens for {text:?}");

        // PoS tags
        let want_tags: Vec<&str> = rec
            .get("tags")
            .as_arr()
            .expect("tags")
            .iter()
            .map(|t| t.as_str().unwrap())
            .collect();
        let got_tags: Vec<&str> =
            pos_tag(&lexicon, &got_tokens).iter().map(Tag::as_str).collect();
        assert_eq!(got_tags, want_tags, "golden {i} tags for {text:?}");

        // vocabulary ids
        let want_ids: Vec<i32> = rec
            .get("ids")
            .as_arr()
            .expect("ids")
            .iter()
            .map(|t| t.as_f64().unwrap() as i32)
            .collect();
        let got_ids = vocab.encode(text, None);
        assert_eq!(got_ids, want_ids, "golden {i} ids for {text:?}");

        // RULEGEN features (exact f64 equality: both sides compute the
        // same integer counts with the same multipliers)
        let want_feats: Vec<f64> = rec
            .get("features")
            .as_arr()
            .expect("features")
            .iter()
            .map(|t| t.as_f64().unwrap())
            .collect();
        let got_feats = rules::features(&lexicon, text, manifest.max_input_len);
        assert_eq!(got_feats.len(), want_feats.len());
        for (j, (got, want)) in got_feats.iter().zip(&want_feats).enumerate() {
            assert_eq!(
                got, want,
                "golden {i} feature {j} ({}) for {text:?}",
                manifest.feature_names[j]
            );
        }

        // the interned fast path must match the same goldens bit for bit
        let fast_feats =
            fastpath::features_scratch(&lexicon, text, manifest.max_input_len, &mut scratch);
        for (j, (got, want)) in fast_feats.iter().zip(&want_feats).enumerate() {
            assert_eq!(
                got.to_bits(),
                want.to_bits(),
                "golden {i} fast-path feature {j} ({}) for {text:?}: fast {got} vs python {want}",
                manifest.feature_names[j]
            );
        }
    }
}

#[test]
fn paper_table1_examples_score_their_own_category() {
    let Some(root) = artifacts_root() else { return };
    let manifest = Manifest::load(&root).expect("manifest");
    let lexicon = Lexicon::load(&manifest.lexicon).expect("lexicon");
    let cases = [
        (0, "John saw a boy in the park with a telescope."),
        (1, "Rice flies like sand."),
        (2, "What's the best way to deal with bats?"),
        (3, "Tell me about the history of art."),
        (4, "What are the causes and consequences of poverty in developing countries?"),
        (5, "How do cats and dogs differ in behavior, diet, and social interaction?"),
    ];
    for (idx, text) in cases {
        let feats = rules::features(&lexicon, text, manifest.max_input_len);
        assert!(feats[idx] > 0.0, "{text:?} should fire scorer {idx}: {feats:?}");
    }
}

#[test]
fn vocab_covers_corpus() {
    let Some(root) = artifacts_root() else { return };
    let manifest = Manifest::load(&root).expect("manifest");
    let lexicon = Lexicon::load(&manifest.lexicon).expect("lexicon");
    let vocab = Vocab::from_lexicon(&lexicon, manifest.vocab_size).expect("vocab");
    let items = rtlm::workload::corpus::load(&manifest.corpus_observation).expect("corpus");
    let mut n_unk = 0;
    let mut n_tok = 0;
    for item in &items {
        for id in vocab.encode(&item.text, None) {
            n_tok += 1;
            if id == rtlm::textgen::vocab::UNK_ID {
                n_unk += 1;
            }
        }
    }
    assert_eq!(n_unk, 0, "corpus produced {n_unk}/{n_tok} <unk> tokens");
}
