//! In-process integration tests for the TCP front-end: the server is
//! `serve_tcp_on` over the *shared* engine core (no dispatch loop of
//! its own), driven by concurrent clients on an ephemeral port with
//! artifact-free stubs (hand-built lexicon/vocab, constant or
//! length-sensitive regressors, instant/sleepy/failing/modeled
//! executors).
//!
//! Covered: concurrent clients all get correlated replies, the line
//! protocol's edge cases (empty lines skipped, over-length prompts
//! truncated, pipelined lines answered in order at K=1), bounded
//! pipelining at K>1 (out-of-order id-tagged replies), a 3-lane
//! heterogeneous fleet on the modeled backend routing traffic per
//! admission predicate, id-tagged timeout and execution-failure error
//! replies, a client disconnecting before its reply never wedging the
//! dispatcher, overload shedding (`--queue-cap`) answering every
//! request with either a served reply or an id-tagged
//! `{"error":"shed"}`, and the load generator the CI `tcp-load` gate
//! runs — closed loop and open loop (`--rate`).

use std::collections::{BTreeMap, HashSet};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use rtlm::config::{DeviceProfile, ModelEntry, SchedParams, ShedPolicy};
use rtlm::executor::{
    modeled_factory, BatchExecutor, ExecReport, ExecutorFactory, InstantExecutor,
};
use rtlm::runtime::bundle::{Bundle, Tensor};
use rtlm::scheduler::{Admission, Batch, LaneSet, LaneSpec, PolicyKind};
use rtlm::server::loadgen::{self, LoadgenOptions};
use rtlm::server::tcp::{serve_tcp_on, TcpServerConfig};
use rtlm::sim::{Calibration, LatencyModel};
use rtlm::textgen::{Lexicon, Vocab};
use rtlm::uncertainty::{Estimator, Regressor};
use rtlm::util::json::Json;

const MAX_INPUT_LEN: usize = 64;

/// Minimal lexicon: a handful of vocab words, every rule list empty
/// (all rule scores 0 — the regressor alone decides the length).
fn test_lexicon() -> Lexicon {
    let json = r#"{
        "vocab": ["<pad>", "<bos>", "<eos>", "<unk>",
                  "about", "art", "history", "me", "of", "tell", "the"],
        "pos_lexicon": {},
        "suffix_rules": [],
        "homonyms": {},
        "nv_ambiguous": [],
        "vague_topics": [],
        "vague_phrases": [],
        "open_markers": [],
        "multipart_markers": [],
        "relativizers": [],
        "wh_words": [],
        "vague_adjectives": [],
        "open_wh_starters": []
    }"#;
    Lexicon::from_json(&Json::parse(json).expect("lexicon json")).expect("lexicon")
}

/// Constant-output regressor: predicts 20 tokens for everything.
fn test_estimator(lexicon: Arc<Lexicon>) -> Estimator {
    let bundle = Bundle::from_tensors(vec![
        Tensor::f32("w0", vec![7, 1], vec![0.0; 7]),
        Tensor::f32("b0", vec![1], vec![20.0]),
    ]);
    let scales = vec![10.0, 10.0, 10.0, 10.0, 10.0, 10.0, MAX_INPUT_LEN as f64];
    let regressor = Regressor::from_bundle(&bundle, &scales).expect("regressor");
    Estimator::new(lexicon, Arc::new(regressor), MAX_INPUT_LEN, 4.0, 96.0)
}

/// Length-sensitive regressor: u = 4 + 1.5 * input_tokens, so short
/// prompts score low, long prompts score past any offload threshold —
/// the knob the multi-lane and pipelining tests route traffic with.
fn length_estimator(lexicon: Arc<Lexicon>) -> Estimator {
    let bundle = Bundle::from_tensors(vec![
        Tensor::f32("w0", vec![7, 1], vec![0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 96.0]),
        Tensor::f32("b0", vec![1], vec![4.0]),
    ]);
    let scales = vec![10.0, 10.0, 10.0, 10.0, 10.0, 10.0, MAX_INPUT_LEN as f64];
    let regressor = Regressor::from_bundle(&bundle, &scales).expect("regressor");
    Estimator::new(lexicon, Arc::new(regressor), MAX_INPUT_LEN, 4.0, 96.0)
}

fn test_config(params: SchedParams, reply_timeout: Duration) -> TcpServerConfig {
    let lexicon = Arc::new(test_lexicon());
    let vocab = Arc::new(Vocab::from_lexicon(&lexicon, 11).expect("vocab"));
    TcpServerConfig {
        vocab,
        estimator: test_estimator(lexicon),
        max_input_len: MAX_INPUT_LEN,
        phi: 0.07,
        params,
        lanes: LaneSet::two_lane("m", 60.0),
        pipeline_depth: 1,
        reply_timeout,
        node: "local".into(),
        register: None,
    }
}

/// Bind an ephemeral port, run the server on a detached thread (the
/// test process exits past it), return the address to dial.
fn start_server_cfg(factory: ExecutorFactory, cfg: TcpServerConfig) -> SocketAddr {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind ephemeral");
    let addr = listener.local_addr().expect("local addr");
    let policy = PolicyKind::RtLm.build(&cfg.params, 0.05, &cfg.lanes);
    thread::spawn(move || {
        let _ = serve_tcp_on(listener, cfg, factory, policy);
    });
    addr
}

fn start_server(
    factory: ExecutorFactory,
    params: SchedParams,
    reply_timeout: Duration,
) -> SocketAddr {
    start_server_cfg(factory, test_config(params, reply_timeout))
}

fn instant_factory() -> ExecutorFactory {
    Arc::new(|_spec: &LaneSpec| Ok(Box::new(InstantExecutor) as Box<dyn BatchExecutor>))
}

/// Tiny calibrated latency model for the modeled-backend tests: fast
/// accelerator decode, so the CPU quarantine lane (offload overhead +
/// lane slowdown) is the visibly slower path.
fn tiny_latency() -> LatencyModel {
    let mut c = Calibration::default();
    c.decode
        .insert("m".into(), BTreeMap::from([(1usize, 0.002), (16, 0.004)]));
    c.prefill
        .insert("m".into(), BTreeMap::from([((1usize, 16usize), 0.004), ((8, 64), 0.01)]));
    LatencyModel::from_calibration(&c)
}

fn modeled_test_factory(time_scale: f64) -> ExecutorFactory {
    let models = BTreeMap::from([("m".to_string(), ModelEntry::stub("m", 0.05, 0.08))]);
    modeled_factory(tiny_latency(), models, DeviceProfile::edge_server(), time_scale)
}

/// Executes like the instant executor after a fixed sleep — long enough
/// for reply timeouts to fire first.
struct SleepyExecutor(Duration);

impl BatchExecutor for SleepyExecutor {
    fn execute(&mut self, batch: &Batch) -> anyhow::Result<Vec<ExecReport>> {
        thread::sleep(self.0);
        InstantExecutor.execute(batch)
    }
}

/// Fails every batch — the lane dies, the server shuts down, and every
/// pending request must still get an id-tagged error reply.
struct FailingExecutor;

impl BatchExecutor for FailingExecutor {
    fn execute(&mut self, _batch: &Batch) -> anyhow::Result<Vec<ExecReport>> {
        Err(anyhow::anyhow!("injected executor failure"))
    }
}

/// Send `lines` on one connection, read `expect` reply lines back.
fn roundtrip(addr: SocketAddr, lines: &[&str], expect: usize) -> Vec<Json> {
    let stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(20))).expect("read timeout");
    let mut writer = stream.try_clone().expect("clone");
    for line in lines {
        writeln!(writer, "{line}").expect("write");
    }
    let mut reader = BufReader::new(stream);
    (0..expect)
        .map(|i| {
            let mut buf = String::new();
            let n = reader.read_line(&mut buf).expect("read reply");
            assert!(n > 0, "connection closed before reply {i}");
            Json::parse(buf.trim()).unwrap_or_else(|e| panic!("bad reply json '{buf}': {e}"))
        })
        .collect()
}

#[test]
fn concurrent_clients_all_get_correlated_replies() {
    let params = SchedParams { batch_size: 4, xi: 0.05, ..Default::default() };
    let addr = start_server(instant_factory(), params, Duration::from_secs(30));

    let clients: Vec<_> = (0..16)
        .map(|_| {
            thread::spawn(move || {
                roundtrip(addr, &["tell me about the history of art"; 4], 4)
            })
        })
        .collect();

    let mut ids = HashSet::new();
    for client in clients {
        for reply in client.join().expect("client") {
            assert_eq!(reply.get("error"), &Json::Null, "unexpected error: {reply}");
            let id = reply.need_f64("id").expect("id") as u64;
            assert!(ids.insert(id), "duplicate reply id {id}");
            assert!(reply.need_f64("response_ms").expect("response_ms") >= 0.0);
            let lane = reply.need_str("lane").expect("lane").to_string();
            assert!(lane == "gpu" || lane == "cpu", "unknown lane {lane}");
        }
    }
    assert_eq!(ids.len(), 64, "every request answered exactly once");
}

#[test]
fn empty_lines_are_skipped_and_long_prompts_truncate() {
    let params = SchedParams { batch_size: 1, xi: 0.05, ..Default::default() };
    let addr = start_server(instant_factory(), params, Duration::from_secs(30));

    // two empty lines produce no replies; the real request is answered
    let replies = roundtrip(addr, &["", "   ", "tell me about art"], 1);
    assert_eq!(replies[0].get("error"), &Json::Null);
    assert!(replies[0].get("id").as_f64().is_some(), "reply must carry the request id");

    // an over-length prompt (way past max_input_len tokens) is
    // truncated server-side and still served
    let long = "history ".repeat(40 * MAX_INPUT_LEN);
    let replies = roundtrip(addr, &[long.as_str()], 1);
    assert_eq!(replies[0].get("error"), &Json::Null, "over-length prompt must be served");
    assert!(replies[0].need_f64("response_ms").expect("response_ms") >= 0.0);
}

#[test]
fn pipelined_lines_get_in_order_id_tagged_replies() {
    let params = SchedParams { batch_size: 2, xi: 0.05, ..Default::default() };
    let addr = start_server(instant_factory(), params, Duration::from_secs(30));

    let replies = roundtrip(addr, &["tell me about art", "the history of art", "art"], 3);
    let ids: Vec<i64> = replies
        .iter()
        .map(|r| r.need_f64("id").expect("every reply carries its id") as i64)
        .collect();
    let mut sorted = ids.clone();
    sorted.sort_unstable();
    assert_eq!(ids, sorted, "at K=1 one connection's replies arrive in request order: {ids:?}");
}

/// Bounded pipelining (K=3) on the modeled two-lane backend: a slow
/// quarantined request pipelined ahead of two fast accelerator requests
/// must NOT hold their replies back — the fast replies overtake it,
/// id-tagged, and the slow reply arrives last.
#[test]
fn pipelined_depth_k_replies_out_of_order() {
    let params = SchedParams { batch_size: 1, xi: 0.02, ..Default::default() };
    let lexicon = Arc::new(test_lexicon());
    let vocab = Arc::new(Vocab::from_lexicon(&lexicon, 11).expect("vocab"));
    let cfg = TcpServerConfig {
        vocab,
        estimator: length_estimator(lexicon),
        max_input_len: MAX_INPUT_LEN,
        phi: 0.07,
        params,
        lanes: LaneSet::two_lane("m", 60.0),
        pipeline_depth: 3,
        reply_timeout: Duration::from_secs(30),
        node: "local".into(),
        register: None,
    };
    // time_scale 1: the quarantined task sleeps its full modeled
    // latency (~5s of modeled seconds -> but offload overhead dominates
    // scaled) — use 10x compression to keep the gap ~0.5s
    let addr = start_server_cfg(modeled_test_factory(10.0), cfg);

    // 45 tokens -> u = 4 + 1.5*45 = 71.5 > tau -> cpu lane (slow);
    // 1-2 tokens -> u ~ 5.5-7 -> gpu lane (fast)
    let slow = "history ".repeat(45);
    let replies = roundtrip(addr, &[slow.as_str(), "art", "the art"], 3);
    let ids: Vec<u64> = replies
        .iter()
        .map(|r| r.need_f64("id").expect("id") as u64)
        .collect();
    let slow_id = ids.iter().copied().min().unwrap(); // first request got the first id
    let mut sorted = ids.clone();
    sorted.sort_unstable();
    assert_eq!(sorted, vec![slow_id, slow_id + 1, slow_id + 2], "all three answered once");
    assert_eq!(
        *ids.last().unwrap(),
        slow_id,
        "slow quarantined reply must arrive last (out-of-order pipelining): {ids:?}"
    );
    let lanes: Vec<&str> = replies
        .iter()
        .map(|r| r.need_str("lane").expect("lane"))
        .collect();
    assert!(lanes.contains(&"cpu") && lanes.contains(&"gpu"), "{lanes:?}");
}

/// A 3-lane heterogeneous fleet (two accelerator variants + CPU
/// quarantine) on the modeled backend: every request is served, replies
/// carry the configured lane names, and each lane's admission predicate
/// decides its traffic.
#[test]
fn three_lane_modeled_backend_serves_by_admission() {
    let params = SchedParams { batch_size: 2, xi: 0.03, ..Default::default() };
    let lanes = LaneSet::new(vec![
        LaneSpec::accelerator("big", "m"),
        LaneSpec {
            admission: Admission::AtMost(10.0),
            ..LaneSpec::accelerator("small", "m")
        },
        LaneSpec {
            workers: Some(2),
            ..LaneSpec::cpu_offload("cpu", "m", 60.0)
        },
    ])
    .expect("3-lane set");
    let lexicon = Arc::new(test_lexicon());
    let vocab = Arc::new(Vocab::from_lexicon(&lexicon, 11).expect("vocab"));
    let cfg = TcpServerConfig {
        vocab,
        estimator: length_estimator(lexicon),
        max_input_len: MAX_INPUT_LEN,
        phi: 0.07,
        params,
        lanes,
        pipeline_depth: 1,
        reply_timeout: Duration::from_secs(30),
        node: "local".into(),
        register: None,
    };
    let addr = start_server_cfg(modeled_test_factory(50.0), cfg);

    let long = "history ".repeat(45); // u = 71.5 -> cpu
    let cases: Vec<(&str, &str)> = vec![
        ("art", "small"),                              // u = 5.5 <= 10
        ("the art", "small"),                          // u = 7
        ("tell me about the history of art", "big"),   // u = 14.5
        (long.as_str(), "cpu"),                        // u = 71.5 > 60
    ];
    let mut seen: HashSet<String> = HashSet::new();
    for (text, want_lane) in cases {
        let replies = roundtrip(addr, &[text], 1);
        assert_eq!(replies[0].get("error"), &Json::Null, "error for '{text}': {}", replies[0]);
        let lane = replies[0].need_str("lane").expect("lane").to_string();
        assert_eq!(lane, want_lane, "text '{}' routed to {lane}", &text[..text.len().min(24)]);
        seen.insert(lane);
    }
    assert_eq!(seen.len(), 3, "every configured lane served traffic: {seen:?}");
}

#[test]
fn timeout_replies_carry_id_and_dead_clients_do_not_wedge() {
    let params = SchedParams { batch_size: 1, xi: 0.02, ..Default::default() };
    let factory: ExecutorFactory = Arc::new(|_spec: &LaneSpec| {
        Ok(Box::new(SleepyExecutor(Duration::from_millis(300))) as Box<dyn BatchExecutor>)
    });
    // reply timeout far below the executor sleep: the first reply is an
    // id-tagged timeout error
    let addr = start_server(factory, params, Duration::from_millis(50));

    let replies = roundtrip(addr, &["tell me about art"], 1);
    assert_eq!(replies[0].need_str("error").expect("error"), "timeout");
    let first_id = replies[0].need_f64("id").expect("timeout reply must carry the id");
    // client disconnects here (roundtrip drops the stream) while its
    // task is still scheduled — the completion callback will hit a dead
    // reply channel and must shrug it off

    thread::sleep(Duration::from_millis(400));

    // a second client is served normally: the dispatcher did not wedge
    let replies = roundtrip(addr, &["the history of art"], 1);
    assert_eq!(replies[0].need_str("error").expect("error"), "timeout");
    let second_id = replies[0].need_f64("id").expect("id");
    assert!(second_id > first_id, "ids keep monotonically increasing");
}

#[test]
fn execution_failure_replies_carry_id() {
    let params = SchedParams { batch_size: 1, xi: 0.02, ..Default::default() };
    let factory: ExecutorFactory =
        Arc::new(|_spec: &LaneSpec| Ok(Box::new(FailingExecutor) as Box<dyn BatchExecutor>));
    let addr = start_server(factory, params, Duration::from_secs(10));

    let replies = roundtrip(addr, &["tell me about art"], 1);
    assert_eq!(replies[0].need_str("error").expect("error"), "execution failed");
    assert!(
        replies[0].get("id").as_f64().is_some(),
        "failure replies must carry the request id for pipelined clients: {}",
        replies[0]
    );
}

#[test]
fn loadgen_drives_concurrent_connections_clean() {
    let params = SchedParams { batch_size: 4, xi: 0.05, ..Default::default() };
    let addr = start_server(instant_factory(), params, Duration::from_secs(30));

    let opts = LoadgenOptions {
        n: 64,
        concurrency: 16,
        reply_timeout: Duration::from_secs(30),
        connect_wait: Duration::from_secs(10),
        rate: 0.0,
    };
    let mut report = loadgen::run(&addr.to_string(), &opts).expect("loadgen");
    assert_eq!(report.n_err, 0, "errors: {:?}", report.errors);
    assert_eq!(report.n_ok, 64);
    assert_eq!(report.response_ms.len(), 64);
    let p95 = report.response_ms.p95();
    assert!(p95.is_finite() && p95 >= 0.0, "p95 {p95}");
    // per-lane served-task counts come back from the reply lane tags
    let total: usize = report.lane_tasks.values().sum();
    assert_eq!(total, 64, "per-lane counts cover every ok reply: {:?}", report.lane_tasks);
    assert!(report.lane_tasks.keys().all(|l| l == "gpu" || l == "cpu"));
}

// ---------------------------------------------------------------------------
// overload admission control on the wire (--queue-cap / --shed)
// ---------------------------------------------------------------------------

/// A bounded queue behind depth-8 pipelining: the batch size exceeds
/// the pipelined burst, so no dispatch can fire before the xi deadline
/// and all eight requests land while the lane queue is capped at four.
/// Identical prompts score identical uncertainty, so each later arrival
/// carries a looser priority point — strictly lower UP priority — and
/// the four newest requests shed themselves with id-tagged
/// `{"error":"shed"}` replies while the four retained are served.
/// Every request id is answered exactly once.
#[test]
fn overloaded_queue_sheds_with_id_tagged_replies() {
    let params = SchedParams {
        batch_size: 32, // > burst: the first pop is the xi-forced one
        xi: 0.5,
        queue_cap: 4,
        shed: ShedPolicy::Priority,
        ..Default::default()
    };
    let cfg =
        TcpServerConfig { pipeline_depth: 8, ..test_config(params, Duration::from_secs(30)) };
    let addr = start_server_cfg(instant_factory(), cfg);

    let replies = roundtrip(addr, &["tell me about the history of art"; 8], 8);
    let mut served = Vec::new();
    let mut shed = Vec::new();
    for reply in &replies {
        let id = reply.need_f64("id").expect("every reply is id-tagged") as u64;
        match reply.get("error") {
            Json::Null => served.push(id),
            err => {
                assert_eq!(err.as_str(), Some("shed"), "unexpected error: {reply}");
                shed.push(id);
            }
        }
    }
    let mut all: Vec<u64> = served.iter().chain(&shed).copied().collect();
    all.sort_unstable();
    all.dedup();
    assert_eq!(all.len(), 8, "every request answered exactly once: {replies:?}");
    assert_eq!(shed.len(), 4, "cap-4 queue must shed the 4-deep overflow: {replies:?}");
    assert!(
        served.iter().max().unwrap() < shed.iter().min().unwrap(),
        "sheds must be the lowest-priority (latest) requests: served {served:?}, shed {shed:?}"
    );
}

/// Open-loop load (`--rate`) far above the xi dispatch cadence into a
/// cap-2 queue: the server must shed, the retained requests must still
/// be served, and the tallies must cover the whole run — every one of
/// the `n` requests gets exactly one reply, ok or shed, never silence.
#[test]
fn open_loop_overload_answers_every_request() {
    let params = SchedParams {
        batch_size: 32,
        xi: 0.1,
        queue_cap: 2,
        shed: ShedPolicy::Priority,
        ..Default::default()
    };
    let cfg =
        TcpServerConfig { pipeline_depth: 8, ..test_config(params, Duration::from_secs(30)) };
    let addr = start_server_cfg(instant_factory(), cfg);

    let opts = LoadgenOptions {
        n: 40,
        concurrency: 8,
        reply_timeout: Duration::from_secs(30),
        connect_wait: Duration::from_secs(10),
        rate: 500.0,
    };
    let report = loadgen::run(&addr.to_string(), &opts).expect("loadgen");
    assert_eq!(report.n_err, 0, "errors: {:?}", report.errors);
    assert_eq!(report.n_ok + report.n_shed, 40, "every request answered exactly once");
    assert!(report.n_shed > 0, "cap-2 queue under 500 req/s offered load must shed");
    assert!(report.n_ok > 0, "retained requests must still be served");
    assert_eq!(
        report.response_ms.len(),
        report.n_ok,
        "latency samples cover exactly the ok replies"
    );
}
