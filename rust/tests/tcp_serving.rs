//! In-process integration tests for the TCP front-end: the server is
//! `serve_tcp_on` over the *shared* engine core (no dispatch loop of
//! its own), driven by concurrent clients on an ephemeral port with
//! artifact-free stubs (hand-built lexicon/vocab, constant regressor,
//! instant/sleepy/failing executors).
//!
//! Covered: concurrent clients all get correlated replies, the line
//! protocol's edge cases (empty lines skipped, over-length prompts
//! truncated, pipelined lines answered in order), id-tagged timeout and
//! execution-failure error replies, a client disconnecting before its
//! reply never wedging the dispatcher, and the load generator the CI
//! `tcp-load` gate runs.

use std::collections::HashSet;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use rtlm::config::SchedParams;
use rtlm::executor::{BatchExecutor, ExecReport, ExecutorFactory, InstantExecutor};
use rtlm::runtime::bundle::{Bundle, Tensor};
use rtlm::scheduler::{Batch, PolicyKind};
use rtlm::server::loadgen::{self, LoadgenOptions};
use rtlm::server::tcp::{serve_tcp_on, TcpServerConfig};
use rtlm::textgen::{Lexicon, Vocab};
use rtlm::uncertainty::{Estimator, Regressor};
use rtlm::util::json::Json;

const MAX_INPUT_LEN: usize = 64;

/// Minimal lexicon: a handful of vocab words, every rule list empty
/// (all rule scores 0 — the constant regressor decides the length).
fn test_lexicon() -> Lexicon {
    let json = r#"{
        "vocab": ["<pad>", "<bos>", "<eos>", "<unk>",
                  "about", "art", "history", "me", "of", "tell", "the"],
        "pos_lexicon": {},
        "suffix_rules": [],
        "homonyms": {},
        "nv_ambiguous": [],
        "vague_topics": [],
        "vague_phrases": [],
        "open_markers": [],
        "multipart_markers": [],
        "relativizers": [],
        "wh_words": [],
        "vague_adjectives": [],
        "open_wh_starters": []
    }"#;
    Lexicon::from_json(&Json::parse(json).expect("lexicon json")).expect("lexicon")
}

/// Constant-output regressor: predicts 20 tokens for everything.
fn test_estimator(lexicon: Arc<Lexicon>) -> Estimator {
    let bundle = Bundle::from_tensors(vec![
        Tensor::f32("w0", vec![7, 1], vec![0.0; 7]),
        Tensor::f32("b0", vec![1], vec![20.0]),
    ]);
    let scales = vec![10.0, 10.0, 10.0, 10.0, 10.0, 10.0, MAX_INPUT_LEN as f64];
    let regressor = Regressor::from_bundle(&bundle, &scales).expect("regressor");
    Estimator::new(lexicon, Arc::new(regressor), MAX_INPUT_LEN, 4.0, 96.0)
}

fn test_config(params: SchedParams, reply_timeout: Duration) -> TcpServerConfig {
    let lexicon = Arc::new(test_lexicon());
    let vocab = Arc::new(Vocab::from_lexicon(&lexicon, 11).expect("vocab"));
    TcpServerConfig {
        vocab,
        estimator: test_estimator(lexicon),
        max_input_len: MAX_INPUT_LEN,
        phi: 0.07,
        params,
        reply_timeout,
    }
}

/// Bind an ephemeral port, run the server on a detached thread (the
/// test process exits past it), return the address to dial.
fn start_server(
    factory: ExecutorFactory,
    params: SchedParams,
    reply_timeout: Duration,
) -> SocketAddr {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind ephemeral");
    let addr = listener.local_addr().expect("local addr");
    let cfg = test_config(params.clone(), reply_timeout);
    let policy = PolicyKind::RtLm.build(&params, 0.05, 60.0);
    thread::spawn(move || {
        let _ = serve_tcp_on(listener, cfg, factory, policy);
    });
    addr
}

fn instant_factory() -> ExecutorFactory {
    Arc::new(|_lane| Ok(Box::new(InstantExecutor) as Box<dyn BatchExecutor>))
}

/// Executes like the instant executor after a fixed sleep — long enough
/// for reply timeouts to fire first.
struct SleepyExecutor(Duration);

impl BatchExecutor for SleepyExecutor {
    fn execute(&mut self, batch: &Batch) -> anyhow::Result<Vec<ExecReport>> {
        thread::sleep(self.0);
        InstantExecutor.execute(batch)
    }
}

/// Fails every batch — the lane dies, the server shuts down, and every
/// pending request must still get an id-tagged error reply.
struct FailingExecutor;

impl BatchExecutor for FailingExecutor {
    fn execute(&mut self, _batch: &Batch) -> anyhow::Result<Vec<ExecReport>> {
        Err(anyhow::anyhow!("injected executor failure"))
    }
}

/// Send `lines` on one connection, read `expect` reply lines back.
fn roundtrip(addr: SocketAddr, lines: &[&str], expect: usize) -> Vec<Json> {
    let stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(20))).expect("read timeout");
    let mut writer = stream.try_clone().expect("clone");
    for line in lines {
        writeln!(writer, "{line}").expect("write");
    }
    let mut reader = BufReader::new(stream);
    (0..expect)
        .map(|i| {
            let mut buf = String::new();
            let n = reader.read_line(&mut buf).expect("read reply");
            assert!(n > 0, "connection closed before reply {i}");
            Json::parse(buf.trim()).unwrap_or_else(|e| panic!("bad reply json '{buf}': {e}"))
        })
        .collect()
}

#[test]
fn concurrent_clients_all_get_correlated_replies() {
    let params = SchedParams { batch_size: 4, xi: 0.05, ..Default::default() };
    let addr = start_server(instant_factory(), params, Duration::from_secs(30));

    let clients: Vec<_> = (0..16)
        .map(|_| {
            thread::spawn(move || {
                roundtrip(addr, &["tell me about the history of art"; 4], 4)
            })
        })
        .collect();

    let mut ids = HashSet::new();
    for client in clients {
        for reply in client.join().expect("client") {
            assert_eq!(reply.get("error"), &Json::Null, "unexpected error: {reply}");
            let id = reply.need_f64("id").expect("id") as u64;
            assert!(ids.insert(id), "duplicate reply id {id}");
            assert!(reply.need_f64("response_ms").expect("response_ms") >= 0.0);
            let lane = reply.need_str("lane").expect("lane").to_string();
            assert!(lane == "Gpu" || lane == "Cpu", "unknown lane {lane}");
        }
    }
    assert_eq!(ids.len(), 64, "every request answered exactly once");
}

#[test]
fn empty_lines_are_skipped_and_long_prompts_truncate() {
    let params = SchedParams { batch_size: 1, xi: 0.05, ..Default::default() };
    let addr = start_server(instant_factory(), params, Duration::from_secs(30));

    // two empty lines produce no replies; the real request is answered
    let replies = roundtrip(addr, &["", "   ", "tell me about art"], 1);
    assert_eq!(replies[0].get("error"), &Json::Null);
    assert!(replies[0].get("id").as_f64().is_some(), "reply must carry the request id");

    // an over-length prompt (way past max_input_len tokens) is
    // truncated server-side and still served
    let long = "history ".repeat(40 * MAX_INPUT_LEN);
    let replies = roundtrip(addr, &[long.as_str()], 1);
    assert_eq!(replies[0].get("error"), &Json::Null, "over-length prompt must be served");
    assert!(replies[0].need_f64("response_ms").expect("response_ms") >= 0.0);
}

#[test]
fn pipelined_lines_get_in_order_id_tagged_replies() {
    let params = SchedParams { batch_size: 2, xi: 0.05, ..Default::default() };
    let addr = start_server(instant_factory(), params, Duration::from_secs(30));

    let replies = roundtrip(addr, &["tell me about art", "the history of art", "art"], 3);
    let ids: Vec<i64> = replies
        .iter()
        .map(|r| r.need_f64("id").expect("every reply carries its id") as i64)
        .collect();
    let mut sorted = ids.clone();
    sorted.sort_unstable();
    assert_eq!(ids, sorted, "one connection's replies arrive in request order: {ids:?}");
}

#[test]
fn timeout_replies_carry_id_and_dead_clients_do_not_wedge() {
    let params = SchedParams { batch_size: 1, xi: 0.02, ..Default::default() };
    let factory: ExecutorFactory = Arc::new(|_lane| {
        Ok(Box::new(SleepyExecutor(Duration::from_millis(300))) as Box<dyn BatchExecutor>)
    });
    // reply timeout far below the executor sleep: the first reply is an
    // id-tagged timeout error
    let addr = start_server(factory, params, Duration::from_millis(50));

    let replies = roundtrip(addr, &["tell me about art"], 1);
    assert_eq!(replies[0].need_str("error").expect("error"), "timeout");
    let first_id = replies[0].need_f64("id").expect("timeout reply must carry the id");
    // client disconnects here (roundtrip drops the stream) while its
    // task is still scheduled — the completion callback will hit a dead
    // reply channel and must shrug it off

    thread::sleep(Duration::from_millis(400));

    // a second client is served normally: the dispatcher did not wedge
    let replies = roundtrip(addr, &["the history of art"], 1);
    assert_eq!(replies[0].need_str("error").expect("error"), "timeout");
    let second_id = replies[0].need_f64("id").expect("id");
    assert!(second_id > first_id, "ids keep monotonically increasing");
}

#[test]
fn execution_failure_replies_carry_id() {
    let params = SchedParams { batch_size: 1, xi: 0.02, ..Default::default() };
    let factory: ExecutorFactory =
        Arc::new(|_lane| Ok(Box::new(FailingExecutor) as Box<dyn BatchExecutor>));
    let addr = start_server(factory, params, Duration::from_secs(10));

    let replies = roundtrip(addr, &["tell me about art"], 1);
    assert_eq!(replies[0].need_str("error").expect("error"), "execution failed");
    assert!(
        replies[0].get("id").as_f64().is_some(),
        "failure replies must carry the request id for pipelined clients: {}",
        replies[0]
    );
}

#[test]
fn loadgen_drives_concurrent_connections_clean() {
    let params = SchedParams { batch_size: 4, xi: 0.05, ..Default::default() };
    let addr = start_server(instant_factory(), params, Duration::from_secs(30));

    let opts = LoadgenOptions {
        n: 64,
        concurrency: 16,
        reply_timeout: Duration::from_secs(30),
        connect_wait: Duration::from_secs(10),
    };
    let mut report = loadgen::run(&addr.to_string(), &opts).expect("loadgen");
    assert_eq!(report.n_err, 0, "errors: {:?}", report.errors);
    assert_eq!(report.n_ok, 64);
    assert_eq!(report.response_ms.len(), 64);
    let p95 = report.response_ms.p95();
    assert!(p95.is_finite() && p95 >= 0.0, "p95 {p95}");
}
