//! Integration tests for the shared dispatcher core (`rtlm::engine`):
//! the cross-backend equivalence property (same trace + policy =>
//! identical per-lane batch sequences in simulation and on the wire),
//! the arrivals-drain regression (no forced dispatch while arrival
//! events are still queued), the ξ-deadline wakeup of the wall-clock
//! dispatcher, and NaN-uncertainty resilience on the wire path.

use std::collections::HashMap;
use std::sync::Arc;

use rtlm::config::{DeviceProfile, ModelEntry, SchedParams};
use rtlm::engine::{run_engine, SimBackend, ThreadedBackend};
use rtlm::executor::{BatchExecutor, ExecutorFactory, InstantExecutor};
use rtlm::scheduler::{Fifo, Lane, PolicyKind, Task};
use rtlm::sim::{Calibration, LatencyModel};
use rtlm::util::rng::Pcg64;

fn mk_task(id: u64, arrival: f64, priority_point: f64, uncertainty: f64) -> Task {
    Task {
        id,
        text: String::new(),
        prompt: vec![],
        arrival,
        priority_point,
        uncertainty,
        true_len: uncertainty.max(1.0).min(96.0) as usize,
        input_len: 8,
        utype: "test".into(),
        malicious: false,
        deferrals: 0,
    }
}

/// A latency model in which every batch takes zero time — the virtual
/// clock never advances, matching the instant executor's wall clock
/// (which advances only by scheduling overhead, microseconds).
fn zero_latency() -> LatencyModel {
    let mut c = Calibration::default();
    c.decode.insert(
        "m".into(),
        std::collections::BTreeMap::from([(1usize, 0.0), (16, 0.0)]),
    );
    c.prefill.insert(
        "m".into(),
        std::collections::BTreeMap::from([((1usize, 16usize), 0.0), ((16, 64), 0.0)]),
    );
    LatencyModel::from_calibration(&c)
}

fn zero_device() -> DeviceProfile {
    DeviceProfile {
        name: "zero".into(),
        gpu_speed: 1.0,
        cpu_speed: 1.0,
        batching_exp: 0.0,
        dispatch_overhead: 0.0,
        offload_overhead: 0.0,
        cpu_workers: 1,
        batch_knee: 1e9,
    }
}

fn instant_factory() -> ExecutorFactory {
    Arc::new(|_lane| Ok(Box::new(InstantExecutor) as Box<dyn BatchExecutor>))
}

fn lane_log(log: &[(Lane, Vec<u64>)], lane: Lane) -> Vec<Vec<u64>> {
    log.iter()
        .filter(|(l, _)| *l == lane)
        .map(|(_, ids)| ids.clone())
        .collect()
}

/// Same trace + same policy through the virtual-clock backend and the
/// threaded wall-clock backend (deterministic instant executor, arrivals
/// pre-queued) must dispatch identical batch sequences on each lane.
#[test]
fn cross_backend_dispatch_equivalence() {
    let model = ModelEntry::stub("m", 0.05, 0.08);
    let lat = zero_latency();
    let dev = zero_device();

    for seed in 0..12u64 {
        let mut rng = Pcg64::new(seed);
        let n = 4 + rng.range_usize(0, 24);
        // coarse value grids keep priorities well separated, so the
        // microseconds of wall-clock drift on the threaded path cannot
        // reorder them; exact ties fall back to arrival/queue order,
        // which both backends share.
        let tasks: Vec<Task> = (0..n)
            .map(|i| {
                let pp = 1.0 + 0.5 * rng.range_usize(0, 10) as f64;
                let u = 5.0 + 10.0 * rng.range_usize(0, 9) as f64;
                mk_task(i as u64, 0.0, pp, u)
            })
            .collect();
        let params = SchedParams { batch_size: 4, ..Default::default() };

        for kind in [
            PolicyKind::Fifo,
            PolicyKind::Hpf,
            PolicyKind::Luf,
            PolicyKind::Muf,
            PolicyKind::UpC,
            PolicyKind::RtLm,
        ] {
            let tau = 60.0;

            let mut sim_policy = kind.build(&params, model.eta, tau);
            let mut sim_backend = SimBackend::new(tasks.clone(), &lat, &model, &dev);
            let sim = run_engine(&mut sim_backend, &mut *sim_policy, &params, n)
                .expect("sim backend");

            let mut thr_policy = kind.build(&params, model.eta, tau);
            let mut thr_backend =
                ThreadedBackend::start(tasks.clone(), instant_factory(), 1.0, true)
                    .expect("threaded backend start");
            let thr = run_engine(&mut thr_backend, &mut *thr_policy, &params, n)
                .expect("threaded backend");
            thr_backend.finish();

            for lane in [Lane::Gpu, Lane::Cpu] {
                assert_eq!(
                    lane_log(&sim.dispatch_log, lane),
                    lane_log(&thr.dispatch_log, lane),
                    "seed {seed} policy {} lane {lane:?}: dispatch sequences diverged",
                    kind.label()
                );
            }
            assert_eq!(sim.outcomes.len(), n);
            assert_eq!(thr.outcomes.len(), n);
            let sim_lanes: HashMap<u64, Lane> =
                sim.outcomes.iter().map(|o| (o.id, o.lane)).collect();
            for o in &thr.outcomes {
                assert_eq!(
                    sim_lanes[&o.id], o.lane,
                    "seed {seed} policy {}: task {} changed lane",
                    kind.label(),
                    o.id
                );
            }
        }
    }
}

/// Regression for the arrivals-done race: the historical wall-clock
/// engine guessed "arrivals done" from `policy.queue_len() <=
/// meta.len()` (vacuously true), so ξ-forced dispatch could fire while
/// Arrival events were still queued in the channel — emitting runt
/// batches. With every arrival pre-queued, the unified core must admit
/// the whole channel before its first (then forced) dispatch.
#[test]
fn arrivals_drain_before_forced_dispatch() {
    let n = 10usize;
    let tasks: Vec<Task> = (0..n)
        .map(|i| mk_task(i as u64, 0.0, 5.0, 10.0))
        .collect();
    let params = SchedParams { batch_size: 4, ..Default::default() };
    let mut policy = Fifo::new(params.batch_size);
    let mut backend = ThreadedBackend::start(tasks, instant_factory(), 1.0, true)
        .expect("backend start");
    let report = run_engine(&mut backend, &mut policy, &params, n).expect("engine");
    backend.finish();

    assert_eq!(
        lane_log(&report.dispatch_log, Lane::Gpu),
        vec![vec![0, 1, 2, 3], vec![4, 5, 6, 7], vec![8, 9]],
        "forced dispatch must not fire before the arrival channel drains"
    );
    assert_eq!(report.n_batches_gpu, 3);
    assert_eq!(report.n_batches_cpu, 0);
}

/// The wall-clock dispatcher must wake at the ξ expiry (computed
/// deadline — not a 10 ms busy-poll) and force the partial batch out,
/// instead of waiting for the next arrival or completion event.
#[test]
fn xi_deadline_wakes_wall_clock_dispatcher() {
    let tasks = vec![
        mk_task(0, 0.0, 5.0, 10.0),
        mk_task(1, 0.0, 5.0, 12.0),
        mk_task(2, 0.8, 5.0, 14.0),
    ];
    let params = SchedParams { batch_size: 4, xi: 0.2, ..Default::default() };
    let mut policy = Fifo::new(params.batch_size);
    let mut backend = ThreadedBackend::start(tasks, instant_factory(), 1.0, false)
        .expect("backend start");
    let report = run_engine(&mut backend, &mut policy, &params, 3).expect("engine");
    backend.finish();

    assert_eq!(
        lane_log(&report.dispatch_log, Lane::Gpu),
        vec![vec![0, 1], vec![2]],
        "ξ expiry should force the partial batch before the late arrival"
    );
    let by_id: HashMap<u64, f64> =
        report.outcomes.iter().map(|o| (o.id, o.completion)).collect();
    assert!(
        by_id[&0] >= 0.18 && by_id[&0] < 0.7,
        "first batch should dispatch at the ξ=0.2s expiry, completed at {}",
        by_id[&0]
    );
    assert!(by_id[&2] >= 0.75, "late task completed at {}", by_id[&2]);
}

/// NaN-uncertainty tasks must not panic the wire path either: ordering
/// is total everywhere on the scheduling hot path.
#[test]
fn nan_uncertainty_survives_the_wire_path() {
    let mut tasks: Vec<Task> = (0..6)
        .map(|i| mk_task(i as u64, 0.0, 5.0 + i as f64, 10.0 + i as f64))
        .collect();
    tasks[1].uncertainty = f64::NAN;
    tasks[4].uncertainty = f64::NAN;
    let params = SchedParams { batch_size: 2, ..Default::default() };
    for kind in [PolicyKind::Fifo, PolicyKind::Hpf, PolicyKind::RtLm] {
        let mut policy = kind.build(&params, 0.05, 60.0);
        let mut backend =
            ThreadedBackend::start(tasks.clone(), instant_factory(), 1.0, true)
                .expect("backend start");
        let report = run_engine(&mut backend, &mut *policy, &params, 6).expect("engine");
        backend.finish();
        assert_eq!(report.outcomes.len(), 6, "{} lost NaN tasks", kind.label());
    }
}
