//! Integration tests for the shared dispatcher core (`rtlm::engine`):
//! the cross-backend equivalence property (same trace + policy =>
//! identical per-lane batch sequences in simulation and on the wire),
//! the open-stream properties (a closed trace served as an open stream
//! dispatches identically to its counted run on both backends; live
//! `ArrivalHandle` producers drain cleanly; streaming callbacks see
//! every completion), the arrivals-drain regression (no forced dispatch
//! while arrival events are still queued), the ξ-deadline wakeup of the
//! wall-clock dispatcher, and NaN-uncertainty resilience on the wire
//! path.

use std::collections::HashMap;
use std::sync::Arc;

use rtlm::config::{DeviceProfile, ModelEntry, SchedParams};
use rtlm::engine::{run_engine, run_engine_stream, ArrivalSource, SimBackend, ThreadedBackend};
use rtlm::executor::{BatchExecutor, ExecutorFactory, InstantExecutor};
use rtlm::scheduler::{Fifo, Lane, PolicyKind, Task};
use rtlm::sim::{Calibration, LatencyModel};
use rtlm::util::rng::Pcg64;

fn mk_task(id: u64, arrival: f64, priority_point: f64, uncertainty: f64) -> Task {
    Task {
        id,
        text: String::new(),
        prompt: vec![],
        arrival,
        priority_point,
        uncertainty,
        true_len: uncertainty.max(1.0).min(96.0) as usize,
        input_len: 8,
        utype: "test".into(),
        malicious: false,
        deferrals: 0,
    }
}

/// A latency model in which every batch takes zero time — the virtual
/// clock never advances, matching the instant executor's wall clock
/// (which advances only by scheduling overhead, microseconds).
fn zero_latency() -> LatencyModel {
    let mut c = Calibration::default();
    c.decode.insert(
        "m".into(),
        std::collections::BTreeMap::from([(1usize, 0.0), (16, 0.0)]),
    );
    c.prefill.insert(
        "m".into(),
        std::collections::BTreeMap::from([((1usize, 16usize), 0.0), ((16, 64), 0.0)]),
    );
    LatencyModel::from_calibration(&c)
}

fn zero_device() -> DeviceProfile {
    DeviceProfile {
        name: "zero".into(),
        gpu_speed: 1.0,
        cpu_speed: 1.0,
        batching_exp: 0.0,
        dispatch_overhead: 0.0,
        offload_overhead: 0.0,
        cpu_workers: 1,
        batch_knee: 1e9,
    }
}

fn instant_factory() -> ExecutorFactory {
    Arc::new(|_lane| Ok(Box::new(InstantExecutor) as Box<dyn BatchExecutor>))
}

fn lane_log(log: &[(Lane, Vec<u64>)], lane: Lane) -> Vec<Vec<u64>> {
    log.iter()
        .filter(|(l, _)| *l == lane)
        .map(|(_, ids)| ids.clone())
        .collect()
}

/// Same trace + same policy through the virtual-clock backend and the
/// threaded wall-clock backend (deterministic instant executor, arrivals
/// pre-queued) must dispatch identical batch sequences on each lane.
#[test]
fn cross_backend_dispatch_equivalence() {
    let model = ModelEntry::stub("m", 0.05, 0.08);
    let lat = zero_latency();
    let dev = zero_device();

    for seed in 0..12u64 {
        let mut rng = Pcg64::new(seed);
        let n = 4 + rng.range_usize(0, 24);
        // coarse value grids keep priorities well separated, so the
        // microseconds of wall-clock drift on the threaded path cannot
        // reorder them; exact ties fall back to arrival/queue order,
        // which both backends share.
        let tasks: Vec<Task> = (0..n)
            .map(|i| {
                let pp = 1.0 + 0.5 * rng.range_usize(0, 10) as f64;
                let u = 5.0 + 10.0 * rng.range_usize(0, 9) as f64;
                mk_task(i as u64, 0.0, pp, u)
            })
            .collect();
        let params = SchedParams { batch_size: 4, ..Default::default() };

        for kind in [
            PolicyKind::Fifo,
            PolicyKind::Hpf,
            PolicyKind::Luf,
            PolicyKind::Muf,
            PolicyKind::UpC,
            PolicyKind::RtLm,
        ] {
            let tau = 60.0;

            let mut sim_policy = kind.build(&params, model.eta, tau);
            let mut sim_backend = SimBackend::new(tasks.clone(), &lat, &model, &dev);
            let sim = run_engine(&mut sim_backend, &mut *sim_policy, &params, n)
                .expect("sim backend");

            let mut thr_policy = kind.build(&params, model.eta, tau);
            let mut thr_backend =
                ThreadedBackend::start(tasks.clone(), instant_factory(), 1.0, true)
                    .expect("threaded backend start");
            let thr = run_engine(&mut thr_backend, &mut *thr_policy, &params, n)
                .expect("threaded backend");
            thr_backend.finish();

            for lane in [Lane::Gpu, Lane::Cpu] {
                assert_eq!(
                    lane_log(&sim.dispatch_log, lane),
                    lane_log(&thr.dispatch_log, lane),
                    "seed {seed} policy {} lane {lane:?}: dispatch sequences diverged",
                    kind.label()
                );
            }
            assert_eq!(sim.outcomes.len(), n);
            assert_eq!(thr.outcomes.len(), n);
            let sim_lanes: HashMap<u64, Lane> =
                sim.outcomes.iter().map(|o| (o.id, o.lane)).collect();
            for o in &thr.outcomes {
                assert_eq!(
                    sim_lanes[&o.id], o.lane,
                    "seed {seed} policy {}: task {} changed lane",
                    kind.label(),
                    o.id
                );
            }
        }
    }
}

/// Regression for the arrivals-done race: the historical wall-clock
/// engine guessed "arrivals done" from `policy.queue_len() <=
/// meta.len()` (vacuously true), so ξ-forced dispatch could fire while
/// Arrival events were still queued in the channel — emitting runt
/// batches. With every arrival pre-queued, the unified core must admit
/// the whole channel before its first (then forced) dispatch.
#[test]
fn arrivals_drain_before_forced_dispatch() {
    let n = 10usize;
    let tasks: Vec<Task> = (0..n)
        .map(|i| mk_task(i as u64, 0.0, 5.0, 10.0))
        .collect();
    let params = SchedParams { batch_size: 4, ..Default::default() };
    let mut policy = Fifo::new(params.batch_size);
    let mut backend = ThreadedBackend::start(tasks, instant_factory(), 1.0, true)
        .expect("backend start");
    let report = run_engine(&mut backend, &mut policy, &params, n).expect("engine");
    backend.finish();

    assert_eq!(
        lane_log(&report.dispatch_log, Lane::Gpu),
        vec![vec![0, 1, 2, 3], vec![4, 5, 6, 7], vec![8, 9]],
        "forced dispatch must not fire before the arrival channel drains"
    );
    assert_eq!(report.n_batches_gpu, 3);
    assert_eq!(report.n_batches_cpu, 0);
}

/// The wall-clock dispatcher must wake at the ξ expiry (computed
/// deadline — not a 10 ms busy-poll) and force the partial batch out,
/// instead of waiting for the next arrival or completion event.
#[test]
fn xi_deadline_wakes_wall_clock_dispatcher() {
    let tasks = vec![
        mk_task(0, 0.0, 5.0, 10.0),
        mk_task(1, 0.0, 5.0, 12.0),
        mk_task(2, 0.8, 5.0, 14.0),
    ];
    let params = SchedParams { batch_size: 4, xi: 0.2, ..Default::default() };
    let mut policy = Fifo::new(params.batch_size);
    let mut backend = ThreadedBackend::start(tasks, instant_factory(), 1.0, false)
        .expect("backend start");
    let report = run_engine(&mut backend, &mut policy, &params, 3).expect("engine");
    backend.finish();

    assert_eq!(
        lane_log(&report.dispatch_log, Lane::Gpu),
        vec![vec![0, 1], vec![2]],
        "ξ expiry should force the partial batch before the late arrival"
    );
    let by_id: HashMap<u64, f64> =
        report.outcomes.iter().map(|o| (o.id, o.completion)).collect();
    assert!(
        by_id[&0] >= 0.18 && by_id[&0] < 0.7,
        "first batch should dispatch at the ξ=0.2s expiry, completed at {}",
        by_id[&0]
    );
    assert!(by_id[&2] >= 0.75, "late task completed at {}", by_id[&2]);
}

/// A closed trace served as an *open stream* (no fixed `n_total`; the
/// backend reports stream closure) must dispatch exactly like its
/// counted run — on the virtual clock and on the wire. This is the
/// property that lets the TCP front-end run the same loop as the
/// simulator.
#[test]
fn open_stream_matches_counted_on_both_backends() {
    let model = ModelEntry::stub("m", 0.05, 0.08);
    let lat = zero_latency();
    let dev = zero_device();

    for seed in 0..6u64 {
        let mut rng = Pcg64::new(seed);
        let n = 4 + rng.range_usize(0, 24);
        let tasks: Vec<Task> = (0..n)
            .map(|i| {
                let pp = 1.0 + 0.5 * rng.range_usize(0, 10) as f64;
                let u = 5.0 + 10.0 * rng.range_usize(0, 9) as f64;
                mk_task(i as u64, 0.0, pp, u)
            })
            .collect();
        let params = SchedParams { batch_size: 4, ..Default::default() };

        for kind in [PolicyKind::Fifo, PolicyKind::RtLm] {
            let tau = 60.0;

            let mut p = kind.build(&params, model.eta, tau);
            let mut b = SimBackend::new(tasks.clone(), &lat, &model, &dev);
            let counted = run_engine(&mut b, &mut *p, &params, n).expect("sim counted");

            let mut p = kind.build(&params, model.eta, tau);
            let mut b = SimBackend::new(tasks.clone(), &lat, &model, &dev);
            let streamed = run_engine_stream(&mut b, &mut *p, &params, ArrivalSource::Stream, None)
                .expect("sim stream");
            // the virtual clock is deterministic: the full interleaved
            // dispatch sequence must match, not just per-lane views
            assert_eq!(
                counted.dispatch_log, streamed.dispatch_log,
                "seed {seed} policy {}: sim stream diverged from counted",
                kind.label()
            );
            assert_eq!(streamed.outcomes.len(), n);

            let mut p = kind.build(&params, model.eta, tau);
            let mut b = ThreadedBackend::start(tasks.clone(), instant_factory(), 1.0, true)
                .expect("threaded start");
            let wired = run_engine_stream(&mut b, &mut *p, &params, ArrivalSource::Stream, None)
                .expect("threaded stream");
            b.finish();
            for lane in [Lane::Gpu, Lane::Cpu] {
                assert_eq!(
                    lane_log(&counted.dispatch_log, lane),
                    lane_log(&wired.dispatch_log, lane),
                    "seed {seed} policy {} lane {lane:?}: wire stream diverged",
                    kind.label()
                );
            }
            assert_eq!(wired.outcomes.len(), n);
        }
    }
}

/// Open-stream ξ-forcing on the wall clock: with the stream still open
/// (no trace count to exhaust), the partial batch must go out at the ξ
/// expiry, not wait for the late arrival.
#[test]
fn open_stream_xi_forcing_with_late_arrivals() {
    let tasks = vec![
        mk_task(0, 0.0, 5.0, 10.0),
        mk_task(1, 0.0, 5.0, 12.0),
        mk_task(2, 0.8, 5.0, 14.0),
    ];
    let params = SchedParams { batch_size: 4, xi: 0.2, ..Default::default() };
    let mut policy = Fifo::new(params.batch_size);
    let mut backend = ThreadedBackend::start(tasks, instant_factory(), 1.0, false)
        .expect("backend start");
    let report = run_engine_stream(&mut backend, &mut policy, &params, ArrivalSource::Stream, None)
        .expect("engine");
    backend.finish();
    assert_eq!(
        lane_log(&report.dispatch_log, Lane::Gpu),
        vec![vec![0, 1], vec![2]],
        "ξ expiry should force the partial batch while the stream is open"
    );
}

/// Live producers: tasks injected through a cloned `ArrivalHandle`
/// (the TCP connection-handler path) are served, and `close()` drains
/// the engine to a clean return.
#[test]
fn live_arrival_handle_feeds_open_stream() {
    let (mut backend, arrivals) = ThreadedBackend::start_stream(instant_factory())
        .expect("backend start");
    let producer = {
        let arrivals = arrivals.clone();
        std::thread::spawn(move || {
            for i in 0..5u64 {
                let now = arrivals.now();
                arrivals.inject(mk_task(i, now, now + 5.0, 10.0)).expect("inject");
            }
            arrivals.close();
        })
    };
    let params = SchedParams { batch_size: 2, xi: 0.05, ..Default::default() };
    let mut policy = Fifo::new(params.batch_size);
    let report = run_engine_stream(&mut backend, &mut policy, &params, ArrivalSource::Stream, None)
        .expect("engine");
    producer.join().expect("producer");
    backend.finish();
    assert_eq!(report.outcomes.len(), 5, "all injected tasks must complete");
    for o in &report.outcomes {
        assert!(o.completion >= o.arrival, "task {} completed before arrival", o.id);
    }
}

/// With a completion callback attached to an open stream, every task is
/// streamed out exactly once and the report stays lean — a long-lived
/// server must not accumulate per-task state in the engine.
#[test]
fn stream_callback_sees_every_completion_and_report_stays_lean() {
    let n = 12usize;
    let tasks: Vec<Task> = (0..n).map(|i| mk_task(i as u64, 0.0, 5.0, 10.0)).collect();
    let params = SchedParams { batch_size: 4, ..Default::default() };
    let mut policy = Fifo::new(params.batch_size);
    let mut backend = ThreadedBackend::start(tasks, instant_factory(), 1.0, true)
        .expect("backend start");
    let mut seen: Vec<u64> = Vec::new();
    let mut on_complete = |o: &rtlm::sim::results::TaskOutcome, output: &[i32]| {
        assert!(output.is_empty(), "instant executor produces no tokens");
        seen.push(o.id);
    };
    let report = run_engine_stream(
        &mut backend,
        &mut policy,
        &params,
        ArrivalSource::Stream,
        Some(&mut on_complete),
    )
    .expect("engine");
    backend.finish();

    seen.sort_unstable();
    assert_eq!(seen, (0..n as u64).collect::<Vec<_>>(), "every task streamed exactly once");
    assert!(report.outcomes.is_empty(), "streaming mode must not store outcomes");
    assert!(report.dispatch_log.is_empty(), "streaming mode must not store the dispatch log");
    assert_eq!(report.n_batches_gpu, 3, "aggregate counters still maintained");
}

/// NaN-uncertainty tasks must not panic the wire path either: ordering
/// is total everywhere on the scheduling hot path.
#[test]
fn nan_uncertainty_survives_the_wire_path() {
    let mut tasks: Vec<Task> = (0..6)
        .map(|i| mk_task(i as u64, 0.0, 5.0 + i as f64, 10.0 + i as f64))
        .collect();
    tasks[1].uncertainty = f64::NAN;
    tasks[4].uncertainty = f64::NAN;
    let params = SchedParams { batch_size: 2, ..Default::default() };
    for kind in [PolicyKind::Fifo, PolicyKind::Hpf, PolicyKind::RtLm] {
        let mut policy = kind.build(&params, 0.05, 60.0);
        let mut backend =
            ThreadedBackend::start(tasks.clone(), instant_factory(), 1.0, true)
                .expect("backend start");
        let report = run_engine(&mut backend, &mut *policy, &params, 6).expect("engine");
        backend.finish();
        assert_eq!(report.outcomes.len(), 6, "{} lost NaN tasks", kind.label());
    }
}
