//! Integration tests for the shared dispatcher core (`rtlm::engine`):
//! the cross-backend equivalence property (same trace + policy =>
//! identical per-lane batch sequences in simulation and on the wire),
//! for the default two-lane fleet, a 3-lane heterogeneous fleet (two
//! accelerator variants + CPU quarantine) across every `PolicyKind`,
//! and the degenerate 1-lane fleet; lane starvation (a predicate that
//! admits nothing must not stall ξ-forced draining); the open-stream
//! properties (a closed trace served as an open stream dispatches
//! identically to its counted run on both backends; live
//! `ArrivalHandle` producers drain cleanly; streaming callbacks see
//! every completion); the arrivals-drain regression (no forced dispatch
//! while arrival events are still queued); the ξ-deadline wakeup of the
//! wall-clock dispatcher; NaN-uncertainty resilience on the wire path;
//! and the CPU-lane scoped-thread pool's makespan matching the
//! simulator's intra-batch worker model.
//!
//! Iteration-level mode (`SchedMode::Step`) gets its own section at the
//! bottom: join-at-step-boundary / individual-leave semantics on the
//! virtual clock, overrun preemption rerouting a mispredicted
//! generation to the CPU lane, and the cross-backend agreement of the
//! step-mode deterministic counters (per-lane steps, per-task lanes,
//! preemption count).

use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;

use rtlm::config::{DeviceProfile, ModelEntry, SchedMode, SchedParams, ShedPolicy};
use rtlm::engine::{
    resolve_lanes, run_engine, run_engine_stream, ArrivalSource, SimBackend, ThreadedBackend,
};
use rtlm::executor::{BatchExecutor, ExecutorFactory, InstantExecutor, ModeledExecutor};
use rtlm::scheduler::{
    Admission, Batch, Fifo, LaneId, LaneKind, LaneSet, LaneSpec, PolicyKind, SloClass, Task,
};
use rtlm::sim::{Calibration, LatencyModel};
use rtlm::util::rng::Pcg64;

fn mk_task(id: u64, arrival: f64, priority_point: f64, uncertainty: f64) -> Task {
    Task {
        id,
        text: String::new(),
        prompt: vec![],
        arrival,
        priority_point,
        uncertainty,
        true_len: uncertainty.max(1.0).min(96.0) as usize,
        input_len: 8,
        utype: "test".into(),
        malicious: false,
        deferrals: 0,
        slo: SloClass::Standard,
    }
}

/// A latency model in which every batch takes zero time — the virtual
/// clock never advances, matching the instant executor's wall clock
/// (which advances only by scheduling overhead, microseconds).
fn zero_latency() -> LatencyModel {
    let mut c = Calibration::default();
    c.decode.insert(
        "m".into(),
        std::collections::BTreeMap::from([(1usize, 0.0), (16, 0.0)]),
    );
    c.prefill.insert(
        "m".into(),
        std::collections::BTreeMap::from([((1usize, 16usize), 0.0), ((16, 64), 0.0)]),
    );
    LatencyModel::from_calibration(&c)
}

fn zero_device() -> DeviceProfile {
    DeviceProfile {
        name: "zero".into(),
        gpu_speed: 1.0,
        cpu_speed: 1.0,
        batching_exp: 0.0,
        dispatch_overhead: 0.0,
        offload_overhead: 0.0,
        cpu_workers: 1,
        batch_knee: 1e9,
    }
}

fn instant_factory() -> ExecutorFactory {
    Arc::new(|_spec: &LaneSpec| Ok(Box::new(InstantExecutor) as Box<dyn BatchExecutor>))
}

fn two_lane(tau: f64) -> LaneSet {
    LaneSet::two_lane("m", tau)
}

/// Two accelerator variants + CPU quarantine: the heterogeneous-fleet
/// fixture. Low-uncertainty traffic takes the small model, the extreme
/// tail quarantines, everything else rides the big fallback lane.
fn three_lane() -> LaneSet {
    LaneSet::new(vec![
        LaneSpec::accelerator("big", "m"),
        LaneSpec {
            admission: Admission::AtMost(25.0),
            ..LaneSpec::accelerator("small", "m")
        },
        LaneSpec {
            workers: Some(2),
            ..LaneSpec::cpu_offload("cpu", "m", 65.0)
        },
    ])
    .expect("3-lane fixture is valid")
}

fn model_table(model: &ModelEntry) -> BTreeMap<String, ModelEntry> {
    BTreeMap::from([(model.name.clone(), model.clone())])
}

fn lane_log(log: &[(LaneId, Vec<u64>)], lane: LaneId) -> Vec<Vec<u64>> {
    log.iter()
        .filter(|(l, _)| *l == lane)
        .map(|(_, ids)| ids.clone())
        .collect()
}

/// Run the same trace + policy kind through both backends over `lanes`
/// and assert identical per-lane dispatch sequences and task lanes.
fn assert_cross_backend_equivalence(
    lanes: &LaneSet,
    tasks: &[Task],
    params: &SchedParams,
    kind: PolicyKind,
    seed: u64,
) {
    let model = ModelEntry::stub("m", 0.05, 0.08);
    let lat = zero_latency();
    let dev = zero_device();
    let n = tasks.len();

    let mut sim_policy = kind.build(params, model.eta, lanes);
    let sim_lanes =
        resolve_lanes(lanes, &model_table(&model), &lat, &dev).expect("resolve lanes");
    let mut sim_backend = SimBackend::new(tasks.to_vec(), &lat, sim_lanes, &dev, params);
    let sim = run_engine(&mut sim_backend, &mut *sim_policy, params, n).expect("sim backend");

    let mut thr_policy = kind.build(params, model.eta, lanes);
    let mut thr_backend =
        ThreadedBackend::start(tasks.to_vec(), instant_factory(), lanes, params, 1.0, true)
            .expect("threaded backend start");
    let thr = run_engine(&mut thr_backend, &mut *thr_policy, params, n).expect("threaded backend");
    thr_backend.finish();

    for lane in lanes.ids() {
        assert_eq!(
            lane_log(&sim.dispatch_log, lane),
            lane_log(&thr.dispatch_log, lane),
            "seed {seed} policy {} lane {} ({}): dispatch sequences diverged",
            kind.label(),
            lane,
            lanes.spec(lane).name
        );
    }
    assert_eq!(sim.outcomes.len(), n);
    assert_eq!(thr.outcomes.len(), n);
    assert_eq!(sim.n_batches, thr.n_batches, "seed {seed} {}", kind.label());
    let sim_lanes_by_id: HashMap<u64, LaneId> =
        sim.outcomes.iter().map(|o| (o.id, o.lane)).collect();
    for o in &thr.outcomes {
        assert_eq!(
            sim_lanes_by_id[&o.id], o.lane,
            "seed {seed} policy {}: task {} changed lane",
            kind.label(),
            o.id
        );
    }
}

/// Coarse value grids keep priorities well separated, so the
/// microseconds of wall-clock drift on the threaded path cannot reorder
/// them; exact ties fall back to arrival/queue order, which both
/// backends share.
fn grid_tasks(rng: &mut Pcg64, n: usize) -> Vec<Task> {
    (0..n)
        .map(|i| {
            let pp = 1.0 + 0.5 * rng.range_usize(0, 10) as f64;
            let u = 5.0 + 10.0 * rng.range_usize(0, 9) as f64;
            mk_task(i as u64, 0.0, pp, u)
        })
        .collect()
}

/// Same trace + same policy through the virtual-clock backend and the
/// threaded wall-clock backend (deterministic instant executor, arrivals
/// pre-queued) must dispatch identical batch sequences on each lane.
#[test]
fn cross_backend_dispatch_equivalence() {
    for seed in 0..12u64 {
        let mut rng = Pcg64::new(seed);
        let n = 4 + rng.range_usize(0, 24);
        let tasks = grid_tasks(&mut rng, n);
        let params = SchedParams { batch_size: 4, ..Default::default() };

        for kind in [
            PolicyKind::Fifo,
            PolicyKind::Hpf,
            PolicyKind::Luf,
            PolicyKind::Muf,
            PolicyKind::UpC,
            PolicyKind::RtLm,
        ] {
            assert_cross_backend_equivalence(&two_lane(60.0), &tasks, &params, kind, seed);
        }
    }
}

/// The same property over the 3-lane heterogeneous fleet, for *every*
/// policy kind: one dispatcher loop schedules an N-lane fleet
/// identically on the virtual clock and on real threads.
#[test]
fn three_lane_cross_backend_dispatch_equivalence() {
    let lanes = three_lane();
    for seed in 0..6u64 {
        let mut rng = Pcg64::new(0x3A5E ^ seed);
        let n = 4 + rng.range_usize(0, 24);
        let tasks = grid_tasks(&mut rng, n);
        let params = SchedParams { batch_size: 4, ..Default::default() };
        for kind in PolicyKind::ALL {
            assert_cross_backend_equivalence(&lanes, &tasks, &params, kind, seed);
        }
    }
}

/// Degenerate 1-lane fleet: a single fallback lane serves everything,
/// identically on both backends, under the full RT-LM policy.
#[test]
fn single_lane_fleet_serves_everything() {
    let lanes = LaneSet::single("m");
    for seed in 0..4u64 {
        let mut rng = Pcg64::new(0x51E ^ seed);
        let n = 3 + rng.range_usize(0, 16);
        let tasks = grid_tasks(&mut rng, n);
        let params = SchedParams { batch_size: 4, ..Default::default() };
        for kind in [PolicyKind::Fifo, PolicyKind::RtLm] {
            assert_cross_backend_equivalence(&lanes, &tasks, &params, kind, seed);
        }
    }
}

/// A lane whose predicate admits nothing gets no traffic — and must not
/// stall the fleet: the partial batch still goes out on the fallback
/// lane at the ξ expiry, and the run drains.
#[test]
fn starved_lane_does_not_stall_xi_forcing() {
    let lanes = LaneSet::new(vec![
        LaneSpec::accelerator("gpu", "m"),
        LaneSpec {
            admission: Admission::Nothing,
            ..LaneSpec::accelerator("idle", "m")
        },
        LaneSpec::cpu_offload("cpu", "m", 65.0),
    ])
    .expect("valid");
    let model = ModelEntry::stub("m", 0.05, 0.08);
    // tiny but nonzero latencies so the virtual clock actually advances
    let mut c = Calibration::default();
    c.decode
        .insert("m".into(), std::collections::BTreeMap::from([(1usize, 0.01), (16, 0.04)]));
    c.prefill
        .insert("m".into(), std::collections::BTreeMap::from([((1usize, 16usize), 0.02)]));
    let lat = LatencyModel::from_calibration(&c);
    let dev = DeviceProfile::edge_server();

    // two tasks at t=0 with C=4: only the ξ=2s expiry can dispatch them
    let tasks = vec![
        mk_task(0, 0.0, 10.0, 10.0),
        mk_task(1, 0.0, 12.0, 12.0),
        mk_task(2, 10.0, 14.0, 90.0), // late arrival, quarantines
    ];
    let params = SchedParams { batch_size: 4, ..Default::default() };
    let mut policy = PolicyKind::RtLm.build(&params, model.eta, &lanes);
    let sim_lanes = resolve_lanes(&lanes, &model_table(&model), &lat, &dev).expect("resolve");
    let mut backend = SimBackend::new(tasks, &lat, sim_lanes, &dev, &params);
    let report = run_engine(&mut backend, &mut *policy, &params, 3).expect("engine");

    assert_eq!(report.outcomes.len(), 3, "starved lane must not lose tasks");
    assert_eq!(report.n_batches[1], 0, "admit-nothing lane executed a batch");
    assert_eq!(report.n_batches[0], 1);
    assert_eq!(report.n_batches[2], 1);
    let by_id: HashMap<u64, f64> =
        report.outcomes.iter().map(|o| (o.id, o.completion)).collect();
    assert!(
        by_id[&0] >= params.xi && by_id[&0] < 4.0,
        "first batch should dispatch at the ξ expiry: {}",
        by_id[&0]
    );
}

/// Regression for the arrivals-done race: the historical wall-clock
/// engine guessed "arrivals done" from `policy.queue_len() <=
/// meta.len()` (vacuously true), so ξ-forced dispatch could fire while
/// Arrival events were still queued in the channel — emitting runt
/// batches. With every arrival pre-queued, the unified core must admit
/// the whole channel before its first (then forced) dispatch.
#[test]
fn arrivals_drain_before_forced_dispatch() {
    let n = 10usize;
    let tasks: Vec<Task> = (0..n)
        .map(|i| mk_task(i as u64, 0.0, 5.0, 10.0))
        .collect();
    let params = SchedParams { batch_size: 4, ..Default::default() };
    let mut policy = Fifo::new(params.batch_size);
    let mut backend =
        ThreadedBackend::start(tasks, instant_factory(), &two_lane(60.0), &params, 1.0, true)
            .expect("backend start");
    let report = run_engine(&mut backend, &mut policy, &params, n).expect("engine");
    backend.finish();

    assert_eq!(
        lane_log(&report.dispatch_log, LaneId::GPU),
        vec![vec![0, 1, 2, 3], vec![4, 5, 6, 7], vec![8, 9]],
        "forced dispatch must not fire before the arrival channel drains"
    );
    assert_eq!(report.n_batches[LaneId::GPU.index()], 3);
    assert_eq!(report.n_batches[LaneId::CPU.index()], 0);
}

/// The wall-clock dispatcher must wake at the ξ expiry (computed
/// deadline — not a 10 ms busy-poll) and force the partial batch out,
/// instead of waiting for the next arrival or completion event.
#[test]
fn xi_deadline_wakes_wall_clock_dispatcher() {
    let tasks = vec![
        mk_task(0, 0.0, 5.0, 10.0),
        mk_task(1, 0.0, 5.0, 12.0),
        mk_task(2, 0.8, 5.0, 14.0),
    ];
    let params = SchedParams { batch_size: 4, xi: 0.2, ..Default::default() };
    let mut policy = Fifo::new(params.batch_size);
    let mut backend =
        ThreadedBackend::start(tasks, instant_factory(), &two_lane(60.0), &params, 1.0, false)
            .expect("backend start");
    let report = run_engine(&mut backend, &mut policy, &params, 3).expect("engine");
    backend.finish();

    assert_eq!(
        lane_log(&report.dispatch_log, LaneId::GPU),
        vec![vec![0, 1], vec![2]],
        "ξ expiry should force the partial batch before the late arrival"
    );
    let by_id: HashMap<u64, f64> =
        report.outcomes.iter().map(|o| (o.id, o.completion)).collect();
    assert!(
        by_id[&0] >= 0.18 && by_id[&0] < 0.7,
        "first batch should dispatch at the ξ=0.2s expiry, completed at {}",
        by_id[&0]
    );
    assert!(by_id[&2] >= 0.75, "late task completed at {}", by_id[&2]);
}

/// A closed trace served as an *open stream* (no fixed `n_total`; the
/// backend reports stream closure) must dispatch exactly like its
/// counted run — on the virtual clock and on the wire. This is the
/// property that lets the TCP front-end run the same loop as the
/// simulator.
#[test]
fn open_stream_matches_counted_on_both_backends() {
    let model = ModelEntry::stub("m", 0.05, 0.08);
    let lat = zero_latency();
    let dev = zero_device();
    let lanes = two_lane(60.0);

    for seed in 0..6u64 {
        let mut rng = Pcg64::new(seed);
        let n = 4 + rng.range_usize(0, 24);
        let tasks = grid_tasks(&mut rng, n);
        let params = SchedParams { batch_size: 4, ..Default::default() };

        for kind in [PolicyKind::Fifo, PolicyKind::RtLm] {
            let mut p = kind.build(&params, model.eta, &lanes);
            let mut b = SimBackend::two_lane(tasks.clone(), &lat, &model, &dev, &params);
            let counted = run_engine(&mut b, &mut *p, &params, n).expect("sim counted");

            let mut p = kind.build(&params, model.eta, &lanes);
            let mut b = SimBackend::two_lane(tasks.clone(), &lat, &model, &dev, &params);
            let streamed = run_engine_stream(&mut b, &mut *p, &params, ArrivalSource::Stream, None)
                .expect("sim stream");
            // the virtual clock is deterministic: the full interleaved
            // dispatch sequence must match, not just per-lane views
            assert_eq!(
                counted.dispatch_log, streamed.dispatch_log,
                "seed {seed} policy {}: sim stream diverged from counted",
                kind.label()
            );
            assert_eq!(streamed.outcomes.len(), n);

            let mut p = kind.build(&params, model.eta, &lanes);
            let mut b =
                ThreadedBackend::start(tasks.clone(), instant_factory(), &lanes, &params, 1.0, true)
                    .expect("threaded start");
            let wired = run_engine_stream(&mut b, &mut *p, &params, ArrivalSource::Stream, None)
                .expect("threaded stream");
            b.finish();
            for lane in lanes.ids() {
                assert_eq!(
                    lane_log(&counted.dispatch_log, lane),
                    lane_log(&wired.dispatch_log, lane),
                    "seed {seed} policy {} lane {lane}: wire stream diverged",
                    kind.label()
                );
            }
            assert_eq!(wired.outcomes.len(), n);
        }
    }
}

/// Open-stream ξ-forcing on the wall clock: with the stream still open
/// (no trace count to exhaust), the partial batch must go out at the ξ
/// expiry, not wait for the late arrival.
#[test]
fn open_stream_xi_forcing_with_late_arrivals() {
    let tasks = vec![
        mk_task(0, 0.0, 5.0, 10.0),
        mk_task(1, 0.0, 5.0, 12.0),
        mk_task(2, 0.8, 5.0, 14.0),
    ];
    let params = SchedParams { batch_size: 4, xi: 0.2, ..Default::default() };
    let mut policy = Fifo::new(params.batch_size);
    let mut backend =
        ThreadedBackend::start(tasks, instant_factory(), &two_lane(60.0), &params, 1.0, false)
            .expect("backend start");
    let report = run_engine_stream(&mut backend, &mut policy, &params, ArrivalSource::Stream, None)
        .expect("engine");
    backend.finish();
    assert_eq!(
        lane_log(&report.dispatch_log, LaneId::GPU),
        vec![vec![0, 1], vec![2]],
        "ξ expiry should force the partial batch while the stream is open"
    );
}

/// Live producers: tasks injected through a cloned `ArrivalHandle`
/// (the TCP connection-handler path) are served, and `close()` drains
/// the engine to a clean return.
#[test]
fn live_arrival_handle_feeds_open_stream() {
    let params = SchedParams { batch_size: 2, xi: 0.05, ..Default::default() };
    let (mut backend, arrivals) =
        ThreadedBackend::start_stream(instant_factory(), &two_lane(60.0), &params)
            .expect("backend start");
    let producer = {
        let arrivals = arrivals.clone();
        std::thread::spawn(move || {
            for i in 0..5u64 {
                let now = arrivals.now();
                arrivals.inject(mk_task(i, now, now + 5.0, 10.0)).expect("inject");
            }
            arrivals.close();
        })
    };
    let mut policy = Fifo::new(params.batch_size);
    let report = run_engine_stream(&mut backend, &mut policy, &params, ArrivalSource::Stream, None)
        .expect("engine");
    producer.join().expect("producer");
    backend.finish();
    assert_eq!(report.outcomes.len(), 5, "all injected tasks must complete");
    for o in &report.outcomes {
        assert!(o.completion >= o.arrival, "task {} completed before arrival", o.id);
    }
}

/// With a completion callback attached to an open stream, every task is
/// streamed out exactly once and the report stays lean — a long-lived
/// server must not accumulate per-task state in the engine.
#[test]
fn stream_callback_sees_every_completion_and_report_stays_lean() {
    let n = 12usize;
    let tasks: Vec<Task> = (0..n).map(|i| mk_task(i as u64, 0.0, 5.0, 10.0)).collect();
    let params = SchedParams { batch_size: 4, ..Default::default() };
    let mut policy = Fifo::new(params.batch_size);
    let mut backend =
        ThreadedBackend::start(tasks, instant_factory(), &two_lane(60.0), &params, 1.0, true)
            .expect("backend start");
    let mut seen: Vec<u64> = Vec::new();
    let mut on_complete = |o: &rtlm::sim::results::TaskOutcome, output: &[i32]| {
        assert!(output.is_empty(), "instant executor produces no tokens");
        seen.push(o.id);
    };
    let report = run_engine_stream(
        &mut backend,
        &mut policy,
        &params,
        ArrivalSource::Stream,
        Some(&mut on_complete),
    )
    .expect("engine");
    backend.finish();

    seen.sort_unstable();
    assert_eq!(seen, (0..n as u64).collect::<Vec<_>>(), "every task streamed exactly once");
    assert!(report.outcomes.is_empty(), "streaming mode must not store outcomes");
    assert!(report.dispatch_log.is_empty(), "streaming mode must not store the dispatch log");
    assert_eq!(report.n_batches[LaneId::GPU.index()], 3, "aggregate counters still maintained");
}

/// NaN-uncertainty tasks must not panic the wire path either: ordering
/// is total everywhere on the scheduling hot path.
#[test]
fn nan_uncertainty_survives_the_wire_path() {
    let mut tasks: Vec<Task> = (0..6)
        .map(|i| mk_task(i as u64, 0.0, 5.0 + i as f64, 10.0 + i as f64))
        .collect();
    tasks[1].uncertainty = f64::NAN;
    tasks[4].uncertainty = f64::NAN;
    let params = SchedParams { batch_size: 2, ..Default::default() };
    let lanes = two_lane(60.0);
    for kind in [PolicyKind::Fifo, PolicyKind::Hpf, PolicyKind::RtLm] {
        let mut policy = kind.build(&params, 0.05, &lanes);
        let mut backend =
            ThreadedBackend::start(tasks.clone(), instant_factory(), &lanes, &params, 1.0, true)
                .expect("backend start");
        let report = run_engine(&mut backend, &mut *policy, &params, 6).expect("engine");
        backend.finish();
        assert_eq!(report.outcomes.len(), 6, "{} lost NaN tasks", kind.label());
    }
}

/// The modeled CPU-lane executor fans one quarantine batch across a
/// scoped std-thread pool — its wall-clock makespan must match the
/// simulator's `cpu_workers` intra-batch earliest-free-first model
/// (ROADMAP "tokio-free async lane pool"), and beat the sequential
/// single-worker execution of the same batch.
#[test]
fn modeled_cpu_pool_makespan_matches_simulator_model() {
    let model = ModelEntry::stub("m", 0.05, 0.08);
    let mut c = Calibration::default();
    c.decode
        .insert("m".into(), std::collections::BTreeMap::from([(1usize, 0.01)]));
    c.prefill
        .insert("m".into(), std::collections::BTreeMap::from([((1usize, 16usize), 0.02)]));
    let lat = LatencyModel::from_calibration(&c);
    let dev = DeviceProfile::edge_server();
    let time_scale = 40.0;

    // 6 quarantined tasks with unequal lengths
    let tasks: Vec<Task> = (0..6)
        .map(|i| mk_task(i as u64, 0.0, 5.0, 70.0 + 4.0 * i as f64))
        .collect();
    let batch = Batch { lane: LaneId::CPU, tasks: tasks.clone() };

    // the simulator's earliest-free-first worker-pool makespan
    let pool_makespan = |workers: usize| -> f64 {
        let mut free = vec![0.0f64; workers];
        for task in &tasks {
            let w = (0..free.len())
                .min_by(|&a, &b| free[a].total_cmp(&free[b]))
                .unwrap();
            free[w] += lat.cpu_task_secs(&model, task.true_len, task.input_len, &dev);
        }
        free.iter().copied().fold(0.0, f64::max)
    };

    let run = |workers: usize| -> f64 {
        let mut exec = ModeledExecutor {
            lat: lat.clone(),
            model: model.clone(),
            dev: dev.clone(),
            time_scale,
            kind: LaneKind::Cpu,
            workers,
        };
        let t0 = std::time::Instant::now();
        let reports = exec.execute(&batch).expect("modeled execute");
        let wall = t0.elapsed().as_secs_f64();
        assert_eq!(reports.len(), 6, "one report per task");
        // reports come back in task order so outputs stay correlated
        let ids: Vec<u64> = reports.iter().flat_map(|r| r.task_ids.clone()).collect();
        assert_eq!(ids, (0..6).collect::<Vec<u64>>());
        wall * time_scale
    };

    let seq = run(1);
    let pooled = run(3);
    let expect_seq = pool_makespan(1);
    let expect_pooled = pool_makespan(3);

    // the pool genuinely parallelises: 3 workers cut the makespan well
    // below sequential (model predicts ~1/3)
    assert!(
        pooled < 0.6 * seq,
        "pooled {pooled:.3}s vs sequential {seq:.3}s: no intra-batch parallelism"
    );
    // and each matches the simulator's modeled makespan (generous
    // tolerance: sleep granularity + thread scheduling jitter, scaled)
    for (wall, expect, label) in [(seq, expect_seq, "seq"), (pooled, expect_pooled, "pooled")] {
        let rel = (wall - expect).abs() / expect;
        assert!(
            rel < 0.35,
            "{label}: wall {wall:.3}s vs modeled {expect:.3}s ({:.0}% off)",
            rel * 100.0
        );
    }
}

// ---------------------------------------------------------------------------
// iteration-level (--sched step) dispatch
// ---------------------------------------------------------------------------

/// Small but nonzero latencies, so step-mode ticks genuinely advance
/// the virtual clock and join/leave ordering is observable.
fn step_latency() -> LatencyModel {
    let mut c = Calibration::default();
    c.decode
        .insert("m".into(), std::collections::BTreeMap::from([(1usize, 0.01), (16, 0.04)]));
    c.prefill
        .insert("m".into(), std::collections::BTreeMap::from([((1usize, 16usize), 0.02)]));
    LatencyModel::from_calibration(&c)
}

/// Step mode on the virtual clock: tasks sharing a slot table leave
/// individually when their own generation ends, and the freed slot is
/// refilled at a step boundary — a later task's first token can only
/// appear after some earlier generation left.
#[test]
fn step_mode_joins_at_boundaries_and_leaves_individually() {
    let model = ModelEntry::stub("m", 0.05, 0.08);
    let lat = step_latency();
    let dev = zero_device();
    let lanes = two_lane(60.0);
    // 2 slots, 3 tasks: the third can only join once a slot frees
    let params = SchedParams {
        batch_size: 2,
        xi: 0.0,
        mode: SchedMode::Step,
        ..Default::default()
    };
    let mut tasks = vec![
        mk_task(0, 0.0, 50.0, 4.0),
        mk_task(1, 0.0, 50.0, 8.0),
        mk_task(2, 0.0, 50.0, 12.0),
    ];
    for t in &mut tasks {
        t.true_len = t.uncertainty as usize; // 4 / 8 / 12 decode steps
    }

    let mut policy = Fifo::new(params.batch_size);
    let sim_lanes = resolve_lanes(&lanes, &model_table(&model), &lat, &dev).expect("resolve");
    let mut backend = SimBackend::new(tasks, &lat, sim_lanes, &dev, &params);
    let report = run_engine(&mut backend, &mut policy, &params, 3).expect("engine");

    assert_eq!(report.outcomes.len(), 3);
    let by_id: HashMap<u64, &rtlm::sim::results::TaskOutcome> =
        report.outcomes.iter().map(|o| (o.id, o)).collect();
    for o in &report.outcomes {
        assert_eq!(o.lane, LaneId::GPU, "task {} left the accelerator lane", o.id);
        assert!(
            o.arrival <= o.first_token && o.first_token < o.completion,
            "task {}: acausal ttft ({} / {} / {})",
            o.id,
            o.arrival,
            o.first_token,
            o.completion
        );
    }
    // individual leaves: the 4-step generation finishes first, well
    // before its 8-step co-batched neighbour
    assert!(by_id[&0].completion < by_id[&1].completion, "short generation held by long");
    // join at a step boundary: task 2 found both slots taken at t=0 and
    // could emit its first token only after task 0 left
    assert!(
        by_id[&2].first_token > by_id[&0].completion,
        "task 2 joined before a slot freed ({} <= {})",
        by_id[&2].first_token,
        by_id[&0].completion
    );
    // two join groups (0,1 then 2), every decode step accounted
    assert_eq!(report.n_batches[LaneId::GPU.index()], 2);
    assert_eq!(report.n_steps[LaneId::GPU.index()], 4 + 8 + 12);
    assert_eq!(report.n_preempted, 0);
}

/// Overrun preemption: a generation whose true length far exceeds its
/// predicted length is ejected at a step boundary, re-scored, and
/// re-routed — with the quarantine threshold below its new score, it
/// finishes on the CPU lane, and both lanes' step counters account for
/// exactly the steps they executed.
#[test]
fn step_mode_overrun_preempts_to_cpu_lane() {
    let model = ModelEntry::stub("m", 0.05, 0.08);
    let lat = step_latency();
    let dev = DeviceProfile::edge_server();
    let lanes = two_lane(5.0); // quarantine anything scored above 5
    let params = SchedParams { batch_size: 4, mode: SchedMode::Step, ..Default::default() };
    // predicted 2 tokens, actually 96: overrun_factor 3 ejects it once
    // done_steps exceeds 3 * 2 = 6, i.e. after step 7
    let mut task = mk_task(0, 0.0, 50.0, 2.0);
    task.true_len = 96;

    let mut policy = PolicyKind::RtLm.build(&params, model.eta, &lanes);
    let sim_lanes = resolve_lanes(&lanes, &model_table(&model), &lat, &dev).expect("resolve");
    let mut backend = SimBackend::new(vec![task], &lat, sim_lanes, &dev, &params);
    let report = run_engine(&mut backend, &mut *policy, &params, 1).expect("engine");

    assert_eq!(report.n_preempted, 1, "overrun generation was not preempted");
    assert_eq!(report.outcomes.len(), 1, "preempted task lost");
    let o = &report.outcomes[0];
    assert_eq!(o.lane, LaneId::CPU, "re-scored task should quarantine to the CPU lane");
    assert_eq!(
        report.n_steps[LaneId::GPU.index()],
        7,
        "accelerator executed steps up to the overrun boundary"
    );
    assert_eq!(
        report.n_steps[LaneId::CPU.index()],
        96 - 7,
        "CPU lane executed exactly the remaining generation"
    );
    assert!(o.first_token.is_finite() && o.completion > o.arrival);
}

/// The step-mode deterministic counters agree across backends: per-lane
/// decode-step totals, per-task lane assignment, and the preemption
/// count are timing-independent (lane routing happens at push time,
/// per-task step counts are fixed integers, preemption triggers on step
/// counts), so the virtual clock and the wire must match them exactly
/// even though join-group composition may race on the wire.
#[test]
fn step_mode_counters_match_across_backends() {
    let model = ModelEntry::stub("m", 0.05, 0.08);
    let lat = zero_latency();
    let dev = zero_device();
    let lanes = two_lane(60.0);
    for seed in 0..6u64 {
        let mut rng = Pcg64::new(0x57E9 ^ seed);
        let n = 4 + rng.range_usize(0, 24);
        let tasks = grid_tasks(&mut rng, n);
        let params = SchedParams {
            batch_size: 4,
            mode: SchedMode::Step,
            ..Default::default()
        };
        for kind in [PolicyKind::Fifo, PolicyKind::RtLm] {
            let mut p = kind.build(&params, model.eta, &lanes);
            let sim_lanes =
                resolve_lanes(&lanes, &model_table(&model), &lat, &dev).expect("resolve");
            let mut b = SimBackend::new(tasks.clone(), &lat, sim_lanes, &dev, &params);
            let sim = run_engine(&mut b, &mut *p, &params, n).expect("sim step run");

            let mut p = kind.build(&params, model.eta, &lanes);
            let mut b = ThreadedBackend::start(
                tasks.clone(),
                instant_factory(),
                &lanes,
                &params,
                1.0,
                true,
            )
            .expect("threaded start");
            let wire = run_engine(&mut b, &mut *p, &params, n).expect("wire step run");
            b.finish();

            assert_eq!(sim.outcomes.len(), n, "seed {seed} {}: sim lost tasks", kind.label());
            assert_eq!(wire.outcomes.len(), n, "seed {seed} {}: wire lost tasks", kind.label());
            assert_eq!(
                sim.n_steps,
                wire.n_steps,
                "seed {seed} {}: per-lane step totals diverged",
                kind.label()
            );
            assert_eq!(sim.n_preempted, wire.n_preempted, "seed {seed} {}", kind.label());
            let sim_lane: HashMap<u64, LaneId> =
                sim.outcomes.iter().map(|o| (o.id, o.lane)).collect();
            for o in &wire.outcomes {
                assert_eq!(
                    sim_lane[&o.id], o.lane,
                    "seed {seed} {}: task {} changed lane between backends",
                    kind.label(),
                    o.id
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// overload admission control (--queue-cap / --shed)
// ---------------------------------------------------------------------------

/// Overload shedding on the virtual clock: 30 simultaneous arrivals
/// into a cap-8 lane, with the batch size above the cap so no dispatch
/// can drain the queue mid-admission (the first pop is the ξ-forced
/// one). Every submitted id gets exactly one outcome — served or shed —
/// the sheds are exactly the lowest-priority tasks, shed outcomes carry
/// zero service, and the cap-8 survivors dispatch normally.
#[test]
fn overload_sheds_lowest_priority_and_accounts_for_every_task() {
    let model = ModelEntry::stub("m", 0.05, 0.08);
    let lat = step_latency();
    let dev = DeviceProfile::edge_server();
    let lanes = two_lane(60.0);
    let params = SchedParams {
        batch_size: 32,
        queue_cap: 8,
        shed: ShedPolicy::Priority,
        ..Default::default()
    };
    // priority strictly decreasing in id (equal uncertainty, deadlines
    // widening): the cap-8 queue must retain exactly ids 0..8
    let tasks: Vec<Task> =
        (0..30).map(|i| mk_task(i, 0.0, 2.0 + i as f64, 10.0)).collect();

    let mut policy = PolicyKind::RtLm.build(&params, model.eta, &lanes);
    let sim_lanes = resolve_lanes(&lanes, &model_table(&model), &lat, &dev).expect("resolve");
    let mut backend = SimBackend::new(tasks, &lat, sim_lanes, &dev, &params);
    let report = run_engine(&mut backend, &mut *policy, &params, 30).expect("engine");

    assert_eq!(report.outcomes.len(), 30, "every id answered exactly once");
    let mut ids: Vec<u64> = report.outcomes.iter().map(|o| o.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..30u64).collect::<Vec<_>>(), "duplicate or missing ids");
    assert_eq!(report.n_shed, 22);
    for o in &report.outcomes {
        if o.id < 8 {
            assert!(!o.shed, "high-priority task {} was shed", o.id);
            assert!(o.completion > o.arrival, "served task {} has no service time", o.id);
        } else {
            assert!(o.shed, "low-priority task {} should have been shed", o.id);
            assert_eq!(o.completion, o.arrival, "shed outcome must carry zero service");
            assert_eq!(o.infer_secs, 0.0);
        }
    }
}

/// `--shed length` picks the highest-predicted-length victim instead:
/// with predicted lengths increasing in id, the cap-4 queue retains the
/// four shortest predictions and sheds the rest — again with exactly
/// one outcome per submitted id.
#[test]
fn overload_length_shed_drops_longest_predictions() {
    let model = ModelEntry::stub("m", 0.05, 0.08);
    let lat = step_latency();
    let dev = DeviceProfile::edge_server();
    let lanes = two_lane(f64::INFINITY); // no quarantine: routing stays put
    let params = SchedParams {
        batch_size: 32,
        queue_cap: 4,
        shed: ShedPolicy::Length,
        ..Default::default()
    };
    let tasks: Vec<Task> =
        (0..12u64).map(|i| mk_task(i, 0.0, 5.0, 10.0 + 5.0 * i as f64)).collect();

    let mut policy = PolicyKind::RtLm.build(&params, model.eta, &lanes);
    let sim_lanes = resolve_lanes(&lanes, &model_table(&model), &lat, &dev).expect("resolve");
    let mut backend = SimBackend::new(tasks, &lat, sim_lanes, &dev, &params);
    let report = run_engine(&mut backend, &mut *policy, &params, 12).expect("engine");

    assert_eq!(report.outcomes.len(), 12);
    assert_eq!(report.n_shed, 8);
    for o in &report.outcomes {
        assert_eq!(o.shed, o.id >= 4, "length shed must drop the longest predictions");
    }
}

/// With the cap at zero (the default) nothing sheds and the report's
/// shed counter stays zero — the knob off is the historical behaviour.
#[test]
fn zero_cap_never_sheds() {
    let model = ModelEntry::stub("m", 0.05, 0.08);
    let lat = step_latency();
    let dev = DeviceProfile::edge_server();
    let lanes = two_lane(60.0);
    let params = SchedParams { batch_size: 4, ..Default::default() };
    let tasks: Vec<Task> =
        (0..30).map(|i| mk_task(i, 0.0, 2.0 + i as f64, 10.0)).collect();
    let mut policy = PolicyKind::RtLm.build(&params, model.eta, &lanes);
    let sim_lanes = resolve_lanes(&lanes, &model_table(&model), &lat, &dev).expect("resolve");
    let mut backend = SimBackend::new(tasks, &lat, sim_lanes, &dev, &params);
    let report = run_engine(&mut backend, &mut *policy, &params, 30).expect("engine");
    assert_eq!(report.outcomes.len(), 30);
    assert_eq!(report.n_shed, 0);
    assert!(report.outcomes.iter().all(|o| !o.shed));
}
