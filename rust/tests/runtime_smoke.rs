//! Runtime integration: the PJRT-executed HLO artifacts must agree with
//! the native-rust implementations and behave deterministically.
//!
//! Requires `make artifacts` (skipped with a clear message otherwise).

use std::path::PathBuf;
use std::sync::Arc;

use rtlm::model::LmSession;
use rtlm::runtime::client::f32_literal;
use rtlm::runtime::{xla, ArtifactStore};

fn open_store() -> Option<Arc<ArtifactStore>> {
    let root = std::env::var("RTLM_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"));
    if !root.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts at {} (run `make artifacts`)", root.display());
        return None;
    }
    let store = Arc::new(ArtifactStore::open(&root).expect("open store"));
    if !store.pjrt_available() {
        eprintln!("skipping: PJRT backend unavailable (in-tree xla stub build)");
        return None;
    }
    Some(store)
}

#[test]
fn regressor_hlo_matches_native() {
    let Some(store) = open_store() else { return };
    let m = &store.manifest;
    let reg = &m.regressor;
    let bucket = *reg.hlo.keys().min().expect("regressor buckets");
    let exe = store.executable(&reg.hlo[&bucket]).expect("compile regressor");

    // weights as literals, in manifest order
    let bundle = store.bundle(&reg.weights).expect("bundle");
    let mut args: Vec<xla::Literal> = Vec::new();
    for name in &reg.param_names {
        args.push(bundle.get(name).expect("weight").to_literal().expect("literal"));
    }
    let n_feats = m.feature_names.len();
    let feats: Vec<f64> = vec![2.0, 0.0, 6.0, 4.0, 3.0, 1.0, 12.0];
    assert_eq!(feats.len(), n_feats);
    let mut flat = vec![0f32; bucket * n_feats];
    flat[..n_feats].copy_from_slice(&feats.iter().map(|&x| x as f32).collect::<Vec<_>>());
    args.push(f32_literal(&flat, &[bucket as i64, n_feats as i64]).unwrap());

    let outs = exe.run(&args).expect("run regressor hlo");
    assert_eq!(outs.len(), 1);
    let pred_hlo = outs[0].to_vec::<f32>().expect("to_vec")[0] as f64;

    let pred_native = store.regressor.predict(&feats).expect("native predict");
    assert!(
        (pred_hlo - pred_native).abs() < 1e-3,
        "HLO {pred_hlo} vs native {pred_native}"
    );
}

#[test]
fn generate_respects_target_lengths_and_is_deterministic() {
    let Some(store) = open_store() else { return };
    let model = store.manifest.model_names()[0].clone();
    let session = LmSession::new(store.clone(), &model).expect("session");

    let prompts = vec![
        store.vocab.encode("tell me about the history of art .", Some(64)),
        store.vocab.encode("i love pizza .", Some(64)),
        store.vocab.encode("how do cats and dogs differ ?", Some(64)),
    ];
    let lens = vec![12usize, 5, 9];
    let out1 = session.generate(&prompts, &lens).expect("generate");
    assert_eq!(out1.tokens.len(), 3);
    for (toks, &want) in out1.tokens.iter().zip(&lens) {
        assert_eq!(toks.len(), want);
        for &t in toks {
            assert!((0..store.manifest.vocab_size as i32).contains(&t));
        }
    }
    assert_eq!(out1.steps, 12);

    let out2 = session.generate(&prompts, &lens).expect("generate again");
    assert_eq!(out1.tokens, out2.tokens, "generation must be deterministic");
}

#[test]
fn batched_generation_matches_solo_generation() {
    // Batching must not change a row's output: decode attention masks
    // other rows, padding rows are inert.
    let Some(store) = open_store() else { return };
    let model = store.manifest.model_names()[0].clone();
    let session = LmSession::new(store.clone(), &model).expect("session");

    let p1 = store.vocab.encode("what do you think about music ?", Some(64));
    let p2 = store.vocab.encode("rice flies like sand .", Some(64));
    let solo = session.generate(&[p1.clone()], &[8]).expect("solo");
    let pair = session.generate(&[p1, p2], &[8, 8]).expect("pair");
    assert_eq!(solo.tokens[0], pair.tokens[0], "batching changed row output");
}

#[test]
fn session_timing_helpers_return_positive() {
    let Some(store) = open_store() else { return };
    let model = store.manifest.model_names()[0].clone();
    let session = LmSession::new(store.clone(), &model).expect("session");
    let entry = store.manifest.model(&model).unwrap();
    let &b = entry.decode.keys().min().unwrap();
    let secs = session.time_decode_step(b, 2).expect("time decode");
    assert!(secs > 0.0 && secs < 10.0, "{secs}");
}

#[test]
fn all_model_weight_bundles_match_param_names() {
    let Some(store) = open_store() else { return };
    for (name, entry) in &store.manifest.models {
        let bundle = store.bundle(&entry.weights).expect("bundle");
        for pname in &entry.param_names {
            assert!(
                bundle.get(pname).is_some(),
                "model {name}: bundle missing param {pname}"
            );
        }
        assert_eq!(
            bundle.tensors.len(),
            entry.param_names.len(),
            "model {name}: bundle/param count mismatch"
        );
    }
}

#[test]
fn chunked_generation_matches_single_step() {
    // The K-token in-graph chunk path must produce exactly the same
    // tokens as the one-step-at-a-time path it optimises.
    let Some(store) = open_store() else { return };
    let model = store.manifest.model_names()[0].clone();
    if store.manifest.model(&model).unwrap().chunk_k == 0 {
        eprintln!("skipping: artifacts built without decode chunks");
        return;
    }
    std::env::set_var("RTLM_USE_CHUNKS", "1");
    let chunked = LmSession::new(store.clone(), &model).expect("session");
    let mut single = LmSession::new(store.clone(), &model).expect("session");
    single.entry.chunk_k = 0; // force the single-step path

    let prompts = vec![
        store.vocab.encode("tell me about the history of art .", Some(64)),
        store.vocab.encode("i love pizza .", Some(64)),
    ];
    let lens = vec![21usize, 11]; // crosses chunk boundaries + remainder
    let a = chunked.generate(&prompts, &lens).expect("chunked");
    let b = single.generate(&prompts, &lens).expect("single");
    assert_eq!(a.tokens, b.tokens, "chunked path diverged from single-step path");
}
