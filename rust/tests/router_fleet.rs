//! Distributed-fleet integration tests: the framed node-side protocol
//! (`hello`/`lanes` gossip, `ping`/`pong` heartbeats, `submit`/`done`
//! task calls) against a real `serve_tcp_on` server, and the `rtlm
//! route` controller end-to-end — union fleets over live nodes,
//! admission-based routing across processes, node death mid-batch with
//! re-queue through lane admission, and heartbeat eviction. Node
//! processes are in-process servers on ephemeral ports; the "dying"
//! node is a scripted raw-TCP stub so its failure timing is exact.

use std::collections::HashSet;
use std::io::{BufReader, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use rtlm::config::SchedParams;
use rtlm::executor::{BatchExecutor, ExecutorFactory, InstantExecutor};
use rtlm::runtime::bundle::{Bundle, Tensor};
use rtlm::scheduler::{LaneSet, LaneSpec, PolicyKind};
use rtlm::server::router::{self, NodeInfo};
use rtlm::server::tcp::{serve_tcp_on, serve_tcp_with, TcpServerConfig};
use rtlm::server::wire;
use rtlm::textgen::{Lexicon, Vocab};
use rtlm::uncertainty::{Estimator, Regressor};
use rtlm::util::json::{obj, Json};

const MAX_INPUT_LEN: usize = 64;

/// Minimal lexicon: a handful of vocab words, every rule list empty.
fn test_lexicon() -> Lexicon {
    let json = r#"{
        "vocab": ["<pad>", "<bos>", "<eos>", "<unk>",
                  "about", "art", "history", "me", "of", "tell", "the"],
        "pos_lexicon": {},
        "suffix_rules": [],
        "homonyms": {},
        "nv_ambiguous": [],
        "vague_topics": [],
        "vague_phrases": [],
        "open_markers": [],
        "multipart_markers": [],
        "relativizers": [],
        "wh_words": [],
        "vague_adjectives": [],
        "open_wh_starters": []
    }"#;
    Lexicon::from_json(&Json::parse(json).expect("lexicon json")).expect("lexicon")
}

/// Constant-output regressor: predicts 20 tokens for everything —
/// every task lands in the fallback admission group.
fn test_estimator(lexicon: Arc<Lexicon>) -> Estimator {
    let bundle = Bundle::from_tensors(vec![
        Tensor::f32("w0", vec![7, 1], vec![0.0; 7]),
        Tensor::f32("b0", vec![1], vec![20.0]),
    ]);
    let scales = vec![10.0, 10.0, 10.0, 10.0, 10.0, 10.0, MAX_INPUT_LEN as f64];
    let regressor = Regressor::from_bundle(&bundle, &scales).expect("regressor");
    Estimator::new(lexicon, Arc::new(regressor), MAX_INPUT_LEN, 4.0, 96.0)
}

/// Length-sensitive regressor: u = 4 + 1.5 * input_tokens, so long
/// prompts score past the quarantine threshold.
fn length_estimator(lexicon: Arc<Lexicon>) -> Estimator {
    let bundle = Bundle::from_tensors(vec![
        Tensor::f32("w0", vec![7, 1], vec![0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 96.0]),
        Tensor::f32("b0", vec![1], vec![4.0]),
    ]);
    let scales = vec![10.0, 10.0, 10.0, 10.0, 10.0, 10.0, MAX_INPUT_LEN as f64];
    let regressor = Regressor::from_bundle(&bundle, &scales).expect("regressor");
    Estimator::new(lexicon, Arc::new(regressor), MAX_INPUT_LEN, 4.0, 96.0)
}

fn instant_factory() -> ExecutorFactory {
    Arc::new(|_spec: &LaneSpec| Ok(Box::new(InstantExecutor) as Box<dyn BatchExecutor>))
}

fn node_config(name: &str, params: SchedParams) -> TcpServerConfig {
    let lexicon = Arc::new(test_lexicon());
    let vocab = Arc::new(Vocab::from_lexicon(&lexicon, 11).expect("vocab"));
    TcpServerConfig {
        vocab,
        estimator: test_estimator(lexicon),
        max_input_len: MAX_INPUT_LEN,
        phi: 0.07,
        params,
        lanes: LaneSet::two_lane("m", 60.0),
        pipeline_depth: 1,
        reply_timeout: Duration::from_secs(30),
        node: name.into(),
        register: None,
    }
}

/// One real node: `serve_tcp_on` over the default gpu+cpu fleet on an
/// ephemeral port, detached (the test process exits past it).
fn start_node(name: &str, factory: ExecutorFactory) -> SocketAddr {
    let params = SchedParams { batch_size: 2, xi: 0.05, ..Default::default() };
    let cfg = node_config(name, params);
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind node");
    let addr = listener.local_addr().expect("node addr");
    let policy = PolicyKind::RtLm.build(&cfg.params, 0.05, &cfg.lanes);
    thread::spawn(move || {
        let _ = serve_tcp_on(listener, cfg, factory, policy);
    });
    addr
}

/// The router: union fleet over `nodes`, RemoteExecutor lanes, and
/// (optionally) heartbeat monitors at `heartbeat`.
fn start_router(
    nodes: Vec<NodeInfo>,
    estimator: Estimator,
    heartbeat: Option<Duration>,
) -> SocketAddr {
    let lanes = router::union_fleet(&nodes).expect("union fleet");
    let params = SchedParams { batch_size: 2, xi: 0.05, ..Default::default() };
    let lexicon = Arc::new(test_lexicon());
    let vocab = Arc::new(Vocab::from_lexicon(&lexicon, 11).expect("vocab"));
    let cfg = TcpServerConfig {
        vocab,
        estimator,
        max_input_len: MAX_INPUT_LEN,
        phi: 0.07,
        params,
        lanes: lanes.clone(),
        pipeline_depth: 1,
        reply_timeout: Duration::from_secs(30),
        node: "router".into(),
        register: None,
    };
    let registry = router::new_registry();
    let factory = router::remote_factory(&nodes, registry.clone());
    let policy = PolicyKind::RtLm.build(&cfg.params, 0.05, &cfg.lanes);
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind router");
    let addr = listener.local_addr().expect("router addr");
    thread::spawn(move || {
        let _ = serve_tcp_with(listener, cfg, factory, policy, |handle| {
            if let Some(interval) = heartbeat {
                router::spawn_monitors(&nodes, &lanes, handle, interval, &registry);
            }
        });
    });
    addr
}

/// Send `lines` on one line-protocol connection, read `expect` replies.
fn roundtrip(addr: SocketAddr, lines: &[&str], expect: usize) -> Vec<Json> {
    let stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(20))).expect("read timeout");
    let mut writer = stream.try_clone().expect("clone");
    for line in lines {
        writeln!(writer, "{line}").expect("write");
    }
    let mut reader = BufReader::new(stream);
    (0..expect)
        .map(|i| {
            use std::io::BufRead;
            let mut buf = String::new();
            let n = reader.read_line(&mut buf).expect("read reply");
            assert!(n > 0, "connection closed before reply {i}");
            Json::parse(buf.trim()).unwrap_or_else(|e| panic!("bad reply json '{buf}': {e}"))
        })
        .collect()
}

/// Open a framed connection: our magic goes out, the reply-side reader
/// comes back (the server's magic is read by the caller when it
/// expects it).
fn framed_dial(addr: SocketAddr) -> (TcpStream, BufReader<TcpStream>) {
    let stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(20))).expect("timeout");
    let mut writer = stream.try_clone().expect("clone");
    wire::write_magic(&mut writer).expect("magic");
    let reader = BufReader::new(stream);
    (writer, reader)
}

/// A scripted raw-TCP "node": speaks just enough of the framed
/// protocol to be adopted into a fleet (answers `hello` with a
/// one-lane table, optionally answers `ping`) but swallows every
/// `submit` — in-flight tasks are only released by [`StubNode::kill`],
/// which hard-closes every accepted connection like a crashed process.
struct StubNode {
    addr: SocketAddr,
    submits: Arc<Mutex<Vec<u64>>>,
    conns: Arc<Mutex<Vec<TcpStream>>>,
}

impl StubNode {
    fn kill(&self) {
        for conn in self.conns.lock().unwrap().drain(..) {
            let _ = conn.shutdown(Shutdown::Both);
        }
    }
}

fn start_stub_node(name: &'static str, pong: bool) -> StubNode {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind stub");
    let addr = listener.local_addr().expect("stub addr");
    let submits: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));
    let conns: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
    {
        let submits = submits.clone();
        let conns = conns.clone();
        thread::spawn(move || {
            for stream in listener.incoming().flatten() {
                if let Ok(clone) = stream.try_clone() {
                    conns.lock().unwrap().push(clone);
                }
                let submits = submits.clone();
                thread::spawn(move || {
                    let _ = stub_conn(stream, name, pong, submits);
                });
            }
        });
    }
    StubNode { addr, submits, conns }
}

fn stub_conn(
    stream: TcpStream,
    name: &str,
    pong: bool,
    submits: Arc<Mutex<Vec<u64>>>,
) -> anyhow::Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    wire::read_magic(&mut reader)?;
    let mut writer = stream;
    wire::write_magic(&mut writer)?;
    loop {
        let Some(msg) = wire::read_frame(&mut reader)? else {
            return Ok(());
        };
        match wire::frame_type(&msg) {
            "hello" => {
                let lane = obj(vec![
                    ("name", Json::Str("gpu".into())),
                    ("kind", Json::Str("gpu".into())),
                    ("model", Json::Str("m".into())),
                    ("admit", Json::Str("default".into())),
                ]);
                let table = wire::frame(
                    "lanes",
                    vec![
                        ("node", Json::Str(name.to_string())),
                        ("queue", Json::Num(0.0)),
                        ("lanes", Json::Arr(vec![lane])),
                    ],
                );
                wire::write_frame(&mut writer, &table)?;
            }
            "ping" if pong => {
                let reply = wire::frame("pong", vec![("seq", msg.get("seq").clone())]);
                wire::write_frame(&mut writer, &reply)?;
            }
            "ping" => {} // heartbeat tests: stay silent, get evicted
            "submit" => {
                submits.lock().unwrap().push(msg.need_f64("id")? as u64);
                // swallow: the reply only ever "arrives" as a dead socket
            }
            _ => {}
        }
    }
}

#[test]
fn node_gossips_lane_table_and_answers_heartbeats() {
    let addr = start_node("nodea", instant_factory());
    let (mut writer, mut reader) = framed_dial(addr);
    wire::write_frame(&mut writer, &wire::frame("hello", vec![])).expect("hello");
    wire::read_magic(&mut reader).expect("server magic");

    let table = wire::read_frame(&mut reader).expect("read").expect("lanes frame");
    assert_eq!(wire::frame_type(&table), "lanes");
    assert_eq!(table.need_str("node").expect("node"), "nodea");
    let lanes = table.need_arr("lanes").expect("lane array");
    assert_eq!(lanes.len(), 2, "the default fleet gossips both lanes");
    assert_eq!(lanes[0].need_str("name").expect("name"), "gpu");
    assert_eq!(lanes[0].need_str("kind").expect("kind"), "gpu");
    assert_eq!(lanes[0].need_str("model").expect("model"), "m");
    assert_eq!(lanes[0].need_str("admit").expect("admit"), "default");
    assert_eq!(lanes[1].need_str("name").expect("name"), "cpu");
    assert_eq!(lanes[1].need_str("admit").expect("admit"), "above:60");

    // heartbeats echo the sequence number and carry the node name
    wire::write_frame(&mut writer, &wire::frame("ping", vec![("seq", Json::Num(7.0))]))
        .expect("ping");
    let pong = wire::read_frame(&mut reader).expect("read").expect("pong frame");
    assert_eq!(wire::frame_type(&pong), "pong");
    assert_eq!(pong.need_f64("seq").expect("seq"), 7.0);
    assert_eq!(pong.need_str("node").expect("node"), "nodea");
}

#[test]
fn node_serves_framed_submits_with_router_ids() {
    let addr = start_node("nodea", instant_factory());
    let (mut writer, mut reader) = framed_dial(addr);
    wire::read_magic(&mut reader).expect("server magic");

    // router-side ids deliberately far from the node's own id space:
    // the node must answer with *our* ids, not its local ones
    for id in [100u64, 101, 102] {
        let submit = wire::frame(
            "submit",
            vec![
                ("id", Json::Num(id as f64)),
                ("text", Json::Str("tell me about art".into())),
                ("u", Json::Num(20.0)),
                ("true_len", Json::Num(8.0)),
                ("input_len", Json::Num(4.0)),
            ],
        );
        wire::write_frame(&mut writer, &submit).expect("submit");
    }

    let mut ids = HashSet::new();
    for _ in 0..3 {
        let done = wire::read_frame(&mut reader).expect("read").expect("done frame");
        assert_eq!(wire::frame_type(&done), "done");
        assert_eq!(done.get("error"), &Json::Null, "unexpected error: {done}");
        ids.insert(done.need_f64("id").expect("id") as u64);
        assert!(done.get("token_ids").as_arr().is_some(), "done carries token ids: {done}");
        assert!(done.need_f64("response_ms").expect("response_ms") >= 0.0);
        assert_eq!(done.need_str("lane").expect("lane"), "gpu", "u=20 rides the gpu lane");
    }
    assert_eq!(ids, HashSet::from([100, 101, 102]), "replies correlate by router id");
}

#[test]
fn malformed_framed_traffic_fails_clean_and_server_survives() {
    let addr = start_node("nodea", instant_factory());

    // an oversized length header is rejected before allocation and the
    // connection just closes — no hang, no reply
    {
        let (mut writer, mut reader) = framed_dial(addr);
        wire::read_magic(&mut reader).expect("server magic");
        writer.write_all(&u32::MAX.to_be_bytes()).expect("header");
        let mut rest = Vec::new();
        let n = reader.read_to_end(&mut rest).expect("connection must close cleanly");
        assert_eq!(n, 0, "no frames may follow a protocol error");
    }

    // a frame header promising bytes that never arrive (abrupt
    // mid-frame disconnect) must not wedge the server
    {
        let (mut writer, _reader) = framed_dial(addr);
        writer.write_all(&8u32.to_be_bytes()).expect("header");
        writer.write_all(b"abc").expect("partial payload");
        // drop: the server sees EOF inside the payload
    }

    // garbage bytes where JSON should be
    {
        let (mut writer, mut reader) = framed_dial(addr);
        wire::read_magic(&mut reader).expect("server magic");
        writer.write_all(&9u32.to_be_bytes()).expect("header");
        writer.write_all(b"not-json!").expect("garbage");
        let mut rest = Vec::new();
        let n = reader.read_to_end(&mut rest).expect("connection must close cleanly");
        assert_eq!(n, 0);
    }

    // a submit missing its required fields errors out that connection
    // without a reply
    {
        let (mut writer, mut reader) = framed_dial(addr);
        wire::read_magic(&mut reader).expect("server magic");
        wire::write_frame(&mut writer, &wire::frame("submit", vec![("id", Json::Num(1.0))]))
            .expect("bad submit");
        assert!(
            wire::read_frame(&mut reader).expect("clean close").is_none(),
            "malformed submit must close the connection, not answer"
        );
    }

    // after all of that, ordinary line clients are still served
    let replies = roundtrip(addr, &["tell me about art"], 1);
    assert_eq!(replies[0].get("error"), &Json::Null, "server survived: {}", replies[0]);
}

#[test]
fn router_unions_nodes_and_routes_by_admission() {
    let a = start_node("nodea", instant_factory());
    let b = start_node("nodeb", instant_factory());
    let nodes = vec![
        router::dial_node(&a.to_string(), Duration::from_secs(10)).expect("dial nodea"),
        router::dial_node(&b.to_string(), Duration::from_secs(10)).expect("dial nodeb"),
    ];
    let addr = start_router(nodes, length_estimator(Arc::new(test_lexicon())), None);

    // u = 4 + 1.5*45 = 71.5 > 60: claimed by a cpu quarantine lane —
    // on whichever node, but always a cpu lane, and the node tag must
    // be the union name's prefix
    let long = "history ".repeat(45);
    let replies = roundtrip(addr, &[long.as_str()], 1);
    assert_eq!(replies[0].get("error"), &Json::Null, "{}", replies[0]);
    let lane = replies[0].need_str("lane").expect("lane").to_string();
    assert!(lane.ends_with("/cpu"), "quarantined task must ride a cpu lane: {lane}");
    let node = replies[0].need_str("node").expect("node");
    assert!(lane.starts_with(node), "node tag '{node}' must prefix the union lane '{lane}'");

    // short prompts score low and ride a gpu fallback lane
    let replies = roundtrip(addr, &["art", "the art", "tell me about art"], 3);
    for r in &replies {
        assert_eq!(r.get("error"), &Json::Null, "{r}");
        let lane = r.need_str("lane").expect("lane");
        assert!(lane.ends_with("/gpu"), "low-uncertainty task on {lane}");
        let node = r.need_str("node").expect("node");
        assert!(node == "nodea" || node == "nodeb", "unknown node tag {node}");
    }
}

/// The chaos scenario the CI router gate scripts with real processes:
/// a node dies with tasks in flight; the router must detect the dead
/// data stream, re-queue those tasks through ordinary lane admission,
/// and answer every request from the survivor — zero lost ids.
#[test]
fn dead_node_mid_batch_requeues_to_survivor() {
    let stub = start_stub_node("stuba", true);
    let b = start_node("nodeb", instant_factory());
    let nodes = vec![
        router::dial_node(&stub.addr.to_string(), Duration::from_secs(10)).expect("dial stub"),
        router::dial_node(&b.to_string(), Duration::from_secs(10)).expect("dial nodeb"),
    ];
    let addr = start_router(nodes, test_estimator(Arc::new(test_lexicon())), None);

    // u = 20 for everything: the shared fallback group is
    // {stuba/gpu, nodeb/gpu}, and least-loaded balancing sends a share
    // of 6 concurrent requests to the stub, which swallows them
    let clients: Vec<_> = (0..6)
        .map(|_| thread::spawn(move || roundtrip(addr, &["tell me about the history of art"], 1)))
        .collect();

    // wait until the stub really holds in-flight submits, then crash it
    let deadline = Instant::now() + Duration::from_secs(10);
    while stub.submits.lock().unwrap().is_empty() {
        assert!(Instant::now() < deadline, "no task was ever routed to the stub node");
        thread::sleep(Duration::from_millis(20));
    }
    thread::sleep(Duration::from_millis(100)); // let the batch finish landing
    stub.kill();

    let mut ids = HashSet::new();
    for client in clients {
        for r in client.join().expect("client") {
            assert_eq!(r.get("error"), &Json::Null, "lost or failed request: {r}");
            let id = r.need_f64("id").expect("id") as u64;
            assert!(ids.insert(id), "duplicate reply for id {id}");
            assert_eq!(
                r.need_str("node").expect("node"),
                "nodeb",
                "after the crash only the survivor serves: {r}"
            );
        }
    }
    assert_eq!(ids.len(), 6, "every request answered exactly once — zero lost ids");
    assert!(
        !stub.submits.lock().unwrap().is_empty(),
        "the re-queue path was exercised (the stub had swallowed tasks)"
    );
}

#[test]
fn missed_heartbeats_evict_a_node_and_reroute() {
    let stub = start_stub_node("stuba", false); // adopts fine, never pongs
    let b = start_node("nodeb", instant_factory());
    let nodes = vec![
        router::dial_node(&stub.addr.to_string(), Duration::from_secs(10)).expect("dial stub"),
        router::dial_node(&b.to_string(), Duration::from_secs(10)).expect("dial nodeb"),
    ];
    let addr = start_router(
        nodes,
        test_estimator(Arc::new(test_lexicon())),
        Some(Duration::from_millis(100)),
    );

    // two missed heartbeats at a 100 ms interval evict within ~400 ms;
    // wait comfortably past that before sending any traffic
    thread::sleep(Duration::from_millis(1200));
    let replies = roundtrip(addr, &["tell me about art", "the history of art"], 2);
    for r in &replies {
        assert_eq!(r.get("error"), &Json::Null, "{r}");
        assert_eq!(
            r.need_str("node").expect("node"),
            "nodeb",
            "traffic must route around the evicted node: {r}"
        );
    }
    assert!(
        stub.submits.lock().unwrap().is_empty(),
        "no task may be dispatched to an evicted node"
    );
}
