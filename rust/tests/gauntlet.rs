//! Scenario-gauntlet integration: the Cargo test-target registration
//! guard (with `autotests = false`, an unregistered `rust/tests/*.rs`
//! file silently never runs — parity_replay/router_fleet were lost that
//! way for four PRs), the SLO-class accounting oracle through BOTH
//! engine backends, the `slo_class`/`deadline_met` JSONL round-trip,
//! classless-export compatibility, and report determinism through the
//! public gauntlet API. Artifact-free: stub model, hand calibration.

use std::collections::BTreeMap;

use rtlm::bench_harness::gauntlet::{gauntlet_json, run_gauntlet, GauntletConfig, Scenario};
use rtlm::bench_harness::replay::ReplayCell;
use rtlm::config::{DeviceProfile, ModelEntry, SchedParams};
use rtlm::scheduler::{PolicyKind, SloClass, Task};
use rtlm::sim::{slo_summary, Calibration, LatencyModel};
use rtlm::util::json::Json;

// ---------------------------------------------------------------------------
// test-target registration guard
// ---------------------------------------------------------------------------

/// Every file in `rust/tests/` must have a matching `[[test]]` entry in
/// Cargo.toml, or `cargo test` silently skips it (`autotests = false`).
#[test]
fn every_test_file_is_registered_in_cargo_toml() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let manifest = std::fs::read_to_string(root.join("Cargo.toml")).expect("reading Cargo.toml");
    let dir = root.join("rust").join("tests");
    let mut checked = 0usize;
    for entry in std::fs::read_dir(&dir).expect("reading rust/tests") {
        let name = entry.expect("dir entry").file_name().into_string().expect("utf-8 name");
        if !name.ends_with(".rs") {
            continue;
        }
        let needle = format!("path = \"rust/tests/{name}\"");
        assert!(
            manifest.contains(&needle),
            "rust/tests/{name} has no [[test]] entry in Cargo.toml — with autotests = false \
             it silently never runs; add:\n[[test]]\nname = \"{}\"\n{needle}",
            name.trim_end_matches(".rs"),
        );
        checked += 1;
    }
    assert!(checked >= 11, "expected at least 11 test files in rust/tests, found {checked}");
}

// ---------------------------------------------------------------------------
// SLO-class accounting: hand-computed oracle through both backends
// ---------------------------------------------------------------------------

fn tiny_latency() -> LatencyModel {
    let mut c = Calibration::default();
    c.decode
        .insert("m".into(), BTreeMap::from([(1, 0.01), (4, 0.018), (16, 0.04)]));
    c.prefill
        .insert("m".into(), BTreeMap::from([((1, 16), 0.02), ((16, 64), 0.08)]));
    LatencyModel::from_calibration(&c)
}

fn mk_task(id: u64, arrival: f64, deadline: f64, u: f64, slo: SloClass) -> Task {
    Task {
        id,
        text: String::new(),
        prompt: vec![],
        arrival,
        priority_point: arrival + deadline,
        uncertainty: u,
        true_len: u.max(1.0) as usize,
        input_len: 8,
        utype: "test".into(),
        malicious: false,
        deferrals: 0,
        slo,
    }
}

/// 16 tasks, alternating classes with extreme deadlines so attainment
/// is knowable without running anything: interactive tasks carry a
/// zero relative deadline (any positive service time misses it), batch
/// tasks carry a week (nothing can miss it). Robust on the wire too —
/// no timing tolerance is involved in either verdict.
fn two_class_cell(kind: PolicyKind) -> ReplayCell {
    let tasks: Vec<Task> = (0..16)
        .map(|i| {
            let arrival = i as f64 * 0.5;
            let u = 5.0 + i as f64 * 2.0; // all below tau: one lane, simple oracle
            if i % 2 == 0 {
                mk_task(i as u64, arrival, 0.0, u, SloClass::Interactive)
            } else {
                mk_task(i as u64, arrival, 6.048e5, u, SloClass::Batch)
            }
        })
        .collect();
    ReplayCell::two_lane(
        &format!("slo/{}", kind.label()),
        kind,
        SchedParams { batch_size: 8, ..Default::default() },
        &ModelEntry::stub("m", 0.05, 0.08),
        1e9, // tau above every uncertainty: the CPU lane stays idle
        DeviceProfile::edge_server(),
        tasks,
    )
}

#[test]
fn two_class_oracle_agrees_on_both_backends() {
    let lat = tiny_latency();
    for kind in [PolicyKind::Fifo, PolicyKind::RtLm] {
        let det = two_class_cell(kind).deterministic();
        let sim = det.run_sim(&lat).expect("sim run");
        let wire = det.run_wire(&lat, 40.0).expect("wire run");
        for (backend, outcomes) in [("sim", &sim.outcomes), ("wire", &wire.outcomes)] {
            assert_eq!(outcomes.len(), 16, "{backend}/{kind:?}");
            let rows = slo_summary(outcomes);
            assert_eq!(rows.len(), 2, "{backend}/{kind:?}: {rows:?}");
            let class_row = |class: SloClass| {
                rows.iter().find(|r| r.class == class).cloned().expect("class row")
            };
            // oracle: every interactive task misses, every batch meets
            let int = class_row(SloClass::Interactive);
            assert_eq!((int.n, int.met, int.shed), (8, 0, 0), "{backend}/{kind:?}");
            assert_eq!(int.attainment(), 0.0);
            let batch = class_row(SloClass::Batch);
            assert_eq!((batch.n, batch.met, batch.shed), (8, 8, 0), "{backend}/{kind:?}");
            assert_eq!(batch.attainment(), 1.0);
        }
    }
}

// ---------------------------------------------------------------------------
// JSONL export: class columns round-trip; classless rows unchanged
// ---------------------------------------------------------------------------

fn export_lines(cell: &ReplayCell, file: &str) -> Vec<String> {
    let sim = cell.deterministic().run_sim(&tiny_latency()).expect("sim run");
    let dir = std::env::temp_dir().join(format!("rtlm_gauntlet_test_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("tmp dir");
    let path = dir.join(file);
    sim.export_jsonl(&path).expect("export");
    let text = std::fs::read_to_string(&path).expect("read back");
    std::fs::remove_file(&path).ok();
    text.lines().map(str::to_string).collect()
}

#[test]
fn jsonl_class_columns_round_trip() {
    let lines = export_lines(&two_class_cell(PolicyKind::RtLm), "classed.jsonl");
    assert_eq!(lines.len(), 16);
    for line in &lines {
        let rec = Json::parse(line).expect("valid json line");
        let class = SloClass::parse(rec.need_str("slo_class").expect("slo_class column"))
            .expect("parsable class");
        let met = rec.get("deadline_met").as_bool().expect("deadline_met column");
        // round-trip consistency with the outcome flags on the same row
        let missed = rec.get("missed").as_bool().expect("missed column");
        let shed = rec.get("shed").as_bool().expect("shed column");
        assert_eq!(met, !shed && !missed);
        match class {
            SloClass::Interactive => assert!(!met, "{line}"),
            SloClass::Batch => assert!(met, "{line}"),
            SloClass::Standard => panic!("standard row exported a class column: {line}"),
        }
    }
}

/// Classless (historical) exports carry exactly the pre-SLO column
/// set — no `slo_class`, no `deadline_met` — keeping default runs
/// bit-identical to pre-PR behaviour.
#[test]
fn classless_export_is_column_compatible() {
    let tasks: Vec<Task> = (0..12)
        .map(|i| mk_task(i as u64, i as f64 * 0.5, 3.0, 5.0 + i as f64, SloClass::Standard))
        .collect();
    let cell = ReplayCell::two_lane(
        "classless",
        PolicyKind::RtLm,
        SchedParams { batch_size: 8, ..Default::default() },
        &ModelEntry::stub("m", 0.05, 0.08),
        1e9,
        DeviceProfile::edge_server(),
        tasks,
    );
    let lines = export_lines(&cell, "classless.jsonl");
    assert_eq!(lines.len(), 12);
    for line in &lines {
        let rec = Json::parse(line).expect("valid json line");
        let keys: Vec<&str> =
            rec.as_obj().expect("object row").keys().map(String::as_str).collect();
        assert_eq!(
            keys,
            vec![
                "arrival",
                "completion",
                "id",
                "lane",
                "malicious",
                "missed",
                "priority_point",
                "response",
                "shed",
                "true_len",
                "ttft",
                "uncertainty",
                "utype",
            ],
            "classless row gained/lost a column: {line}"
        );
    }
}

// ---------------------------------------------------------------------------
// gauntlet public API: determinism + nominal interactive attainment
// ---------------------------------------------------------------------------

#[test]
fn gauntlet_report_is_deterministic_through_public_api() {
    let cfg = GauntletConfig {
        n: 16,
        scenarios: vec![Scenario::Nominal, Scenario::Flash, Scenario::EdgeCpu],
        ..Default::default()
    };
    let cells = run_gauntlet(&cfg);
    assert_eq!(cells.len(), 6);
    for c in &cells {
        assert!(c.clean(), "{}/{}: {:?}", c.scenario, c.policy, c.error);
    }
    let a = gauntlet_json(&cfg, &cells).to_string();
    let b = gauntlet_json(&cfg, &run_gauntlet(&cfg)).to_string();
    assert_eq!(a, b, "same config must produce a byte-identical report");
    // the nominal interactive class attains under both policies — the
    // same property the CI gauntlet gate enforces via the report script
    for c in cells.iter().filter(|c| c.scenario == "nominal") {
        let att = c.attainment(SloClass::Interactive).expect("interactive row");
        assert!(att > 0.0, "{}: zero interactive attainment under nominal load", c.policy);
    }
}
