//! Artifact-free integration smoke: exercises the pure-logic core
//! (JSON model, PCG64 RNG, scheduling policies, consolidation, the
//! simulator) with hand-built tasks so `cargo test -q` asserts real
//! behavior on a clean checkout, before `make artifacts` has ever run.

use std::collections::BTreeMap;

use rtlm::config::{DeviceProfile, ModelEntry, SchedParams};
use rtlm::scheduler::{
    up_priority, Fifo, LaneId, LaneSet, Policy, PolicyKind, SloClass, Task, UaSched, WHOLE_BATCH,
};
use rtlm::sim::{run_sim, Calibration, LatencyModel};
use rtlm::util::json::{obj, Json};
use rtlm::util::rng::Pcg64;

fn task(id: u64, arrival: f64, priority_point: f64, uncertainty: f64) -> Task {
    Task {
        id,
        text: String::new(),
        prompt: vec![],
        arrival,
        priority_point,
        uncertainty,
        true_len: uncertainty.max(1.0) as usize,
        input_len: 8,
        utype: "unit".into(),
        malicious: false,
        deferrals: 0,
        slo: SloClass::Standard,
    }
}

// ---------------------------------------------------------------------------
// util::cli — help text must track the real flag sets
// ---------------------------------------------------------------------------

/// Every public flag of every subcommand, as read with `args.get*` /
/// `args.flag` in `rust/src/main.rs`. When a flag is added or renamed
/// there, this list — and the help text in `util::cli::help_text` —
/// must follow; the help drifted silently across PR 3-4, hence the gate.
const PUBLIC_FLAGS: &[&str] = &[
    "--artifacts",
    "--reps",
    "--n",
    "--seed",
    "--model",
    "--policy",
    "--device",
    "--variance",
    "--export",
    "--beta",
    "--time-scale",
    "--backend",
    "--lanes",
    "--require-all-lanes",
    "--verbose",
    "--addr",
    "--pipeline",
    "--concurrency",
    "--timeout-s",
    "--connect-wait-s",
    "--expect-lanes",
    "--p95-ms",
    "--wire",
    "--parity-rel",
    "--parity-slop-ms",
    "--parity-out",
    "--sched",
    "--slots",
    "--overrun-factor",
    "--node-name",
    "--register",
    "--nodes",
    "--expect-nodes",
    "--heartbeat-s",
    "--allow-server-errors",
    "--queue-cap",
    "--shed",
    "--rate",
    "--min-shed",
    "--max-shed-rate",
    "--policies",
    "--scenarios",
    "--out",
];

#[test]
fn help_text_mentions_every_public_flag_and_command() {
    let help = rtlm::util::cli::help_text(rtlm::bench_harness::scenarios::EXPERIMENTS);
    for flag in PUBLIC_FLAGS {
        assert!(help.contains(flag), "help text is missing the {flag} flag");
    }
    for cmd in [
        "check", "calibrate", "bench", "gauntlet", "sim", "serve", "tcp", "route", "loadgen",
        "score",
    ] {
        assert!(help.contains(cmd), "help text is missing the {cmd} command");
    }
    for exp in rtlm::bench_harness::scenarios::EXPERIMENTS {
        assert!(help.contains(exp), "help text is missing experiment {exp}");
    }
    // the gauntlet's scenario tokens stay documented inline
    for scenario in ["nominal", "diurnal", "flash", "heavytail", "edge-cpu"] {
        assert!(help.contains(scenario), "help text is missing the {scenario} scenario");
    }
    // the lane-spec grammar stays documented inline
    assert!(help.contains("kind[:model][:key=value]*"));
}

// ---------------------------------------------------------------------------
// util::json
// ---------------------------------------------------------------------------

#[test]
fn json_round_trips_nested_values() {
    let cases = [
        r#"{"models":{"t5":{"eta":0.04}},"buckets":[1,2,4,8]}"#,
        r#"[true,false,null,-12.5,"esc\"aped\n"]"#,
        r#"{"empty_obj":{},"empty_arr":[]}"#,
    ];
    for case in cases {
        let v = Json::parse(case).expect(case);
        let text = v.to_string();
        assert_eq!(Json::parse(&text).expect("reparse"), v, "{case}");
    }
}

#[test]
fn json_accessors_and_builder() {
    let v = obj(vec![
        ("name", Json::Str("rtlm".into())),
        ("n", Json::Num(42.0)),
        ("tags", Json::Arr(vec![Json::Str("a".into()), Json::Str("b".into())])),
    ]);
    assert_eq!(v.get("name").as_str(), Some("rtlm"));
    assert_eq!(v.need_f64("n").unwrap(), 42.0);
    assert_eq!(v.get("tags").idx(1).as_str(), Some("b"));
    assert_eq!(v.get("missing"), &Json::Null);
    assert!(v.need_str("missing").is_err());

    let round = Json::parse(&v.to_string()).unwrap();
    assert_eq!(round.get("n").as_usize(), Some(42));
}

#[test]
fn json_rejects_malformed_input_with_offsets() {
    for bad in ["{", "[1,", "{\"a\":}", "tru", "1 2"] {
        let err = Json::parse(bad).expect_err(bad);
        let msg = err.to_string();
        assert!(msg.contains("byte"), "error should carry an offset: {msg}");
    }
}

// ---------------------------------------------------------------------------
// util::rng
// ---------------------------------------------------------------------------

#[test]
fn pcg64_is_deterministic_per_seed_and_stream() {
    let mut a = Pcg64::new(1234);
    let mut b = Pcg64::new(1234);
    let xs: Vec<u64> = (0..64).map(|_| a.next_u64()).collect();
    let ys: Vec<u64> = (0..64).map(|_| b.next_u64()).collect();
    assert_eq!(xs, ys, "same seed must replay the same stream");

    let mut c = Pcg64::new(1235);
    assert_ne!(xs, (0..64).map(|_| c.next_u64()).collect::<Vec<_>>());

    let mut d = Pcg64::with_stream(1234, 7);
    assert_ne!(
        xs,
        (0..64).map(|_| d.next_u64()).collect::<Vec<_>>(),
        "distinct streams must diverge"
    );
}

#[test]
fn pcg64_distribution_helpers_stay_in_bounds() {
    let mut rng = Pcg64::new(99);
    for _ in 0..5_000 {
        let x = rng.f64();
        assert!((0.0..1.0).contains(&x));
        let n = rng.range_usize(3, 9);
        assert!((3..9).contains(&n));
        assert!(rng.exponential(2.0) >= 0.0);
    }
    let idx_counts = {
        let mut counts = [0usize; 2];
        for _ in 0..2_000 {
            counts[rng.weighted_index(&[1.0, 9.0])] += 1;
        }
        counts
    };
    assert!(idx_counts[1] > idx_counts[0], "{idx_counts:?}");
}

// ---------------------------------------------------------------------------
// scheduler push/pop ordering
// ---------------------------------------------------------------------------

#[test]
fn fifo_pops_in_arrival_order() {
    let mut fifo = Fifo::new(2);
    fifo.push(task(10, 0.0, 9.0, 30.0));
    fifo.push(task(11, 1.0, 2.0, 80.0));
    fifo.push(task(12, 2.0, 5.0, 10.0));
    let b = fifo.pop(LaneId::GPU, 2.0, false, WHOLE_BATCH).expect("full batch");
    assert_eq!(b.tasks.iter().map(|t| t.id).collect::<Vec<_>>(), vec![10, 11]);
    assert_eq!(fifo.queue_len(), 1);
    // CPU lane is never used by baselines
    assert!(fifo.pop(LaneId::CPU, 2.0, true, WHOLE_BATCH).is_none());
}

#[test]
fn uasched_prefers_low_uncertainty_at_equal_slack() {
    let params = SchedParams { batch_size: 2, ..Default::default() };
    let mut sched = UaSched::two_lane(params, 0.05, f64::INFINITY, false);
    // same deadline: the more certain tasks must come out first
    sched.push(task(1, 0.0, 5.0, 90.0));
    sched.push(task(2, 0.0, 5.0, 10.0));
    sched.push(task(3, 0.0, 5.0, 60.0));
    let b = sched.pop(LaneId::GPU, 0.0, true, WHOLE_BATCH).expect("batch");
    assert_eq!(b.tasks.iter().map(|t| t.id).collect::<Vec<_>>(), vec![2, 3]);
}

#[test]
fn uasched_offloads_above_tau_and_conserves_tasks() {
    let params = SchedParams { batch_size: 4, ..Default::default() };
    let mut sched = UaSched::two_lane(params, 0.05, 50.0, true);
    for i in 0..12 {
        let u = if i % 3 == 0 { 80.0 + i as f64 } else { 10.0 + i as f64 };
        sched.push(task(i, 0.0, 6.0, u));
    }
    let mut seen = std::collections::HashSet::new();
    let mut now = 0.0;
    while sched.queue_len() > 0 {
        now += 1.0;
        for lane in [LaneId::GPU, LaneId::CPU] {
            if let Some(b) = sched.pop(lane, now, true, WHOLE_BATCH) {
                for t in &b.tasks {
                    assert!(seen.insert(t.id), "task {} dispatched twice", t.id);
                    match lane {
                        LaneId::CPU => assert!(t.uncertainty > 50.0, "certain task offloaded"),
                        _ => assert!(t.uncertainty <= 50.0, "malicious task on GPU"),
                    }
                }
            }
        }
        assert!(now < 100.0, "scheduler failed to drain");
    }
    assert_eq!(seen.len(), 12, "lost tasks");
}

#[test]
fn up_priority_orders_by_slack_and_uncertainty() {
    let p = SchedParams::default();
    let tight = task(1, 0.0, 1.0, 20.0);
    let loose = task(2, 0.0, 9.0, 20.0);
    assert!(up_priority(&tight, &p, 0.05, 0.0) > up_priority(&loose, &p, 0.05, 0.0));

    let certain = task(3, 0.0, 5.0, 5.0);
    let uncertain = task(4, 0.0, 5.0, 90.0);
    assert!(up_priority(&certain, &p, 0.05, 0.0) > up_priority(&uncertain, &p, 0.05, 0.0));
}

// ---------------------------------------------------------------------------
// simulator end-to-end on a hand-built latency model
// ---------------------------------------------------------------------------

fn tiny_model() -> ModelEntry {
    ModelEntry::stub("m", 0.05, 0.08)
}

fn tiny_latency() -> LatencyModel {
    let mut c = Calibration::default();
    c.decode
        .insert("m".into(), BTreeMap::from([(1, 0.01), (4, 0.018), (16, 0.04)]));
    c.prefill
        .insert("m".into(), BTreeMap::from([((1, 16), 0.02), ((8, 64), 0.08)]));
    LatencyModel::from_calibration(&c)
}

#[test]
fn simulator_completes_every_policy_without_artifacts() {
    let params = SchedParams { batch_size: 4, ..Default::default() };
    let model = tiny_model();
    let lat = tiny_latency();
    let dev = DeviceProfile::edge_server();
    let mut rng = Pcg64::new(5);
    let tasks: Vec<Task> = (0..50)
        .map(|i| {
            task(
                i,
                rng.f64() * 20.0,
                rng.f64() * 20.0 + 2.0,
                4.0 + rng.f64() * 90.0,
            )
        })
        .collect();
    for kind in PolicyKind::ALL_BASELINES {
        let mut policy = kind.build(&params, model.eta, &LaneSet::two_lane("m", 60.0));
        let r = run_sim(tasks.clone(), &mut *policy, &lat, &model, &dev, &params);
        assert_eq!(r.outcomes.len(), 50, "{} lost tasks", kind.label());
        assert!(r.makespan > 0.0);
        for o in &r.outcomes {
            assert!(o.completion > o.arrival, "{}: acausal completion", kind.label());
        }
    }
}
