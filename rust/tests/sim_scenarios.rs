//! End-to-end simulation scenarios over the real corpus + regressor:
//! the paper's qualitative claims must hold on this testbed.
//!
//! Requires `make artifacts` (skipped with a clear message otherwise).

use std::path::PathBuf;
use std::sync::Arc;

use rtlm::bench_harness::scenarios::ExperimentCtx;
use rtlm::config::DeviceProfile;
use rtlm::runtime::ArtifactStore;
use rtlm::scheduler::PolicyKind;
use rtlm::workload::malicious;
use rtlm::workload::subsets::Variance;
use rtlm::workload::{ArrivalTrace, TaskFactory};

fn ctx() -> Option<ExperimentCtx> {
    let root = std::env::var("RTLM_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"));
    if !root.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts at {} (run `make artifacts`)", root.display());
        return None;
    }
    let store = Arc::new(ArtifactStore::open(&root).expect("store"));
    Some(ExperimentCtx::new(store, 300, 42).expect("ctx"))
}

#[test]
fn rtlm_beats_fifo_on_large_variance() {
    let Some(ctx) = ctx() else { return };
    let dev = DeviceProfile::edge_server();
    let mut wins = 0;
    let n_models = ctx.manifest().models.len();
    for name in ctx.manifest().model_names() {
        let model = ctx.model(&name).unwrap().clone();
        let tasks = ctx.scenario_tasks(&model, Variance::Large, 42).unwrap();
        let fifo = ctx.run_policy(&model, tasks.clone(), PolicyKind::Fifo, &dev);
        let rtlm = ctx.run_policy(&model, tasks, PolicyKind::RtLm, &dev);
        if rtlm.mean_response() < fifo.mean_response() {
            wins += 1;
        }
        eprintln!(
            "{name}: FIFO {:.2}s vs RT-LM {:.2}s",
            fifo.mean_response(),
            rtlm.mean_response()
        );
    }
    assert!(
        wins >= n_models - 1,
        "RT-LM should beat FIFO on large variance for nearly all models (won {wins}/{n_models})"
    );
}

#[test]
fn uncertainty_aware_advantage_grows_with_variance() {
    let Some(ctx) = ctx() else { return };
    let dev = DeviceProfile::edge_server();
    let model = ctx.model("dialogpt").unwrap().clone();
    let mut gaps = Vec::new();
    for variance in [Variance::Small, Variance::Large] {
        let tasks = ctx.scenario_tasks(&model, variance, 43).unwrap();
        let fifo = ctx.run_policy(&model, tasks.clone(), PolicyKind::Fifo, &dev);
        let rtlm = ctx.run_policy(&model, tasks, PolicyKind::RtLm, &dev);
        gaps.push(fifo.mean_response() - rtlm.mean_response());
    }
    eprintln!("advantage small={:.3}s large={:.3}s", gaps[0], gaps[1]);
    assert!(
        gaps[1] > gaps[0] - 0.05,
        "advantage should not shrink with variance: {gaps:?}"
    );
}

#[test]
fn throughput_ordering_matches_response_ordering() {
    let Some(ctx) = ctx() else { return };
    let dev = DeviceProfile::edge_server();
    let model = ctx.model("godel").unwrap().clone();
    let tasks = ctx.scenario_tasks(&model, Variance::Normal, 44).unwrap();
    let fifo = ctx.run_policy(&model, tasks.clone(), PolicyKind::Fifo, &dev);
    let rtlm = ctx.run_policy(&model, tasks, PolicyKind::RtLm, &dev);
    // RT-LM should not lose throughput while improving response time
    assert!(
        rtlm.throughput_per_min() >= fifo.throughput_per_min() * 0.95,
        "rtlm {:.1}/min vs fifo {:.1}/min",
        rtlm.throughput_per_min(),
        fifo.throughput_per_min()
    );
}

#[test]
fn rtlm_resilient_to_malicious_tasks() {
    let Some(ctx) = ctx() else { return };
    let dev = DeviceProfile::edge_server();
    let model = ctx.model("dialogpt").unwrap().clone();
    let mut factory = TaskFactory::new(
        rtlm::uncertainty::Estimator::new(
            ctx.store.lexicon.clone(),
            ctx.store.regressor.clone(),
            ctx.manifest().max_input_len,
            ctx.manifest().min_output_len as f64,
            ctx.manifest().max_output_len as f64,
        ),
        2.0,
    );
    let items = ctx.all_test_items();
    let base: Vec<_> = items.into_iter().take(200).collect();

    let mut rtlm_means = Vec::new();
    let mut fifo_means = Vec::new();
    for ratio in [0.0, 0.5] {
        let (crafted, _) =
            malicious::inject(&base, ratio, ctx.manifest().max_output_len, 7);
        let step = ArrivalTrace::sweep_step_for(crafted.len(), 10, 150);
        let trace = ArrivalTrace::poisson_sweep_scaled(crafted.len(), 10, 150, step, 7);
        let tasks = factory.build_all(&crafted, &trace, &model, true).unwrap();
        let fifo = ctx.run_policy(&model, tasks.clone(), PolicyKind::Fifo, &dev);
        let rtlm = ctx.run_policy(&model, tasks, PolicyKind::RtLm, &dev);
        fifo_means.push(fifo.mean_response());
        rtlm_means.push(rtlm.mean_response());
    }
    let fifo_degradation = fifo_means[1] / fifo_means[0].max(1e-9);
    let rtlm_degradation = rtlm_means[1] / rtlm_means[0].max(1e-9);
    eprintln!(
        "malicious 0%->50%: FIFO {:.2}->{:.2} ({fifo_degradation:.2}x), \
         RT-LM {:.2}->{:.2} ({rtlm_degradation:.2}x)",
        fifo_means[0], fifo_means[1], rtlm_means[0], rtlm_means[1]
    );
    assert!(
        rtlm_degradation < fifo_degradation,
        "RT-LM should degrade less than FIFO under attack"
    );
}

#[test]
fn crafted_tasks_rescore_higher() {
    let Some(ctx) = ctx() else { return };
    let items = ctx.all_test_items();
    let mut rng = rtlm::util::rng::Pcg64::new(3);
    let mut higher = 0;
    let mut total = 0;
    for item in items.iter().take(100) {
        let crafted = malicious::craft(item, ctx.manifest().max_output_len, &mut rng);
        let u_base = ctx.estimator.score(&item.text).unwrap();
        let u_crafted = ctx.estimator.score(&crafted.text).unwrap();
        total += 1;
        if u_crafted > u_base {
            higher += 1;
        }
    }
    assert!(
        higher as f64 / total as f64 > 0.9,
        "crafted tasks should rescore higher ({higher}/{total})"
    );
}

#[test]
fn offline_decisions_are_sane() {
    let Some(ctx) = ctx() else { return };
    for (name, &c) in &ctx.batch_sizes {
        assert!((1..=32).contains(&c), "{name}: C_f = {c}");
    }
    for (name, &tau) in &ctx.taus {
        assert!(
            tau > ctx.manifest().min_output_len as f64 && tau <= ctx.manifest().max_output_len as f64,
            "{name}: tau = {tau}"
        );
    }
}

#[test]
fn synth_generator_produces_scorable_utterances() {
    let Some(ctx) = ctx() else { return };
    let m = ctx.manifest();
    let mut gen = rtlm::workload::SynthGenerator::new(
        ctx.store.lexicon.clone(),
        m.length_model.clone(),
        42,
    );
    let names = m.model_names();
    let idx: std::collections::HashMap<&str, usize> = m
        .feature_names
        .iter()
        .enumerate()
        .map(|(i, n)| (n.as_str(), i))
        .collect();
    for utype in ["structural", "syntactic", "semantic", "vague", "open", "multipart"] {
        let mut fired = 0;
        for _ in 0..20 {
            let item = gen.work_item(utype, &names);
            assert!((4..=96).contains(&item.base_len), "{utype}: {item:?}");
            assert!(!item.text.is_empty());
            let feats = ctx.estimator.features(&item.text);
            if feats[idx[utype]] > 0.0 {
                fired += 1;
            }
        }
        assert!(fired >= 18, "{utype}: only {fired}/20 fired its own scorer");
    }
}

#[test]
fn synth_stream_deterministic_by_seed() {
    let Some(ctx) = ctx() else { return };
    let m = ctx.manifest();
    let types = m.uncertainty_types.clone();
    let names = m.model_names();
    let mk = |seed| {
        let mut g = rtlm::workload::SynthGenerator::new(
            ctx.store.lexicon.clone(),
            m.length_model.clone(),
            seed,
        );
        g.stream(&types, 30, &names)
            .into_iter()
            .map(|i| i.text)
            .collect::<Vec<_>>()
    };
    assert_eq!(mk(9), mk(9));
    assert_ne!(mk(9), mk(10));
}

#[test]
fn slack_policy_runs_and_matches_alpha_zero_up() {
    let Some(ctx) = ctx() else { return };
    let dev = rtlm::config::DeviceProfile::edge_server();
    let model = ctx.model("t5").unwrap().clone();
    let tasks = ctx.scenario_tasks(&model, Variance::Normal, 77).unwrap();
    let slack = ctx.run_policy(&model, tasks.clone(), PolicyKind::Slack, &dev);
    assert_eq!(slack.outcomes.len(), tasks.len());
    assert_eq!(slack.policy, "UP"); // internally UaSched with alpha=0
}

#[test]
fn deadline_override_sets_priority_point() {
    let Some(ctx) = ctx() else { return };
    let mut factory = TaskFactory::new(ctx.estimator.clone(), 2.0);
    let model = ctx.model("t5").unwrap().clone();
    let item = &ctx.all_test_items()[0];
    let t = factory
        .build_with_deadline(1, item, 10.0, &model, 0.75)
        .unwrap();
    assert!((t.priority_point - 10.75).abs() < 1e-12);
}
