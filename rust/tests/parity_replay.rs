//! End-to-end wire-path parity (`rtlm bench --wire` machinery): replay
//! one experiment cell through the virtual-clock simulator AND the
//! threaded wall-clock engine (real dispatcher + lane-worker threads,
//! modeled batch durations, dilated engine clock) and assert the parity
//! report is clean — per-lane batch counts exactly equal, response
//! stats within the time-scale-aware tolerance. Artifact-free: stub
//! model, hand-built latency calibration.

use std::collections::BTreeMap;

use rtlm::bench_harness::replay::{run_parity, ParityTolerance, ReplayCell};
use rtlm::config::{DeviceProfile, ModelEntry, SchedMode, SchedParams};
use rtlm::scheduler::{PolicyKind, SloClass, Task};
use rtlm::sim::{Calibration, LatencyModel};
use rtlm::util::rng::Pcg64;

fn tiny_latency() -> LatencyModel {
    let mut c = Calibration::default();
    c.decode
        .insert("m".into(), BTreeMap::from([(1, 0.01), (4, 0.018), (16, 0.04)]));
    c.prefill
        .insert("m".into(), BTreeMap::from([((1, 16), 0.02), ((16, 64), 0.08)]));
    LatencyModel::from_calibration(&c)
}

fn mk_task(id: u64, arrival: f64, priority_point: f64, uncertainty: f64) -> Task {
    Task {
        id,
        text: String::new(),
        prompt: vec![],
        arrival,
        priority_point,
        uncertainty,
        true_len: uncertainty.max(1.0) as usize,
        input_len: 8,
        utype: "test".into(),
        malicious: false,
        deferrals: 0,
        slo: SloClass::Standard,
    }
}

/// A paper-shaped cell: 24 tasks over a 7 s arrival sweep, uncertainty
/// spread across the quarantine threshold so RT-LM exercises every lane.
fn cell(kind: PolicyKind) -> ReplayCell {
    let mut rng = Pcg64::new(0xCE11);
    let tasks: Vec<Task> = (0..24)
        .map(|i| {
            let arrival = i as f64 * 0.3;
            // ~1 in 4 tasks above tau = 50 quarantines under RT-LM
            let u = if i % 4 == 0 { 52.0 + rng.f64() * 8.0 } else { 5.0 + rng.f64() * 40.0 };
            mk_task(i as u64, arrival, arrival + 3.0, u)
        })
        .collect();
    ReplayCell::two_lane(
        &format!("e2e/{}", kind.label()),
        kind,
        SchedParams { batch_size: 16, ..Default::default() },
        &ModelEntry::stub("m", 0.05, 0.08),
        50.0,
        DeviceProfile::edge_server(),
        tasks,
    )
}

fn assert_clean(kind: PolicyKind) -> rtlm::bench_harness::replay::CellParity {
    let time_scale = 25.0;
    let parity = run_parity(
        &cell(kind),
        &tiny_latency(),
        time_scale,
        &ParityTolerance::for_time_scale(time_scale),
    )
    .expect("parity replay runs");
    assert!(
        parity.clean(),
        "{} parity diverged: {:?}",
        kind.label(),
        parity.failures
    );
    assert_eq!(parity.n_tasks, 24);
    assert_eq!(
        parity.sim_batches, parity.wire_batches,
        "clean report implies exact batch agreement"
    );
    parity
}

/// FIFO replays identically on both backends: same per-lane batch
/// counts, response stats within tolerance, and no quarantine traffic
/// (baselines only dispatch on the primary lane).
#[test]
fn fifo_cell_replays_clean_on_the_wire() {
    let parity = assert_clean(PolicyKind::Fifo);
    assert!(parity.sim_batches[0] >= 2, "24 tasks at C=16 need >= 2 gpu batches");
    assert_eq!(parity.sim_batches[1], 0, "FIFO must not use the quarantine lane");
    assert_eq!(parity.sim_lane_tasks[0], 24);
}

/// The full RT-LM machine — UP priorities, λ-consolidation, strategic
/// offloading — replays identically too, with both lanes genuinely
/// serving traffic on both backends.
#[test]
fn rtlm_cell_replays_clean_on_the_wire() {
    let parity = assert_clean(PolicyKind::RtLm);
    assert!(
        parity.sim_batches.iter().all(|&n| n >= 1),
        "every lane must serve >= 1 batch: {:?}",
        parity.sim_batches
    );
    assert!(
        parity.sim_lane_tasks[1] >= 3,
        "the u > tau tail must quarantine: {:?}",
        parity.sim_lane_tasks
    );
    // stats came out of genuinely different executions, not one report
    // echoed twice: wire times carry wall jitter
    let mean = parity.stats.iter().find(|f| f.name == "mean_response").unwrap();
    assert!(mean.sim > 0.0 && mean.wire > 0.0);
}

/// The same cell under iteration-level dispatch (`--sched step`).
fn step_cell(kind: PolicyKind) -> ReplayCell {
    let mut c = cell(kind);
    c.params.mode = SchedMode::Step;
    c.labelled(&format!("e2e/step-{}", kind.label()))
}

fn assert_step_clean(kind: PolicyKind) -> rtlm::bench_harness::replay::CellParity {
    let time_scale = 25.0;
    let parity = run_parity(
        &step_cell(kind),
        &tiny_latency(),
        time_scale,
        &ParityTolerance::for_time_scale(time_scale),
    )
    .expect("step parity replay runs");
    assert!(
        parity.clean(),
        "{} step parity diverged: {:?}",
        kind.label(),
        parity.failures
    );
    assert_eq!(parity.n_tasks, 24);
    // step mode's deterministic counters must agree exactly — per-lane
    // decode-step totals, per-lane task counts, and the preemption count
    // (join-group composition, i.e. n_batches, is allowed to race)
    assert_eq!(parity.sim_steps, parity.wire_steps, "per-lane step totals diverged");
    assert_eq!(parity.sim_lane_tasks, parity.wire_lane_tasks);
    assert_eq!(parity.sim_preempted, parity.wire_preempted);
    parity
}

/// FIFO under iteration-level dispatch replays clean on both backends:
/// every decode step is accounted on the same lane in simulation and on
/// the wire, and baselines still never touch the quarantine lane.
#[test]
fn fifo_step_cell_replays_clean_on_the_wire() {
    let parity = assert_step_clean(PolicyKind::Fifo);
    assert_eq!(parity.sim_lane_tasks[0], 24, "FIFO serves everything on the accelerator");
    assert_eq!(parity.sim_steps[1], 0, "FIFO must not use the quarantine lane");
    assert!(parity.sim_steps[0] > 0, "accelerator executed no decode steps");
}

/// RT-LM under iteration-level dispatch: slot-table packing plus
/// strategic offloading replay clean, with both lanes serving traffic.
#[test]
fn rtlm_step_cell_replays_clean_on_the_wire() {
    let parity = assert_step_clean(PolicyKind::RtLm);
    assert!(
        parity.sim_lane_tasks.iter().all(|&n| n >= 1),
        "every lane must serve >= 1 task: {:?}",
        parity.sim_lane_tasks
    );
    assert!(parity.sim_steps[0] > 0 && parity.sim_steps[1] > 0);
}

/// Whole-batch mode stays bit-identical: a clean batch-mode parity
/// report implies *exact* per-lane batch counts (the tolerance never
/// applies to them) — the invariant that guards the historical engine
/// against regressions from the slot-table refactor.
#[test]
fn batch_mode_parity_is_exact_on_batch_counts() {
    for kind in [PolicyKind::Fifo, PolicyKind::RtLm] {
        let c = cell(kind);
        assert_eq!(c.params.mode, SchedMode::Batch, "cells default to whole-batch dispatch");
        let parity = assert_clean(kind);
        assert_eq!(parity.sim_batches, parity.wire_batches);
        assert_eq!(parity.sim_steps, parity.wire_steps, "batch mode steps diverged");
    }
}
