//! End-to-end wire-path parity (`rtlm bench --wire` machinery): replay
//! one experiment cell through the virtual-clock simulator AND the
//! threaded wall-clock engine (real dispatcher + lane-worker threads,
//! modeled batch durations, dilated engine clock) and assert the parity
//! report is clean — per-lane batch counts exactly equal, response
//! stats within the time-scale-aware tolerance. Artifact-free: stub
//! model, hand-built latency calibration.

use std::collections::BTreeMap;

use rtlm::bench_harness::replay::{run_parity, ParityTolerance, ReplayCell};
use rtlm::config::{DeviceProfile, ModelEntry, SchedParams};
use rtlm::scheduler::{PolicyKind, Task};
use rtlm::sim::{Calibration, LatencyModel};
use rtlm::util::rng::Pcg64;

fn tiny_latency() -> LatencyModel {
    let mut c = Calibration::default();
    c.decode
        .insert("m".into(), BTreeMap::from([(1, 0.01), (4, 0.018), (16, 0.04)]));
    c.prefill
        .insert("m".into(), BTreeMap::from([((1, 16), 0.02), ((16, 64), 0.08)]));
    LatencyModel::from_calibration(&c)
}

fn mk_task(id: u64, arrival: f64, priority_point: f64, uncertainty: f64) -> Task {
    Task {
        id,
        text: String::new(),
        prompt: vec![],
        arrival,
        priority_point,
        uncertainty,
        true_len: uncertainty.max(1.0) as usize,
        input_len: 8,
        utype: "test".into(),
        malicious: false,
        deferrals: 0,
    }
}

/// A paper-shaped cell: 24 tasks over a 7 s arrival sweep, uncertainty
/// spread across the quarantine threshold so RT-LM exercises every lane.
fn cell(kind: PolicyKind) -> ReplayCell {
    let mut rng = Pcg64::new(0xCE11);
    let tasks: Vec<Task> = (0..24)
        .map(|i| {
            let arrival = i as f64 * 0.3;
            // ~1 in 4 tasks above tau = 50 quarantines under RT-LM
            let u = if i % 4 == 0 { 52.0 + rng.f64() * 8.0 } else { 5.0 + rng.f64() * 40.0 };
            mk_task(i as u64, arrival, arrival + 3.0, u)
        })
        .collect();
    ReplayCell::two_lane(
        &format!("e2e/{}", kind.label()),
        kind,
        SchedParams { batch_size: 16, ..Default::default() },
        &ModelEntry::stub("m", 0.05, 0.08),
        50.0,
        DeviceProfile::edge_server(),
        tasks,
    )
}

fn assert_clean(kind: PolicyKind) -> rtlm::bench_harness::replay::CellParity {
    let time_scale = 25.0;
    let parity = run_parity(
        &cell(kind),
        &tiny_latency(),
        time_scale,
        &ParityTolerance::for_time_scale(time_scale),
    )
    .expect("parity replay runs");
    assert!(
        parity.clean(),
        "{} parity diverged: {:?}",
        kind.label(),
        parity.failures
    );
    assert_eq!(parity.n_tasks, 24);
    assert_eq!(
        parity.sim_batches, parity.wire_batches,
        "clean report implies exact batch agreement"
    );
    parity
}

/// FIFO replays identically on both backends: same per-lane batch
/// counts, response stats within tolerance, and no quarantine traffic
/// (baselines only dispatch on the primary lane).
#[test]
fn fifo_cell_replays_clean_on_the_wire() {
    let parity = assert_clean(PolicyKind::Fifo);
    assert!(parity.sim_batches[0] >= 2, "24 tasks at C=16 need >= 2 gpu batches");
    assert_eq!(parity.sim_batches[1], 0, "FIFO must not use the quarantine lane");
    assert_eq!(parity.sim_lane_tasks[0], 24);
}

/// The full RT-LM machine — UP priorities, λ-consolidation, strategic
/// offloading — replays identically too, with both lanes genuinely
/// serving traffic on both backends.
#[test]
fn rtlm_cell_replays_clean_on_the_wire() {
    let parity = assert_clean(PolicyKind::RtLm);
    assert!(
        parity.sim_batches.iter().all(|&n| n >= 1),
        "every lane must serve >= 1 batch: {:?}",
        parity.sim_batches
    );
    assert!(
        parity.sim_lane_tasks[1] >= 3,
        "the u > tau tail must quarantine: {:?}",
        parity.sim_lane_tasks
    );
    // stats came out of genuinely different executions, not one report
    // echoed twice: wire times carry wall jitter
    let mean = parity.stats.iter().find(|f| f.name == "mean_response").unwrap();
    assert!(mean.sim > 0.0 && mean.wire > 0.0);
}
