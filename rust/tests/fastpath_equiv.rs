//! Bit-equality suite for the interned scoring fast path: on every
//! input — random word soups, unicode edge cases, every artifact
//! golden — `uncertainty::fastpath::features_scratch` must produce the
//! exact same f64 bits as the legacy `uncertainty::rules::features`
//! (the test oracle, itself pinned to python by the goldens), and the
//! estimator's scratch scoring must match its allocating twin.

use std::sync::Arc;

use rtlm::runtime::bundle::{Bundle, Tensor};
use rtlm::textgen::{tokenize, Lexicon, ScoreScratch};
use rtlm::uncertainty::{fastpath, rules, Estimator, Regressor};
use rtlm::util::json::Json;
use rtlm::util::prop;

const MAX_INPUT_LEN: usize = 64;

/// A lexicon that exercises every rule list, with deliberate overlaps
/// (words in several lists, phrase words that are also topics, a
/// punctuation "word" in a marker list) and non-ASCII entries.
fn rich_lexicon() -> Lexicon {
    let json = r#"{
        "vocab": ["<pad>", "<bos>", "<eos>", "<unk>"],
        "pos_lexicon": {
            "in": "ADP", "with": "ADP", "of": "ADP", "on": "ADP",
            "saw": "VERB", "runs": "VERB", "is": "VERB",
            "park": "NOUN", "boy": "NOUN", "telescope": "NOUN",
            "happily": "ADV", "and": "CONJ", "the": "DET", "a": "DET",
            "what": "WH", "that": "PRON", "café": "NOUN"
        },
        "suffix_rules": [
            ["ly", "ADV"], ["ing", "VERB"], ["ed", "VERB"],
            ["tion", "NOUN"], ["ness", "NOUN"], ["ous", "ADJ"]
        ],
        "homonyms": {"bank": 3, "scale": 4, "bats": 2, "duck": 2},
        "nv_ambiguous": ["saw", "duck", "flies", "watch"],
        "vague_topics": ["history", "art", "science", "poverty"],
        "vague_phrases": [
            ["tell", "me", "about"],
            ["what", "do", "you", "think", "about"],
            ["talk", "about"],
            ["describe"]
        ],
        "open_markers": ["causes", "consequences", "ways", "best"],
        "multipart_markers": ["both", "also", ","],
        "relativizers": ["that", "which", "who"],
        "wh_words": ["what", "why", "how", "who", "when", "where"],
        "vague_adjectives": ["general", "various", "different"],
        "open_wh_starters": ["what", "why", "how"]
    }"#;
    Lexicon::from_json(&Json::parse(json).expect("lexicon json")).expect("lexicon")
}

/// Mixed word pool the generator draws from: list members, phrase
/// fragments, suffix-rule bait, punctuation, unknowns, unicode.
const POOL: &[&str] = &[
    // structural / syntactic / semantic
    "in", "with", "of", "saw", "duck", "park", "boy", "that", "which", "bank", "scale",
    // vague / open / multipart
    "history", "art", "tell", "me", "about", "describe", "talk", "causes", "best", "both",
    "also", "general", "various",
    // phrase fragments and question scaffolding
    "what", "why", "how", "do", "you", "think", "and", "the", "a", "is",
    // suffix bait and unknowns
    "happily", "running", "guarded", "station", "darkness", "famous", "zzz", "qwerty",
    // punctuation tokens (attach to neighbours through the joiner too)
    ",", "?", ".", "!", "(", ")", "\"", ":",
    // unicode: multi-byte lowercasing, combining marks, greek sigma
    "İstanbul", "STRASSE", "ΣΟΦΟΣ", "caf\u{e9}", "cafe\u{301}", "na\u{ef}ve", "中文",
];

const SEPARATORS: &[&str] = &[" ", "  ", "\t", "\n", " \r\n "];

fn random_text(rng: &mut rtlm::util::rng::Pcg64) -> String {
    let n_words = rng.range_usize(0, 14);
    let mut text = String::new();
    for i in 0..n_words {
        if i > 0 {
            text.push_str(rng.choice(SEPARATORS));
        }
        // occasionally glue punctuation straight onto the word
        let word = *rng.choice(POOL);
        text.push_str(word);
        if rng.f64() < 0.25 {
            text.push_str(rng.choice(&[",", "?", ".", "!", "\"", ")"]));
        }
    }
    // sometimes uppercase the whole thing (scoring lowercases first)
    if rng.f64() < 0.2 {
        text = text.to_uppercase();
    }
    text
}

fn assert_features_match(lex: &Lexicon, scratch: &mut ScoreScratch, text: &str) {
    let want = rules::features(lex, text, MAX_INPUT_LEN);
    let got = fastpath::features_scratch(lex, text, MAX_INPUT_LEN, scratch);
    for (j, (g, w)) in got.iter().zip(&want).enumerate() {
        assert_eq!(
            g.to_bits(),
            w.to_bits(),
            "feature {j} diverged on {text:?}: fast {g} vs legacy {w}\n\
             (tokens: {:?})",
            tokenize(text)
        );
    }
}

#[test]
fn fastpath_matches_legacy_on_random_texts() {
    let lex = rich_lexicon();
    // one scratch reused across every case — the reuse contract is part
    // of what's under test
    let mut scratch = ScoreScratch::new();
    prop::check_result(
        "fastpath-bit-equality",
        500,
        random_text,
        |text| {
            let want = rules::features(&lex, text, MAX_INPUT_LEN);
            let got = fastpath::features_scratch(&lex, text, MAX_INPUT_LEN, &mut scratch);
            for (j, (g, w)) in got.iter().zip(&want).enumerate() {
                if g.to_bits() != w.to_bits() {
                    return Err(format!(
                        "feature {j}: fast {g} vs legacy {w} (tokens {:?})",
                        tokenize(text)
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn fastpath_matches_legacy_on_edge_cases() {
    let lex = rich_lexicon();
    let mut scratch = ScoreScratch::new();
    for text in [
        "",
        " ",
        "\t\n",
        "...",
        "?!?!",
        "(,)",
        "what",
        "what?",
        "of",
        "and",
        "do you think",
        "so, what do you think about it?",
        "what do you think of that?",
        "tell me about the history of art.",
        "What are the causes and consequences of poverty?",
        "john saw a boy in the park with a telescope.",
        "rice flies like sand.",
        "duck duck duck",
        "that that that",
        "the boy that saw",
        // first-token sensitivities
        "what of", "of what", "and what?", "what and",
        // unicode: lowercasing expansions, sigma, combining chars
        "İstanbul DİYARBAKIR",
        "ΟΔΥΣΣΕΥΣ kai ΣΟΦΟΣ.",
        "STRASSE weiß",
        "caf\u{e9} cafe\u{301}",
        "e\u{301}toile, (NA\u{cf}VE)!",
        "中文 测试 ?",
        // max_input_len clamping (a run past 64 tokens)
        &"word ".repeat(100),
        &"what , and ? both ".repeat(20),
    ] {
        assert_features_match(&lex, &mut scratch, text);
    }
}

#[test]
fn fastpath_matches_on_empty_rule_lists() {
    // an all-empty lexicon still scores (everything 0 except length)
    let json = r#"{
        "vocab": [], "pos_lexicon": {}, "suffix_rules": [],
        "homonyms": {}, "nv_ambiguous": [], "vague_topics": [],
        "vague_phrases": [], "open_markers": [], "multipart_markers": [],
        "relativizers": [], "wh_words": [], "vague_adjectives": [],
        "open_wh_starters": []
    }"#;
    let lex = Lexicon::from_json(&Json::parse(json).unwrap()).unwrap();
    let mut scratch = ScoreScratch::new();
    for text in ["", "hello world?", "tell me about art, and history."] {
        assert_features_match(&lex, &mut scratch, text);
    }
}

#[test]
fn estimator_scratch_scoring_matches_allocating_path() {
    let lex = Arc::new(rich_lexicon());
    // a regressor that weighs every feature, so any feature divergence
    // shows up in the score
    let bundle = Bundle::from_tensors(vec![
        Tensor::f32(
            "w0",
            vec![7, 3],
            vec![
                0.31, -0.7, 1.1, 0.9, 0.33, -0.21, 1.7, 0.05, -0.6, 0.42, 0.8, 0.13, -1.2, 0.64,
                0.27, 0.55, -0.44, 0.91, 0.18, 0.72, -0.08,
            ],
        ),
        Tensor::f32("b0", vec![3], vec![0.1, -0.2, 0.3]),
        Tensor::f32("w1", vec![3, 1], vec![1.4, -0.9, 0.6]),
        Tensor::f32("b1", vec![1], vec![12.0]),
    ]);
    let scales = vec![10.0, 10.0, 10.0, 10.0, 10.0, 10.0, MAX_INPUT_LEN as f64];
    let reg = Arc::new(Regressor::from_bundle(&bundle, &scales).expect("regressor"));
    let est = Estimator::new(lex, reg, MAX_INPUT_LEN, 4.0, 96.0);

    let mut scratch = ScoreScratch::new();
    prop::check_result(
        "estimator-scratch-bit-equality",
        200,
        random_text,
        |text| {
            let (want_u, want_f) = est.score_with_features(text).map_err(|e| e.to_string())?;
            let (got_u, got_f) = est
                .score_with_features_scratch(text, &mut scratch)
                .map_err(|e| e.to_string())?;
            if got_u.to_bits() != want_u.to_bits() {
                return Err(format!("score: fast {got_u} vs legacy {want_u}"));
            }
            for (g, w) in got_f.iter().zip(&want_f) {
                if g.to_bits() != w.to_bits() {
                    return Err(format!("features: fast {got_f:?} vs legacy {want_f:?}"));
                }
            }
            let solo = est.score_scratch(text, &mut scratch).map_err(|e| e.to_string())?;
            if solo.to_bits() != want_u.to_bits() {
                return Err(format!("score_scratch: {solo} vs {want_u}"));
            }
            Ok(())
        },
    );
}
