//! Zero-allocation guarantee for the scoring fast path: after warmup
//! (the scratch buffers grown to steady-state capacity), a
//! `score_with_features_scratch` call performs **zero** heap
//! allocations. Verified with a counting `#[global_allocator]`.
//!
//! This file deliberately holds a single `#[test]`: the default test
//! harness runs tests on multiple threads, and any concurrent test
//! would pollute the global allocation counter.
//!
//! Documented exception: text containing 'Σ' (U+03A3) falls back to
//! `str::to_lowercase` for its context-sensitive final-sigma mapping,
//! which takes one transient allocation — asserted separately.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use rtlm::runtime::bundle::{Bundle, Tensor};
use rtlm::textgen::{Lexicon, ScoreScratch};
use rtlm::uncertainty::{Estimator, Regressor};
use rtlm::util::json::Json;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn allocations() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

fn lexicon() -> Lexicon {
    let json = r#"{
        "vocab": ["<pad>", "<bos>", "<eos>", "<unk>"],
        "pos_lexicon": {
            "in": "ADP", "with": "ADP", "of": "ADP",
            "saw": "VERB", "is": "VERB", "the": "DET", "a": "DET",
            "park": "NOUN", "boy": "NOUN", "what": "WH", "and": "CONJ"
        },
        "suffix_rules": [["ly", "ADV"], ["ing", "VERB"], ["tion", "NOUN"]],
        "homonyms": {"bank": 3, "duck": 2},
        "nv_ambiguous": ["saw", "duck"],
        "vague_topics": ["history", "art"],
        "vague_phrases": [["tell", "me", "about"], ["describe"]],
        "open_markers": ["causes", "consequences"],
        "multipart_markers": ["both", "also"],
        "relativizers": ["that", "which"],
        "wh_words": ["what", "why", "how"],
        "vague_adjectives": ["general"],
        "open_wh_starters": ["what", "why", "how"]
    }"#;
    Lexicon::from_json(&Json::parse(json).expect("lexicon json")).expect("lexicon")
}

fn estimator() -> Estimator {
    // two layers so the regressor's ping-pong buffers are exercised
    let bundle = Bundle::from_tensors(vec![
        Tensor::f32(
            "w0",
            vec![7, 2],
            vec![0.3, -0.2, 0.8, 0.1, 0.5, 0.4, -0.7, 0.9, 0.2, 0.6, 1.1, -0.3, 0.05, 0.75],
        ),
        Tensor::f32("b0", vec![2], vec![0.1, -0.1]),
        Tensor::f32("w1", vec![2, 1], vec![1.2, 0.7]),
        Tensor::f32("b1", vec![1], vec![8.0]),
    ]);
    let scales = vec![10.0, 10.0, 10.0, 10.0, 10.0, 10.0, 64.0];
    let reg = Regressor::from_bundle(&bundle, &scales).expect("regressor");
    Estimator::new(Arc::new(lexicon()), Arc::new(reg), 64, 4.0, 96.0)
}

#[test]
fn steady_state_scoring_does_not_allocate() {
    let est = estimator();
    let mut scratch = ScoreScratch::new();
    let texts = [
        "what are the causes and consequences of poverty, both here and there?",
        "tell me about the history of art.",
        "the boy that saw a duck in the park, with a telescope!",
        "İstanbul cafe\u{301} na\u{ef}ve \"quoted\" (parens)...",
        "short",
        "",
    ];

    // warmup: grow every buffer (lowercase text, spans, ids, regressor
    // activations) to its steady-state capacity
    for text in &texts {
        est.score_with_features_scratch(text, &mut scratch).expect("warmup score");
    }

    // steady state: repeat the same workload; not a single heap
    // allocation is allowed
    for round in 0..3 {
        for text in &texts {
            let before = allocations();
            let (u, feats) = est
                .score_with_features_scratch(text, &mut scratch)
                .expect("steady-state score");
            let delta = allocations() - before;
            assert_eq!(
                delta, 0,
                "round {round}: scoring {text:?} allocated {delta} times (u={u}, feats={feats:?})"
            );
        }
    }

    // sanity: the counter works — the legacy path must allocate (token
    // Strings at minimum)
    let before = allocations();
    est.score_with_features(texts[0]).expect("legacy score");
    assert!(
        allocations() > before,
        "counting allocator saw no allocations from the legacy path — counter broken?"
    );

    // documented exception: 'Σ' falls back to str::to_lowercase (one
    // transient String); still bounded, and only for sigma inputs
    est.score_with_features_scratch("ΟΔΥΣΣΕΥΣ ΣΟΦΟΣ", &mut scratch).expect("sigma warmup");
    let before = allocations();
    est.score_with_features_scratch("ΟΔΥΣΣΕΥΣ ΣΟΦΟΣ", &mut scratch).expect("sigma score");
    let sigma_delta = allocations() - before;
    assert!(
        sigma_delta <= 2,
        "sigma fallback should cost at most the one transient lowercase String \
         (plus a possible growth realloc), saw {sigma_delta}"
    );
}
