//! Framed wire protocol for the distributed fleet (router ⇄ node).
//!
//! The line protocol (`tcp.rs`) is what *clients* speak; router⇄node
//! traffic instead uses length-prefixed frames so payloads may contain
//! newlines and replies can be id-tagged and arrive out of order. A
//! framed peer announces itself by sending [`MAGIC`] immediately after
//! connecting; the preamble starts with a NUL byte, which a text line
//! can never contain, so the node's accept loop can tell the two
//! protocols apart by peeking a single buffered byte
//! ([`is_framed_peer`]).
//!
//! After the preamble, the stream is a sequence of frames:
//!
//! ```text
//! [u32 big-endian payload length][payload: one JSON object]
//! ```
//!
//! Every payload is a JSON object with a `"type"` field. The fleet
//! protocol uses: `hello` (router → node, asks for the lane table),
//! `lanes` (node → router, the gossip reply), `register` (node →
//! router dial-in), `ok` (registration ack), `ping`/`pong`
//! (heartbeats), `submit` (router → node, a batch of pre-scored
//! tasks), and `done` (node → router, one per-task reply, id-tagged
//! and unordered).
//!
//! Robustness contract (exercised by the in-module tests): truncated
//! headers, truncated payloads, oversized lengths, and non-JSON
//! payloads all surface as clean `Err`s — never a hang, a panic, or an
//! unbounded allocation. Only EOF *between* frames is a clean end of
//! stream (`Ok(None)`).

use std::io::{self, BufRead, Read, Write};

use anyhow::{bail, Context, Result};

use crate::util::json::{obj, Json};

/// Connection preamble a framed peer sends once, immediately after
/// connecting. Starts with NUL so line-protocol text can never
/// collide with it.
pub const MAGIC: [u8; 6] = [0, b'R', b'T', b'L', b'M', b'1'];

/// Upper bound on a single frame payload. A submit frame carries at
/// most one scheduler batch of short prompts, so 4 MiB is generous;
/// anything larger is treated as a corrupt or hostile stream.
pub const MAX_FRAME: usize = 4 << 20;

/// Send the connection preamble (framed peers call this once, before
/// the first frame).
pub fn write_magic(w: &mut impl Write) -> Result<()> {
    w.write_all(&MAGIC).context("writing frame preamble")?;
    Ok(())
}

/// Consume and verify the connection preamble.
pub fn read_magic(r: &mut impl Read) -> Result<()> {
    let mut buf = [0u8; MAGIC.len()];
    r.read_exact(&mut buf).context("reading frame preamble")?;
    if buf != MAGIC {
        bail!("bad frame preamble (expected RTLM1 magic)");
    }
    Ok(())
}

/// Peek (without consuming anything) whether the peer on a freshly
/// accepted connection speaks the framed protocol. Blocks until the
/// first byte arrives; returns `false` on immediate EOF (probe
/// connections) so the caller falls through to the line handler,
/// which sees the same EOF and exits cleanly.
pub fn is_framed_peer<R: BufRead>(reader: &mut R) -> io::Result<bool> {
    let buf = reader.fill_buf()?;
    Ok(buf.first() == Some(&MAGIC[0]))
}

/// Build a frame payload: an object with `"type": kind` plus fields.
pub fn frame(kind: &str, fields: Vec<(&str, Json)>) -> Json {
    let mut pairs = vec![("type", Json::Str(kind.to_string()))];
    pairs.extend(fields);
    obj(pairs)
}

/// The `"type"` tag of a frame payload (empty string if absent).
pub fn frame_type(msg: &Json) -> &str {
    msg.get("type").as_str().unwrap_or("")
}

/// Write one frame (length prefix + JSON payload) and flush.
pub fn write_frame(w: &mut impl Write, msg: &Json) -> Result<()> {
    let payload = msg.to_string().into_bytes();
    if payload.len() > MAX_FRAME {
        bail!("refusing to send a {} byte frame (cap {MAX_FRAME})", payload.len());
    }
    w.write_all(&(payload.len() as u32).to_be_bytes())
        .context("writing frame header")?;
    w.write_all(&payload).context("writing frame payload")?;
    w.flush().context("flushing frame")?;
    Ok(())
}

/// Read one frame. `Ok(None)` on a clean EOF at a frame boundary;
/// `Err` on a truncated header/payload, an oversized or empty length,
/// or a payload that is not valid JSON. The length is validated
/// *before* the payload buffer is allocated, so a corrupt header can
/// not trigger a multi-gigabyte allocation.
pub fn read_frame(r: &mut impl Read) -> Result<Option<Json>> {
    let mut header = [0u8; 4];
    // First header byte by hand: EOF *here* is a clean end of stream,
    // EOF anywhere later is a truncation error.
    loop {
        match r.read(&mut header[..1]) {
            Ok(0) => return Ok(None),
            Ok(_) => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e).context("reading frame header"),
        }
    }
    r.read_exact(&mut header[1..])
        .context("unexpected EOF inside a frame header")?;
    let len = u32::from_be_bytes(header) as usize;
    if len == 0 {
        bail!("empty frame");
    }
    if len > MAX_FRAME {
        bail!("frame length {len} exceeds the {MAX_FRAME} byte cap");
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)
        .context("unexpected EOF inside a frame payload")?;
    let text = std::str::from_utf8(&payload).context("frame payload is not UTF-8")?;
    let msg = Json::parse(text)
        .map_err(|e| anyhow::anyhow!("frame payload is not valid JSON: {e}"))?;
    Ok(Some(msg))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn err_of(bytes: &[u8]) -> String {
        read_frame(&mut Cursor::new(bytes.to_vec()))
            .expect_err("corrupt input must error")
            .to_string()
    }

    #[test]
    fn frames_round_trip_through_a_byte_stream() {
        let mut wire = Vec::new();
        write_magic(&mut wire).unwrap();
        let a = frame("ping", vec![("seq", Json::Num(3.0))]);
        let b = frame("done", vec![("id", Json::Num(7.0)), ("text", Json::Str("x\ny".into()))]);
        write_frame(&mut wire, &a).unwrap();
        write_frame(&mut wire, &b).unwrap();

        let mut r = Cursor::new(wire);
        read_magic(&mut r).unwrap();
        let got_a = read_frame(&mut r).unwrap().expect("first frame");
        assert_eq!(frame_type(&got_a), "ping");
        assert_eq!(got_a.need_f64("seq").unwrap(), 3.0);
        let got_b = read_frame(&mut r).unwrap().expect("second frame");
        assert_eq!(got_b.need_str("text").unwrap(), "x\ny");
        // clean EOF at a frame boundary
        assert!(read_frame(&mut r).unwrap().is_none());
        assert!(read_frame(&mut r).unwrap().is_none(), "EOF must stay clean on re-read");
    }

    #[test]
    fn truncated_header_is_an_error_not_a_hang() {
        let msg = err_of(&[0, 0, 1]);
        assert!(msg.contains("frame header"), "{msg}");
    }

    #[test]
    fn truncated_payload_is_an_error() {
        // header says 10 bytes, only 3 arrive before disconnect
        let mut bytes = 10u32.to_be_bytes().to_vec();
        bytes.extend_from_slice(b"abc");
        let msg = err_of(&bytes);
        assert!(msg.contains("frame payload"), "{msg}");
    }

    #[test]
    fn oversized_length_is_rejected_before_allocation() {
        let bytes = u32::MAX.to_be_bytes().to_vec();
        let msg = err_of(&bytes);
        assert!(msg.contains("exceeds"), "{msg}");
    }

    #[test]
    fn zero_length_frame_is_rejected() {
        let msg = err_of(&0u32.to_be_bytes());
        assert!(msg.contains("empty frame"), "{msg}");
    }

    #[test]
    fn garbage_payload_is_a_clean_parse_error() {
        let mut bytes = 9u32.to_be_bytes().to_vec();
        bytes.extend_from_slice(b"not-json!");
        let msg = err_of(&bytes);
        assert!(msg.contains("not valid JSON"), "{msg}");

        // and non-UTF8 garbage
        let mut bytes = 4u32.to_be_bytes().to_vec();
        bytes.extend_from_slice(&[0xff, 0xfe, 0x00, 0x80]);
        let msg = err_of(&bytes);
        assert!(msg.contains("not UTF-8"), "{msg}");
    }

    #[test]
    fn oversized_write_is_refused() {
        let huge = Json::Str("x".repeat(MAX_FRAME + 1));
        let mut sink = Vec::new();
        assert!(write_frame(&mut sink, &huge).is_err());
        assert!(sink.is_empty(), "nothing may hit the wire");
    }

    #[test]
    fn magic_and_peek_distinguish_framed_peers_from_text() {
        let mut wire = Vec::new();
        write_magic(&mut wire).unwrap();
        let mut r = std::io::BufReader::new(Cursor::new(wire));
        assert!(is_framed_peer(&mut r).unwrap());
        read_magic(&mut r).unwrap();

        let mut text = std::io::BufReader::new(Cursor::new(b"hello line\n".to_vec()));
        assert!(!is_framed_peer(&mut text).unwrap());
        // the peek consumed nothing: the line is still there
        let mut line = String::new();
        text.read_line(&mut line).unwrap();
        assert_eq!(line, "hello line\n");

        let mut empty = std::io::BufReader::new(Cursor::new(Vec::new()));
        assert!(!is_framed_peer(&mut empty).unwrap(), "probe connections are not framed");

        let bad = read_magic(&mut Cursor::new(b"\x00RTLM2".to_vec()));
        assert!(bad.unwrap_err().to_string().contains("preamble"));
    }
}
