//! Minimal line-protocol TCP front-end (the "chatbot server" face of
//! RT-LM).
//!
//! Protocol: one request per line — the raw utterance. The server
//! replies with one JSON line: `{"id":..,"tokens":..,"text":..,
//! "response_ms":..,"lane":..}`. Requests from all connections funnel
//! into the shared RT-LM scheduler, so concurrent clients exercise
//! batching and prioritisation exactly like the benchmark workloads.
//!
//! PJRT handles are not `Send`, so the LM session lives on the
//! dispatcher thread and batches execute inline; connection threads only
//! tokenize/score (pure rust, Send).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::SchedParams;
use crate::executor::{execute_cpu, execute_gpu};
use crate::model::LmSession;
use crate::scheduler::{Lane, Policy, Task};
use crate::textgen::Vocab;
use crate::uncertainty::Estimator;
use crate::util::json::{obj, Json};

struct Pending {
    reply_tx: mpsc::Sender<String>,
    submitted: Instant,
}

/// Serve forever on `addr` (e.g. "127.0.0.1:7490").
pub fn serve_tcp(
    session: Arc<LmSession>,
    estimator: Estimator,
    mut policy: Box<dyn Policy>,
    params: SchedParams,
    addr: &str,
) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    eprintln!(
        "rtlm tcp server on {addr} (model={}, policy={})",
        session.model_name(),
        policy.name()
    );
    let store = session.store();
    let vocab = store.vocab.clone();
    let max_input_len = store.manifest.max_input_len;
    let phi = session.entry.phi;

    let (req_tx, req_rx) = mpsc::channel::<(Task, Pending)>();
    let next_id = Arc::new(AtomicU64::new(0));
    let epoch = Instant::now();

    // acceptor thread: connection handlers only touch Send-safe state
    {
        let vocab = vocab.clone();
        thread::spawn(move || {
            for stream in listener.incoming().flatten() {
                let req_tx = req_tx.clone();
                let estimator = estimator.clone();
                let next_id = next_id.clone();
                let vocab = vocab.clone();
                thread::spawn(move || {
                    if let Err(e) = handle_conn(
                        stream,
                        req_tx,
                        estimator,
                        next_id,
                        vocab,
                        max_input_len,
                        phi,
                        epoch,
                    ) {
                        eprintln!("connection error: {e:#}");
                    }
                });
            }
        });
    }

    // dispatcher loop: owns the policy and runs lanes inline
    let mut pending: std::collections::HashMap<u64, Pending> = std::collections::HashMap::new();
    let mut oldest: Option<Instant> = None;
    loop {
        match req_rx.recv_timeout(Duration::from_millis(25)) {
            Ok((task, info)) => {
                oldest = Some(oldest.unwrap_or(info.submitted).min(info.submitted));
                pending.insert(task.id, info);
                policy.push(task);
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => return Ok(()),
        }
        let force = oldest
            .map(|t| t.elapsed().as_secs_f64() >= params.xi)
            .unwrap_or(false);
        for lane in [Lane::Gpu, Lane::Cpu] {
            let now = epoch.elapsed().as_secs_f64();
            let Some(batch) = policy.pop_batch(lane, now, force) else { continue };
            let reports = match lane {
                Lane::Gpu => execute_gpu(&session, &batch).map(|r| vec![r]),
                Lane::Cpu => execute_cpu(&session, &batch),
            };
            match reports {
                Ok(reports) => {
                    for rep in reports {
                        for (i, &id) in rep.task_ids.iter().enumerate() {
                            if let Some(info) = pending.remove(&id) {
                                let text = vocab.decode(&rep.outputs[i]);
                                let ms = info.submitted.elapsed().as_secs_f64() * 1e3;
                                let reply = obj(vec![
                                    ("id", Json::Num(id as f64)),
                                    ("tokens", Json::Num(rep.outputs[i].len() as f64)),
                                    ("text", Json::Str(text)),
                                    ("response_ms", Json::Num(ms)),
                                    ("lane", Json::Str(format!("{:?}", rep.lane))),
                                ]);
                                let _ = info.reply_tx.send(reply.to_string());
                            }
                        }
                    }
                    if pending.is_empty() {
                        oldest = None;
                    }
                }
                Err(e) => eprintln!("lane error: {e:#}"),
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn handle_conn(
    stream: TcpStream,
    req_tx: mpsc::Sender<(Task, Pending)>,
    estimator: Estimator,
    next_id: Arc<AtomicU64>,
    vocab: Arc<Vocab>,
    max_input_len: usize,
    phi: f64,
    epoch: Instant,
) -> Result<()> {
    let peer = stream.peer_addr()?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let text = line?;
        if text.trim().is_empty() {
            continue;
        }
        let id = next_id.fetch_add(1, Ordering::Relaxed);
        let (u, feats) = estimator.score_with_features(&text)?;
        let input_len = feats[feats.len() - 1] as usize;
        let mut prompt = vocab.encode(&text, Some(max_input_len));
        if prompt.is_empty() {
            prompt.push(crate::textgen::vocab::BOS_ID);
        }
        let now = epoch.elapsed().as_secs_f64();
        let task = Task {
            id,
            text: text.clone(),
            prompt,
            arrival: now,
            priority_point: now + 2.0 + phi * input_len as f64,
            uncertainty: u,
            // interactive requests have no oracle: serve the predicted length
            true_len: (u.round() as usize).clamp(4, 96),
            input_len,
            utype: "interactive".into(),
            malicious: false,
            deferrals: 0,
        };
        let (reply_tx, reply_rx) = mpsc::channel();
        req_tx.send((task, Pending { reply_tx, submitted: Instant::now() })).ok();
        match reply_rx.recv_timeout(Duration::from_secs(120)) {
            Ok(reply) => writeln!(writer, "{reply}")?,
            Err(_) => {
                writeln!(writer, "{{\"error\":\"timeout\"}}")?;
                eprintln!("request from {peer} timed out");
            }
        }
    }
    Ok(())
}
