//! Minimal line-protocol TCP front-end (the "chatbot server" face of
//! RT-LM).
//!
//! Protocol: one request per line — the raw utterance (empty lines are
//! ignored). The server replies with one JSON line:
//! `{"id":..,"tokens":..,"text":..,"response_ms":..,"ttft_ms":..,"lane":..}`, or
//! `{"id":..,"error":..}` — every reply carries the request `id`, so a
//! client pipelining multiple lines on one connection can correlate
//! failures too. `lane` is the configured lane name the task executed
//! on (`gpu` / `cpu` on the default fleet).
//!
//! There is no dispatch loop here. Connection handlers tokenize + score
//! (pure rust, `Send`) and feed tasks through the engine's
//! [`ArrivalHandle`]; the shared dispatcher core
//! ([`run_engine_stream`] over a [`ThreadedBackend`], the exact loop
//! the simulator and `rtlm serve` drive) owns admission, ξ-forcing,
//! lane gating and accounting, with batches executing on per-lane
//! worker threads — every configured lane genuinely concurrent — and
//! replies flowing back from the per-task completion callback.
//!
//! **Pipelining**: with `pipeline_depth = 1` (the default) a connection
//! serves one request at a time and replies in request order. With
//! `pipeline_depth = K > 1` a connection may have up to K requests in
//! flight; replies are written as their tasks complete — out of order,
//! correlated by `id` — and the per-request reply timeout becomes a
//! per-connection inactivity timeout (no reply for `reply_timeout` with
//! requests outstanding times out *all* outstanding requests).
//!
//! **Framed peers**: a connection that opens with the
//! [`wire`](super::wire) magic is another rtlm process — the `rtlm
//! route` controller — not a chat client. Those connections speak the
//! length-prefixed frame protocol instead of text lines: `hello` /
//! `lanes` gossips this node's lane table, `ping` / `pong` carries
//! heartbeats, and `submit` / `done` carries pre-scored tasks whose
//! replies are correlated by id out of order. The first buffered byte
//! decides (the magic starts with a NUL no text line can), so ordinary
//! line clients are untouched.

use std::collections::{HashMap, HashSet};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::{SchedMode, SchedParams};
use crate::engine::{run_engine_stream, ArrivalHandle, ArrivalSource, ThreadedBackend};
use crate::executor::ExecutorFactory;
use crate::runtime::ArtifactStore;
use crate::scheduler::{LaneKind, LaneSet, Policy, Task};
use crate::sim::results::TaskOutcome;
use crate::textgen::{ScoreScratch, Vocab};
use crate::uncertainty::Estimator;
use crate::util::json::{obj, Json};

use super::wire;

/// Everything a connection handler needs to turn a text line into a
/// scored task and wait for its reply. Built from an [`ArtifactStore`]
/// by [`serve_tcp`]; tests construct it directly from stubs.
#[derive(Clone)]
pub struct TcpServerConfig {
    /// Vocabulary used to encode prompts and decode replies.
    pub vocab: Arc<Vocab>,
    /// The uncertainty estimator requests are scored with.
    pub estimator: Estimator,
    /// Prompts are truncated to this many tokens.
    pub max_input_len: usize,
    /// The primary serving model's input-tokens -> priority-point
    /// coefficient.
    pub phi: f64,
    /// Scheduler parameters of the serving policy.
    pub params: SchedParams,
    /// The lane fleet this server schedules over; replies carry the
    /// executing lane's name.
    pub lanes: LaneSet,
    /// Max in-flight requests per connection (K). 1 = serve one request
    /// at a time, replies in request order (the historical behaviour).
    pub pipeline_depth: usize,
    /// How long a connection handler waits for its reply before sending
    /// an id-tagged timeout error (the task itself stays scheduled). In
    /// pipelined mode this is a per-connection inactivity timeout.
    pub reply_timeout: Duration,
    /// This process's node name: gossiped to routers, stamped on every
    /// reply as the `node` field. `"local"` for a plain single-process
    /// server; on a router, replies instead derive the tag from the
    /// executing lane's `node/lane` union name.
    pub node: String,
    /// Router address to register with at startup (`--register`): the
    /// node dials it, announces its own listen address, and the router
    /// dials back to adopt the node's lanes into its fleet. `None` (the
    /// default) serves standalone.
    pub register: Option<String>,
}

/// Reply channel of one in-flight request, keyed by task id; replies
/// travel as `(id, json_line)` so pipelined writers can retire the
/// right in-flight slot. Entries are removed by the completion callback
/// (or the shutdown drain) — a client that disconnected first just
/// makes the send a no-op, it can never wedge the dispatcher.
type PendingMap = Arc<Mutex<HashMap<u64, mpsc::Sender<(u64, String)>>>>;

impl TcpServerConfig {
    /// Build a server config from an artifact store: vocab and
    /// truncation limits come from the manifest, `phi` from the primary
    /// lane's model variant.
    pub fn from_store(
        store: &ArtifactStore,
        estimator: Estimator,
        lanes: LaneSet,
        params: SchedParams,
        pipeline_depth: usize,
    ) -> Result<TcpServerConfig> {
        let primary_model = lanes.spec(lanes.primary()).model.clone();
        Ok(TcpServerConfig {
            vocab: store.vocab.clone(),
            estimator,
            max_input_len: store.manifest.max_input_len,
            phi: store.manifest.model(&primary_model)?.phi,
            params,
            lanes,
            pipeline_depth,
            reply_timeout: Duration::from_secs(120),
            node: "local".to_string(),
            register: None,
        })
    }
}

/// Serve forever on `addr` (e.g. "127.0.0.1:7490"), over the config's
/// lane fleet, with per-lane executors built by `factory` (real PJRT
/// sessions of each lane's model variant, or the modeled-latency
/// executor for a backend-free serving smoke).
pub fn serve_tcp(
    cfg: TcpServerConfig,
    factory: ExecutorFactory,
    policy: Box<dyn Policy>,
    addr: &str,
) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    eprintln!(
        "rtlm tcp server on {addr} (lanes={}, policy={}, pipeline={})",
        cfg.lanes
            .iter()
            .map(|l| format!("{}:{}", l.name, l.model))
            .collect::<Vec<_>>()
            .join(","),
        policy.name(),
        cfg.pipeline_depth
    );
    serve_tcp_on(listener, cfg, factory, policy)
}

/// Serve on an already-bound listener (tests bind port 0 and read the
/// ephemeral address back before calling this). Returns when the engine
/// stops: a lane failure is fatal to the serving process — every
/// still-pending request is failed with an id-tagged error reply first,
/// so no client is left hanging until its timeout.
pub fn serve_tcp_on(
    listener: TcpListener,
    cfg: TcpServerConfig,
    factory: ExecutorFactory,
    policy: Box<dyn Policy>,
) -> Result<()> {
    serve_tcp_with(listener, cfg, factory, policy, |_| {})
}

/// [`serve_tcp_on`] with a hook that observes the engine's
/// [`ArrivalHandle`] once every lane is up, before the first connection
/// is accepted — the router uses it to hand the handle to its heartbeat
/// monitors so they can retire a node's lanes from outside the lane
/// workers.
pub fn serve_tcp_with(
    listener: TcpListener,
    cfg: TcpServerConfig,
    factory: ExecutorFactory,
    mut policy: Box<dyn Policy>,
    on_ready: impl FnOnce(&ArrivalHandle),
) -> Result<()> {
    let (mut backend, arrivals) = ThreadedBackend::start_stream(factory, &cfg.lanes, &cfg.params)?;
    let pending: PendingMap = Arc::new(Mutex::new(HashMap::new()));
    let next_id = Arc::new(AtomicU64::new(0));
    let listen_addr = listener.local_addr().context("reading listen address")?;
    on_ready(&arrivals);

    // acceptor thread: connection handlers only touch Send-safe state
    {
        let cfg = cfg.clone();
        let pending = pending.clone();
        let arrivals = arrivals.clone();
        thread::spawn(move || {
            for stream in listener.incoming().flatten() {
                // per-line request/reply traffic: never let Nagle hold
                // a reply back behind a ~40ms delayed-ACK window
                let _ = stream.set_nodelay(true);
                let cfg = cfg.clone();
                let arrivals = arrivals.clone();
                let pending = pending.clone();
                let next_id = next_id.clone();
                thread::spawn(move || {
                    if let Err(e) = handle_any_conn(stream, &cfg, &arrivals, &pending, &next_id) {
                        eprintln!("connection error: {e:#}");
                    }
                });
            }
        });
    }

    // node mode: announce this server to its router, which dials back
    // into the acceptor above to adopt our lanes — so registration must
    // come after the accept loop is live
    if let Some(router) = cfg.register.clone() {
        register_with_router(&router, &cfg, listen_addr)?;
    }

    // dispatcher: the one shared engine loop, replies streamed from the
    // completion callback as batches finish
    let vocab = cfg.vocab.clone();
    let lane_names = cfg.lanes.names();
    let node_name = cfg.node.clone();
    let reply_map = pending.clone();
    let mut on_complete = move |o: &TaskOutcome, output: &[i32]| {
        let Some(reply_tx) = reply_map.lock().unwrap().remove(&o.id) else {
            return;
        };
        if o.shed {
            // dropped by overload admission control: id-tagged error so
            // the client can tell load shedding from a real failure
            let _ = reply_tx.send((o.id, error_reply(o.id, "shed")));
            return;
        }
        let lane = lane_names
            .get(o.lane.index())
            .cloned()
            .unwrap_or_else(|| o.lane.to_string());
        // a router's union lanes are named `node/lane`: the node tag is
        // the prefix; a plain server's bare lane names tag its own name
        let node = match lane.split_once('/') {
            Some((node, _)) => node.to_string(),
            None => node_name.clone(),
        };
        let reply = obj(vec![
            ("id", Json::Num(o.id as f64)),
            ("tokens", Json::Num(output.len() as f64)),
            ("text", Json::Str(vocab.decode(output))),
            (
                "token_ids",
                Json::Arr(output.iter().map(|&t| Json::Num(t as f64)).collect()),
            ),
            ("response_ms", Json::Num((o.completion - o.arrival) * 1e3)),
            ("ttft_ms", Json::Num(o.ttft() * 1e3)),
            ("infer_ms", Json::Num(o.infer_secs * 1e3)),
            ("lane", Json::Str(lane)),
            ("node", Json::Str(node)),
        ]);
        let _ = reply_tx.send((o.id, reply.to_string()));
    };
    let result = run_engine_stream(
        &mut backend,
        &mut *policy,
        &cfg.params,
        ArrivalSource::Stream,
        Some(&mut on_complete),
    );

    // tear the backend down first — after finish() the event channel is
    // gone, so a handler racing this shutdown has its inject() fail and
    // replies "server shutting down" itself — then fail everything that
    // registered before the channel closed, with its id attached
    backend.finish();
    for (id, reply_tx) in pending.lock().unwrap().drain() {
        let _ = reply_tx.send((id, error_reply(id, "execution failed")));
    }
    result.map(|_| ())
}

fn error_reply(id: u64, msg: &str) -> String {
    obj(vec![("id", Json::Num(id as f64)), ("error", Json::Str(msg.to_string()))]).to_string()
}

/// Peek one buffered byte to tell a framed rtlm peer (the router) from
/// a text-line chat client, then run the matching handler.
fn handle_any_conn(
    stream: TcpStream,
    cfg: &TcpServerConfig,
    arrivals: &ArrivalHandle,
    pending: &PendingMap,
    next_id: &AtomicU64,
) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone().context("cloning connection")?);
    // the peek buffers socket bytes into `reader`, so both handlers
    // must keep reading through it — a fresh BufReader would lose them
    if wire::is_framed_peer(&mut reader)? {
        handle_framed_conn(stream, reader, cfg, arrivals, pending, next_id)
    } else {
        handle_conn(stream, reader, cfg, arrivals, pending, next_id)
    }
}

/// The lane table this node gossips to routers: everything the router
/// needs to adopt each lane into its union fleet (`lanes` frame reply
/// to `hello`). `queue` is the node's current in-flight request count —
/// a liveness-cheap load signal, not a scheduling contract.
fn lane_table_frame(cfg: &TcpServerConfig, pending: &PendingMap) -> Json {
    let lanes: Vec<Json> = cfg
        .lanes
        .iter()
        .map(|l| {
            let slots = (cfg.params.mode == SchedMode::Step
                && l.kind == LaneKind::Accelerator)
                .then(|| cfg.params.slots_for(l.batch_size.unwrap_or(cfg.params.batch_size)));
            obj(vec![
                ("name", Json::Str(l.name.clone())),
                ("kind", Json::Str(l.kind.label().to_string())),
                ("model", Json::Str(l.model.clone())),
                ("batch_size", l.batch_size.map(|b| Json::Num(b as f64)).unwrap_or(Json::Null)),
                ("workers", l.workers.map(|w| Json::Num(w as f64)).unwrap_or(Json::Null)),
                ("slots", slots.map(|s| Json::Num(s as f64)).unwrap_or(Json::Null)),
                ("admit", Json::Str(l.admission.spec())),
                ("xi", l.xi.map(Json::Num).unwrap_or(Json::Null)),
                ("lambda", l.lambda.map(Json::Num).unwrap_or(Json::Null)),
            ])
        })
        .collect();
    wire::frame(
        "lanes",
        vec![
            ("node", Json::Str(cfg.node.clone())),
            ("queue", Json::Num(pending.lock().unwrap().len() as f64)),
            ("lanes", Json::Arr(lanes)),
        ],
    )
}

/// Dial the router, announce this node's name and listen address, and
/// wait for its `ok`. The router dials back into our accept loop (a
/// framed `hello`) to gossip the lane table — that part is just the
/// ordinary framed-peer path.
fn register_with_router(
    router: &str,
    cfg: &TcpServerConfig,
    listen_addr: std::net::SocketAddr,
) -> Result<()> {
    // an all-zeroes bind address is not dialable; advertise loopback
    // (the fleet is single-machine — see DESIGN.md "Distributed fleet")
    let advertised = if listen_addr.ip().is_unspecified() {
        format!("127.0.0.1:{}", listen_addr.port())
    } else {
        listen_addr.to_string()
    };
    let stream = TcpStream::connect(router)
        .with_context(|| format!("registering with router {router}"))?;
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    let mut writer = stream.try_clone()?;
    wire::write_magic(&mut writer)?;
    wire::write_frame(
        &mut writer,
        &wire::frame(
            "register",
            vec![
                ("node", Json::Str(cfg.node.clone())),
                ("addr", Json::Str(advertised)),
            ],
        ),
    )?;
    let mut reader = BufReader::new(stream);
    wire::read_magic(&mut reader)?;
    let reply = wire::read_frame(&mut reader)?
        .ok_or_else(|| anyhow!("router {router} closed the registration connection"))?;
    match wire::frame_type(&reply) {
        "ok" => {
            eprintln!("registered with router {router} as node '{}'", cfg.node);
            Ok(())
        }
        "error" => bail!(
            "router {router} rejected registration: {}",
            reply.get("error").as_str().unwrap_or("unknown error")
        ),
        other => bail!("router {router} sent unexpected '{other}' to registration"),
    }
}

/// Build a task from a router `submit` frame. The router scored
/// uncertainty once at admission and ships the numbers; this node must
/// *not* re-score — it only tokenizes the prompt for its own executors.
/// Re-admission through this node's policy uses the same predicates the
/// router gossiped, so both hops route the task identically.
fn task_from_submit(msg: &Json, cfg: &TcpServerConfig, id: u64, now: f64) -> Result<Task> {
    let text = msg.need_str("text").context("submit frame")?.to_string();
    let u = msg.need_f64("u").context("submit frame")?;
    let true_len = msg.need_f64("true_len").context("submit frame")? as usize;
    let input_len = msg.need_f64("input_len").context("submit frame")? as usize;
    let pp_offset = msg.get("pp_offset").as_f64().unwrap_or(0.0);
    let utype = msg.get("utype").as_str().unwrap_or("interactive").to_string();
    let malicious = msg.get("malicious").as_bool().unwrap_or(false);
    let mut prompt = cfg.vocab.encode(&text, Some(cfg.max_input_len));
    if prompt.is_empty() {
        prompt.push(crate::textgen::vocab::BOS_ID);
    }
    Ok(Task {
        id,
        text,
        prompt,
        arrival: now,
        priority_point: now + pp_offset,
        uncertainty: u,
        true_len: true_len.max(1),
        input_len,
        utype,
        malicious,
        deferrals: 0,
        slo: crate::scheduler::SloClass::Standard,
    })
}

/// Serve one framed peer (the router): `hello` gossips the lane table,
/// `ping` answers `pong`, `submit` injects a pre-scored task whose
/// completion comes back as an id-tagged `done` frame — out of order,
/// exactly like the pipelined line protocol. Any wire error (garbage,
/// truncated frame, disconnect) cleans up this connection's pending
/// entries and closes; it can never wedge the dispatcher.
fn handle_framed_conn(
    stream: TcpStream,
    mut reader: BufReader<TcpStream>,
    cfg: &TcpServerConfig,
    arrivals: &ArrivalHandle,
    pending: &PendingMap,
    next_id: &AtomicU64,
) -> Result<()> {
    let peer = stream.peer_addr()?;
    wire::read_magic(&mut reader)?;
    let writer = Arc::new(Mutex::new(stream));
    wire::write_magic(&mut *writer.lock().unwrap())?;

    // Tasks get fresh local ids (the engine's id space) mapped back to
    // the router's ids on reply; `owned` holds the mapping exactly
    // while a reply is still owed, so disconnect cleanup knows which
    // pending entries are this connection's.
    let owned: Arc<Mutex<HashMap<u64, u64>>> = Arc::new(Mutex::new(HashMap::new()));
    let (reply_tx, reply_rx) = mpsc::channel::<(u64, String)>();

    // forwarder: completion-callback replies -> `done` frames, router
    // ids restored; exits when the reader drops its sender and the
    // pending map holds no more entries pointing here
    let fwd_writer = writer.clone();
    let fwd_owned = owned.clone();
    let forwarder = thread::spawn(move || {
        while let Ok((local_id, reply)) = reply_rx.recv() {
            let Some(router_id) = fwd_owned.lock().unwrap().remove(&local_id) else {
                continue;
            };
            let Ok(mut msg) = Json::parse(&reply) else { continue };
            if let Json::Obj(ref mut map) = msg {
                map.insert("type".to_string(), Json::Str("done".to_string()));
                map.insert("id".to_string(), Json::Num(router_id as f64));
            }
            if wire::write_frame(&mut *fwd_writer.lock().unwrap(), &msg).is_err() {
                return; // router gone; late completions degrade to no-ops
            }
        }
    });

    let result = (|| -> Result<()> {
        loop {
            let Some(msg) = wire::read_frame(&mut reader)? else {
                return Ok(()); // clean EOF between frames
            };
            match wire::frame_type(&msg) {
                "hello" => {
                    let table = lane_table_frame(cfg, pending);
                    wire::write_frame(&mut *writer.lock().unwrap(), &table)?;
                }
                "ping" => {
                    let pong = wire::frame(
                        "pong",
                        vec![
                            ("seq", msg.get("seq").clone()),
                            ("node", Json::Str(cfg.node.clone())),
                        ],
                    );
                    wire::write_frame(&mut *writer.lock().unwrap(), &pong)?;
                }
                "submit" => {
                    let router_id = msg.need_f64("id").context("submit frame")? as u64;
                    let local_id = next_id.fetch_add(1, Ordering::Relaxed);
                    let task = task_from_submit(&msg, cfg, local_id, arrivals.now())?;
                    // same ordering as the line handlers: register the
                    // reply slot before injecting
                    owned.lock().unwrap().insert(local_id, router_id);
                    pending.lock().unwrap().insert(local_id, reply_tx.clone());
                    if arrivals.inject(task).is_err() {
                        pending.lock().unwrap().remove(&local_id);
                        owned.lock().unwrap().remove(&local_id);
                        let gone = wire::frame(
                            "done",
                            vec![
                                ("id", Json::Num(router_id as f64)),
                                ("error", Json::Str("server shutting down".to_string())),
                            ],
                        );
                        wire::write_frame(&mut *writer.lock().unwrap(), &gone)?;
                        return Ok(());
                    }
                }
                "register" => {
                    // dynamic registration happens in the router's
                    // gather phase, before its fleet is built — a
                    // register frame reaching a running server is late
                    let err = wire::frame(
                        "error",
                        vec![("error", Json::Str("fleet already running".to_string()))],
                    );
                    wire::write_frame(&mut *writer.lock().unwrap(), &err)?;
                    bail!("late registration attempt from {peer}");
                }
                other => bail!("unexpected '{other}' frame from framed peer {peer}"),
            }
        }
    })();

    // disconnect/error: unregister every reply still owed to this
    // router so completions degrade to no-ops instead of dangling
    {
        let mut map = pending.lock().unwrap();
        for (local_id, _) in owned.lock().unwrap().drain() {
            map.remove(&local_id);
        }
    }
    drop(reply_tx);
    let _ = forwarder.join();
    result
}

/// Score one request line into a task stamped on the engine clock.
/// Scoring runs through the interned fast path against the caller's
/// per-connection scratch, so a connection's steady-state request flow
/// does not allocate in feature extraction.
fn build_task(
    text: String,
    id: u64,
    cfg: &TcpServerConfig,
    now: f64,
    scratch: &mut ScoreScratch,
) -> Result<Task> {
    let (u, feats) = cfg.estimator.score_with_features_scratch(&text, scratch)?;
    let input_len = feats[feats.len() - 1] as usize;
    let mut prompt = cfg.vocab.encode(&text, Some(cfg.max_input_len));
    if prompt.is_empty() {
        prompt.push(crate::textgen::vocab::BOS_ID);
    }
    Ok(Task {
        id,
        text,
        prompt,
        arrival: now,
        priority_point: now + 2.0 + cfg.phi * input_len as f64,
        uncertainty: u,
        // interactive requests have no oracle: serve the predicted length
        true_len: (u.round() as usize).clamp(4, 96),
        input_len,
        utype: "interactive".into(),
        malicious: false,
        deferrals: 0,
        slo: crate::scheduler::SloClass::Standard,
    })
}

fn handle_conn(
    stream: TcpStream,
    reader: BufReader<TcpStream>,
    cfg: &TcpServerConfig,
    arrivals: &ArrivalHandle,
    pending: &PendingMap,
    next_id: &AtomicU64,
) -> Result<()> {
    if cfg.pipeline_depth > 1 {
        return handle_conn_pipelined(stream, reader, cfg, arrivals, pending, next_id);
    }
    let peer = stream.peer_addr()?;
    let mut writer = stream;
    // one scoring scratch per connection: request N reuses the buffers
    // request N-1 grew
    let mut scratch = ScoreScratch::new();
    for line in reader.lines() {
        let text = line?;
        if text.trim().is_empty() {
            continue;
        }
        let id = next_id.fetch_add(1, Ordering::Relaxed);
        let task = build_task(text, id, cfg, arrivals.now(), &mut scratch)?;
        let (reply_tx, reply_rx) = mpsc::channel();
        // register the reply slot *before* injecting: the completion
        // callback may fire before this thread runs again
        pending.lock().unwrap().insert(id, reply_tx);
        if arrivals.inject(task).is_err() {
            pending.lock().unwrap().remove(&id);
            writeln!(writer, "{}", error_reply(id, "server shutting down"))?;
            return Ok(());
        }
        match reply_rx.recv_timeout(cfg.reply_timeout) {
            Ok((_, reply)) => writeln!(writer, "{reply}")?,
            Err(_) => {
                // leave the pending entry: the task is still scheduled,
                // and the callback cleans it up whenever it completes
                writeln!(writer, "{}", error_reply(id, "timeout"))?;
                eprintln!("request {id} from {peer} timed out");
            }
        }
    }
    Ok(())
}

/// In-flight request ids of one pipelined connection, guarded by the
/// "fewer than K outstanding" condition the reader waits on. An id is
/// *in* the set exactly while a reply for it may still be written —
/// removal (by delivery or timeout) is what licenses discarding any
/// later duplicate. `writer_gone` unblocks a reader parked at the
/// window when the writer dies (client disconnected mid-stream).
struct ConnWindow {
    state: Mutex<WindowState>,
    may_send: Condvar,
}

#[derive(Default)]
struct WindowState {
    outstanding: HashSet<u64>,
    writer_gone: bool,
}

/// Bounded pipelining (K > 1): the reader admits up to K requests, the
/// writer thread streams id-tagged replies back as tasks complete —
/// out of order when lanes finish out of order.
fn handle_conn_pipelined(
    stream: TcpStream,
    reader: BufReader<TcpStream>,
    cfg: &TcpServerConfig,
    arrivals: &ArrivalHandle,
    pending: &PendingMap,
    next_id: &AtomicU64,
) -> Result<()> {
    let k = cfg.pipeline_depth;
    let peer = stream.peer_addr()?;
    let mut writer = stream;
    let (reply_tx, reply_rx) = mpsc::channel::<(u64, String)>();
    let window = Arc::new(ConnWindow {
        state: Mutex::new(WindowState::default()),
        may_send: Condvar::new(),
    });

    // Writer: drain replies as they arrive; on inactivity past the
    // reply timeout, fail every outstanding request (removed from the
    // pending map so a late completion cannot produce a duplicate
    // reply). A reply is written only while its id is still in the
    // window — removal is atomic with the decision to write, so a task
    // completing after its timeout error can never produce a second
    // reply for the same id. Exits when every sender is gone (the
    // reader dropped its handle and no pending entry still points
    // here), and always marks `writer_gone` on the way out so a reader
    // parked at a full window wakes up instead of leaking.
    let writer_window = window.clone();
    let writer_pending = pending.clone();
    let writer_timeout = cfg.reply_timeout;
    let writer_thread = thread::spawn(move || {
        // returns false once the client socket is gone
        let deliver = |writer: &mut TcpStream, id: u64, reply: &str| -> bool {
            let known = {
                let mut state = writer_window.state.lock().unwrap();
                let known = state.outstanding.remove(&id);
                writer_window.may_send.notify_all();
                known
            };
            // an id no longer in the window was already answered
            // (timed out) — discard the late reply
            !known || writeln!(writer, "{reply}").is_ok()
        };
        loop {
            match reply_rx.recv_timeout(writer_timeout) {
                Ok((id, reply)) => {
                    if !deliver(&mut writer, id, &reply) {
                        break; // client gone; completions degrade to no-ops
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    // snapshot who is overdue; ids admitted from here on
                    // are NOT part of this timeout round
                    let mut ids: Vec<u64> = {
                        let state = writer_window.state.lock().unwrap();
                        state.outstanding.iter().copied().collect()
                    };
                    if ids.is_empty() {
                        continue; // idle connection, keep waiting
                    }
                    // unregister first so late completions cannot race a
                    // duplicate reply in behind the timeout errors...
                    {
                        let mut map = writer_pending.lock().unwrap();
                        for id in &ids {
                            map.remove(id);
                        }
                    }
                    // ...but deliver anything that completed while we
                    // were deciding — those are answered, not overdue
                    let mut dead = false;
                    while let Ok((id, reply)) = reply_rx.try_recv() {
                        if !deliver(&mut writer, id, &reply) {
                            dead = true;
                            break;
                        }
                        ids.retain(|&i| i != id);
                    }
                    if dead {
                        break;
                    }
                    // fail the true remainder, retiring their window
                    // slots as we go
                    let overdue: Vec<u64> = {
                        let mut state = writer_window.state.lock().unwrap();
                        ids.retain(|id| state.outstanding.remove(id));
                        writer_window.may_send.notify_all();
                        ids
                    };
                    if overdue.is_empty() {
                        continue;
                    }
                    eprintln!("{} pipelined request(s) timed out", overdue.len());
                    if overdue
                        .into_iter()
                        .any(|id| writeln!(writer, "{}", error_reply(id, "timeout")).is_err())
                    {
                        break;
                    }
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        let mut state = writer_window.state.lock().unwrap();
        state.writer_gone = true;
        writer_window.may_send.notify_all();
    });

    let result = (|| -> Result<()> {
        let mut scratch = ScoreScratch::new();
        for line in reader.lines() {
            let text = line?;
            if text.trim().is_empty() {
                continue;
            }
            let id = next_id.fetch_add(1, Ordering::Relaxed);
            let task = build_task(text, id, cfg, arrivals.now(), &mut scratch)?;
            {
                let mut state = window.state.lock().unwrap();
                while state.outstanding.len() >= k && !state.writer_gone {
                    state = window.may_send.wait(state).unwrap();
                }
                if state.writer_gone {
                    // client socket already failed; stop reading
                    return Ok(());
                }
                state.outstanding.insert(id);
            }
            pending.lock().unwrap().insert(id, reply_tx.clone());
            if arrivals.inject(task).is_err() {
                pending.lock().unwrap().remove(&id);
                // route the shutdown error through the writer so it
                // interleaves cleanly with in-flight replies
                let _ = reply_tx.send((id, error_reply(id, "server shutting down")));
                eprintln!("connection from {peer}: server shutting down");
                return Ok(());
            }
        }
        Ok(())
    })();
    // EOF/error: our sender drops; the writer drains replies still owed
    // by the pending map entries and exits on disconnect.
    drop(reply_tx);
    let _ = writer_thread.join();
    result
}
