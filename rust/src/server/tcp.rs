//! Minimal line-protocol TCP front-end (the "chatbot server" face of
//! RT-LM).
//!
//! Protocol: one request per line — the raw utterance (empty lines are
//! ignored). The server replies with one JSON line:
//! `{"id":..,"tokens":..,"text":..,"response_ms":..,"lane":..}`, or
//! `{"id":..,"error":..}` — every reply carries the request `id`, so a
//! client pipelining multiple lines on one connection can correlate
//! failures too.
//!
//! There is no dispatch loop here. Connection handlers tokenize + score
//! (pure rust, `Send`) and feed tasks through the engine's
//! [`ArrivalHandle`]; the shared dispatcher core
//! ([`run_engine_stream`] over a [`ThreadedBackend`], the exact loop
//! the simulator and `rtlm serve` drive) owns admission, ξ-forcing,
//! lane gating and accounting, with batches executing on per-lane
//! worker threads — both lanes genuinely concurrent — and replies
//! flowing back from the per-task completion callback.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::config::SchedParams;
use crate::engine::{run_engine_stream, ArrivalHandle, ArrivalSource, ThreadedBackend};
use crate::executor::ExecutorFactory;
use crate::runtime::ArtifactStore;
use crate::scheduler::{Policy, Task};
use crate::sim::results::TaskOutcome;
use crate::textgen::Vocab;
use crate::uncertainty::Estimator;
use crate::util::json::{obj, Json};

/// Everything a connection handler needs to turn a text line into a
/// scored task and wait for its reply. Built from an [`ArtifactStore`]
/// by [`serve_tcp`]; tests construct it directly from stubs.
#[derive(Clone)]
pub struct TcpServerConfig {
    pub vocab: Arc<Vocab>,
    pub estimator: Estimator,
    /// Prompts are truncated to this many tokens.
    pub max_input_len: usize,
    /// The serving model's input-tokens -> priority-point coefficient.
    pub phi: f64,
    pub params: SchedParams,
    /// How long a connection handler waits for its reply before sending
    /// an id-tagged timeout error (the task itself stays scheduled).
    pub reply_timeout: Duration,
}

/// Reply channel of one in-flight request, keyed by task id. Entries
/// are removed by the completion callback (or the shutdown drain) — a
/// client that disconnected first just makes the send a no-op, it can
/// never wedge the dispatcher.
type PendingMap = Arc<Mutex<HashMap<u64, mpsc::Sender<String>>>>;

/// Serve forever on `addr` (e.g. "127.0.0.1:7490"), with per-lane
/// executors built by `factory` (real PJRT sessions, or the
/// modeled-latency executor for a backend-free serving smoke).
pub fn serve_tcp(
    store: Arc<ArtifactStore>,
    model: &str,
    factory: ExecutorFactory,
    estimator: Estimator,
    policy: Box<dyn Policy>,
    params: SchedParams,
    addr: &str,
) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    eprintln!(
        "rtlm tcp server on {addr} (model={model}, policy={})",
        policy.name()
    );
    let cfg = TcpServerConfig {
        vocab: store.vocab.clone(),
        estimator,
        max_input_len: store.manifest.max_input_len,
        phi: store.manifest.model(model)?.phi,
        params,
        reply_timeout: Duration::from_secs(120),
    };
    serve_tcp_on(listener, cfg, factory, policy)
}

/// Serve on an already-bound listener (tests bind port 0 and read the
/// ephemeral address back before calling this). Returns when the engine
/// stops: a lane failure is fatal to the serving process — every
/// still-pending request is failed with an id-tagged error reply first,
/// so no client is left hanging until its timeout.
pub fn serve_tcp_on(
    listener: TcpListener,
    cfg: TcpServerConfig,
    factory: ExecutorFactory,
    mut policy: Box<dyn Policy>,
) -> Result<()> {
    let (mut backend, arrivals) = ThreadedBackend::start_stream(factory)?;
    let pending: PendingMap = Arc::new(Mutex::new(HashMap::new()));
    let next_id = Arc::new(AtomicU64::new(0));

    // acceptor thread: connection handlers only touch Send-safe state
    {
        let cfg = cfg.clone();
        let pending = pending.clone();
        thread::spawn(move || {
            for stream in listener.incoming().flatten() {
                let cfg = cfg.clone();
                let arrivals = arrivals.clone();
                let pending = pending.clone();
                let next_id = next_id.clone();
                thread::spawn(move || {
                    if let Err(e) = handle_conn(stream, &cfg, &arrivals, &pending, &next_id) {
                        eprintln!("connection error: {e:#}");
                    }
                });
            }
        });
    }

    // dispatcher: the one shared engine loop, replies streamed from the
    // completion callback as batches finish
    let vocab = cfg.vocab.clone();
    let reply_map = pending.clone();
    let mut on_complete = move |o: &TaskOutcome, output: &[i32]| {
        let Some(reply_tx) = reply_map.lock().unwrap().remove(&o.id) else {
            return;
        };
        let reply = obj(vec![
            ("id", Json::Num(o.id as f64)),
            ("tokens", Json::Num(output.len() as f64)),
            ("text", Json::Str(vocab.decode(output))),
            ("response_ms", Json::Num((o.completion - o.arrival) * 1e3)),
            ("lane", Json::Str(format!("{:?}", o.lane))),
        ]);
        let _ = reply_tx.send(reply.to_string());
    };
    let result = run_engine_stream(
        &mut backend,
        &mut *policy,
        &cfg.params,
        ArrivalSource::Stream,
        Some(&mut on_complete),
    );

    // tear the backend down first — after finish() the event channel is
    // gone, so a handler racing this shutdown has its inject() fail and
    // replies "server shutting down" itself — then fail everything that
    // registered before the channel closed, with its id attached
    backend.finish();
    for (id, reply_tx) in pending.lock().unwrap().drain() {
        let _ = reply_tx.send(error_reply(id, "execution failed"));
    }
    result.map(|_| ())
}

fn error_reply(id: u64, msg: &str) -> String {
    obj(vec![("id", Json::Num(id as f64)), ("error", Json::Str(msg.to_string()))]).to_string()
}

fn handle_conn(
    stream: TcpStream,
    cfg: &TcpServerConfig,
    arrivals: &ArrivalHandle,
    pending: &PendingMap,
    next_id: &AtomicU64,
) -> Result<()> {
    let peer = stream.peer_addr()?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let text = line?;
        if text.trim().is_empty() {
            continue;
        }
        let id = next_id.fetch_add(1, Ordering::Relaxed);
        let (u, feats) = cfg.estimator.score_with_features(&text)?;
        let input_len = feats[feats.len() - 1] as usize;
        let mut prompt = cfg.vocab.encode(&text, Some(cfg.max_input_len));
        if prompt.is_empty() {
            prompt.push(crate::textgen::vocab::BOS_ID);
        }
        let now = arrivals.now();
        let task = Task {
            id,
            text,
            prompt,
            arrival: now,
            priority_point: now + 2.0 + cfg.phi * input_len as f64,
            uncertainty: u,
            // interactive requests have no oracle: serve the predicted length
            true_len: (u.round() as usize).clamp(4, 96),
            input_len,
            utype: "interactive".into(),
            malicious: false,
            deferrals: 0,
        };
        let (reply_tx, reply_rx) = mpsc::channel();
        // register the reply slot *before* injecting: the completion
        // callback may fire before this thread runs again
        pending.lock().unwrap().insert(id, reply_tx);
        if arrivals.inject(task).is_err() {
            pending.lock().unwrap().remove(&id);
            writeln!(writer, "{}", error_reply(id, "server shutting down"))?;
            return Ok(());
        }
        match reply_rx.recv_timeout(cfg.reply_timeout) {
            Ok(reply) => writeln!(writer, "{reply}")?,
            Err(_) => {
                // leave the pending entry: the task is still scheduled,
                // and the callback cleans it up whenever it completes
                writeln!(writer, "{}", error_reply(id, "timeout"))?;
                eprintln!("request {id} from {peer} timed out");
            }
        }
    }
    Ok(())
}
