//! Minimal line-protocol TCP front-end (the "chatbot server" face of
//! RT-LM).
//!
//! Protocol: one request per line — the raw utterance. The server
//! replies with one JSON line: `{"id":..,"tokens":..,"text":..,
//! "response_ms":..,"lane":..}`. Requests from all connections funnel
//! into the shared RT-LM scheduler, so concurrent clients exercise
//! batching and prioritisation exactly like the benchmark workloads.
//!
//! PJRT handles are not `Send`, so the batch executor lives on the
//! dispatcher thread and batches execute inline; connection threads only
//! tokenize/score (pure rust, Send). Any [`BatchExecutor`] works — real
//! PJRT sessions, or the modeled-latency executor for a backend-free
//! serving smoke.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::SchedParams;
use crate::executor::BatchExecutor;
use crate::runtime::ArtifactStore;
use crate::scheduler::{Lane, Policy, Task};
use crate::textgen::Vocab;
use crate::uncertainty::Estimator;
use crate::util::json::{obj, Json};

struct Pending {
    reply_tx: mpsc::Sender<String>,
    submitted: Instant,
}

/// Serve forever on `addr` (e.g. "127.0.0.1:7490"), executing batches
/// through `executor`.
pub fn serve_tcp(
    store: Arc<ArtifactStore>,
    model: &str,
    mut executor: Box<dyn BatchExecutor>,
    estimator: Estimator,
    mut policy: Box<dyn Policy>,
    params: SchedParams,
    addr: &str,
) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    eprintln!(
        "rtlm tcp server on {addr} (model={model}, policy={})",
        policy.name()
    );
    let vocab = store.vocab.clone();
    let max_input_len = store.manifest.max_input_len;
    let phi = store.manifest.model(model)?.phi;

    let (req_tx, req_rx) = mpsc::channel::<(Task, Pending)>();
    let next_id = Arc::new(AtomicU64::new(0));
    let epoch = Instant::now();

    // acceptor thread: connection handlers only touch Send-safe state
    {
        let vocab = vocab.clone();
        thread::spawn(move || {
            for stream in listener.incoming().flatten() {
                let req_tx = req_tx.clone();
                let estimator = estimator.clone();
                let next_id = next_id.clone();
                let vocab = vocab.clone();
                thread::spawn(move || {
                    if let Err(e) = handle_conn(
                        stream,
                        req_tx,
                        estimator,
                        next_id,
                        vocab,
                        max_input_len,
                        phi,
                        epoch,
                    ) {
                        eprintln!("connection error: {e:#}");
                    }
                });
            }
        });
    }

    // dispatcher loop: owns the policy and runs lanes inline. Like the
    // engine core it sleeps until the next request or the oldest queued
    // request's ξ expiry — no fixed-interval polling — and `oldest` is
    // recomputed from what is actually still queued after each dispatch
    // round, so one slow client cannot latch `force` permanently on and
    // degrade the server to batch-1 dispatch.
    let mut pending: std::collections::HashMap<u64, Pending> = std::collections::HashMap::new();
    let mut oldest: Option<Instant> = None;
    loop {
        let received = match oldest {
            // idle: block until the next request arrives
            None => match req_rx.recv() {
                Ok(pair) => Some(pair),
                Err(_) => return Ok(()),
            },
            // requests queued: wake at the oldest one's ξ expiry
            Some(t) => {
                let remaining = (params.xi - t.elapsed().as_secs_f64()).max(0.0);
                match req_rx.recv_timeout(Duration::from_secs_f64(remaining)) {
                    Ok(pair) => Some(pair),
                    Err(mpsc::RecvTimeoutError::Timeout) => None,
                    Err(mpsc::RecvTimeoutError::Disconnected) => return Ok(()),
                }
            }
        };
        if let Some((task, info)) = received {
            oldest = Some(oldest.unwrap_or(info.submitted).min(info.submitted));
            pending.insert(task.id, info);
            policy.push(task);
            // admit everything already queued before dispatching
            while let Ok((task, info)) = req_rx.try_recv() {
                oldest = Some(oldest.unwrap_or(info.submitted).min(info.submitted));
                pending.insert(task.id, info);
                policy.push(task);
            }
        }
        let force = oldest
            .map(|t| t.elapsed().as_secs_f64() >= params.xi)
            .unwrap_or(false);
        for lane in Lane::ALL {
            let now = epoch.elapsed().as_secs_f64();
            let Some(batch) = policy.pop_batch(lane, now, force) else { continue };
            match executor.execute(&batch) {
                Ok(reports) => {
                    for rep in reports {
                        for (i, &id) in rep.task_ids.iter().enumerate() {
                            if let Some(info) = pending.remove(&id) {
                                let text = vocab.decode(&rep.outputs[i]);
                                let ms = info.submitted.elapsed().as_secs_f64() * 1e3;
                                let reply = obj(vec![
                                    ("id", Json::Num(id as f64)),
                                    ("tokens", Json::Num(rep.outputs[i].len() as f64)),
                                    ("text", Json::Str(text)),
                                    ("response_ms", Json::Num(ms)),
                                    ("lane", Json::Str(format!("{:?}", rep.lane))),
                                ]);
                                let _ = info.reply_tx.send(reply.to_string());
                            }
                        }
                    }
                }
                Err(e) => {
                    eprintln!("lane error: {e:#}");
                    // fail the batch's requests instead of leaving them
                    // pending forever (their expired ξ would otherwise
                    // pin the wait timeout at zero)
                    for t in &batch.tasks {
                        if let Some(info) = pending.remove(&t.id) {
                            let _ = info
                                .reply_tx
                                .send("{\"error\":\"execution failed\"}".to_string());
                        }
                    }
                }
            }
        }
        // ξ tracks the oldest *still-queued* request, not a high-water mark
        oldest = pending.values().map(|p| p.submitted).min();
    }
}

#[allow(clippy::too_many_arguments)]
fn handle_conn(
    stream: TcpStream,
    req_tx: mpsc::Sender<(Task, Pending)>,
    estimator: Estimator,
    next_id: Arc<AtomicU64>,
    vocab: Arc<Vocab>,
    max_input_len: usize,
    phi: f64,
    epoch: Instant,
) -> Result<()> {
    let peer = stream.peer_addr()?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let text = line?;
        if text.trim().is_empty() {
            continue;
        }
        let id = next_id.fetch_add(1, Ordering::Relaxed);
        let (u, feats) = estimator.score_with_features(&text)?;
        let input_len = feats[feats.len() - 1] as usize;
        let mut prompt = vocab.encode(&text, Some(max_input_len));
        if prompt.is_empty() {
            prompt.push(crate::textgen::vocab::BOS_ID);
        }
        let now = epoch.elapsed().as_secs_f64();
        let task = Task {
            id,
            text: text.clone(),
            prompt,
            arrival: now,
            priority_point: now + 2.0 + phi * input_len as f64,
            uncertainty: u,
            // interactive requests have no oracle: serve the predicted length
            true_len: (u.round() as usize).clamp(4, 96),
            input_len,
            utype: "interactive".into(),
            malicious: false,
            deferrals: 0,
        };
        let (reply_tx, reply_rx) = mpsc::channel();
        req_tx.send((task, Pending { reply_tx, submitted: Instant::now() })).ok();
        match reply_rx.recv_timeout(Duration::from_secs(120)) {
            Ok(reply) => writeln!(writer, "{reply}")?,
            Err(_) => {
                writeln!(writer, "{{\"error\":\"timeout\"}}")?;
                eprintln!("request from {peer} timed out");
            }
        }
    }
    Ok(())
}
