//! Minimal line-protocol TCP front-end (the "chatbot server" face of
//! RT-LM).
//!
//! Protocol: one request per line — the raw utterance (empty lines are
//! ignored). The server replies with one JSON line:
//! `{"id":..,"tokens":..,"text":..,"response_ms":..,"ttft_ms":..,"lane":..}`, or
//! `{"id":..,"error":..}` — every reply carries the request `id`, so a
//! client pipelining multiple lines on one connection can correlate
//! failures too. `lane` is the configured lane name the task executed
//! on (`gpu` / `cpu` on the default fleet).
//!
//! There is no dispatch loop here. Connection handlers tokenize + score
//! (pure rust, `Send`) and feed tasks through the engine's
//! [`ArrivalHandle`]; the shared dispatcher core
//! ([`run_engine_stream`] over a [`ThreadedBackend`], the exact loop
//! the simulator and `rtlm serve` drive) owns admission, ξ-forcing,
//! lane gating and accounting, with batches executing on per-lane
//! worker threads — every configured lane genuinely concurrent — and
//! replies flowing back from the per-task completion callback.
//!
//! **Pipelining**: with `pipeline_depth = 1` (the default) a connection
//! serves one request at a time and replies in request order. With
//! `pipeline_depth = K > 1` a connection may have up to K requests in
//! flight; replies are written as their tasks complete — out of order,
//! correlated by `id` — and the per-request reply timeout becomes a
//! per-connection inactivity timeout (no reply for `reply_timeout` with
//! requests outstanding times out *all* outstanding requests).

use std::collections::{HashMap, HashSet};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::config::SchedParams;
use crate::engine::{run_engine_stream, ArrivalHandle, ArrivalSource, ThreadedBackend};
use crate::executor::ExecutorFactory;
use crate::runtime::ArtifactStore;
use crate::scheduler::{LaneSet, Policy, Task};
use crate::sim::results::TaskOutcome;
use crate::textgen::Vocab;
use crate::uncertainty::Estimator;
use crate::util::json::{obj, Json};

/// Everything a connection handler needs to turn a text line into a
/// scored task and wait for its reply. Built from an [`ArtifactStore`]
/// by [`serve_tcp`]; tests construct it directly from stubs.
#[derive(Clone)]
pub struct TcpServerConfig {
    /// Vocabulary used to encode prompts and decode replies.
    pub vocab: Arc<Vocab>,
    /// The uncertainty estimator requests are scored with.
    pub estimator: Estimator,
    /// Prompts are truncated to this many tokens.
    pub max_input_len: usize,
    /// The primary serving model's input-tokens -> priority-point
    /// coefficient.
    pub phi: f64,
    /// Scheduler parameters of the serving policy.
    pub params: SchedParams,
    /// The lane fleet this server schedules over; replies carry the
    /// executing lane's name.
    pub lanes: LaneSet,
    /// Max in-flight requests per connection (K). 1 = serve one request
    /// at a time, replies in request order (the historical behaviour).
    pub pipeline_depth: usize,
    /// How long a connection handler waits for its reply before sending
    /// an id-tagged timeout error (the task itself stays scheduled). In
    /// pipelined mode this is a per-connection inactivity timeout.
    pub reply_timeout: Duration,
}

/// Reply channel of one in-flight request, keyed by task id; replies
/// travel as `(id, json_line)` so pipelined writers can retire the
/// right in-flight slot. Entries are removed by the completion callback
/// (or the shutdown drain) — a client that disconnected first just
/// makes the send a no-op, it can never wedge the dispatcher.
type PendingMap = Arc<Mutex<HashMap<u64, mpsc::Sender<(u64, String)>>>>;

impl TcpServerConfig {
    /// Build a server config from an artifact store: vocab and
    /// truncation limits come from the manifest, `phi` from the primary
    /// lane's model variant.
    pub fn from_store(
        store: &ArtifactStore,
        estimator: Estimator,
        lanes: LaneSet,
        params: SchedParams,
        pipeline_depth: usize,
    ) -> Result<TcpServerConfig> {
        let primary_model = lanes.spec(lanes.primary()).model.clone();
        Ok(TcpServerConfig {
            vocab: store.vocab.clone(),
            estimator,
            max_input_len: store.manifest.max_input_len,
            phi: store.manifest.model(&primary_model)?.phi,
            params,
            lanes,
            pipeline_depth,
            reply_timeout: Duration::from_secs(120),
        })
    }
}

/// Serve forever on `addr` (e.g. "127.0.0.1:7490"), over the config's
/// lane fleet, with per-lane executors built by `factory` (real PJRT
/// sessions of each lane's model variant, or the modeled-latency
/// executor for a backend-free serving smoke).
pub fn serve_tcp(
    cfg: TcpServerConfig,
    factory: ExecutorFactory,
    policy: Box<dyn Policy>,
    addr: &str,
) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    eprintln!(
        "rtlm tcp server on {addr} (lanes={}, policy={}, pipeline={})",
        cfg.lanes
            .iter()
            .map(|l| format!("{}:{}", l.name, l.model))
            .collect::<Vec<_>>()
            .join(","),
        policy.name(),
        cfg.pipeline_depth
    );
    serve_tcp_on(listener, cfg, factory, policy)
}

/// Serve on an already-bound listener (tests bind port 0 and read the
/// ephemeral address back before calling this). Returns when the engine
/// stops: a lane failure is fatal to the serving process — every
/// still-pending request is failed with an id-tagged error reply first,
/// so no client is left hanging until its timeout.
pub fn serve_tcp_on(
    listener: TcpListener,
    cfg: TcpServerConfig,
    factory: ExecutorFactory,
    mut policy: Box<dyn Policy>,
) -> Result<()> {
    let (mut backend, arrivals) = ThreadedBackend::start_stream(factory, &cfg.lanes, &cfg.params)?;
    let pending: PendingMap = Arc::new(Mutex::new(HashMap::new()));
    let next_id = Arc::new(AtomicU64::new(0));

    // acceptor thread: connection handlers only touch Send-safe state
    {
        let cfg = cfg.clone();
        let pending = pending.clone();
        thread::spawn(move || {
            for stream in listener.incoming().flatten() {
                let cfg = cfg.clone();
                let arrivals = arrivals.clone();
                let pending = pending.clone();
                let next_id = next_id.clone();
                thread::spawn(move || {
                    if let Err(e) = handle_conn(stream, &cfg, &arrivals, &pending, &next_id) {
                        eprintln!("connection error: {e:#}");
                    }
                });
            }
        });
    }

    // dispatcher: the one shared engine loop, replies streamed from the
    // completion callback as batches finish
    let vocab = cfg.vocab.clone();
    let lane_names = cfg.lanes.names();
    let reply_map = pending.clone();
    let mut on_complete = move |o: &TaskOutcome, output: &[i32]| {
        let Some(reply_tx) = reply_map.lock().unwrap().remove(&o.id) else {
            return;
        };
        let lane = lane_names
            .get(o.lane.index())
            .cloned()
            .unwrap_or_else(|| o.lane.to_string());
        let reply = obj(vec![
            ("id", Json::Num(o.id as f64)),
            ("tokens", Json::Num(output.len() as f64)),
            ("text", Json::Str(vocab.decode(output))),
            ("response_ms", Json::Num((o.completion - o.arrival) * 1e3)),
            ("ttft_ms", Json::Num(o.ttft() * 1e3)),
            ("lane", Json::Str(lane)),
        ]);
        let _ = reply_tx.send((o.id, reply.to_string()));
    };
    let result = run_engine_stream(
        &mut backend,
        &mut *policy,
        &cfg.params,
        ArrivalSource::Stream,
        Some(&mut on_complete),
    );

    // tear the backend down first — after finish() the event channel is
    // gone, so a handler racing this shutdown has its inject() fail and
    // replies "server shutting down" itself — then fail everything that
    // registered before the channel closed, with its id attached
    backend.finish();
    for (id, reply_tx) in pending.lock().unwrap().drain() {
        let _ = reply_tx.send((id, error_reply(id, "execution failed")));
    }
    result.map(|_| ())
}

fn error_reply(id: u64, msg: &str) -> String {
    obj(vec![("id", Json::Num(id as f64)), ("error", Json::Str(msg.to_string()))]).to_string()
}

/// Score one request line into a task stamped on the engine clock.
fn build_task(text: String, id: u64, cfg: &TcpServerConfig, now: f64) -> Result<Task> {
    let (u, feats) = cfg.estimator.score_with_features(&text)?;
    let input_len = feats[feats.len() - 1] as usize;
    let mut prompt = cfg.vocab.encode(&text, Some(cfg.max_input_len));
    if prompt.is_empty() {
        prompt.push(crate::textgen::vocab::BOS_ID);
    }
    Ok(Task {
        id,
        text,
        prompt,
        arrival: now,
        priority_point: now + 2.0 + cfg.phi * input_len as f64,
        uncertainty: u,
        // interactive requests have no oracle: serve the predicted length
        true_len: (u.round() as usize).clamp(4, 96),
        input_len,
        utype: "interactive".into(),
        malicious: false,
        deferrals: 0,
    })
}

fn handle_conn(
    stream: TcpStream,
    cfg: &TcpServerConfig,
    arrivals: &ArrivalHandle,
    pending: &PendingMap,
    next_id: &AtomicU64,
) -> Result<()> {
    if cfg.pipeline_depth > 1 {
        return handle_conn_pipelined(stream, cfg, arrivals, pending, next_id);
    }
    let peer = stream.peer_addr()?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let text = line?;
        if text.trim().is_empty() {
            continue;
        }
        let id = next_id.fetch_add(1, Ordering::Relaxed);
        let task = build_task(text, id, cfg, arrivals.now())?;
        let (reply_tx, reply_rx) = mpsc::channel();
        // register the reply slot *before* injecting: the completion
        // callback may fire before this thread runs again
        pending.lock().unwrap().insert(id, reply_tx);
        if arrivals.inject(task).is_err() {
            pending.lock().unwrap().remove(&id);
            writeln!(writer, "{}", error_reply(id, "server shutting down"))?;
            return Ok(());
        }
        match reply_rx.recv_timeout(cfg.reply_timeout) {
            Ok((_, reply)) => writeln!(writer, "{reply}")?,
            Err(_) => {
                // leave the pending entry: the task is still scheduled,
                // and the callback cleans it up whenever it completes
                writeln!(writer, "{}", error_reply(id, "timeout"))?;
                eprintln!("request {id} from {peer} timed out");
            }
        }
    }
    Ok(())
}

/// In-flight request ids of one pipelined connection, guarded by the
/// "fewer than K outstanding" condition the reader waits on. An id is
/// *in* the set exactly while a reply for it may still be written —
/// removal (by delivery or timeout) is what licenses discarding any
/// later duplicate. `writer_gone` unblocks a reader parked at the
/// window when the writer dies (client disconnected mid-stream).
struct ConnWindow {
    state: Mutex<WindowState>,
    may_send: Condvar,
}

#[derive(Default)]
struct WindowState {
    outstanding: HashSet<u64>,
    writer_gone: bool,
}

/// Bounded pipelining (K > 1): the reader admits up to K requests, the
/// writer thread streams id-tagged replies back as tasks complete —
/// out of order when lanes finish out of order.
fn handle_conn_pipelined(
    stream: TcpStream,
    cfg: &TcpServerConfig,
    arrivals: &ArrivalHandle,
    pending: &PendingMap,
    next_id: &AtomicU64,
) -> Result<()> {
    let k = cfg.pipeline_depth;
    let peer = stream.peer_addr()?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    let (reply_tx, reply_rx) = mpsc::channel::<(u64, String)>();
    let window = Arc::new(ConnWindow {
        state: Mutex::new(WindowState::default()),
        may_send: Condvar::new(),
    });

    // Writer: drain replies as they arrive; on inactivity past the
    // reply timeout, fail every outstanding request (removed from the
    // pending map so a late completion cannot produce a duplicate
    // reply). A reply is written only while its id is still in the
    // window — removal is atomic with the decision to write, so a task
    // completing after its timeout error can never produce a second
    // reply for the same id. Exits when every sender is gone (the
    // reader dropped its handle and no pending entry still points
    // here), and always marks `writer_gone` on the way out so a reader
    // parked at a full window wakes up instead of leaking.
    let writer_window = window.clone();
    let writer_pending = pending.clone();
    let writer_timeout = cfg.reply_timeout;
    let writer_thread = thread::spawn(move || {
        // returns false once the client socket is gone
        let deliver = |writer: &mut TcpStream, id: u64, reply: &str| -> bool {
            let known = {
                let mut state = writer_window.state.lock().unwrap();
                let known = state.outstanding.remove(&id);
                writer_window.may_send.notify_all();
                known
            };
            // an id no longer in the window was already answered
            // (timed out) — discard the late reply
            !known || writeln!(writer, "{reply}").is_ok()
        };
        loop {
            match reply_rx.recv_timeout(writer_timeout) {
                Ok((id, reply)) => {
                    if !deliver(&mut writer, id, &reply) {
                        break; // client gone; completions degrade to no-ops
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    // snapshot who is overdue; ids admitted from here on
                    // are NOT part of this timeout round
                    let mut ids: Vec<u64> = {
                        let state = writer_window.state.lock().unwrap();
                        state.outstanding.iter().copied().collect()
                    };
                    if ids.is_empty() {
                        continue; // idle connection, keep waiting
                    }
                    // unregister first so late completions cannot race a
                    // duplicate reply in behind the timeout errors...
                    {
                        let mut map = writer_pending.lock().unwrap();
                        for id in &ids {
                            map.remove(id);
                        }
                    }
                    // ...but deliver anything that completed while we
                    // were deciding — those are answered, not overdue
                    let mut dead = false;
                    while let Ok((id, reply)) = reply_rx.try_recv() {
                        if !deliver(&mut writer, id, &reply) {
                            dead = true;
                            break;
                        }
                        ids.retain(|&i| i != id);
                    }
                    if dead {
                        break;
                    }
                    // fail the true remainder, retiring their window
                    // slots as we go
                    let overdue: Vec<u64> = {
                        let mut state = writer_window.state.lock().unwrap();
                        ids.retain(|id| state.outstanding.remove(id));
                        writer_window.may_send.notify_all();
                        ids
                    };
                    if overdue.is_empty() {
                        continue;
                    }
                    eprintln!("{} pipelined request(s) timed out", overdue.len());
                    if overdue
                        .into_iter()
                        .any(|id| writeln!(writer, "{}", error_reply(id, "timeout")).is_err())
                    {
                        break;
                    }
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        let mut state = writer_window.state.lock().unwrap();
        state.writer_gone = true;
        writer_window.may_send.notify_all();
    });

    let result = (|| -> Result<()> {
        for line in reader.lines() {
            let text = line?;
            if text.trim().is_empty() {
                continue;
            }
            let id = next_id.fetch_add(1, Ordering::Relaxed);
            let task = build_task(text, id, cfg, arrivals.now())?;
            {
                let mut state = window.state.lock().unwrap();
                while state.outstanding.len() >= k && !state.writer_gone {
                    state = window.may_send.wait(state).unwrap();
                }
                if state.writer_gone {
                    // client socket already failed; stop reading
                    return Ok(());
                }
                state.outstanding.insert(id);
            }
            pending.lock().unwrap().insert(id, reply_tx.clone());
            if arrivals.inject(task).is_err() {
                pending.lock().unwrap().remove(&id);
                // route the shutdown error through the writer so it
                // interleaves cleanly with in-flight replies
                let _ = reply_tx.send((id, error_reply(id, "server shutting down")));
                eprintln!("connection from {peer}: server shutting down");
                return Ok(());
            }
        }
        Ok(())
    })();
    // EOF/error: our sender drops; the writer drains replies still owed
    // by the pending map entries and exits on disconnect.
    drop(reply_tx);
    let _ = writer_thread.join();
    result
}
