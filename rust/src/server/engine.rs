//! Wall-clock serving engine: replay an arrival trace against the real
//! PJRT artifacts under any scheduling policy.
//!
//! The `xla` crate's PJRT handles are not `Send` (Rc-based internals),
//! so each lane worker thread constructs its *own* client + session from
//! the artifacts directory — the same "one engine per lane" shape a
//! GPU+CPU deployment has, and no PJRT state ever crosses threads.

use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::config::SchedParams;
use crate::executor::{execute_cpu, execute_gpu, ExecReport};
use crate::metrics::Samples;
use crate::model::LmSession;
use crate::runtime::ArtifactStore;
use crate::scheduler::{Batch, Lane, Policy, Task};
use crate::sim::results::TaskOutcome;

/// Knobs for a real serving run.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Compress arrival gaps by this factor (10 = 10x faster replay).
    pub time_scale: f64,
    /// Print per-batch progress.
    pub verbose: bool,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions { time_scale: 1.0, verbose: false }
    }
}

/// Outcome of a real serving run.
#[derive(Debug, Default)]
pub struct ServeReport {
    pub policy: String,
    pub outcomes: Vec<TaskOutcome>,
    pub wall_secs: f64,
    /// Wall time spent inside policy push/pop calls (Table VII).
    pub sched_secs: f64,
    pub n_batches_gpu: usize,
    pub n_batches_cpu: usize,
    /// Pure model-inference seconds, summed over batches.
    pub infer_secs: f64,
}

impl ServeReport {
    pub fn response_times(&self) -> Samples {
        Samples::from_vec(self.outcomes.iter().map(|o| o.response_time()).collect())
    }

    pub fn throughput_per_min(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            return 0.0;
        }
        self.outcomes.len() as f64 / (self.wall_secs / 60.0)
    }
}

enum Event {
    LaneReady(#[allow(dead_code)] Lane),
    Arrival(Task, f64),
    Done(Lane, Vec<ExecReport>, f64),
    LaneError(Lane, String),
}

fn lane_worker(
    lane: Lane,
    root: PathBuf,
    model: String,
    batch_rx: mpsc::Receiver<Batch>,
    tx: mpsc::Sender<Event>,
    start: Instant,
) {
    let init = || -> Result<(Arc<ArtifactStore>, Arc<LmSession>)> {
        let store = Arc::new(ArtifactStore::open(&root)?);
        let session = Arc::new(LmSession::new(store.clone(), &model)?);
        // warm up: compile the common buckets before the clock matters
        let warm = vec![session.store().manifest.bos_id];
        session.generate(&[warm], &[2])?;
        Ok((store, session))
    };
    let session = match init() {
        Ok((_store, session)) => {
            let _ = tx.send(Event::LaneReady(lane));
            session
        }
        Err(e) => {
            let _ = tx.send(Event::LaneError(lane, format!("{e:#}")));
            return;
        }
    };
    while let Ok(batch) = batch_rx.recv() {
        let result = match lane {
            Lane::Gpu => execute_gpu(&session, &batch).map(|r| vec![r]),
            Lane::Cpu => execute_cpu(&session, &batch),
        };
        let done = start.elapsed().as_secs_f64();
        match result {
            Ok(reps) => {
                if tx.send(Event::Done(lane, reps, done)).is_err() {
                    return;
                }
            }
            Err(e) => {
                let _ = tx.send(Event::LaneError(lane, format!("{e:#}")));
                return;
            }
        }
    }
}

/// Serve `tasks` (arrival times already set, prompts encoded) with the
/// given policy against real PJRT sessions of `model`.
pub fn serve_from_root(
    artifacts_root: &std::path::Path,
    model: &str,
    mut tasks: Vec<Task>,
    policy: &mut dyn Policy,
    params: &SchedParams,
    opts: &ServeOptions,
) -> Result<ServeReport> {
    tasks.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
    let n_total = tasks.len();
    let mut report = ServeReport { policy: policy.name(), ..Default::default() };

    let (event_tx, event_rx) = mpsc::channel::<Event>();
    let (gpu_tx, gpu_rx) = mpsc::channel::<Batch>();
    let (cpu_tx, cpu_rx) = mpsc::channel::<Batch>();

    let start = Instant::now();

    let gpu_worker = {
        let tx = event_tx.clone();
        let root = artifacts_root.to_path_buf();
        let model = model.to_string();
        thread::spawn(move || lane_worker(Lane::Gpu, root, model, gpu_rx, tx, start))
    };
    let cpu_worker = {
        let tx = event_tx.clone();
        let root = artifacts_root.to_path_buf();
        let model = model.to_string();
        thread::spawn(move || lane_worker(Lane::Cpu, root, model, cpu_rx, tx, start))
    };

    // wait for both lanes to finish compiling before starting the clock
    let mut ready = 0;
    while ready < 2 {
        match event_rx.recv_timeout(Duration::from_secs(600)) {
            Ok(Event::LaneReady(_)) => ready += 1,
            Ok(Event::LaneError(lane, e)) => {
                return Err(anyhow!("{lane:?} lane failed to initialise: {e}"))
            }
            Ok(_) => {}
            Err(e) => return Err(anyhow!("lane initialisation timed out: {e}")),
        }
    }

    // --- injector: replay the (scaled) arrival trace ------------------------
    let epoch = Instant::now();
    let injector = {
        let tx = event_tx.clone();
        let time_scale = opts.time_scale.max(1e-9);
        thread::spawn(move || {
            for task in tasks {
                let due = task.arrival / time_scale;
                let now = epoch.elapsed().as_secs_f64();
                if due > now {
                    thread::sleep(Duration::from_secs_f64(due - now));
                }
                let arrived = epoch.elapsed().as_secs_f64();
                if tx.send(Event::Arrival(task, arrived)).is_err() {
                    return;
                }
            }
        })
    };
    drop(event_tx);

    // --- dispatcher ----------------------------------------------------------
    let mut meta: std::collections::HashMap<u64, Task> = std::collections::HashMap::new();
    let mut gpu_busy = false;
    let mut cpu_busy = false;
    let mut arrivals_done = false;
    let mut completed = 0usize;
    let xi_scaled = params.xi / opts.time_scale.max(1e-9);

    while completed < n_total {
        match event_rx.recv_timeout(Duration::from_millis(10)) {
            Ok(Event::Arrival(mut task, arrived)) => {
                // rebase to the dispatcher clock so response times are real
                task.priority_point = arrived + (task.priority_point - task.arrival);
                task.arrival = arrived;
                meta.insert(task.id, task.clone());
                let t0 = Instant::now();
                policy.push(task);
                report.sched_secs += t0.elapsed().as_secs_f64();
            }
            Ok(Event::Done(lane, reps, done)) => {
                match lane {
                    Lane::Gpu => gpu_busy = false,
                    Lane::Cpu => cpu_busy = false,
                }
                for rep in reps {
                    report.infer_secs += rep.infer_secs;
                    for &id in &rep.task_ids {
                        let task = meta.remove(&id).expect("unknown task completed");
                        report.outcomes.push(TaskOutcome {
                            id,
                            arrival: task.arrival,
                            completion: done,
                            priority_point: task.priority_point,
                            uncertainty: task.uncertainty,
                            true_len: task.true_len,
                            lane: rep.lane,
                            utype: task.utype.clone(),
                            malicious: task.malicious,
                            infer_secs: rep.infer_secs,
                        });
                        completed += 1;
                    }
                }
                if opts.verbose {
                    eprintln!("[{:7.2}s] {lane:?} done: {completed}/{n_total}", done);
                }
            }
            Ok(Event::LaneReady(_)) => {}
            Ok(Event::LaneError(lane, e)) => {
                return Err(anyhow!("{lane:?} lane failed mid-run: {e}"));
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => arrivals_done = true,
        }
        if !arrivals_done && injector.is_finished() && policy.queue_len() <= meta.len() {
            arrivals_done = true;
        }

        // oldest task still waiting in the queue (meta minus in-flight is
        // a superset; xi forcing only needs a lower bound, so this is safe)
        let now = epoch.elapsed().as_secs_f64();
        let oldest = meta.values().map(|t| t.arrival).fold(f64::INFINITY, f64::min);
        let force = arrivals_done || (oldest.is_finite() && now - oldest >= xi_scaled);

        if !gpu_busy {
            let t0 = Instant::now();
            let batch = policy.pop_batch(Lane::Gpu, now, force);
            report.sched_secs += t0.elapsed().as_secs_f64();
            if let Some(batch) = batch {
                report.n_batches_gpu += 1;
                gpu_busy = true;
                gpu_tx.send(batch).map_err(|_| anyhow!("gpu lane died"))?;
            }
        }
        if !cpu_busy {
            let t0 = Instant::now();
            let batch = policy.pop_batch(Lane::Cpu, now, force);
            report.sched_secs += t0.elapsed().as_secs_f64();
            if let Some(batch) = batch {
                report.n_batches_cpu += 1;
                cpu_busy = true;
                cpu_tx.send(batch).map_err(|_| anyhow!("cpu lane died"))?;
            }
        }
    }

    report.wall_secs = epoch.elapsed().as_secs_f64();
    drop(gpu_tx);
    drop(cpu_tx);
    injector.join().ok();
    gpu_worker.join().ok();
    cpu_worker.join().ok();
    report.outcomes.sort_by_key(|o| o.id);
    Ok(report)
}

/// Convenience wrapper taking an open store (dispatcher side only).
pub fn serve(
    session: Arc<LmSession>,
    tasks: Vec<Task>,
    policy: &mut dyn Policy,
    params: &SchedParams,
    opts: &ServeOptions,
) -> Result<ServeReport> {
    let root = session.store().manifest.root.clone();
    let model = session.model_name().to_string();
    serve_from_root(&root, &model, tasks, policy, params, opts)
}

/// Encode prompts into tasks (real-mode preparation).
pub fn encode_prompts(store: &ArtifactStore, tasks: &mut [Task]) {
    for task in tasks.iter_mut() {
        task.prompt = crate::model::session::encode_prompt(store, &task.text);
    }
}
