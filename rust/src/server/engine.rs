//! Wall-clock serving engine: replay an arrival trace against real PJRT
//! artifacts (or any other [`BatchExecutor`]) under any scheduling
//! policy, over an N-lane fleet described by a [`LaneSet`].
//!
//! Since the dispatcher-core unification this is a thin wrapper: the
//! loop itself lives in [`crate::engine::run_engine`], driven here by
//! the wall-clock [`ThreadedBackend`] (injector thread + one worker
//! thread per lane). The simulator and the TCP front-end drive the
//! *same* loop (the latter in open-stream mode), so scheduling
//! behaviour in simulation and on the wire is identical by
//! construction.
//!
//! The `xla` crate's PJRT handles are not `Send` (Rc-based internals),
//! so each lane worker thread constructs its *own* client + session for
//! its lane's model variant from the artifacts directory — the same
//! "one engine per lane" shape a heterogeneous GPU+CPU fleet has, and
//! no PJRT state ever crosses threads.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::Result;

use crate::config::SchedParams;
use crate::engine::{run_engine, ThreadedBackend};
use crate::executor::{BatchExecutor, ExecutorFactory, PjrtExecutor};
use crate::metrics::Samples;
use crate::model::LmSession;
use crate::runtime::ArtifactStore;
use crate::scheduler::{format_lane_counts, LaneSet, Policy, Task};
use crate::sim::results::TaskOutcome;

/// Knobs for a real serving run.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Compress arrival gaps by this factor (10 = 10x faster replay).
    /// The ξ wait interval is compressed by the same factor (live
    /// replay) or left untouched (deterministic replay, where the
    /// engine clock itself is dilated — see
    /// [`deterministic`](Self::deterministic)).
    pub time_scale: f64,
    /// Print a per-lane summary after the run.
    pub verbose: bool,
    /// Deterministic parity replay (the `rtlm bench --wire` harness,
    /// [`crate::bench_harness::replay`]): inject every arrival upfront
    /// (burst admission — all tasks admitted before the first dispatch,
    /// so every pop runs forced and batch structure cannot race arrival
    /// timing) and dilate the engine clock by `time_scale`, so the
    /// engine, the policy's time-dependent priorities, and the reported
    /// outcomes all read in *virtual* (uncompressed) seconds —
    /// comparable 1:1 against [`crate::sim::run_sim_lanes`] on the same
    /// cell. Off (the default) replays arrivals live on the wall clock.
    pub deterministic: bool,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions { time_scale: 1.0, verbose: false, deterministic: false }
    }
}

/// Outcome of a real serving run.
///
/// All per-task times are engine-clock seconds: compressed wall seconds
/// on a live replay, *virtual* (uncompressed) seconds on a
/// deterministic replay ([`ServeOptions::deterministic`]).
#[derive(Debug, Default)]
pub struct ServeReport {
    /// Name the policy reported for itself (e.g. "RT-LM").
    pub policy: String,
    /// Per-task outcomes, sorted by task id.
    pub outcomes: Vec<TaskOutcome>,
    /// Wall-clock seconds from the post-init epoch to teardown
    /// (undilated even on a deterministic replay).
    pub wall_secs: f64,
    /// Wall time spent inside policy push/pop calls (Table VII).
    pub sched_secs: f64,
    /// Lane names, in `LaneId` order.
    pub lanes: Vec<String>,
    /// Dispatched batches per lane, indexed like `lanes`.
    pub n_batches: Vec<usize>,
    /// Executed decode steps per lane, indexed like `lanes` (summed
    /// per-task under `--sched step`; max-length per batch otherwise).
    pub n_steps: Vec<usize>,
    /// Generations preempted back to the scheduler (`--sched step`).
    pub n_preempted: usize,
    /// Completed tasks per lane, indexed like `lanes` — on a router
    /// this is the per-node served breakdown (`node/lane` names).
    pub n_tasks: Vec<usize>,
    /// Tasks re-queued through lane admission after the lane they were
    /// in flight on died survivably (distributed fleets only).
    pub n_retried: usize,
    /// Lanes retired mid-run after their node died or was evicted for
    /// missed heartbeats (distributed fleets only).
    pub n_evicted: usize,
    /// Pure model-inference seconds, summed over batches.
    pub infer_secs: f64,
    /// Tasks dropped by overload admission control — each still has an
    /// outcome (flagged [`TaskOutcome::shed`]) and got a wire reply.
    pub n_shed: usize,
}

impl ServeReport {
    /// Response-time samples over every outcome.
    pub fn response_times(&self) -> Samples {
        Samples::from_vec(self.outcomes.iter().map(|o| o.response_time()).collect())
    }

    /// Time-to-first-token samples over every outcome.
    pub fn ttft_times(&self) -> Samples {
        Samples::from_vec(self.outcomes.iter().map(|o| o.ttft()).collect())
    }

    /// Completed tasks per wall-clock minute.
    pub fn throughput_per_min(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            return 0.0;
        }
        self.outcomes.len() as f64 / (self.wall_secs / 60.0)
    }

    /// `name=count` per-lane batch table, e.g. `gpu=12 cpu=3`.
    pub fn fmt_batches(&self) -> String {
        format_lane_counts(&self.lanes, &self.n_batches)
    }

    /// Per-SLO-class attainment rows (see [`crate::sim::slo_summary`]).
    pub fn slo_summaries(&self) -> Vec<crate::sim::results::SloSummary> {
        crate::sim::results::slo_summary(&self.outcomes)
    }
}

/// Serve `tasks` with `policy` over the `lanes` fleet, executing
/// batches through whatever lane executors `factory` builds — the
/// engine core, lane threads, arrival injection and ξ deadlines are
/// identical regardless of executor.
pub fn serve_with_factory(
    mut tasks: Vec<Task>,
    policy: &mut dyn Policy,
    params: &SchedParams,
    lanes: &LaneSet,
    opts: &ServeOptions,
    factory: ExecutorFactory,
) -> Result<ServeReport> {
    tasks.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
    let n_total = tasks.len();
    let time_scale = opts.time_scale.max(1e-9);
    let (scaled_params, mut backend) = if opts.deterministic {
        // burst admission + dilated engine clock: the engine reads
        // virtual seconds, so ξ (compared against those readings) must
        // stay uncompressed
        let backend = ThreadedBackend::start_scaled(
            tasks, factory, lanes, params, time_scale, true, time_scale,
        )?;
        (params.clone(), backend)
    } else {
        // arrivals replay compressed, so the wait interval compresses too
        let scaled = SchedParams { xi: params.xi / time_scale, ..params.clone() };
        let backend = ThreadedBackend::start(tasks, factory, lanes, params, time_scale, false)?;
        (scaled, backend)
    };
    let report = run_engine(&mut backend, policy, &scaled_params, n_total)?;
    let wall_secs = backend.finish();

    let mut outcomes = report.outcomes;
    outcomes.sort_by_key(|o| o.id);
    let serve_report = ServeReport {
        policy: report.policy,
        outcomes,
        wall_secs,
        sched_secs: report.sched_secs,
        lanes: lanes.names(),
        n_batches: report.n_batches,
        n_steps: report.n_steps,
        n_preempted: report.n_preempted,
        n_tasks: report.n_tasks,
        n_retried: report.n_retried,
        n_evicted: report.n_evicted,
        infer_secs: report.infer_secs,
        n_shed: report.n_shed,
    };
    if opts.verbose {
        eprintln!(
            "[{wall_secs:7.2}s] {} done: batches {}",
            serve_report.policy,
            serve_report.fmt_batches()
        );
    }
    Ok(serve_report)
}

/// Per-lane PJRT executor factory: each lane opens its own store +
/// session *for its spec's model variant* from `artifacts_root` inside
/// its worker thread (PJRT handles are not `Send`) and warms up the
/// common buckets before the serving clock starts. Shared by
/// `serve_from_root` and the TCP front-end.
pub fn pjrt_factory(artifacts_root: &std::path::Path) -> ExecutorFactory {
    let root: PathBuf = artifacts_root.to_path_buf();
    Arc::new(move |spec| {
        let store = Arc::new(ArtifactStore::open(&root)?);
        let session = Arc::new(LmSession::new(store.clone(), &spec.model)?);
        // warm up: compile the common buckets before the clock matters
        let warm = vec![session.store().manifest.bos_id];
        session.generate(&[warm], &[2])?;
        Ok(Box::new(PjrtExecutor { session, kind: spec.kind }) as Box<dyn BatchExecutor>)
    })
}

/// Serve `tasks` (arrival times already set, prompts encoded) with the
/// given policy against real PJRT sessions of each lane's model
/// variant. Each lane opens its own store + session inside its worker
/// thread and warms up the common buckets before the serving clock
/// starts.
pub fn serve_from_root(
    artifacts_root: &std::path::Path,
    lanes: &LaneSet,
    tasks: Vec<Task>,
    policy: &mut dyn Policy,
    params: &SchedParams,
    opts: &ServeOptions,
) -> Result<ServeReport> {
    let factory = pjrt_factory(artifacts_root);
    serve_with_factory(tasks, policy, params, lanes, opts, factory)
}

/// Encode prompts into tasks (real-mode preparation).
pub fn encode_prompts(store: &ArtifactStore, tasks: &mut [Task]) {
    for task in tasks.iter_mut() {
        task.prompt = crate::model::session::encode_prompt(store, &task.text);
    }
}
