//! The real-time serving loop (wall clock), the line-protocol TCP
//! front-end, and the concurrent load generator that gates it in CI.
//!
//! Architecture (std threads — see DESIGN.md §Substitutions for why not
//! tokio): one lane worker thread per configured lane owns that lane's
//! batch executor (real PJRT session of its model variant, modeled
//! latencies, …), and the dispatcher thread owns the policy. The
//! dispatch loop itself is `crate::engine::run_engine_stream`
//! — the exact same code the simulator drives — fed either by an
//! injector thread replaying a trace (`serve*`) or by TCP connection
//! handlers injecting live arrivals (`tcp::serve_tcp`), so scheduling
//! behaviour is identical in every mode by construction.
//!
//! The distributed fleet adds two pieces on the same skeleton:
//! [`wire`] (the length-prefixed framed protocol rtlm processes speak
//! to each other) and [`router`] (the `rtlm route` controller, whose
//! per-lane executors proxy lanes hosted by `rtlm tcp` nodes).

pub mod engine;
pub mod loadgen;
pub mod router;
pub mod tcp;
pub mod wire;

pub use engine::{serve_from_root, serve_with_factory, ServeOptions, ServeReport};
