//! The real-time serving loop (wall clock) and the line-protocol TCP
//! front-end.
//!
//! Architecture (std threads — see DESIGN.md §Substitutions for why not
//! tokio): an injector thread replays the arrival trace, two lane worker
//! threads own the batch executors (real PJRT sessions or modeled
//! latencies), and the dispatcher thread owns the policy. The dispatch
//! loop itself is `crate::engine::run_engine` — the exact same code the
//! simulator drives — so scheduling behaviour is identical in both modes
//! by construction.

pub mod engine;
pub mod tcp;

pub use engine::{serve, serve_with_factory, ServeOptions, ServeReport};
