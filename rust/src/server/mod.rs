//! The real-time serving loop (wall clock, real PJRT execution) and the
//! line-protocol TCP front-end.
//!
//! Architecture (std threads — see DESIGN.md §Substitutions for why not
//! tokio): an injector thread replays the arrival trace, two lane worker
//! threads own the LM session executions, and the dispatcher thread owns
//! the policy — the same `Policy` objects the simulator drives, so
//! scheduling behaviour is identical in both modes.

pub mod engine;
pub mod tcp;

pub use engine::{serve, ServeOptions, ServeReport};
