//! Concurrent line-protocol load generator for the TCP front-end.
//!
//! Opens `concurrency` connections, drives `n` requests through them
//! (one in flight per connection — concurrency on this protocol means
//! concurrent connections), parses every reply JSON, and aggregates
//! errors plus client- and server-side latency distributions. The CI
//! `tcp-load` gate runs this via `rtlm loadgen` against a modeled-
//! backend server and fails on any error/timeout or a p95
//! `response_ms` above its bound; `rust/tests/tcp_serving.rs` drives
//! the same code in-process.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::metrics::Samples;
use crate::util::json::Json;

/// Load-generator knobs (`rtlm loadgen` flags).
#[derive(Clone, Debug)]
pub struct LoadgenOptions {
    /// Total requests to send.
    pub n: usize,
    /// Concurrent connections (each sends `n / concurrency`-ish
    /// requests sequentially).
    pub concurrency: usize,
    /// Per-reply read timeout; an expired read counts as an error.
    pub reply_timeout: Duration,
    /// How long to retry the initial connect (server still starting).
    pub connect_wait: Duration,
}

impl Default for LoadgenOptions {
    fn default() -> Self {
        LoadgenOptions {
            n: 200,
            concurrency: 200,
            reply_timeout: Duration::from_secs(60),
            connect_wait: Duration::from_secs(30),
        }
    }
}

/// Aggregated result of one load run.
#[derive(Debug, Default)]
pub struct LoadReport {
    /// Replies that parsed as success.
    pub n_ok: usize,
    /// Errors (connect, timeout, or error replies).
    pub n_err: usize,
    /// Subset of `n_err` that were explicit id-tagged *server* error
    /// replies — every such request got an answer, just not a result.
    /// A chaos gate killing a node mid-run accepts these
    /// (`--allow-server-errors`) while still rejecting lost replies.
    pub n_server_err: usize,
    /// First few error strings, for diagnostics.
    pub errors: Vec<String>,
    /// Server-reported `response_ms` of every ok reply.
    pub response_ms: Samples,
    /// Server-reported `ttft_ms` (time to first token) of every ok
    /// reply that carried one.
    pub ttft_ms: Samples,
    /// Client-measured round-trip ms of every ok reply.
    pub rtt_ms: Samples,
    /// Tasks served per lane, keyed by the lane name each ok reply
    /// carried — the client-side view of the fleet's per-lane traffic.
    /// On a router the names are qualified `node/lane` union names.
    pub lane_tasks: BTreeMap<String, usize>,
    /// Tasks served per node, keyed by the `node` tag each ok reply
    /// carried (`"local"` on a single-process server) — shows where a
    /// distributed fleet's traffic ran, and after a node kill, how much
    /// the survivors absorbed.
    pub node_tasks: BTreeMap<String, usize>,
}

impl LoadReport {
    fn record_err(&mut self, msg: String) {
        self.n_err += 1;
        if self.errors.len() < 8 {
            self.errors.push(msg);
        }
    }

    fn merge(&mut self, other: LoadReport) {
        self.n_ok += other.n_ok;
        self.n_err += other.n_err;
        self.n_server_err += other.n_server_err;
        for e in other.errors {
            if self.errors.len() < 8 {
                self.errors.push(e);
            }
        }
        self.response_ms.extend(other.response_ms.values().iter().copied());
        self.ttft_ms.extend(other.ttft_ms.values().iter().copied());
        self.rtt_ms.extend(other.rtt_ms.values().iter().copied());
        for (lane, n) in other.lane_tasks {
            *self.lane_tasks.entry(lane).or_insert(0) += n;
        }
        for (node, n) in other.node_tasks {
            *self.node_tasks.entry(node).or_insert(0) += n;
        }
    }

    /// `name=count` per-lane served-task table, e.g. `gpu=198 cpu=2`.
    pub fn fmt_lane_tasks(&self) -> String {
        self.lane_tasks
            .iter()
            .map(|(n, c)| format!("{n}={c}"))
            .collect::<Vec<_>>()
            .join(" ")
    }

    /// `name=count` per-node served-task table, e.g. `nodeA=120 nodeB=80`.
    pub fn fmt_node_tasks(&self) -> String {
        self.node_tasks
            .iter()
            .map(|(n, c)| format!("{n}={c}"))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

/// Wait until `addr` accepts a connection (server startup can race the
/// load generator in CI).
pub fn wait_for_server(addr: &str, wait: Duration) -> Result<()> {
    let deadline = Instant::now() + wait;
    loop {
        match TcpStream::connect(addr) {
            Ok(_) => return Ok(()),
            Err(e) if Instant::now() >= deadline => {
                return Err(anyhow!("server at {addr} not reachable after {wait:?}: {e}"))
            }
            Err(_) => thread::sleep(Duration::from_millis(100)),
        }
    }
}

fn drive_connection(
    addr: &str,
    requests: usize,
    worker: usize,
    opts: &LoadgenOptions,
) -> LoadReport {
    let mut report = LoadReport::default();
    // a thundering herd of connects can race the listener backlog:
    // retry briefly before counting the connection as failed
    let mut attempt = 0;
    let stream = loop {
        match TcpStream::connect(addr) {
            Ok(s) => break s,
            Err(_) if attempt < 20 => {
                attempt += 1;
                thread::sleep(Duration::from_millis(25 * attempt));
            }
            Err(e) => {
                for _ in 0..requests {
                    report.record_err(format!("connect: {e}"));
                }
                return report;
            }
        }
    };
    stream.set_read_timeout(Some(opts.reply_timeout)).ok();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(e) => {
            for _ in 0..requests {
                report.record_err(format!("clone: {e}"));
            }
            return report;
        }
    };
    let mut reader = BufReader::new(stream);
    for i in 0..requests {
        let text = format!("tell me about the history of art {worker} {i}");
        // on a dead connection, account for every request this worker
        // will now never send — totals must always add up to its share
        let abort = |report: &mut LoadReport, msg: String| {
            report.record_err(msg);
            for _ in i + 1..requests {
                report.record_err("not attempted (connection aborted)".into());
            }
        };
        let t0 = Instant::now();
        // a partial write would desynchronize request/reply pairing on
        // this connection, so a write error aborts it like a read error
        if let Err(e) = writeln!(writer, "{text}") {
            abort(&mut report, format!("write: {e}"));
            return report;
        }
        let mut line = String::new();
        match reader.read_line(&mut line) {
            Ok(0) => {
                abort(&mut report, "server closed the connection".into());
                return report;
            }
            Ok(_) => {}
            Err(e) => {
                abort(&mut report, format!("read (timeout?): {e}"));
                return report;
            }
        }
        let rtt_ms = t0.elapsed().as_secs_f64() * 1e3;
        match Json::parse(line.trim()) {
            Ok(reply) => {
                if let Some(err) = reply.get("error").as_str() {
                    let id = reply.get("id").as_i64().unwrap_or(-1);
                    report.n_server_err += 1;
                    report.record_err(format!("server error (id {id}): {err}"));
                } else {
                    match reply.need_f64("response_ms") {
                        Ok(ms) => {
                            report.n_ok += 1;
                            report.response_ms.push(ms);
                            if let Some(t) = reply.get("ttft_ms").as_f64() {
                                report.ttft_ms.push(t);
                            }
                            report.rtt_ms.push(rtt_ms);
                            if let Some(lane) = reply.get("lane").as_str() {
                                *report.lane_tasks.entry(lane.to_string()).or_insert(0) += 1;
                            }
                            if let Some(node) = reply.get("node").as_str() {
                                *report.node_tasks.entry(node.to_string()).or_insert(0) += 1;
                            }
                        }
                        Err(e) => report.record_err(format!("bad reply: {e}")),
                    }
                }
            }
            Err(e) => report.record_err(format!("unparseable reply: {e}")),
        }
    }
    report
}

/// Run a load test against a serving `rtlm tcp` instance.
pub fn run(addr: &str, opts: &LoadgenOptions) -> Result<LoadReport> {
    anyhow::ensure!(opts.n > 0 && opts.concurrency > 0, "n and concurrency must be positive");
    // resolve once so a bad address fails fast, not 200 times
    addr.to_socket_addrs().with_context(|| format!("resolving {addr}"))?;
    wait_for_server(addr, opts.connect_wait)?;

    let concurrency = opts.concurrency.min(opts.n);
    let mut handles = Vec::with_capacity(concurrency);
    for worker in 0..concurrency {
        // spread the remainder so exactly n requests go out
        let requests = opts.n / concurrency + usize::from(worker < opts.n % concurrency);
        let addr = addr.to_string();
        let opts = opts.clone();
        handles.push(thread::spawn(move || drive_connection(&addr, requests, worker, &opts)));
    }
    let mut total = LoadReport::default();
    for handle in handles {
        match handle.join() {
            Ok(report) => total.merge(report),
            Err(_) => total.record_err("load worker panicked".into()),
        }
    }
    Ok(total)
}
