//! Concurrent line-protocol load generator for the TCP front-end.
//!
//! Opens `concurrency` connections, drives `n` requests through them
//! (one in flight per connection — concurrency on this protocol means
//! concurrent connections), parses every reply JSON, and aggregates
//! errors plus client- and server-side latency distributions. The CI
//! `tcp-load` gate runs this via `rtlm loadgen` against a modeled-
//! backend server and fails on any error/timeout or a p95
//! `response_ms` above its bound; `rust/tests/tcp_serving.rs` drives
//! the same code in-process.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::metrics::Samples;
use crate::util::json::Json;
use crate::util::rng::Pcg64;

/// Load-generator knobs (`rtlm loadgen` flags).
#[derive(Clone, Debug)]
pub struct LoadgenOptions {
    /// Total requests to send.
    pub n: usize,
    /// Concurrent connections (each sends `n / concurrency`-ish
    /// requests sequentially).
    pub concurrency: usize,
    /// Per-reply read timeout; an expired read counts as an error.
    pub reply_timeout: Duration,
    /// How long to retry the initial connect (server still starting).
    pub connect_wait: Duration,
    /// Open-loop arrival rate in requests/second across the whole run
    /// (`--rate`). 0 (the default) is the historical closed loop: each
    /// connection waits for a reply before its next request, so offered
    /// load can never exceed service capacity. Positive, each
    /// connection fires its share at Poisson inter-arrival gaps without
    /// waiting — the arrival process survives server slowdown, which is
    /// what makes overload (and shedding) actually reachable.
    pub rate: f64,
}

impl Default for LoadgenOptions {
    fn default() -> Self {
        LoadgenOptions {
            n: 200,
            concurrency: 200,
            reply_timeout: Duration::from_secs(60),
            connect_wait: Duration::from_secs(30),
            rate: 0.0,
        }
    }
}

/// Aggregated result of one load run.
#[derive(Debug, Default)]
pub struct LoadReport {
    /// Replies that parsed as success.
    pub n_ok: usize,
    /// Errors (connect, timeout, or error replies).
    pub n_err: usize,
    /// Subset of `n_err` that were explicit id-tagged *server* error
    /// replies — every such request got an answer, just not a result.
    /// A chaos gate killing a node mid-run accepts these
    /// (`--allow-server-errors`) while still rejecting lost replies.
    pub n_server_err: usize,
    /// Replies that were explicit `{"error":"shed"}`: overload
    /// admission control answered the request by dropping it. Counted
    /// separately from both `n_ok` and `n_err` — a shed is an answered
    /// request, and the CI overload gate asserts the split directly.
    pub n_shed: usize,
    /// First few error strings, for diagnostics.
    pub errors: Vec<String>,
    /// Server-reported `response_ms` of every ok reply.
    pub response_ms: Samples,
    /// Server-reported `ttft_ms` (time to first token) of every ok
    /// reply that carried one.
    pub ttft_ms: Samples,
    /// Client-measured round-trip ms of every ok reply.
    pub rtt_ms: Samples,
    /// Tasks served per lane, keyed by the lane name each ok reply
    /// carried — the client-side view of the fleet's per-lane traffic.
    /// On a router the names are qualified `node/lane` union names.
    pub lane_tasks: BTreeMap<String, usize>,
    /// Tasks served per node, keyed by the `node` tag each ok reply
    /// carried (`"local"` on a single-process server) — shows where a
    /// distributed fleet's traffic ran, and after a node kill, how much
    /// the survivors absorbed.
    pub node_tasks: BTreeMap<String, usize>,
}

impl LoadReport {
    fn record_err(&mut self, msg: String) {
        self.n_err += 1;
        if self.errors.len() < 8 {
            self.errors.push(msg);
        }
    }

    fn merge(&mut self, other: LoadReport) {
        self.n_ok += other.n_ok;
        self.n_err += other.n_err;
        self.n_server_err += other.n_server_err;
        self.n_shed += other.n_shed;
        for e in other.errors {
            if self.errors.len() < 8 {
                self.errors.push(e);
            }
        }
        self.response_ms.extend(other.response_ms.values().iter().copied());
        self.ttft_ms.extend(other.ttft_ms.values().iter().copied());
        self.rtt_ms.extend(other.rtt_ms.values().iter().copied());
        for (lane, n) in other.lane_tasks {
            *self.lane_tasks.entry(lane).or_insert(0) += n;
        }
        for (node, n) in other.node_tasks {
            *self.node_tasks.entry(node).or_insert(0) += n;
        }
    }

    /// `name=count` per-lane served-task table, e.g. `gpu=198 cpu=2`.
    pub fn fmt_lane_tasks(&self) -> String {
        self.lane_tasks
            .iter()
            .map(|(n, c)| format!("{n}={c}"))
            .collect::<Vec<_>>()
            .join(" ")
    }

    /// `name=count` per-node served-task table, e.g. `nodeA=120 nodeB=80`.
    pub fn fmt_node_tasks(&self) -> String {
        self.node_tasks
            .iter()
            .map(|(n, c)| format!("{n}={c}"))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

/// Wait until `addr` accepts a connection (server startup can race the
/// load generator in CI).
pub fn wait_for_server(addr: &str, wait: Duration) -> Result<()> {
    let deadline = Instant::now() + wait;
    loop {
        match TcpStream::connect(addr) {
            Ok(_) => return Ok(()),
            Err(e) if Instant::now() >= deadline => {
                return Err(anyhow!("server at {addr} not reachable after {wait:?}: {e}"))
            }
            Err(_) => thread::sleep(Duration::from_millis(100)),
        }
    }
}

/// Parse one reply line into the report's tallies. `rtt_ms` is the
/// client-measured round trip when the caller paired request and reply
/// (closed loop); open-loop replies are unpaired and pass `None`.
fn tally_reply(report: &mut LoadReport, line: &str, rtt_ms: Option<f64>) {
    match Json::parse(line) {
        Ok(reply) => {
            if let Some(err) = reply.get("error").as_str() {
                if err == "shed" {
                    report.n_shed += 1;
                } else {
                    let id = reply.get("id").as_i64().unwrap_or(-1);
                    report.n_server_err += 1;
                    report.record_err(format!("server error (id {id}): {err}"));
                }
            } else {
                match reply.need_f64("response_ms") {
                    Ok(ms) => {
                        report.n_ok += 1;
                        report.response_ms.push(ms);
                        if let Some(t) = reply.get("ttft_ms").as_f64() {
                            report.ttft_ms.push(t);
                        }
                        if let Some(rtt) = rtt_ms {
                            report.rtt_ms.push(rtt);
                        }
                        if let Some(lane) = reply.get("lane").as_str() {
                            *report.lane_tasks.entry(lane.to_string()).or_insert(0) += 1;
                        }
                        if let Some(node) = reply.get("node").as_str() {
                            *report.node_tasks.entry(node.to_string()).or_insert(0) += 1;
                        }
                    }
                    Err(e) => report.record_err(format!("bad reply: {e}")),
                }
            }
        }
        Err(e) => report.record_err(format!("unparseable reply: {e}")),
    }
}

/// Connect with brief retries (a thundering herd of connects can race
/// the listener backlog); on failure, account every request this worker
/// will now never send.
fn connect_with_retry(addr: &str, requests: usize, report: &mut LoadReport) -> Option<TcpStream> {
    let mut attempt = 0;
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => {
                // measured request/reply latency must not include Nagle
                let _ = s.set_nodelay(true);
                return Some(s);
            }
            Err(_) if attempt < 20 => {
                attempt += 1;
                thread::sleep(Duration::from_millis(25 * attempt));
            }
            Err(e) => {
                for _ in 0..requests {
                    report.record_err(format!("connect: {e}"));
                }
                return None;
            }
        }
    }
}

fn drive_connection(
    addr: &str,
    requests: usize,
    worker: usize,
    opts: &LoadgenOptions,
) -> LoadReport {
    let mut report = LoadReport::default();
    let Some(stream) = connect_with_retry(addr, requests, &mut report) else {
        return report;
    };
    stream.set_read_timeout(Some(opts.reply_timeout)).ok();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(e) => {
            for _ in 0..requests {
                report.record_err(format!("clone: {e}"));
            }
            return report;
        }
    };
    let mut reader = BufReader::new(stream);
    for i in 0..requests {
        let text = format!("tell me about the history of art {worker} {i}");
        // on a dead connection, account for every request this worker
        // will now never send — totals must always add up to its share
        let abort = |report: &mut LoadReport, msg: String| {
            report.record_err(msg);
            for _ in i + 1..requests {
                report.record_err("not attempted (connection aborted)".into());
            }
        };
        let t0 = Instant::now();
        // a partial write would desynchronize request/reply pairing on
        // this connection, so a write error aborts it like a read error
        if let Err(e) = writeln!(writer, "{text}") {
            abort(&mut report, format!("write: {e}"));
            return report;
        }
        let mut line = String::new();
        match reader.read_line(&mut line) {
            Ok(0) => {
                abort(&mut report, "server closed the connection".into());
                return report;
            }
            Ok(_) => {}
            Err(e) => {
                abort(&mut report, format!("read (timeout?): {e}"));
                return report;
            }
        }
        let rtt_ms = t0.elapsed().as_secs_f64() * 1e3;
        tally_reply(&mut report, line.trim(), Some(rtt_ms));
    }
    report
}

/// Open-loop worker: a writer thread fires this connection's share of
/// requests at Poisson gaps (fire-and-forget), while this thread reads
/// and tallies replies as they come back. Totals still add up to
/// `requests`: unanswered sends and never-attempted requests are
/// counted as errors at the end.
fn drive_connection_open(
    addr: &str,
    requests: usize,
    worker: usize,
    mean_gap_secs: f64,
    opts: &LoadgenOptions,
) -> LoadReport {
    let mut report = LoadReport::default();
    let Some(stream) = connect_with_retry(addr, requests, &mut report) else {
        return report;
    };
    stream.set_read_timeout(Some(opts.reply_timeout)).ok();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(e) => {
            for _ in 0..requests {
                report.record_err(format!("clone: {e}"));
            }
            return report;
        }
    };
    let mut reader = BufReader::new(stream);
    let writer_thread = thread::spawn(move || -> (usize, Option<String>) {
        let mut rng = Pcg64::new(0x10AD_0000 ^ worker as u64);
        for i in 0..requests {
            thread::sleep(Duration::from_secs_f64(rng.exponential(mean_gap_secs)));
            let text = format!("tell me about the history of art {worker} {i}");
            if let Err(e) = writeln!(writer, "{text}") {
                return (i, Some(format!("write: {e}")));
            }
        }
        (requests, None)
    });
    // tally replies until this connection's full share is answered or
    // the read fails; a short writer leaves the reader to time out once
    let mut replies = 0usize;
    let mut read_err: Option<String> = None;
    while replies < requests {
        let mut line = String::new();
        match reader.read_line(&mut line) {
            Ok(0) => {
                read_err = Some("server closed the connection".into());
                break;
            }
            Ok(_) => {
                tally_reply(&mut report, line.trim(), None);
                replies += 1;
            }
            Err(e) => {
                read_err = Some(format!("read (timeout?): {e}"));
                break;
            }
        }
    }
    let (sent, write_err) = writer_thread
        .join()
        .unwrap_or((0, Some("writer panicked".into())));
    for _ in replies..sent {
        report.record_err(read_err.clone().unwrap_or_else(|| "no reply".into()));
    }
    if let Some(e) = write_err {
        report.record_err(e);
        for _ in sent + 1..requests {
            report.record_err("not attempted (connection aborted)".into());
        }
    }
    report
}

/// Run a load test against a serving `rtlm tcp` instance.
pub fn run(addr: &str, opts: &LoadgenOptions) -> Result<LoadReport> {
    anyhow::ensure!(opts.n > 0 && opts.concurrency > 0, "n and concurrency must be positive");
    // resolve once so a bad address fails fast, not 200 times
    addr.to_socket_addrs().with_context(|| format!("resolving {addr}"))?;
    wait_for_server(addr, opts.connect_wait)?;

    let concurrency = opts.concurrency.min(opts.n);
    // open loop: the run-wide Poisson rate splits evenly across the
    // connections (superposing them restores the target process)
    let mean_gap_secs = (opts.rate > 0.0).then(|| concurrency as f64 / opts.rate);
    let mut handles = Vec::with_capacity(concurrency);
    for worker in 0..concurrency {
        // spread the remainder so exactly n requests go out
        let requests = opts.n / concurrency + usize::from(worker < opts.n % concurrency);
        let addr = addr.to_string();
        let opts = opts.clone();
        handles.push(thread::spawn(move || match mean_gap_secs {
            Some(gap) => drive_connection_open(&addr, requests, worker, gap, &opts),
            None => drive_connection(&addr, requests, worker, &opts),
        }));
    }
    let mut total = LoadReport::default();
    for handle in handles {
        match handle.join() {
            Ok(report) => total.merge(report),
            Err(_) => total.record_err("load worker panicked".into()),
        }
    }
    Ok(total)
}
