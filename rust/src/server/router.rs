//! The `rtlm route` controller: one uncertainty-aware dispatcher over
//! lanes living in other processes.
//!
//! A *node* is an ordinary `rtlm tcp` server; the router dials each one
//! (or waits for `--register` dial-ins), gossips its lane table over
//! the framed [`wire`](super::wire) protocol, and adopts every
//! advertised lane into a union [`LaneSet`] as a [`LaneKind::Remote`]
//! lane named `node/lane`. From there the stack is unchanged: the
//! router *is* a `serve_tcp` server whose per-lane executors happen to
//! be [`RemoteExecutor`]s — uncertainty is scored once at the router's
//! admission, the policy routes across the union fleet by the gossiped
//! admission predicates, and each dispatched batch becomes framed
//! `submit` calls with id-tagged, out-of-order `done` replies.
//!
//! Failure model: a per-node monitor thread heartbeats a dedicated
//! control connection. Two consecutive missed pongs (or a dead control
//! connection) evict the node — its registered data streams are shut
//! down so lane workers parked in a blocking read wake up even when
//! the node hangs rather than resets, every in-flight task comes back
//! as [`ExecOutcome::LaneLost`] re-queue work, and
//! [`ArrivalHandle::fail_lane`] retires the idle lanes. The engine then
//! re-routes through the surviving lanes' ordinary admissions; nothing
//! is dropped silently.

use std::collections::{HashMap, HashSet};
use std::io::BufReader;
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::engine::ArrivalHandle;
use crate::executor::{BatchExecutor, ExecOutcome, ExecReport, ExecutorFactory};
use crate::scheduler::lane::numeric_thresholds;
use crate::scheduler::{Admission, Batch, LaneId, LaneKind, LaneSet, LaneSpec, Task};
use crate::util::json::Json;

use super::wire;

/// One lane a node advertises in its `lanes` gossip frame.
#[derive(Clone, Debug)]
pub struct NodeLane {
    /// Lane name on the node ("gpu", "cpu", …).
    pub name: String,
    /// The node-side lane kind label ("gpu" / "cpu") — informational;
    /// the router's proxy lane is always [`LaneKind::Remote`].
    pub kind: String,
    /// Model variant the lane serves.
    pub model: String,
    /// Per-lane batch-size override, if the node configured one.
    pub batch_size: Option<usize>,
    /// Intra-batch worker count, if the node configured one.
    pub workers: Option<usize>,
    /// Admission predicate in [`Admission::spec`] grammar.
    pub admit: String,
    /// Per-lane batching-window override (seconds), if any.
    pub xi: Option<f64>,
    /// Per-lane consolidation-split override, if any.
    pub lambda: Option<f64>,
}

/// One node of the fleet: a name, a dialable address, and the lane
/// table it gossiped.
#[derive(Clone, Debug)]
pub struct NodeInfo {
    /// The node's self-reported name (`--node-name`); must be unique
    /// across the fleet.
    pub name: String,
    /// Address the router dials for data and control connections.
    pub addr: String,
    /// Lanes the node advertised.
    pub lanes: Vec<NodeLane>,
}

/// Dial a node, send `hello`, and parse the `lanes` gossip reply.
pub fn dial_node(addr: &str, timeout: Duration) -> Result<NodeInfo> {
    let stream =
        TcpStream::connect(addr).with_context(|| format!("dialing node {addr}"))?;
    // small framed request/reply hops: Nagle only adds latency here
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(timeout))?;
    let mut writer = stream.try_clone()?;
    wire::write_magic(&mut writer)?;
    wire::write_frame(&mut writer, &wire::frame("hello", vec![]))?;
    let mut reader = BufReader::new(stream);
    wire::read_magic(&mut reader)
        .with_context(|| format!("node {addr} did not answer as a framed rtlm server"))?;
    let msg = wire::read_frame(&mut reader)?
        .ok_or_else(|| anyhow!("node {addr} closed before gossiping its lane table"))?;
    if wire::frame_type(&msg) != "lanes" {
        bail!("node {addr} answered hello with '{}'", wire::frame_type(&msg));
    }
    parse_lanes_frame(addr, &msg)
}

fn parse_lanes_frame(addr: &str, msg: &Json) -> Result<NodeInfo> {
    let name = msg.need_str("node")?.to_string();
    let mut lanes = Vec::new();
    for entry in msg.need_arr("lanes")? {
        lanes.push(NodeLane {
            name: entry.need_str("name")?.to_string(),
            kind: entry.need_str("kind")?.to_string(),
            model: entry.need_str("model")?.to_string(),
            batch_size: entry.get("batch_size").as_usize(),
            workers: entry.get("workers").as_usize(),
            admit: entry.need_str("admit")?.to_string(),
            xi: entry.get("xi").as_f64(),
            lambda: entry.get("lambda").as_f64(),
        });
    }
    if lanes.is_empty() {
        bail!("node '{name}' ({addr}) advertised no lanes");
    }
    Ok(NodeInfo { name, addr: addr.to_string(), lanes })
}

/// Assemble the fleet: dial every `--nodes` address, then (if
/// `expect_nodes > 0`) hold the router's listener open for that many
/// `register` dial-ins, dialing each registrant back for its lane
/// table before acking. Connections that are not framed registrations
/// are ignored — clients arriving early simply retry.
pub fn gather_nodes(
    static_addrs: &[String],
    listener: &TcpListener,
    expect_nodes: usize,
    timeout: Duration,
) -> Result<Vec<NodeInfo>> {
    let mut nodes = Vec::new();
    for addr in static_addrs {
        let node = dial_node(addr, timeout)?;
        eprintln!(
            "rtlm route: node '{}' at {addr} gossiped {} lane(s)",
            node.name,
            node.lanes.len()
        );
        nodes.push(node);
    }
    if expect_nodes > 0 {
        eprintln!("rtlm route: waiting for {expect_nodes} node registration(s)…");
    }
    let mut registered = 0usize;
    while registered < expect_nodes {
        let (stream, peer) = listener.accept().context("accepting node registrations")?;
        match accept_registration(stream, timeout) {
            Ok(Some(node)) => {
                eprintln!(
                    "rtlm route: node '{}' registered from {peer}, serving at {}",
                    node.name, node.addr
                );
                nodes.push(node);
                registered += 1;
            }
            Ok(None) => {} // probe or early client; not a registration
            Err(e) => eprintln!("rtlm route: registration from {peer} failed: {e:#}"),
        }
    }
    Ok(nodes)
}

/// Handle one possible registration connection: `Ok(None)` when the
/// peer is not a framed registrant, `Ok(Some(node))` after a
/// successful dial-back, `Err` on a malformed or unreachable one.
fn accept_registration(stream: TcpStream, timeout: Duration) -> Result<Option<NodeInfo>> {
    let _ = stream.set_nodelay(true);
    stream.set_read_timeout(Some(timeout))?;
    let mut reader = BufReader::new(stream.try_clone().context("cloning registration")?);
    if !wire::is_framed_peer(&mut reader)? {
        return Ok(None);
    }
    wire::read_magic(&mut reader)?;
    let mut writer = stream;
    wire::write_magic(&mut writer)?;
    let Some(msg) = wire::read_frame(&mut reader)? else {
        return Ok(None);
    };
    if wire::frame_type(&msg) != "register" {
        bail!("expected a register frame, got '{}'", wire::frame_type(&msg));
    }
    let name = msg.need_str("node")?.to_string();
    let addr = msg.need_str("addr")?.to_string();
    match dial_node(&addr, timeout) {
        Ok(mut node) => {
            node.name = name;
            wire::write_frame(&mut writer, &wire::frame("ok", vec![]))?;
            Ok(Some(node))
        }
        Err(e) => {
            let err = wire::frame(
                "error",
                vec![("error", Json::Str(format!("dial-back to {addr} failed: {e:#}")))],
            );
            let _ = wire::write_frame(&mut writer, &err);
            Err(e)
        }
    }
}

/// Build the router's union [`LaneSet`]: every gossiped lane becomes a
/// [`LaneKind::Remote`] lane named `node/lane` carrying the node's
/// admission predicate and scheduling overrides, so one policy routes
/// the whole fleet exactly as if the lanes were local.
pub fn union_fleet(nodes: &[NodeInfo]) -> Result<LaneSet> {
    let mut seen = HashSet::new();
    let mut specs = Vec::new();
    for node in nodes {
        if node.name.is_empty() || node.name.contains('/') {
            bail!("bad node name '{}' (must be non-empty, without '/')", node.name);
        }
        if !seen.insert(node.name.clone()) {
            bail!(
                "duplicate node name '{}' in the fleet (give each node a distinct --node-name)",
                node.name
            );
        }
        for lane in &node.lanes {
            let admission = Admission::parse(&lane.admit, &mut numeric_thresholds)
                .with_context(|| {
                    format!(
                        "node '{}' lane '{}' gossiped admission '{}'",
                        node.name, lane.name, lane.admit
                    )
                })?;
            specs.push(LaneSpec {
                name: format!("{}/{}", node.name, lane.name),
                kind: LaneKind::Remote,
                model: lane.model.clone(),
                batch_size: lane.batch_size,
                workers: lane.workers,
                admission,
                xi: lane.xi,
                lambda: lane.lambda,
                node: Some(node.name.clone()),
            });
        }
    }
    LaneSet::new(specs).context("building the union fleet")
}

/// Live data-stream clones per node name, registered by
/// [`RemoteExecutor`]s at connect time. The heartbeat monitor shuts a
/// dead node's streams down on eviction, so lane workers blocked in a
/// read wake up even when the node hangs or is partitioned instead of
/// resetting the connection.
pub type StreamRegistry = Arc<Mutex<HashMap<String, Vec<TcpStream>>>>;

/// An empty [`StreamRegistry`].
pub fn new_registry() -> StreamRegistry {
    Arc::new(Mutex::new(HashMap::new()))
}

/// An [`ExecutorFactory`] that builds one [`RemoteExecutor`] per
/// remote lane, resolving each lane's node tag to its dial address.
pub fn remote_factory(nodes: &[NodeInfo], registry: StreamRegistry) -> ExecutorFactory {
    let addrs: HashMap<String, String> =
        nodes.iter().map(|n| (n.name.clone(), n.addr.clone())).collect();
    Arc::new(move |spec: &LaneSpec| {
        let node = spec
            .node
            .clone()
            .ok_or_else(|| anyhow!("lane '{}' has no node tag (not a union lane)", spec.name))?;
        let addr = addrs
            .get(&node)
            .ok_or_else(|| anyhow!("lane '{}': unknown node '{node}'", spec.name))?;
        let exec = RemoteExecutor::connect(&node, addr, spec, registry.clone())?;
        Ok(Box::new(exec) as Box<dyn BatchExecutor>)
    })
}

/// A remote lane's executor: one framed data connection to the lane's
/// node. `execute` turns a batch into per-task `submit` frames and
/// collects id-tagged `done` replies (out of order — the node serves
/// them as its own scheduler finishes them). A dead node is reported
/// as [`ExecOutcome::LaneLost`] with the unanswered tasks attached, so
/// the engine re-routes them instead of crashing the router.
pub struct RemoteExecutor {
    node: String,
    lane: String,
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl RemoteExecutor {
    /// Dial the node and exchange the framed preamble; the data stream
    /// registers itself for eviction shutdown.
    pub fn connect(
        node: &str,
        addr: &str,
        spec: &LaneSpec,
        registry: StreamRegistry,
    ) -> Result<RemoteExecutor> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("lane '{}': dialing node '{node}' at {addr}", spec.name))?;
        // per-task submit frames must not sit in a Nagle buffer
        stream.set_nodelay(true)?;
        let mut writer = stream.try_clone()?;
        wire::write_magic(&mut writer)?;
        let mut reader = BufReader::new(stream.try_clone()?);
        wire::read_magic(&mut reader)
            .with_context(|| format!("lane '{}': node '{node}' preamble", spec.name))?;
        registry.lock().unwrap().entry(node.to_string()).or_default().push(stream);
        Ok(RemoteExecutor {
            node: node.to_string(),
            lane: spec.name.clone(),
            writer,
            reader,
        })
    }

    fn lost(
        &self,
        completed: Vec<ExecReport>,
        unanswered: HashMap<u64, Task>,
        error: String,
    ) -> ExecOutcome {
        ExecOutcome::LaneLost {
            completed,
            requeue: unanswered.into_values().collect(),
            error,
        }
    }
}

impl BatchExecutor for RemoteExecutor {
    fn execute(&mut self, batch: &Batch) -> Result<Vec<ExecReport>> {
        match self.execute_failable(batch)? {
            ExecOutcome::Done(reports) => Ok(reports),
            ExecOutcome::LaneLost { error, .. } => Err(anyhow!(error)),
        }
    }

    fn execute_failable(&mut self, batch: &Batch) -> Result<ExecOutcome> {
        let start = Instant::now();
        let mut unanswered: HashMap<u64, Task> =
            batch.tasks.iter().map(|t| (t.id, t.clone())).collect();
        let mut completed: Vec<ExecReport> = Vec::new();

        for task in &batch.tasks {
            // ship the admission-time score — the node must not re-score
            let submit = wire::frame(
                "submit",
                vec![
                    ("id", Json::Num(task.id as f64)),
                    ("text", Json::Str(task.text.clone())),
                    ("u", Json::Num(task.uncertainty)),
                    ("true_len", Json::Num(task.true_len as f64)),
                    ("input_len", Json::Num(task.input_len as f64)),
                    ("pp_offset", Json::Num(task.priority_point - task.arrival)),
                    ("utype", Json::Str(task.utype.clone())),
                    ("malicious", Json::Bool(task.malicious)),
                ],
            );
            if let Err(e) = wire::write_frame(&mut self.writer, &submit) {
                let err = format!("node '{}' unreachable mid-submit: {e:#}", self.node);
                return Ok(self.lost(completed, unanswered, err));
            }
        }

        while !unanswered.is_empty() {
            let msg = match wire::read_frame(&mut self.reader) {
                Ok(Some(msg)) => msg,
                Ok(None) => {
                    let err = format!("node '{}' closed the data stream", self.node);
                    return Ok(self.lost(completed, unanswered, err));
                }
                Err(e) => {
                    let err = format!("node '{}' data stream failed: {e:#}", self.node);
                    return Ok(self.lost(completed, unanswered, err));
                }
            };
            if wire::frame_type(&msg) != "done" {
                continue; // stray frame on the data stream; ignore
            }
            let Some(id) = msg.get("id").as_f64().map(|x| x as u64) else {
                continue;
            };
            if unanswered.remove(&id).is_none() {
                continue; // unknown or duplicate id; ignore
            }
            let elapsed = start.elapsed().as_secs_f64();
            if let Some(err) = msg.get("error").as_str() {
                // the node answered, just unsuccessfully: that is a
                // completion (empty output), not a lane failure
                eprintln!(
                    "lane '{}': node '{}' failed request {id}: {err}",
                    self.lane, self.node
                );
                completed.push(ExecReport {
                    task_ids: vec![id],
                    outputs: vec![Vec::new()],
                    infer_secs: 0.0,
                    steps: 0,
                    end_offset_secs: elapsed,
                    ttft_back_secs: 0.0,
                });
                continue;
            }
            let output: Vec<i32> = msg
                .get("token_ids")
                .as_arr()
                .map(|arr| arr.iter().filter_map(|t| t.as_i64().map(|x| x as i32)).collect())
                .unwrap_or_default();
            let response_ms = msg.get("response_ms").as_f64().unwrap_or(0.0);
            let ttft_ms = msg.get("ttft_ms").as_f64().unwrap_or(response_ms);
            completed.push(ExecReport {
                task_ids: vec![id],
                steps: output.len().max(msg.get("tokens").as_usize().unwrap_or(0)),
                outputs: vec![output],
                infer_secs: msg.get("infer_ms").as_f64().unwrap_or(0.0) / 1e3,
                end_offset_secs: elapsed,
                ttft_back_secs: ((response_ms - ttft_ms) / 1e3).max(0.0),
            });
        }
        Ok(ExecOutcome::Done(completed))
    }
}

/// Spawn one heartbeat monitor thread per node. Each keeps a dedicated
/// control connection, pings every `interval`, and evicts the node
/// after two consecutive missed pongs (or a dead control connection):
/// registered data streams are shut down (waking lane workers blocked
/// mid-batch into their [`ExecOutcome::LaneLost`] path) and every lane
/// of the node is retired via [`ArrivalHandle::fail_lane`].
pub fn spawn_monitors(
    nodes: &[NodeInfo],
    lanes: &LaneSet,
    handle: &ArrivalHandle,
    interval: Duration,
    registry: &StreamRegistry,
) {
    for node in nodes {
        let lane_ids: Vec<LaneId> = lanes
            .ids()
            .filter(|&id| lanes.spec(id).node.as_deref() == Some(node.name.as_str()))
            .collect();
        let node = node.clone();
        let handle = handle.clone();
        let registry = registry.clone();
        thread::spawn(move || monitor_node(node, lane_ids, handle, interval, registry));
    }
}

fn monitor_node(
    node: NodeInfo,
    lane_ids: Vec<LaneId>,
    handle: ArrivalHandle,
    interval: Duration,
    registry: StreamRegistry,
) {
    let evict = |reason: &str| {
        eprintln!("rtlm route: evicting node '{}' — {reason}", node.name);
        if let Some(streams) = registry.lock().unwrap().remove(&node.name) {
            for stream in streams {
                let _ = stream.shutdown(Shutdown::Both);
            }
        }
        for &lane in &lane_ids {
            handle.fail_lane(lane, format!("node '{}' evicted: {reason}", node.name));
        }
    };

    let control = (|| -> Result<(TcpStream, BufReader<TcpStream>)> {
        let stream = TcpStream::connect(&node.addr)?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(interval.max(Duration::from_millis(50))))?;
        let mut writer = stream.try_clone()?;
        wire::write_magic(&mut writer)?;
        let mut reader = BufReader::new(stream);
        wire::read_magic(&mut reader)?;
        Ok((writer, reader))
    })();
    let (mut writer, mut reader) = match control {
        Ok(conn) => conn,
        Err(e) => return evict(&format!("control connection failed: {e:#}")),
    };

    let mut misses = 0u32;
    let mut seq = 0u64;
    loop {
        thread::sleep(interval);
        seq += 1;
        let ping = wire::frame("ping", vec![("seq", Json::Num(seq as f64))]);
        let answered = wire::write_frame(&mut writer, &ping).is_ok()
            && matches!(
                wire::read_frame(&mut reader),
                Ok(Some(ref msg)) if wire::frame_type(msg) == "pong"
            );
        if answered {
            misses = 0;
            continue;
        }
        misses += 1;
        if misses >= 2 {
            return evict(&format!("missed {misses} consecutive heartbeats"));
        }
    }
}
