//! Measurement utilities: sample summaries, quantiles, ASCII tables and
//! text histograms used by the bench harness to print paper-style
//! tables/figures.

pub mod summary;
pub mod table;

pub use summary::Samples;
pub use table::{histogram, Table};
