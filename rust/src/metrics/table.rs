//! ASCII table and text-histogram rendering for the bench harness
//! (stands in for the paper's plotted figures — each figure becomes a
//! printed series the shape of which can be compared to the paper).

/// Simple column-aligned ASCII table.
#[derive(Debug, Default)]
pub struct Table {
    /// Title printed above the table.
    pub title: String,
    /// Column headers.
    pub header: Vec<String>,
    /// Body rows (each the header's arity).
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// Start a table with the given title and column headers.
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (must match the header's arity).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render to a column-aligned string.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<width$}", c, width = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Render to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Render a labelled horizontal bar chart (max width `width` chars).
pub fn bar_chart(title: &str, entries: &[(String, f64)], width: usize) -> String {
    let max = entries.iter().map(|(_, v)| *v).fold(f64::MIN, f64::max).max(1e-12);
    let label_w = entries.iter().map(|(l, _)| l.len()).max().unwrap_or(0);
    let mut out = format!("-- {title} --\n");
    for (label, value) in entries {
        let n = ((value / max) * width as f64).round() as usize;
        out.push_str(&format!(
            "{:<label_w$} | {}{} {:.3}\n",
            label,
            "#".repeat(n),
            " ".repeat(width - n),
            value,
        ));
    }
    out
}

/// Format a float with a fixed number of decimals.
pub fn fmt_f(x: f64, decimals: usize) -> String {
    format!("{:.*}", decimals, x)
}

/// Render a compact text histogram of samples (the paper's violin/CDF
/// plots become printable distributions).
pub fn histogram(title: &str, values: &[f64], n_bins: usize, width: usize) -> String {
    if values.is_empty() {
        return format!("-- {title} -- (no samples)\n");
    }
    let lo = values.iter().copied().fold(f64::INFINITY, f64::min);
    let hi = values.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let span = (hi - lo).max(1e-12);
    let mut bins = vec![0usize; n_bins.max(1)];
    for &v in values {
        let idx = (((v - lo) / span) * n_bins as f64) as usize;
        bins[idx.min(n_bins - 1)] += 1;
    }
    let max_count = bins.iter().copied().max().unwrap_or(1).max(1);
    let mut out = format!("-- {title} (n={}) --\n", values.len());
    for (i, count) in bins.iter().enumerate() {
        let b_lo = lo + span * i as f64 / n_bins as f64;
        let b_hi = lo + span * (i + 1) as f64 / n_bins as f64;
        let bar = "#".repeat(count * width / max_count);
        out.push_str(&format!("[{b_lo:6.2}, {b_hi:6.2}) |{bar:<width$}| {count}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(vec!["alpha".into(), "1.0".into()]);
        t.row(vec!["b".into(), "123.456".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        let lines: Vec<&str> = s.lines().collect();
        // all data lines equal width columns
        assert!(lines[1].starts_with("name"));
        assert!(lines[3].starts_with("alpha"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn rejects_wrong_arity() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn bar_chart_scales() {
        let s = bar_chart("t", &[("a".into(), 1.0), ("bb".into(), 2.0)], 10);
        assert!(s.contains("##########")); // the max bar hits full width
    }
}
