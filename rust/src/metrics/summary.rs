//! Exact sample summaries (datasets here are at most a few hundred
//! thousand points, so we keep everything and compute exact quantiles).

/// A growable set of f64 samples with exact summary statistics.
#[derive(Clone, Debug, Default)]
pub struct Samples {
    values: Vec<f64>,
    sorted: bool,
}

impl Samples {
    /// An empty sample set.
    pub fn new() -> Self {
        Self::default()
    }

    /// Adopt an existing vector of samples.
    pub fn from_vec(values: Vec<f64>) -> Self {
        Samples { values, sorted: false }
    }

    /// Append one sample.
    pub fn push(&mut self, x: f64) {
        self.values.push(x);
        self.sorted = false;
    }

    /// Append many samples.
    pub fn extend(&mut self, xs: impl IntoIterator<Item = f64>) {
        self.values.extend(xs);
        self.sorted = false;
    }

    /// Number of samples.
    pub fn len(&self) -> usize {
        self.values.len()
    }

    /// Are there no samples?
    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// The raw samples (order unspecified once quantiles were taken).
    pub fn values(&self) -> &[f64] {
        &self.values
    }

    /// Arithmetic mean (NaN when empty).
    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        self.values.iter().sum::<f64>() / self.values.len() as f64
    }

    /// Sample standard deviation (0 for fewer than two samples).
    pub fn std(&self) -> f64 {
        if self.values.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.values.iter().map(|x| (x - m).powi(2)).sum::<f64>()
            / (self.values.len() - 1) as f64)
            .sqrt()
    }

    /// Smallest sample (+inf when empty).
    pub fn min(&self) -> f64 {
        self.values.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Largest sample (-inf when empty).
    pub fn max(&self) -> f64 {
        self.values.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.values.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    /// Exact quantile with linear interpolation; q in [0, 1].
    pub fn quantile(&mut self, q: f64) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        self.ensure_sorted();
        let q = q.clamp(0.0, 1.0);
        let pos = q * (self.values.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        let frac = pos - lo as f64;
        self.values[lo] * (1.0 - frac) + self.values[hi] * frac
    }

    /// Median.
    pub fn p50(&mut self) -> f64 {
        self.quantile(0.5)
    }

    /// 95th percentile.
    pub fn p95(&mut self) -> f64 {
        self.quantile(0.95)
    }

    /// 99th percentile.
    pub fn p99(&mut self) -> f64 {
        self.quantile(0.99)
    }
}

/// Pearson correlation of two equal-length series (figure harness).
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if xs.is_empty() {
        return f64::NAN;
    }
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        cov += (x - mx) * (y - my);
        vx += (x - mx).powi(2);
        vy += (y - my).powi(2);
    }
    cov / (vx.sqrt() * vy.sqrt()).max(1e-12)
}

/// Least-squares slope/intercept of y on x (figure harness trend lines).
pub fn linregress(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        den += (x - mx).powi(2);
    }
    let slope = num / den.max(1e-12);
    (slope, my - slope * mx)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_stats() {
        let mut s = Samples::from_vec(vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.mean(), 2.5);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert_eq!(s.p50(), 2.5);
        assert_eq!(s.quantile(0.0), 1.0);
        assert_eq!(s.quantile(1.0), 4.0);
    }

    #[test]
    fn quantile_interpolates() {
        let mut s = Samples::from_vec(vec![0.0, 10.0]);
        assert!((s.quantile(0.25) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn empty_is_nan() {
        let mut s = Samples::new();
        assert!(s.mean().is_nan());
        assert!(s.quantile(0.5).is_nan());
    }

    #[test]
    fn std_of_constant_is_zero() {
        let s = Samples::from_vec(vec![3.0; 10]);
        assert_eq!(s.std(), 0.0);
    }

    #[test]
    fn pearson_perfect_correlation() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-9);
        let neg: Vec<f64> = ys.iter().map(|y| -y).collect();
        assert!((pearson(&xs, &neg) + 1.0).abs() < 1e-9);
    }

    #[test]
    fn linregress_recovers_line() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x + 7.0).collect();
        let (slope, intercept) = linregress(&xs, &ys);
        assert!((slope - 3.0).abs() < 1e-9);
        assert!((intercept - 7.0).abs() < 1e-9);
    }
}
