//! The latency model: batch duration on accelerator-kind lanes,
//! per-task duration on CPU-kind quarantine lanes, derived from
//! calibration measurements (preferred) or an analytic FLOPs estimate.
//!
//! All curves are keyed by model name, so an N-lane fleet draws each
//! lane's durations from its own model variant's calibration
//! ([`gpu_batch_secs`](LatencyModel::gpu_batch_secs) with the lane's
//! [`ModelEntry`] for [`LaneKind::Accelerator`] lanes,
//! [`cpu_task_secs`](LatencyModel::cpu_task_secs) per task for
//! [`LaneKind::Cpu`] pools — see `engine::sim_backend::SimLane` and
//! `executor::ModeledExecutor`, which share these exact functions).
//!
//! [`LaneKind::Accelerator`]: crate::scheduler::LaneKind::Accelerator
//! [`LaneKind::Cpu`]: crate::scheduler::LaneKind::Cpu

use std::collections::BTreeMap;

use anyhow::Result;

use crate::config::{DeviceProfile, Manifest, ModelEntry};
use crate::scheduler::Batch;

use super::calib::Calibration;

/// Effective CPU-lane slowdown vs the accelerator lane. The paper's
/// Fig. 6 shows CPU transfer ~ GPU execution per layer — for 100-400M
/// LMs a 96-core EPYC is nearly accelerator-comparable, so the lane
/// penalty is mild (the offload transfer overhead lives in
/// `DeviceProfile::offload_overhead`).
pub const CPU_LANE_SLOWDOWN: f64 = 1.2;

/// Analytic FLOPs throughput assumed when no calibration file exists.
const FALLBACK_FLOPS: f64 = 2.0e9;

/// Calibrated (or analytic) latency curves, keyed by model name.
#[derive(Clone, Debug)]
pub struct LatencyModel {
    /// model -> decode bucket -> seconds per decode step.
    decode: BTreeMap<String, BTreeMap<usize, f64>>,
    /// model -> (batch, seq) -> prefill seconds.
    prefill: BTreeMap<String, BTreeMap<(usize, usize), f64>>,
}

impl LatencyModel {
    /// Adopt measured curves from a [`Calibration`].
    pub fn from_calibration(calib: &Calibration) -> LatencyModel {
        LatencyModel { decode: calib.decode.clone(), prefill: calib.prefill.clone() }
    }

    /// FLOPs-based analytic model over the manifest's buckets. Batching
    /// efficiency follows `B^batching_exp` of the edge profile (real
    /// hardware amortises per-step overheads sublinearly).
    pub fn analytic(manifest: &Manifest) -> LatencyModel {
        let mut decode = BTreeMap::new();
        let mut prefill = BTreeMap::new();
        for (name, entry) in &manifest.models {
            let flops1 = entry.decode_flops_per_row(manifest.seq_max / 2);
            let t1 = flops1 / FALLBACK_FLOPS;
            let mut d = BTreeMap::new();
            for &b in &manifest.decode_batch_buckets {
                d.insert(b, t1 * (b as f64).powf(0.55));
            }
            decode.insert(name.clone(), d);
            let mut p = BTreeMap::new();
            for &b in &manifest.prefill_batch_buckets {
                for &s in &manifest.prefill_seq_buckets {
                    p.insert((b, s), t1 * (s as f64) * 0.25 * (b as f64).powf(0.55));
                }
            }
            prefill.insert(name.clone(), p);
        }
        LatencyModel { decode, prefill }
    }

    /// Do both curve tables cover `model`? Called at every resolution
    /// boundary that binds a lane to a model (`engine::sim_backend`'s
    /// lane resolution, `executor::modeled_factory`), so a misnamed
    /// `--lanes` variant is an error at construction instead of
    /// silently simulating with a placeholder latency. The per-step
    /// accessors below panic as a backstop for callers that skip it.
    pub fn require_model(&self, model: &str) -> Result<()> {
        if self.decode.get(model).map(|b| !b.is_empty()) != Some(true) {
            anyhow::bail!(
                "latency model has no decode curve for model '{model}' \
                 (known: {:?}) — misnamed lane/model variant?",
                self.decode.keys().collect::<Vec<_>>()
            );
        }
        if self.prefill.get(model).map(|b| !b.is_empty()) != Some(true) {
            anyhow::bail!(
                "latency model has no prefill curve for model '{model}' \
                 (known: {:?}) — misnamed lane/model variant?",
                self.prefill.keys().collect::<Vec<_>>()
            );
        }
        Ok(())
    }

    /// Seconds per decode step at the smallest bucket >= `n` rows.
    ///
    /// Panics on a model the curves do not cover — historically this
    /// returned a hardcoded 0.01 s, which silently skewed every result
    /// of a misnamed lane variant. [`require_model`](Self::require_model)
    /// turns the same mistake into a proper error at construction.
    pub fn decode_step(&self, model: &str, n: usize) -> f64 {
        let Some(buckets) = self.decode.get(model) else {
            panic!("latency model has no decode curve for model '{model}'")
        };
        buckets
            .iter()
            .find(|(b, _)| **b >= n)
            .or_else(|| buckets.iter().last())
            .map(|(_, t)| *t)
            .unwrap_or_else(|| panic!("empty decode curve for model '{model}'"))
    }

    /// The decode bucket `n` rows pad to. Panics on an uncovered model,
    /// like [`decode_step`](Self::decode_step).
    pub fn decode_bucket(&self, model: &str, n: usize) -> usize {
        let Some(buckets) = self.decode.get(model) else {
            panic!("latency model has no decode curve for model '{model}'")
        };
        buckets
            .keys()
            .copied()
            .find(|b| *b >= n)
            .or_else(|| buckets.keys().copied().max())
            .unwrap_or(n)
    }

    /// Prefill seconds for `n` rows of max input length `s`. Panics on
    /// an uncovered model, like [`decode_step`](Self::decode_step).
    pub fn prefill_secs(&self, model: &str, n: usize, s: usize) -> f64 {
        let Some(buckets) = self.prefill.get(model) else {
            panic!("latency model has no prefill curve for model '{model}'")
        };
        // smallest covering bucket, by area
        let mut best: Option<((usize, usize), f64)> = None;
        for (&(b, bs), &t) in buckets {
            if b >= n && bs >= s {
                match best {
                    Some(((pb, pbs), _)) if pb * pbs <= b * bs => {}
                    _ => best = Some(((b, bs), t)),
                }
            }
        }
        match best {
            Some((_, t)) => t,
            None => {
                // batch exceeds largest prefill bucket: chunk at the
                // widest batch bucket that still covers the sequence
                let covering: Vec<(&(usize, usize), &f64)> =
                    buckets.iter().filter(|((_, bs), _)| *bs >= s).collect();
                let (&(maxb, _), &per) = covering
                    .iter()
                    .max_by_key(|((b, bs), _)| (*b, std::cmp::Reverse(*bs)))
                    .copied()
                    .or_else(|| {
                        buckets.iter().max_by_key(|((b, bs), _)| (*b, *bs))
                    })
                    .expect("no prefill buckets");
                let chunks = n.div_ceil(maxb.max(1));
                per * chunks as f64
            }
        }
    }

    /// Modeled accelerator decode step for a batch of `n` rows: the
    /// calibrated batch-1 cost, amortised up to the device's batching
    /// knee and linear beyond (CPU-PJRT executes rows serially — the
    /// simulated accelerator lane restores GPU-style batching on the
    /// measured anchor; DESIGN.md §Hardware-Adaptation).
    pub fn decode_step_dev(&self, model: &str, n: usize, dev: &DeviceProfile) -> f64 {
        let t1 = self.decode_step(model, 1);
        t1 * (n as f64 / dev.batch_knee).max(1.0)
    }

    /// Modeled accelerator prefill for `n` rows of max length `s`.
    pub fn prefill_secs_dev(&self, model: &str, n: usize, s: usize, dev: &DeviceProfile) -> f64 {
        let t1 = self.prefill_secs(model, 1, s);
        t1 * (n as f64 / dev.batch_knee).max(1.0)
    }

    /// Accelerator-lane duration of a batch: dispatch overhead + prefill
    /// + max-output-length decode steps, scaled by the device profile.
    pub fn gpu_batch_secs(&self, model: &ModelEntry, batch: &Batch, dev: &DeviceProfile) -> f64 {
        let n = batch.tasks.len();
        let s = batch.max_input_len();
        let steps = batch.max_true_len();
        let raw = self.prefill_secs_dev(&model.name, n, s, dev)
            + steps as f64 * self.decode_step_dev(&model.name, n, dev);
        dev.dispatch_overhead + dev.gpu_speed * raw
    }

    /// CPU-lane duration of ONE task: offload transfer + unbatched
    /// slowed-down execution.
    pub fn cpu_task_secs(&self, model: &ModelEntry, true_len: usize, input_len: usize, dev: &DeviceProfile) -> f64 {
        let raw = self.prefill_secs(&model.name, 1, input_len.max(1))
            + true_len as f64 * self.decode_step(&model.name, 1);
        dev.offload_overhead + dev.cpu_speed * CPU_LANE_SLOWDOWN * raw
    }

    /// Load calibration if present, else analytic fallback.
    pub fn load_or_analytic(manifest: &Manifest) -> Result<LatencyModel> {
        let calib_path = manifest.root.join("calib.json");
        if calib_path.exists() {
            Ok(Self::from_calibration(&Calibration::load(&calib_path)?))
        } else {
            Ok(Self::analytic(manifest))
        }
    }

    /// Batching efficiency curve used for Fig. 8a: normalised
    /// throughput-per-row gain of batch size B vs the best bucket, on
    /// the modeled accelerator lane.
    pub fn batching_utilisation(&self, model: &str, dev: &DeviceProfile) -> Vec<(usize, f64)> {
        let Some(buckets) = self.decode.get(model) else {
            panic!("latency model has no decode curve for model '{model}'")
        };
        let rates: Vec<(usize, f64)> = buckets
            .keys()
            .map(|&b| (b, b as f64 / self.decode_step_dev(model, b, dev).max(1e-12)))
            .collect();
        let best = rates.iter().map(|(_, r)| *r).fold(1e-12, f64::max);
        rates.into_iter().map(|(b, r)| (b, r / best)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model_for_test() -> LatencyModel {
        let mut decode = BTreeMap::new();
        decode.insert(
            "m".to_string(),
            BTreeMap::from([(1, 0.010), (4, 0.016), (16, 0.040)]),
        );
        let mut prefill = BTreeMap::new();
        prefill.insert(
            "m".to_string(),
            BTreeMap::from([((1, 16), 0.02), ((8, 16), 0.05), ((8, 64), 0.12)]),
        );
        LatencyModel { decode, prefill }
    }

    #[test]
    fn decode_rounds_up_to_bucket() {
        let lm = model_for_test();
        assert_eq!(lm.decode_step("m", 1), 0.010);
        assert_eq!(lm.decode_step("m", 3), 0.016);
        assert_eq!(lm.decode_step("m", 5), 0.040);
        assert_eq!(lm.decode_step("m", 99), 0.040); // clamps to max bucket
        assert_eq!(lm.decode_bucket("m", 3), 4);
    }

    #[test]
    fn prefill_picks_smallest_covering_bucket() {
        let lm = model_for_test();
        assert_eq!(lm.prefill_secs("m", 1, 10), 0.02);
        assert_eq!(lm.prefill_secs("m", 4, 16), 0.05);
        assert_eq!(lm.prefill_secs("m", 8, 40), 0.12);
    }

    #[test]
    fn oversized_batch_chunks_prefill() {
        let lm = model_for_test();
        // 20 rows at s=16 -> 3 chunks of the (8,16) bucket
        let t = lm.prefill_secs("m", 20, 16);
        assert!((t - 3.0 * 0.05).abs() < 1e-12, "{t}");
    }

    #[test]
    fn batching_utilisation_saturates_at_knee() {
        let lm = model_for_test();
        let dev = crate::config::DeviceProfile::edge_server(); // knee 12
        let util = lm.batching_utilisation("m", &dev);
        // below the knee throughput grows with B; the largest bucket
        // (16 > knee) saturates
        assert_eq!(util.len(), 3);
        assert!(util[0].1 < util[1].1, "{util:?}");
        assert!((util[2].1 - 1.0).abs() < 1e-9 || util[1].1 <= util[2].1, "{util:?}");
    }

    #[test]
    fn unknown_model_fails_loudly() {
        let lm = model_for_test();
        assert!(lm.require_model("m").is_ok());
        let err = lm.require_model("typo-model").unwrap_err().to_string();
        assert!(err.contains("typo-model"), "{err}");
        assert!(
            std::panic::catch_unwind(|| lm.decode_step("typo-model", 1)).is_err(),
            "decode_step must panic on an uncovered model"
        );
        assert!(
            std::panic::catch_unwind(|| lm.prefill_secs("typo-model", 1, 8)).is_err(),
            "prefill_secs must panic on an uncovered model"
        );
    }

    #[test]
    fn decode_step_dev_amortises_to_knee() {
        let lm = model_for_test();
        let dev = crate::config::DeviceProfile::edge_server(); // knee 12
        let t1 = lm.decode_step("m", 1);
        assert_eq!(lm.decode_step_dev("m", 4, &dev), t1);
        assert_eq!(lm.decode_step_dev("m", 12, &dev), t1);
        assert!((lm.decode_step_dev("m", 24, &dev) - 2.0 * t1).abs() < 1e-12);
    }
}
