//! Calibration data: measured PJRT latencies of the real artifacts,
//! written by `rtlm calibrate` to `artifacts/calib.json` and consumed by
//! the simulator's latency model.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::{obj, Json};

/// Measured latency curves, as stored in `artifacts/calib.json`.
#[derive(Clone, Debug, Default)]
pub struct Calibration {
    /// model -> decode bucket -> seconds per step.
    pub decode: BTreeMap<String, BTreeMap<usize, f64>>,
    /// model -> (batch, seq) -> prefill seconds.
    pub prefill: BTreeMap<String, BTreeMap<(usize, usize), f64>>,
    /// Measured native-regressor latency per task (seconds).
    pub regressor_secs: f64,
    /// Host the calibration was taken on (informational).
    pub note: String,
}

impl Calibration {
    /// Parse a calibration JSON file.
    pub fn load(path: &Path) -> Result<Calibration> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading calibration {}", path.display()))?;
        let v = Json::parse(&text).map_err(|e| anyhow!("parsing calibration: {e}"))?;
        let mut decode = BTreeMap::new();
        let mut prefill = BTreeMap::new();
        for (model, entry) in v.need_obj("models")? {
            let mut d = BTreeMap::new();
            for (bucket, secs) in entry.need_obj("decode")? {
                d.insert(
                    bucket.parse::<usize>()?,
                    secs.as_f64().ok_or_else(|| anyhow!("bad decode secs"))?,
                );
            }
            decode.insert(model.clone(), d);
            let mut p = BTreeMap::new();
            for (key, secs) in entry.need_obj("prefill")? {
                let (b, s) = key.split_once(',').ok_or_else(|| anyhow!("bad prefill key"))?;
                p.insert(
                    (b.parse()?, s.parse()?),
                    secs.as_f64().ok_or_else(|| anyhow!("bad prefill secs"))?,
                );
            }
            prefill.insert(model.clone(), p);
        }
        Ok(Calibration {
            decode,
            prefill,
            regressor_secs: v.get("regressor_secs").as_f64().unwrap_or(0.0),
            note: v.get("note").as_str().unwrap_or("").to_string(),
        })
    }

    /// Write the calibration as JSON.
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut models = Vec::new();
        for (model, d) in &self.decode {
            let decode_obj = Json::Obj(
                d.iter().map(|(b, t)| (b.to_string(), Json::Num(*t))).collect(),
            );
            let prefill_obj = Json::Obj(
                self.prefill
                    .get(model)
                    .map(|p| {
                        p.iter()
                            .map(|((b, s), t)| (format!("{b},{s}"), Json::Num(*t)))
                            .collect()
                    })
                    .unwrap_or_default(),
            );
            models.push((
                model.clone(),
                obj(vec![("decode", decode_obj), ("prefill", prefill_obj)]),
            ));
        }
        let root = obj(vec![
            (
                "models",
                Json::Obj(models.into_iter().collect()),
            ),
            ("regressor_secs", Json::Num(self.regressor_secs)),
            ("note", Json::Str(self.note.clone())),
        ]);
        std::fs::write(path, root.to_string())
            .with_context(|| format!("writing {}", path.display()))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips() {
        let mut c = Calibration {
            regressor_secs: 1e-5,
            note: "test".into(),
            ..Default::default()
        };
        c.decode
            .insert("t5".into(), BTreeMap::from([(1, 0.01), (8, 0.02)]));
        c.prefill
            .insert("t5".into(), BTreeMap::from([((1, 16), 0.03), ((8, 64), 0.1)]));
        let dir = std::env::temp_dir().join("rtlm_calib_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("calib.json");
        c.save(&path).unwrap();
        let back = Calibration::load(&path).unwrap();
        assert_eq!(back.decode["t5"][&8], 0.02);
        assert_eq!(back.prefill["t5"][&(8, 64)], 0.1);
        assert_eq!(back.note, "test");
    }
}
