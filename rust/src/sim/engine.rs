//! The discrete-event serving engine: Poisson arrivals feed the policy;
//! two lanes (accelerator + CPU quarantine) execute batches with
//! durations from the latency model; virtual time advances event by
//! event.
//!
//! The same policy objects drive the real-time server (`server`), so
//! scheduling behaviour in simulation and on the wire is identical by
//! construction.

use std::collections::HashMap;
use std::time::Instant;

use crate::config::{DeviceProfile, ModelEntry, SchedParams};
use crate::scheduler::{Lane, Policy, Task};

use super::latency::LatencyModel;
use super::results::{SimResult, TaskOutcome};

/// Alias kept for the public API surface.
pub type SimOutcome = SimResult;

/// Run one simulated serving session.
///
/// `tasks` carry their arrival times; the engine sorts them. Returns
/// per-task outcomes plus aggregate counters.
pub fn run_sim(
    mut tasks: Vec<Task>,
    policy: &mut dyn Policy,
    lat: &LatencyModel,
    model: &ModelEntry,
    dev: &DeviceProfile,
    params: &SchedParams,
) -> SimResult {
    tasks.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
    let n_total = tasks.len();

    let mut result = SimResult { policy: policy.name(), ..Default::default() };
    let mut idx = 0usize;
    let mut now = 0.0f64;
    let mut gpu_free = 0.0f64;
    // CPU-lane worker pool: offloaded tasks run batch-1, several in
    // parallel (dev.cpu_workers); the lane accepts a new batch when any
    // worker is free.
    let mut cpu_workers = vec![0.0f64; dev.cpu_workers.max(1)];
    // arrival time of every task currently inside the policy
    let mut waiting: HashMap<u64, f64> = HashMap::new();
    let mut sched_wall = 0.0f64;

    let guard_limit = 1000 + 100 * n_total;
    let mut iterations = 0usize;

    loop {
        iterations += 1;
        assert!(
            iterations < guard_limit,
            "simulation did not converge (policy {} stuck with {} waiting)",
            result.policy,
            waiting.len()
        );

        // -- admit arrivals --------------------------------------------------
        while idx < tasks.len() && tasks[idx].arrival <= now {
            let task = tasks[idx].clone();
            waiting.insert(task.id, task.arrival);
            let t0 = Instant::now();
            policy.push(task);
            sched_wall += t0.elapsed().as_secs_f64();
            idx += 1;
        }

        // -- dispatch idle lanes ---------------------------------------------
        let oldest = waiting.values().copied().fold(f64::INFINITY, f64::min);
        let no_more_arrivals = idx >= tasks.len();
        let force = no_more_arrivals || (now - oldest >= params.xi);

        if gpu_free <= now {
            let t0 = Instant::now();
            let batch = policy.pop_batch(Lane::Gpu, now, force);
            sched_wall += t0.elapsed().as_secs_f64();
            if let Some(batch) = batch {
                let duration = lat.gpu_batch_secs(model, &batch, dev);
                gpu_free = now + duration;
                result.n_batches_gpu += 1;
                for task in batch.tasks {
                    waiting.remove(&task.id);
                    result.outcomes.push(TaskOutcome {
                        id: task.id,
                        arrival: task.arrival,
                        completion: gpu_free,
                        priority_point: task.priority_point,
                        uncertainty: task.uncertainty,
                        true_len: task.true_len,
                        lane: Lane::Gpu,
                        utype: task.utype,
                        malicious: task.malicious,
                        infer_secs: duration,
                    });
                }
            }
        }

        let cpu_free = cpu_workers.iter().copied().fold(f64::INFINITY, f64::min);
        if cpu_free <= now {
            let t0 = Instant::now();
            let batch = policy.pop_batch(Lane::Cpu, now, force);
            sched_wall += t0.elapsed().as_secs_f64();
            if let Some(batch) = batch {
                result.n_batches_cpu += 1;
                for task in batch.tasks {
                    // earliest-free worker takes the task
                    let w = (0..cpu_workers.len())
                        .min_by(|&a, &b| {
                            cpu_workers[a].partial_cmp(&cpu_workers[b]).unwrap()
                        })
                        .unwrap();
                    let start = cpu_workers[w].max(now);
                    let dur = lat.cpu_task_secs(model, task.true_len, task.input_len, dev);
                    cpu_workers[w] = start + dur;
                    waiting.remove(&task.id);
                    result.outcomes.push(TaskOutcome {
                        id: task.id,
                        arrival: task.arrival,
                        completion: cpu_workers[w],
                        priority_point: task.priority_point,
                        uncertainty: task.uncertainty,
                        true_len: task.true_len,
                        lane: Lane::Cpu,
                        utype: task.utype,
                        malicious: task.malicious,
                        infer_secs: dur,
                    });
                }
            }
        }

        // -- advance to the next strictly-future event -----------------------
        let mut next = f64::INFINITY;
        if idx < tasks.len() {
            next = next.min(tasks[idx].arrival);
        }
        if gpu_free > now {
            next = next.min(gpu_free);
        }
        let cpu_free = cpu_workers.iter().copied().fold(f64::INFINITY, f64::min);
        if cpu_free > now && cpu_free.is_finite() {
            next = next.min(cpu_free);
        }
        if !waiting.is_empty() {
            // xi expiry wakes the dispatcher for a forced dispatch; if it
            // is already in the past the forced attempt above already ran,
            // so only a future expiry counts as an event.
            let oldest = waiting.values().copied().fold(f64::INFINITY, f64::min);
            if oldest + params.xi > now {
                next = next.min(oldest + params.xi);
            } else if next.is_infinite() {
                // both lanes idle, force already attempted, still stuck:
                // the policy refuses to emit — that's a bug, not a wait.
                panic!(
                    "policy {} deadlocked with {} waiting tasks",
                    result.policy,
                    waiting.len()
                );
            }
        }
        if next.is_infinite() {
            break; // no arrivals, nothing waiting, lanes idle
        }
        now = next.max(now);
    }

    result.makespan = result
        .outcomes
        .iter()
        .map(|o| o.completion)
        .fold(0.0, f64::max);
    result.sched_wall_secs = sched_wall;
    assert_eq!(
        result.outcomes.len(),
        n_total,
        "policy {} lost tasks",
        result.policy
    );
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DeviceProfile, SchedParams};
    use crate::scheduler::{Fifo, PolicyKind, Task};
    use crate::sim::latency::LatencyModel;
    use crate::util::prop;
    use crate::util::rng::Pcg64;
    use std::collections::BTreeMap;

    fn test_model() -> ModelEntry {
        ModelEntry::stub("m", 0.05, 0.08)
    }

    fn test_lat() -> LatencyModel {
        // hand-built via calibration struct for determinism
        let mut c = crate::sim::calib::Calibration::default();
        c.decode.insert(
            "m".into(),
            BTreeMap::from([(1, 0.01), (4, 0.018), (16, 0.04)]),
        );
        c.prefill.insert(
            "m".into(),
            BTreeMap::from([((1, 16), 0.02), ((8, 64), 0.08)]),
        );
        LatencyModel::from_calibration(&c)
    }

    fn mk_task(id: u64, arrival: f64, u: f64, len: usize) -> Task {
        Task {
            id,
            text: String::new(),
            prompt: vec![],
            arrival,
            priority_point: arrival + 2.0,
            uncertainty: u,
            true_len: len,
            input_len: 8,
            utype: "plain".into(),
            malicious: false,
            deferrals: 0,
        }
    }

    #[test]
    fn fifo_single_task_completes() {
        let tasks = vec![mk_task(0, 0.0, 10.0, 10)];
        let mut policy = Fifo::new(4);
        let r = run_sim(
            tasks,
            &mut policy,
            &test_lat(),
            &test_model(),
            &DeviceProfile::edge_server(),
            &SchedParams::default(),
        );
        assert_eq!(r.outcomes.len(), 1);
        // forced dispatch happens immediately (no more arrivals)
        let rt = r.outcomes[0].response_time();
        assert!(rt > 0.0 && rt < 1.0, "rt {rt}");
    }

    #[test]
    fn completes_all_tasks_every_policy() {
        let params = SchedParams { batch_size: 4, ..Default::default() };
        let model = test_model();
        let lat = test_lat();
        let dev = DeviceProfile::edge_server();
        let mut rng = Pcg64::new(5);
        let tasks: Vec<Task> = (0..60)
            .map(|i| {
                mk_task(
                    i,
                    rng.f64() * 20.0,
                    4.0 + rng.f64() * 90.0,
                    4 + rng.range_usize(0, 90),
                )
            })
            .collect();
        for kind in PolicyKind::ALL_BASELINES {
            let mut policy = kind.build(&params, model.eta, 60.0);
            let r = run_sim(tasks.clone(), &mut *policy, &lat, &model, &dev, &params);
            assert_eq!(r.outcomes.len(), 60, "{}", kind.label());
            assert!(r.makespan > 0.0);
            assert!(r.throughput_per_min() > 0.0);
        }
    }

    #[test]
    fn completion_after_arrival_invariant() {
        prop::check_result(
            "sim-causality",
            50,
            |rng| {
                let n = rng.range_usize(1, 80);
                (0..n)
                    .map(|i| {
                        mk_task(
                            i as u64,
                            rng.f64() * 30.0,
                            4.0 + rng.f64() * 90.0,
                            4 + rng.range_usize(0, 90),
                        )
                    })
                    .collect::<Vec<_>>()
            },
            |tasks| {
                let params = SchedParams { batch_size: 4, ..Default::default() };
                let mut policy =
                    PolicyKind::RtLm.build(&params, 0.05, 60.0);
                let r = run_sim(
                    tasks.clone(),
                    &mut *policy,
                    &test_lat(),
                    &test_model(),
                    &DeviceProfile::edge_server(),
                    &params,
                );
                for o in &r.outcomes {
                    if o.completion <= o.arrival {
                        return Err(format!("task {} completed before arrival", o.id));
                    }
                }
                if r.outcomes.len() != tasks.len() {
                    return Err("task count mismatch".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn high_uncertainty_tasks_take_cpu_lane_under_rtlm() {
        let params = SchedParams { batch_size: 2, ..Default::default() };
        let mut policy = PolicyKind::RtLm.build(&params, 0.05, 50.0);
        let tasks = vec![
            mk_task(0, 0.0, 90.0, 90), // malicious
            mk_task(1, 0.0, 10.0, 10),
            mk_task(2, 0.1, 12.0, 12),
        ];
        let r = run_sim(
            tasks,
            &mut *policy,
            &test_lat(),
            &test_model(),
            &DeviceProfile::edge_server(),
            &params,
        );
        let by_id: HashMap<u64, &TaskOutcome> = r.outcomes.iter().map(|o| (o.id, o)).collect();
        assert_eq!(by_id[&0].lane, Lane::Cpu);
        assert_eq!(by_id[&1].lane, Lane::Gpu);
    }

    #[test]
    fn xavier_profile_is_slower() {
        let params = SchedParams { batch_size: 4, ..Default::default() };
        let model = test_model();
        let lat = test_lat();
        let mut rng = Pcg64::new(9);
        let tasks: Vec<Task> = (0..40)
            .map(|i| mk_task(i, rng.f64() * 10.0, 20.0, 20 + rng.range_usize(0, 40)))
            .collect();
        let mut p1 = PolicyKind::Fifo.build(&params, model.eta, f64::INFINITY);
        let edge = run_sim(
            tasks.clone(),
            &mut *p1,
            &lat,
            &model,
            &DeviceProfile::edge_server(),
            &params,
        );
        let mut p2 = PolicyKind::Fifo.build(&params, model.eta, f64::INFINITY);
        let agx = run_sim(
            tasks,
            &mut *p2,
            &lat,
            &model,
            &DeviceProfile::agx_xavier(),
            &params,
        );
        assert!(agx.mean_response() > edge.mean_response());
    }
}
