//! The discrete-event serving engine: Poisson arrivals feed the policy;
//! an N-lane fleet (accelerator variants + CPU quarantine pools)
//! executes batches with durations from the latency model; virtual time
//! advances event by event.
//!
//! Since the dispatcher-core unification this is a thin wrapper: the
//! loop itself lives in [`crate::engine::run_engine`], driven here by
//! the virtual-clock [`SimBackend`]. The wall-clock server drives the
//! *same* loop, so scheduling behaviour in simulation and on the wire is
//! identical by construction — and the cross-backend property test in
//! `rust/tests/engine_core.rs` asserts it for two-lane and N-lane
//! fleets alike.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::config::{DeviceProfile, ModelEntry, SchedParams};
use crate::engine::{resolve_lanes, run_engine, SimBackend, SimLane};
use crate::scheduler::{LaneSet, Policy, Task};

use super::latency::LatencyModel;
use super::results::SimResult;

/// Alias kept for the public API surface.
pub type SimOutcome = SimResult;

/// Run one simulated serving session on the historical two-lane fleet
/// (accelerator + CPU quarantine pool, both serving `model`).
///
/// `tasks` carry their arrival times; the engine sorts them. Returns
/// per-task outcomes plus aggregate counters.
pub fn run_sim(
    tasks: Vec<Task>,
    policy: &mut dyn Policy,
    lat: &LatencyModel,
    model: &ModelEntry,
    dev: &DeviceProfile,
    params: &SchedParams,
) -> SimResult {
    let lanes = vec![
        SimLane {
            kind: crate::scheduler::LaneKind::Accelerator,
            model: model.clone(),
            workers: 1,
            batch_size: None,
        },
        SimLane {
            kind: crate::scheduler::LaneKind::Cpu,
            model: model.clone(),
            workers: dev.cpu_workers.max(1),
            batch_size: None,
        },
    ];
    run_sim_on(tasks, policy, lat, lanes, vec!["gpu".into(), "cpu".into()], dev, params)
}

/// Run one simulated serving session over an arbitrary lane fleet:
/// every lane's model variant is resolved from `models`, its worker
/// count from the spec (defaulting to the device profile).
pub fn run_sim_lanes(
    tasks: Vec<Task>,
    policy: &mut dyn Policy,
    lat: &LatencyModel,
    lane_set: &LaneSet,
    models: &BTreeMap<String, ModelEntry>,
    dev: &DeviceProfile,
    params: &SchedParams,
) -> Result<SimResult> {
    let lanes = resolve_lanes(lane_set, models, lat, dev)?;
    Ok(run_sim_on(tasks, policy, lat, lanes, lane_set.names(), dev, params))
}

fn run_sim_on(
    mut tasks: Vec<Task>,
    policy: &mut dyn Policy,
    lat: &LatencyModel,
    lanes: Vec<SimLane>,
    lane_names: Vec<String>,
    dev: &DeviceProfile,
    params: &SchedParams,
) -> SimResult {
    tasks.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
    let n_total = tasks.len();
    let mut backend = SimBackend::new(tasks, lat, lanes, dev, params);
    let report = run_engine(&mut backend, policy, params, n_total)
        .expect("the virtual-clock backend cannot fail");
    let makespan = report
        .outcomes
        .iter()
        .map(|o| o.completion)
        .fold(0.0, f64::max);
    SimResult {
        policy: report.policy,
        outcomes: report.outcomes,
        makespan,
        sched_wall_secs: report.sched_secs,
        lanes: lane_names,
        n_batches: report.n_batches,
        n_steps: report.n_steps,
        n_preempted: report.n_preempted,
        n_shed: report.n_shed,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DeviceProfile, SchedParams};
    use crate::scheduler::{Fifo, LaneId, LaneSet, PolicyKind, Task};
    use crate::sim::latency::LatencyModel;
    use crate::sim::results::TaskOutcome;
    use crate::util::prop;
    use crate::util::rng::Pcg64;
    use std::collections::{BTreeMap, HashMap};

    fn test_model() -> ModelEntry {
        ModelEntry::stub("m", 0.05, 0.08)
    }

    fn test_lat() -> LatencyModel {
        // hand-built via calibration struct for determinism
        let mut c = crate::sim::calib::Calibration::default();
        c.decode.insert(
            "m".into(),
            BTreeMap::from([(1, 0.01), (4, 0.018), (16, 0.04)]),
        );
        c.prefill.insert(
            "m".into(),
            BTreeMap::from([((1, 16), 0.02), ((8, 64), 0.08)]),
        );
        LatencyModel::from_calibration(&c)
    }

    fn mk_task(id: u64, arrival: f64, u: f64, len: usize) -> Task {
        Task {
            id,
            text: String::new(),
            prompt: vec![],
            arrival,
            priority_point: arrival + 2.0,
            uncertainty: u,
            true_len: len,
            input_len: 8,
            utype: "plain".into(),
            malicious: false,
            deferrals: 0,
            slo: crate::scheduler::SloClass::Standard,
        }
    }

    fn two_lane(tau: f64) -> LaneSet {
        LaneSet::two_lane("m", tau)
    }

    #[test]
    fn fifo_single_task_completes() {
        let tasks = vec![mk_task(0, 0.0, 10.0, 10)];
        let mut policy = Fifo::new(4);
        let r = run_sim(
            tasks,
            &mut policy,
            &test_lat(),
            &test_model(),
            &DeviceProfile::edge_server(),
            &SchedParams::default(),
        );
        assert_eq!(r.outcomes.len(), 1);
        // forced dispatch happens immediately (no more arrivals)
        let rt = r.outcomes[0].response_time();
        assert!(rt > 0.0 && rt < 1.0, "rt {rt}");
    }

    #[test]
    fn completes_all_tasks_every_policy() {
        let params = SchedParams { batch_size: 4, ..Default::default() };
        let model = test_model();
        let lat = test_lat();
        let dev = DeviceProfile::edge_server();
        let mut rng = Pcg64::new(5);
        let tasks: Vec<Task> = (0..60)
            .map(|i| {
                mk_task(
                    i,
                    rng.f64() * 20.0,
                    4.0 + rng.f64() * 90.0,
                    4 + rng.range_usize(0, 90),
                )
            })
            .collect();
        for kind in PolicyKind::ALL_BASELINES {
            let mut policy = kind.build(&params, model.eta, &two_lane(60.0));
            let r = run_sim(tasks.clone(), &mut *policy, &lat, &model, &dev, &params);
            assert_eq!(r.outcomes.len(), 60, "{}", kind.label());
            assert!(r.makespan > 0.0);
            assert!(r.throughput_per_min() > 0.0);
        }
    }

    #[test]
    fn completion_after_arrival_invariant() {
        prop::check_result(
            "sim-causality",
            50,
            |rng| {
                let n = rng.range_usize(1, 80);
                (0..n)
                    .map(|i| {
                        mk_task(
                            i as u64,
                            rng.f64() * 30.0,
                            4.0 + rng.f64() * 90.0,
                            4 + rng.range_usize(0, 90),
                        )
                    })
                    .collect::<Vec<_>>()
            },
            |tasks| {
                let params = SchedParams { batch_size: 4, ..Default::default() };
                let mut policy =
                    PolicyKind::RtLm.build(&params, 0.05, &two_lane(60.0));
                let r = run_sim(
                    tasks.clone(),
                    &mut *policy,
                    &test_lat(),
                    &test_model(),
                    &DeviceProfile::edge_server(),
                    &params,
                );
                for o in &r.outcomes {
                    if o.completion <= o.arrival {
                        return Err(format!("task {} completed before arrival", o.id));
                    }
                }
                if r.outcomes.len() != tasks.len() {
                    return Err("task count mismatch".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn high_uncertainty_tasks_take_cpu_lane_under_rtlm() {
        let params = SchedParams { batch_size: 2, ..Default::default() };
        let mut policy = PolicyKind::RtLm.build(&params, 0.05, &two_lane(50.0));
        let tasks = vec![
            mk_task(0, 0.0, 90.0, 90), // malicious
            mk_task(1, 0.0, 10.0, 10),
            mk_task(2, 0.1, 12.0, 12),
        ];
        let r = run_sim(
            tasks,
            &mut *policy,
            &test_lat(),
            &test_model(),
            &DeviceProfile::edge_server(),
            &params,
        );
        let by_id: HashMap<u64, &TaskOutcome> = r.outcomes.iter().map(|o| (o.id, o)).collect();
        assert_eq!(by_id[&0].lane, LaneId::CPU);
        assert_eq!(by_id[&1].lane, LaneId::GPU);
    }

    #[test]
    fn three_lane_fleet_serves_every_band() {
        // two accelerator variants + quarantine: each lane's traffic is
        // decided by its admission predicate, and all of it completes.
        use crate::scheduler::{Admission, LaneSpec};
        let params = SchedParams { batch_size: 2, ..Default::default() };
        let lane_set = LaneSet::new(vec![
            LaneSpec::accelerator("big", "m"),
            LaneSpec {
                admission: Admission::AtMost(20.0),
                ..LaneSpec::accelerator("small", "m")
            },
            LaneSpec::cpu_offload("cpu", "m", 60.0),
        ])
        .unwrap();
        let models = BTreeMap::from([("m".to_string(), test_model())]);
        let mut policy = PolicyKind::RtLm.build(&params, 0.05, &lane_set);
        let tasks = vec![
            mk_task(0, 0.0, 10.0, 10), // -> small
            mk_task(1, 0.0, 40.0, 40), // -> big
            mk_task(2, 0.1, 90.0, 90), // -> cpu
            mk_task(3, 0.1, 12.0, 12), // -> small
        ];
        let r = run_sim_lanes(
            tasks,
            &mut *policy,
            &test_lat(),
            &lane_set,
            &models,
            &DeviceProfile::edge_server(),
            &params,
        )
        .expect("3-lane sim");
        assert_eq!(r.outcomes.len(), 4);
        let by_id: HashMap<u64, &TaskOutcome> = r.outcomes.iter().map(|o| (o.id, o)).collect();
        assert_eq!(by_id[&0].lane, LaneId(1));
        assert_eq!(by_id[&1].lane, LaneId(0));
        assert_eq!(by_id[&2].lane, LaneId(2));
        assert_eq!(by_id[&3].lane, LaneId(1));
        assert_eq!(r.lanes, vec!["big", "small", "cpu"]);
        assert!(r.n_batches.iter().all(|&n| n >= 1), "{:?}", r.n_batches);
    }

    #[test]
    fn xavier_profile_is_slower() {
        let params = SchedParams { batch_size: 4, ..Default::default() };
        let model = test_model();
        let lat = test_lat();
        let mut rng = Pcg64::new(9);
        let tasks: Vec<Task> = (0..40)
            .map(|i| mk_task(i, rng.f64() * 10.0, 20.0, 20 + rng.range_usize(0, 40)))
            .collect();
        let no_offload = two_lane(f64::INFINITY);
        let mut p1 = PolicyKind::Fifo.build(&params, model.eta, &no_offload);
        let edge = run_sim(
            tasks.clone(),
            &mut *p1,
            &lat,
            &model,
            &DeviceProfile::edge_server(),
            &params,
        );
        let mut p2 = PolicyKind::Fifo.build(&params, model.eta, &no_offload);
        let agx = run_sim(
            tasks,
            &mut *p2,
            &lat,
            &model,
            &DeviceProfile::agx_xavier(),
            &params,
        );
        assert!(agx.mean_response() > edge.mean_response());
    }

    #[test]
    fn nan_uncertainty_completes_under_every_policy() {
        // a regressor bug must degrade gracefully, never panic the engine
        let params = SchedParams { batch_size: 2, ..Default::default() };
        let model = test_model();
        let lat = test_lat();
        let dev = DeviceProfile::edge_server();
        let mut tasks: Vec<Task> = (0..8)
            .map(|i| mk_task(i, i as f64 * 0.1, 10.0 + i as f64, 10))
            .collect();
        tasks[3].uncertainty = f64::NAN;
        tasks[6].uncertainty = f64::NAN;
        for kind in PolicyKind::ALL_BASELINES {
            let mut policy = kind.build(&params, model.eta, &two_lane(60.0));
            let r = run_sim(tasks.clone(), &mut *policy, &lat, &model, &dev, &params);
            assert_eq!(r.outcomes.len(), 8, "{} lost NaN tasks", kind.label());
        }
    }

    #[test]
    fn step_mode_completes_and_counts_steps() {
        use crate::config::SchedMode;
        // iteration-level dispatch: everything completes, and the
        // accelerator lane's decode-iteration counter is exactly the
        // summed generation lengths (no preemption: factor disabled)
        let params = SchedParams {
            batch_size: 4,
            mode: SchedMode::Step,
            overrun_factor: f64::INFINITY,
            ..Default::default()
        };
        let mut rng = Pcg64::new(3);
        let tasks: Vec<Task> = (0..24)
            .map(|i| mk_task(i, rng.f64() * 6.0, 10.0, 4 + rng.range_usize(0, 40)))
            .collect();
        let total_len: usize = tasks.iter().map(|t| t.true_len).sum();
        let mut policy = Fifo::new(4);
        let r = run_sim(
            tasks,
            &mut policy,
            &test_lat(),
            &test_model(),
            &DeviceProfile::edge_server(),
            &params,
        );
        assert_eq!(r.outcomes.len(), 24);
        assert_eq!(r.n_steps[LaneId::GPU.index()], total_len);
        assert_eq!(r.n_preempted, 0);
        for o in &r.outcomes {
            assert!(o.first_token > o.arrival, "task {} ttft not positive", o.id);
            assert!(o.first_token <= o.completion, "task {} first token after completion", o.id);
        }
    }

    #[test]
    fn step_mode_improves_ttft_on_heavy_tails() {
        use crate::config::SchedMode;
        // one predicted-long task pins every co-batched short one in
        // whole-batch mode; iteration-level leave releases the shorts
        let mut rng = Pcg64::new(11);
        let tasks: Vec<Task> = (0..32)
            .map(|i| {
                // heavy-tailed lengths: mostly short, a few very long
                let len = if rng.f64() < 0.15 { 80 + rng.range_usize(0, 16) } else { 4 + rng.range_usize(0, 8) };
                mk_task(i, rng.f64() * 4.0, len as f64, len)
            })
            .collect();
        let run = |mode: SchedMode| {
            let params = SchedParams {
                batch_size: 8,
                mode,
                overrun_factor: f64::INFINITY,
                ..Default::default()
            };
            let mut policy = Fifo::new(8);
            run_sim(
                tasks.clone(),
                &mut policy,
                &test_lat(),
                &test_model(),
                &DeviceProfile::edge_server(),
                &params,
            )
        };
        let batch = run(SchedMode::Batch);
        let step = run(SchedMode::Step);
        assert_eq!(step.outcomes.len(), batch.outcomes.len());
        assert!(
            step.mean_response() < batch.mean_response(),
            "step {} !< batch {}",
            step.mean_response(),
            batch.mean_response()
        );
        assert!(
            step.ttft_times().p95() < batch.ttft_times().p95(),
            "step ttft p95 {} !< batch {}",
            step.ttft_times().p95(),
            batch.ttft_times().p95()
        );
    }

    #[test]
    fn xi_expiry_forces_partial_batch() {
        // two tasks at t=0 with C=4: nothing dispatches until the ξ=2s
        // wait interval expires, then the partial batch goes out forced
        let params = SchedParams { batch_size: 4, ..Default::default() };
        let tasks = vec![
            mk_task(0, 0.0, 10.0, 10),
            mk_task(1, 0.0, 12.0, 12),
            mk_task(2, 10.0, 14.0, 14),
        ];
        let mut policy = Fifo::new(4);
        let r = run_sim(
            tasks,
            &mut policy,
            &test_lat(),
            &test_model(),
            &DeviceProfile::edge_server(),
            &params,
        );
        let by_id: HashMap<u64, &TaskOutcome> = r.outcomes.iter().map(|o| (o.id, o)).collect();
        // forced at t = ξ = 2.0, not at t = 10 when the trace drains
        let xi = params.xi;
        assert!(
            by_id[&0].completion >= xi && by_id[&0].completion < 4.0,
            "first batch should dispatch at the ξ expiry: {}",
            by_id[&0].completion
        );
        assert!(by_id[&2].completion >= 10.0);
        assert_eq!(r.n_batches[LaneId::GPU.index()], 2);
    }
}
