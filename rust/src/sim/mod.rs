//! Discrete-event simulator with a calibrated latency model.
//!
//! Paper-scale experiments (five LMs x three variance subsets x five
//! policies x minutes of Poisson arrivals at beta up to 150/min) cannot
//! run wall-clock on this testbed; the simulator replays them in virtual
//! time, with per-batch durations taken from *measured* PJRT latencies
//! of the real artifacts (`rtlm calibrate` -> `artifacts/calib.json`) or
//! an analytic FLOPs model when no calibration exists.

pub mod calib;
pub mod engine;
pub mod latency;
pub mod results;

pub use calib::Calibration;
pub use engine::{run_sim, run_sim_lanes, SimOutcome};
pub use latency::LatencyModel;
pub use results::{slo_summary, SimResult, SloSummary, TaskOutcome};
