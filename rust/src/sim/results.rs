//! Simulation outcomes: per-task records and the aggregate metrics the
//! paper reports (response-time distribution, maximum response time,
//! throughput, priority-point misses).

use crate::metrics::Samples;
use crate::scheduler::{LaneId, SloClass};
use crate::util::json::{obj, Json};

/// Everything the engine accounted for one completed task.
#[derive(Clone, Debug)]
pub struct TaskOutcome {
    /// Task id.
    pub id: u64,
    /// Arrival time on the engine clock (seconds).
    pub arrival: f64,
    /// Completion time on the engine clock (seconds).
    pub completion: f64,
    /// Engine-clock time the first output token was ready: prefill end
    /// for whole-batch dispatch, the task's own first decode step for
    /// iteration-level dispatch — so TTFT is comparable across modes.
    pub first_token: f64,
    /// Absolute priority point d_J the task was scheduled against.
    pub priority_point: f64,
    /// Uncertainty score u_J the task was scheduled with.
    pub uncertainty: f64,
    /// Ground-truth output length (tokens).
    pub true_len: usize,
    /// Lane the task executed on.
    pub lane: LaneId,
    /// Primary uncertainty type (diagnostics).
    pub utype: String,
    /// Whether the task was adversarially crafted (Sec. V-G).
    pub malicious: bool,
    /// Pure model-inference time of the batch this task rode in.
    pub infer_secs: f64,
    /// Dropped by overload admission control instead of executing:
    /// `completion == first_token == arrival` and `infer_secs == 0`.
    /// Serving front-ends reply `{"error":"shed"}` for these.
    pub shed: bool,
    /// SLO class the task was submitted under ([`SloClass::Standard`]
    /// for classless traffic — such outcomes export no class columns).
    pub slo: SloClass,
}

impl TaskOutcome {
    /// Response time: completion minus arrival (the paper's headline
    /// metric).
    pub fn response_time(&self) -> f64 {
        self.completion - self.arrival
    }

    /// Time to first token: first output token minus arrival (the
    /// latency metric iteration-level scheduling exists to improve).
    pub fn ttft(&self) -> f64 {
        self.first_token - self.arrival
    }

    /// Did the task complete after its priority point?
    pub fn missed(&self) -> bool {
        self.completion > self.priority_point
    }

    /// SLO attainment for this task: it actually executed (was not
    /// shed) and completed by its priority point. Shed tasks count as
    /// violations — dropping a request never satisfies its SLO.
    pub fn deadline_met(&self) -> bool {
        !self.shed && !self.missed()
    }
}

/// Per-class SLO attainment over one run's outcomes (pure accounting:
/// classes carry no scheduler state, see [`SloClass`]).
#[derive(Clone, Debug)]
pub struct SloSummary {
    /// The class this row aggregates.
    pub class: SloClass,
    /// Tasks submitted under the class (including shed ones).
    pub n: usize,
    /// Tasks whose [`TaskOutcome::deadline_met`] held.
    pub met: usize,
    /// Tasks dropped by overload admission control (subset of `n - met`).
    pub shed: usize,
}

impl SloSummary {
    /// Fraction of the class's tasks that met their deadline (0 for an
    /// empty class).
    pub fn attainment(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        self.met as f64 / self.n as f64
    }
}

/// Group outcomes by SLO class, in declaration order (standard,
/// interactive, batch), skipping classes with no tasks. Shared by
/// [`SimResult`], the wire engine's report, and the serving report.
pub fn slo_summary(outcomes: &[TaskOutcome]) -> Vec<SloSummary> {
    let mut by_class = std::collections::BTreeMap::<SloClass, SloSummary>::new();
    for o in outcomes {
        let row = by_class.entry(o.slo).or_insert(SloSummary {
            class: o.slo,
            n: 0,
            met: 0,
            shed: 0,
        });
        row.n += 1;
        if o.deadline_met() {
            row.met += 1;
        }
        if o.shed {
            row.shed += 1;
        }
    }
    by_class.into_values().collect()
}

/// Aggregate outcome of one simulated serving run.
#[derive(Clone, Debug, Default)]
pub struct SimResult {
    /// Name the policy reported for itself (e.g. "RT-LM").
    pub policy: String,
    /// Per-task outcomes, in completion order.
    pub outcomes: Vec<TaskOutcome>,
    /// Virtual time at which the last task completed.
    pub makespan: f64,
    /// Wall-clock seconds the policy itself consumed (scheduling
    /// overhead — Table VII measures this for the real implementation).
    pub sched_wall_secs: f64,
    /// Lane names, in [`LaneId`] order (the default two-lane fleet is
    /// `["gpu", "cpu"]`).
    pub lanes: Vec<String>,
    /// Dispatched batches per lane, indexed like `lanes` (join groups
    /// on stepped lanes).
    pub n_batches: Vec<usize>,
    /// Decode iterations per lane, indexed like `lanes` (see
    /// `engine::BatchDone::steps`). Exact-matched by step-mode parity.
    pub n_steps: Vec<usize>,
    /// Generations preempted mid-flight to another lane (step mode).
    pub n_preempted: usize,
    /// Tasks dropped by overload admission control (their outcomes are
    /// still present, flagged [`TaskOutcome::shed`]).
    pub n_shed: usize,
}

impl SimResult {
    /// `name=count` per-lane batch table, e.g. `gpu=12 cpu=3`.
    pub fn fmt_batches(&self) -> String {
        crate::scheduler::format_lane_counts(&self.lanes, &self.n_batches)
    }

    /// The lane's display name (falls back to `laneN` for outcomes from
    /// a fleet this result has no name table for).
    pub fn lane_name(&self, lane: LaneId) -> String {
        self.lanes
            .get(lane.index())
            .cloned()
            .unwrap_or_else(|| lane.to_string())
    }

    /// Response-time samples over every outcome.
    pub fn response_times(&self) -> Samples {
        Samples::from_vec(self.outcomes.iter().map(|o| o.response_time()).collect())
    }

    /// Time-to-first-token samples over every outcome.
    pub fn ttft_times(&self) -> Samples {
        Samples::from_vec(self.outcomes.iter().map(|o| o.ttft()).collect())
    }

    /// Mean response time (seconds).
    pub fn mean_response(&self) -> f64 {
        self.response_times().mean()
    }

    /// Maximum response time (Table III's metric).
    pub fn max_response(&self) -> f64 {
        self.response_times().max()
    }

    /// Average completed tasks per minute (Sec. V-C).
    pub fn throughput_per_min(&self) -> f64 {
        if self.makespan <= 0.0 {
            return 0.0;
        }
        self.outcomes.len() as f64 / (self.makespan / 60.0)
    }

    /// Mean response time of tasks arriving in the peak third of the
    /// sweep (where scheduling decisions actually bind).
    pub fn peak_mean_response(&self) -> f64 {
        if self.outcomes.is_empty() {
            return f64::NAN;
        }
        let mut arrivals: Vec<f64> = self.outcomes.iter().map(|o| o.arrival).collect();
        arrivals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let cut = arrivals[(arrivals.len() * 2) / 3];
        let peak: Vec<f64> = self
            .outcomes
            .iter()
            .filter(|o| o.arrival >= cut)
            .map(|o| o.response_time())
            .collect();
        peak.iter().sum::<f64>() / peak.len().max(1) as f64
    }

    /// Throughput over the *peak* third of the arrival sweep — where the
    /// paper's policies actually separate (off-peak, every policy clears
    /// the queue and throughput equals the arrival rate).
    pub fn peak_throughput_per_min(&self) -> f64 {
        if self.outcomes.is_empty() {
            return 0.0;
        }
        let mut arrivals: Vec<f64> = self.outcomes.iter().map(|o| o.arrival).collect();
        arrivals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let cut = arrivals[(arrivals.len() * 2) / 3];
        let peak: Vec<&TaskOutcome> =
            self.outcomes.iter().filter(|o| o.arrival >= cut).collect();
        if peak.is_empty() {
            return 0.0;
        }
        let start = peak.iter().map(|o| o.arrival).fold(f64::INFINITY, f64::min);
        let end = peak.iter().map(|o| o.completion).fold(0.0, f64::max);
        if end <= start {
            return 0.0;
        }
        peak.len() as f64 / ((end - start) / 60.0)
    }

    /// Number of tasks that completed after their priority point.
    pub fn miss_count(&self) -> usize {
        self.outcomes.iter().filter(|o| o.missed()).count()
    }

    /// Fraction of tasks that missed their priority point.
    pub fn miss_rate(&self) -> f64 {
        if self.outcomes.is_empty() {
            return 0.0;
        }
        self.miss_count() as f64 / self.outcomes.len() as f64
    }

    /// Export per-task outcomes as JSONL (offline analysis / plotting).
    pub fn export_jsonl(&self, path: &std::path::Path) -> anyhow::Result<()> {
        use std::io::Write;
        let mut f = std::fs::File::create(path)?;
        for o in &self.outcomes {
            let mut fields = vec![
                ("id", Json::Num(o.id as f64)),
                ("arrival", Json::Num(o.arrival)),
                ("completion", Json::Num(o.completion)),
                ("response", Json::Num(o.response_time())),
                ("ttft", Json::Num(o.ttft())),
                ("priority_point", Json::Num(o.priority_point)),
                ("uncertainty", Json::Num(o.uncertainty)),
                ("true_len", Json::Num(o.true_len as f64)),
                ("lane", Json::Str(self.lane_name(o.lane))),
                ("utype", Json::Str(o.utype.clone())),
                ("malicious", Json::Bool(o.malicious)),
                ("missed", Json::Bool(o.missed())),
                ("shed", Json::Bool(o.shed)),
            ];
            // Class columns only for classed tasks: classless exports
            // stay byte-identical to the pre-SLO format.
            if o.slo != SloClass::Standard {
                fields.push(("slo_class", Json::Str(o.slo.label().to_string())));
                fields.push(("deadline_met", Json::Bool(o.deadline_met())));
            }
            let rec = obj(fields);
            writeln!(f, "{rec}")?;
        }
        Ok(())
    }

    /// Per-SLO-class attainment rows (see [`slo_summary`]).
    pub fn slo_summaries(&self) -> Vec<SloSummary> {
        slo_summary(&self.outcomes)
    }

    /// Mean pure-inference latency (Fig. 14's second series).
    pub fn mean_infer_secs(&self) -> f64 {
        if self.outcomes.is_empty() {
            return 0.0;
        }
        self.outcomes.iter().map(|o| o.infer_secs).sum::<f64>() / self.outcomes.len() as f64
    }
}
