//! In-tree stand-in for the `xla` crate (PJRT bindings are not in the
//! offline crate set, and `anyhow` must stay the only external
//! dependency — see DESIGN.md §Substitutions).
//!
//! [`Literal`] is a fully functional host tensor, so every IO path
//! (tensor bundles, literal construction/reshape/readback) works without
//! a PJRT backend. Client construction ([`PjRtClient::cpu`]) reports the
//! backend as unavailable; the device-side types are uninhabited, which
//! proves at the type level that no execution path can be reached without
//! a real backend. Swapping in the real `xla` crate is a one-line
//! `Cargo.toml` change plus deleting this module — the API surface below
//! mirrors the subset of `xla-rs` the crate uses.

use std::fmt;

/// Error type mirroring `xla::Error` closely enough for `?`/`context`.
#[derive(Debug)]
pub struct Error(/** the error message */ pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

impl Error {
    fn backend_unavailable() -> Error {
        Error(
            "PJRT backend unavailable: built against the in-tree xla stub \
             (real HLO execution requires the xla crate; simulation and \
             native-regressor paths do not need it)"
                .to_string(),
        )
    }
}

/// Result alias mirroring `xla::Result`.
pub type Result<T> = std::result::Result<T, Error>;

/// Uninhabited: proves device-side code paths cannot be reached.
#[derive(Clone, Copy, Debug)]
enum Never {}

// ---------------------------------------------------------------------------
// Host literals (fully functional)
// ---------------------------------------------------------------------------

/// Element payload of a [`Literal`].
#[derive(Clone, Debug)]
pub enum Data {
    /// 32-bit float elements.
    F32(Vec<f32>),
    /// 32-bit signed integer elements.
    I32(Vec<i32>),
    /// A tuple of literals.
    Tuple(Vec<Literal>),
}

mod sealed {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for i32 {}
}

/// Element types a [`Literal`] can be built from / read back as.
pub trait NativeType: sealed::Sealed + Copy {
    /// Wrap host values as literal payload.
    fn into_data(values: Vec<Self>) -> Data;
    /// Read payload back as host values (None on dtype mismatch).
    fn from_data(data: &Data) -> Option<Vec<Self>>;
    /// Display name for error messages.
    fn type_name() -> &'static str;
}

impl NativeType for f32 {
    fn into_data(values: Vec<Self>) -> Data {
        Data::F32(values)
    }
    fn from_data(data: &Data) -> Option<Vec<Self>> {
        match data {
            Data::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
    fn type_name() -> &'static str {
        "f32"
    }
}

impl NativeType for i32 {
    fn into_data(values: Vec<Self>) -> Data {
        Data::I32(values)
    }
    fn from_data(data: &Data) -> Option<Vec<Self>> {
        match data {
            Data::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
    fn type_name() -> &'static str {
        "i32"
    }
}

/// Host-resident tensor (mirror of `xla::Literal`).
#[derive(Clone, Debug)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

/// Shape descriptor: only the tuple-ness is consulted by this crate.
#[derive(Clone, Copy, Debug)]
pub struct Shape {
    tuple: bool,
}

impl Shape {
    /// Is this the shape of a tuple literal?
    pub fn is_tuple(&self) -> bool {
        self.tuple
    }
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(values: &[T]) -> Literal {
        Literal { dims: vec![values.len() as i64], data: T::into_data(values.to_vec()) }
    }

    /// Tuple literal (what a `return_tuple=True` executable produces).
    pub fn tuple(elements: Vec<Literal>) -> Literal {
        Literal { dims: vec![], data: Data::Tuple(elements) }
    }

    /// Number of elements (tuple literals count their members).
    pub fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(v) => v.len(),
        }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        if matches!(self.data, Data::Tuple(_)) {
            return Err(Error("cannot reshape a tuple literal".into()));
        }
        let n: i64 = dims.iter().product();
        if n as usize != self.element_count() {
            return Err(Error(format!(
                "reshape {:?} -> {:?}: element count mismatch",
                self.dims, dims
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// The literal's dimensions.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// The literal's shape descriptor.
    pub fn shape(&self) -> Result<Shape> {
        Ok(Shape { tuple: matches!(self.data, Data::Tuple(_)) })
    }

    /// Read the elements back out (error on dtype mismatch / tuples).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::from_data(&self.data)
            .ok_or_else(|| Error(format!("literal is not {}", T::type_name())))
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.data {
            Data::Tuple(v) => Ok(v),
            _ => Err(Error("literal is not a tuple".into())),
        }
    }
}

// ---------------------------------------------------------------------------
// HLO interchange (host-side parsing only)
// ---------------------------------------------------------------------------

/// Parsed-enough HLO module: the stub keeps the text so callers can
/// still validate that artifact files exist and are readable.
#[derive(Clone, Debug)]
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    /// Read an HLO text file (validated to look like HLO).
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("reading HLO text {path}: {e}")))?;
        if !text.contains("HloModule") {
            return Err(Error(format!("{path} does not look like HLO text")));
        }
        Ok(HloModuleProto { text })
    }

    /// The raw HLO text.
    pub fn text(&self) -> &str {
        &self.text
    }
}

/// Computation wrapper (mirror of `xla::XlaComputation`).
#[derive(Clone, Debug)]
pub struct XlaComputation {
    #[allow(dead_code)]
    hlo_text: String,
}

impl XlaComputation {
    /// Adopt a parsed module.
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { hlo_text: proto.text.clone() }
    }
}

// ---------------------------------------------------------------------------
// PJRT client surface (uninhabited: construction always fails)
// ---------------------------------------------------------------------------

/// PJRT client (uninhabited in the stub build).
pub struct PjRtClient(Never);
/// PJRT device handle (uninhabited in the stub build).
pub struct Device(Never);
/// Device-resident buffer (uninhabited in the stub build).
pub struct PjRtBuffer(Never);
/// Compiled executable handle (uninhabited in the stub build).
pub struct PjRtLoadedExecutable(Never);

impl PjRtClient {
    /// Always errors in the stub build: there is no PJRT runtime.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::backend_unavailable())
    }

    /// Backend platform name (unreachable in the stub build).
    pub fn platform_name(&self) -> String {
        match self.0 {}
    }

    /// Enumerate devices (unreachable in the stub build).
    pub fn devices(&self) -> Vec<Device> {
        match self.0 {}
    }

    /// Compile a computation (unreachable in the stub build).
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        match self.0 {}
    }

    /// Upload a literal to a device (unreachable in the stub build).
    pub fn buffer_from_host_literal(
        &self,
        _device: Option<&Device>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        match self.0 {}
    }
}

impl PjRtBuffer {
    /// Read a buffer back to the host (unreachable in the stub build).
    pub fn to_literal_sync(&self) -> Result<Literal> {
        match self.0 {}
    }
}

impl PjRtLoadedExecutable {
    /// Execute with host literals (unreachable in the stub build).
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        match self.0 {}
    }

    /// Execute with device buffers (unreachable in the stub build).
    pub fn execute_b<B: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        match self.0 {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trips_f32() {
        let lit = Literal::vec1(&[1.5f32, -2.0, 0.0]);
        assert_eq!(lit.dims(), &[3]);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.5, -2.0, 0.0]);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn literal_reshape_checks_counts() {
        let lit = Literal::vec1(&[1i32, 2, 3, 4, 5, 6]);
        let r = lit.reshape(&[2, 3]).unwrap();
        assert_eq!(r.dims(), &[2, 3]);
        assert_eq!(r.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4, 5, 6]);
        assert!(lit.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn tuple_literals_decompose() {
        let t = Literal::tuple(vec![Literal::vec1(&[1f32]), Literal::vec1(&[2i32])]);
        assert!(t.shape().unwrap().is_tuple());
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[1].to_vec::<i32>().unwrap(), vec![2]);
    }

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must not construct");
        assert!(err.to_string().contains("unavailable"));
    }
}
