//! Runtime bridge: load AOT artifacts (HLO text + tensor bundles) and
//! execute them on the PJRT CPU client via the `xla` crate.
//!
//! Python never runs here — everything below consumes files produced
//! once by `make artifacts`.

pub mod artifacts;
pub mod bundle;
pub mod client;

pub use artifacts::ArtifactStore;
pub use bundle::{Bundle, Dtype, Tensor};
pub use client::{Executable, RtClient};
