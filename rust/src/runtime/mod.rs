//! Runtime bridge: load AOT artifacts (HLO text + tensor bundles) and
//! execute them on the PJRT CPU client via the `xla` crate surface.
//!
//! Python never runs here — everything below consumes files produced
//! once by `make artifacts`.
//!
//! The offline crate set has no PJRT bindings, so [`xla`] is an in-tree
//! stand-in: host-side literals/bundles are fully functional, while
//! client construction reports the backend as unavailable. Simulation
//! and the native regressor never touch the client; only real HLO
//! execution ([`client`], `model::LmSession`) requires a real backend.

pub mod artifacts;
pub mod bundle;
pub mod client;
pub mod xla;

pub use artifacts::ArtifactStore;
pub use bundle::{Bundle, Dtype, Tensor};
pub use client::{Executable, RtClient};
