//! Tensor-bundle reader (mirror of `python/compile/bundle.py`).
//!
//! Layout (little-endian): magic "RTLMTB01", u32 count, then per tensor
//! u16 name_len, name, u8 dtype (0=f32, 1=i32), u8 ndim, ndim*u32 dims,
//! raw data.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, bail, ensure, Context, Result};

use super::xla;

const MAGIC: &[u8; 8] = b"RTLMTB01";

/// Element type of a bundle tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    /// 32-bit float.
    F32,
    /// 32-bit signed integer.
    I32,
}

/// A tensor's raw elements.
#[derive(Clone, Debug)]
pub enum Data {
    /// 32-bit float elements.
    F32(Vec<f32>),
    /// 32-bit signed integer elements.
    I32(Vec<i32>),
}

/// One named tensor of a bundle.
#[derive(Clone, Debug)]
pub struct Tensor {
    /// Tensor name (the python export's key).
    pub name: String,
    /// Element type.
    pub dtype: Dtype,
    /// Shape.
    pub dims: Vec<usize>,
    /// Raw elements, row-major.
    pub data: Data,
}

impl Tensor {
    /// Build an f32 tensor (dims must match the element count).
    pub fn f32(name: &str, dims: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        Tensor { name: name.to_string(), dtype: Dtype::F32, dims, data: Data::F32(data) }
    }

    /// Build an i32 tensor (dims must match the element count).
    pub fn i32(name: &str, dims: Vec<usize>, data: Vec<i32>) -> Tensor {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        Tensor { name: name.to_string(), dtype: Dtype::I32, dims, data: Data::I32(data) }
    }

    /// Product of the dims.
    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }

    /// The elements as f32 (error if the tensor is not f32).
    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            Data::F32(v) => Ok(v),
            _ => Err(anyhow!("tensor '{}' is not f32", self.name)),
        }
    }

    /// The elements as i32 (error if the tensor is not i32).
    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            Data::I32(v) => Ok(v),
            _ => Err(anyhow!("tensor '{}' is not i32", self.name)),
        }
    }

    /// Convert to an xla literal with this tensor's shape.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.dims.iter().map(|d| *d as i64).collect();
        let lit = match &self.data {
            Data::F32(v) => xla::Literal::vec1(v.as_slice()),
            Data::I32(v) => xla::Literal::vec1(v.as_slice()),
        };
        Ok(lit.reshape(&dims)?)
    }
}

/// A parsed tensor bundle (RTLMTB01 format).
#[derive(Clone, Debug, Default)]
pub struct Bundle {
    /// The tensors, in file order.
    pub tensors: Vec<Tensor>,
    index: HashMap<String, usize>,
}

impl Bundle {
    /// Index a list of tensors by name.
    pub fn from_tensors(tensors: Vec<Tensor>) -> Bundle {
        let index = tensors
            .iter()
            .enumerate()
            .map(|(i, t)| (t.name.clone(), i))
            .collect();
        Bundle { tensors, index }
    }

    /// Look one tensor up by name.
    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.index.get(name).map(|&i| &self.tensors[i])
    }

    /// Read and parse a bundle file.
    pub fn load(path: &Path) -> Result<Bundle> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading bundle {}", path.display()))?;
        Self::parse(&bytes).with_context(|| format!("parsing bundle {}", path.display()))
    }

    /// Parse bundle bytes.
    pub fn parse(bytes: &[u8]) -> Result<Bundle> {
        let mut r = Reader { bytes, pos: 0 };
        ensure!(r.take(8)? == MAGIC, "bad bundle magic");
        let count = r.u32()? as usize;
        let mut tensors = Vec::with_capacity(count);
        for _ in 0..count {
            let name_len = r.u16()? as usize;
            let name = String::from_utf8(r.take(name_len)?.to_vec())
                .map_err(|_| anyhow!("non-utf8 tensor name"))?;
            let dtype = match r.u8()? {
                0 => Dtype::F32,
                1 => Dtype::I32,
                other => bail!("unknown dtype {other}"),
            };
            let ndim = r.u8()? as usize;
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(r.u32()? as usize);
            }
            let n: usize = dims.iter().product();
            let raw = r.take(4 * n)?;
            let data = match dtype {
                Dtype::F32 => Data::F32(
                    raw.chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect(),
                ),
                Dtype::I32 => Data::I32(
                    raw.chunks_exact(4)
                        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect(),
                ),
            };
            tensors.push(Tensor { name, dtype, dims, data });
        }
        ensure!(r.pos == bytes.len(), "trailing bytes in bundle");
        Ok(Bundle::from_tensors(tensors))
    }
}

struct Reader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(self.pos + n <= self.bytes.len(), "truncated bundle");
        let out = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn encode(tensors: &[(&str, u8, Vec<u32>, Vec<u8>)]) -> Vec<u8> {
        let mut out = MAGIC.to_vec();
        out.extend((tensors.len() as u32).to_le_bytes());
        for (name, dtype, dims, data) in tensors {
            out.extend((name.len() as u16).to_le_bytes());
            out.extend(name.as_bytes());
            out.push(*dtype);
            out.push(dims.len() as u8);
            for d in dims {
                out.extend(d.to_le_bytes());
            }
            out.extend(data);
        }
        out
    }

    #[test]
    fn parses_f32_and_i32() {
        let f = [1.5f32, -2.0];
        let i = [7i32];
        let bytes = encode(&[
            ("a", 0, vec![2], f.iter().flat_map(|x| x.to_le_bytes()).collect()),
            ("b", 1, vec![1], i.iter().flat_map(|x| x.to_le_bytes()).collect()),
        ]);
        let bundle = Bundle::parse(&bytes).unwrap();
        assert_eq!(bundle.get("a").unwrap().as_f32().unwrap(), &[1.5, -2.0]);
        assert_eq!(bundle.get("b").unwrap().as_i32().unwrap(), &[7]);
        assert!(bundle.get("missing").is_none());
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(Bundle::parse(b"WRONG!!!").is_err());
    }

    #[test]
    fn rejects_truncated() {
        let bytes = encode(&[("a", 0, vec![4], vec![0u8; 4])]); // claims 4 elems, has 1
        assert!(Bundle::parse(&bytes).is_err());
    }

    #[test]
    fn rejects_trailing() {
        let mut bytes = encode(&[]);
        bytes.push(0);
        assert!(Bundle::parse(&bytes).is_err());
    }
}
