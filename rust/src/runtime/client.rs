//! PJRT client wrapper: compile HLO text, execute with literals or
//! device-resident buffers.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`): jax
//! >= 0.5 serialises protos with 64-bit instruction ids that the pinned
//! xla_extension 0.5.1 rejects; the text parser reassigns ids.
//!
//! Output convention: the AOT path lowers with `return_tuple=True`, so
//! every executable returns a single tuple buffer; [`Executable::run`]
//! decomposes it into per-output literals.

use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use super::xla;

/// Shared PJRT CPU client.
#[derive(Clone)]
pub struct RtClient {
    client: Arc<xla::PjRtClient>,
}

impl RtClient {
    /// Create the PJRT CPU client.
    pub fn cpu() -> Result<RtClient> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(RtClient { client: Arc::new(client) })
    }

    /// The backend platform's display name.
    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Load HLO text from `path` and compile it.
    pub fn compile_file(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe, name: path.display().to_string() })
    }

    /// Upload a literal to device 0 (weights live on-device across calls).
    pub fn upload(&self, literal: &xla::Literal) -> Result<xla::PjRtBuffer> {
        let device = self
            .client
            .devices()
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("no PJRT devices"))?;
        Ok(self.client.buffer_from_host_literal(Some(&device), literal)?)
    }
}

/// A compiled HLO module ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Source path, for error messages.
    pub name: String,
}

impl Executable {
    /// Execute with host literals; returns the decomposed output tuple.
    /// Accepts owned or borrowed literals (pass `&Literal`s to avoid the
    /// deep copy `Literal::clone` performs).
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        args: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let outputs = self
            .exe
            .execute::<L>(args)
            .with_context(|| format!("executing {}", self.name))?;
        self.collect(outputs)
    }

    /// Execute with device-resident buffers (no host copies for inputs).
    pub fn run_buffers(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        let outputs = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(args)
            .with_context(|| format!("executing {}", self.name))?;
        self.collect(outputs)
    }

    /// Execute with device buffers, returning the raw output buffers
    /// (still tupled) — used when the caller chains executions.
    pub fn run_buffers_raw(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<xla::PjRtBuffer>> {
        let mut outputs = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(args)
            .with_context(|| format!("executing {}", self.name))?;
        if outputs.is_empty() {
            return Err(anyhow!("{}: no output replicas", self.name));
        }
        Ok(outputs.swap_remove(0))
    }

    fn collect(&self, mut outputs: Vec<Vec<xla::PjRtBuffer>>) -> Result<Vec<xla::Literal>> {
        if outputs.is_empty() {
            return Err(anyhow!("{}: no output replicas", self.name));
        }
        let replica = outputs.swap_remove(0);
        let mut literals = Vec::new();
        for buffer in &replica {
            let lit = buffer.to_literal_sync()?;
            // return_tuple=True wraps outputs in one tuple; decompose it.
            if lit.shape()?.is_tuple() {
                literals.extend(lit.to_tuple()?);
            } else {
                literals.push(lit);
            }
        }
        Ok(literals)
    }
}

/// Build an i32 vector literal with the given shape.
pub fn i32_literal(values: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(values).reshape(dims)?)
}

/// Build an f32 vector literal with the given shape.
pub fn f32_literal(values: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(values).reshape(dims)?)
}
