//! The artifact store: one-stop runtime context owning the manifest,
//! the PJRT client, lazily-compiled executables, and loaded weight
//! bundles.
//!
//! Executables compile on first use and are cached for the process
//! lifetime (one compiled executable per (model, entrypoint, bucket),
//! matching the "compile once per variant" serving design).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Context, Result};

use super::bundle::Bundle;
use super::client::{Executable, RtClient};
use crate::config::Manifest;
use crate::textgen::{Lexicon, Vocab};
use crate::uncertainty::Regressor;

/// Everything loaded from the artifacts directory, with lazy PJRT
/// compilation caches.
pub struct ArtifactStore {
    /// The parsed manifest contract.
    pub manifest: Manifest,
    /// The shared lexicon.
    pub lexicon: Arc<Lexicon>,
    /// The id <-> word vocabulary.
    pub vocab: Arc<Vocab>,
    /// The native LW regressor.
    pub regressor: Arc<Regressor>,
    /// PJRT client, created on first use: simulation, scoring, and
    /// bundle IO never need one, and the in-tree `xla` stub has no
    /// backend at all — only real HLO execution forces creation.
    client: Mutex<Option<RtClient>>,
    executables: Mutex<HashMap<PathBuf, Arc<Executable>>>,
    bundles: Mutex<HashMap<PathBuf, Arc<Bundle>>>,
}

impl ArtifactStore {
    /// Open the artifacts directory (validates the manifest + lexicon +
    /// regressor eagerly; the PJRT client and HLO compile lazily).
    pub fn open(root: &Path) -> Result<ArtifactStore> {
        let manifest = Manifest::load(root)?;
        let lexicon = Arc::new(Lexicon::load(&manifest.lexicon)?);
        let vocab = Arc::new(Vocab::from_lexicon(&lexicon, manifest.vocab_size)?);
        let reg_bundle = Bundle::load(&manifest.regressor.weights)?;
        let regressor = Arc::new(Regressor::from_bundle(&reg_bundle, &manifest.feature_scales)?);
        Ok(ArtifactStore {
            manifest,
            lexicon,
            vocab,
            regressor,
            client: Mutex::new(None),
            executables: Mutex::new(HashMap::new()),
            bundles: Mutex::new(HashMap::new()),
        })
    }

    /// Open `$RTLM_ARTIFACTS` or `./artifacts`.
    pub fn open_default() -> Result<ArtifactStore> {
        Self::open(&Manifest::default_root())
    }

    /// The (lazily created, process-cached) PJRT client. Errors when no
    /// backend exists — e.g. under the in-tree `xla` stub.
    pub fn client(&self) -> Result<RtClient> {
        let mut guard = self.client.lock().unwrap();
        if let Some(client) = guard.as_ref() {
            return Ok(client.clone());
        }
        let client = RtClient::cpu()?;
        *guard = Some(client.clone());
        Ok(client)
    }

    /// Whether real HLO execution is possible in this build/environment.
    pub fn pjrt_available(&self) -> bool {
        self.client().is_ok()
    }

    /// Compile (or fetch the cached) executable for an HLO file.
    pub fn executable(&self, path: &Path) -> Result<Arc<Executable>> {
        if let Some(exe) = self.executables.lock().unwrap().get(path) {
            return Ok(exe.clone());
        }
        // Compile outside the lock: compiles can take hundreds of ms and
        // other lanes should not stall on an unrelated bucket.
        let exe = Arc::new(self.client()?.compile_file(path)?);
        let mut cache = self.executables.lock().unwrap();
        Ok(cache.entry(path.to_path_buf()).or_insert(exe).clone())
    }

    /// Load (or fetch the cached) tensor bundle.
    pub fn bundle(&self, path: &Path) -> Result<Arc<Bundle>> {
        if let Some(b) = self.bundles.lock().unwrap().get(path) {
            return Ok(b.clone());
        }
        let bundle = Arc::new(Bundle::load(path)?);
        let mut cache = self.bundles.lock().unwrap();
        Ok(cache.entry(path.to_path_buf()).or_insert(bundle).clone())
    }

    /// Pick the smallest decode batch bucket >= n for a model.
    pub fn decode_bucket(&self, model: &str, n: usize) -> Result<usize> {
        let entry = self.manifest.model(model)?;
        entry
            .decode
            .keys()
            .copied()
            .find(|b| *b >= n)
            .or_else(|| entry.decode.keys().copied().max())
            .ok_or_else(|| anyhow!("model {model} has no decode buckets"))
    }

    /// Pick the smallest (batch, seq) prefill bucket covering (n, s).
    pub fn prefill_bucket(&self, model: &str, n: usize, s: usize) -> Result<(usize, usize)> {
        let entry = self.manifest.model(model)?;
        let mut best: Option<(usize, usize)> = None;
        for &(b, bs) in entry.prefill.keys() {
            if b >= n && bs >= s {
                let cand = (b, bs);
                best = Some(match best {
                    None => cand,
                    Some(prev) => {
                        if (b * bs) < (prev.0 * prev.1) {
                            cand
                        } else {
                            prev
                        }
                    }
                });
            }
        }
        best.ok_or_else(|| {
            anyhow!("no prefill bucket for model {model} covering batch={n} seq={s}")
        })
    }

    /// The compiled decode executable for one batch bucket.
    pub fn decode_hlo(&self, model: &str, bucket: usize) -> Result<Arc<Executable>> {
        let entry = self.manifest.model(model)?;
        let path = entry
            .decode
            .get(&bucket)
            .ok_or_else(|| anyhow!("model {model}: no decode bucket {bucket}"))?;
        self.executable(path).context("compiling decode HLO")
    }

    /// Multi-token chunk executable (None when artifacts lack chunks).
    pub fn decode_chunk_hlo(
        &self,
        model: &str,
        bucket: usize,
    ) -> Result<Option<Arc<Executable>>> {
        let entry = self.manifest.model(model)?;
        match entry.decode_chunk.get(&bucket) {
            None => Ok(None),
            Some(path) => Ok(Some(self.executable(path).context("compiling chunk HLO")?)),
        }
    }

    /// The compiled prefill executable for one (batch, seq) bucket.
    pub fn prefill_hlo(&self, model: &str, bucket: (usize, usize)) -> Result<Arc<Executable>> {
        let entry = self.manifest.model(model)?;
        let path = entry
            .prefill
            .get(&bucket)
            .ok_or_else(|| anyhow!("model {model}: no prefill bucket {bucket:?}"))?;
        self.executable(path).context("compiling prefill HLO")
    }
}
