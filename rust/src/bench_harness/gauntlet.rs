//! The scenario gauntlet: a policy × scenario matrix over diverse
//! arrival traces, SLO-class mixes, and device profiles, run through
//! the [`ReplayCell`] machinery and emitted as one deterministic JSON
//! report (`rtlm gauntlet`; rendered by `scripts/gauntlet_report.py`).
//!
//! Every cell is artifact-free — synthetic seeded tasks, a stub model
//! entry, a hand-built latency calibration — so the whole matrix runs
//! in `cargo test` and CI without `make artifacts`. Scenarios:
//!
//! | scenario    | arrivals                      | lengths      | fleet            |
//! |-------------|-------------------------------|--------------|------------------|
//! | `nominal`   | fixed Poisson, under capacity | uniform mix  | gpu+cpu          |
//! | `diurnal`   | MMPP low/high/medium cycle    | uniform mix  | gpu+cpu          |
//! | `flash`     | flash-crowd spike + shedding  | uniform mix  | gpu+cpu, cap 16  |
//! | `heavytail` | fixed Poisson                 | lognormal    | gpu+cpu          |
//! | `edge-cpu`  | slow fixed Poisson            | uniform mix  | single CPU lane  |
//!
//! Tasks carry a 50/50 interactive/batch SLO mix whose class deadlines
//! are folded into the priority point (see
//! [`crate::scheduler::SloClass`]), so per-class attainment is pure
//! accounting over the outcomes.
//!
//! ## Determinism contract
//!
//! The report contains no wall-clock fields: every metric comes from
//! the virtual-clock simulation (plus, for wire-replayed cells, the
//! parity verdict's deterministic counters and pass/fail extras). A
//! sim-only run of the same configuration is therefore byte-identical
//! across invocations and machines — the matrix doubles as a
//! regression suite.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::config::{DeviceProfile, ModelEntry, SchedParams};
use crate::metrics::table::fmt_f;
use crate::metrics::Table;
use crate::scheduler::{Admission, LaneSet, LaneSpec, PolicyKind, SloClass, Task};
use crate::sim::results::SloSummary;
use crate::sim::{Calibration, LatencyModel};
use crate::util::json::{obj, Json};
use crate::util::rng::Pcg64;
use crate::workload::{ArrivalTrace, LengthDist, LengthSampler, MmppPhase, SloMix};

use super::replay::{run_parity, CellParity, ParityTolerance, ReplayCell};

/// Offload threshold: uncertainty above this quarantines to the CPU
/// lane under RT-LM (matches the parity suite's synthetic cells).
const TAU: f64 = 50.0;

/// One scenario of the gauntlet matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scenario {
    /// Fixed-rate Poisson arrivals comfortably under capacity — the
    /// regime the CI gate asserts nonzero interactive attainment in.
    Nominal,
    /// Diurnal/bursty MMPP arrivals: a low/high/medium rate cycle
    /// modelling a day's traffic curve at compressed scale.
    Diurnal,
    /// Flash crowd: half the arrivals land in a 2 s spike window, with
    /// overload admission control on (`queue_cap`) so shedding engages.
    Flash,
    /// Heavy-tailed (lognormal) output lengths; uncertainty tracks the
    /// sampled length, so the tail crosses the quarantine threshold.
    HeavyTail,
    /// Accelerator-less edge device: a single CPU fallback lane on the
    /// [`DeviceProfile::edge_cpu`] profile, slow Poisson arrivals.
    EdgeCpu,
}

impl Scenario {
    /// Every scenario, in report order.
    pub const ALL: [Scenario; 5] = [
        Scenario::Nominal,
        Scenario::Diurnal,
        Scenario::Flash,
        Scenario::HeavyTail,
        Scenario::EdgeCpu,
    ];

    /// CLI/report token.
    pub fn label(&self) -> &'static str {
        match self {
            Scenario::Nominal => "nominal",
            Scenario::Diurnal => "diurnal",
            Scenario::Flash => "flash",
            Scenario::HeavyTail => "heavytail",
            Scenario::EdgeCpu => "edge-cpu",
        }
    }

    /// Parse a CLI token produced by [`label`](Self::label).
    pub fn parse(s: &str) -> Result<Scenario> {
        Scenario::ALL
            .iter()
            .copied()
            .find(|sc| sc.label() == s)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown scenario '{s}' (nominal | diurnal | flash | heavytail | edge-cpu)"
                )
            })
    }
}

/// The gauntlet's serving model: a stub entry whose eta/phi match the
/// parity suite's synthetic cells.
fn gauntlet_model() -> ModelEntry {
    ModelEntry::stub("m", 0.05, 0.08)
}

/// Hand-built latency tables (same anchors as the parity tests), so
/// the gauntlet needs no calibration artifact.
fn gauntlet_latency() -> LatencyModel {
    let mut c = Calibration::default();
    c.decode
        .insert("m".into(), BTreeMap::from([(1, 0.01), (4, 0.018), (16, 0.04)]));
    c.prefill
        .insert("m".into(), BTreeMap::from([((1, 16), 0.02), ((16, 64), 0.08)]));
    LatencyModel::from_calibration(&c)
}

/// Build one scenario's task set: seeded arrivals from the scenario's
/// trace generator, a seeded uncertainty/length mix, and the 50/50
/// interactive (8 s) / batch (60 s) SLO assignment.
fn scenario_tasks(scenario: Scenario, n: usize, seed: u64) -> Vec<Task> {
    let trace = match scenario {
        Scenario::Nominal => ArrivalTrace::poisson_fixed(n, 90.0, seed),
        Scenario::Diurnal => ArrivalTrace::mmpp(
            n,
            &[
                MmppPhase::new(30.0, 20.0),
                MmppPhase::new(240.0, 20.0),
                MmppPhase::new(90.0, 20.0),
            ],
            seed,
        ),
        Scenario::Flash => ArrivalTrace::flash_crowd(n, 40.0, 4.0, 2.0, 0.5, seed),
        Scenario::HeavyTail => ArrivalTrace::poisson_fixed(n, 90.0, seed),
        Scenario::EdgeCpu => ArrivalTrace::poisson_fixed(n, 24.0, seed),
    };
    let mut rng = Pcg64::new(seed ^ 0x6AB7_1E7);
    let sampler = LengthSampler {
        dist: LengthDist::Lognormal { mu: 2.5, sigma: 0.9 },
        lo: 4,
        hi: 96,
    };
    let mut tasks: Vec<Task> = trace
        .times
        .iter()
        .enumerate()
        .map(|(i, &arrival)| {
            // heavy-tail cells: uncertainty tracks the sampled length
            // (a perfect predictor), so the tail quarantines; others:
            // ~1 in 4 tasks above tau, like the parity cells
            let (u, len) = if scenario == Scenario::HeavyTail {
                let len = sampler.sample(&mut rng);
                (len as f64, len)
            } else {
                let u = if i % 4 == 0 {
                    52.0 + rng.f64() * 8.0
                } else {
                    5.0 + rng.f64() * 40.0
                };
                (u, (u.round() as usize).clamp(4, 96))
            };
            Task {
                id: i as u64,
                text: String::new(),
                prompt: vec![],
                arrival,
                priority_point: arrival + 3.0, // overwritten by the SLO mix
                uncertainty: u,
                true_len: len,
                input_len: 8,
                utype: scenario.label().into(),
                malicious: false,
                deferrals: 0,
                slo: SloClass::Standard,
            }
        })
        .collect();
    let mix = SloMix {
        interactive_frac: 0.5,
        interactive_deadline: 8.0,
        batch_deadline: 60.0,
    };
    mix.assign(&mut tasks, seed ^ 0x510);
    tasks
}

/// Build the [`ReplayCell`] for one (scenario, policy) pair.
fn scenario_cell(scenario: Scenario, kind: PolicyKind, n: usize, seed: u64) -> Result<ReplayCell> {
    let model = gauntlet_model();
    let mut params = SchedParams { batch_size: 8, ..Default::default() };
    if scenario == Scenario::Flash {
        // overload admission control on, so the spike actually sheds
        params.queue_cap = 16;
    }
    let tasks = scenario_tasks(scenario, n, seed);
    let label = format!("{}/{}", scenario.label(), kind.label());
    if scenario == Scenario::EdgeCpu {
        // accelerator-less device: one CPU lane, promoted to fallback
        let mut spec = LaneSpec::cpu_offload("cpu", &model.name, 0.0);
        spec.admission = Admission::Fallback;
        let lanes = LaneSet::new(vec![spec])?;
        return Ok(ReplayCell {
            label,
            kind,
            params,
            eta: model.eta,
            lanes,
            models: BTreeMap::from([(model.name.clone(), model.clone())]),
            dev: DeviceProfile::edge_cpu(),
            tasks,
        });
    }
    Ok(ReplayCell::two_lane(
        &label,
        kind,
        params,
        &model,
        TAU,
        DeviceProfile::edge_server(),
        tasks,
    ))
}

/// One evaluated cell of the gauntlet matrix. All metrics come from
/// the virtual-clock simulation; `wire` (when present) carries the
/// deterministic sim-vs-wire parity verdict for the same cell.
#[derive(Clone, Debug)]
pub struct GauntletCell {
    /// Scenario token (row key).
    pub scenario: String,
    /// Policy display name (column key), e.g. `RT-LM`.
    pub policy: String,
    /// Tasks in the cell (shed tasks included).
    pub n_tasks: usize,
    /// Mean response time (virtual seconds).
    pub mean_response: f64,
    /// p95 response time.
    pub p95_response: f64,
    /// p99 response time.
    pub p99_response: f64,
    /// p95 time to first token.
    pub p95_ttft: f64,
    /// Virtual time the last task completed at.
    pub makespan: f64,
    /// Fraction of tasks completing after their priority point.
    pub miss_rate: f64,
    /// Fraction of tasks dropped by overload admission control.
    pub shed_rate: f64,
    /// Lane names, in `LaneId` order.
    pub lanes: Vec<String>,
    /// Completed tasks per lane, indexed like `lanes`.
    pub lane_tasks: Vec<usize>,
    /// Per-SLO-class attainment rows.
    pub slo: Vec<SloSummary>,
    /// Sim-vs-wire parity verdict, for cells the wire filter selected.
    pub wire: Option<CellParity>,
    /// Populated instead of metrics when the cell failed to run.
    pub error: Option<String>,
}

impl GauntletCell {
    /// Did the cell run (and, if wire-replayed, agree across backends)?
    pub fn clean(&self) -> bool {
        if self.error.is_some() {
            return false;
        }
        match &self.wire {
            Some(w) => w.clean(),
            None => true,
        }
    }

    /// Attainment of one class, if the cell carried any such tasks.
    pub fn attainment(&self, class: SloClass) -> Option<f64> {
        self.slo.iter().find(|s| s.class == class).map(|s| s.attainment())
    }
}

/// Configuration of one gauntlet run.
#[derive(Clone, Debug)]
pub struct GauntletConfig {
    /// Tasks per cell.
    pub n: usize,
    /// Master seed: traces, length mixes and SLO assignment all derive
    /// from it, so equal configs yield byte-identical reports.
    pub seed: u64,
    /// Policies (matrix columns).
    pub policies: Vec<PolicyKind>,
    /// Scenarios (matrix rows).
    pub scenarios: Vec<Scenario>,
    /// Scenarios to additionally wire-replay (sim-vs-wire parity);
    /// empty = sim only, which keeps the report fully deterministic.
    pub wire: Vec<Scenario>,
    /// Wire-replay clock compression (`--time-scale`).
    pub time_scale: f64,
}

impl Default for GauntletConfig {
    fn default() -> Self {
        GauntletConfig {
            n: 48,
            seed: 7,
            policies: vec![PolicyKind::Fifo, PolicyKind::RtLm],
            scenarios: Scenario::ALL.to_vec(),
            wire: Vec::new(),
            time_scale: 25.0,
        }
    }
}

/// Evaluate one (scenario, policy) cell: virtual-clock sim always,
/// plus the wire parity replay when selected.
fn run_cell(
    cfg: &GauntletConfig,
    lat: &LatencyModel,
    scenario: Scenario,
    kind: PolicyKind,
) -> GauntletCell {
    let err_cell = |msg: String| GauntletCell {
        scenario: scenario.label().into(),
        policy: kind.label().into(),
        n_tasks: 0,
        mean_response: 0.0,
        p95_response: 0.0,
        p99_response: 0.0,
        p95_ttft: 0.0,
        makespan: 0.0,
        miss_rate: 0.0,
        shed_rate: 0.0,
        lanes: Vec::new(),
        lane_tasks: Vec::new(),
        slo: Vec::new(),
        wire: None,
        error: Some(msg),
    };
    let cell = match scenario_cell(scenario, kind, cfg.n, cfg.seed) {
        Ok(c) => c,
        Err(e) => return err_cell(format!("building cell: {e:#}")),
    };
    let sim = match cell.run_sim(lat) {
        Ok(r) => r,
        Err(e) => return err_cell(format!("sim run: {e:#}")),
    };
    let wire = if cfg.wire.contains(&scenario) {
        let tol = ParityTolerance::for_time_scale(cfg.time_scale);
        match run_parity(&cell, lat, cfg.time_scale, &tol) {
            Ok(p) => Some(p),
            Err(e) => return err_cell(format!("wire replay: {e:#}")),
        }
    } else {
        None
    };
    let mut rt = sim.response_times();
    let mut ttft = sim.ttft_times();
    let mut lane_tasks = vec![0usize; sim.lanes.len()];
    for o in &sim.outcomes {
        if o.lane.index() < lane_tasks.len() {
            lane_tasks[o.lane.index()] += 1;
        }
    }
    let n_tasks = sim.outcomes.len();
    GauntletCell {
        scenario: scenario.label().into(),
        policy: sim.policy.clone(),
        n_tasks,
        mean_response: rt.mean(),
        p95_response: rt.p95(),
        p99_response: rt.p99(),
        p95_ttft: ttft.p95(),
        makespan: sim.makespan,
        miss_rate: sim.miss_rate(),
        shed_rate: if n_tasks == 0 { 0.0 } else { sim.n_shed as f64 / n_tasks as f64 },
        lanes: sim.lanes.clone(),
        lane_tasks,
        slo: sim.slo_summaries(),
        wire,
        error: None,
    }
}

/// Run the full policy × scenario matrix. Cells that fail to run are
/// reported as error cells instead of aborting the matrix, so one bad
/// combination cannot hide the rest of the report.
pub fn run_gauntlet(cfg: &GauntletConfig) -> Vec<GauntletCell> {
    let lat = gauntlet_latency();
    let mut cells = Vec::with_capacity(cfg.scenarios.len() * cfg.policies.len());
    for &scenario in &cfg.scenarios {
        for &kind in &cfg.policies {
            cells.push(run_cell(cfg, &lat, scenario, kind));
        }
    }
    cells
}

/// Serialise the matrix as the JSON report `scripts/gauntlet_report.py`
/// consumes. Contains no wall-clock fields (see the module docs'
/// determinism contract).
pub fn gauntlet_json(cfg: &GauntletConfig, cells: &[GauntletCell]) -> Json {
    let slo_json = |s: &SloSummary| {
        obj(vec![
            ("class", Json::Str(s.class.label().to_string())),
            ("n", Json::Num(s.n as f64)),
            ("met", Json::Num(s.met as f64)),
            ("shed", Json::Num(s.shed as f64)),
            ("attainment", Json::Num(s.attainment())),
        ])
    };
    let cell_json = |c: &GauntletCell| {
        if let Some(err) = &c.error {
            return obj(vec![
                ("scenario", Json::Str(c.scenario.clone())),
                ("policy", Json::Str(c.policy.clone())),
                ("error", Json::Str(err.clone())),
            ]);
        }
        let mut fields = vec![
            ("scenario", Json::Str(c.scenario.clone())),
            ("policy", Json::Str(c.policy.clone())),
            ("n_tasks", Json::Num(c.n_tasks as f64)),
            ("mean_response", Json::Num(c.mean_response)),
            ("p95_response", Json::Num(c.p95_response)),
            ("p99_response", Json::Num(c.p99_response)),
            ("p95_ttft", Json::Num(c.p95_ttft)),
            ("makespan", Json::Num(c.makespan)),
            ("miss_rate", Json::Num(c.miss_rate)),
            ("shed_rate", Json::Num(c.shed_rate)),
            (
                "lanes",
                Json::Arr(c.lanes.iter().map(|l| Json::Str(l.clone())).collect()),
            ),
            (
                "lane_tasks",
                Json::Arr(c.lane_tasks.iter().map(|&n| Json::Num(n as f64)).collect()),
            ),
            ("slo", Json::Arr(c.slo.iter().map(slo_json).collect())),
        ];
        if let Some(w) = &c.wire {
            fields.push((
                "wire",
                obj(vec![
                    ("clean", Json::Bool(w.clean())),
                    (
                        "failures",
                        Json::Arr(w.failures.iter().map(|f| Json::Str(f.clone())).collect()),
                    ),
                ]),
            ));
        }
        obj(fields)
    };
    obj(vec![
        ("n", Json::Num(cfg.n as f64)),
        ("seed", Json::Num(cfg.seed as f64)),
        ("time_scale", Json::Num(cfg.time_scale)),
        (
            "policies",
            Json::Arr(cfg.policies.iter().map(|p| Json::Str(p.label().into())).collect()),
        ),
        (
            "scenarios",
            Json::Arr(cfg.scenarios.iter().map(|s| Json::Str(s.label().into())).collect()),
        ),
        ("cells", Json::Arr(cells.iter().map(cell_json).collect())),
    ])
}

/// Render the matrix as the ASCII table `rtlm gauntlet` prints.
pub fn render_gauntlet(cells: &[GauntletCell]) -> String {
    let mut table = Table::new(
        "scenario gauntlet (virtual-clock metrics; attainment = met/total per SLO class)",
        &[
            "scenario", "policy", "n", "mean s", "p95 s", "p99 s", "ttft p95 s", "shed",
            "int att", "batch att", "status",
        ],
    );
    let att = |c: &GauntletCell, class: SloClass| {
        c.attainment(class).map(|a| fmt_f(a, 2)).unwrap_or_else(|| "-".into())
    };
    for c in cells {
        if let Some(err) = &c.error {
            table.row(vec![
                c.scenario.clone(),
                c.policy.clone(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                format!("ERROR: {err}"),
            ]);
            continue;
        }
        let status = match &c.wire {
            Some(w) if w.clean() => "ok (wire)".to_string(),
            Some(w) => format!("WIRE FAIL ({})", w.failures.len()),
            None => "ok".to_string(),
        };
        table.row(vec![
            c.scenario.clone(),
            c.policy.clone(),
            c.n_tasks.to_string(),
            fmt_f(c.mean_response, 2),
            fmt_f(c.p95_response, 2),
            fmt_f(c.p99_response, 2),
            fmt_f(c.p95_ttft, 2),
            fmt_f(c.shed_rate, 2),
            att(c, SloClass::Interactive),
            att(c, SloClass::Batch),
            status,
        ]);
    }
    table.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_cfg() -> GauntletConfig {
        GauntletConfig { n: 24, ..Default::default() }
    }

    /// Tentpole acceptance: same config, byte-identical report JSON.
    #[test]
    fn sim_only_report_is_byte_identical() {
        let cfg = test_cfg();
        let a = gauntlet_json(&cfg, &run_gauntlet(&cfg)).to_string();
        let b = gauntlet_json(&cfg, &run_gauntlet(&cfg)).to_string();
        assert_eq!(a, b);
        assert!(a.contains("\"scenario\":"));
        assert!(!a.contains("\"error\""), "matrix has error cells: {a}");
    }

    /// The full default matrix runs clean and conserves tasks and SLO
    /// class counts in every cell.
    #[test]
    fn matrix_runs_clean_and_conserves_classes() {
        let cfg = test_cfg();
        let cells = run_gauntlet(&cfg);
        assert_eq!(cells.len(), Scenario::ALL.len() * 2);
        for c in &cells {
            assert!(c.error.is_none(), "{}/{}: {:?}", c.scenario, c.policy, c.error);
            assert_eq!(c.n_tasks, cfg.n, "{}/{}", c.scenario, c.policy);
            assert_eq!(c.lane_tasks.iter().sum::<usize>(), cfg.n);
            let classed: usize = c.slo.iter().map(|s| s.n).sum();
            assert_eq!(classed, cfg.n);
            // the mix assigns only interactive/batch, never standard
            assert!(c.slo.iter().all(|s| s.class != SloClass::Standard));
        }
    }

    /// The CI gate's core assertion: interactive traffic attains its
    /// deadline under nominal load.
    #[test]
    fn nominal_interactive_attainment_positive() {
        let cfg = test_cfg();
        let cells = run_gauntlet(&cfg);
        for policy in ["FIFO", "RT-LM"] {
            let c = cells
                .iter()
                .find(|c| c.scenario == "nominal" && c.policy == policy)
                .expect("nominal cell present");
            let att = c.attainment(SloClass::Interactive).expect("interactive row");
            assert!(att > 0.0, "{policy}: zero interactive attainment under nominal load");
        }
    }

    /// The edge-cpu scenario really runs on a single CPU lane.
    #[test]
    fn edge_cpu_runs_on_a_single_cpu_lane() {
        let cfg = test_cfg();
        let cells = run_gauntlet(&cfg);
        let c = cells
            .iter()
            .find(|c| c.scenario == "edge-cpu" && c.policy == "RT-LM")
            .expect("edge-cpu cell present");
        assert!(c.error.is_none());
        assert_eq!(c.lanes, vec!["cpu".to_string()]);
        assert_eq!(c.lane_tasks, vec![cfg.n]);
    }

    /// Scenario tokens round-trip through parse.
    #[test]
    fn scenario_parse_round_trips() {
        for s in Scenario::ALL {
            assert_eq!(Scenario::parse(s.label()).unwrap(), s);
        }
        assert!(Scenario::parse("weekend").is_err());
    }
}
