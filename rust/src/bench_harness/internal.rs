//! Ablations of *this implementation's* design decisions (DESIGN.md
//! §Scheduler-semantics) — the paper's Algorithm 1 is underspecified;
//! these benches justify each choice with measurements:
//!
//! 1. dynamic slack (aging) vs the literal static-arrival reading
//! 2. bounded deferral on/off
//! 3. accelerator batching-knee sensitivity
//! 4. CPU-lane worker-pool sensitivity
//!
//! Every comparison is built as a [`ReplayCell`], so the same cells
//! double as the **wire-parity suite** ([`parity_cells`]): `rtlm bench
//! --wire` replays each on the virtual-clock and threaded backends and
//! diffs the reports (see [`super::replay`]).
//!
//! Run with `rtlm bench internal` or
//! `cargo bench --bench paper_tables -- internal`.

use anyhow::Result;

use crate::config::{DeviceProfile, SchedMode};
use crate::metrics::table::fmt_f;
use crate::metrics::{histogram, Table};
use crate::scheduler::{PolicyKind, Task};
use crate::workload::subsets::Variance;

use super::replay::ReplayCell;
use super::scenarios::ExperimentCtx;

/// Run every internal ablation and print its table.
pub fn run_internal(ctx: &ExperimentCtx) -> Result<()> {
    aging_ablation(ctx)?;
    println!();
    knee_sensitivity(ctx)?;
    println!();
    cpu_worker_sensitivity(ctx)?;
    println!();
    step_vs_batch(ctx)?;
    println!();
    response_distributions(ctx)?;
    Ok(())
}

/// The full-RT-LM aging cell (dialogpt, large variance).
fn aging_cell(ctx: &ExperimentCtx) -> Result<ReplayCell> {
    let model = ctx.model("dialogpt")?.clone();
    let dev = DeviceProfile::edge_server();
    let tasks = ctx.scenario_tasks(&model, Variance::Large, ctx.seed ^ 0x1A)?;
    Ok(ctx
        .cell(&model, tasks, PolicyKind::RtLm, &dev)
        .labelled("internal/aging"))
}

/// The static-slack emulation of [`aging_cell`] (derived from it — same
/// task set): every priority point is pushed so far out that aging
/// never binds within the run — the ordering degenerates to the
/// numerator-only order the paper's literal Eq. 3 produces under load.
fn static_slack_cell(aging: &ReplayCell) -> ReplayCell {
    let mut cell = aging.clone().labelled("internal/static-slack");
    for t in &mut cell.tasks {
        t.priority_point = t.arrival + 1e6;
    }
    cell
}

/// Static-arrival slack (the literal Eq. 3 reading) is emulated by
/// freezing each task's arrival as its "now": we shift priority points
/// so the slack term equals the arrival-time value forever.
fn aging_ablation(ctx: &ExperimentCtx) -> Result<()> {
    let mut table = Table::new(
        "internal ablation — dynamic slack (aging) and bounded deferral",
        &["variant", "mean s", "p95 s", "max s", "misses"],
    );

    // full RT-LM (aging + bounded deferral)
    let aging = aging_cell(ctx)?;
    let r = aging.run_sim(&ctx.lat)?;
    let mut s = r.response_times();
    table.row(vec![
        "aging + bounded deferral (ours)".into(),
        fmt_f(s.mean(), 2),
        fmt_f(s.p95(), 2),
        fmt_f(s.max(), 2),
        r.miss_count().to_string(),
    ]);

    let r = static_slack_cell(&aging).run_sim(&ctx.lat)?;
    let mut s = r.response_times();
    table.row(vec![
        "static slack (literal Eq. 3)".into(),
        fmt_f(s.mean(), 2),
        fmt_f(s.p95(), 2),
        fmt_f(s.max(), 2),
        "-".into(),
    ]);
    table.print();
    println!("(static slack loses deadline awareness; aging bounds the starvation tail)");
    Ok(())
}

/// The shared task set of the batching-knee grid (built once, cloned
/// into each knee's cell).
fn knee_tasks(ctx: &ExperimentCtx) -> Result<Vec<Task>> {
    let model = ctx.model("dialogpt")?.clone();
    ctx.scenario_tasks(&model, Variance::Normal, ctx.seed ^ 0x2B)
}

/// The FIFO batching-knee cell: offloading disabled, device knee
/// overridden.
fn knee_cell(ctx: &ExperimentCtx, tasks: Vec<Task>, knee: f64) -> Result<ReplayCell> {
    let model = ctx.model("dialogpt")?.clone();
    let dev = DeviceProfile { batch_knee: knee, ..DeviceProfile::edge_server() };
    let params = ctx.params_for(&model.name);
    Ok(ctx
        .cell_with(&model, tasks, PolicyKind::Fifo, &dev, params, f64::INFINITY)
        .labelled(&format!("internal/knee{knee:.0}")))
}

fn knee_sensitivity(ctx: &ExperimentCtx) -> Result<()> {
    let mut table = Table::new(
        "internal ablation — accelerator batching-knee sensitivity (FIFO)",
        &["knee", "mean s", "p95 s", "throughput/min"],
    );
    let tasks = knee_tasks(ctx)?;
    for knee in [1.0, 4.0, 12.0, 32.0] {
        let r = knee_cell(ctx, tasks.clone(), knee)?.run_sim(&ctx.lat)?;
        let mut s = r.response_times();
        table.row(vec![
            format!("{knee:.0}"),
            fmt_f(s.mean(), 2),
            fmt_f(s.p95(), 2),
            fmt_f(r.throughput_per_min(), 1),
        ]);
    }
    table.print();
    println!("(knee=1 is serial CPU-PJRT reality; knee=12 is the modeled A4500 lane)");
    Ok(())
}

/// The shared task set of the quarantine-pool grid.
fn cpu_workers_tasks(ctx: &ExperimentCtx) -> Result<Vec<Task>> {
    let model = ctx.model("blenderbot")?.clone();
    ctx.scenario_tasks(&model, Variance::Large, ctx.seed ^ 0x3C)
}

/// The RT-LM quarantine-pool cell: CPU-lane worker count overridden.
fn cpu_workers_cell(
    ctx: &ExperimentCtx,
    tasks: Vec<Task>,
    workers: usize,
) -> Result<ReplayCell> {
    let model = ctx.model("blenderbot")?.clone();
    let dev = DeviceProfile { cpu_workers: workers, ..DeviceProfile::edge_server() };
    Ok(ctx
        .cell(&model, tasks, PolicyKind::RtLm, &dev)
        .labelled(&format!("internal/cpu-workers{workers}")))
}

fn cpu_worker_sensitivity(ctx: &ExperimentCtx) -> Result<()> {
    let mut table = Table::new(
        "internal ablation — CPU-lane worker pool (RT-LM, large variance)",
        &["workers", "mean s", "p95 s", "max s", "offloaded"],
    );
    let tasks = cpu_workers_tasks(ctx)?;
    for workers in [1usize, 2, 4, 8] {
        let r = cpu_workers_cell(ctx, tasks.clone(), workers)?.run_sim(&ctx.lat)?;
        let offloaded = r
            .outcomes
            .iter()
            .filter(|o| o.lane == crate::scheduler::LaneId::CPU)
            .count();
        let mut s = r.response_times();
        table.row(vec![
            workers.to_string(),
            fmt_f(s.mean(), 2),
            fmt_f(s.p95(), 2),
            fmt_f(s.max(), 2),
            offloaded.to_string(),
        ]);
    }
    table.print();
    println!("(offloading helps only when the quarantine lane has real parallel capacity)");
    Ok(())
}

/// The shared task set of the distribution comparison.
fn distribution_tasks(ctx: &ExperimentCtx) -> Result<Vec<Task>> {
    let model = ctx.model("dialogpt")?.clone();
    ctx.scenario_tasks(&model, Variance::Large, ctx.seed ^ 0x4D)
}

/// The FIFO-vs-RT-LM distribution cells (dialogpt, large variance).
fn distribution_cell(
    ctx: &ExperimentCtx,
    tasks: Vec<Task>,
    kind: PolicyKind,
) -> Result<ReplayCell> {
    let model = ctx.model("dialogpt")?.clone();
    let dev = DeviceProfile::edge_server();
    Ok(ctx
        .cell(&model, tasks, kind, &dev)
        .labelled(&format!("internal/dist-{}", kind.label().to_ascii_lowercase())))
}

/// The iteration-level (`--sched step`) variant of a distribution cell:
/// same heavy-tailed task set, slot-table dispatch.
fn step_cell(ctx: &ExperimentCtx, tasks: Vec<Task>, kind: PolicyKind) -> Result<ReplayCell> {
    let mut cell = distribution_cell(ctx, tasks, kind)?;
    cell.params.mode = SchedMode::Step;
    Ok(cell.labelled(&format!("internal/step-{}", kind.label().to_ascii_lowercase())))
}

/// Whole-batch vs iteration-level dispatch on the heavy-tailed
/// (large-variance) trace: batch mode pins short co-batched tasks
/// behind the longest generation; step mode releases them at their own
/// step boundary. CI records this table in the step summary.
fn step_vs_batch(ctx: &ExperimentCtx) -> Result<()> {
    let mut table = Table::new(
        "internal ablation — whole-batch vs iteration-level dispatch (heavy-tailed trace)",
        &["policy", "sched", "mean s", "p95 s", "ttft p95 s", "steps", "preempted"],
    );
    let tasks = distribution_tasks(ctx)?;
    for kind in [PolicyKind::Fifo, PolicyKind::RtLm] {
        for mode in [SchedMode::Batch, SchedMode::Step] {
            let cell = match mode {
                SchedMode::Batch => distribution_cell(ctx, tasks.clone(), kind)?,
                SchedMode::Step => step_cell(ctx, tasks.clone(), kind)?,
            };
            let r = cell.run_sim(&ctx.lat)?;
            let mut s = r.response_times();
            let mut ttft = r.ttft_times();
            table.row(vec![
                kind.label().into(),
                mode.label().into(),
                fmt_f(s.mean(), 2),
                fmt_f(s.p95(), 2),
                fmt_f(ttft.p95(), 2),
                r.n_steps.iter().sum::<usize>().to_string(),
                r.n_preempted.to_string(),
            ]);
        }
    }
    table.print();
    println!("(step mode joins at step boundaries and leaves individually; see DESIGN.md)");
    Ok(())
}

/// Fig. 9's distributions as printable histograms (FIFO vs RT-LM).
fn response_distributions(ctx: &ExperimentCtx) -> Result<()> {
    let tasks = distribution_tasks(ctx)?;
    for kind in [PolicyKind::Fifo, PolicyKind::RtLm] {
        let r = distribution_cell(ctx, tasks.clone(), kind)?.run_sim(&ctx.lat)?;
        let values: Vec<f64> = r.outcomes.iter().map(|o| o.response_time()).collect();
        print!(
            "{}",
            histogram(
                &format!("response time s — {} (dialogpt, large variance)", kind.label()),
                &values,
                12,
                40
            )
        );
    }
    Ok(())
}

/// The internal comparison cells, as the wire-parity suite `rtlm bench
/// --wire` replays: aging (full + static-slack emulation), the batching
/// knee extremes, the quarantine-pool extremes, the FIFO/RT-LM
/// distribution pair, and the iteration-level (`--sched step`) pair
/// over the same distribution trace. Together they cover every policy
/// machinery the internal ablations measure — UP priorities,
/// consolidation, strategic offloading, FIFO batching, slot-table
/// dispatch — on both engine backends.
///
/// `filter` selects cells by label — an exact match (whole label, or
/// its final `/`-segment, e.g. `knee1`) selects just that cell even
/// when the name is a prefix of another (`knee1` vs `knee12`); any
/// other filter keeps every cell whose label contains it as a
/// substring. Cells are only built (task sets only generated) when
/// they survive the filter.
pub fn parity_cells(ctx: &ExperimentCtx, filter: Option<&str>) -> Result<Vec<ReplayCell>> {
    let knee_points = [1.0, 12.0];
    let pool_points = [1usize, 4];
    let kind_points = [PolicyKind::Fifo, PolicyKind::RtLm];
    let mut labels = vec!["internal/aging".to_string(), "internal/static-slack".to_string()];
    labels.extend(knee_points.iter().map(|knee| format!("internal/knee{knee:.0}")));
    labels.extend(pool_points.iter().map(|w| format!("internal/cpu-workers{w}")));
    labels.extend(
        kind_points
            .iter()
            .map(|kind| format!("internal/dist-{}", kind.label().to_ascii_lowercase())),
    );
    labels.extend(
        kind_points
            .iter()
            .map(|kind| format!("internal/step-{}", kind.label().to_ascii_lowercase())),
    );
    let exact = filter
        .map(|f| labels.iter().any(|l| l == f || l.ends_with(&format!("/{f}"))))
        .unwrap_or(false);
    let keep = |label: &str| match filter {
        None => true,
        Some(f) if exact => label == f || label.ends_with(&format!("/{f}")),
        Some(f) => label.contains(f),
    };
    let mut cells = Vec::new();
    if keep("internal/aging") || keep("internal/static-slack") {
        let aging = aging_cell(ctx)?;
        let slack = static_slack_cell(&aging);
        if keep(&aging.label) {
            cells.push(aging);
        }
        if keep(&slack.label) {
            cells.push(slack);
        }
    }
    let knees: Vec<f64> = knee_points
        .into_iter()
        .filter(|knee| keep(&format!("internal/knee{knee:.0}")))
        .collect();
    if !knees.is_empty() {
        let tasks = knee_tasks(ctx)?;
        for knee in knees {
            cells.push(knee_cell(ctx, tasks.clone(), knee)?);
        }
    }
    let pools: Vec<usize> = pool_points
        .into_iter()
        .filter(|workers| keep(&format!("internal/cpu-workers{workers}")))
        .collect();
    if !pools.is_empty() {
        let tasks = cpu_workers_tasks(ctx)?;
        for workers in pools {
            cells.push(cpu_workers_cell(ctx, tasks.clone(), workers)?);
        }
    }
    let kinds: Vec<PolicyKind> = kind_points
        .into_iter()
        .filter(|kind| keep(&format!("internal/dist-{}", kind.label().to_ascii_lowercase())))
        .collect();
    let step_kinds: Vec<PolicyKind> = kind_points
        .into_iter()
        .filter(|kind| keep(&format!("internal/step-{}", kind.label().to_ascii_lowercase())))
        .collect();
    if !kinds.is_empty() || !step_kinds.is_empty() {
        let tasks = distribution_tasks(ctx)?;
        for kind in kinds {
            cells.push(distribution_cell(ctx, tasks.clone(), kind)?);
        }
        for kind in step_kinds {
            cells.push(step_cell(ctx, tasks.clone(), kind)?);
        }
    }
    Ok(cells)
}
