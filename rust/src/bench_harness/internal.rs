//! Ablations of *this implementation's* design decisions (DESIGN.md
//! §Scheduler-semantics) — the paper's Algorithm 1 is underspecified;
//! these benches justify each choice with measurements:
//!
//! 1. dynamic slack (aging) vs the literal static-arrival reading
//! 2. bounded deferral on/off
//! 3. accelerator batching-knee sensitivity
//! 4. CPU-lane worker-pool sensitivity
//!
//! Run with `rtlm bench internal` or
//! `cargo bench --bench paper_tables -- internal`.

use anyhow::Result;

use crate::config::{DeviceProfile, SchedParams};
use crate::metrics::table::fmt_f;
use crate::metrics::{histogram, Table};
use crate::scheduler::{LaneSet, PolicyKind, Task};
use crate::sim::run_sim;
use crate::workload::subsets::Variance;

use super::scenarios::ExperimentCtx;

pub fn run_internal(ctx: &ExperimentCtx) -> Result<()> {
    aging_ablation(ctx)?;
    println!();
    knee_sensitivity(ctx)?;
    println!();
    cpu_worker_sensitivity(ctx)?;
    println!();
    response_distributions(ctx)?;
    Ok(())
}

/// Static-arrival slack (the literal Eq. 3 reading) is emulated by
/// freezing each task's arrival as its "now": we shift priority points
/// so the slack term equals the arrival-time value forever.
fn aging_ablation(ctx: &ExperimentCtx) -> Result<()> {
    let model = ctx.model("dialogpt")?.clone();
    let dev = DeviceProfile::edge_server();
    let tasks = ctx.scenario_tasks(&model, Variance::Large, ctx.seed ^ 0x1A)?;

    let run = |tasks: Vec<Task>, params: &SchedParams| {
        let tau = ctx.taus[&model.name];
        let mut policy =
            PolicyKind::RtLm.build(params, model.eta, &LaneSet::two_lane(&model.name, tau));
        run_sim(tasks, &mut *policy, &ctx.lat, &model, &dev, params)
    };

    let mut table = Table::new(
        "internal ablation — dynamic slack (aging) and bounded deferral",
        &["variant", "mean s", "p95 s", "max s", "misses"],
    );

    // full RT-LM (aging + bounded deferral)
    let params = ctx.params_for(&model.name);
    let r = run(tasks.clone(), &params);
    let mut s = r.response_times();
    table.row(vec![
        "aging + bounded deferral (ours)".into(),
        fmt_f(s.mean(), 2),
        fmt_f(s.p95(), 2),
        fmt_f(s.max(), 2),
        r.miss_count().to_string(),
    ]);

    // static slack emulation: make every priority point so far away that
    // aging never binds within the run -> ordering is numerator-only,
    // i.e. the static low-uncertainty-first order the paper's literal
    // formula degenerates to under load.
    let mut frozen = tasks.clone();
    for t in &mut frozen {
        t.priority_point = t.arrival + 1e6;
    }
    let r = run(frozen, &params);
    let mut s = r.response_times();
    table.row(vec![
        "static slack (literal Eq. 3)".into(),
        fmt_f(s.mean(), 2),
        fmt_f(s.p95(), 2),
        fmt_f(s.max(), 2),
        "-".into(),
    ]);
    table.print();
    println!("(static slack loses deadline awareness; aging bounds the starvation tail)");
    Ok(())
}

fn knee_sensitivity(ctx: &ExperimentCtx) -> Result<()> {
    let model = ctx.model("dialogpt")?.clone();
    let tasks = ctx.scenario_tasks(&model, Variance::Normal, ctx.seed ^ 0x2B)?;
    let mut table = Table::new(
        "internal ablation — accelerator batching-knee sensitivity (FIFO)",
        &["knee", "mean s", "p95 s", "throughput/min"],
    );
    for knee in [1.0, 4.0, 12.0, 32.0] {
        let dev = DeviceProfile { batch_knee: knee, ..DeviceProfile::edge_server() };
        let params = ctx.params_for(&model.name);
        let no_offload = LaneSet::two_lane(&model.name, f64::INFINITY);
        let mut policy = PolicyKind::Fifo.build(&params, model.eta, &no_offload);
        let r = run_sim(tasks.clone(), &mut *policy, &ctx.lat, &model, &dev, &params);
        let mut s = r.response_times();
        table.row(vec![
            format!("{knee:.0}"),
            fmt_f(s.mean(), 2),
            fmt_f(s.p95(), 2),
            fmt_f(r.throughput_per_min(), 1),
        ]);
    }
    table.print();
    println!("(knee=1 is serial CPU-PJRT reality; knee=12 is the modeled A4500 lane)");
    Ok(())
}

fn cpu_worker_sensitivity(ctx: &ExperimentCtx) -> Result<()> {
    let model = ctx.model("blenderbot")?.clone();
    let tasks = ctx.scenario_tasks(&model, Variance::Large, ctx.seed ^ 0x3C)?;
    let mut table = Table::new(
        "internal ablation — CPU-lane worker pool (RT-LM, large variance)",
        &["workers", "mean s", "p95 s", "max s", "offloaded"],
    );
    for workers in [1usize, 2, 4, 8] {
        let dev = DeviceProfile { cpu_workers: workers, ..DeviceProfile::edge_server() };
        let params = ctx.params_for(&model.name);
        let tau = ctx.taus[&model.name];
        let mut policy =
            PolicyKind::RtLm.build(&params, model.eta, &LaneSet::two_lane(&model.name, tau));
        let r = run_sim(tasks.clone(), &mut *policy, &ctx.lat, &model, &dev, &params);
        let offloaded = r
            .outcomes
            .iter()
            .filter(|o| o.lane == crate::scheduler::LaneId::CPU)
            .count();
        let mut s = r.response_times();
        table.row(vec![
            workers.to_string(),
            fmt_f(s.mean(), 2),
            fmt_f(s.p95(), 2),
            fmt_f(s.max(), 2),
            offloaded.to_string(),
        ]);
    }
    table.print();
    println!("(offloading helps only when the quarantine lane has real parallel capacity)");
    Ok(())
}

/// Fig. 9's distributions as printable histograms (FIFO vs RT-LM).
fn response_distributions(ctx: &ExperimentCtx) -> Result<()> {
    let model = ctx.model("dialogpt")?.clone();
    let dev = DeviceProfile::edge_server();
    let tasks = ctx.scenario_tasks(&model, Variance::Large, ctx.seed ^ 0x4D)?;
    for kind in [PolicyKind::Fifo, PolicyKind::RtLm] {
        let r = ctx.run_policy(&model, tasks.clone(), kind, &dev);
        let values: Vec<f64> = r.outcomes.iter().map(|o| o.response_time()).collect();
        print!(
            "{}",
            histogram(
                &format!("response time s — {} (dialogpt, large variance)", kind.label()),
                &values,
                12,
                40
            )
        );
    }
    Ok(())
}
