//! Paper-reproduction harness: one runner per table/figure in the
//! evaluation section. Each runner prints the same rows/series the paper
//! reports (tables as ASCII tables, figures as labelled series/bars).
//!
//! Every runner executes its cells through the [`replay::ReplayCell`]
//! abstraction, so any cell can also be replayed over the wall-clock
//! threaded engine and diffed against the simulation (`rtlm bench
//! --wire`, see [`replay`]).
//!
//! Invoked by `rtlm bench <experiment>` and the `paper_tables` bench.

pub mod gauntlet;
pub mod internal;
pub mod replay;
pub mod scenarios;

pub use gauntlet::{gauntlet_json, render_gauntlet, run_gauntlet, GauntletConfig, Scenario};
pub use replay::{run_parity, CellParity, ParityTolerance, ReplayCell};
pub use scenarios::{run_experiment, ExperimentCtx};
