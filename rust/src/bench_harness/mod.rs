//! Paper-reproduction harness: one runner per table/figure in the
//! evaluation section. Each runner prints the same rows/series the paper
//! reports (tables as ASCII tables, figures as labelled series/bars).
//!
//! Invoked by `rtlm bench <experiment>` and the `paper_tables` bench.

pub mod internal;
pub mod scenarios;

pub use scenarios::{run_experiment, ExperimentCtx};
