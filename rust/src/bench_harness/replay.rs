//! Wire-path experiment parity: capture an experiment cell's inputs
//! once, execute them on *either* engine backend, and diff the reports.
//!
//! A [`ReplayCell`] is everything one cell of the paper's evaluation
//! grid needs to run: the task set, the lane fleet, the scheduling
//! parameters, the policy kind, and the device/model tables the latency
//! model is resolved against. Every experiment runner in
//! [`super::scenarios`] / [`super::internal`] builds cells instead of
//! calling the simulator directly, which is what makes the wire replay
//! free: the same cell can run
//!
//! - through [`crate::sim::run_sim_lanes`] (virtual clock — the path
//!   that produces the paper tables), or
//! - through [`crate::server::serve_with_factory`] over a
//!   [`crate::engine::ThreadedBackend`] with
//!   [`crate::executor::modeled_factory`] executors (real injector /
//!   dispatcher / lane-worker threads, modeled batch durations).
//!
//! [`run_parity`] runs both and [`check_parity`] diffs the reports into
//! a [`CellParity`]: *exact-match* fields (task conservation, per-lane
//! task counts, per-lane batch counts) and *toleranced* fields
//! (response-time statistics, makespan, inference time) compared under
//! a `--time-scale`-aware [`ParityTolerance`]. `rtlm bench --wire`
//! replays the internal comparison cells this way and CI gates on a
//! clean report.
//!
//! ## Why the exact-match fields are deterministic across backends
//!
//! The replay runs the cell's [`deterministic`](ReplayCell::deterministic)
//! variant:
//!
//! 1. **Burst admission** — every arrival is injected before the first
//!    dispatch (upfront injection; arrivals rebased to t = 0), so
//!    "arrivals done" holds from the first pop, every pop runs forced,
//!    and batch structure cannot race arrival timing.
//! 2. **Dilated engine clock** — the threaded backend reports engine
//!    time in virtual seconds (wall × time-scale), so the policy's
//!    time-dependent priorities see the same timeline the simulator's
//!    virtual clock provides.
//! 3. **Backlog-covering reorder window** — `params.b` is raised so the
//!    consolidation window spans the whole queued backlog; the λ-split
//!    then depends only on the queued *set* (sorted by uncertainty),
//!    not on the clock-sensitive priority ranking of a partial window.
//!
//! Under those three, routing happens at push time (a pure function of
//! each task's uncertainty), non-consolidated pops always take
//! `min(C, queue)` tasks, and consolidated pops split a set that both
//! backends agree on — so per-lane task counts and per-lane batch
//! counts are equal by construction, and any divergence is a real
//! engine/back-end bug, not scheduling noise. Response-time statistics
//! remain subject to wall-clock sleep/wakeup jitter (dilated by the
//! time scale), which is what the toleranced comparison absorbs.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::config::{DeviceProfile, ModelEntry, SchedMode, SchedParams};
use crate::executor::modeled_factory;
use crate::metrics::table::fmt_f;
use crate::metrics::Table;
use crate::scheduler::{LaneKind, LaneSet, Policy, PolicyKind, Task};
use crate::server::{serve_with_factory, ServeOptions, ServeReport};
use crate::sim::{run_sim_lanes, LatencyModel, SimResult};
use crate::util::json::{obj, Json};

/// One experiment cell, captured as data: executable on the virtual
/// clock ([`run_sim`](Self::run_sim)) or over real threads
/// ([`run_wire`](Self::run_wire)).
#[derive(Clone)]
pub struct ReplayCell {
    /// Human-readable cell id, e.g. `internal/aging/dialogpt`.
    pub label: String,
    /// Which policy schedules the cell.
    pub kind: PolicyKind,
    /// Scheduler hyper-parameters (per-cell batch size included).
    pub params: SchedParams,
    /// Output-tokens→seconds coefficient of the primary lane's model
    /// (what [`PolicyKind::build`] receives).
    pub eta: f64,
    /// The lane fleet the cell schedules.
    pub lanes: LaneSet,
    /// Model table every lane's variant resolves against.
    pub models: BTreeMap<String, ModelEntry>,
    /// Device profile supplying latency multipliers and CPU workers.
    pub dev: DeviceProfile,
    /// The task set, arrival times included.
    pub tasks: Vec<Task>,
}

impl ReplayCell {
    /// A cell over the historical two-lane fleet (accelerator fallback +
    /// CPU quarantine admitting `u > tau`), serving `model` on both
    /// lanes — the shape every paper-grid cell has.
    pub fn two_lane(
        label: &str,
        kind: PolicyKind,
        params: SchedParams,
        model: &ModelEntry,
        tau: f64,
        dev: DeviceProfile,
        tasks: Vec<Task>,
    ) -> ReplayCell {
        ReplayCell {
            label: label.to_string(),
            kind,
            params,
            eta: model.eta,
            lanes: LaneSet::two_lane(&model.name, tau),
            models: BTreeMap::from([(model.name.clone(), model.clone())]),
            dev,
            tasks,
        }
    }

    /// Same cell under a new label (cells built by shared helpers are
    /// relabelled by the suites that register them).
    pub fn labelled(mut self, label: &str) -> ReplayCell {
        self.label = label.to_string();
        self
    }

    /// Build this cell's policy instance (fresh state per run).
    pub fn policy(&self) -> Box<dyn Policy> {
        self.kind.build(&self.params, self.eta, &self.lanes)
    }

    /// Execute the cell on the virtual-clock backend — exactly the
    /// discrete-event simulation the experiment tables are produced by.
    pub fn run_sim(&self, lat: &LatencyModel) -> Result<SimResult> {
        let mut policy = self.policy();
        run_sim_lanes(
            self.tasks.clone(),
            &mut *policy,
            lat,
            &self.lanes,
            &self.models,
            &self.dev,
            &self.params,
        )
    }

    /// Execute the cell over the wall-clock engine: real injector,
    /// dispatcher and per-lane worker threads, modeled batch durations
    /// compressed by `time_scale`, deterministic replay mode
    /// ([`ServeOptions::deterministic`]) so the report reads in virtual
    /// seconds, directly comparable against [`Self::run_sim`].
    pub fn run_wire(&self, lat: &LatencyModel, time_scale: f64) -> Result<ServeReport> {
        let mut policy = self.policy();
        let factory =
            modeled_factory(lat.clone(), self.models.clone(), self.dev.clone(), time_scale);
        let opts = ServeOptions { time_scale, deterministic: true, ..Default::default() };
        serve_with_factory(
            self.tasks.clone(),
            &mut *policy,
            &self.params,
            &self.lanes,
            &opts,
            factory,
        )
    }

    /// The deterministic-replay variant of this cell (see the module
    /// docs for why each transformation is needed): arrivals rebased to
    /// a t = 0 burst (priority-point offsets preserved), tasks in
    /// arrival order, and the consolidation reorder window widened to
    /// cover the whole backlog.
    pub fn deterministic(&self) -> ReplayCell {
        let mut cell = self.clone();
        cell.tasks
            .sort_by(|a, b| a.arrival.total_cmp(&b.arrival).then(a.id.cmp(&b.id)));
        for t in &mut cell.tasks {
            t.priority_point -= t.arrival;
            t.arrival = 0.0;
        }
        let c_min = cell
            .lanes
            .iter()
            .filter(|l| l.kind == LaneKind::Accelerator)
            .map(|l| l.batch_size.unwrap_or(cell.params.batch_size).max(1))
            .min()
            .unwrap_or_else(|| cell.params.batch_size.max(1));
        let need = cell.tasks.len() as f64 / c_min as f64 + 1.0;
        cell.params.b = cell.params.b.max(need);
        cell
    }
}

/// The `--time-scale`-aware comparison budget for the toleranced fields
/// of a parity diff.
///
/// A value passes when `|sim - wire| <= abs_secs + rel * max(|sim|,
/// |wire|)`. The absolute term absorbs wall-clock sleep overshoot and
/// thread wakeup latency, which the dilated engine clock multiplies by
/// the time scale — so callers derive it from a *wall* slop budget via
/// [`for_time_scale`](Self::for_time_scale).
#[derive(Clone, Debug)]
pub struct ParityTolerance {
    /// Relative tolerance on each compared statistic.
    pub rel: f64,
    /// Absolute tolerance in engine (virtual) seconds.
    pub abs_secs: f64,
}

impl ParityTolerance {
    /// Budget from explicit knobs: `rel` relative tolerance plus
    /// `wall_slop_ms` milliseconds of *wall* slop, dilated by the time
    /// scale (the one place the dilation rule lives).
    pub fn new(rel: f64, wall_slop_ms: f64, time_scale: f64) -> ParityTolerance {
        ParityTolerance { rel, abs_secs: wall_slop_ms / 1e3 * time_scale.max(1.0) }
    }

    /// Default budget: 25% relative, plus 40 ms of wall slop dilated by
    /// the time scale.
    pub fn for_time_scale(time_scale: f64) -> ParityTolerance {
        ParityTolerance::new(0.25, 40.0, time_scale)
    }

    /// Does `wire` agree with `sim` within this budget?
    pub fn within(&self, sim: f64, wire: f64) -> bool {
        (sim - wire).abs() <= self.abs_secs + self.rel * sim.abs().max(wire.abs())
    }
}

/// One toleranced statistic of a parity diff.
#[derive(Clone, Debug)]
pub struct FieldCheck {
    /// Statistic name, e.g. `mean_response`.
    pub name: String,
    /// Virtual-clock value (seconds).
    pub sim: f64,
    /// Wire value (virtual seconds, via the dilated clock).
    pub wire: f64,
    /// Whether the value passed the tolerance.
    pub ok: bool,
}

impl FieldCheck {
    /// `|sim - wire| / max(|sim|, |wire|)` (0 when both are 0).
    pub fn rel_err(&self) -> f64 {
        let scale = self.sim.abs().max(self.wire.abs());
        if scale <= 0.0 {
            0.0
        } else {
            (self.sim - self.wire).abs() / scale
        }
    }
}

/// The structured sim-vs-wire diff of one cell.
#[derive(Clone, Debug)]
pub struct CellParity {
    /// The cell's label.
    pub label: String,
    /// Policy name both backends ran (a mismatch is itself a failure).
    pub policy: String,
    /// Task count of the cell.
    pub n_tasks: usize,
    /// Lane names, in `LaneId` order.
    pub lanes: Vec<String>,
    /// Dispatched batches per lane on the virtual clock (exact-match in
    /// batch mode; reported but not asserted in step mode, where a
    /// "batch" is a join group and group composition races lane timing).
    pub sim_batches: Vec<usize>,
    /// Dispatched batches per lane on the wire (see `sim_batches`).
    pub wire_batches: Vec<usize>,
    /// Completed tasks per lane on the virtual clock (exact-match).
    pub sim_lane_tasks: Vec<usize>,
    /// Completed tasks per lane on the wire (exact-match).
    pub wire_lane_tasks: Vec<usize>,
    /// Executed decode steps per lane on the virtual clock
    /// (exact-match: per-task step counts are timing-independent).
    pub sim_steps: Vec<usize>,
    /// Executed decode steps per lane on the wire (exact-match).
    pub wire_steps: Vec<usize>,
    /// Preempted generations on the virtual clock (exact-match; always
    /// 0 in batch mode).
    pub sim_preempted: usize,
    /// Preempted generations on the wire (exact-match).
    pub wire_preempted: usize,
    /// Toleranced statistics.
    pub stats: Vec<FieldCheck>,
    /// Every violated check, rendered human-readably; empty = clean.
    pub failures: Vec<String>,
}

impl CellParity {
    /// Did every exact and toleranced check pass?
    pub fn clean(&self) -> bool {
        self.failures.is_empty()
    }

    /// `name=sim/wire` per-lane batch table, e.g. `gpu=6/6 cpu=2/2`.
    pub fn fmt_batches(&self) -> String {
        self.lanes
            .iter()
            .zip(self.sim_batches.iter().zip(&self.wire_batches))
            .map(|(name, (s, w))| format!("{name}={s}/{w}"))
            .collect::<Vec<_>>()
            .join(" ")
    }

    /// `name=sim/wire` per-lane decode-step table, e.g. `gpu=412/412`.
    pub fn fmt_steps(&self) -> String {
        self.lanes
            .iter()
            .enumerate()
            .map(|(i, name)| {
                format!(
                    "{name}={}/{}",
                    self.sim_steps.get(i).copied().unwrap_or(0),
                    self.wire_steps.get(i).copied().unwrap_or(0)
                )
            })
            .collect::<Vec<_>>()
            .join(" ")
    }
}

fn lane_task_counts(outcomes: &[crate::sim::results::TaskOutcome], n_lanes: usize) -> Vec<usize> {
    let mut counts = vec![0usize; n_lanes];
    for o in outcomes {
        if o.lane.index() < n_lanes {
            counts[o.lane.index()] += 1;
        }
    }
    counts
}

/// Diff a cell's virtual-clock and wire reports into a [`CellParity`].
///
/// Exact-match fields: policy name, total task count, per-lane task
/// counts, per-lane decode-step counts, preemption counts — and, in
/// [`SchedMode::Batch`], per-lane batch counts (in step mode a "batch"
/// is a join group whose composition races lane timing on the wire, so
/// group counts are reported but not asserted; the timing-independent
/// step counters take over as the exact discriminator). Toleranced
/// fields (under `tol`): mean/p95/max response time, mean/p95 TTFT,
/// makespan, mean pure-inference time.
pub fn check_parity(
    label: &str,
    n_tasks: usize,
    mode: SchedMode,
    sim: &SimResult,
    wire: &ServeReport,
    tol: &ParityTolerance,
) -> CellParity {
    let mut failures = Vec::new();
    if sim.policy != wire.policy {
        failures.push(format!("policy: sim '{}' != wire '{}'", sim.policy, wire.policy));
    }
    if sim.lanes != wire.lanes {
        failures.push(format!("lanes: sim {:?} != wire {:?}", sim.lanes, wire.lanes));
    }
    if sim.outcomes.len() != n_tasks || wire.outcomes.len() != n_tasks {
        failures.push(format!(
            "tasks: expected {n_tasks}, sim completed {}, wire completed {}",
            sim.outcomes.len(),
            wire.outcomes.len()
        ));
    }

    let n_lanes = sim.lanes.len().max(wire.lanes.len());
    let sim_lane_tasks = lane_task_counts(&sim.outcomes, n_lanes);
    let wire_lane_tasks = lane_task_counts(&wire.outcomes, n_lanes);
    for (i, name) in sim.lanes.iter().enumerate() {
        let (s, w) = (sim_lane_tasks[i], wire_lane_tasks[i]);
        if s != w {
            failures.push(format!("tasks[{name}]: sim {s} != wire {w}"));
        }
        let (sb, wb) = (
            sim.n_batches.get(i).copied().unwrap_or(0),
            wire.n_batches.get(i).copied().unwrap_or(0),
        );
        if mode == SchedMode::Batch && sb != wb {
            failures.push(format!("batches[{name}]: sim {sb} != wire {wb}"));
        }
        let (ss, ws) = (
            sim.n_steps.get(i).copied().unwrap_or(0),
            wire.n_steps.get(i).copied().unwrap_or(0),
        );
        if ss != ws {
            failures.push(format!("steps[{name}]: sim {ss} != wire {ws}"));
        }
    }
    if sim.n_preempted != wire.n_preempted {
        failures.push(format!(
            "preempted: sim {} != wire {}",
            sim.n_preempted, wire.n_preempted
        ));
    }

    let mut sim_rt = sim.response_times();
    let mut wire_rt = wire.response_times();
    let mut sim_ttft = sim.ttft_times();
    let mut wire_ttft = wire.ttft_times();
    let wire_makespan = wire.outcomes.iter().map(|o| o.completion).fold(0.0, f64::max);
    let wire_mean_infer = if wire.outcomes.is_empty() {
        0.0
    } else {
        wire.outcomes.iter().map(|o| o.infer_secs).sum::<f64>() / wire.outcomes.len() as f64
    };
    let mut stats = Vec::new();
    for (name, s, w) in [
        ("mean_response", sim_rt.mean(), wire_rt.mean()),
        ("p95_response", sim_rt.p95(), wire_rt.p95()),
        ("max_response", sim_rt.max(), wire_rt.max()),
        ("mean_ttft", sim_ttft.mean(), wire_ttft.mean()),
        ("p95_ttft", sim_ttft.p95(), wire_ttft.p95()),
        ("makespan", sim.makespan, wire_makespan),
        ("mean_infer", sim.mean_infer_secs(), wire_mean_infer),
    ] {
        let ok = tol.within(s, w);
        if !ok {
            failures.push(format!(
                "{name}: sim {} vs wire {} (|Δ| {} > {}·max + {} abs)",
                fmt_f(s, 3),
                fmt_f(w, 3),
                fmt_f((s - w).abs(), 3),
                fmt_f(tol.rel, 2),
                fmt_f(tol.abs_secs, 3)
            ));
        }
        stats.push(FieldCheck { name: name.to_string(), sim: s, wire: w, ok });
    }

    CellParity {
        label: label.to_string(),
        policy: sim.policy.clone(),
        n_tasks,
        lanes: sim.lanes.clone(),
        sim_batches: sim.n_batches.clone(),
        wire_batches: wire.n_batches.clone(),
        sim_lane_tasks,
        wire_lane_tasks,
        sim_steps: sim.n_steps.clone(),
        wire_steps: wire.n_steps.clone(),
        sim_preempted: sim.n_preempted,
        wire_preempted: wire.n_preempted,
        stats,
        failures,
    }
}

/// Replay `cell` on both backends in deterministic mode and diff the
/// reports (see the module docs for the determinism argument).
pub fn run_parity(
    cell: &ReplayCell,
    lat: &LatencyModel,
    time_scale: f64,
    tol: &ParityTolerance,
) -> Result<CellParity> {
    let det = cell.deterministic();
    let sim = det.run_sim(lat)?;
    let wire = det.run_wire(lat, time_scale)?;
    Ok(check_parity(&det.label, det.tasks.len(), det.params.mode, &sim, &wire, tol))
}

/// Render the parity suite as the ASCII table `rtlm bench --wire`
/// prints.
pub fn render_parity(cells: &[CellParity]) -> String {
    let mut table = Table::new(
        "sim-vs-wire parity (counts exact, stats toleranced; values sim/wire)",
        &["cell", "policy", "n", "batches", "steps", "mean s", "p95 s", "ttft p95 s", "status"],
    );
    for c in cells {
        let stat = |name: &str| -> String {
            c.stats
                .iter()
                .find(|f| f.name == name)
                .map(|f| format!("{}/{}", fmt_f(f.sim, 2), fmt_f(f.wire, 2)))
                .unwrap_or_else(|| "-".into())
        };
        table.row(vec![
            c.label.clone(),
            c.policy.clone(),
            c.n_tasks.to_string(),
            c.fmt_batches(),
            c.fmt_steps(),
            stat("mean_response"),
            stat("p95_response"),
            stat("p95_ttft"),
            if c.clean() { "ok".into() } else { format!("FAIL ({})", c.failures.len()) },
        ]);
    }
    table.render()
}

/// Serialise the parity suite as the structured JSON report
/// `scripts/parity_delta.py` renders into the CI step summary.
pub fn parity_json(time_scale: f64, tol: &ParityTolerance, cells: &[CellParity]) -> Json {
    let cell_json = |c: &CellParity| {
        obj(vec![
            ("label", Json::Str(c.label.clone())),
            ("policy", Json::Str(c.policy.clone())),
            ("n_tasks", Json::Num(c.n_tasks as f64)),
            ("clean", Json::Bool(c.clean())),
            (
                "lanes",
                Json::Arr(c.lanes.iter().map(|l| Json::Str(l.clone())).collect()),
            ),
            (
                "sim_batches",
                Json::Arr(c.sim_batches.iter().map(|&n| Json::Num(n as f64)).collect()),
            ),
            (
                "wire_batches",
                Json::Arr(c.wire_batches.iter().map(|&n| Json::Num(n as f64)).collect()),
            ),
            (
                "sim_lane_tasks",
                Json::Arr(c.sim_lane_tasks.iter().map(|&n| Json::Num(n as f64)).collect()),
            ),
            (
                "wire_lane_tasks",
                Json::Arr(c.wire_lane_tasks.iter().map(|&n| Json::Num(n as f64)).collect()),
            ),
            (
                "sim_steps",
                Json::Arr(c.sim_steps.iter().map(|&n| Json::Num(n as f64)).collect()),
            ),
            (
                "wire_steps",
                Json::Arr(c.wire_steps.iter().map(|&n| Json::Num(n as f64)).collect()),
            ),
            ("sim_preempted", Json::Num(c.sim_preempted as f64)),
            ("wire_preempted", Json::Num(c.wire_preempted as f64)),
            (
                "stats",
                Json::Arr(
                    c.stats
                        .iter()
                        .map(|f| {
                            obj(vec![
                                ("name", Json::Str(f.name.clone())),
                                ("sim", Json::Num(f.sim)),
                                ("wire", Json::Num(f.wire)),
                                ("ok", Json::Bool(f.ok)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "failures",
                Json::Arr(c.failures.iter().map(|f| Json::Str(f.clone())).collect()),
            ),
        ])
    };
    obj(vec![
        ("time_scale", Json::Num(time_scale)),
        ("rel_tol", Json::Num(tol.rel)),
        ("abs_secs", Json::Num(tol.abs_secs)),
        ("cells", Json::Arr(cells.iter().map(cell_json).collect())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::LaneId;
    use crate::sim::results::TaskOutcome;

    fn outcome(id: u64, completion: f64, lane: LaneId) -> TaskOutcome {
        TaskOutcome {
            id,
            arrival: 0.0,
            completion,
            first_token: completion / 2.0,
            priority_point: 5.0,
            uncertainty: 10.0,
            true_len: 10,
            lane,
            utype: "test".into(),
            malicious: false,
            infer_secs: completion / 2.0,
            shed: false,
            slo: crate::scheduler::SloClass::Standard,
        }
    }

    fn sim_result(n_batches: Vec<usize>, completions: &[(u64, f64, LaneId)]) -> SimResult {
        let outcomes: Vec<TaskOutcome> =
            completions.iter().map(|&(id, c, l)| outcome(id, c, l)).collect();
        let makespan = outcomes.iter().map(|o| o.completion).fold(0.0, f64::max);
        SimResult {
            policy: "FIFO".into(),
            outcomes,
            makespan,
            sched_wall_secs: 0.0,
            lanes: vec!["gpu".into(), "cpu".into()],
            n_batches,
            n_steps: vec![0, 0],
            n_preempted: 0,
            n_shed: 0,
        }
    }

    fn wire_report(n_batches: Vec<usize>, completions: &[(u64, f64, LaneId)]) -> ServeReport {
        let outcomes: Vec<TaskOutcome> =
            completions.iter().map(|&(id, c, l)| outcome(id, c, l)).collect();
        ServeReport {
            policy: "FIFO".into(),
            outcomes,
            lanes: vec!["gpu".into(), "cpu".into()],
            n_batches,
            ..Default::default()
        }
    }

    #[test]
    fn tolerance_is_rel_plus_abs() {
        let tol = ParityTolerance { rel: 0.1, abs_secs: 0.5 };
        assert!(tol.within(10.0, 10.0));
        assert!(tol.within(10.0, 11.4)); // 1.4 <= 0.5 + 0.1*11.4
        assert!(!tol.within(10.0, 12.0)); // 2.0 > 0.5 + 0.1*12.0
        assert!(tol.within(0.0, 0.4)); // abs term alone
        assert!(!tol.within(0.0, 0.6));
        // symmetric in its arguments
        assert_eq!(tol.within(3.0, 4.0), tol.within(4.0, 3.0));
    }

    #[test]
    fn dilated_tolerance_scales_with_time() {
        let t1 = ParityTolerance::for_time_scale(1.0);
        let t50 = ParityTolerance::for_time_scale(50.0);
        assert!(t50.abs_secs > t1.abs_secs * 40.0);
        assert_eq!(t1.rel, t50.rel);
    }

    #[test]
    fn matching_reports_are_clean() {
        let done = [
            (0, 1.0, LaneId::GPU),
            (1, 1.0, LaneId::GPU),
            (2, 3.0, LaneId::CPU),
        ];
        let sim = sim_result(vec![1, 1], &done);
        let wire = wire_report(vec![1, 1], &done);
        let parity = check_parity(
            "cell",
            3,
            SchedMode::Batch,
            &sim,
            &wire,
            &ParityTolerance { rel: 0.1, abs_secs: 0.1 },
        );
        assert!(parity.clean(), "{:?}", parity.failures);
        assert_eq!(parity.fmt_batches(), "gpu=1/1 cpu=1/1");
        assert!(parity.stats.iter().all(|f| f.ok));
    }

    #[test]
    fn batch_count_mismatch_is_exact_and_names_the_lane() {
        let done = [(0, 1.0, LaneId::GPU), (1, 1.2, LaneId::GPU)];
        let sim = sim_result(vec![1, 0], &done);
        // same stats, one extra wire batch on the cpu lane: must fail
        // even though every toleranced field agrees
        let wire = wire_report(vec![1, 1], &done);
        let parity = check_parity(
            "cell",
            2,
            SchedMode::Batch,
            &sim,
            &wire,
            &ParityTolerance { rel: 1.0, abs_secs: 100.0 },
        );
        assert!(!parity.clean());
        assert!(
            parity.failures.iter().any(|f| f.contains("batches[cpu]")),
            "failure must name the diverging lane: {:?}",
            parity.failures
        );
        assert!(parity.stats.iter().all(|f| f.ok), "stats were within tolerance");
    }

    #[test]
    fn step_mode_skips_batch_counts_but_exact_matches_steps() {
        let done = [(0, 1.0, LaneId::GPU), (1, 2.0, LaneId::GPU)];
        let mut sim = sim_result(vec![2, 0], &done);
        sim.n_steps = vec![20, 0];
        // one join group on the wire vs two in sim: fine in step mode
        let mut wire = wire_report(vec![1, 0], &done);
        wire.n_steps = vec![20, 0];
        let tol = ParityTolerance { rel: 1.0, abs_secs: 100.0 };
        let parity = check_parity("cell", 2, SchedMode::Step, &sim, &wire, &tol);
        assert!(parity.clean(), "{:?}", parity.failures);
        // diverging step counts fail exactly
        wire.n_steps = vec![19, 0];
        let parity = check_parity("cell", 2, SchedMode::Step, &sim, &wire, &tol);
        assert!(
            parity.failures.iter().any(|f| f.contains("steps[gpu]")),
            "{:?}",
            parity.failures
        );
        // so does a preemption-count mismatch
        wire.n_steps = vec![20, 0];
        wire.n_preempted = 1;
        let parity = check_parity("cell", 2, SchedMode::Step, &sim, &wire, &tol);
        assert!(parity.failures.iter().any(|f| f.contains("preempted")));
        // and in batch mode the group-count divergence is itself a failure
        wire.n_preempted = 0;
        let parity = check_parity("cell", 2, SchedMode::Batch, &sim, &wire, &tol);
        assert!(parity.failures.iter().any(|f| f.contains("batches[gpu]")));
    }

    #[test]
    fn lane_routing_mismatch_is_exact() {
        let sim = sim_result(vec![1, 1], &[(0, 1.0, LaneId::GPU), (1, 3.0, LaneId::CPU)]);
        let wire = wire_report(vec![1, 1], &[(0, 1.0, LaneId::GPU), (1, 3.0, LaneId::GPU)]);
        let parity = check_parity(
            "cell",
            2,
            SchedMode::Batch,
            &sim,
            &wire,
            &ParityTolerance { rel: 1.0, abs_secs: 100.0 },
        );
        assert!(parity.failures.iter().any(|f| f.contains("tasks[gpu]")), "{:?}", parity.failures);
        assert!(parity.failures.iter().any(|f| f.contains("tasks[cpu]")));
    }

    #[test]
    fn stat_outside_tolerance_fails_with_values_rendered() {
        let sim = sim_result(vec![1, 0], &[(0, 1.0, LaneId::GPU)]);
        let wire = wire_report(vec![1, 0], &[(0, 9.0, LaneId::GPU)]);
        let parity = check_parity(
            "cell",
            1,
            SchedMode::Batch,
            &sim,
            &wire,
            &ParityTolerance { rel: 0.1, abs_secs: 0.1 },
        );
        assert!(!parity.clean());
        let failure = parity
            .failures
            .iter()
            .find(|f| f.contains("mean_response"))
            .expect("mean_response must be reported");
        assert!(failure.contains("1.000") && failure.contains("9.000"), "{failure}");
        let mean = parity.stats.iter().find(|f| f.name == "mean_response").unwrap();
        assert!(!mean.ok);
        assert!((mean.rel_err() - 8.0 / 9.0).abs() < 1e-9);
    }

    #[test]
    fn lost_task_is_reported() {
        let sim = sim_result(vec![1, 0], &[(0, 1.0, LaneId::GPU), (1, 1.0, LaneId::GPU)]);
        let wire = wire_report(vec![1, 0], &[(0, 1.0, LaneId::GPU)]);
        let parity = check_parity(
            "cell",
            2,
            SchedMode::Batch,
            &sim,
            &wire,
            &ParityTolerance { rel: 1.0, abs_secs: 100.0 },
        );
        assert!(parity.failures.iter().any(|f| f.starts_with("tasks:")), "{:?}", parity.failures);
    }

    #[test]
    fn render_and_json_cover_every_cell() {
        let done = [(0, 1.0, LaneId::GPU)];
        let sim = sim_result(vec![1, 0], &done);
        let wire = wire_report(vec![1, 0], &done);
        let tol = ParityTolerance { rel: 0.1, abs_secs: 0.1 };
        let parity = check_parity("my-cell", 1, SchedMode::Batch, &sim, &wire, &tol);
        let rendered = render_parity(std::slice::from_ref(&parity));
        assert!(rendered.contains("my-cell") && rendered.contains("ok"), "{rendered}");
        let json = parity_json(25.0, &tol, std::slice::from_ref(&parity));
        let text = json.to_string();
        let round = Json::parse(&text).expect("parity json parses");
        assert_eq!(round.get("cells").idx(0).get("label").as_str(), Some("my-cell"));
        assert_eq!(round.get("cells").idx(0).get("clean"), &Json::Bool(true));
        assert_eq!(round.get("time_scale").as_f64(), Some(25.0));
    }

    #[test]
    fn deterministic_variant_bursts_and_widens_window() {
        use crate::scheduler::task::test_task;
        let model = ModelEntry::stub("m", 0.05, 0.08);
        let tasks: Vec<Task> = (0..40)
            .map(|i| test_task(i as u64, 3.0 + i as f64 * 0.25, 8.0 + i as f64 * 0.25, 10.0))
            .collect();
        let cell = ReplayCell::two_lane(
            "cell",
            PolicyKind::RtLm,
            SchedParams { batch_size: 8, ..Default::default() },
            &model,
            60.0,
            DeviceProfile::edge_server(),
            tasks,
        );
        let det = cell.deterministic();
        assert!(det.tasks.iter().all(|t| t.arrival == 0.0));
        // priority-point offsets preserved relative to arrival
        assert!((det.tasks[0].priority_point - 5.0).abs() < 1e-9);
        // the reorder window now covers the whole backlog on every lane
        assert!(det.params.accumulate_len_for(8) >= det.tasks.len());
        // the original cell is untouched
        assert!(cell.tasks[0].arrival > 0.0);
    }
}
