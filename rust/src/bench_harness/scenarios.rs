//! Experiment runners — one per table/figure of the paper's evaluation.
//!
//! Each runner prints the same rows/series the paper reports. Absolute
//! numbers reflect *this* testbed (CPU-PJRT calibration, see DESIGN.md
//! §Hardware-Adaptation); the claims being reproduced are the shapes:
//! who wins, by roughly what factor, and where the crossovers fall.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::config::{DeviceProfile, ModelEntry, SchedParams};
use crate::metrics::summary::{linregress, pearson};
use crate::metrics::table::{bar_chart, fmt_f};
use crate::metrics::{Samples, Table};
use crate::runtime::ArtifactStore;
use crate::scheduler::{PolicyKind, Task};
use crate::sim::{LatencyModel, SimResult};
use crate::uncertainty::Estimator;
use crate::workload::subsets::{self, Variance};
use crate::workload::{corpus, malicious, ArrivalTrace, TaskFactory, WorkItem};

use super::replay::ReplayCell;

/// Shared context for all experiments.
pub struct ExperimentCtx {
    /// Artifact store the corpora/regressor/manifest were loaded from.
    pub store: Arc<ArtifactStore>,
    /// Latency model every cell simulates against.
    pub lat: LatencyModel,
    /// Baseline scheduler parameters (per-model C_f applied on top).
    pub params: SchedParams,
    /// The uncertainty estimator (RULEGEN features + LW regressor).
    pub estimator: Estimator,
    /// Tasks per simulated run (paper uses full test sets; scale knob).
    pub n_tasks: usize,
    /// Base RNG seed for workload construction.
    pub seed: u64,
    /// Per-model optimal batch size C_f (Fig. 8a decision).
    pub batch_sizes: BTreeMap<String, usize>,
    /// Per-model malicious threshold tau (Fig. 8b / Eq. 4 decision).
    pub taus: BTreeMap<String, f64>,
    train_items: Vec<WorkItem>,
    test_items: BTreeMap<String, Vec<WorkItem>>,
    observation: Vec<WorkItem>,
}

impl ExperimentCtx {
    /// Load corpora, fit offline decisions (per-model C_f and tau), and
    /// seal the shared experiment context.
    pub fn new(store: Arc<ArtifactStore>, n_tasks: usize, seed: u64) -> Result<ExperimentCtx> {
        let m = &store.manifest;
        let lat = LatencyModel::load_or_analytic(m)?;
        let estimator = Estimator::new(
            store.lexicon.clone(),
            store.regressor.clone(),
            m.max_input_len,
            m.min_output_len as f64,
            m.max_output_len as f64,
        );
        let train_items = corpus::load_many(m.corpus_train.values())?;
        let mut test_items = BTreeMap::new();
        for (ds, path) in &m.corpus_test {
            test_items.insert(ds.clone(), corpus::load(path)?);
        }
        let observation = corpus::load(&m.corpus_observation)?;

        // Offline decisions (Algorithm 1 lines 7-9).
        let mut batch_sizes = BTreeMap::new();
        let mut taus = BTreeMap::new();
        let train_scores: Vec<f64> = train_items
            .iter()
            .map(|it| estimator.score_features(&it.features))
            .collect::<Result<_>>()?;
        let params = SchedParams::default();
        let mut sorted_scores = Samples::from_vec(train_scores.clone());
        let tau = sorted_scores.quantile(params.k);
        for (name, _) in &m.models {
            batch_sizes.insert(name.clone(), optimal_batch(&lat, name));
            taus.insert(name.clone(), tau);
        }

        Ok(ExperimentCtx {
            store,
            lat,
            params,
            estimator,
            n_tasks,
            seed,
            batch_sizes,
            taus,
            train_items,
            test_items,
            observation,
        })
    }

    /// The artifact manifest.
    pub fn manifest(&self) -> &crate::config::Manifest {
        &self.store.manifest
    }

    /// Look up one model entry by name.
    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.store.manifest.model(name)
    }

    /// Every test-set work item, across datasets.
    pub fn all_test_items(&self) -> Vec<WorkItem> {
        self.test_items.values().flatten().cloned().collect()
    }

    /// The test items of one dataset.
    pub fn test_items(&self, dataset: &str) -> Result<&[WorkItem]> {
        self.test_items
            .get(dataset)
            .map(|v| v.as_slice())
            .ok_or_else(|| anyhow!("unknown dataset {dataset}"))
    }

    /// The training split (offline decisions are fit on it).
    pub fn train_items(&self) -> &[WorkItem] {
        &self.train_items
    }

    /// The Fig. 1a observation set.
    pub fn observation_items(&self) -> &[WorkItem] {
        &self.observation
    }

    /// Scheduler parameters with the model's optimal batch size C_f.
    pub fn params_for(&self, model: &str) -> SchedParams {
        SchedParams {
            batch_size: self.batch_sizes.get(model).copied().unwrap_or(16),
            ..self.params.clone()
        }
    }

    /// Simulated single-task latency at batch 1 (Fig. 1b's y-axis).
    pub fn solo_latency(&self, model: &str, input_len: usize, out_len: usize) -> f64 {
        self.lat.prefill_secs(model, 1, input_len.max(1))
            + out_len as f64 * self.lat.decode_step(model, 1)
    }

    /// Per-model beta range (arrivals/min): the paper sweeps 10..150 on
    /// hardware whose peak rate comfortably exceeds 150/min; this
    /// testbed's calibrated service rates differ per LM, so the sweep is
    /// rescaled to peak at ~90% of the model's service capacity —
    /// preserving the light-load-to-peak *shape* (DESIGN.md
    /// §Hardware-Adaptation).
    pub fn beta_range(&self, model: &ModelEntry, dev: &DeviceProfile) -> (u32, u32) {
        let c = self.batch_sizes.get(&model.name).copied().unwrap_or(16);
        // An uncertainty-oblivious batch decodes for the MAX output
        // length of its members (~E[max of C draws] ≈ 55 tokens on this
        // corpus), not the mean — capacity is estimated for the *worst*
        // (FIFO) batching so the peak stresses but does not permanently
        // saturate any policy.
        let batch_len = 55.0;
        let batch_secs = dev.gpu_speed
            * (self.lat.prefill_secs_dev(&model.name, c, 64, dev)
                + batch_len * self.lat.decode_step_dev(&model.name, c, dev))
            + dev.dispatch_overhead;
        let thr_per_min = 60.0 * c as f64 / batch_secs.max(1e-6);
        // peak transiently exceeds capacity (1.15x) — as real traffic
        // spikes do — so ordering policies actually bind; the sweep's
        // light phases let the backlog drain
        let beta_hi = (1.15 * thr_per_min).max(15.0) as u32;
        let beta_lo = (beta_hi / 15).max(1);
        (beta_lo, beta_hi)
    }

    /// Build the task set for one (model, variance) cell on the edge
    /// profile (see [`Self::scenario_tasks_on`]).
    pub fn scenario_tasks(
        &self,
        model: &ModelEntry,
        variance: Variance,
        seed: u64,
    ) -> Result<Vec<Task>> {
        self.scenario_tasks_on(model, variance, &DeviceProfile::edge_server(), seed)
    }

    /// Build the task set for one (model, variance, device) cell.
    pub fn scenario_tasks_on(
        &self,
        model: &ModelEntry,
        variance: Variance,
        dev: &DeviceProfile,
        seed: u64,
    ) -> Result<Vec<Task>> {
        let items = self.all_test_items();
        let scores: Vec<f64> = items
            .iter()
            .map(|it| self.estimator.score_features(&it.features))
            .collect::<Result<_>>()?;
        let chosen = subsets::select(&items, &scores, variance, self.n_tasks, seed);
        // compressed beta sweep: n arrivals cover the full light-to-peak
        // range of the (capacity-rescaled) paper workload
        let (lo, hi) = self.beta_range(model, dev);
        let step = ArrivalTrace::sweep_step_for(self.n_tasks, lo, hi);
        let trace =
            ArrivalTrace::poisson_sweep_scaled(self.n_tasks, lo, hi, step, seed ^ 0xA11);
        let mut factory = TaskFactory::new(self.estimator.clone(), 2.0);
        factory.build_all(&chosen, &trace, model, false)
    }

    /// Capture one (model, tasks, policy, device) grid cell on the
    /// default two-lane fleet, with this context's offline decisions
    /// (per-model batch size C_f, malicious threshold tau).
    pub fn cell(
        &self,
        model: &ModelEntry,
        tasks: Vec<Task>,
        kind: PolicyKind,
        dev: &DeviceProfile,
    ) -> ReplayCell {
        let params = self.params_for(&model.name);
        let tau = self.taus.get(&model.name).copied().unwrap_or(f64::INFINITY);
        self.cell_with(model, tasks, kind, dev, params, tau)
    }

    /// [`Self::cell`] with explicit scheduler parameters and offload
    /// threshold — the parameter-study and ablation runners override
    /// them per cell.
    pub fn cell_with(
        &self,
        model: &ModelEntry,
        tasks: Vec<Task>,
        kind: PolicyKind,
        dev: &DeviceProfile,
        params: SchedParams,
        tau: f64,
    ) -> ReplayCell {
        ReplayCell::two_lane(
            &format!("{}/{}", model.name, kind.label()),
            kind,
            params,
            model,
            tau,
            dev.clone(),
            tasks,
        )
    }

    /// Run one policy over a prepared task set (one grid cell, on the
    /// virtual-clock backend via the cell abstraction).
    pub fn run_policy(
        &self,
        model: &ModelEntry,
        tasks: Vec<Task>,
        kind: PolicyKind,
        dev: &DeviceProfile,
    ) -> SimResult {
        self.cell(model, tasks, kind, dev)
            .run_sim(&self.lat)
            .expect("a two-lane grid cell resolves its own model table")
    }
}

/// Fig. 8a decision: smallest decode bucket whose normalised batching
/// utilisation reaches 90% (the paper picks the smallest batch reaching
/// 100% GPU usage).
pub fn optimal_batch(lat: &LatencyModel, model: &str) -> usize {
    let util = lat.batching_utilisation(model, &DeviceProfile::edge_server());
    util.iter()
        .find(|(_, u)| *u >= 0.90)
        .map(|(b, _)| *b)
        .or_else(|| util.last().map(|(b, _)| *b))
        .unwrap_or(16)
}

// ===========================================================================
// experiment dispatch
// ===========================================================================

/// Every experiment name `rtlm bench` accepts (besides `all`).
pub const EXPERIMENTS: &[&str] = &[
    "fig1a", "fig1b", "fig2", "fig3", "fig4", "fig5", "fig6", "fig8", "fig9", "table3",
    "table4", "fig10", "fig11", "fig12", "fig13", "fig14", "table6", "table7", "internal",
];

/// Dispatch one experiment (or `all`) by name.
pub fn run_experiment(ctx: &ExperimentCtx, name: &str) -> Result<()> {
    match name {
        "fig1a" => fig1a(ctx),
        "fig1b" => fig1b(ctx),
        "fig2" => fig2(ctx),
        "fig3" => fig3(ctx),
        "fig4" => fig4(ctx),
        "fig5" => fig5(ctx),
        "fig6" => fig6(ctx),
        "fig8" => fig8(ctx),
        "fig9" => fig9_table3(ctx, false),
        "table3" => fig9_table3(ctx, true),
        "table4" => table4(ctx),
        "fig10" => ablation(ctx, &DeviceProfile::edge_server(), "Fig. 10 ablation (edge server)"),
        "fig11" => fig11(ctx),
        "fig12" => ablation(ctx, &DeviceProfile::agx_xavier(), "Fig. 12 ablation (AGX Xavier)"),
        "fig13" => fig13(ctx),
        "fig14" => fig14(ctx),
        "table6" => table6(ctx),
        "table7" => table7(ctx),
        "internal" => super::internal::run_internal(ctx),
        "all" => {
            for e in EXPERIMENTS {
                run_experiment(ctx, e)?;
                println!();
            }
            Ok(())
        }
        other => Err(anyhow!("unknown experiment '{other}' (have {EXPERIMENTS:?} or 'all')")),
    }
}

// ---------------------------------------------------------------------------
// Fig. 1a — output-length distribution per uncertainty type
// ---------------------------------------------------------------------------

fn fig1a(ctx: &ExperimentCtx) -> Result<()> {
    let m = ctx.manifest();
    let types = &m.uncertainty_types;
    let mut table = Table::new(
        "Fig. 1a — mean output length (tokens) per uncertainty type",
        &[&"type".to_string()[..], "mean", "std", "p95"],
    );
    let mut bars = Vec::new();
    for utype in types {
        let mut lens = Samples::new();
        for item in ctx.observation_items().iter().filter(|i| &i.utype == utype) {
            lens.push(item.mean_len());
        }
        table.row(vec![
            utype.clone(),
            fmt_f(lens.mean(), 1),
            fmt_f(lens.std(), 1),
            fmt_f(lens.p95(), 1),
        ]);
        bars.push((utype.clone(), lens.mean()));
    }
    table.print();
    print!("{}", bar_chart("mean output length by type", &bars, 40));

    let mut per_model = Table::new(
        "Fig. 1a (cont.) — mean output length per LM",
        &["type", "dialogpt", "godel", "blenderbot", "bart", "t5"],
    );
    for utype in types {
        let mut row = vec![utype.clone()];
        for model in ["dialogpt", "godel", "blenderbot", "bart", "t5"] {
            let mut lens = Samples::new();
            for item in ctx.observation_items().iter().filter(|i| &i.utype == utype) {
                lens.push(item.len_for(model) as f64);
            }
            row.push(fmt_f(lens.mean(), 1));
        }
        per_model.row(row);
    }
    per_model.print();
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 1b — inference latency is proportional to output length
// ---------------------------------------------------------------------------

fn fig1b(ctx: &ExperimentCtx) -> Result<()> {
    let mut table = Table::new(
        "Fig. 1b — latency (ms) vs output length (batch-1, calibrated model)",
        &["model", "len=8", "len=24", "len=48", "len=96", "pearson(len,lat)"],
    );
    for name in ctx.manifest().model_names() {
        let lens: Vec<f64> = ctx
            .observation_items()
            .iter()
            .map(|i| i.len_for(&name) as f64)
            .collect();
        let lats: Vec<f64> = ctx
            .observation_items()
            .iter()
            .map(|i| ctx.solo_latency(&name, i.input_len, i.len_for(&name)) * 1e3)
            .collect();
        let r = pearson(&lens, &lats);
        table.row(vec![
            name.clone(),
            fmt_f(ctx.solo_latency(&name, 12, 8) * 1e3, 1),
            fmt_f(ctx.solo_latency(&name, 12, 24) * 1e3, 1),
            fmt_f(ctx.solo_latency(&name, 12, 48) * 1e3, 1),
            fmt_f(ctx.solo_latency(&name, 12, 96) * 1e3, 1),
            fmt_f(r, 3),
        ]);
    }
    table.print();
    println!("(paper: latency grows linearly with output length; uncertain sentences 2-4x normal)");
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 2 — correlation of heuristics with output length
// ---------------------------------------------------------------------------

fn fig2(ctx: &ExperimentCtx) -> Result<()> {
    let items = ctx.all_test_items();
    let mean_lens: Vec<f64> = items.iter().map(|i| i.mean_len()).collect();
    let wr = &ctx.manifest().regressor;

    let input_lens: Vec<f64> = items.iter().map(|i| i.input_len as f64).collect();
    let single: Vec<f64> = items
        .iter()
        .map(|i| {
            crate::uncertainty::single_rule_score(
                ctx.estimator.lexicon(),
                &i.text,
                ctx.manifest().max_input_len,
            )
        })
        .collect();
    let weighted: Vec<f64> = items
        .iter()
        .map(|i| {
            i.features
                .iter()
                .zip(&wr.weighted_rule_coef)
                .map(|(f, c)| f * c)
                .sum::<f64>()
                + wr.weighted_rule_intercept
        })
        .collect();
    let lw: Vec<f64> = items
        .iter()
        .map(|i| ctx.estimator.score_features(&i.features))
        .collect::<Result<_>>()?;

    let mut table = Table::new(
        "Fig. 2 — correlation of each heuristic with mean output length",
        &["panel", "heuristic", "pearson r", "slope"],
    );
    for (panel, name, xs) in [
        ("a", "input length", &input_lens),
        ("b", "single rule", &single),
        ("c", "weighted rule", &weighted),
        ("d", "LW model", &lw),
    ] {
        let r = pearson(xs, &mean_lens);
        let (slope, _) = linregress(xs, &mean_lens);
        table.row(vec![panel.into(), name.into(), fmt_f(r, 3), fmt_f(slope, 3)]);
    }
    table.print();

    let mut ds_table = Table::new(
        "Fig. 2e — input length vs output length per dataset",
        &["dataset", "pearson r"],
    );
    for (ds, items) in &ctx.manifest().corpus_test {
        let items = corpus::load(items)?;
        let xs: Vec<f64> = items.iter().map(|i| i.input_len as f64).collect();
        let ys: Vec<f64> = items.iter().map(|i| i.mean_len()).collect();
        ds_table.row(vec![ds.clone(), fmt_f(pearson(&xs, &ys), 3)]);
    }
    ds_table.print();
    println!("(paper: r increases a -> d, LW model near-linear)");
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 3 — predicted uncertainty tracks latency on each dataset
// ---------------------------------------------------------------------------

fn fig3(ctx: &ExperimentCtx) -> Result<()> {
    let mut table = Table::new(
        "Fig. 3 — LW score vs simulated latency, per benchmark dataset",
        &["dataset", "pearson(score, latency)", "mean latency ms", "mean score"],
    );
    for ds in ctx.manifest().corpus_test.keys() {
        let items = ctx.test_items(ds)?;
        let mut scores = Vec::new();
        let mut lats = Vec::new();
        for item in items {
            scores.push(ctx.estimator.score_features(&item.features)?);
            // average latency across the five LMs (paper's Fig. 3 setup)
            let lat: f64 = ctx
                .manifest()
                .model_names()
                .iter()
                .map(|m| ctx.solo_latency(m, item.input_len, item.len_for(m)))
                .sum::<f64>()
                / ctx.manifest().models.len() as f64;
            lats.push(lat * 1e3);
        }
        table.row(vec![
            ds.clone(),
            fmt_f(pearson(&scores, &lats), 3),
            fmt_f(lats.iter().sum::<f64>() / lats.len() as f64, 1),
            fmt_f(scores.iter().sum::<f64>() / scores.len() as f64, 1),
        ]);
    }
    table.print();
    println!("(paper: predicted scores highly consistent with latency on all four datasets)");
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 4 — prioritisation toy example (HPF vs LUF vs UP)
// ---------------------------------------------------------------------------

fn fig4(ctx: &ExperimentCtx) -> Result<()> {
    // Reconstruct the paper's 5-task example on a unit latency model
    // (0.1 s/token, sequential execution). The paper hand-picks a task
    // set where HPF and LUF each strand tasks while UP balances both
    // signals; we search the same space for an instance exhibiting that
    // pattern under *our* exact scheduler semantics, then print it.
    let _ = ctx;
    let lat = unit_latency_model();
    let model = unit_model();
    let dev = unit_device();
    let mut params = SchedParams::default();
    params.batch_size = 1;

    let mut rng = crate::util::rng::Pcg64::new(0xF164);
    for _attempt in 0..5000 {
        let tasks: Vec<Task> = (0..5)
            .map(|i| {
                let u = 10.0 + rng.f64() * 70.0;
                let exec = 0.1 * u;
                // deadlines tight relative to total work: the sequential
                // server is overloaded, where EDF-style HPF falters
                let d = exec * (0.4 + rng.f64() * 2.2);
                unit_task(i + 1, d, u)
            })
            .collect();
        let mut misses = Vec::new();
        let mut orders = Vec::new();
        for kind in [PolicyKind::Hpf, PolicyKind::Luf, PolicyKind::Up] {
            let cell = ReplayCell::two_lane(
                "fig4",
                kind,
                params.clone(),
                &model,
                f64::INFINITY,
                dev.clone(),
                tasks.clone(),
            );
            let r = cell.run_sim(&lat)?;
            let mut order: Vec<(f64, u64)> =
                r.outcomes.iter().map(|o| (o.completion, o.id)).collect();
            order.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            misses.push(r.miss_count());
            orders.push(
                order.iter().map(|(_, id)| format!("J{id}")).collect::<Vec<_>>().join(" "),
            );
        }
        // paper pattern: UP best (balancing both signals), LUF worst
        if misses[2] < misses[1]
            && misses[2] <= misses[0]
            && misses[1] > misses[0]
            && misses[2] >= 1
        {
            let mut table = Table::new(
                "Fig. 4 — priority-point misses on a 5-task example (0.1 s/token)",
                &["policy", "missed", "order"],
            );
            for (i, kind) in [PolicyKind::Hpf, PolicyKind::Luf, PolicyKind::Up]
                .iter()
                .enumerate()
            {
                table.row(vec![kind.label().into(), misses[i].to_string(), orders[i].clone()]);
            }
            table.print();
            println!("tasks (id, deadline s, est. exec s):");
            for t in &tasks {
                println!("  J{}: d={:.2}  exec={:.2}", t.id, t.priority_point, 0.1 * t.uncertainty);
            }
            println!("(paper example: HPF misses 2, LUF misses 3, UP misses 1)");
            return Ok(());
        }
    }
    println!("Fig. 4: no instance found (unexpected — check scheduler semantics)");
    Ok(())
}

/// Unit-model helpers for the Fig. 4/5 mechanism illustrations.
fn unit_latency_model() -> LatencyModel {
    let mut c = crate::sim::calib::Calibration::default();
    let mut d = BTreeMap::new();
    for b in [1usize, 2, 4, 8, 16, 32] {
        // perfect batching: a batch step costs the same as a single row
        d.insert(b, 0.1);
    }
    c.decode.insert("unit".into(), d);
    let mut pf = BTreeMap::new();
    pf.insert((1usize, 16usize), 0.0);
    pf.insert((32usize, 64usize), 0.0);
    c.prefill.insert("unit".into(), pf);
    LatencyModel::from_calibration(&c)
}

fn unit_model() -> ModelEntry {
    ModelEntry::stub("unit", 0.1, 0.0)
}

fn unit_device() -> DeviceProfile {
    DeviceProfile {
        name: "unit".into(),
        gpu_speed: 1.0,
        cpu_speed: 1.0,
        batching_exp: 0.0,
        dispatch_overhead: 0.0,
        offload_overhead: 0.0,
        cpu_workers: 1,
        batch_knee: 1e9, // perfect batching in the unit examples
    }
}

fn unit_task(id: u64, d: f64, u: f64) -> Task {
    Task {
        id,
        text: String::new(),
        prompt: vec![],
        arrival: 0.0,
        priority_point: d,
        uncertainty: u,
        true_len: u.round() as usize,
        input_len: 8,
        utype: "plain".into(),
        malicious: false,
        deferrals: 0,
        slo: crate::scheduler::SloClass::Standard,
    }
}

// ---------------------------------------------------------------------------
// Fig. 5 — consolidation toy example (8 tasks, C = 4)
// ---------------------------------------------------------------------------

fn fig5(ctx: &ExperimentCtx) -> Result<()> {
    // 8 tasks, C = 4, unit latency model: uncertainty-oblivious batching
    // (similar priority points together) vs uncertainty-aware batching
    // (similar execution times together). As in Fig. 4, we search for an
    // instance exhibiting the paper's pattern under our semantics.
    let _ = ctx;
    let lat = unit_latency_model();
    let model = unit_model();
    let dev = unit_device();
    let mut params = SchedParams::default();
    params.batch_size = 4;

    let mut rng = crate::util::rng::Pcg64::new(0xF165);
    for _attempt in 0..5000 {
        let tasks: Vec<Task> = (0..8)
            .map(|i| {
                // two natural length groups, interleaved deadlines
                let u = if rng.f64() < 0.5 {
                    8.0 + rng.f64() * 10.0
                } else {
                    40.0 + rng.f64() * 30.0
                };
                let d = 1.0 + rng.f64() * 9.0;
                unit_task(i + 1, d, u)
            })
            .collect();
        let mut rows = Vec::new();
        let mut misses = Vec::new();
        let mut makespans = Vec::new();
        for kind in [PolicyKind::Hpf, PolicyKind::UpC] {
            let cell = ReplayCell::two_lane(
                "fig5",
                kind,
                params.clone(),
                &model,
                f64::INFINITY,
                dev.clone(),
                tasks.clone(),
            );
            let r = cell.run_sim(&lat)?;
            misses.push(r.miss_count());
            makespans.push(r.makespan);
            let busy: f64 = {
                let mut durs: Vec<f64> = r.outcomes.iter().map(|o| o.infer_secs).collect();
                durs.sort_by(|a, b| a.partial_cmp(b).unwrap());
                durs.dedup_by(|a, b| (*a - *b).abs() < 1e-12);
                durs.iter().sum()
            };
            let label = if kind == PolicyKind::Hpf {
                "priority-point batching"
            } else {
                "uncertainty batching"
            };
            rows.push(vec![
                label.to_string(),
                r.miss_count().to_string(),
                fmt_f(r.makespan, 2),
                fmt_f(busy / r.makespan.max(1e-9), 2),
            ]);
        }
        if misses[1] < misses[0] && makespans[1] <= makespans[0] + 1e-9 {
            let mut table = Table::new(
                "Fig. 5 — uncertainty-oblivious vs uncertainty-aware batching (8 tasks, C=4)",
                &["batching", "missed", "makespan s", "gpu util"],
            );
            for row in rows {
                table.row(row);
            }
            table.print();
            println!("tasks (id, deadline s, est. exec s):");
            for t in &tasks {
                println!("  J{}: d={:.2}  exec={:.2}", t.id, t.priority_point, 0.1 * t.uncertainty);
            }
            println!("(paper example: 4 misses oblivious vs 2 consolidated, higher util)");
            return Ok(());
        }
    }
    println!("Fig. 5: no instance found (unexpected — check consolidation semantics)");
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 6 — offload transfer cost vs execution time
// ---------------------------------------------------------------------------

fn fig6(ctx: &ExperimentCtx) -> Result<()> {
    let dev = DeviceProfile::edge_server();
    let mut table = Table::new(
        "Fig. 6 — offload transfer overhead vs execution time per task",
        &["model", "exec ms (len=24)", "transfer ms", "transfer/exec"],
    );
    for name in ctx.manifest().model_names() {
        let exec = ctx.solo_latency(&name, 12, 24);
        let transfer = dev.offload_overhead;
        table.row(vec![
            name.clone(),
            fmt_f(exec * 1e3, 1),
            fmt_f(transfer * 1e3, 1),
            fmt_f(transfer / exec.max(1e-12), 2),
        ]);
    }
    table.print();
    println!("(paper: transfer is a comparable fraction of execution -> offload only demanding tasks)");
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 8 — offline decisions: optimal batch size and malicious threshold
// ---------------------------------------------------------------------------

fn fig8(ctx: &ExperimentCtx) -> Result<()> {
    let mut table = Table::new(
        "Fig. 8a — batching utilisation per decode bucket (1.0 = best rows/sec)",
        &["model", "b=1", "b=2", "b=4", "b=8", "b=16", "b=32", "C_f"],
    );
    for name in ctx.manifest().model_names() {
        let util: BTreeMap<usize, f64> = ctx
            .lat
            .batching_utilisation(&name, &DeviceProfile::edge_server())
            .into_iter()
            .collect();
        let mut row = vec![name.clone()];
        for b in [1usize, 2, 4, 8, 16, 32] {
            row.push(util.get(&b).map(|u| fmt_f(*u, 2)).unwrap_or_else(|| "-".into()));
        }
        row.push(ctx.batch_sizes.get(&name).copied().unwrap_or(0).to_string());
        table.row(row);
    }
    table.print();

    let mut t2 = Table::new(
        "Fig. 8b — training-set uncertainty distribution and tau (k=0.9)",
        &["model", "u p50", "u p90", "tau"],
    );
    let scores: Vec<f64> = ctx
        .train_items()
        .iter()
        .map(|i| ctx.estimator.score_features(&i.features))
        .collect::<Result<_>>()?;
    let mut s = Samples::from_vec(scores);
    for name in ctx.manifest().model_names() {
        t2.row(vec![
            name.clone(),
            fmt_f(s.p50(), 1),
            fmt_f(s.quantile(0.9), 1),
            fmt_f(ctx.taus.get(&name).copied().unwrap_or(f64::NAN), 1),
        ]);
    }
    t2.print();
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 9 + Table III — response time per (model, variance, policy), edge
// ---------------------------------------------------------------------------

fn fig9_table3(ctx: &ExperimentCtx, as_table3: bool) -> Result<()> {
    run_grid(ctx, &DeviceProfile::edge_server(), as_table3, "edge server")
}

fn fig11(ctx: &ExperimentCtx) -> Result<()> {
    run_grid(ctx, &DeviceProfile::agx_xavier(), false, "AGX Xavier")
}

fn run_grid(
    ctx: &ExperimentCtx,
    dev: &DeviceProfile,
    as_table3: bool,
    label: &str,
) -> Result<()> {
    let title = if as_table3 {
        format!("Table III — maximum response time (s), {label}")
    } else {
        format!("Fig. 9/11 — response time distribution (mean / p95 s), {label}")
    };
    let mut table = Table::new(
        &title,
        &["model", "variance", "FIFO", "HPF", "LUF", "MUF", "RT-LM", "RT-LM vs FIFO"],
    );
    for name in ctx.manifest().model_names() {
        let model = ctx.model(&name)?;
        for variance in Variance::ALL {
            let tasks = ctx.scenario_tasks_on(model, variance, dev, ctx.seed)?;
            let mut cells = Vec::new();
            let mut fifo_val = 0.0;
            let mut rtlm_val = 0.0;
            for kind in PolicyKind::ALL_BASELINES {
                let r = ctx.run_policy(model, tasks.clone(), kind, dev);
                let val = if as_table3 {
                    r.max_response()
                } else {
                    r.mean_response()
                };
                if kind == PolicyKind::Fifo {
                    fifo_val = val;
                }
                if kind == PolicyKind::RtLm {
                    rtlm_val = val;
                }
                cells.push(if as_table3 {
                    fmt_f(val, 2)
                } else {
                    let mut s = r.response_times();
                    format!("{}/{}", fmt_f(s.mean(), 2), fmt_f(s.p95(), 2))
                });
            }
            let improvement = (fifo_val - rtlm_val) / fifo_val.max(1e-9) * 100.0;
            let mut row = vec![name.clone(), variance.label().into()];
            row.extend(cells);
            row.push(format!("{:+.1}%", -improvement * -1.0));
            table.row(row);
        }
    }
    table.print();
    println!("(paper: uncertainty-aware wins grow with variance; RT-LM up to 30% better max RT)");
    Ok(())
}

// ---------------------------------------------------------------------------
// Table IV — throughput per (model, variance, policy)
// ---------------------------------------------------------------------------

fn table4(ctx: &ExperimentCtx) -> Result<()> {
    let dev = DeviceProfile::edge_server();
    let mut table = Table::new(
        "Table IV — peak-period throughput (tasks/min), edge server",
        &["model", "variance", "FIFO", "HPF", "LUF", "MUF", "RT-LM"],
    );
    for name in ctx.manifest().model_names() {
        let model = ctx.model(&name)?;
        for variance in Variance::ALL {
            let tasks = ctx.scenario_tasks(model, variance, ctx.seed)?;
            let mut row = vec![name.clone(), variance.label().into()];
            for kind in PolicyKind::ALL_BASELINES {
                let r = ctx.run_policy(model, tasks.clone(), kind, &dev);
                row.push(fmt_f(r.peak_throughput_per_min(), 2));
            }
            table.row(row);
        }
    }
    table.print();
    println!("(paper: RT-LM consistently highest; LUF > MUF)");
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 10 / Fig. 12 — component ablation
// ---------------------------------------------------------------------------

fn ablation(ctx: &ExperimentCtx, dev: &DeviceProfile, title: &str) -> Result<()> {
    let mut table = Table::new(
        title,
        &["model", "FIFO", "UP", "UP+C", "RT-LM (=UP+C+Off)"],
    );
    for name in ctx.manifest().model_names() {
        let model = ctx.model(&name)?;
        let tasks = ctx.scenario_tasks_on(model, Variance::Normal, dev, ctx.seed ^ 0xAB1)?;
        let mut row = vec![name.clone()];
        for kind in [PolicyKind::Fifo, PolicyKind::Up, PolicyKind::UpC, PolicyKind::RtLm] {
            let r = ctx.run_policy(model, tasks.clone(), kind, dev);
            row.push(fmt_f(r.mean_response(), 2));
        }
        table.row(row);
    }
    table.print();
    println!("(paper: every component helps; prioritisation+consolidation > offloading)");
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 13 — parameter study (alpha, b)
// ---------------------------------------------------------------------------

fn fig13(ctx: &ExperimentCtx) -> Result<()> {
    let dev = DeviceProfile::edge_server();
    let alphas: Vec<f64> = (1..=20).map(|i| i as f64 * 0.1).collect();
    let mut table = Table::new(
        "Fig. 13a — peak-period mean response (s) vs alpha (b = 2.0)",
        &["model", "a=0.1", "a=0.5", "a=1.0", "a=1.5", "a=2.0", "max dev"],
    );
    for name in ctx.manifest().model_names() {
        let model = ctx.model(&name)?;
        let tasks = ctx.scenario_tasks(model, Variance::Normal, ctx.seed ^ 0x13A)?;
        let mut series = Vec::new();
        for &alpha in &alphas {
            let mut params = ctx.params_for(&name);
            params.alpha = alpha;
            params.b = 2.0;
            let tau = ctx.taus[&name];
            let cell =
                ctx.cell_with(model, tasks.clone(), PolicyKind::RtLm, &dev, params, tau);
            let r = cell.run_sim(&ctx.lat)?;
            series.push(r.peak_mean_response());
        }
        let max_dev = series.iter().cloned().fold(f64::MIN, f64::max)
            - series.iter().cloned().fold(f64::MAX, f64::min);
        table.row(vec![
            name.clone(),
            fmt_f(series[0], 2),
            fmt_f(series[4], 2),
            fmt_f(series[9], 2),
            fmt_f(series[14], 2),
            fmt_f(series[19], 2),
            fmt_f(max_dev, 2),
        ]);
    }
    table.print();

    let bs: Vec<f64> = (10..=30).map(|i| i as f64 * 0.1).collect();
    let mut tb = Table::new(
        "Fig. 13b — peak-period mean response (s) vs b (alpha = 1.0)",
        &["model", "b=1.0", "b=1.5", "b=1.8", "b=2.5", "b=3.0", "max dev"],
    );
    for name in ctx.manifest().model_names() {
        let model = ctx.model(&name)?;
        let tasks = ctx.scenario_tasks(model, Variance::Normal, ctx.seed ^ 0x13B)?;
        let mut series = Vec::new();
        for &b in &bs {
            let mut params = ctx.params_for(&name);
            params.b = b;
            let tau = ctx.taus[&name];
            let cell =
                ctx.cell_with(model, tasks.clone(), PolicyKind::RtLm, &dev, params, tau);
            let r = cell.run_sim(&ctx.lat)?;
            series.push(r.peak_mean_response());
        }
        let max_dev = series.iter().cloned().fold(f64::MIN, f64::max)
            - series.iter().cloned().fold(f64::MAX, f64::min);
        tb.row(vec![
            name.clone(),
            fmt_f(series[0], 2),
            fmt_f(series[5], 2),
            fmt_f(series[8], 2),
            fmt_f(series[15], 2),
            fmt_f(series[20], 2),
            fmt_f(max_dev, 2),
        ]);
    }
    tb.print();
    println!("(paper: robust to alpha [max dev <= 0.35s]; b matters more [<= 0.75s], optimum ~1.8)");
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 14 — malicious-task ratio sweep
// ---------------------------------------------------------------------------

fn fig14(ctx: &ExperimentCtx) -> Result<()> {
    let dev = DeviceProfile::edge_server();
    let model = ctx.model("dialogpt")?;
    let mut factory = TaskFactory::new(ctx.estimator.clone(), 2.0);
    let items = ctx.all_test_items();
    let scores: Vec<f64> = items
        .iter()
        .map(|i| ctx.estimator.score_features(&i.features))
        .collect::<Result<_>>()?;
    let base = subsets::select(&items, &scores, Variance::Normal, ctx.n_tasks, ctx.seed ^ 0x14);

    let mut table = Table::new(
        "Fig. 14 — mean response time (s) vs malicious ratio (dialogpt)",
        &["ratio %", "FIFO", "RT-LM", "FIFO infer", "RT-LM infer"],
    );
    for pct in (0..=100).step_by(10) {
        let ratio = pct as f64 / 100.0;
        let (crafted, _) =
            malicious::inject(&base, ratio, ctx.manifest().max_output_len, ctx.seed ^ pct as u64);
        let (lo, hi) = ctx.beta_range(model, &dev);
        let step = ArrivalTrace::sweep_step_for(crafted.len(), lo, hi);
        let trace =
            ArrivalTrace::poisson_sweep_scaled(crafted.len(), lo, hi, step, ctx.seed ^ 0x141);
        // crafted items need rescoring from text (features are stale)
        let tasks = factory.build_all(&crafted, &trace, model, true)?;
        let mut row = vec![pct.to_string()];
        let mut infers = Vec::new();
        for kind in [PolicyKind::Fifo, PolicyKind::RtLm] {
            let r = ctx.run_policy(model, tasks.clone(), kind, &dev);
            row.push(fmt_f(r.mean_response(), 2));
            infers.push(fmt_f(r.mean_infer_secs(), 2));
        }
        row.extend(infers);
        table.row(row);
    }
    table.print();
    println!("(paper: FIFO degrades sharply past 30% malicious; RT-LM stays flat)");
    Ok(())
}

// ---------------------------------------------------------------------------
// Table VI — offline profiling overhead
// ---------------------------------------------------------------------------

fn table6(ctx: &ExperimentCtx) -> Result<()> {
    let reg = &ctx.manifest().regressor;
    let mut table = Table::new(
        "Table VI — offline profiling cost",
        &["model", "LW train s", "LM inference s (train set)", "ratio %", "LW params"],
    );
    let n_params: usize = {
        let sizes = &reg.layer_sizes;
        sizes.windows(2).map(|w| w[0] * w[1] + w[1]).sum()
    };
    for name in ctx.manifest().model_names() {
        // simulated total inference time of the training corpus on this LM
        let total_infer: f64 = ctx
            .train_items()
            .iter()
            .map(|i| ctx.solo_latency(&name, i.input_len, i.len_for(&name)))
            .sum();
        table.row(vec![
            name.clone(),
            fmt_f(reg.train_seconds, 1),
            fmt_f(total_infer, 1),
            fmt_f(reg.train_seconds / total_infer.max(1e-9) * 100.0, 2),
            n_params.to_string(),
        ]);
    }
    table.print();
    println!("(paper: LW training is ~3-4% of LM inference time)");
    Ok(())
}

// ---------------------------------------------------------------------------
// Table VII — online scheduling overhead
// ---------------------------------------------------------------------------

fn table7(ctx: &ExperimentCtx) -> Result<()> {
    use std::time::Instant;
    let dev = DeviceProfile::edge_server();
    let mut table = Table::new(
        "Table VII — online scheduling overhead per task",
        &["model", "prior. us", "consol.+off. us", "total us", "vs inference %"],
    );
    for name in ctx.manifest().model_names() {
        let model = ctx.model(&name)?;
        // prioritisation: feature extraction + regressor, measured on text
        let items = ctx.all_test_items();
        let texts: Vec<&str> = items.iter().take(400).map(|i| i.text.as_str()).collect();
        let mut scratch = crate::textgen::ScoreScratch::new();
        let t0 = Instant::now();
        for text in &texts {
            let _ = ctx.estimator.score_scratch(text, &mut scratch)?;
        }
        let prior_us = t0.elapsed().as_secs_f64() / texts.len() as f64 * 1e6;

        // consolidation + offload: policy push/pop wall time from a sim run
        let tasks = ctx.scenario_tasks(model, Variance::Normal, ctx.seed ^ 0x77)?;
        let n = tasks.len();
        let r = ctx.run_policy(model, tasks, PolicyKind::RtLm, &dev);
        let sched_us = r.sched_wall_secs / n as f64 * 1e6;

        let mean_infer_ms = ctx.solo_latency(&name, 12, 24) * 1e3;
        let total_us = prior_us + sched_us;
        table.row(vec![
            name.clone(),
            fmt_f(prior_us, 1),
            fmt_f(sched_us, 1),
            fmt_f(total_us, 1),
            fmt_f(total_us / 1e3 / mean_infer_ms * 100.0, 3),
        ]);
    }
    table.print();
    println!("(paper: <3% overhead vs inference; prioritisation dominates)");
    Ok(())
}
