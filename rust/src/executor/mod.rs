//! Execution lanes: how a dispatched batch actually runs.
//!
//! [`BatchExecutor`] is the pluggable execution strategy of the serving
//! engine's lane workers — real PJRT artifacts ([`PjrtExecutor`]),
//! modeled latencies with no backend ([`ModeledExecutor`]), or instant
//! completion for deterministic tests ([`InstantExecutor`]).
//!
//! On the PJRT path the accelerator lane runs batches through
//! [`LmSession::generate`] (bucketed batched decode); the quarantine
//! lane executes tasks one by one at batch 1 — the honest
//! on-this-hardware analogue of the paper's CPU offload lane: no
//! batching amortisation, strictly slower per task.

use std::sync::Arc;

use anyhow::Result;

use crate::config::{DeviceProfile, ModelEntry};
use crate::model::LmSession;
use crate::scheduler::{Batch, Lane};
use crate::sim::LatencyModel;

/// Execution record for one completed batch.
#[derive(Debug)]
pub struct ExecReport {
    pub lane: Lane,
    pub task_ids: Vec<u64>,
    /// Generated token ids per task (order matches `task_ids`).
    pub outputs: Vec<Vec<i32>>,
    /// Pure model time (prefill + decode) for the whole batch.
    pub infer_secs: f64,
    /// Decode steps executed.
    pub steps: usize,
}

/// A lane's execution strategy. The accelerator lane expects one report
/// for the whole batch; the quarantine lane one report per task (so
/// completions stream out one at a time on backends that support it).
/// Generated `outputs` travel with the engine's per-task completions —
/// that is what the TCP front-end decodes into reply text — so order
/// must match `task_ids`.
pub trait BatchExecutor {
    fn execute(&mut self, batch: &Batch) -> Result<Vec<ExecReport>>;
}

/// Builds a lane's executor *inside* the lane worker thread (PJRT
/// handles are not `Send`, so they must be born on the thread that uses
/// them).
pub type ExecutorFactory =
    Arc<dyn Fn(Lane) -> Result<Box<dyn BatchExecutor>> + Send + Sync>;

/// Real execution over PJRT artifacts.
pub struct PjrtExecutor {
    pub session: Arc<LmSession>,
}

impl BatchExecutor for PjrtExecutor {
    fn execute(&mut self, batch: &Batch) -> Result<Vec<ExecReport>> {
        match batch.lane {
            Lane::Gpu => execute_gpu(&self.session, batch).map(|r| vec![r]),
            Lane::Cpu => execute_cpu(&self.session, batch),
        }
    }
}

/// No-backend execution: sleeps the latency the calibrated model
/// predicts for the batch (compressed by `time_scale`, matching the
/// arrival-trace compression), then reports predicted-length outputs.
/// Lets the full wire path — threads, channels, ξ deadlines — run with
/// no PJRT backend and no model artifacts.
///
/// Reported `infer_secs` are the *slept* (compressed) seconds, so every
/// time in the resulting report — arrivals, completions, inference —
/// shares the one compressed wall clock.
///
/// The quarantine lane sleeps its tasks sequentially (one worker), the
/// same shape as the single PJRT quarantine thread; the simulator's
/// `cpu_workers` pool is an intra-batch parallelism model the wire path
/// does not have yet (see ROADMAP § Open items).
pub struct ModeledExecutor {
    pub lat: LatencyModel,
    pub model: ModelEntry,
    pub dev: DeviceProfile,
    pub time_scale: f64,
}

impl ModeledExecutor {
    /// Sleep the compressed duration and return how long was slept.
    fn sleep_scaled(&self, secs: f64) -> f64 {
        let scaled = secs / self.time_scale.max(1e-9);
        if scaled > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(scaled));
        }
        scaled
    }
}

impl BatchExecutor for ModeledExecutor {
    fn execute(&mut self, batch: &Batch) -> Result<Vec<ExecReport>> {
        match batch.lane {
            Lane::Gpu => {
                let secs = self.lat.gpu_batch_secs(&self.model, batch, &self.dev);
                let slept = self.sleep_scaled(secs);
                Ok(vec![ExecReport {
                    lane: Lane::Gpu,
                    task_ids: batch.tasks.iter().map(|t| t.id).collect(),
                    outputs: vec![Vec::new(); batch.tasks.len()],
                    infer_secs: slept,
                    steps: batch.max_true_len(),
                }])
            }
            Lane::Cpu => {
                let mut reports = Vec::with_capacity(batch.tasks.len());
                for task in &batch.tasks {
                    let secs = self.lat.cpu_task_secs(
                        &self.model,
                        task.true_len,
                        task.input_len,
                        &self.dev,
                    );
                    let slept = self.sleep_scaled(secs);
                    reports.push(ExecReport {
                        lane: Lane::Cpu,
                        task_ids: vec![task.id],
                        outputs: vec![Vec::new()],
                        infer_secs: slept,
                        steps: task.true_len,
                    });
                }
                Ok(reports)
            }
        }
    }
}

/// Per-lane factory over [`ModeledExecutor`]: every lane worker gets
/// its own copy of the latency model and device profile. Shared by
/// `rtlm serve --backend modeled` and the TCP front-end.
pub fn modeled_factory(
    lat: LatencyModel,
    model: ModelEntry,
    dev: DeviceProfile,
    time_scale: f64,
) -> ExecutorFactory {
    Arc::new(move |_lane| {
        Ok(Box::new(ModeledExecutor {
            lat: lat.clone(),
            model: model.clone(),
            dev: dev.clone(),
            time_scale,
        }) as Box<dyn BatchExecutor>)
    })
}

/// Completes every batch immediately — the deterministic executor the
/// cross-backend equivalence and drain tests drive the wire path with.
pub struct InstantExecutor;

impl BatchExecutor for InstantExecutor {
    fn execute(&mut self, batch: &Batch) -> Result<Vec<ExecReport>> {
        match batch.lane {
            Lane::Gpu => Ok(vec![ExecReport {
                lane: Lane::Gpu,
                task_ids: batch.tasks.iter().map(|t| t.id).collect(),
                outputs: vec![Vec::new(); batch.tasks.len()],
                infer_secs: 0.0,
                steps: 0,
            }]),
            Lane::Cpu => Ok(batch
                .tasks
                .iter()
                .map(|t| ExecReport {
                    lane: Lane::Cpu,
                    task_ids: vec![t.id],
                    outputs: vec![Vec::new()],
                    infer_secs: 0.0,
                    steps: 0,
                })
                .collect()),
        }
    }
}

/// Run a batch on the accelerator lane (batched prefill + decode).
pub fn execute_gpu(session: &Arc<LmSession>, batch: &Batch) -> Result<ExecReport> {
    let prompts: Vec<Vec<i32>> = batch.tasks.iter().map(|t| t.prompt.clone()).collect();
    let lens: Vec<usize> = batch.tasks.iter().map(|t| t.true_len.max(1)).collect();
    let gen = session.generate(&prompts, &lens)?;
    Ok(ExecReport {
        lane: Lane::Gpu,
        task_ids: batch.tasks.iter().map(|t| t.id).collect(),
        outputs: gen.tokens,
        infer_secs: gen.prefill_secs + gen.decode_secs,
        steps: gen.steps,
    })
}

/// Run a batch on the quarantine lane: tasks sequentially at batch 1.
/// Returns one report per task so completions stream out one at a time.
pub fn execute_cpu(session: &Arc<LmSession>, batch: &Batch) -> Result<Vec<ExecReport>> {
    let mut reports = Vec::with_capacity(batch.tasks.len());
    for task in &batch.tasks {
        let gen = session.generate(
            std::slice::from_ref(&task.prompt),
            &[task.true_len.max(1)],
        )?;
        reports.push(ExecReport {
            lane: Lane::Cpu,
            task_ids: vec![task.id],
            outputs: gen.tokens,
            infer_secs: gen.prefill_secs + gen.decode_secs,
            steps: gen.steps,
        });
    }
    Ok(reports)
}
