//! Real execution lanes over the PJRT artifacts.
//!
//! The accelerator lane runs batches through [`LmSession::generate`]
//! (bucketed batched decode); the quarantine lane executes tasks one by
//! one at batch 1 — the honest on-this-hardware analogue of the paper's
//! CPU offload lane: no batching amortisation, strictly slower per task.

use std::sync::Arc;

use anyhow::Result;

use crate::model::LmSession;
use crate::scheduler::{Batch, Lane};

/// Execution record for one completed batch.
#[derive(Debug)]
pub struct ExecReport {
    pub lane: Lane,
    pub task_ids: Vec<u64>,
    /// Generated token ids per task (order matches `task_ids`).
    pub outputs: Vec<Vec<i32>>,
    /// Pure model time (prefill + decode) for the whole batch.
    pub infer_secs: f64,
    /// Decode steps executed.
    pub steps: usize,
}

/// Run a batch on the accelerator lane (batched prefill + decode).
pub fn execute_gpu(session: &Arc<LmSession>, batch: &Batch) -> Result<ExecReport> {
    let prompts: Vec<Vec<i32>> = batch.tasks.iter().map(|t| t.prompt.clone()).collect();
    let lens: Vec<usize> = batch.tasks.iter().map(|t| t.true_len.max(1)).collect();
    let gen = session.generate(&prompts, &lens)?;
    Ok(ExecReport {
        lane: Lane::Gpu,
        task_ids: batch.tasks.iter().map(|t| t.id).collect(),
        outputs: gen.tokens,
        infer_secs: gen.prefill_secs + gen.decode_secs,
        steps: gen.steps,
    })
}

/// Run a batch on the quarantine lane: tasks sequentially at batch 1.
/// Returns one report per task so completions stream out one at a time.
pub fn execute_cpu(session: &Arc<LmSession>, batch: &Batch) -> Result<Vec<ExecReport>> {
    let mut reports = Vec::with_capacity(batch.tasks.len());
    for task in &batch.tasks {
        let gen = session.generate(
            std::slice::from_ref(&task.prompt),
            &[task.true_len.max(1)],
        )?;
        reports.push(ExecReport {
            lane: Lane::Cpu,
            task_ids: vec![task.id],
            outputs: gen.tokens,
            infer_secs: gen.prefill_secs + gen.decode_secs,
            steps: gen.steps,
        });
    }
    Ok(reports)
}
