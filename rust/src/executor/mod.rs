//! Execution lanes: how a dispatched batch actually runs.
//!
//! [`BatchExecutor`] is the pluggable execution strategy of the serving
//! engine's lane workers — real PJRT artifacts ([`PjrtExecutor`]),
//! modeled latencies with no backend ([`ModeledExecutor`]), or instant
//! completion for deterministic tests ([`InstantExecutor`]). Executors
//! are built per lane from that lane's [`LaneSpec`] by an
//! [`ExecutorFactory`], so a heterogeneous fleet runs each lane against
//! its own model variant.
//!
//! On the PJRT path an accelerator-kind lane runs batches through
//! [`LmSession::generate`] (bucketed batched decode); a CPU-kind
//! quarantine lane executes tasks one by one at batch 1 — the honest
//! on-this-hardware analogue of the paper's CPU offload lane: no
//! batching amortisation, strictly slower per task. On the modeled
//! path a CPU-kind lane fans its batch across a scoped std-thread pool
//! of `workers` threads (greedy, earliest-free-first — the same
//! assignment the simulator models), so the wire path's intra-batch
//! makespan matches the simulated CPU lane.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use crate::config::{DeviceProfile, ModelEntry};
use crate::model::LmSession;
use crate::scheduler::{Batch, LaneKind, LaneSpec, Task};
use crate::sim::LatencyModel;

/// Execution record for one completed batch (or one task of a CPU-lane
/// batch — quarantine executors emit one report per task).
#[derive(Debug)]
pub struct ExecReport {
    /// Ids of the tasks this report covers.
    pub task_ids: Vec<u64>,
    /// Generated token ids per task (order matches `task_ids`).
    pub outputs: Vec<Vec<i32>>,
    /// Pure model time (prefill + decode) for the whole report.
    pub infer_secs: f64,
    /// Decode steps executed.
    pub steps: usize,
    /// Wall seconds from batch-execution start to this report's
    /// completion. CPU-kind executors emit one report per task, so this
    /// reconstructs *intra-batch* completion times on the wire — the
    /// threaded backend backdates each completion by the gap to the
    /// batch's last report, matching the simulator's per-task worker
    /// model instead of stamping the whole batch at its end.
    pub end_offset_secs: f64,
    /// Wall seconds from the first output token (prefill end) back to
    /// this report's completion — the threaded backend subtracts it
    /// from the completion stamp to reconstruct each task's
    /// time-to-first-token on the engine clock.
    pub ttft_back_secs: f64,
}

/// How a batch execution ended, for executors whose substrate can
/// *survivably* disappear mid-batch (a remote node dying under its
/// in-flight work). In-process executors only ever produce `Done` —
/// their hard failures stay `Err`, which kills the run, exactly the
/// historical semantics.
#[derive(Debug)]
pub enum ExecOutcome {
    /// The batch ran to completion.
    Done(Vec<ExecReport>),
    /// The lane's substrate died mid-batch. The engine retires the
    /// lane, re-queues `requeue` through ordinary lane admission (the
    /// same path overrun preemption uses) and keeps serving on the
    /// surviving lanes.
    LaneLost {
        /// Reports for tasks that completed before the loss.
        completed: Vec<ExecReport>,
        /// In-flight tasks that never got a reply, for re-queueing.
        requeue: Vec<Task>,
        /// What killed the lane (for the eviction log line).
        error: String,
    },
}

/// A lane's execution strategy. Accelerator-kind executors return one
/// report for the whole batch; CPU-kind executors one report per task
/// (so completions stream out one at a time on backends that support
/// it). Generated `outputs` travel with the engine's per-task
/// completions — that is what the TCP front-end decodes into reply
/// text — so order must match `task_ids`.
pub trait BatchExecutor {
    /// Execute one dispatched batch to completion and report what ran.
    fn execute(&mut self, batch: &Batch) -> Result<Vec<ExecReport>>;

    /// Execute with a survivable-failure channel. The default wraps
    /// [`execute`](BatchExecutor::execute), so in-process executors are
    /// unchanged; remote-lane executors override it to report a dead
    /// node as [`ExecOutcome::LaneLost`] instead of a fatal `Err`.
    fn execute_failable(&mut self, batch: &Batch) -> Result<ExecOutcome> {
        self.execute(batch).map(ExecOutcome::Done)
    }

    /// Iteration-level interface, when this executor can price a single
    /// decode tick (`--sched step`). Whole-batch-only executors return
    /// `None` and their lanes reject step mode at spawn.
    fn stepped(&mut self) -> Option<&mut dyn SteppedExecutor> {
        None
    }
}

/// Executes step mode's two primitives one at a time: the shared
/// prefill of a join group, and one decode tick over the lane's
/// occupied slots. Both return the wall seconds spent, on the same
/// (compressed) clock as [`ExecReport::infer_secs`].
pub trait SteppedExecutor {
    /// Run the shared prefill for a join group of `n` rows with max
    /// input length `s`; returns wall seconds spent.
    fn prefill(&mut self, n: usize, s: usize) -> f64;
    /// Run one decode tick over `n` occupied slots; returns wall
    /// seconds spent.
    fn tick(&mut self, n: usize) -> f64;
}

/// Builds a lane's executor from its [`LaneSpec`], *inside* the lane
/// worker thread (PJRT handles are not `Send`, so they must be born on
/// the thread that uses them). The spec carries the lane's model
/// variant, device kind and worker count.
pub type ExecutorFactory =
    Arc<dyn Fn(&LaneSpec) -> Result<Box<dyn BatchExecutor>> + Send + Sync>;

/// Real execution over PJRT artifacts, shaped by the lane's kind.
pub struct PjrtExecutor {
    /// The lane's own PJRT session (born on the lane thread).
    pub session: Arc<LmSession>,
    /// Device kind shaping batch execution (fused vs per-task).
    pub kind: LaneKind,
}

impl BatchExecutor for PjrtExecutor {
    fn execute(&mut self, batch: &Batch) -> Result<Vec<ExecReport>> {
        match self.kind {
            LaneKind::Accelerator => execute_gpu(&self.session, batch).map(|r| vec![r]),
            // PJRT sessions are not Send, so the quarantine pool cannot
            // fan across threads here: tasks run sequentially at batch 1
            // on this lane's single session.
            LaneKind::Cpu => execute_cpu(&self.session, batch),
            LaneKind::Remote => Err(anyhow!(
                "remote lanes have no in-process PJRT executor (use rtlm route)"
            )),
        }
    }
}

/// No-backend execution: sleeps the latency the calibrated model
/// predicts for the batch (compressed by `time_scale`, matching the
/// arrival-trace compression), then reports predicted-length outputs.
/// Lets the full wire path — threads, channels, ξ deadlines — run with
/// no PJRT backend and no model artifacts.
///
/// Reported `infer_secs` are the *slept* (compressed) seconds, so every
/// time in the resulting report — arrivals, completions, inference —
/// shares the one compressed wall clock.
///
/// A CPU-kind lane fans its batch across `workers` scoped std threads
/// (tokio-free): each worker greedily pulls the next task when free,
/// which is exactly the earliest-free-first assignment
/// `SimBackend` models, so the modeled wire makespan matches the
/// simulated intra-batch makespan.
pub struct ModeledExecutor {
    /// Latency curves batch durations are drawn from.
    pub lat: LatencyModel,
    /// The lane's model variant (latency-curve key + η).
    pub model: ModelEntry,
    /// Device profile scaling the modeled durations.
    pub dev: DeviceProfile,
    /// Sleep compression factor (matches the arrival-trace compression).
    pub time_scale: f64,
    /// Device kind shaping batch execution (fused vs worker pool).
    pub kind: LaneKind,
    /// Intra-batch workers (CPU-kind lanes).
    pub workers: usize,
}

impl ModeledExecutor {
    /// Sleep the compressed duration and return how long was slept.
    fn sleep_scaled(&self, secs: f64) -> f64 {
        let scaled = secs / self.time_scale.max(1e-9);
        if scaled > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(scaled));
        }
        scaled
    }

    /// Fan one quarantine batch across the worker pool. Returns one
    /// report per task, in task order, each stamped with its own
    /// completion offset (workers finish at different times).
    fn execute_cpu_pool(&self, batch: &Batch) -> Vec<ExecReport> {
        let workers = self.workers.max(1).min(batch.tasks.len().max(1));
        let t0 = std::time::Instant::now();
        let next = AtomicUsize::new(0);
        let reports: Mutex<Vec<(usize, ExecReport)>> =
            Mutex::new(Vec::with_capacity(batch.tasks.len()));
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    let Some(task) = batch.tasks.get(i) else { return };
                    let secs = self.lat.cpu_task_secs(
                        &self.model,
                        task.true_len,
                        task.input_len,
                        &self.dev,
                    );
                    // first token lands at offload + slowed prefill end
                    let first_part = self.dev.offload_overhead
                        + self.dev.cpu_speed
                            * crate::sim::latency::CPU_LANE_SLOWDOWN
                            * self.lat.prefill_secs(
                                &self.model.name,
                                1,
                                task.input_len.max(1),
                            );
                    let slept = self.sleep_scaled(secs);
                    let report = ExecReport {
                        task_ids: vec![task.id],
                        outputs: vec![Vec::new()],
                        infer_secs: slept,
                        steps: task.true_len,
                        end_offset_secs: t0.elapsed().as_secs_f64(),
                        ttft_back_secs: ((secs - first_part)
                            / self.time_scale.max(1e-9))
                        .max(0.0),
                    };
                    reports.lock().unwrap().push((i, report));
                });
            }
        });
        let mut reports = reports.into_inner().unwrap();
        reports.sort_by_key(|(i, _)| *i);
        reports.into_iter().map(|(_, r)| r).collect()
    }
}

impl BatchExecutor for ModeledExecutor {
    fn execute(&mut self, batch: &Batch) -> Result<Vec<ExecReport>> {
        match self.kind {
            LaneKind::Accelerator => {
                let secs = self.lat.gpu_batch_secs(&self.model, batch, &self.dev);
                // first token lands at dispatch + batched prefill end
                let first_part = self.dev.dispatch_overhead
                    + self.dev.gpu_speed
                        * self.lat.prefill_secs_dev(
                            &self.model.name,
                            batch.tasks.len(),
                            batch.max_input_len(),
                            &self.dev,
                        );
                let slept = self.sleep_scaled(secs);
                Ok(vec![ExecReport {
                    task_ids: batch.tasks.iter().map(|t| t.id).collect(),
                    outputs: vec![Vec::new(); batch.tasks.len()],
                    infer_secs: slept,
                    steps: batch.max_true_len(),
                    end_offset_secs: slept,
                    ttft_back_secs: ((secs - first_part) / self.time_scale.max(1e-9))
                        .max(0.0),
                }])
            }
            LaneKind::Cpu => Ok(self.execute_cpu_pool(batch)),
            LaneKind::Remote => Err(anyhow!(
                "remote lanes have no in-process modeled executor (use rtlm route)"
            )),
        }
    }

    fn stepped(&mut self) -> Option<&mut dyn SteppedExecutor> {
        match self.kind {
            LaneKind::Accelerator => Some(self),
            LaneKind::Cpu | LaneKind::Remote => None,
        }
    }
}

impl SteppedExecutor for ModeledExecutor {
    fn prefill(&mut self, n: usize, s: usize) -> f64 {
        let secs = self.dev.dispatch_overhead
            + self.dev.gpu_speed
                * self.lat.prefill_secs_dev(&self.model.name, n, s, &self.dev);
        self.sleep_scaled(secs)
    }

    fn tick(&mut self, n: usize) -> f64 {
        let secs =
            self.dev.gpu_speed * self.lat.decode_step_dev(&self.model.name, n, &self.dev);
        self.sleep_scaled(secs)
    }
}

/// Per-lane factory over [`ModeledExecutor`]: every lane worker gets
/// its own copy of the latency model and device profile, resolved to
/// its spec's model variant and worker count (`None` workers default to
/// the device profile's `cpu_workers`, matching the simulator). Shared
/// by `rtlm serve --backend modeled` and the TCP front-end.
pub fn modeled_factory(
    lat: LatencyModel,
    models: BTreeMap<String, ModelEntry>,
    dev: DeviceProfile,
    time_scale: f64,
) -> ExecutorFactory {
    Arc::new(move |spec: &LaneSpec| {
        if spec.kind == LaneKind::Remote {
            anyhow::bail!("lane '{}': remote lanes need the rtlm route front-end", spec.name);
        }
        let model = models
            .get(&spec.model)
            .ok_or_else(|| anyhow!("lane '{}': unknown model '{}'", spec.name, spec.model))?
            .clone();
        lat.require_model(&model.name)
            .map_err(|e| anyhow!("lane '{}': {e}", spec.name))?;
        Ok(Box::new(ModeledExecutor {
            lat: lat.clone(),
            model,
            dev: dev.clone(),
            time_scale,
            kind: spec.kind,
            workers: spec.workers.unwrap_or(dev.cpu_workers).max(1),
        }) as Box<dyn BatchExecutor>)
    })
}

/// Completes every batch immediately — the deterministic executor the
/// cross-backend equivalence and drain tests drive the wire path with.
/// Kind-agnostic: one report for the whole batch.
pub struct InstantExecutor;

impl BatchExecutor for InstantExecutor {
    fn execute(&mut self, batch: &Batch) -> Result<Vec<ExecReport>> {
        Ok(vec![ExecReport {
            task_ids: batch.tasks.iter().map(|t| t.id).collect(),
            outputs: vec![Vec::new(); batch.tasks.len()],
            infer_secs: 0.0,
            steps: 0,
            end_offset_secs: 0.0,
            ttft_back_secs: 0.0,
        }])
    }

    fn stepped(&mut self) -> Option<&mut dyn SteppedExecutor> {
        Some(self)
    }
}

impl SteppedExecutor for InstantExecutor {
    fn prefill(&mut self, _n: usize, _s: usize) -> f64 {
        0.0
    }

    fn tick(&mut self, _n: usize) -> f64 {
        0.0
    }
}

/// Run a batch on an accelerator lane (batched prefill + decode).
pub fn execute_gpu(session: &Arc<LmSession>, batch: &Batch) -> Result<ExecReport> {
    let prompts: Vec<Vec<i32>> = batch.tasks.iter().map(|t| t.prompt.clone()).collect();
    let lens: Vec<usize> = batch.tasks.iter().map(|t| t.true_len.max(1)).collect();
    let t0 = std::time::Instant::now();
    let gen = session.generate(&prompts, &lens)?;
    Ok(ExecReport {
        task_ids: batch.tasks.iter().map(|t| t.id).collect(),
        outputs: gen.tokens,
        infer_secs: gen.prefill_secs + gen.decode_secs,
        steps: gen.steps,
        end_offset_secs: t0.elapsed().as_secs_f64(),
        ttft_back_secs: gen.decode_secs,
    })
}

/// Run a batch on a quarantine lane: tasks sequentially at batch 1.
/// Returns one report per task so completions stream out one at a time.
pub fn execute_cpu(session: &Arc<LmSession>, batch: &Batch) -> Result<Vec<ExecReport>> {
    let t0 = std::time::Instant::now();
    let mut reports = Vec::with_capacity(batch.tasks.len());
    for task in &batch.tasks {
        let gen = session.generate(
            std::slice::from_ref(&task.prompt),
            &[task.true_len.max(1)],
        )?;
        reports.push(ExecReport {
            task_ids: vec![task.id],
            outputs: gen.tokens,
            infer_secs: gen.prefill_secs + gen.decode_secs,
            steps: gen.steps,
            end_offset_secs: t0.elapsed().as_secs_f64(),
            ttft_back_secs: gen.decode_secs,
        });
    }
    Ok(reports)
}
