//! Device profiles for the simulator — the paper's two testbeds
//! (Table II) mapped onto calibrated multipliers of the measured CPU-PJRT
//! latencies. See DESIGN.md §Hardware-Adaptation.

/// A simulated execution platform.
#[derive(Clone, Debug)]
pub struct DeviceProfile {
    /// Profile name as accepted by `--device`.
    pub name: String,
    /// Multiplier on the accelerator-lane latency model (1.0 = the
    /// calibrated edge-server profile).
    pub gpu_speed: f64,
    /// Multiplier on the CPU-lane latency model.
    pub cpu_speed: f64,
    /// Batching efficiency exponent: a batch of size B costs
    /// `t1 * B^batching_exp` per step (1.0 = no batching benefit,
    /// 0.0 = perfect batching). Calibration overrides this when real
    /// measurements exist.
    pub batching_exp: f64,
    /// Fixed per-dispatch overhead in seconds (kernel launch, transfer).
    pub dispatch_overhead: f64,
    /// CPU-lane offload transfer overhead per task in seconds (Fig. 6:
    /// transfer time is comparable to execution for most layers).
    pub offload_overhead: f64,
    /// Parallel CPU-lane workers (the paper's edge server has a 96-core
    /// EPYC; offloaded tasks run batch-1 but several at a time).
    pub cpu_workers: usize,
    /// Accelerator batching knee: batches up to this size cost the same
    /// as batch-1 (the GPU's parallel lanes amortise them); beyond it
    /// cost grows linearly. CPU-PJRT executes rows serially, so the
    /// simulator restores the accelerator's batching behaviour on top of
    /// the calibrated batch-1 anchor (DESIGN.md §Hardware-Adaptation).
    pub batch_knee: f64,
}

impl DeviceProfile {
    /// The paper's edge server (RTX A4500, 96-core EPYC).
    pub fn edge_server() -> DeviceProfile {
        DeviceProfile {
            name: "edge-server".into(),
            // Maps the calibrated CPU-PJRT batch-1 anchor into the
            // paper's serving regime: the A4500 serves the paper's
            // 100M-400M LMs at ~0.4 s/task; x6 puts our five variants at
            // 0.29-0.83 s/task with the same relative ordering, so the
            // paper's time constants (xi = 2 s, deadlines ~2-4 s) apply
            // natively. See DESIGN.md §Hardware-Adaptation.
            gpu_speed: 6.0,
            cpu_speed: 6.0,
            batching_exp: 0.55,
            dispatch_overhead: 2.0e-3,
            offload_overhead: 8.0e-3,
            cpu_workers: 8,
            batch_knee: 12.0,
        }
    }

    /// The paper's embedded platform (NVIDIA AGX Xavier): ~3.5x slower
    /// accelerator, weaker CPU, less batching headroom.
    pub fn agx_xavier() -> DeviceProfile {
        DeviceProfile {
            name: "agx-xavier".into(),
            // 3.5x slower than the edge accelerator, 5x weaker CPU
            gpu_speed: 21.0,
            cpu_speed: 30.0,
            batching_exp: 0.70,
            dispatch_overhead: 6.0e-3,
            offload_overhead: 20.0e-3,
            cpu_workers: 2,
            batch_knee: 6.0,
        }
    }

    /// Accelerator-less edge device ("Edge-First Language Model
    /// Inference", PAPERS.md): a small-core CPU is the only compute, so
    /// fleets on this profile run a single CPU lane — no quarantine
    /// target, no batching amortisation (`batch_knee = 1`), and few
    /// workers. The `gpu_speed` is set but unreachable: gauntlet
    /// edge-cpu cells build CPU-only lane sets.
    pub fn edge_cpu() -> DeviceProfile {
        DeviceProfile {
            name: "edge-cpu".into(),
            gpu_speed: 12.0,
            // 2x slower than the edge server's EPYC per core
            cpu_speed: 12.0,
            batching_exp: 0.70,
            dispatch_overhead: 4.0e-3,
            // no PCIe hop: "offload" is a local queue hand-off
            offload_overhead: 4.0e-3,
            cpu_workers: 2,
            batch_knee: 1.0,
        }
    }

    /// Look a profile up by CLI name (`edge-server`/`edge`,
    /// `agx-xavier`/`xavier`/`agx`, `edge-cpu`/`cpu`).
    pub fn by_name(name: &str) -> anyhow::Result<DeviceProfile> {
        match name {
            "edge-server" | "edge" => Ok(Self::edge_server()),
            "agx-xavier" | "xavier" | "agx" => Ok(Self::agx_xavier()),
            "edge-cpu" | "cpu" => Ok(Self::edge_cpu()),
            other => Err(anyhow::anyhow!(
                "unknown device profile '{other}' (edge-server | agx-xavier | edge-cpu)"
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xavier_is_slower() {
        let edge = DeviceProfile::edge_server();
        let agx = DeviceProfile::agx_xavier();
        assert!(agx.gpu_speed > edge.gpu_speed);
        assert!(agx.batching_exp > edge.batching_exp);
    }

    #[test]
    fn lookup_by_name() {
        assert!(DeviceProfile::by_name("edge").is_ok());
        assert!(DeviceProfile::by_name("xavier").is_ok());
        assert!(DeviceProfile::by_name("edge-cpu").is_ok());
        assert!(DeviceProfile::by_name("tpu-v9000").is_err());
    }

    #[test]
    fn edge_cpu_has_no_batching_amortisation() {
        let d = DeviceProfile::edge_cpu();
        assert_eq!(d.batch_knee, 1.0);
        assert!(d.cpu_speed > DeviceProfile::edge_server().cpu_speed);
        assert!(d.cpu_workers < DeviceProfile::edge_server().cpu_workers);
    }
}
