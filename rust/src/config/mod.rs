//! Configuration: the artifact manifest (the python-AOT contract), the
//! scheduler hyper-parameters, and device profiles for the simulator.

pub mod device;
pub mod manifest;
pub mod sched;

pub use device::DeviceProfile;
pub use manifest::{Manifest, ModelEntry, RegressorEntry};
pub use sched::{SchedMode, SchedParams, ShedPolicy};
