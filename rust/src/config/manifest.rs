//! `artifacts/manifest.json` — the contract between the python AOT build
//! and the rust runtime: model shapes, bucket sets, parameter order,
//! file locations, and the length-model constants the workload generator
//! mirrors.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// One transformer LM variant.
#[derive(Clone, Debug)]
pub struct ModelEntry {
    /// Manifest model name (e.g. "dialogpt").
    pub name: String,
    /// Transformer depth.
    pub n_layers: usize,
    /// Hidden width.
    pub d_model: usize,
    /// Attention heads.
    pub n_heads: usize,
    /// Feed-forward width.
    pub d_ff: usize,
    /// Output-tokens -> seconds coefficient (paper's eta_f).
    pub eta: f64,
    /// Input-tokens -> priority-point coefficient (paper's phi_f).
    pub phi: f64,
    /// Length-oracle calibration (see corpus.py).
    pub gamma: f64,
    /// Length-oracle offset (see corpus.py).
    pub delta: f64,
    /// Weights bundle path.
    pub weights: PathBuf,
    /// Parameter order the lowered HLO expects.
    pub param_names: Vec<String>,
    /// (batch, seq) -> HLO path.
    pub prefill: BTreeMap<(usize, usize), PathBuf>,
    /// batch -> HLO path.
    pub decode: BTreeMap<usize, PathBuf>,
    /// batch -> multi-token chunk HLO path (perf variant; optional).
    pub decode_chunk: BTreeMap<usize, PathBuf>,
    /// Tokens per chunk execution (0 when chunks are absent).
    pub chunk_k: usize,
}

impl ModelEntry {
    /// Minimal entry for tests/benches that drive the simulator with a
    /// hand-built latency model and never touch artifact paths.
    pub fn stub(name: &str, eta: f64, phi: f64) -> ModelEntry {
        ModelEntry {
            name: name.to_string(),
            n_layers: 2,
            d_model: 64,
            n_heads: 2,
            d_ff: 128,
            eta,
            phi,
            gamma: 1.0,
            delta: 0.0,
            weights: PathBuf::new(),
            param_names: Vec::new(),
            prefill: BTreeMap::new(),
            decode: BTreeMap::new(),
            decode_chunk: BTreeMap::new(),
            chunk_k: 0,
        }
    }

    /// Per-head attention width.
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Approximate per-token FLOPs of a decode step at batch 1 (used as
    /// the analytic latency-model fallback when calibration is absent).
    pub fn decode_flops_per_row(&self, kv_len: usize) -> f64 {
        let d = self.d_model as f64;
        let f = self.d_ff as f64;
        let att = 4.0 * d * d + 2.0 * (kv_len as f64) * d;
        let ffn = 2.0 * d * f;
        (self.n_layers as f64) * 2.0 * (att + ffn)
    }
}

/// The trained LW regressor's artifact entry.
#[derive(Clone, Debug)]
pub struct RegressorEntry {
    /// Weights bundle path.
    pub weights: PathBuf,
    /// Parameter order the lowered HLO expects.
    pub param_names: Vec<String>,
    /// MLP layer widths (7 -> ... -> 1).
    pub layer_sizes: Vec<usize>,
    /// batch -> lowered-forward HLO path.
    pub hlo: BTreeMap<usize, PathBuf>,
    /// Fig. 2c weighted-rule baseline coefficients.
    pub weighted_rule_coef: Vec<f64>,
    /// Fig. 2c weighted-rule baseline intercept.
    pub weighted_rule_intercept: f64,
    /// Wall seconds the python training run took (Table VI).
    pub train_seconds: f64,
    /// Final training MSE (diagnostics).
    pub final_train_mse: f64,
}

/// Per-uncertainty-type length model (mean, std) mirrored from python.
#[derive(Clone, Debug)]
pub struct LengthModel {
    /// type -> (mean, std) output-length distribution.
    pub per_type: BTreeMap<String, (f64, f64)>,
    /// Output-length dependence on input length.
    pub input_coef: f64,
    /// Gaussian noise around the modeled mean.
    pub noise_std: f64,
}

/// The parsed `manifest.json` contract.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Artifacts directory the manifest was loaded from.
    pub root: PathBuf,
    /// Vocabulary size shared by every model.
    pub vocab_size: usize,
    /// Padding token id.
    pub pad_id: i32,
    /// Beginning-of-sequence token id.
    pub bos_id: i32,
    /// End-of-sequence token id.
    pub eos_id: i32,
    /// Unknown-word token id.
    pub unk_id: i32,
    /// Maximum total sequence length any HLO was lowered for.
    pub seq_max: usize,
    /// Input truncation length (tokens).
    pub max_input_len: usize,
    /// Upper bound on generated lengths (u_scale, quarantine cap).
    pub max_output_len: usize,
    /// Lower bound on generated lengths.
    pub min_output_len: usize,
    /// RULEGEN feature names, in feature-vector order.
    pub feature_names: Vec<String>,
    /// Normalisation scales per feature.
    pub feature_scales: Vec<f64>,
    /// The six uncertainty types of Fig. 1a.
    pub uncertainty_types: Vec<String>,
    /// Length-oracle constants mirrored from python.
    pub length_model: LengthModel,
    /// Prefill batch buckets HLO was lowered for.
    pub prefill_batch_buckets: Vec<usize>,
    /// Prefill sequence buckets HLO was lowered for.
    pub prefill_seq_buckets: Vec<usize>,
    /// Decode batch buckets HLO was lowered for.
    pub decode_batch_buckets: Vec<usize>,
    /// Every model variant, keyed by name.
    pub models: BTreeMap<String, ModelEntry>,
    /// The LW regressor entry.
    pub regressor: RegressorEntry,
    /// Lexicon JSON path.
    pub lexicon: PathBuf,
    /// dataset -> training-split JSONL path.
    pub corpus_train: BTreeMap<String, PathBuf>,
    /// dataset -> test-split JSONL path.
    pub corpus_test: BTreeMap<String, PathBuf>,
    /// Fig. 1a observation-set JSONL path.
    pub corpus_observation: PathBuf,
    /// Tokenizer/tagger/RULEGEN golden-file path.
    pub golden_textproc: PathBuf,
    /// Was this a `--quick` build (reduced buckets/corpora)?
    pub quick: bool,
}

fn f64_list(v: &Json, key: &str) -> Result<Vec<f64>> {
    v.need_arr(key)?
        .iter()
        .map(|x| x.as_f64().ok_or_else(|| anyhow!("'{key}': non-numeric entry")))
        .collect()
}

fn usize_list(v: &Json, key: &str) -> Result<Vec<usize>> {
    Ok(f64_list(v, key)?.into_iter().map(|x| x as usize).collect())
}

fn str_list(v: &Json, key: &str) -> Result<Vec<String>> {
    v.need_arr(key)?
        .iter()
        .map(|x| {
            x.as_str()
                .map(str::to_string)
                .ok_or_else(|| anyhow!("'{key}': non-string entry"))
        })
        .collect()
}

impl Manifest {
    /// Load `<root>/manifest.json` and resolve all paths against root.
    pub fn load(root: &Path) -> Result<Manifest> {
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading manifest {} (run `make artifacts`)", path.display()))?;
        let v = Json::parse(&text).map_err(|e| anyhow!("parsing manifest: {e}"))?;
        Self::from_json(root, &v)
    }

    fn from_json(root: &Path, v: &Json) -> Result<Manifest> {
        let mut models = BTreeMap::new();
        for (name, m) in v.need_obj("models")? {
            let cfg = m.get("config");
            let mut prefill = BTreeMap::new();
            for (key, path) in m.need_obj("prefill")? {
                let (b, s) = key
                    .split_once(',')
                    .ok_or_else(|| anyhow!("bad prefill bucket key '{key}'"))?;
                prefill.insert(
                    (b.parse()?, s.parse()?),
                    root.join(path.as_str().ok_or_else(|| anyhow!("bad path"))?),
                );
            }
            let mut decode = BTreeMap::new();
            for (key, path) in m.need_obj("decode")? {
                decode.insert(
                    key.parse::<usize>()?,
                    root.join(path.as_str().ok_or_else(|| anyhow!("bad path"))?),
                );
            }
            let mut decode_chunk = BTreeMap::new();
            if let Some(chunks) = m.get("decode_chunk").as_obj() {
                for (key, path) in chunks {
                    decode_chunk.insert(
                        key.parse::<usize>()?,
                        root.join(path.as_str().ok_or_else(|| anyhow!("bad path"))?),
                    );
                }
            }
            let chunk_k = m.get("chunk_k").as_usize().unwrap_or(0);
            models.insert(
                name.clone(),
                ModelEntry {
                    name: name.clone(),
                    n_layers: cfg.need_f64("n_layers")? as usize,
                    d_model: cfg.need_f64("d_model")? as usize,
                    n_heads: cfg.need_f64("n_heads")? as usize,
                    d_ff: cfg.need_f64("d_ff")? as usize,
                    eta: m.need_f64("eta")?,
                    phi: m.need_f64("phi")?,
                    gamma: m.need_f64("gamma")?,
                    delta: m.need_f64("delta")?,
                    weights: root.join(m.need_str("weights")?),
                    param_names: str_list(m, "param_names")?,
                    prefill,
                    decode,
                    decode_chunk,
                    chunk_k,
                },
            );
        }

        let r = v.get("regressor");
        let wr = r.get("weighted_rule");
        let mut reg_hlo = BTreeMap::new();
        for (key, path) in r.need_obj("hlo")? {
            reg_hlo.insert(
                key.parse::<usize>()?,
                root.join(path.as_str().ok_or_else(|| anyhow!("bad path"))?),
            );
        }
        let regressor = RegressorEntry {
            weights: root.join(r.need_str("weights")?),
            param_names: str_list(r, "param_names")?,
            layer_sizes: usize_list(r, "layer_sizes")?,
            hlo: reg_hlo,
            weighted_rule_coef: f64_list(wr, "coef")?,
            weighted_rule_intercept: wr.need_f64("intercept")?,
            train_seconds: r.need_f64("train_seconds")?,
            final_train_mse: r.need_f64("final_train_mse")?,
        };

        let lm = v.get("length_model");
        let mut per_type = BTreeMap::new();
        for (utype, pair) in lm.as_obj().ok_or_else(|| anyhow!("missing length_model"))? {
            per_type.insert(
                utype.clone(),
                (
                    pair.idx(0).as_f64().ok_or_else(|| anyhow!("bad length mean"))?,
                    pair.idx(1).as_f64().ok_or_else(|| anyhow!("bad length std"))?,
                ),
            );
        }
        let length_model = LengthModel {
            per_type,
            input_coef: v.need_f64("length_input_coef")?,
            noise_std: v.need_f64("length_noise_std")?,
        };

        let buckets = v.get("buckets");
        let corpus = v.get("corpus");
        let path_map = |j: &Json, key: &str| -> Result<BTreeMap<String, PathBuf>> {
            let mut out = BTreeMap::new();
            for (k, p) in j.need_obj(key)? {
                out.insert(
                    k.clone(),
                    root.join(p.as_str().ok_or_else(|| anyhow!("bad corpus path"))?),
                );
            }
            Ok(out)
        };

        Ok(Manifest {
            root: root.to_path_buf(),
            vocab_size: v.need_f64("vocab_size")? as usize,
            pad_id: v.need_f64("pad_id")? as i32,
            bos_id: v.need_f64("bos_id")? as i32,
            eos_id: v.need_f64("eos_id")? as i32,
            unk_id: v.need_f64("unk_id")? as i32,
            seq_max: v.need_f64("seq_max")? as usize,
            max_input_len: v.need_f64("max_input_len")? as usize,
            max_output_len: v.need_f64("max_output_len")? as usize,
            min_output_len: v.need_f64("min_output_len")? as usize,
            feature_names: str_list(v, "feature_names")?,
            feature_scales: f64_list(v, "feature_scales")?,
            uncertainty_types: str_list(v, "uncertainty_types")?,
            length_model,
            prefill_batch_buckets: usize_list(buckets, "prefill_batch")?,
            prefill_seq_buckets: usize_list(buckets, "prefill_seq")?,
            decode_batch_buckets: usize_list(buckets, "decode_batch")?,
            models,
            regressor,
            lexicon: root.join(v.need_str("lexicon")?),
            corpus_train: path_map(corpus, "train")?,
            corpus_test: path_map(corpus, "test")?,
            corpus_observation: root.join(corpus.need_str("observation")?),
            golden_textproc: root.join(v.get("goldens").need_str("textproc")?),
            quick: v.get("quick").as_bool().unwrap_or(false),
        })
    }

    /// Look up one model entry by name.
    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("unknown model '{name}' (have: {:?})", self.models.keys()))
    }

    /// Every model name, in manifest order.
    pub fn model_names(&self) -> Vec<String> {
        self.models.keys().cloned().collect()
    }

    /// Default artifacts root: `$RTLM_ARTIFACTS` or `./artifacts`.
    pub fn default_root() -> PathBuf {
        std::env::var("RTLM_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}
