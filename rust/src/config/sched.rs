//! Scheduler hyper-parameters (paper Sec. IV / V-A).

/// All tunables of UASCHED (Algorithm 1) plus workload-level knobs.
#[derive(Clone, Debug)]
pub struct SchedParams {
    /// Uncertainty weight in the UP priority (Eq. 3). Paper optimum: 1.0.
    pub alpha: f64,
    /// Max allowed uncertainty ratio between adjacent batched tasks
    /// (dynamic consolidation). Paper: 1.5.
    pub lambda: f64,
    /// Batch-accumulation factor: consolidation examines b*C queued
    /// tasks before forming a batch. Paper optimum: 1.8.
    pub b: f64,
    /// Malicious quantile (Eq. 4): tau = quantile_k of training-set
    /// uncertainty scores. Paper: 0.9.
    pub k: f64,
    /// Wait-interval: tasks arriving within xi seconds are batched
    /// together (paper Sec. V-A: 2 s).
    pub xi: f64,
    /// Fixed batch size used by the uncertainty-oblivious baselines and
    /// as the per-model optimal C_f once calibrated.
    pub batch_size: usize,
    /// Scale for normalising uncertainty scores (predicted tokens) into
    /// [0, 1] for the UP numerator; set to the max output length.
    pub u_scale: f64,
    /// Floor for the slack denominator in Eq. 3 (seconds): an overdue
    /// task saturates at maximal priority instead of dividing by <= 0.
    pub min_slack: f64,
}

impl Default for SchedParams {
    fn default() -> Self {
        SchedParams {
            alpha: 1.0,
            lambda: 1.5,
            b: 1.8,
            k: 0.9,
            xi: 2.0,
            batch_size: 16,
            u_scale: 96.0,
            min_slack: 1e-3,
        }
    }
}

impl SchedParams {
    /// Number of tasks consolidation accumulates before reordering.
    pub fn accumulate_len(&self) -> usize {
        self.accumulate_len_for(self.batch_size)
    }

    /// The reorder window for a lane with batch size `c` (lanes may
    /// override the global batch size).
    pub fn accumulate_len_for(&self, c: usize) -> usize {
        ((self.b * c as f64).floor() as usize).max(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let p = SchedParams::default();
        assert_eq!(p.alpha, 1.0);
        assert_eq!(p.lambda, 1.5);
        assert_eq!(p.b, 1.8);
        assert_eq!(p.k, 0.9);
        assert_eq!(p.xi, 2.0);
    }

    #[test]
    fn accumulate_len_scales_with_b() {
        let mut p = SchedParams { batch_size: 10, ..Default::default() };
        p.b = 1.8;
        assert_eq!(p.accumulate_len(), 18);
        p.b = 0.5; // never below one batch
        assert_eq!(p.accumulate_len(), 10);
    }
}
