//! Scheduler hyper-parameters (paper Sec. IV / V-A) and the scheduler
//! mode selector (whole-batch vs iteration-level dispatch).

/// How accelerator lanes cycle work through the engine.
///
/// `Batch` is the paper's discipline: a lane takes a whole batch,
/// executes prefill + max-length decode, and frees only when every
/// co-batched task is done. `Step` is iteration-level (continuous)
/// batching: each accelerator lane owns a slot table and runs a
/// persistent decode loop — tasks join at the next step boundary after
/// their prefill, leave individually when their own generation ends,
/// and freed slots are refilled from the queue between steps.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SchedMode {
    /// Whole-batch dispatch: one batch in flight per lane (default;
    /// bit-identical to the historical engine).
    #[default]
    Batch,
    /// Iteration-level dispatch: per-lane slot table, per-decode-step
    /// join/leave.
    Step,
}

impl SchedMode {
    /// Parse a `--sched` CLI value (`batch` | `step`).
    pub fn parse(s: &str) -> anyhow::Result<SchedMode> {
        match s {
            "batch" => Ok(SchedMode::Batch),
            "step" => Ok(SchedMode::Step),
            _ => anyhow::bail!("--sched: expected 'batch' or 'step', got '{s}'"),
        }
    }

    /// The CLI spelling (`batch` / `step`).
    pub fn label(&self) -> &'static str {
        match self {
            SchedMode::Batch => "batch",
            SchedMode::Step => "step",
        }
    }
}

/// Which queued task an at-capacity lane sacrifices when a new arrival
/// must be admitted (`--shed`). Only meaningful with a nonzero
/// `queue_cap`; the victim may be the incoming task itself.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ShedPolicy {
    /// Drop the lowest-priority task under the lane's own dispatch
    /// order (UP lanes: minimal Eq. 3 priority at arrival time; sorted
    /// baselines: the back of the queue; FIFO lanes: the newcomer).
    #[default]
    Priority,
    /// Drop the highest-predicted-length task (max uncertainty score) —
    /// sacrifices the most accelerator-seconds per dropped request.
    Length,
}

impl ShedPolicy {
    /// Parse a `--shed` CLI value (`priority` | `length`).
    pub fn parse(s: &str) -> anyhow::Result<ShedPolicy> {
        match s {
            "priority" => Ok(ShedPolicy::Priority),
            "length" => Ok(ShedPolicy::Length),
            _ => anyhow::bail!("--shed: expected 'priority' or 'length', got '{s}'"),
        }
    }

    /// The CLI spelling (`priority` / `length`).
    pub fn label(&self) -> &'static str {
        match self {
            ShedPolicy::Priority => "priority",
            ShedPolicy::Length => "length",
        }
    }
}

/// All tunables of UASCHED (Algorithm 1) plus workload-level knobs.
#[derive(Clone, Debug)]
pub struct SchedParams {
    /// Uncertainty weight in the UP priority (Eq. 3). Paper optimum: 1.0.
    pub alpha: f64,
    /// Max allowed uncertainty ratio between adjacent batched tasks
    /// (dynamic consolidation). Paper: 1.5.
    pub lambda: f64,
    /// Batch-accumulation factor: consolidation examines b*C queued
    /// tasks before forming a batch. Paper optimum: 1.8.
    pub b: f64,
    /// Malicious quantile (Eq. 4): tau = quantile_k of training-set
    /// uncertainty scores. Paper: 0.9.
    pub k: f64,
    /// Wait-interval: tasks arriving within xi seconds are batched
    /// together (paper Sec. V-A: 2 s).
    pub xi: f64,
    /// Fixed batch size used by the uncertainty-oblivious baselines and
    /// as the per-model optimal C_f once calibrated.
    pub batch_size: usize,
    /// Scale for normalising uncertainty scores (predicted tokens) into
    /// [0, 1] for the UP numerator; set to the max output length.
    pub u_scale: f64,
    /// Floor for the slack denominator in Eq. 3 (seconds): an overdue
    /// task saturates at maximal priority instead of dividing by <= 0.
    pub min_slack: f64,
    /// Dispatch discipline: whole-batch (default) or iteration-level.
    pub mode: SchedMode,
    /// Step mode only: decode slots per accelerator lane (0 = use the
    /// lane's batch size). Batch mode ignores it.
    pub slots: usize,
    /// Step mode only: preempt a running generation to the CPU lane once
    /// its executed decode steps exceed `overrun_factor` times its
    /// predicted length (uncertainty score). Non-finite or <= 0 disables
    /// preemption. Batch mode ignores it.
    pub overrun_factor: f64,
    /// Overload admission control: max queued tasks per lane (0 =
    /// unbounded, the historical behaviour). A push into a full lane
    /// sheds one task per [`ShedPolicy`]; shed tasks complete
    /// immediately with a `shed` outcome instead of executing.
    pub queue_cap: usize,
    /// Which task a full lane sheds (`--shed priority|length`).
    pub shed: ShedPolicy,
}

impl Default for SchedParams {
    fn default() -> Self {
        SchedParams {
            alpha: 1.0,
            lambda: 1.5,
            b: 1.8,
            k: 0.9,
            xi: 2.0,
            batch_size: 16,
            u_scale: 96.0,
            min_slack: 1e-3,
            mode: SchedMode::Batch,
            slots: 0,
            overrun_factor: 3.0,
            queue_cap: 0,
            shed: ShedPolicy::Priority,
        }
    }
}

impl SchedParams {
    /// Decode slots a step-mode accelerator lane with batch size `c`
    /// exposes: the explicit `slots` override, else the lane's batch
    /// size (so `--sched step` alone keeps lane capacity comparable to
    /// batch mode).
    pub fn slots_for(&self, c: usize) -> usize {
        if self.slots > 0 { self.slots } else { c.max(1) }
    }
}

impl SchedParams {
    /// Number of tasks consolidation accumulates before reordering.
    pub fn accumulate_len(&self) -> usize {
        self.accumulate_len_for(self.batch_size)
    }

    /// The reorder window for a lane with batch size `c` (lanes may
    /// override the global batch size).
    pub fn accumulate_len_for(&self, c: usize) -> usize {
        ((self.b * c as f64).floor() as usize).max(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let p = SchedParams::default();
        assert_eq!(p.alpha, 1.0);
        assert_eq!(p.lambda, 1.5);
        assert_eq!(p.b, 1.8);
        assert_eq!(p.k, 0.9);
        assert_eq!(p.xi, 2.0);
    }

    #[test]
    fn accumulate_len_scales_with_b() {
        let mut p = SchedParams { batch_size: 10, ..Default::default() };
        p.b = 1.8;
        assert_eq!(p.accumulate_len(), 18);
        p.b = 0.5; // never below one batch
        assert_eq!(p.accumulate_len(), 10);
    }

    #[test]
    fn mode_defaults_to_batch() {
        let p = SchedParams::default();
        assert_eq!(p.mode, SchedMode::Batch);
        assert_eq!(p.slots_for(16), 16); // slots=0 -> lane batch size
        let p = SchedParams { slots: 4, ..Default::default() };
        assert_eq!(p.slots_for(16), 4);
    }

    #[test]
    fn shedding_defaults_off() {
        let p = SchedParams::default();
        assert_eq!(p.queue_cap, 0, "unbounded queues by default");
        assert_eq!(p.shed, ShedPolicy::Priority);
    }

    #[test]
    fn shed_policy_parses() {
        assert_eq!(ShedPolicy::parse("priority").unwrap(), ShedPolicy::Priority);
        assert_eq!(ShedPolicy::parse("length").unwrap(), ShedPolicy::Length);
        assert!(ShedPolicy::parse("random").is_err());
        assert_eq!(ShedPolicy::Length.label(), "length");
    }

    #[test]
    fn sched_mode_parses() {
        assert_eq!(SchedMode::parse("batch").unwrap(), SchedMode::Batch);
        assert_eq!(SchedMode::parse("step").unwrap(), SchedMode::Step);
        assert!(SchedMode::parse("rolling").is_err());
        assert_eq!(SchedMode::Step.label(), "step");
    }
}
