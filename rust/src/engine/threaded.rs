//! Wall-clock execution backend: an injector thread replays the arrival
//! trace and one worker thread per lane runs batches through a
//! [`BatchExecutor`] (real PJRT sessions, modeled latencies, or an
//! instant executor for deterministic tests).
//!
//! PJRT handles are not `Send` (Rc-based internals), so executors are
//! constructed *inside* their lane thread by an [`ExecutorFactory`] —
//! each lane owns its own client + session, the same "one engine per
//! lane" shape a GPU+CPU deployment has, and no PJRT state ever crosses
//! threads.

use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::executor::{ExecReport, ExecutorFactory};
use crate::scheduler::{Batch, Lane, Task};

use super::core::{BatchDone, ExecutionBackend, Step};

enum Event {
    LaneReady(Lane),
    Arrival(Task, f64),
    /// Completion timestamps are taken by the dispatcher on receipt, so
    /// every time in a run shares the single post-init epoch clock.
    Done(Lane, Vec<ExecReport>),
    LaneError(Lane, String),
}

fn lane_worker(
    lane: Lane,
    factory: ExecutorFactory,
    batch_rx: mpsc::Receiver<Batch>,
    tx: mpsc::Sender<Event>,
) {
    let mut executor = match factory(lane) {
        Ok(e) => {
            let _ = tx.send(Event::LaneReady(lane));
            e
        }
        Err(e) => {
            let _ = tx.send(Event::LaneError(lane, format!("{e:#}")));
            return;
        }
    };
    while let Ok(batch) = batch_rx.recv() {
        match executor.execute(&batch) {
            Ok(reports) => {
                if tx.send(Event::Done(lane, reports)).is_err() {
                    return;
                }
            }
            Err(e) => {
                let _ = tx.send(Event::LaneError(lane, format!("{e:#}")));
                return;
            }
        }
    }
}

pub struct ThreadedBackend {
    event_rx: mpsc::Receiver<Event>,
    gpu_tx: Option<mpsc::Sender<Batch>>,
    cpu_tx: Option<mpsc::Sender<Batch>>,
    epoch: Instant,
    injector: Option<thread::JoinHandle<()>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadedBackend {
    /// Spawn the lane workers, wait for *both* lanes to report ready
    /// (tracked per lane — one lane reporting twice cannot mask the
    /// other failing), start the epoch clock, then start replaying
    /// `tasks` (arrival gaps compressed by `time_scale`).
    ///
    /// With `inject_upfront` every arrival is queued synchronously
    /// before this constructor returns — deterministic admission for
    /// the cross-backend equivalence and drain tests.
    pub fn start(
        tasks: Vec<Task>,
        factory: ExecutorFactory,
        time_scale: f64,
        inject_upfront: bool,
    ) -> Result<ThreadedBackend> {
        let (event_tx, event_rx) = mpsc::channel::<Event>();
        let (gpu_tx, gpu_rx) = mpsc::channel::<Batch>();
        let (cpu_tx, cpu_rx) = mpsc::channel::<Batch>();

        let mut workers = Vec::with_capacity(2);
        for (lane, rx) in [(Lane::Gpu, gpu_rx), (Lane::Cpu, cpu_rx)] {
            let tx = event_tx.clone();
            let factory = factory.clone();
            workers.push(thread::spawn(move || lane_worker(lane, factory, rx, tx)));
        }

        // wait for both lanes to finish initialising (e.g. compiling the
        // warmup buckets) before the serving clock starts
        let mut ready = [false; Lane::ALL.len()];
        while ready.contains(&false) {
            match event_rx.recv_timeout(Duration::from_secs(600)) {
                Ok(Event::LaneReady(lane)) => ready[lane.index()] = true,
                Ok(Event::LaneError(lane, e)) => {
                    return Err(anyhow!("{lane:?} lane failed to initialise: {e}"))
                }
                Ok(_) => {}
                Err(e) => return Err(anyhow!("lane initialisation timed out: {e}")),
            }
        }

        let epoch = Instant::now();
        let time_scale = time_scale.max(1e-9);
        let injector = if inject_upfront {
            for task in tasks {
                let arrived = epoch.elapsed().as_secs_f64();
                event_tx
                    .send(Event::Arrival(task, arrived))
                    .map_err(|_| anyhow!("event channel closed during upfront injection"))?;
            }
            None
        } else {
            let tx = event_tx.clone();
            Some(thread::spawn(move || {
                for task in tasks {
                    let due = task.arrival / time_scale;
                    let now = epoch.elapsed().as_secs_f64();
                    if due > now {
                        thread::sleep(Duration::from_secs_f64(due - now));
                    }
                    let arrived = epoch.elapsed().as_secs_f64();
                    if tx.send(Event::Arrival(task, arrived)).is_err() {
                        return;
                    }
                }
            }))
        };
        drop(event_tx); // only workers + injector hold senders now

        Ok(ThreadedBackend {
            event_rx,
            gpu_tx: Some(gpu_tx),
            cpu_tx: Some(cpu_tx),
            epoch,
            injector,
            workers,
        })
    }

    /// Total wall seconds since the post-init epoch, then shut the lane
    /// workers and injector down.
    pub fn finish(mut self) -> f64 {
        let wall = self.epoch.elapsed().as_secs_f64();
        self.gpu_tx.take();
        self.cpu_tx.take();
        if let Some(injector) = self.injector.take() {
            injector.join().ok();
        }
        for worker in self.workers.drain(..) {
            worker.join().ok();
        }
        wall
    }

    fn apply(&self, event: Event, step: &mut Step) -> Result<()> {
        match event {
            Event::Arrival(mut task, arrived) => {
                // rebase to the dispatcher clock so response times are real
                task.priority_point = arrived + (task.priority_point - task.arrival);
                task.arrival = arrived;
                step.arrivals.push(task);
            }
            Event::Done(lane, reports) => {
                let done = self.epoch.elapsed().as_secs_f64();
                let mut completions = Vec::new();
                let mut batch_infer_secs = 0.0;
                for rep in &reports {
                    batch_infer_secs += rep.infer_secs;
                    for &id in &rep.task_ids {
                        completions.push((id, done, rep.infer_secs));
                    }
                }
                step.done.push(BatchDone { lane, completions, batch_infer_secs });
            }
            Event::LaneReady(_) => {}
            Event::LaneError(lane, e) => {
                return Err(anyhow!("{lane:?} lane failed mid-run: {e}"));
            }
        }
        Ok(())
    }
}

impl ExecutionBackend for ThreadedBackend {
    fn now(&mut self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    fn submit(&mut self, batch: Batch) -> Result<()> {
        let tx = match batch.lane {
            Lane::Gpu => self.gpu_tx.as_ref(),
            Lane::Cpu => self.cpu_tx.as_ref(),
        };
        tx.expect("backend already finished")
            .send(batch)
            .map_err(|e| anyhow!("{:?} lane died", e.0.lane))
    }

    fn wait(&mut self, deadline: Option<f64>) -> Result<Step> {
        let disconnected = || anyhow!("all lane workers exited with tasks outstanding");
        let first = match deadline {
            Some(d) => {
                let timeout = (d - self.epoch.elapsed().as_secs_f64()).max(0.0);
                match self.event_rx.recv_timeout(Duration::from_secs_f64(timeout)) {
                    Ok(event) => Some(event),
                    Err(mpsc::RecvTimeoutError::Timeout) => None,
                    Err(mpsc::RecvTimeoutError::Disconnected) => return Err(disconnected()),
                }
            }
            // No ξ-expiry pending: the next state change can only be an
            // arrival or a completion, so block for one — no busy-poll.
            None => Some(self.event_rx.recv().map_err(|_| disconnected())?),
        };

        let mut step = Step::default();
        if let Some(event) = first {
            self.apply(event, &mut step)?;
        }
        // drain everything already queued so the dispatcher acts on the
        // freshest state (and admission is atomic for pre-queued traces)
        while let Ok(event) = self.event_rx.try_recv() {
            self.apply(event, &mut step)?;
        }
        Ok(step)
    }
}
