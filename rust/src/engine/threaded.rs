//! Wall-clock execution backend: arrivals come either from an injector
//! thread replaying a finite trace, or from [`ArrivalHandle`]s held by
//! live producers (the TCP connection handlers); one worker thread per
//! lane runs batches through a [`BatchExecutor`] built for that lane's
//! [`LaneSpec`] (real PJRT sessions of the lane's model variant,
//! modeled latencies, or an instant executor for deterministic tests).
//!
//! PJRT handles are not `Send` (Rc-based internals), so executors are
//! constructed *inside* their lane thread by an [`ExecutorFactory`] —
//! each lane owns its own client + session, the same "one engine per
//! lane" shape a heterogeneous GPU+CPU fleet has, and no PJRT state
//! ever crosses threads.
//!
//! Under `--sched step` accelerator lanes swap the whole-batch worker
//! loop for a persistent decode loop ([`stepped_lane_worker`]): join
//! groups are drained at step boundaries, every occupied slot pays one
//! decode tick per iteration, and tasks leave (or are preempted back to
//! the scheduler) individually. One modelling difference from the
//! simulator is deliberate: the worker thread serialises a join group's
//! prefill with the lane's decode ticks, where the simulator overlaps
//! them. That shifts toleranced timing stats only — per-task step
//! counts and lane membership, the step-mode parity fields, are
//! timing-independent.
//!
//! [`BatchExecutor`]: crate::executor::BatchExecutor
//! [`LaneSpec`]: crate::scheduler::LaneSpec

use std::collections::HashSet;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::config::{SchedMode, SchedParams};
use crate::executor::{BatchExecutor, ExecOutcome, ExecReport, ExecutorFactory};
use crate::scheduler::{Batch, LaneId, LaneKind, LaneSet, Task};

use super::core::{BatchDone, ExecutionBackend, LaneFailure, Preempted, Step, TaskDone};

enum Event {
    LaneReady(LaneId),
    Arrival(Task, f64),
    /// Completion timestamps are taken by the dispatcher on receipt, so
    /// every time in a run shares the single post-init epoch clock.
    Done(LaneId, Vec<ExecReport>),
    /// A stepped lane ejected an overrunning generation: the re-scored
    /// task goes back to the scheduler with the steps / inference wall
    /// seconds it already consumed.
    Preempt(LaneId, Box<Task>, usize, f64),
    LaneError(LaneId, String),
    /// A lane's executor substrate died *survivably* (remote node lost
    /// mid-batch, or evicted by the heartbeat monitor): the listed
    /// tasks were in flight there and need re-queueing. Becomes
    /// [`Step::failed`]; the engine retires the lane and keeps serving.
    LaneFailed(LaneId, Vec<Task>, String),
    /// The arrival source will never produce another task: the trace
    /// injector drained, or a live producer called
    /// [`ArrivalHandle::close`].
    StreamClosed,
}

/// A live producer's handle into the backend: stamp tasks onto the
/// engine clock and feed them to the dispatcher. Clone one per
/// connection handler; call [`close`](ArrivalHandle::close) to end the
/// stream (an open-stream [`run_engine_stream`] run then drains and
/// returns).
///
/// [`run_engine_stream`]: super::core::run_engine_stream
#[derive(Clone)]
pub struct ArrivalHandle {
    tx: mpsc::Sender<Event>,
    epoch: Instant,
}

impl ArrivalHandle {
    /// Current engine-clock time in seconds (the dispatcher's clock).
    pub fn now(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    /// Feed one task to the dispatcher. The task's `arrival` /
    /// `priority_point` should be stamped with [`now`](Self::now);
    /// the dispatcher rebases them onto its receipt time either way.
    /// Errors only when the dispatcher is gone.
    pub fn inject(&self, task: Task) -> Result<()> {
        let arrived = self.epoch.elapsed().as_secs_f64();
        self.tx.send(Event::Arrival(task, arrived)).map_err(|_| anyhow!("dispatcher is gone"))
    }

    /// Declare the arrival stream closed. Idempotent; ignored if the
    /// dispatcher already exited.
    pub fn close(&self) {
        let _ = self.tx.send(Event::StreamClosed);
    }

    /// Report `lane` survivably dead from outside its worker thread —
    /// the router's heartbeat monitor calls this when a node misses its
    /// pings. The lane worker reports its own in-flight tasks if a
    /// batch was running; this path covers the idle-lane case, so the
    /// re-queue list is empty. Idempotent at the engine (a lane is
    /// retired once); ignored if the dispatcher already exited.
    pub fn fail_lane(&self, lane: LaneId, error: String) {
        let _ = self.tx.send(Event::LaneFailed(lane, Vec::new(), error));
    }
}

fn lane_worker(
    lane: LaneId,
    spec: crate::scheduler::LaneSpec,
    factory: ExecutorFactory,
    batch_rx: mpsc::Receiver<Batch>,
    tx: mpsc::Sender<Event>,
) {
    let mut executor = match factory(&spec) {
        Ok(e) => {
            let _ = tx.send(Event::LaneReady(lane));
            e
        }
        Err(e) => {
            let _ = tx.send(Event::LaneError(lane, format!("{e:#}")));
            return;
        }
    };
    while let Ok(batch) = batch_rx.recv() {
        match executor.execute_failable(&batch) {
            Ok(ExecOutcome::Done(reports)) => {
                if tx.send(Event::Done(lane, reports)).is_err() {
                    return;
                }
            }
            Ok(ExecOutcome::LaneLost { completed, requeue, error }) => {
                // survivable substrate loss (remote node died): deliver
                // whatever completed before the cut, hand the rest back
                // for re-routing, and shut this lane down
                if !completed.is_empty() {
                    let _ = tx.send(Event::Done(lane, completed));
                }
                let _ = tx.send(Event::LaneFailed(lane, requeue, error));
                return;
            }
            Err(e) => {
                let _ = tx.send(Event::LaneError(lane, format!("{e:#}")));
                return;
            }
        }
    }
}

/// One in-flight generation in a stepped lane's slot table.
struct StepGen {
    task: Task,
    remaining: usize,
    done_steps: usize,
    infer_wall: f64,
    ready_wall: f64,
}

/// Run a join group's shared prefill and seat its tasks in the slot
/// table. The prefill cost is split evenly across the joiners, the same
/// attribution the simulator uses.
fn join_group(
    executor: &mut dyn BatchExecutor,
    epoch: Instant,
    active: &mut Vec<StepGen>,
    batch: Batch,
) {
    let k = batch.tasks.len().max(1);
    let s = batch.max_input_len();
    let slept = executor.stepped().expect("checked at lane init").prefill(k, s);
    let ready_wall = epoch.elapsed().as_secs_f64();
    let share = slept / k as f64;
    for task in batch.tasks {
        let remaining = task.true_len.max(1);
        active.push(StepGen { task, remaining, done_steps: 0, infer_wall: share, ready_wall });
    }
}

/// Iteration-level lane loop (`--sched step`): admit join groups at step
/// boundaries, charge one decode tick per iteration over every occupied
/// slot, and release (or preempt) generations individually. Preemption
/// fires when a generation's executed steps exceed `overrun ×` its
/// predicted length, at most once per task id across the whole fleet
/// (`preempted_ids` is shared between stepped lanes, mirroring the
/// simulator's global set).
fn stepped_lane_worker(
    lane: LaneId,
    spec: crate::scheduler::LaneSpec,
    factory: ExecutorFactory,
    batch_rx: mpsc::Receiver<Batch>,
    tx: mpsc::Sender<Event>,
    overrun: f64,
    preempted_ids: Arc<Mutex<HashSet<u64>>>,
) {
    let mut executor = match factory(&spec) {
        Ok(e) => e,
        Err(e) => {
            let _ = tx.send(Event::LaneError(lane, format!("{e:#}")));
            return;
        }
    };
    if executor.stepped().is_none() {
        let _ = tx.send(Event::LaneError(
            lane,
            "lane executor does not support --sched step".into(),
        ));
        return;
    }
    let _ = tx.send(Event::LaneReady(lane));
    let epoch = Instant::now();
    let mut active: Vec<StepGen> = Vec::new();
    loop {
        // Joins land at step boundaries: block while the lane is idle,
        // otherwise take whatever the dispatcher queued since the last
        // tick.
        if active.is_empty() {
            match batch_rx.recv() {
                Ok(batch) => join_group(executor.as_mut(), epoch, &mut active, batch),
                Err(_) => return, // dispatcher gone: shut the lane down
            }
        }
        while let Ok(batch) = batch_rx.try_recv() {
            join_group(executor.as_mut(), epoch, &mut active, batch);
        }

        // One decode tick across every occupied slot.
        let n = active.len();
        let slept = executor.stepped().expect("checked at lane init").tick(n);
        let share = slept / n as f64;
        for g in &mut active {
            g.remaining -= 1;
            g.done_steps += 1;
            g.infer_wall += share;
        }
        let now_wall = epoch.elapsed().as_secs_f64();

        let mut i = 0;
        while i < active.len() {
            if active[i].remaining == 0 {
                // finished: leave individually, freeing the slot
                let g = active.swap_remove(i);
                let report = ExecReport {
                    task_ids: vec![g.task.id],
                    outputs: vec![Vec::new()],
                    infer_secs: g.infer_wall,
                    steps: g.done_steps,
                    end_offset_secs: 0.0,
                    ttft_back_secs: (now_wall - g.ready_wall).max(0.0),
                };
                if tx.send(Event::Done(lane, vec![report])).is_err() {
                    return;
                }
                continue;
            }
            let g = &active[i];
            let u = g.task.uncertainty;
            let trigger = overrun.is_finite()
                && overrun > 0.0
                && u.is_finite()
                && (g.done_steps as f64) > overrun * u.max(1.0);
            if trigger && preempted_ids.lock().unwrap().insert(g.task.id) {
                let mut g = active.swap_remove(i);
                // re-score with what the generation has revealed
                g.task.uncertainty = (g.done_steps as f64).max(u);
                g.task.true_len = g.remaining;
                if tx
                    .send(Event::Preempt(lane, Box::new(g.task), g.done_steps, g.infer_wall))
                    .is_err()
                {
                    return;
                }
                continue;
            }
            i += 1;
        }
    }
}

/// The wall-clock [`ExecutionBackend`]: injector / producer threads feed
/// arrivals, one worker thread per lane executes batches.
pub struct ThreadedBackend {
    event_rx: mpsc::Receiver<Event>,
    /// One batch channel per lane, indexed by [`LaneId`]; `None` after
    /// [`finish`](Self::finish) begins teardown.
    lane_txs: Vec<Option<mpsc::Sender<Batch>>>,
    epoch: Instant,
    /// Engine-clock dilation factor: every engine-facing time this
    /// backend reports (`now()`, arrival stamps, completion stamps,
    /// `infer_secs`) is wall-seconds-since-epoch multiplied by this, and
    /// `wait` deadlines are divided by it before sleeping. With the
    /// executor sleeping modeled durations compressed by the same
    /// factor, the engine — and the policy's time-dependent priorities —
    /// observe the *virtual* (uncompressed) timeline, which is what
    /// makes wire replays comparable 1:1 against the virtual-clock
    /// simulator (see `bench_harness::replay`). `1.0` (the live-serving
    /// default) reports plain wall seconds.
    clock_scale: f64,
    stream_closed: bool,
    /// Per-lane slot capacity: `Some(slots)` for stepped lanes
    /// (`--sched step` accelerator lanes), `None` for whole-batch lanes.
    lane_slots: Vec<Option<usize>>,
    injector: Option<thread::JoinHandle<()>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadedBackend {
    /// Spawn one worker per lane of `lanes`, wait for *every* lane to
    /// report ready (tracked per lane — one lane reporting twice cannot
    /// mask another failing), and start the epoch clock. Under
    /// `params.mode == Step` accelerator lanes get the iteration-level
    /// worker loop and expose their slot capacity through
    /// [`ExecutionBackend::lane_slots`].
    fn spawn_lanes(
        factory: ExecutorFactory,
        lanes: &LaneSet,
        params: &SchedParams,
    ) -> Result<(ThreadedBackend, mpsc::Sender<Event>)> {
        let (event_tx, event_rx) = mpsc::channel::<Event>();

        let preempted_ids = Arc::new(Mutex::new(HashSet::new()));
        let mut lane_slots = Vec::with_capacity(lanes.len());
        let mut lane_txs = Vec::with_capacity(lanes.len());
        let mut workers = Vec::with_capacity(lanes.len());
        for (i, spec) in lanes.iter().enumerate() {
            let slots = (params.mode == SchedMode::Step
                && spec.kind == LaneKind::Accelerator)
                .then(|| params.slots_for(spec.batch_size.unwrap_or(params.batch_size)));
            lane_slots.push(slots);
            let (batch_tx, batch_rx) = mpsc::channel::<Batch>();
            lane_txs.push(Some(batch_tx));
            let tx = event_tx.clone();
            let factory = factory.clone();
            let spec = spec.clone();
            if slots.is_some() {
                let overrun = params.overrun_factor;
                let seen = preempted_ids.clone();
                workers.push(thread::spawn(move || {
                    stepped_lane_worker(LaneId(i), spec, factory, batch_rx, tx, overrun, seen)
                }));
            } else {
                workers.push(thread::spawn(move || {
                    lane_worker(LaneId(i), spec, factory, batch_rx, tx)
                }));
            }
        }

        // wait for every lane to finish initialising (e.g. compiling the
        // warmup buckets) before the serving clock starts
        let mut ready = vec![false; lanes.len()];
        while ready.contains(&false) {
            match event_rx.recv_timeout(Duration::from_secs(600)) {
                Ok(Event::LaneReady(lane)) => ready[lane.index()] = true,
                Ok(Event::LaneError(lane, e)) => {
                    return Err(anyhow!(
                        "lane '{}' failed to initialise: {e}",
                        lanes.spec(lane).name
                    ))
                }
                Ok(_) => {}
                Err(e) => return Err(anyhow!("lane initialisation timed out: {e}")),
            }
        }

        let backend = ThreadedBackend {
            event_rx,
            lane_txs,
            epoch: Instant::now(),
            clock_scale: 1.0,
            stream_closed: false,
            lane_slots,
            injector: None,
            workers,
        };
        Ok((backend, event_tx))
    }

    /// Trace-replay mode: spawn the lane workers, then start replaying
    /// `tasks` (arrival gaps compressed by `time_scale`). The stream
    /// closes when the trace drains, so the trace can drive both
    /// counted and open-stream engine runs.
    ///
    /// With `inject_upfront` every arrival is queued synchronously
    /// before this constructor returns — deterministic admission for
    /// the cross-backend equivalence and drain tests.
    pub fn start(
        tasks: Vec<Task>,
        factory: ExecutorFactory,
        lanes: &LaneSet,
        params: &SchedParams,
        time_scale: f64,
        inject_upfront: bool,
    ) -> Result<ThreadedBackend> {
        Self::start_scaled(tasks, factory, lanes, params, time_scale, inject_upfront, 1.0)
    }

    /// [`start`](Self::start) with an explicit engine-clock dilation
    /// factor. With `clock_scale = time_scale` the engine observes the
    /// virtual (uncompressed) timeline while wall time runs compressed —
    /// the deterministic-replay configuration the sim-vs-wire parity
    /// harness uses ([`crate::bench_harness::replay`]); the ξ wait
    /// interval must then *not* be pre-compressed by the caller, since
    /// the engine already compares it against virtual clock readings.
    pub fn start_scaled(
        tasks: Vec<Task>,
        factory: ExecutorFactory,
        lanes: &LaneSet,
        params: &SchedParams,
        time_scale: f64,
        inject_upfront: bool,
        clock_scale: f64,
    ) -> Result<ThreadedBackend> {
        let (mut backend, event_tx) = Self::spawn_lanes(factory, lanes, params)?;
        backend.clock_scale = clock_scale.max(1e-9);
        let epoch = backend.epoch;
        let time_scale = time_scale.max(1e-9);
        if inject_upfront {
            for task in tasks {
                let arrived = epoch.elapsed().as_secs_f64();
                event_tx
                    .send(Event::Arrival(task, arrived))
                    .map_err(|_| anyhow!("event channel closed during upfront injection"))?;
            }
            let _ = event_tx.send(Event::StreamClosed);
        } else {
            let tx = event_tx.clone();
            backend.injector = Some(thread::spawn(move || {
                for task in tasks {
                    let due = task.arrival / time_scale;
                    let now = epoch.elapsed().as_secs_f64();
                    if due > now {
                        thread::sleep(Duration::from_secs_f64(due - now));
                    }
                    let arrived = epoch.elapsed().as_secs_f64();
                    if tx.send(Event::Arrival(task, arrived)).is_err() {
                        return;
                    }
                }
                let _ = tx.send(Event::StreamClosed);
            }));
        }
        drop(event_tx); // only workers + injector hold senders now
        Ok(backend)
    }

    /// Live-stream mode: spawn the lane workers and hand back an
    /// [`ArrivalHandle`] for producers (connection handlers) to feed.
    /// The stream stays open until a handle calls `close`.
    pub fn start_stream(
        factory: ExecutorFactory,
        lanes: &LaneSet,
        params: &SchedParams,
    ) -> Result<(ThreadedBackend, ArrivalHandle)> {
        let (backend, event_tx) = Self::spawn_lanes(factory, lanes, params)?;
        let handle = ArrivalHandle { tx: event_tx, epoch: backend.epoch };
        Ok((backend, handle))
    }

    /// Total wall seconds since the post-init epoch (undilated even when
    /// a `clock_scale` is set), then shut the lane workers and injector
    /// down.
    pub fn finish(mut self) -> f64 {
        let wall = self.epoch.elapsed().as_secs_f64();
        for tx in &mut self.lane_txs {
            tx.take();
        }
        if let Some(injector) = self.injector.take() {
            injector.join().ok();
        }
        for worker in self.workers.drain(..) {
            worker.join().ok();
        }
        wall
    }

    fn apply(&mut self, event: Event, step: &mut Step) -> Result<()> {
        match event {
            Event::Arrival(mut task, arrived) => {
                // rebase to the dispatcher clock so response times are
                // real (dilated to engine seconds first)
                let arrived = arrived * self.clock_scale;
                task.priority_point = arrived + (task.priority_point - task.arrival);
                task.arrival = arrived;
                step.arrivals.push(task);
            }
            Event::Done(lane, reports) => {
                let done = self.epoch.elapsed().as_secs_f64() * self.clock_scale;
                // Per-task completion times: each report is backdated by
                // its gap to the batch's *last* report, so a CPU-lane
                // worker pool's intra-batch completions land at their
                // real times (the simulator's per-task worker model)
                // instead of all at batch end. Single-report accelerator
                // batches have zero gap and stay stamped at `done`.
                let batch_wall = reports
                    .iter()
                    .map(|r| r.end_offset_secs)
                    .fold(0.0, f64::max);
                let mut completions = Vec::new();
                let mut batch_infer_secs = 0.0;
                let mut steps = 0;
                for rep in reports {
                    let ExecReport {
                        task_ids,
                        outputs,
                        infer_secs,
                        steps: rep_steps,
                        end_offset_secs,
                        ttft_back_secs,
                    } = rep;
                    // executor-reported wall seconds -> engine seconds
                    let infer_secs = infer_secs * self.clock_scale;
                    batch_infer_secs += infer_secs;
                    steps += rep_steps;
                    let at = done - (batch_wall - end_offset_secs) * self.clock_scale;
                    // first token backdated the same way completions are
                    let first_token_at = at - ttft_back_secs * self.clock_scale;
                    for (id, output) in task_ids.into_iter().zip(outputs) {
                        completions.push(TaskDone { id, at, infer_secs, first_token_at, output });
                    }
                }
                step.done.push(BatchDone { lane, completions, batch_infer_secs, steps });
            }
            Event::Preempt(lane, task, steps, infer_wall) => {
                step.preempted.push(Preempted {
                    lane,
                    steps,
                    infer_secs: infer_wall * self.clock_scale,
                    task: *task,
                });
            }
            Event::LaneReady(_) => {}
            Event::LaneError(lane, e) => {
                return Err(anyhow!("{lane} failed mid-run: {e}"));
            }
            Event::LaneFailed(lane, requeue, error) => {
                // tasks were dispatched by this engine, so their arrival
                // stamps are already on the engine clock — no rebase
                step.failed.push(LaneFailure { lane, requeue, error });
            }
            Event::StreamClosed => self.stream_closed = true,
        }
        Ok(())
    }
}

impl ExecutionBackend for ThreadedBackend {
    fn n_lanes(&self) -> usize {
        self.lane_txs.len()
    }

    fn lane_slots(&self, lane: LaneId) -> Option<usize> {
        self.lane_slots.get(lane.index()).copied().flatten()
    }

    fn now(&mut self) -> f64 {
        self.epoch.elapsed().as_secs_f64() * self.clock_scale
    }

    fn submit(&mut self, batch: Batch) -> Result<()> {
        let lane = batch.lane;
        self.lane_txs
            .get(lane.index())
            .ok_or_else(|| anyhow!("batch dispatched to unknown {lane}"))?
            .as_ref()
            .expect("backend already finished")
            .send(batch)
            .map_err(|e| anyhow!("{} died", e.0.lane))
    }

    fn wait(&mut self, deadline: Option<f64>) -> Result<Step> {
        let disconnected = || anyhow!("all lane workers exited with tasks outstanding");
        let first = match deadline {
            Some(d) => {
                // the deadline arrives in engine (possibly dilated)
                // seconds; sleep the wall-clock equivalent
                let timeout =
                    (d / self.clock_scale - self.epoch.elapsed().as_secs_f64()).max(0.0);
                match self.event_rx.recv_timeout(Duration::from_secs_f64(timeout)) {
                    Ok(event) => Some(event),
                    Err(mpsc::RecvTimeoutError::Timeout) => None,
                    Err(mpsc::RecvTimeoutError::Disconnected) => return Err(disconnected()),
                }
            }
            // No ξ-expiry pending: the next state change can only be an
            // arrival or a completion, so block for one — no busy-poll.
            None => Some(self.event_rx.recv().map_err(|_| disconnected())?),
        };

        let mut step = Step::default();
        if let Some(event) = first {
            self.apply(event, &mut step)?;
        }
        // drain everything already queued so the dispatcher acts on the
        // freshest state (and admission is atomic for pre-queued traces)
        while let Ok(event) = self.event_rx.try_recv() {
            self.apply(event, &mut step)?;
        }
        step.stream_closed = self.stream_closed;
        Ok(step)
    }
}
