//! The generic dispatcher loop and the [`ExecutionBackend`] trait it is
//! parameterised over.
//!
//! Unified semantics (both backends, by construction):
//!
//! - **Admission**: the backend delivers arrivals on its engine clock;
//!   the core pushes them into the policy and tracks their arrival times.
//! - **ξ-forcing**: a lane pop is forced once *all* `n_total` tasks have
//!   been admitted (never earlier — the historical wall-clock engine
//!   guessed "arrivals done" from queue lengths and could force while
//!   arrivals were still in flight), or once the oldest queued task has
//!   waited `params.xi` engine-seconds.
//! - **Lane gating**: at most one batch in flight per lane; a lane
//!   accepts the next batch only when the previous one has fully
//!   completed (the historical simulator let the CPU lane stack tasks
//!   onto busy workers).
//! - **Waiting**: the core computes the next ξ-expiry and hands it to
//!   the backend as an absolute-time deadline — wall-clock backends
//!   sleep until an event or that deadline instead of busy-polling.

use std::collections::HashMap;
use std::time::Instant;

use anyhow::Result;

use crate::config::SchedParams;
use crate::scheduler::{Batch, Lane, Policy, Task};
use crate::sim::results::TaskOutcome;

/// One completed batch, reported by the backend.
#[derive(Debug)]
pub struct BatchDone {
    pub lane: Lane,
    /// Per-task `(id, completion time, inference seconds)` on the
    /// engine clock. CPU-lane tasks may complete at different times
    /// within one batch (worker pool / sequential execution); the lane
    /// itself frees only when the whole batch is done.
    pub completions: Vec<(u64, f64, f64)>,
    /// Pure model-inference seconds of the whole batch (counted once,
    /// not per task).
    pub batch_infer_secs: f64,
}

/// Everything that happened since the previous wait, up to the
/// backend's (possibly advanced) `now`.
#[derive(Debug, Default)]
pub struct Step {
    /// Newly arrived tasks, arrival times already on the engine clock.
    pub arrivals: Vec<Task>,
    /// Batches that finished; their lanes are free again.
    pub done: Vec<BatchDone>,
    /// Virtual-clock backends only: no event can ever occur again (no
    /// pending arrivals, nothing in flight, no deadline). With tasks
    /// still queued this means the policy refuses to emit — a bug.
    pub exhausted: bool,
}

/// An execution environment the dispatcher core can drive: a clock, two
/// lanes, and a stream of arrivals.
pub trait ExecutionBackend {
    /// Current engine-clock time in seconds.
    fn now(&mut self) -> f64;

    /// Start executing a batch on its lane. The core guarantees at most
    /// one batch in flight per lane.
    fn submit(&mut self, batch: Batch) -> Result<()>;

    /// Block until the next event (arrival or completion) or until the
    /// absolute engine time `deadline` passes, whichever comes first.
    /// Returns every event that has occurred up to the new `now`.
    fn wait(&mut self, deadline: Option<f64>) -> Result<Step>;
}

/// Backend-agnostic outcome of one serving run.
#[derive(Debug, Default)]
pub struct EngineReport {
    pub policy: String,
    pub outcomes: Vec<TaskOutcome>,
    /// Engine-clock seconds spent inside policy push/pop (Table VII).
    pub sched_secs: f64,
    /// Pure model-inference seconds, summed over batches.
    pub infer_secs: f64,
    pub n_batches_gpu: usize,
    pub n_batches_cpu: usize,
    /// Every dispatched batch in dispatch order: `(lane, task ids)`.
    /// The cross-backend equivalence test compares these.
    pub dispatch_log: Vec<(Lane, Vec<u64>)>,
}

/// Run `policy` over `n_total` tasks delivered by `backend` until every
/// task has completed. Panics (like the historical simulator) if the
/// policy deadlocks or the loop fails to converge; backend errors (lane
/// worker death, channel loss) propagate as `Err`.
pub fn run_engine(
    backend: &mut dyn ExecutionBackend,
    policy: &mut dyn Policy,
    params: &SchedParams,
    n_total: usize,
) -> Result<EngineReport> {
    let mut report = EngineReport { policy: policy.name(), ..Default::default() };

    // arrival time of every task queued inside the policy (removed at
    // dispatch — in-flight tasks no longer age the ξ timer)
    let mut queued: HashMap<u64, f64> = HashMap::new();
    // full task records for outcome accounting (removed at completion)
    let mut meta: HashMap<u64, Task> = HashMap::new();
    let mut admitted = 0usize;
    let mut completed = 0usize;
    let mut busy = [false; Lane::ALL.len()];

    let guard_limit = 1000 + 100 * n_total;
    let mut iterations = 0usize;

    while completed < n_total {
        iterations += 1;
        assert!(
            iterations < guard_limit,
            "engine did not converge (policy {} stuck with {} queued, {} completed)",
            report.policy,
            queued.len(),
            completed
        );

        // -- dispatch idle lanes ------------------------------------------
        let now = backend.now();
        let oldest = queued.values().copied().fold(f64::INFINITY, f64::min);
        // ξ-expiry is compared as `now >= oldest + xi` — the *same*
        // float expression the wait deadline below hands the backend —
        // so a wakeup at the deadline always observes force=true. (The
        // subtraction form `now - oldest >= xi` can round down at the
        // expiry instant and livelock the loop re-arming a deadline
        // that never fires force.)
        let force = admitted == n_total || (oldest.is_finite() && now >= oldest + params.xi);
        for lane in Lane::ALL {
            if busy[lane.index()] {
                continue;
            }
            let t0 = Instant::now();
            let batch = policy.pop_batch(lane, now, force);
            report.sched_secs += t0.elapsed().as_secs_f64();
            if let Some(batch) = batch {
                busy[lane.index()] = true;
                match lane {
                    Lane::Gpu => report.n_batches_gpu += 1,
                    Lane::Cpu => report.n_batches_cpu += 1,
                }
                let ids: Vec<u64> = batch.tasks.iter().map(|t| t.id).collect();
                for id in &ids {
                    queued.remove(id);
                }
                report.dispatch_log.push((lane, ids));
                backend.submit(batch)?;
            }
        }

        // -- wait for the next event --------------------------------------
        // The only reason to wake with no event is a pending ξ-expiry on
        // an idle lane; if this round's pops already ran forced and
        // declined, only arrivals/completions can change anything, so
        // wait for those without a deadline (no busy-poll). The decision
        // keys on the same `force` the pops used — re-reading the clock
        // here could see the expiry slip into the past between the pop
        // and the wait and skip the deadline entirely, parking a
        // wall-clock backend until the next unrelated event. A deadline
        // that is already due simply makes `wait` return immediately and
        // the next iteration dispatch forced.
        let any_idle = busy.contains(&false);
        let oldest = queued.values().copied().fold(f64::INFINITY, f64::min);
        let deadline = if any_idle && !force && oldest.is_finite() {
            Some(oldest + params.xi)
        } else {
            None
        };
        let step = backend.wait(deadline)?;

        if step.exhausted {
            assert!(
                step.arrivals.is_empty() && step.done.is_empty(),
                "backend reported exhausted with undelivered events"
            );
            panic!(
                "policy {} deadlocked with {} waiting tasks",
                report.policy,
                queued.len()
            );
        }

        // -- admit arrivals ------------------------------------------------
        for task in step.arrivals {
            queued.insert(task.id, task.arrival);
            meta.insert(task.id, task.clone());
            admitted += 1;
            let t0 = Instant::now();
            policy.push(task);
            report.sched_secs += t0.elapsed().as_secs_f64();
        }

        // -- account completions -------------------------------------------
        for done in step.done {
            busy[done.lane.index()] = false;
            report.infer_secs += done.batch_infer_secs;
            for (id, completion, infer_secs) in done.completions {
                let task = meta.remove(&id).expect("unknown task completed");
                report.outcomes.push(TaskOutcome {
                    id,
                    arrival: task.arrival,
                    completion,
                    priority_point: task.priority_point,
                    uncertainty: task.uncertainty,
                    true_len: task.true_len,
                    lane: done.lane,
                    utype: task.utype,
                    malicious: task.malicious,
                    infer_secs,
                });
                completed += 1;
            }
        }
    }

    assert_eq!(
        report.outcomes.len(),
        n_total,
        "policy {} lost tasks",
        report.policy
    );
    Ok(report)
}
