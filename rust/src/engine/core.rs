//! The generic dispatcher loop and the [`ExecutionBackend`] trait it is
//! parameterised over.
//!
//! Unified semantics (all backends, by construction):
//!
//! - **Admission**: the backend delivers arrivals on its engine clock;
//!   the core pushes them into the policy and tracks their arrival times.
//! - **ξ-forcing**: a lane pop is forced once the arrival source is
//!   *known drained* — every task of a counted trace admitted, or the
//!   open stream reported closed (never earlier — the historical
//!   wall-clock engine guessed "arrivals done" from queue lengths and
//!   could force while arrivals were still in flight) — or once the
//!   oldest queued task has waited `params.xi` engine-seconds.
//! - **Lane gating**: every lane owns a slot table. A whole-batch lane
//!   (the default, and every lane in [`SchedMode::Batch`]) exposes one
//!   slot holding one batch: it accepts the next batch only when the
//!   previous one has fully completed (the historical simulator let the
//!   CPU lane stack tasks onto busy workers). A stepped lane
//!   ([`SchedMode::Step`] accelerator lanes, declared via
//!   [`ExecutionBackend::lane_slots`]) exposes K slots holding one
//!   *task* each: tasks join the lane's persistent decode loop at the
//!   next step boundary after prefill and leave individually when their
//!   generation ends, freeing their slot for the next pop.
//! - **Preemption** (stepped lanes only): a backend may eject a running
//!   generation that overran its predicted length
//!   ([`SchedParams::overrun_factor`]) at a step boundary; the core
//!   frees its slot and re-queues the re-scored task through the
//!   policy, so the existing CPU-lane admission decides where the
//!   remainder runs.
//! - **Waiting**: the core computes the next ξ-expiry and hands it to
//!   the backend as an absolute-time deadline — wall-clock backends
//!   sleep until an event or that deadline instead of busy-polling.
//!
//! The lane fleet is a runtime table: the backend declares how many
//! lanes exist ([`ExecutionBackend::n_lanes`]), the core keeps per-lane
//! `busy` flags and batch counters `Vec`-indexed by [`LaneId`], and
//! each round offers every idle lane a pop in lane order — two lanes or
//! twenty, the loop is the same.
//!
//! The loop is workload-shape agnostic: [`ArrivalSource::Counted`]
//! replays a closed trace of known size (simulation, `rtlm serve`),
//! [`ArrivalSource::Stream`] serves an open-ended request stream until
//! the backend reports it closed (the TCP front-end). With a
//! [`run_engine_stream`] completion callback attached, per-task results
//! are emitted as batches finish — that is how TCP replies flow — rather
//! than only in the final [`EngineReport`].

use std::collections::HashMap;
use std::time::Instant;

use anyhow::Result;

use crate::config::{SchedMode, SchedParams};
use crate::scheduler::{Batch, LaneId, Policy, Task, WHOLE_BATCH};
use crate::sim::results::TaskOutcome;

/// One completed task inside a [`BatchDone`].
#[derive(Debug)]
pub struct TaskDone {
    /// Id of the completed task.
    pub id: u64,
    /// Completion time on the engine clock.
    pub at: f64,
    /// Pure inference seconds attributed to this task.
    pub infer_secs: f64,
    /// Engine-clock time the task's first output token was ready: the
    /// end of its prefill (whole-batch lanes charge the whole batch's
    /// prefill; stepped lanes charge the task's own join prefill plus
    /// its first decode step). Non-finite when the backend cannot
    /// attribute one — the core then falls back to the completion time.
    pub first_token_at: f64,
    /// Generated token ids (empty on backends that produce no text,
    /// e.g. the virtual-clock simulator).
    pub output: Vec<i32>,
}

/// One completed batch, reported by the backend.
///
/// CPU-lane tasks may complete at different times within one batch
/// (worker pool / sequential execution); the lane itself frees only when
/// the whole batch is done.
#[derive(Debug)]
pub struct BatchDone {
    /// Lane the batch ran on (free again once this is processed).
    pub lane: LaneId,
    /// Per-task completions (order unspecified).
    pub completions: Vec<TaskDone>,
    /// Pure model-inference seconds of the whole batch (counted once,
    /// not per task).
    pub batch_infer_secs: f64,
    /// Decode iterations this event accounts for: the batch's
    /// max-output-length on a whole-batch accelerator lane, the summed
    /// per-task output lengths on a CPU pool, the leaving task's own
    /// executed steps on a stepped lane. Summed per lane into
    /// [`EngineReport::n_steps`] — a deterministic, timing-independent
    /// counter the step-mode parity cells exact-match on.
    pub steps: usize,
}

/// Everything that happened since the previous wait, up to the
/// backend's (possibly advanced) `now`.
#[derive(Debug, Default)]
pub struct Step {
    /// Newly arrived tasks, arrival times already on the engine clock.
    pub arrivals: Vec<Task>,
    /// Batches that finished; their lanes are free again.
    pub done: Vec<BatchDone>,
    /// Stepped lanes only: running generations the backend ejected at a
    /// step boundary for overrunning their predicted length
    /// ([`SchedParams::overrun_factor`]). Each task arrives re-scored —
    /// `uncertainty` raised to the steps it already executed,
    /// `true_len` reduced to the steps it still needs — and the core
    /// frees its slot and re-queues it through the policy, which routes
    /// it through the ordinary lane admissions (typically to the CPU
    /// lane). A backend ejects any given task at most once.
    pub preempted: Vec<Preempted>,
    /// Lanes whose executor substrate died *survivably* (a remote node
    /// lost mid-batch or evicted for missed heartbeats). The core
    /// retires each lane from routing, re-queues its in-flight tasks
    /// through ordinary lane admission (the same path `preempted`
    /// uses), and keeps serving on the surviving lanes. In-process
    /// lane failures stay fatal backend errors, not `failed` entries.
    pub failed: Vec<LaneFailure>,
    /// The arrival stream is closed: every arrival the source will ever
    /// produce has been delivered in this or an earlier step. Latched by
    /// the core; only [`ArrivalSource::Stream`] runs consult it.
    pub stream_closed: bool,
    /// Virtual-clock backends only: no event can ever occur again (no
    /// pending arrivals, nothing in flight, no deadline). With tasks
    /// still queued this means the policy refuses to emit — a bug.
    pub exhausted: bool,
}

/// One survivably-dead lane (see [`Step::failed`]).
#[derive(Debug)]
pub struct LaneFailure {
    /// The lane whose executor is permanently gone.
    pub lane: LaneId,
    /// Tasks that were in flight there and never completed; the core
    /// re-queues them through the policy. Empty when the failure was
    /// detected between batches (heartbeat eviction of an idle lane).
    pub requeue: Vec<Task>,
    /// What killed the lane (eviction log line).
    pub error: String,
}

/// A generation ejected from a stepped lane at a step boundary (see
/// [`Step::preempted`]).
#[derive(Debug)]
pub struct Preempted {
    /// Lane the task was running on; its slot frees.
    pub lane: LaneId,
    /// Decode steps it executed there before ejection (accounted into
    /// [`EngineReport::n_steps`]).
    pub steps: usize,
    /// Lane-seconds the partial generation consumed (accounted into
    /// [`EngineReport::infer_secs`] — the eventual completion on the
    /// new lane reports only the work done there).
    pub infer_secs: f64,
    /// The re-scored task the core re-queues through the policy.
    pub task: Task,
}

/// An execution environment the dispatcher core can drive: a clock, a
/// table of N lanes, and a stream of arrivals.
pub trait ExecutionBackend {
    /// How many lanes this backend executes on. Constant for the life
    /// of the backend; the core sizes its per-lane state from it.
    fn n_lanes(&self) -> usize;

    /// Current engine-clock time in seconds.
    fn now(&mut self) -> f64;

    /// Slot capacity of `lane`: `Some(k)` if the lane runs an
    /// iteration-level decode loop with `k` concurrent task slots
    /// (tasks join and leave individually, the core counts occupancy in
    /// tasks), `None` if the lane executes whole batches (at most one
    /// in flight, occupancy counted in batches). The default — every
    /// lane whole-batch — is exactly the historical engine, so batch
    /// mode is untouched by the slot-table generalisation.
    fn lane_slots(&self, _lane: LaneId) -> Option<usize> {
        None
    }

    /// Start executing a batch on its lane. The core guarantees the
    /// lane has capacity: a whole-batch lane is idle, a stepped lane
    /// has at least `batch.tasks.len()` free slots (the tasks join the
    /// lane's decode loop at its next step boundary).
    fn submit(&mut self, batch: Batch) -> Result<()>;

    /// Block until the next event (arrival or completion) or until the
    /// absolute engine time `deadline` passes, whichever comes first.
    /// Returns every event that has occurred up to the new `now`.
    fn wait(&mut self, deadline: Option<f64>) -> Result<Step>;
}

/// The workload shape a [`run_engine_stream`] run serves.
#[derive(Clone, Copy, Debug)]
pub enum ArrivalSource {
    /// A closed workload: exactly this many tasks will arrive. The run
    /// ends when all of them have completed, and "arrivals done" (the
    /// ξ-forcing trigger) is the counted admission of the last one.
    Counted(usize),
    /// An open-ended stream (live serving): arrivals keep coming until
    /// the backend reports [`Step::stream_closed`]. The run ends when
    /// the stream has closed and every admitted task has completed.
    Stream,
}

/// Per-task completion callback: called as each task finishes, with the
/// accounted outcome and the generated token ids. Runs on the
/// dispatcher thread — keep it cheap (hand replies to a channel, don't
/// do I/O that can block dispatch).
pub type OnComplete<'a> = dyn FnMut(&TaskOutcome, &[i32]) + 'a;

/// Backend-agnostic outcome of one serving run.
#[derive(Debug, Default)]
pub struct EngineReport {
    /// Name the policy reported for itself.
    pub policy: String,
    /// Per-task outcomes. Empty in streaming mode (an open stream with a
    /// completion callback attached): a long-lived server hands results
    /// to the callback instead of growing this without bound.
    pub outcomes: Vec<TaskOutcome>,
    /// Engine-clock seconds spent inside policy push/pop (Table VII).
    pub sched_secs: f64,
    /// Pure model-inference seconds, summed over batches.
    pub infer_secs: f64,
    /// Dispatched batches per lane, indexed by [`LaneId`] — the old
    /// `n_batches_gpu` / `n_batches_cpu` pair is slots 0 / 1 of the
    /// default two-lane fleet. On stepped lanes a "batch" is one join
    /// group (the tasks admitted together at a step boundary).
    pub n_batches: Vec<usize>,
    /// Decode iterations per lane (see [`BatchDone::steps`]), indexed
    /// by [`LaneId`]. Deterministic across backends — step-mode parity
    /// cells compare it exactly.
    pub n_steps: Vec<usize>,
    /// Stepped lanes only: generations ejected mid-flight for
    /// overrunning their predicted length and re-queued.
    pub n_preempted: usize,
    /// Completed tasks per lane, indexed by [`LaneId`] — the serving
    /// front-ends roll these up per node to show where a fleet's
    /// traffic actually ran (and, after a node death, how much the
    /// survivors absorbed).
    pub n_tasks: Vec<usize>,
    /// Tasks re-queued through lane admission because the lane they
    /// were in flight on died survivably (see [`Step::failed`]).
    pub n_retried: usize,
    /// Tasks dropped by overload admission control
    /// ([`SchedParams::queue_cap`]): each got a `shed` outcome (and a
    /// wire reply in serving runs) instead of executing.
    pub n_shed: usize,
    /// Lanes retired mid-run after their executor substrate died
    /// (remote node loss / heartbeat eviction).
    pub n_evicted: usize,
    /// Every dispatched batch in dispatch order: `(lane, task ids)`.
    /// The cross-backend equivalence test compares these. Empty in
    /// streaming mode, like `outcomes`.
    pub dispatch_log: Vec<(LaneId, Vec<u64>)>,
}

impl EngineReport {
    /// Per-SLO-class attainment rows over the stored outcomes (empty in
    /// streaming mode, like `outcomes` itself).
    pub fn slo_summaries(&self) -> Vec<crate::sim::results::SloSummary> {
        crate::sim::results::slo_summary(&self.outcomes)
    }
}

/// Run `policy` over `n_total` tasks delivered by `backend` until every
/// task has completed — the closed-workload wrapper around
/// [`run_engine_stream`]. Panics (like the historical simulator) if the
/// policy deadlocks or the loop fails to converge; backend errors (lane
/// worker death, channel loss) propagate as `Err`.
pub fn run_engine(
    backend: &mut dyn ExecutionBackend,
    policy: &mut dyn Policy,
    params: &SchedParams,
    n_total: usize,
) -> Result<EngineReport> {
    run_engine_stream(backend, policy, params, ArrivalSource::Counted(n_total), None)
}

/// The dispatcher core: drive `policy` over whatever `source` delivers
/// through `backend`, optionally streaming per-task completions to
/// `on_complete` as batches finish.
pub fn run_engine_stream(
    backend: &mut dyn ExecutionBackend,
    policy: &mut dyn Policy,
    params: &SchedParams,
    source: ArrivalSource,
    mut on_complete: Option<&mut OnComplete<'_>>,
) -> Result<EngineReport> {
    let n_lanes = backend.n_lanes();
    let mut report = EngineReport {
        policy: policy.name(),
        n_batches: vec![0; n_lanes],
        n_steps: vec![0; n_lanes],
        n_tasks: vec![0; n_lanes],
        ..Default::default()
    };

    // Streaming mode: an open stream with a consumer attached. Per-task
    // results go to the callback only — a server alive for millions of
    // requests must not accumulate them in the report.
    let store_results = matches!(source, ArrivalSource::Counted(_)) || on_complete.is_none();

    // arrival time of every task queued inside the policy (removed at
    // dispatch — in-flight tasks no longer age the ξ timer)
    let mut queued: HashMap<u64, f64> = HashMap::new();
    // full task records for outcome accounting (removed at completion)
    let mut meta: HashMap<u64, Task> = HashMap::new();
    let mut admitted = 0usize;
    let mut completed = 0usize;
    let mut stream_closed = false;
    // Per-lane slot tables. `None` capacity = whole-batch lane, one
    // batch in flight, occupancy counted 0/1 in batches (the historical
    // `busy` flag); `Some(k)` = stepped lane, occupancy counted in
    // tasks against k slots.
    let slot_cap: Vec<Option<usize>> =
        (0..n_lanes).map(|l| backend.lane_slots(LaneId(l))).collect();
    debug_assert!(
        params.mode == SchedMode::Step || slot_cap.iter().all(|c| c.is_none()),
        "whole-batch runs must not expose stepped lanes"
    );
    let mut occupied = vec![0usize; n_lanes];
    // Lanes retired mid-run (remote executor died): never offered pops
    // again, never counted idle for the wait decision.
    let mut dead = vec![false; n_lanes];
    let slots_free = |occupied: &[usize], dead: &[bool], lane: usize| {
        if dead[lane] {
            0
        } else {
            slot_cap[lane].unwrap_or(1).saturating_sub(occupied[lane])
        }
    };
    let mut iterations = 0usize;

    loop {
        let served = match source {
            ArrivalSource::Counted(n) => completed >= n,
            ArrivalSource::Stream => stream_closed && completed == admitted,
        };
        if served {
            break;
        }

        iterations += 1;
        // Convergence guard, sized to the work actually admitted so far:
        // a live stream grows the bound with its traffic, a closed trace
        // keeps the historical fixed bound.
        let expected = match source {
            ArrivalSource::Counted(n) => n,
            ArrivalSource::Stream => admitted,
        };
        assert!(
            iterations < 1000 + 100 * expected,
            "engine did not converge (policy {} stuck with {} queued, {} completed)",
            report.policy,
            queued.len(),
            completed
        );

        // -- dispatch idle lanes ------------------------------------------
        // "Arrivals done" is known, never guessed: the counted admission
        // of a closed trace, or the stream-closed signal of an open one.
        let arrivals_done = match source {
            ArrivalSource::Counted(n) => admitted == n,
            ArrivalSource::Stream => stream_closed,
        };
        let now = backend.now();
        // The oldest queued arrival drives both the ξ-forcing decision
        // here and the wait deadline below. One fold per round: dispatch
        // below shrinks `queued`, so the deadline site refreshes the
        // value only when something was actually dispatched.
        let mut oldest = queued.values().copied().fold(f64::INFINITY, f64::min);
        // ξ-expiry is compared as `now >= oldest + xi` — the *same*
        // float expression the wait deadline below hands the backend —
        // so a wakeup at the deadline always observes force=true. (The
        // subtraction form `now - oldest >= xi` can round down at the
        // expiry instant and livelock the loop re-arming a deadline
        // that never fires force.) A policy with per-lane ξ overrides
        // supplies the expiry itself; `None` keeps the global window.
        let force = arrivals_done
            || match policy.next_force_deadline(now) {
                Some(d) => now >= d,
                None => oldest.is_finite() && now >= oldest + params.xi,
            };
        let mut dispatched_any = false;
        for lane in (0..n_lanes).map(LaneId) {
            let free = slots_free(&occupied, &dead, lane.index());
            if free == 0 {
                continue;
            }
            let t0 = Instant::now();
            // one pop seam for both disciplines: a whole-batch lane
            // passes the WHOLE_BATCH sentinel (the policy sizes the
            // batch), a stepped lane its actual free slot count
            let free_cap = match slot_cap[lane.index()] {
                None => WHOLE_BATCH,
                Some(_) => free,
            };
            let batch = policy.pop(lane, now, force, free_cap);
            report.sched_secs += t0.elapsed().as_secs_f64();
            if let Some(batch) = batch {
                if slot_cap[lane.index()].is_some() {
                    assert!(
                        batch.tasks.len() <= free,
                        "policy overfilled lane {lane}: {} tasks into {free} slots",
                        batch.tasks.len()
                    );
                }
                occupied[lane.index()] += match slot_cap[lane.index()] {
                    None => 1,
                    Some(_) => batch.tasks.len(),
                };
                report.n_batches[lane.index()] += 1;
                for task in &batch.tasks {
                    queued.remove(&task.id);
                }
                dispatched_any = true;
                if store_results {
                    let ids: Vec<u64> = batch.tasks.iter().map(|t| t.id).collect();
                    report.dispatch_log.push((lane, ids));
                }
                backend.submit(batch)?;
            }
        }

        // -- wait for the next event --------------------------------------
        // The only reason to wake with no event is a pending ξ-expiry on
        // an idle lane; if this round's pops already ran forced and
        // declined, only arrivals/completions can change anything, so
        // wait for those without a deadline (no busy-poll). The decision
        // keys on the same `force` the pops used — re-reading the clock
        // here could see the expiry slip into the past between the pop
        // and the wait and skip the deadline entirely, parking a
        // wall-clock backend until the next unrelated event. A deadline
        // that is already due simply makes `wait` return immediately and
        // the next iteration dispatch forced.
        let any_idle = (0..n_lanes).any(|l| slots_free(&occupied, &dead, l) > 0);
        if dispatched_any {
            // dispatch removed entries from `queued`; refresh the fold
            // so the deadline keys on what is still waiting
            oldest = queued.values().copied().fold(f64::INFINITY, f64::min);
        }
        let deadline = if any_idle && !force {
            // same per-lane-override hook as the force decision above;
            // dispatch only shrinks queues, so this deadline is never
            // earlier than the one force was judged against.
            match policy.next_force_deadline(now) {
                Some(d) => Some(d),
                None => oldest.is_finite().then_some(oldest + params.xi),
            }
        } else {
            None
        };
        let step = backend.wait(deadline)?;
        stream_closed = stream_closed || step.stream_closed;

        if step.exhausted {
            assert!(
                step.arrivals.is_empty()
                    && step.done.is_empty()
                    && step.preempted.is_empty()
                    && step.failed.is_empty(),
                "backend reported exhausted with undelivered events"
            );
            // an empty stream can close and exhaust in the same step;
            // that is a served run, not a deadlock
            if matches!(source, ArrivalSource::Stream) && stream_closed && completed == admitted {
                break;
            }
            panic!(
                "policy {} deadlocked with {} waiting tasks",
                report.policy,
                queued.len()
            );
        }

        // -- admit arrivals ------------------------------------------------
        for task in step.arrivals {
            queued.insert(task.id, task.arrival);
            meta.insert(task.id, task.clone());
            admitted += 1;
            let t0 = Instant::now();
            policy.push(task);
            report.sched_secs += t0.elapsed().as_secs_f64();
        }

        // -- re-queue preempted generations --------------------------------
        // The slot frees immediately; the re-scored remainder goes back
        // through policy.push, whose lane routing (the ordinary CPU-lane
        // admission) decides where it finishes. `meta` keeps the
        // original record, so the final outcome reports the task's true
        // arrival/uncertainty/length, not the re-scored stub.
        for p in step.preempted {
            let lane = p.lane.index();
            assert!(slot_cap[lane].is_some(), "preemption on a whole-batch lane");
            occupied[lane] = occupied[lane].saturating_sub(1);
            report.n_steps[lane] += p.steps;
            report.infer_secs += p.infer_secs;
            report.n_preempted += 1;
            queued.insert(p.task.id, p.task.arrival);
            let t0 = Instant::now();
            policy.push(p.task);
            report.sched_secs += t0.elapsed().as_secs_f64();
        }

        // -- account completions -------------------------------------------
        for done in step.done {
            let lane = done.lane.index();
            occupied[lane] = occupied[lane].saturating_sub(match slot_cap[lane] {
                None => 1,
                Some(_) => done.completions.len(),
            });
            report.infer_secs += done.batch_infer_secs;
            report.n_steps[lane] += done.steps;
            report.n_tasks[lane] += done.completions.len();
            for t in done.completions {
                let task = meta.remove(&t.id).expect("unknown task completed");
                let outcome = TaskOutcome {
                    id: t.id,
                    arrival: task.arrival,
                    completion: t.at,
                    first_token: if t.first_token_at.is_finite() { t.first_token_at } else { t.at },
                    priority_point: task.priority_point,
                    uncertainty: task.uncertainty,
                    true_len: task.true_len,
                    lane: done.lane,
                    utype: task.utype,
                    malicious: task.malicious,
                    infer_secs: t.infer_secs,
                    shed: false,
                    slo: task.slo,
                };
                if let Some(cb) = on_complete.as_mut() {
                    cb(&outcome, &t.output);
                }
                if store_results {
                    report.outcomes.push(outcome);
                }
                completed += 1;
            }
        }

        // -- retire dead lanes, re-queue their in-flight work --------------
        // Processed after completions: a task that finished in the same
        // step (its reply raced the node's death) keeps its completion
        // and must not be retried — the `meta` guard below sees it gone.
        // The monitor thread and the lane worker can both report the
        // same death; the `dead` latch makes the second report a no-op
        // and the per-task guards make duplicate re-queues impossible.
        for f in step.failed {
            let lane = f.lane.index();
            if !dead[lane] {
                dead[lane] = true;
                occupied[lane] = 0;
                report.n_evicted += 1;
                eprintln!(
                    "[engine] lane {} lost ({}); re-queueing {} in-flight task(s)",
                    f.lane,
                    f.error,
                    f.requeue.len()
                );
                // Retire from routing. A policy that cannot re-route
                // (single-queue baselines) errors here; the serving
                // front-end shuts down and every pending request gets
                // an explicit error reply instead of silence.
                policy.retire_lane(f.lane).map_err(|e| {
                    anyhow::anyhow!(
                        "lane {} died ({}) and cannot be rerouted: {e}",
                        f.lane,
                        f.error
                    )
                })?;
            }
            for task in f.requeue {
                if !meta.contains_key(&task.id) || queued.contains_key(&task.id) {
                    continue; // completed already, or a duplicate report
                }
                report.n_retried += 1;
                queued.insert(task.id, task.arrival);
                let t0 = Instant::now();
                policy.push(task);
                report.sched_secs += t0.elapsed().as_secs_f64();
            }
        }

        // -- account shed tasks --------------------------------------------
        // Overload admission control (queue_cap > 0) sheds inside
        // policy.push; every push site above has run, so one drain per
        // round sees them all. A shed task completes immediately with a
        // flagged outcome — serving front-ends reply `{"error":"shed"}`
        // from it, so every submitted id still gets exactly one reply —
        // and counts toward termination like any completion.
        for (lane, task) in policy.take_shed() {
            queued.remove(&task.id);
            meta.remove(&task.id);
            report.n_shed += 1;
            let outcome = TaskOutcome {
                id: task.id,
                arrival: task.arrival,
                completion: task.arrival, // dropped at admission: zero service
                first_token: task.arrival,
                priority_point: task.priority_point,
                uncertainty: task.uncertainty,
                true_len: task.true_len,
                lane,
                utype: task.utype,
                malicious: task.malicious,
                infer_secs: 0.0,
                shed: true,
                slo: task.slo,
            };
            if let Some(cb) = on_complete.as_mut() {
                cb(&outcome, &[]);
            }
            if store_results {
                report.outcomes.push(outcome);
            }
            completed += 1;
        }
    }

    if let ArrivalSource::Counted(n) = source {
        assert_eq!(completed, n, "policy {} lost tasks", report.policy);
    }
    Ok(report)
}
