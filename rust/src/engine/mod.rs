//! The shared dispatcher core: one scheduling loop, many execution
//! backends, any workload shape.
//!
//! Historically the repo carried hand-maintained copies of the dispatch
//! loop — a virtual-clock one in `sim::engine`, a wall-clock one in
//! `server::engine`, and a third inline-execution one in `server::tcp` —
//! which drifted apart on ξ-forcing, arrival draining and lane gating.
//! This module is the single source of truth: arrival admission,
//! ξ-forced dispatch, lane gating (one batch in flight per lane) and
//! outcome accounting live exactly once in [`core::run_engine_stream`],
//! parameterised over an [`ExecutionBackend`] and an [`ArrivalSource`]:
//!
//! - [`SimBackend`] — a virtual clock over the calibrated
//!   [`crate::sim::LatencyModel`]; `sim::run_sim` is a thin wrapper.
//! - [`ThreadedBackend`] — wall clock, one worker thread per lane
//!   running any [`crate::executor::BatchExecutor`] (real PJRT,
//!   modeled-latency, or instant). Arrivals come from an injector
//!   thread replaying a trace (`server::serve_from_root`) or from
//!   [`ArrivalHandle`]s held by live producers (`server::tcp` feeds one
//!   per connection, so the TCP front-end is just another way to drive
//!   the same loop).
//!
//! [`ArrivalSource::Counted`] ends a run after a known task count
//! (closed traces); [`ArrivalSource::Stream`] serves until the backend
//! reports the stream closed (live serving). A [`core::OnComplete`]
//! callback streams per-task results out as batches finish — TCP
//! replies, progress meters — instead of waiting for the final report.
//!
//! Because all backends drive the *same* loop, the cross-backend
//! property test in `rust/tests/engine_core.rs` can assert that the same
//! trace + policy dispatches identical batch sequences in simulation and
//! on the wire — and that counted and open-stream runs agree.

pub mod core;
pub mod sim_backend;
pub mod threaded;

pub use self::core::{run_engine, run_engine_stream, ArrivalSource, BatchDone, EngineReport};
pub use self::core::{ExecutionBackend, LaneFailure, OnComplete, Preempted, Step, TaskDone};
pub use sim_backend::{resolve_lanes, SimBackend, SimLane};
pub use threaded::{ArrivalHandle, ThreadedBackend};
