//! The shared dispatcher core: one scheduling loop, many execution
//! backends.
//!
//! Historically the repo carried two hand-maintained copies of the
//! dispatch loop — a virtual-clock one in `sim::engine` and a wall-clock
//! one in `server::engine` — which drifted apart on ξ-forcing, arrival
//! draining and lane gating. This module is the single source of truth:
//! arrival admission, ξ-forced dispatch, lane gating (one batch in
//! flight per lane) and outcome accounting live exactly once in
//! [`core::run_engine`], parameterised over an [`ExecutionBackend`]:
//!
//! - [`SimBackend`] — a virtual clock over the calibrated
//!   [`crate::sim::LatencyModel`]; `sim::run_sim` is a thin wrapper.
//! - [`ThreadedBackend`] — wall clock, an injector thread replaying the
//!   arrival trace and one worker thread per lane running any
//!   [`crate::executor::BatchExecutor`] (real PJRT, modeled-latency, or
//!   instant); `server::serve_from_root` is a thin wrapper.
//!
//! Because both backends drive the *same* loop, the cross-backend
//! property test in `rust/tests/engine_core.rs` can assert that the same
//! trace + policy dispatches identical batch sequences in simulation and
//! on the wire.

pub mod core;
pub mod sim_backend;
pub mod threaded;

pub use self::core::{run_engine, BatchDone, EngineReport, ExecutionBackend, Step};
pub use sim_backend::SimBackend;
pub use threaded::ThreadedBackend;
