//! Virtual-clock execution backend over the calibrated latency model.
//!
//! Time advances event by event: the next arrival in the trace, the
//! completion of an in-flight batch, or the dispatcher's ξ-expiry
//! deadline — whichever is earliest. Batch durations come from
//! [`LatencyModel`], per lane: each [`SimLane`] resolves its
//! [`LaneSpec`]'s model variant and device kind, so one backend
//! simulates a heterogeneous fleet (several accelerator variants plus
//! CPU quarantine pools). A `run_engine` drive of this backend is
//! exactly the discrete-event simulation the paper-scale experiments
//! use.
//!
//! [`LaneSpec`]: crate::scheduler::LaneSpec

use std::collections::{BTreeMap, HashSet};

use anyhow::{anyhow, Result};

use crate::config::{DeviceProfile, ModelEntry, SchedMode, SchedParams};
use crate::scheduler::{Batch, LaneId, LaneKind, LaneSet, Task};
use crate::sim::latency::LatencyModel;

use super::core::{BatchDone, ExecutionBackend, Preempted, Step, TaskDone};

/// One lane's resolved simulation parameters: which latency curves it
/// draws from and how it executes a batch.
#[derive(Clone, Debug)]
pub struct SimLane {
    /// Device kind: fused batches vs intra-batch worker pool.
    pub kind: LaneKind,
    /// The model variant whose latency curves this lane draws from.
    pub model: ModelEntry,
    /// Intra-batch workers ([`LaneKind::Cpu`] lanes only).
    pub workers: usize,
    /// Per-lane batch-size override (`None` uses
    /// `SchedParams::batch_size`); sizes the step-mode slot table.
    pub batch_size: Option<usize>,
}

/// Resolve a [`LaneSet`] against a model table, latency curves, and
/// device profile into per-lane simulation parameters. `models` maps
/// manifest model names to entries; every lane's variant must be
/// present in both the table and the latency curves — a misnamed
/// variant is an error here, not a silently-wrong simulation.
pub fn resolve_lanes(
    lanes: &LaneSet,
    models: &BTreeMap<String, ModelEntry>,
    lat: &LatencyModel,
    dev: &DeviceProfile,
) -> Result<Vec<SimLane>> {
    lanes
        .iter()
        .map(|spec| {
            if spec.kind == LaneKind::Remote {
                anyhow::bail!(
                    "lane '{}': remote lanes live in other processes and cannot be simulated \
                     (use rtlm route)",
                    spec.name
                );
            }
            let model = models
                .get(&spec.model)
                .ok_or_else(|| anyhow!("lane '{}': unknown model '{}'", spec.name, spec.model))?
                .clone();
            lat.require_model(&model.name)
                .map_err(|e| anyhow!("lane '{}': {e}", spec.name))?;
            Ok(SimLane {
                kind: spec.kind,
                model,
                workers: spec.workers.unwrap_or(dev.cpu_workers).max(1),
                batch_size: spec.batch_size,
            })
        })
        .collect()
}

/// An in-flight batch: frees its lane at `lane_free`, with per-task
/// completion times possibly earlier (CPU worker pool).
struct InFlight {
    lane_free: f64,
    done: BatchDone,
}

/// One generation inside a stepped lane's decode loop.
struct StepSlot {
    task: Task,
    /// Engine time its join-group prefill completes (first token; the
    /// generation participates in ticks from here on).
    ready_at: f64,
    /// Decode steps still to execute.
    remaining: usize,
    /// Decode steps executed on this lane so far.
    done_steps: usize,
    /// Lane-seconds attributed to this task (prefill + tick shares).
    infer_secs: f64,
    /// Participating in the tick currently in progress?
    in_tick: bool,
}

/// A stepped accelerator lane: a slot table plus the persistent decode
/// loop's state. Each *tick* advances every ready generation by one
/// decode step and costs `decode_step_dev(model, n_participants)` —
/// occupancy prices the tick, co-batched tasks do not wait for each
/// other's completion.
struct StepLane {
    slots: usize,
    active: Vec<StepSlot>,
    /// End of the tick in progress (`None` = loop parked, waiting for a
    /// join's prefill to complete).
    tick_done_at: Option<f64>,
}

/// The virtual-clock [`ExecutionBackend`] over a [`LatencyModel`].
pub struct SimBackend<'a> {
    /// Remaining arrivals, sorted ascending by arrival time.
    trace: std::vec::IntoIter<Task>,
    /// The next arrival, held back until the clock reaches it.
    next_arrival: Option<Task>,
    now: f64,
    lanes: Vec<SimLane>,
    in_flight: Vec<Option<InFlight>>,
    /// `Some` for stepped lanes ([`SchedMode::Step`] accelerator
    /// lanes); whole-batch lanes stay on `in_flight`.
    stepped: Vec<Option<StepLane>>,
    /// Overrun factor for mid-flight preemption (non-finite disables).
    overrun: f64,
    /// Tasks already preempted once — never ejected again.
    preempted_ids: HashSet<u64>,
    lat: &'a LatencyModel,
    dev: &'a DeviceProfile,
}

impl<'a> SimBackend<'a> {
    /// `tasks` must be sorted ascending by arrival time. `lanes` come
    /// from [`resolve_lanes`]. In [`SchedMode::Step`] every accelerator
    /// lane becomes a stepped lane with
    /// [`SchedParams::slots_for`]\(lane batch size) decode slots; CPU
    /// pools keep whole-batch semantics in both modes.
    pub fn new(
        tasks: Vec<Task>,
        lat: &'a LatencyModel,
        lanes: Vec<SimLane>,
        dev: &'a DeviceProfile,
        params: &SchedParams,
    ) -> SimBackend<'a> {
        assert!(!lanes.is_empty(), "a sim backend needs at least one lane");
        let mut trace = tasks.into_iter();
        let next_arrival = trace.next();
        let in_flight = (0..lanes.len()).map(|_| None).collect();
        let stepped = lanes
            .iter()
            .map(|lane| {
                if params.mode == SchedMode::Step && lane.kind == LaneKind::Accelerator {
                    Some(StepLane {
                        slots: params.slots_for(lane.batch_size.unwrap_or(params.batch_size)),
                        active: Vec::new(),
                        tick_done_at: None,
                    })
                } else {
                    None
                }
            })
            .collect();
        SimBackend {
            trace,
            next_arrival,
            now: 0.0,
            lanes,
            in_flight,
            stepped,
            overrun: params.overrun_factor,
            preempted_ids: HashSet::new(),
            lat,
            dev,
        }
    }

    /// The historical two-lane configuration: accelerator + CPU
    /// quarantine pool (`dev.cpu_workers` intra-batch workers), both
    /// serving `model`. Reproduces the pre-lane-table simulator exactly.
    pub fn two_lane(
        tasks: Vec<Task>,
        lat: &'a LatencyModel,
        model: &ModelEntry,
        dev: &'a DeviceProfile,
        params: &SchedParams,
    ) -> SimBackend<'a> {
        let lanes = vec![
            SimLane {
                kind: LaneKind::Accelerator,
                model: model.clone(),
                workers: 1,
                batch_size: None,
            },
            SimLane {
                kind: LaneKind::Cpu,
                model: model.clone(),
                workers: dev.cpu_workers.max(1),
                batch_size: None,
            },
        ];
        SimBackend::new(tasks, lat, lanes, dev, params)
    }

    /// Earliest future event on the backend's own timeline.
    fn next_event(&self) -> f64 {
        let mut next = f64::INFINITY;
        if let Some(t) = &self.next_arrival {
            next = next.min(t.arrival);
        }
        for slot in self.in_flight.iter().flatten() {
            next = next.min(slot.lane_free);
        }
        for sl in self.stepped.iter().flatten() {
            match sl.tick_done_at {
                Some(end) => next = next.min(end),
                // parked: wake when the earliest join prefill completes
                None => {
                    for s in &sl.active {
                        next = next.min(s.ready_at);
                    }
                }
            }
        }
        next
    }

    /// Drive every stepped lane's decode loop up to `self.now`:
    /// complete due ticks (advancing participants one step, emitting
    /// leaves and overrun preemptions), then start the next tick over
    /// every ready generation. Loops until quiescent so zero-cost test
    /// latency models cannot wedge a tick chain at one instant.
    fn pump_stepped(&mut self, step: &mut Step) {
        loop {
            let mut progressed = false;
            for idx in 0..self.lanes.len() {
                let model = self.lanes[idx].model.name.clone();
                let Some(sl) = self.stepped[idx].as_mut() else { continue };
                // -- complete a due tick --------------------------------
                if sl.tick_done_at.is_some_and(|end| end <= self.now) {
                    let end = sl.tick_done_at.take().unwrap();
                    progressed = true;
                    let mut i = 0;
                    while i < sl.active.len() {
                        if !sl.active[i].in_tick {
                            i += 1;
                            continue;
                        }
                        let s = &mut sl.active[i];
                        s.in_tick = false;
                        s.remaining -= 1;
                        s.done_steps += 1;
                        if s.remaining == 0 {
                            let s = sl.active.swap_remove(i);
                            step.done.push(BatchDone {
                                lane: LaneId(idx),
                                completions: vec![TaskDone {
                                    id: s.task.id,
                                    at: end,
                                    infer_secs: s.infer_secs,
                                    first_token_at: s.ready_at,
                                    output: Vec::new(),
                                }],
                                batch_infer_secs: s.infer_secs,
                                steps: s.done_steps,
                            });
                            continue;
                        }
                        // overrun → eject at the step boundary, at most
                        // once per task (count-based: deterministic
                        // across the virtual-clock and wire backends)
                        let over = self.overrun.is_finite()
                            && self.overrun > 0.0
                            && s.task.uncertainty.is_finite()
                            && (s.done_steps as f64)
                                > self.overrun * s.task.uncertainty.max(1.0)
                            && !self.preempted_ids.contains(&s.task.id);
                        if over {
                            let s = sl.active.swap_remove(i);
                            self.preempted_ids.insert(s.task.id);
                            let mut task = s.task;
                            // re-score: it has already generated
                            // done_steps tokens and is still going
                            task.uncertainty = (s.done_steps as f64).max(task.uncertainty);
                            task.true_len = s.remaining;
                            step.preempted.push(Preempted {
                                lane: LaneId(idx),
                                steps: s.done_steps,
                                infer_secs: s.infer_secs,
                                task,
                            });
                            continue;
                        }
                        i += 1;
                    }
                }
                // -- start the next tick over ready generations ---------
                if sl.tick_done_at.is_none() {
                    let n = sl.active.iter().filter(|s| s.ready_at <= self.now).count();
                    if n > 0 {
                        let dur =
                            self.dev.gpu_speed * self.lat.decode_step_dev(&model, n, self.dev);
                        let share = dur / n as f64;
                        for s in sl.active.iter_mut().filter(|s| s.ready_at <= self.now) {
                            s.in_tick = true;
                            s.infer_secs += share;
                        }
                        sl.tick_done_at = Some(self.now + dur);
                        progressed = true;
                    }
                }
            }
            if !progressed {
                break;
            }
        }
    }
}

impl ExecutionBackend for SimBackend<'_> {
    fn n_lanes(&self) -> usize {
        self.lanes.len()
    }

    fn now(&mut self) -> f64 {
        self.now
    }

    fn lane_slots(&self, lane: LaneId) -> Option<usize> {
        self.stepped[lane.index()].as_ref().map(|sl| sl.slots)
    }

    fn submit(&mut self, batch: Batch) -> Result<()> {
        let idx = batch.lane.index();
        assert!(idx < self.lanes.len(), "batch dispatched to unknown {}", batch.lane);
        if let Some(sl) = self.stepped[idx].as_mut() {
            // join group: charge one shared prefill now; the tasks
            // enter the decode loop at its end (their first token)
            let k = batch.tasks.len();
            assert!(
                sl.active.len() + k <= sl.slots,
                "{} overfilled: {k} joins into {} free slots",
                batch.lane,
                sl.slots - sl.active.len().min(sl.slots),
            );
            let model = &self.lanes[idx].model.name;
            let prefill = self.dev.dispatch_overhead
                + self.dev.gpu_speed
                    * self.lat.prefill_secs_dev(model, k, batch.max_input_len(), self.dev);
            let ready_at = self.now + prefill;
            let share = prefill / k.max(1) as f64;
            let sl = self.stepped[idx].as_mut().unwrap();
            for task in batch.tasks {
                let remaining = task.true_len.max(1);
                sl.active.push(StepSlot {
                    task,
                    ready_at,
                    remaining,
                    done_steps: 0,
                    infer_secs: share,
                    in_tick: false,
                });
            }
            return Ok(());
        }
        assert!(self.in_flight[idx].is_none(), "{} already busy", batch.lane);
        let lane = &self.lanes[idx];
        let in_flight = match lane.kind {
            LaneKind::Accelerator => {
                // one fused batch: every task completes when the batch does
                let dur = self.lat.gpu_batch_secs(&lane.model, &batch, self.dev);
                let done_at = self.now + dur;
                // the fused batch emits its first tokens at prefill end
                let first_token_at = self.now
                    + self.dev.dispatch_overhead
                    + self.dev.gpu_speed
                        * self.lat.prefill_secs_dev(
                            &lane.model.name,
                            batch.tasks.len(),
                            batch.max_input_len(),
                            self.dev,
                        );
                InFlight {
                    lane_free: done_at,
                    done: BatchDone {
                        lane: batch.lane,
                        completions: batch
                            .tasks
                            .iter()
                            .map(|t| TaskDone {
                                id: t.id,
                                at: done_at,
                                infer_secs: dur,
                                first_token_at,
                                output: Vec::new(),
                            })
                            .collect(),
                        batch_infer_secs: dur,
                        steps: batch.max_true_len(),
                    },
                }
            }
            LaneKind::Cpu => {
                // worker pool *within* the batch: tasks run batch-1 on
                // the lane's workers, earliest-free first; the lane
                // frees when the whole batch is done (one batch in
                // flight — same gate as the wire path).
                let mut workers = vec![self.now; lane.workers.max(1)];
                let mut completions = Vec::with_capacity(batch.tasks.len());
                let mut infer = 0.0;
                let mut steps = 0usize;
                for task in &batch.tasks {
                    let w = (0..workers.len())
                        .min_by(|&a, &b| workers[a].total_cmp(&workers[b]))
                        .unwrap();
                    let dur = self.lat.cpu_task_secs(
                        &lane.model,
                        task.true_len,
                        task.input_len,
                        self.dev,
                    );
                    // first token once the offload transfer + the
                    // task's own (slowed) prefill are done
                    let first_token_at = workers[w]
                        + self.dev.offload_overhead
                        + self.dev.cpu_speed
                            * crate::sim::latency::CPU_LANE_SLOWDOWN
                            * self.lat.prefill_secs(&lane.model.name, 1, task.input_len.max(1));
                    workers[w] += dur;
                    completions.push(TaskDone {
                        id: task.id,
                        at: workers[w],
                        infer_secs: dur,
                        first_token_at,
                        output: Vec::new(),
                    });
                    infer += dur;
                    steps += task.true_len;
                }
                let lane_free = workers.iter().copied().fold(self.now, f64::max);
                InFlight {
                    lane_free,
                    done: BatchDone {
                        lane: batch.lane,
                        completions,
                        batch_infer_secs: infer,
                        steps,
                    },
                }
            }
            // resolve_lanes rejects remote lanes before a backend exists
            LaneKind::Remote => unreachable!("remote lanes cannot be simulated"),
        };
        self.in_flight[idx] = Some(in_flight);
        Ok(())
    }

    fn wait(&mut self, deadline: Option<f64>) -> Result<Step> {
        let next = self.next_event();
        let target = next.min(deadline.unwrap_or(f64::INFINITY));
        if target.is_infinite() {
            return Ok(Step {
                exhausted: true,
                stream_closed: self.next_arrival.is_none(),
                ..Default::default()
            });
        }
        self.now = self.now.max(target);

        let mut step = Step::default();
        // deliver every arrival due by the new clock
        while self
            .next_arrival
            .as_ref()
            .is_some_and(|t| t.arrival <= self.now)
        {
            step.arrivals.push(self.next_arrival.take().unwrap());
            self.next_arrival = self.trace.next();
        }
        // deliver every batch whose lane has freed by the new clock
        for slot in &mut self.in_flight {
            if slot.as_ref().is_some_and(|f| f.lane_free <= self.now) {
                step.done.push(slot.take().unwrap().done);
            }
        }
        // advance stepped decode loops: complete due ticks (leaves,
        // preemptions) and start the next ones
        self.pump_stepped(&mut step);
        // a finite trace is an "open stream" that closes with its last
        // arrival — open-stream runs over the simulator terminate
        step.stream_closed = self.next_arrival.is_none();
        Ok(step)
    }
}
