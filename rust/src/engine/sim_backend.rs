//! Virtual-clock execution backend over the calibrated latency model.
//!
//! Time advances event by event: the next arrival in the trace, the
//! completion of an in-flight batch, or the dispatcher's ξ-expiry
//! deadline — whichever is earliest. Batch durations come from
//! [`LatencyModel`], per lane: each [`SimLane`] resolves its
//! [`LaneSpec`]'s model variant and device kind, so one backend
//! simulates a heterogeneous fleet (several accelerator variants plus
//! CPU quarantine pools). A `run_engine` drive of this backend is
//! exactly the discrete-event simulation the paper-scale experiments
//! use.
//!
//! [`LaneSpec`]: crate::scheduler::LaneSpec

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::config::{DeviceProfile, ModelEntry};
use crate::scheduler::{Batch, LaneKind, LaneSet, Task};
use crate::sim::latency::LatencyModel;

use super::core::{BatchDone, ExecutionBackend, Step, TaskDone};

/// One lane's resolved simulation parameters: which latency curves it
/// draws from and how it executes a batch.
#[derive(Clone, Debug)]
pub struct SimLane {
    /// Device kind: fused batches vs intra-batch worker pool.
    pub kind: LaneKind,
    /// The model variant whose latency curves this lane draws from.
    pub model: ModelEntry,
    /// Intra-batch workers ([`LaneKind::Cpu`] lanes only).
    pub workers: usize,
}

/// Resolve a [`LaneSet`] against a model table and device profile into
/// per-lane simulation parameters. `models` maps manifest model names
/// to entries; every lane's variant must be present.
pub fn resolve_lanes(
    lanes: &LaneSet,
    models: &BTreeMap<String, ModelEntry>,
    dev: &DeviceProfile,
) -> Result<Vec<SimLane>> {
    lanes
        .iter()
        .map(|spec| {
            let model = models
                .get(&spec.model)
                .ok_or_else(|| anyhow!("lane '{}': unknown model '{}'", spec.name, spec.model))?
                .clone();
            Ok(SimLane {
                kind: spec.kind,
                model,
                workers: spec.workers.unwrap_or(dev.cpu_workers).max(1),
            })
        })
        .collect()
}

/// An in-flight batch: frees its lane at `lane_free`, with per-task
/// completion times possibly earlier (CPU worker pool).
struct InFlight {
    lane_free: f64,
    done: BatchDone,
}

/// The virtual-clock [`ExecutionBackend`] over a [`LatencyModel`].
pub struct SimBackend<'a> {
    /// Remaining arrivals, sorted ascending by arrival time.
    trace: std::vec::IntoIter<Task>,
    /// The next arrival, held back until the clock reaches it.
    next_arrival: Option<Task>,
    now: f64,
    lanes: Vec<SimLane>,
    in_flight: Vec<Option<InFlight>>,
    lat: &'a LatencyModel,
    dev: &'a DeviceProfile,
}

impl<'a> SimBackend<'a> {
    /// `tasks` must be sorted ascending by arrival time. `lanes` come
    /// from [`resolve_lanes`].
    pub fn new(
        tasks: Vec<Task>,
        lat: &'a LatencyModel,
        lanes: Vec<SimLane>,
        dev: &'a DeviceProfile,
    ) -> SimBackend<'a> {
        assert!(!lanes.is_empty(), "a sim backend needs at least one lane");
        let mut trace = tasks.into_iter();
        let next_arrival = trace.next();
        let in_flight = (0..lanes.len()).map(|_| None).collect();
        SimBackend { trace, next_arrival, now: 0.0, lanes, in_flight, lat, dev }
    }

    /// The historical two-lane configuration: accelerator + CPU
    /// quarantine pool (`dev.cpu_workers` intra-batch workers), both
    /// serving `model`. Reproduces the pre-lane-table simulator exactly.
    pub fn two_lane(
        tasks: Vec<Task>,
        lat: &'a LatencyModel,
        model: &ModelEntry,
        dev: &'a DeviceProfile,
    ) -> SimBackend<'a> {
        let lanes = vec![
            SimLane { kind: LaneKind::Accelerator, model: model.clone(), workers: 1 },
            SimLane { kind: LaneKind::Cpu, model: model.clone(), workers: dev.cpu_workers.max(1) },
        ];
        SimBackend::new(tasks, lat, lanes, dev)
    }

    /// Earliest future event on the backend's own timeline.
    fn next_event(&self) -> f64 {
        let mut next = f64::INFINITY;
        if let Some(t) = &self.next_arrival {
            next = next.min(t.arrival);
        }
        for slot in self.in_flight.iter().flatten() {
            next = next.min(slot.lane_free);
        }
        next
    }
}

impl ExecutionBackend for SimBackend<'_> {
    fn n_lanes(&self) -> usize {
        self.lanes.len()
    }

    fn now(&mut self) -> f64 {
        self.now
    }

    fn submit(&mut self, batch: Batch) -> Result<()> {
        let idx = batch.lane.index();
        assert!(idx < self.lanes.len(), "batch dispatched to unknown {}", batch.lane);
        assert!(self.in_flight[idx].is_none(), "{} already busy", batch.lane);
        let lane = &self.lanes[idx];
        let in_flight = match lane.kind {
            LaneKind::Accelerator => {
                // one fused batch: every task completes when the batch does
                let dur = self.lat.gpu_batch_secs(&lane.model, &batch, self.dev);
                let done_at = self.now + dur;
                InFlight {
                    lane_free: done_at,
                    done: BatchDone {
                        lane: batch.lane,
                        completions: batch
                            .tasks
                            .iter()
                            .map(|t| TaskDone {
                                id: t.id,
                                at: done_at,
                                infer_secs: dur,
                                output: Vec::new(),
                            })
                            .collect(),
                        batch_infer_secs: dur,
                    },
                }
            }
            LaneKind::Cpu => {
                // worker pool *within* the batch: tasks run batch-1 on
                // the lane's workers, earliest-free first; the lane
                // frees when the whole batch is done (one batch in
                // flight — same gate as the wire path).
                let mut workers = vec![self.now; lane.workers.max(1)];
                let mut completions = Vec::with_capacity(batch.tasks.len());
                let mut infer = 0.0;
                for task in &batch.tasks {
                    let w = (0..workers.len())
                        .min_by(|&a, &b| workers[a].total_cmp(&workers[b]))
                        .unwrap();
                    let dur = self.lat.cpu_task_secs(
                        &lane.model,
                        task.true_len,
                        task.input_len,
                        self.dev,
                    );
                    workers[w] += dur;
                    completions.push(TaskDone {
                        id: task.id,
                        at: workers[w],
                        infer_secs: dur,
                        output: Vec::new(),
                    });
                    infer += dur;
                }
                let lane_free = workers.iter().copied().fold(self.now, f64::max);
                InFlight {
                    lane_free,
                    done: BatchDone {
                        lane: batch.lane,
                        completions,
                        batch_infer_secs: infer,
                    },
                }
            }
        };
        self.in_flight[idx] = Some(in_flight);
        Ok(())
    }

    fn wait(&mut self, deadline: Option<f64>) -> Result<Step> {
        let next = self.next_event();
        let target = next.min(deadline.unwrap_or(f64::INFINITY));
        if target.is_infinite() {
            return Ok(Step {
                exhausted: true,
                stream_closed: self.next_arrival.is_none(),
                ..Default::default()
            });
        }
        self.now = self.now.max(target);

        let mut step = Step::default();
        // deliver every arrival due by the new clock
        while self
            .next_arrival
            .as_ref()
            .is_some_and(|t| t.arrival <= self.now)
        {
            step.arrivals.push(self.next_arrival.take().unwrap());
            self.next_arrival = self.trace.next();
        }
        // deliver every batch whose lane has freed by the new clock
        for slot in &mut self.in_flight {
            if slot.as_ref().is_some_and(|f| f.lane_free <= self.now) {
                step.done.push(slot.take().unwrap().done);
            }
        }
        // a finite trace is an "open stream" that closes with its last
        // arrival — open-stream runs over the simulator terminate
        step.stream_closed = self.next_arrival.is_none();
        Ok(step)
    }
}
