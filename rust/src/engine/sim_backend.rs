//! Virtual-clock execution backend over the calibrated latency model.
//!
//! Time advances event by event: the next arrival in the trace, the
//! completion of an in-flight batch, or the dispatcher's ξ-expiry
//! deadline — whichever is earliest. Batch durations come from
//! [`LatencyModel`], so a `run_engine` drive of this backend is exactly
//! the discrete-event simulation the paper-scale experiments use.

use anyhow::Result;

use crate::config::{DeviceProfile, ModelEntry};
use crate::scheduler::{Batch, Lane, Task};
use crate::sim::latency::LatencyModel;

use super::core::{BatchDone, ExecutionBackend, Step, TaskDone};

/// An in-flight batch: frees its lane at `lane_free`, with per-task
/// completion times possibly earlier (CPU worker pool).
struct InFlight {
    lane_free: f64,
    done: BatchDone,
}

pub struct SimBackend<'a> {
    /// Remaining arrivals, sorted ascending by arrival time.
    trace: std::vec::IntoIter<Task>,
    /// The next arrival, held back until the clock reaches it.
    next_arrival: Option<Task>,
    now: f64,
    lanes: [Option<InFlight>; 2],
    lat: &'a LatencyModel,
    model: &'a ModelEntry,
    dev: &'a DeviceProfile,
}

impl<'a> SimBackend<'a> {
    /// `tasks` must be sorted ascending by arrival time.
    pub fn new(
        tasks: Vec<Task>,
        lat: &'a LatencyModel,
        model: &'a ModelEntry,
        dev: &'a DeviceProfile,
    ) -> SimBackend<'a> {
        let mut trace = tasks.into_iter();
        let next_arrival = trace.next();
        SimBackend { trace, next_arrival, now: 0.0, lanes: [None, None], lat, model, dev }
    }

    /// Earliest future event on the backend's own timeline.
    fn next_event(&self) -> f64 {
        let mut next = f64::INFINITY;
        if let Some(t) = &self.next_arrival {
            next = next.min(t.arrival);
        }
        for slot in self.lanes.iter().flatten() {
            next = next.min(slot.lane_free);
        }
        next
    }
}

impl ExecutionBackend for SimBackend<'_> {
    fn now(&mut self) -> f64 {
        self.now
    }

    fn submit(&mut self, batch: Batch) -> Result<()> {
        let idx = batch.lane.index();
        assert!(self.lanes[idx].is_none(), "lane {:?} already busy", batch.lane);
        let in_flight = match batch.lane {
            Lane::Gpu => {
                // one fused batch: every task completes when the batch does
                let dur = self.lat.gpu_batch_secs(self.model, &batch, self.dev);
                let done_at = self.now + dur;
                InFlight {
                    lane_free: done_at,
                    done: BatchDone {
                        lane: Lane::Gpu,
                        completions: batch
                            .tasks
                            .iter()
                            .map(|t| TaskDone {
                                id: t.id,
                                at: done_at,
                                infer_secs: dur,
                                output: Vec::new(),
                            })
                            .collect(),
                        batch_infer_secs: dur,
                    },
                }
            }
            Lane::Cpu => {
                // worker pool *within* the batch: tasks run batch-1 on
                // `dev.cpu_workers` parallel workers, earliest-free
                // first; the lane frees when the whole batch is done
                // (one batch in flight — same gate as the wire path).
                let mut workers = vec![self.now; self.dev.cpu_workers.max(1)];
                let mut completions = Vec::with_capacity(batch.tasks.len());
                let mut infer = 0.0;
                for task in &batch.tasks {
                    let w = (0..workers.len())
                        .min_by(|&a, &b| workers[a].total_cmp(&workers[b]))
                        .unwrap();
                    let dur = self.lat.cpu_task_secs(
                        self.model,
                        task.true_len,
                        task.input_len,
                        self.dev,
                    );
                    workers[w] += dur;
                    completions.push(TaskDone {
                        id: task.id,
                        at: workers[w],
                        infer_secs: dur,
                        output: Vec::new(),
                    });
                    infer += dur;
                }
                let lane_free = workers.iter().copied().fold(self.now, f64::max);
                InFlight {
                    lane_free,
                    done: BatchDone {
                        lane: Lane::Cpu,
                        completions,
                        batch_infer_secs: infer,
                    },
                }
            }
        };
        self.lanes[idx] = Some(in_flight);
        Ok(())
    }

    fn wait(&mut self, deadline: Option<f64>) -> Result<Step> {
        let next = self.next_event();
        let target = next.min(deadline.unwrap_or(f64::INFINITY));
        if target.is_infinite() {
            return Ok(Step {
                exhausted: true,
                stream_closed: self.next_arrival.is_none(),
                ..Default::default()
            });
        }
        self.now = self.now.max(target);

        let mut step = Step::default();
        // deliver every arrival due by the new clock
        while self
            .next_arrival
            .as_ref()
            .is_some_and(|t| t.arrival <= self.now)
        {
            step.arrivals.push(self.next_arrival.take().unwrap());
            self.next_arrival = self.trace.next();
        }
        // deliver every batch whose lane has freed by the new clock
        for slot in &mut self.lanes {
            if slot.as_ref().is_some_and(|f| f.lane_free <= self.now) {
                step.done.push(slot.take().unwrap().done);
            }
        }
        // a finite trace is an "open stream" that closes with its last
        // arrival — open-stream runs over the simulator terminate
        step.stream_closed = self.next_arrival.is_none();
        Ok(step)
    }
}
