//! A serving session for one LM variant.
//!
//! Weights upload to the device once at construction; each `generate`
//! call runs bucketed prefill (chunked to the largest prefill batch
//! bucket), assembles the decode-bucket KV cache, and then steps the
//! batched decode executable until every row has produced its target
//! number of tokens.
//!
//! The *length oracle* (how many tokens a row generates) comes from the
//! workload record — see DESIGN.md §Substitutions: with synthetic
//! weights the EOS head carries no signal, so the corpus supplies
//! per-(input, model) output lengths calibrated to the paper's Fig. 1a,
//! and the session runs exactly that many real decode steps.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, ensure, Result};

use crate::config::ModelEntry;
use crate::runtime::client::i32_literal;
use crate::runtime::xla;
use crate::runtime::{ArtifactStore, RtClient};

/// Result of one batched generation call.
#[derive(Debug)]
pub struct GenOutput {
    /// Generated token ids per input row (length = its target length).
    pub tokens: Vec<Vec<i32>>,
    /// Wall seconds spent in prefill execution.
    pub prefill_secs: f64,
    /// Wall seconds spent in decode steps.
    pub decode_secs: f64,
    /// Number of decode steps executed (= max target length).
    pub steps: usize,
    /// Batch bucket the decode executed at.
    pub decode_bucket: usize,
}

/// A loaded LM: weights resident on the PJRT device, generation over
/// bucketed prefill/decode executables.
pub struct LmSession {
    store: Arc<ArtifactStore>,
    /// PJRT client this session executes on (obtained lazily from the
    /// store: constructing a session requires a real backend).
    client: RtClient,
    /// The manifest entry this session serves.
    pub entry: ModelEntry,
    /// Weights as device buffers, in canonical param order.
    param_buffers: Vec<xla::PjRtBuffer>,
    /// Weights as host literals — kept alive for the whole session:
    /// `buffer_from_host_literal` transfers asynchronously, so the
    /// source of every device-resident weight buffer must outlive it.
    #[allow(dead_code)]
    param_literals: Vec<xla::Literal>,
}

impl LmSession {
    /// Open a session for `model`: obtain the PJRT client and upload
    /// every weight tensor to the device.
    pub fn new(store: Arc<ArtifactStore>, model: &str) -> Result<LmSession> {
        let client = store.client()?;
        let entry = store.manifest.model(model)?.clone();
        let bundle = store.bundle(&entry.weights)?;
        let mut param_literals = Vec::with_capacity(entry.param_names.len());
        let mut param_buffers = Vec::with_capacity(entry.param_names.len());
        for name in &entry.param_names {
            let tensor = bundle
                .get(name)
                .ok_or_else(|| anyhow!("weights.bin missing tensor '{name}'"))?;
            let lit = tensor.to_literal()?;
            param_buffers.push(client.upload(&lit)?);
            param_literals.push(lit);
        }
        Ok(LmSession { store, client, entry, param_buffers, param_literals })
    }

    /// The served model's manifest name.
    pub fn model_name(&self) -> &str {
        &self.entry.name
    }

    /// The artifact store this session loads from.
    pub fn store(&self) -> Arc<ArtifactStore> {
        self.store.clone()
    }

    /// Generate `target_lens[i]` tokens for each prompt. Prompts must be
    /// pre-encoded and pre-truncated to `max_input_len`.
    pub fn generate(&self, prompts: &[Vec<i32>], target_lens: &[usize]) -> Result<GenOutput> {
        ensure!(!prompts.is_empty(), "empty batch");
        ensure!(prompts.len() == target_lens.len(), "prompts/target_lens mismatch");
        let m = &self.store.manifest;
        let n = prompts.len();
        let max_decode_bucket = *self
            .entry
            .decode
            .keys()
            .max()
            .ok_or_else(|| anyhow!("no decode buckets"))?;
        ensure!(
            n <= max_decode_bucket,
            "batch {n} exceeds max decode bucket {max_decode_bucket}"
        );
        for p in prompts {
            ensure!(p.len() <= m.max_input_len, "prompt exceeds max_input_len");
        }

        let decode_bucket = self.store.decode_bucket(&self.entry.name, n)?;
        let (cache_elems_per_row, row_stride, layer_stride) = self.cache_geometry();

        // --- prefill, chunked to available prefill buckets -------------
        let t0 = Instant::now();
        let max_prefill_b = *self
            .entry
            .prefill
            .keys()
            .map(|(b, _)| b)
            .max()
            .ok_or_else(|| anyhow!("no prefill buckets"))?;

        // Assemble the decode-bucket cache host-side from per-chunk
        // prefill outputs.
        let n_layers = self.entry.n_layers;
        let mut cache_k = vec![0f32; n_layers * decode_bucket * row_stride];
        let mut cache_v = vec![0f32; n_layers * decode_bucket * row_stride];
        let mut next_tokens = vec![m.pad_id; decode_bucket];
        let mut positions = vec![0i32; decode_bucket];

        let mut row = 0usize;
        while row < n {
            let chunk = (n - row).min(max_prefill_b);
            let longest = prompts[row..row + chunk]
                .iter()
                .map(|p| p.len().max(1))
                .max()
                .unwrap();
            let (bb, sb) = self.store.prefill_bucket(&self.entry.name, chunk, longest)?;
            let exe = self.store.prefill_hlo(&self.entry.name, (bb, sb))?;

            let mut toks = vec![m.pad_id; bb * sb];
            let mut lens = vec![1i32; bb];
            for (i, p) in prompts[row..row + chunk].iter().enumerate() {
                let take = p.len().min(sb);
                toks[i * sb..i * sb + take].copy_from_slice(&p[..take]);
                lens[i] = take.max(1) as i32;
            }
            // source literals must outlive the execute: the transfer
            // behind buffer_from_host_literal is asynchronous
            let toks_lit = i32_literal(&toks, &[bb as i64, sb as i64])?;
            let lens_lit = i32_literal(&lens, &[bb as i64])?;
            let toks_buf = self.client.upload(&toks_lit)?;
            let lens_buf = self.client.upload(&lens_lit)?;
            let mut args: Vec<&xla::PjRtBuffer> =
                Vec::with_capacity(self.param_buffers.len() + 2);
            args.extend(self.param_buffers.iter());
            args.push(&toks_buf);
            args.push(&lens_buf);
            let outs = exe.run_buffers(&args)?;
            ensure!(outs.len() == 3, "prefill returned {} outputs", outs.len());
            let logits = outs[0].to_vec::<f32>()?;
            let ck = outs[1].to_vec::<f32>()?;
            let cv = outs[2].to_vec::<f32>()?;

            // chunk cache layout: [L, bb, H, S, Dh]
            let vocab = m.vocab_size;
            for i in 0..chunk {
                let dst_row = row + i;
                next_tokens[dst_row] = argmax(&logits[i * vocab..(i + 1) * vocab]) as i32;
                positions[dst_row] = lens[i];
                for l in 0..n_layers {
                    let src = (l * bb + i) * row_stride;
                    let dst = l * (decode_bucket * row_stride) + dst_row * row_stride;
                    cache_k[dst..dst + row_stride].copy_from_slice(&ck[src..src + row_stride]);
                    cache_v[dst..dst + row_stride].copy_from_slice(&cv[src..src + row_stride]);
                }
            }
            row += chunk;
        }
        let prefill_secs = t0.elapsed().as_secs_f64();
        let _ = (cache_elems_per_row, layer_stride);

        // --- decode loop ------------------------------------------------
        let t1 = Instant::now();
        let exe = self.store.decode_hlo(&self.entry.name, decode_bucket)?;
        let cache_dims = [
            n_layers as i64,
            decode_bucket as i64,
            self.entry.n_heads as i64,
            m.seq_max as i64,
            self.entry.head_dim() as i64,
        ];
        let steps = target_lens.iter().copied().max().unwrap_or(0);
        let mut outputs: Vec<Vec<i32>> = (0..n).map(|i| Vec::with_capacity(target_lens[i])) .collect();
        // the prefill's next-token prediction is the first generated token
        for i in 0..n {
            if target_lens[i] > 0 {
                outputs[i].push(next_tokens[i]);
            }
        }

        // weights stay device-resident (param_buffers); the KV cache
        // round-trips host<->device once per step (the tuple output of
        // the xla crate cannot be re-fed without decomposing to
        // literals) — see EXPERIMENTS.md §Perf for the measured cost.
        let mut ck_lit = crate::runtime::client::f32_literal(&cache_k, &cache_dims)?;
        let mut cv_lit = crate::runtime::client::f32_literal(&cache_v, &cache_dims)?;
        let vocab = m.vocab_size;

        // --- bulk of the generation: K-token in-graph chunks -------------
        // (argmax + cache update inside the lowered scan; one cache
        // round trip per K tokens instead of per token)
        let mut step = 1usize;
        // Measured result (EXPERIMENTS.md §Perf): through the HLO-text
        // interchange the scan's carried KV cache loses buffer donation,
        // so every in-graph step copies the full cache and the chunk is
        // ~4x SLOWER than single-step on CPU-PJRT. Kept for TPU targets
        // (where donation survives jax.export); opt in via env.
        let chunk_k = if std::env::var("RTLM_USE_CHUNKS").is_ok() {
            self.entry.chunk_k
        } else {
            0
        };
        if chunk_k > 1 {
            if let Some(chunk_exe) =
                self.store.decode_chunk_hlo(&self.entry.name, decode_bucket)?
            {
                while steps.saturating_sub(step) >= chunk_k {
                    let pos_lit = i32_literal(&positions, &[decode_bucket as i64])?;
                    let tok_lit = i32_literal(&next_tokens, &[decode_bucket as i64])?;
                    let ck_buf = self.client.upload(&ck_lit)?;
                    let cv_buf = self.client.upload(&cv_lit)?;
                    let pos_buf = self.client.upload(&pos_lit)?;
                    let tok_buf = self.client.upload(&tok_lit)?;
                    let mut args: Vec<&xla::PjRtBuffer> =
                        Vec::with_capacity(self.param_buffers.len() + 4);
                    args.extend(self.param_buffers.iter());
                    args.push(&ck_buf);
                    args.push(&cv_buf);
                    args.push(&pos_buf);
                    args.push(&tok_buf);
                    let mut outs = chunk_exe.run_buffers(&args)?;
                    ensure!(outs.len() == 4, "chunk returned {} outputs", outs.len());
                    let new_pos = outs.pop().unwrap().to_vec::<i32>()?;
                    cv_lit = outs.pop().unwrap();
                    ck_lit = outs.pop().unwrap();
                    let toks = outs.pop().unwrap().to_vec::<i32>()?; // [B, K]
                    for i in 0..n {
                        for j in 0..chunk_k {
                            if step + j < target_lens[i] {
                                outputs[i].push(toks[i * chunk_k + j]);
                            }
                        }
                        next_tokens[i] = toks[i * chunk_k + chunk_k - 1];
                        positions[i] = new_pos[i];
                    }
                    for i in n..decode_bucket {
                        next_tokens[i] = toks[i * chunk_k + chunk_k - 1];
                        positions[i] = new_pos[i];
                    }
                    step += chunk_k;
                }
            }
        }

        // --- remainder: single-token steps --------------------------------
        for step in step..steps {
            let pos_lit = i32_literal(&positions, &[decode_bucket as i64])?;
            let tok_lit = i32_literal(&next_tokens, &[decode_bucket as i64])?;
            let ck_buf = self.client.upload(&ck_lit)?;
            let cv_buf = self.client.upload(&cv_lit)?;
            let pos_buf = self.client.upload(&pos_lit)?;
            let tok_buf = self.client.upload(&tok_lit)?;
            let mut args: Vec<&xla::PjRtBuffer> =
                Vec::with_capacity(self.param_buffers.len() + 4);
            args.extend(self.param_buffers.iter());
            args.push(&ck_buf);
            args.push(&cv_buf);
            args.push(&pos_buf);
            args.push(&tok_buf);
            let mut outs = exe.run_buffers(&args)?;
            ensure!(outs.len() == 3, "decode returned {} outputs", outs.len());
            cv_lit = outs.pop().unwrap();
            ck_lit = outs.pop().unwrap();
            let logits = outs.pop().unwrap().to_vec::<f32>()?;
            for i in 0..n {
                if step < target_lens[i] {
                    let tok = argmax(&logits[i * vocab..(i + 1) * vocab]) as i32;
                    outputs[i].push(tok);
                    next_tokens[i] = tok;
                    positions[i] = (positions[i] + 1).min(m.seq_max as i32 - 1);
                }
            }
        }
        let decode_secs = t1.elapsed().as_secs_f64();

        Ok(GenOutput { tokens: outputs, prefill_secs, decode_secs, steps, decode_bucket })
    }

    /// (elements per cache row per layer, row stride, layer stride).
    fn cache_geometry(&self) -> (usize, usize, usize) {
        let m = &self.store.manifest;
        let row = self.entry.n_heads * m.seq_max * self.entry.head_dim();
        (row, row, row)
    }

    /// Time one decode step at the given bucket (calibration helper).
    pub fn time_decode_step(&self, bucket: usize, reps: usize) -> Result<f64> {
        let m = &self.store.manifest;
        let exe = self.store.decode_hlo(&self.entry.name, bucket)?;
        let cache_dims = [
            self.entry.n_layers as i64,
            bucket as i64,
            self.entry.n_heads as i64,
            m.seq_max as i64,
            self.entry.head_dim() as i64,
        ];
        let elems: usize = cache_dims.iter().map(|d| *d as usize).product();
        let mut ck = crate::runtime::client::f32_literal(&vec![0f32; elems], &cache_dims)?;
        let mut cv = crate::runtime::client::f32_literal(&vec![0f32; elems], &cache_dims)?;
        let positions = vec![4i32; bucket];
        let toks = vec![5i32; bucket];
        // warmup: absorb one-time lazy-compile/allocation costs
        for _ in 0..2 {
            let pos_lit = i32_literal(&positions, &[bucket as i64])?;
            let tok_lit = i32_literal(&toks, &[bucket as i64])?;
            let ck_buf = self.client.upload(&ck)?;
            let cv_buf = self.client.upload(&cv)?;
            let pos_buf = self.client.upload(&pos_lit)?;
            let tok_buf = self.client.upload(&tok_lit)?;
            let mut args: Vec<&xla::PjRtBuffer> =
                Vec::with_capacity(self.param_buffers.len() + 4);
            args.extend(self.param_buffers.iter());
            args.push(&ck_buf);
            args.push(&cv_buf);
            args.push(&pos_buf);
            args.push(&tok_buf);
            let mut outs = exe.run_buffers(&args)?;
            cv = outs.pop().unwrap();
            ck = outs.pop().unwrap();
        }
        // min-of-reps: robust to scheduler interference on a busy host.
        // Timed region includes the cache upload — the serving decode
        // loop pays it every step, so the calibration must too.
        let mut best = f64::INFINITY;
        for _ in 0..reps {
            let pos_lit = i32_literal(&positions, &[bucket as i64])?;
            let tok_lit = i32_literal(&toks, &[bucket as i64])?;
            let t0 = Instant::now();
            let ck_buf = self.client.upload(&ck)?;
            let cv_buf = self.client.upload(&cv)?;
            let pos_buf = self.client.upload(&pos_lit)?;
            let tok_buf = self.client.upload(&tok_lit)?;
            let mut args: Vec<&xla::PjRtBuffer> =
                Vec::with_capacity(self.param_buffers.len() + 4);
            args.extend(self.param_buffers.iter());
            args.push(&ck_buf);
            args.push(&cv_buf);
            args.push(&pos_buf);
            args.push(&tok_buf);
            let mut outs = exe.run_buffers(&args)?;
            best = best.min(t0.elapsed().as_secs_f64());
            cv = outs.pop().unwrap();
            ck = outs.pop().unwrap();
        }
        Ok(best)
    }

    /// Time one prefill at the given bucket (calibration helper).
    pub fn time_prefill(&self, bucket: (usize, usize), reps: usize) -> Result<f64> {
        let exe = self.store.prefill_hlo(&self.entry.name, bucket)?;
        let (b, s) = bucket;
        let toks = vec![5i32; b * s];
        let lens = vec![s as i32; b];
        // warmup: absorb one-time lazy-compile/allocation costs
        for _ in 0..2 {
            let toks_lit = i32_literal(&toks, &[b as i64, s as i64])?;
            let lens_lit = i32_literal(&lens, &[b as i64])?;
            let toks_buf = self.client.upload(&toks_lit)?;
            let lens_buf = self.client.upload(&lens_lit)?;
            let mut args: Vec<&xla::PjRtBuffer> =
                Vec::with_capacity(self.param_buffers.len() + 2);
            args.extend(self.param_buffers.iter());
            args.push(&toks_buf);
            args.push(&lens_buf);
            let _ = exe.run_buffers(&args)?;
        }
        // min-of-reps: robust to scheduler interference on a busy host
        let mut best = f64::INFINITY;
        for _ in 0..reps {
            let toks_lit = i32_literal(&toks, &[b as i64, s as i64])?;
            let lens_lit = i32_literal(&lens, &[b as i64])?;
            let t0 = Instant::now();
            let toks_buf = self.client.upload(&toks_lit)?;
            let lens_buf = self.client.upload(&lens_lit)?;
            let mut args: Vec<&xla::PjRtBuffer> =
                Vec::with_capacity(self.param_buffers.len() + 2);
            args.extend(self.param_buffers.iter());
            args.push(&toks_buf);
            args.push(&lens_buf);
            let outs = exe.run_buffers(&args)?;
            best = best.min(t0.elapsed().as_secs_f64());
            ensure!(outs.len() == 3, "prefill returned {} outputs", outs.len());
        }
        Ok(best)
    }

    /// The device-resident weight buffers, in canonical param order.
    pub fn param_buffers(&self) -> &[xla::PjRtBuffer] {
        &self.param_buffers
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > best_v {
            best_v = x;
            best = i;
        }
    }
    best
}

/// Encode + truncate a prompt for a session (empty prompts become a
/// single BOS so shapes stay valid).
pub fn encode_prompt(store: &ArtifactStore, text: &str) -> Vec<i32> {
    let m = &store.manifest;
    let mut ids = store.vocab.encode(text, Some(m.max_input_len));
    if ids.is_empty() {
        ids.push(m.bos_id);
    }
    ids
}

#[cfg(test)]
mod tests {
    use super::argmax;

    #[test]
    fn argmax_finds_max() {
        assert_eq!(argmax(&[1.0, 5.0, 3.0]), 1);
        assert_eq!(argmax(&[-1.0, -5.0]), 0);
    }
}
