//! LM inference sessions: prefill + autoregressive decode over the AOT
//! artifacts, with shape bucketing and host-side KV-cache management.

pub mod session;

pub use session::{GenOutput, LmSession};
