//! The paper's system contribution (Sec. IV): uncertainty-aware
//! prioritization (UP, Eq. 3), dynamic consolidation, strategic CPU
//! offloading generalised to per-lane admission predicates over an
//! N-lane fleet ([`lane::LaneSet`]), and the uncertainty-oblivious
//! baselines (FIFO, HPF, LUF, MUF) it is evaluated against.
//!
//! All policies implement [`Policy`]; the serving loop / simulator is
//! policy-agnostic. Scheduling itself is pure logic with no runtime
//! dependencies, so this module is fully unit- and property-tested.

pub mod baselines;
pub mod consolidation;
pub mod lane;
pub mod policy;
pub mod queue;
pub mod task;
pub mod uasched;
pub mod up;

pub use baselines::{Fifo, Hpf, Luf, Muf};
pub use lane::{format_lane_counts, Admission, LaneId, LaneKind, LaneSet, LaneSpec};
pub use policy::{Batch, Policy, PolicyKind, WHOLE_BATCH};
pub use queue::{LaneQ, PolicyQueues, UpQueue};
pub use task::{SloClass, Task};
pub use uasched::UaSched;
pub use up::up_priority;
