//! UASCHED (Algorithm 1) — the full RT-LM scheduler: UP priority queue
//! + dynamic consolidation + strategic offloading, generalised from the
//! paper's single `tau` CPU threshold to per-lane admission predicates
//! over an N-lane fleet. The `UP` and `UP+C` ablation arms are the same
//! machine with offloading and/or consolidation disabled.
//!
//! Each lane owns a queue inside the shared [`PolicyQueues`] storage.
//! Arrivals are routed by [`LaneSet::route`] (first claiming lane wins,
//! unclaimed tasks go to the primary fallback lane — with offloading
//! disabled everything goes primary). Accelerator-kind lanes pop with
//! UP priorities + dynamic consolidation from an indexed [`UpQueue`]
//! (order-equivalent to the historical full re-sort, but O(batch)
//! instead of O(n log n) per pop — see `queue.rs`); CPU-kind quarantine
//! lanes pop FIFO, exactly the historical CPU-lane behaviour.
//!
//! Priorities are *dynamic* (Eq. 2/3's slack is the remaining time until
//! the priority point at scheduling time), so waiting tasks age upward
//! and cannot be starved by a stream of lower-uncertainty arrivals.

use crate::config::SchedParams;

use super::consolidation::{sort_by_uncertainty, split_point};
use super::lane::{LaneId, LaneKind, LaneSet};
use super::policy::{Batch, Policy, WHOLE_BATCH};
use super::queue::{LaneQ, PolicyQueues, Selector};
use super::task::Task;

/// The UASCHED scheduling machine (UP + consolidation + offloading,
/// each independently toggleable — the ablation arms are the same
/// struct with features off).
pub struct UaSched {
    params: SchedParams,
    /// Output-tokens -> seconds coefficient of the primary serving model.
    eta: f64,
    /// The fleet this policy schedules; admission predicates generalise
    /// the malicious threshold tau (Eq. 4).
    lanes: LaneSet,
    /// Dynamic consolidation on/off (off = UP with static batching).
    consolidate: bool,
    /// Strategic offloading on/off: off routes everything to the
    /// primary lane, the historical `tau = +inf` ablation arms.
    offload: bool,
    /// One waiting queue per lane (indexed by LaneId): accelerator-kind
    /// lanes hold an indexed [`UpQueue`], CPU lanes a FIFO. Overload
    /// shedding (`queue_cap`/`shed`) lives here too.
    queues: PolicyQueues,
}

impl UaSched {
    /// Build the machine over a lane fleet. `eta` is the primary
    /// model's output-tokens -> seconds coefficient (execution-time
    /// estimate in Eq. 2/3); `consolidate`/`offload` toggle the
    /// respective Algorithm 1 components.
    pub fn new(
        params: SchedParams,
        eta: f64,
        lanes: LaneSet,
        consolidate: bool,
        offload: bool,
    ) -> UaSched {
        let per_lane: Vec<(LaneId, LaneQ)> = lanes
            .ids()
            .map(|id| {
                let q = match lanes.spec(id).kind {
                    // remote lanes proxy a node's accelerator path and
                    // pop in UP order, so they index like accelerators
                    LaneKind::Accelerator | LaneKind::Remote => {
                        LaneQ::up(params.clone(), eta)
                    }
                    LaneKind::Cpu => LaneQ::fifo(),
                };
                (id, q)
            })
            .collect();
        let queues = PolicyQueues::new(per_lane, params.queue_cap, params.shed);
        UaSched { params, eta, lanes, consolidate, offload, queues }
    }

    /// The historical two-lane constructor: accelerator + CPU
    /// quarantine admitting `u > tau`, offloading on.
    pub fn two_lane(params: SchedParams, eta: f64, tau: f64, consolidate: bool) -> UaSched {
        UaSched::new(params, eta, LaneSet::two_lane("", tau), consolidate, true)
    }

    fn lane_batch_size(&self, lane: LaneId) -> usize {
        self.lanes.spec(lane).batch_size.unwrap_or(self.params.batch_size).max(1)
    }

    fn pop_accel(&mut self, lane: LaneId, now: f64, force: bool) -> Option<Batch> {
        let c = self.lane_batch_size(lane);
        let idx = lane.index();
        let len = self.queues.len(idx);
        if len == 0 {
            return None;
        }
        if !self.consolidate {
            // UP with static batching: first C by priority.
            if !force && len < c {
                return None;
            }
            let tasks = self.queues.up_mut(idx).pop_top(now, c);
            return Some(Batch { lane, tasks });
        }

        // Dynamic consolidation: reorder a window of up to b*C tasks by
        // uncertainty and segment by lambda. A full batch C suffices to
        // dispatch — Algorithm 1 "ensures there is always a batch of
        // tasks ready for execution"; b only widens the reorder window
        // when the queue runs deeper.
        let accumulate = self.params.accumulate_len_for(c);
        let lambda = self.lanes.spec(lane).lambda.unwrap_or(self.params.lambda);
        if !force && len < c {
            return None;
        }
        let take = len.min(accumulate);
        let mut tmp = self.queues.up_mut(idx).pop_top(now, take);
        sort_by_uncertainty(&mut tmp);

        // Bounded deferral (anti-starvation, see module docs): if the
        // lambda-split has already re-queued some task MAX_DEFERRALS
        // times, this round serves the u-sorted window *ending at* the
        // most-starved task, so it is guaranteed to dispatch.
        const MAX_DEFERRALS: u32 = 3;
        let starved_idx = tmp
            .iter()
            .enumerate()
            .filter(|(_, t)| t.deferrals >= MAX_DEFERRALS)
            .max_by_key(|(_, t)| t.deferrals)
            .map(|(i, _)| i);
        let (batch, rest): (Vec<Task>, Vec<Task>) = if let Some(i) = starved_idx {
            let start = (i + 1).saturating_sub(c);
            let mut batch: Vec<Task> = tmp.drain(start..=i).collect();
            debug_assert!(batch.iter().any(|t| t.deferrals >= MAX_DEFERRALS));
            batch.shrink_to_fit();
            (batch, tmp)
        } else {
            let split = split_point(&tmp, lambda, c);
            let rest = tmp.split_off(split);
            (tmp, rest)
        };
        for mut task in rest {
            task.deferrals += 1;
            // re-queued with a fresh insertion sequence — the same tail
            // position the historical append gave it; re-prioritised
            // (and possibly re-promoted) next pop
            self.queues.reinsert(idx, task);
        }
        Some(Batch { lane, tasks: batch })
    }

    /// Whole-batch FIFO pop: CPU quarantine lanes always, and the
    /// direct-call stepped path on remote lanes (insertion order from
    /// the indexed queue).
    fn pop_fifo(&mut self, lane: LaneId, force: bool) -> Option<Batch> {
        let c = self.lane_batch_size(lane);
        let idx = lane.index();
        let len = self.queues.len(idx);
        if len == 0 || (!force && len < c) {
            return None;
        }
        let n = len.min(c);
        let tasks = if matches!(self.queues.lane(idx), LaneQ::Up(_)) {
            self.queues.up_mut(idx).pop_fifo_order(n)
        } else {
            self.queues.pop_front(idx, n)
        };
        Some(Batch { lane, tasks })
    }

    /// Length-aware slot packing (`--sched step`): fill freed slots in
    /// UP-priority order, but cap co-admitted *predicted-long* tasks
    /// (uncertainty ≥ u_scale/2) at `max(1, ⌈free/2⌉)` per fill. A slot
    /// table packed entirely with long generations stays pinned for the
    /// whole tail; holding some long tasks back keeps slots churning so
    /// freed capacity reaches the short traffic. Deferred tasks stay
    /// queued and age upward under UP, so the cap cannot starve them —
    /// and the first admitted task is always exempt, so a forced fill
    /// always makes progress.
    fn pop_fill_accel(&mut self, lane: LaneId, now: f64, force: bool, free: usize) -> Option<Batch> {
        let c = self.lane_batch_size(lane);
        let idx = lane.index();
        let len = self.queues.len(idx);
        // same admission rule as whole-batch pops, shrunk to the free
        // slots: wait for a fill's worth of tasks unless xi forces
        if len == 0 || (!force && len < free.min(c)) {
            return None;
        }
        let long_u = self.params.u_scale * 0.5;
        let cap_long = free.div_ceil(2).max(1);
        let q = self.queues.up_mut(idx);
        q.promote(now);
        let mut picked = Vec::with_capacity(free.min(len));
        let mut n_long = 0;
        {
            // walk the exact priority order lazily, skipping capped
            // longs, without disturbing the queue until selection is
            // final — the indexed replacement for the sorted-vec walk
            let mut sel = Selector::new(q, now);
            while picked.len() < free {
                let Some(r) = sel.next() else { break };
                let is_long = q.task(r).uncertainty >= long_u;
                if is_long && n_long >= cap_long && !picked.is_empty() {
                    continue; // defer: enough long generations co-admitted
                }
                n_long += usize::from(is_long);
                picked.push(r);
            }
        }
        if picked.is_empty() {
            return None;
        }
        let tasks = q.remove_selected(&picked);
        Some(Batch { lane, tasks })
    }

    /// The fleet this policy schedules.
    pub fn lanes(&self) -> &LaneSet {
        &self.lanes
    }

    /// Queued tasks on one lane (test/diagnostic hook).
    #[cfg(test)]
    pub(crate) fn lane_queue_len(&self, lane: LaneId) -> usize {
        self.queues.len(lane.index())
    }

    /// Among lanes sharing `routed`'s admission predicate (a union
    /// fleet can hold several fallback lanes — one per node — or
    /// several nodes advertising the same band), pick the shortest
    /// queue, lowest index on ties. Every single-process fleet has
    /// distinct predicates per lane, so this returns `routed`
    /// unchanged there — bit-identical to the historical router.
    fn balanced(&self, routed: LaneId) -> LaneId {
        let adm = self.lanes.spec(routed).admission;
        let mut best = routed;
        let mut best_len = self.queues.len(routed.index());
        for id in self.lanes.ids() {
            if id == routed || self.lanes.spec(id).admission != adm {
                continue;
            }
            let len = self.queues.len(id.index());
            if len < best_len || (len == best_len && id.index() < best.index()) {
                best = id;
                best_len = len;
            }
        }
        best
    }
}

impl Policy for UaSched {
    fn name(&self) -> String {
        let offloading = self.offload && self.lanes.has_offload();
        match (self.consolidate, offloading) {
            (false, _) => "UP".into(),
            (true, false) => "UP+C".into(),
            (true, true) => "RT-LM".into(),
        }
    }

    fn push(&mut self, task: Task) {
        let lane = if self.offload {
            // strategic offloading (Eq. 4, per lane), least-loaded
            // among lanes advertising the same admission
            self.balanced(self.lanes.route(task.uncertainty))
        } else {
            self.lanes.primary()
        };
        self.queues.push(lane.index(), task);
    }

    fn pop(&mut self, lane: LaneId, now: f64, force: bool, free: usize) -> Option<Batch> {
        if free == 0 || lane.index() >= self.lanes.len() {
            return None;
        }
        if free == WHOLE_BATCH {
            return match self.lanes.spec(lane).kind {
                // remote lanes proxy a node's accelerator path: same UP
                // + consolidation ordering, executed over the wire
                LaneKind::Accelerator | LaneKind::Remote => self.pop_accel(lane, now, force),
                LaneKind::Cpu => self.pop_fifo(lane, force),
            };
        }
        if self.lanes.spec(lane).kind != LaneKind::Accelerator {
            // quarantine lanes keep whole-batch FIFO semantics; trim to
            // the free slots, re-admitting overflow through routing
            let mut batch = self.pop_fifo(lane, force)?;
            if batch.tasks.len() > free {
                for task in batch.tasks.split_off(free) {
                    self.push(task);
                }
            }
            return Some(batch);
        }
        self.pop_fill_accel(lane, now, force, free)
    }

    fn queue_len(&self) -> usize {
        self.queues.total_len()
    }

    fn take_shed(&mut self) -> Vec<(LaneId, Task)> {
        self.queues.take_shed()
    }

    fn retire_lane(&mut self, lane: LaneId) -> anyhow::Result<()> {
        if lane.index() >= self.lanes.len() {
            anyhow::bail!("retire_lane: no such lane {lane}");
        }
        self.lanes.retire(lane)?;
        // re-admit everything the dead lane had queued through the
        // surviving admissions (same path as ordinary arrivals — which
        // means a capped survivor may shed some of the rerouted load)
        let orphans: Vec<Task> = self.queues.drain_lane(lane.index());
        for task in orphans {
            self.push(task);
        }
        Ok(())
    }

    fn next_force_deadline(&self, _now: f64) -> Option<f64> {
        if self.lanes.iter().all(|l| l.xi.is_none()) {
            return None; // no overrides: the engine's global-xi path is exact
        }
        let mut deadline = f64::INFINITY;
        for id in self.lanes.ids() {
            if self.queues.len(id.index()) == 0 {
                continue;
            }
            let oldest = self.queues.lane(id.index()).min_arrival();
            let xi = self.lanes.spec(id).xi.unwrap_or(self.params.xi);
            // the engine compares `now >= oldest + xi` — keep the same
            // float expression so the wait deadline and the force test
            // agree to the last bit (see engine/core.rs)
            deadline = deadline.min(oldest + xi);
        }
        deadline.is_finite().then_some(deadline)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ShedPolicy;
    use crate::scheduler::lane::{Admission, LaneSpec};
    use crate::scheduler::task::test_task;
    use crate::util::prop;
    use crate::util::rng::Pcg64;

    fn params(c: usize) -> SchedParams {
        SchedParams { batch_size: c, ..Default::default() }
    }

    fn rand_task(rng: &mut Pcg64, id: u64) -> Task {
        let arrival = rng.f64() * 10.0;
        let u = 4.0 + rng.f64() * 92.0;
        let d = arrival + 0.5 + rng.f64() * 5.0;
        test_task(id, arrival, d, u)
    }

    #[test]
    fn up_static_batching_orders_by_priority() {
        let mut s = UaSched::two_lane(params(2), 0.05, f64::INFINITY, false);
        // same uncertainty, different deadlines -> earliest deadline first
        s.push(test_task(1, 0.0, 9.0, 10.0));
        s.push(test_task(2, 0.0, 1.0, 10.0));
        s.push(test_task(3, 0.0, 4.0, 10.0));
        let b = s.pop(LaneId::GPU, 0.0, true, WHOLE_BATCH).unwrap();
        assert_eq!(b.tasks.iter().map(|t| t.id).collect::<Vec<_>>(), vec![2, 3]);
    }

    #[test]
    fn offload_quarantines_above_tau() {
        let mut s = UaSched::two_lane(params(2), 0.05, 50.0, true);
        s.push(test_task(1, 0.0, 5.0, 80.0)); // malicious
        s.push(test_task(2, 0.0, 5.0, 10.0));
        s.push(test_task(3, 0.0, 5.0, 60.0)); // malicious
        assert_eq!(s.queue_len(), 3);
        let cpu = s.pop(LaneId::CPU, 0.0, false, WHOLE_BATCH).unwrap();
        assert_eq!(cpu.lane, LaneId::CPU);
        let mut ids: Vec<u64> = cpu.tasks.iter().map(|t| t.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![1, 3]);
        let gpu = s.pop(LaneId::GPU, 0.0, true, WHOLE_BATCH).unwrap();
        assert_eq!(gpu.tasks[0].id, 2);
    }

    #[test]
    fn three_lane_fleet_routes_by_band() {
        // two accelerator variants + quarantine: low-u traffic goes to
        // the small model, the extreme tail to the CPU lane, the rest to
        // the big fallback lane.
        let lanes = LaneSet::new(vec![
            LaneSpec::accelerator("big", "m1"),
            LaneSpec {
                admission: Admission::AtMost(20.0),
                batch_size: Some(1),
                ..LaneSpec::accelerator("small", "m2")
            },
            LaneSpec::cpu_offload("cpu", "m1", 60.0),
        ])
        .unwrap();
        let mut s = UaSched::new(params(2), 0.05, lanes, true, true);
        s.push(test_task(1, 0.0, 5.0, 10.0)); // -> small
        s.push(test_task(2, 0.0, 5.0, 40.0)); // -> big
        s.push(test_task(3, 0.0, 5.0, 90.0)); // -> cpu
        let small = s.pop(LaneId(1), 0.0, true, WHOLE_BATCH).unwrap();
        assert_eq!(small.tasks[0].id, 1);
        assert_eq!(small.tasks.len(), 1, "per-lane batch size respected");
        let big = s.pop(LaneId(0), 0.0, true, WHOLE_BATCH).unwrap();
        assert_eq!(big.tasks[0].id, 2);
        let cpu = s.pop(LaneId(2), 0.0, true, WHOLE_BATCH).unwrap();
        assert_eq!(cpu.tasks[0].id, 3);
        assert_eq!(s.queue_len(), 0);
    }

    #[test]
    fn offload_disabled_routes_everything_primary() {
        let lanes = LaneSet::two_lane("m", 50.0);
        let mut s = UaSched::new(params(2), 0.05, lanes, true, false);
        s.push(test_task(1, 0.0, 5.0, 80.0)); // would quarantine under RT-LM
        s.push(test_task(2, 0.0, 5.0, 10.0));
        assert!(s.pop(LaneId::CPU, 0.0, true, WHOLE_BATCH).is_none());
        let b = s.pop(LaneId::GPU, 0.0, true, WHOLE_BATCH).unwrap();
        assert_eq!(b.tasks.len(), 2);
        assert_eq!(s.name(), "UP+C");
    }

    #[test]
    fn policy_names_track_offload_effectiveness() {
        assert_eq!(UaSched::two_lane(params(2), 0.05, 50.0, true).name(), "RT-LM");
        assert_eq!(UaSched::two_lane(params(2), 0.05, f64::INFINITY, true).name(), "UP+C");
        assert_eq!(UaSched::two_lane(params(2), 0.05, 50.0, false).name(), "UP");
    }

    #[test]
    fn consolidation_returns_leftovers_to_queue() {
        let mut s = UaSched::two_lane(params(4), 0.05, f64::INFINITY, true);
        // 8 tasks: 4 similar-u, 4 much larger u (accumulate = 7 with b=1.8)
        for i in 0..4 {
            s.push(test_task(i, 0.0, 5.0, 10.0 + i as f64));
        }
        for i in 4..8 {
            s.push(test_task(i, 0.0, 5.0, 80.0 + i as f64));
        }
        let b = s.pop(LaneId::GPU, 0.0, false, WHOLE_BATCH).unwrap();
        // the low-uncertainty group forms the batch
        assert!(b.tasks.iter().all(|t| t.uncertainty < 20.0), "{:?}", b.tasks);
        assert_eq!(b.tasks.len(), 4);
        assert_eq!(s.queue_len(), 4);
    }

    #[test]
    fn waits_for_full_batch_unless_forced() {
        let mut s = UaSched::two_lane(params(4), 0.05, f64::INFINITY, true);
        for i in 0..3 {
            s.push(test_task(i, 0.0, 5.0, 10.0));
        }
        // fewer than C=4 queued -> wait for more arrivals unless forced
        assert!(s.pop(LaneId::GPU, 0.0, false, WHOLE_BATCH).is_none());
        assert!(s.pop(LaneId::GPU, 0.0, true, WHOLE_BATCH).is_some());
    }

    #[test]
    fn full_batch_dispatches_without_waiting_for_accumulation() {
        // Algorithm 1 keeps a batch ready: C tasks suffice even though
        // the reorder window b*C is larger.
        let mut s = UaSched::two_lane(params(4), 0.05, f64::INFINITY, true);
        for i in 0..4 {
            s.push(test_task(i, 0.0, 5.0, 10.0));
        }
        let b = s.pop(LaneId::GPU, 0.0, false, WHOLE_BATCH).unwrap();
        assert_eq!(b.tasks.len(), 4);
    }

    #[test]
    fn pop_fill_caps_predicted_long_coadmission() {
        // u_scale defaults to 96, so "predicted long" means u >= 48.
        // The long tasks get tight deadlines so UP ranks them first: an
        // uncapped fill of 4 would be all-long, pinning every slot.
        let mut s = UaSched::two_lane(params(8), 0.05, f64::INFINITY, true);
        for i in 0..4 {
            s.push(test_task(i, 0.0, 1.0, 90.0)); // long, urgent
        }
        for i in 4..8 {
            s.push(test_task(i, 0.0, 50.0, 10.0)); // short, relaxed
        }
        let b = s.pop(LaneId::GPU, 0.0, true, 4).unwrap();
        assert_eq!(b.tasks.len(), 4);
        let longs = b.tasks.iter().filter(|t| t.uncertainty >= 48.0).count();
        assert_eq!(longs, 2, "cap is ceil(free/2) = 2 predicted-long tasks");
        assert_eq!(s.queue_len(), 4, "deferred tasks stay queued");
    }

    #[test]
    fn pop_fill_all_long_queue_still_progresses() {
        let mut s = UaSched::two_lane(params(8), 0.05, f64::INFINITY, true);
        for i in 0..3 {
            s.push(test_task(i, 0.0, 1.0, 90.0));
        }
        // cap = ceil(1/2) = 1: a single freed slot must still admit one
        let b = s.pop(LaneId::GPU, 0.0, true, 1).unwrap();
        assert_eq!(b.tasks.len(), 1);
        assert_eq!(s.queue_len(), 2);
    }

    #[test]
    fn per_lane_lambda_overrides_consolidation_split() {
        let mk = |lambda: Option<f64>| {
            let lanes = LaneSet::new(vec![
                LaneSpec { lambda, ..LaneSpec::accelerator("gpu", "m") },
                LaneSpec::cpu_offload("cpu", "m", f64::INFINITY),
            ])
            .unwrap();
            UaSched::new(params(4), 0.05, lanes, true, true)
        };
        // 80 > 1.5*11: the default lambda splits after two tasks; a wide
        // per-lane override keeps the whole window in one batch
        for (lambda, expect) in [(None, 2usize), (Some(100.0), 4)] {
            let mut s = mk(lambda);
            for (i, u) in [10.0, 11.0, 80.0, 88.0].into_iter().enumerate() {
                s.push(test_task(i as u64, 0.0, 5.0, u));
            }
            let b = s.pop(LaneId::GPU, 0.0, false, WHOLE_BATCH).unwrap();
            assert_eq!(b.tasks.len(), expect, "lambda={lambda:?}");
        }
    }

    #[test]
    fn per_lane_xi_surfaces_as_force_deadline() {
        let lanes = LaneSet::new(vec![
            LaneSpec { xi: Some(0.5), ..LaneSpec::accelerator("gpu", "m") },
            LaneSpec::cpu_offload("cpu", "m", 60.0),
        ])
        .unwrap();
        let mut s = UaSched::new(params(4), 0.05, lanes, true, true);
        assert_eq!(s.next_force_deadline(0.0), None, "empty queues have no window");
        s.push(test_task(1, 1.0, 5.0, 10.0)); // gpu lane, xi override 0.5
        s.push(test_task(2, 0.0, 5.0, 90.0)); // cpu lane, global xi (default 2.0)
        assert_eq!(s.next_force_deadline(0.0), Some(1.5), "min over per-lane windows");

        // without overrides the hook stays silent: the engine's global
        // xi path must remain bit-identical
        let mut plain = UaSched::two_lane(params(4), 0.05, 60.0, true);
        plain.push(test_task(1, 0.0, 5.0, 10.0));
        assert_eq!(plain.next_force_deadline(0.0), None);
    }

    #[test]
    fn push_balances_identical_admission_lanes_by_queue_depth() {
        // a union fleet: two fallback lanes (one per node) + a shared
        // quarantine band
        let lanes = LaneSet::new(vec![
            LaneSpec::accelerator("a/gpu", "m"),
            LaneSpec::accelerator("b/gpu", "m"),
            LaneSpec::cpu_offload("a/cpu", "m", 60.0),
        ])
        .unwrap();
        let mut s = UaSched::new(params(2), 0.05, lanes, true, true);
        for i in 0..4 {
            s.push(test_task(i, 0.0, 5.0, 10.0));
        }
        let a = s.pop(LaneId(0), 0.0, true, WHOLE_BATCH).expect("lane a got traffic");
        let b = s.pop(LaneId(1), 0.0, true, WHOLE_BATCH).expect("lane b got traffic");
        assert_eq!(a.tasks.len() + b.tasks.len(), 4);
        assert_eq!(a.tasks.len(), 2, "fallback traffic split evenly");
        // the claiming lane is a singleton group: untouched by balancing
        s.push(test_task(9, 0.0, 5.0, 90.0));
        assert_eq!(s.pop(LaneId(2), 0.0, true, WHOLE_BATCH).unwrap().tasks[0].id, 9);
    }

    #[test]
    fn retire_lane_reroutes_queued_tasks() {
        let lanes = LaneSet::new(vec![
            LaneSpec::accelerator("a/gpu", "m"),
            LaneSpec::accelerator("b/gpu", "m"),
        ])
        .unwrap();
        let mut s = UaSched::new(params(2), 0.05, lanes, true, true);
        for i in 0..4 {
            s.push(test_task(i, 0.0, 5.0, 10.0));
        }
        s.retire_lane(LaneId(0)).unwrap();
        assert!(s.pop(LaneId(0), 0.0, true, WHOLE_BATCH).is_none(), "dead lane drained");
        let b = s.pop(LaneId(1), 0.0, true, WHOLE_BATCH).unwrap();
        assert_eq!(b.tasks.len(), 2, "survivor serves at its batch size");
        assert_eq!(s.queue_len(), 2, "re-routed tasks are queued, not lost");
        // fresh arrivals also avoid the dead lane
        s.push(test_task(9, 0.0, 5.0, 10.0));
        assert_eq!(s.lane_queue_len(LaneId(0)), 0);
        // the whole fleet dying is an error
        assert!(s.retire_lane(LaneId(1)).is_err());
    }

    #[test]
    fn aged_task_eventually_dispatches_first() {
        // A high-uncertainty task left waiting long enough must outrank
        // fresh low-uncertainty arrivals (no starvation).
        let mut s = UaSched::two_lane(params(1), 0.05, f64::INFINITY, false);
        s.push(test_task(1, 0.0, 2.0, 90.0)); // old, uncertain
        s.push(test_task(2, 50.0, 60.0, 5.0)); // fresh, certain, far deadline
        let b = s.pop(LaneId::GPU, 50.0, true, WHOLE_BATCH).unwrap();
        assert_eq!(b.tasks[0].id, 1, "aged task must win");
    }

    #[test]
    fn nan_uncertainty_task_does_not_panic_the_queue() {
        // a broken regressor must degrade gracefully: NaN-uncertainty
        // tasks route to the fallback lane, sort deterministically
        // (total order) and still dispatch
        let mut s = UaSched::two_lane(params(2), 0.05, 50.0, true);
        let mut nan_task = test_task(1, 0.0, 5.0, 10.0);
        nan_task.uncertainty = f64::NAN;
        s.push(nan_task);
        s.push(test_task(2, 0.0, 5.0, 10.0));
        s.push(test_task(3, 0.1, 5.0, 12.0));
        let mut seen = 0;
        let mut guard = 0;
        while s.queue_len() > 0 {
            guard += 1;
            assert!(guard < 100, "queue with NaN task failed to drain");
            for lane in [LaneId::GPU, LaneId::CPU] {
                if let Some(b) = s.pop(lane, guard as f64, true, WHOLE_BATCH) {
                    seen += b.tasks.len();
                }
            }
        }
        assert_eq!(seen, 3);
    }

    #[test]
    fn capped_uasched_sheds_on_push() {
        let p = SchedParams {
            batch_size: 2,
            queue_cap: 2,
            shed: ShedPolicy::Priority,
            ..Default::default()
        };
        let mut s = UaSched::two_lane(p, 0.05, f64::INFINITY, true);
        s.push(test_task(1, 0.0, 50.0, 10.0)); // loose deadline: worst
        s.push(test_task(2, 0.0, 5.0, 10.0));
        s.push(test_task(3, 0.1, 2.0, 10.0)); // evicts task 1
        let shed = s.take_shed();
        assert_eq!(shed.len(), 1);
        assert_eq!(shed[0].0, LaneId::GPU, "shed is attributed to the full lane");
        assert_eq!(shed[0].1.id, 1);
        assert_eq!(s.queue_len(), 2);
    }

    #[test]
    fn prop_conservation_no_loss_no_dup() {
        prop::check_result(
            "uasched-conservation",
            200,
            |rng| {
                let n = rng.range_usize(1, 40);
                let c = rng.range_usize(1, 8);
                let tau = if rng.f64() < 0.5 { 60.0 } else { f64::INFINITY };
                let tasks: Vec<Task> =
                    (0..n).map(|i| rand_task(rng, i as u64)).collect();
                (tasks, c, tau)
            },
            |(tasks, c, tau)| {
                let mut s = UaSched::two_lane(params(*c), 0.05, *tau, true);
                for t in tasks.clone() {
                    s.push(t);
                }
                let mut seen = std::collections::HashSet::new();
                let mut guard = 0;
                let mut now = 0.0;
                while s.queue_len() > 0 {
                    guard += 1;
                    now += 1.0;
                    if guard > 1000 {
                        return Err("scheduler did not drain".into());
                    }
                    for lane in [LaneId::GPU, LaneId::CPU] {
                        if let Some(b) = s.pop(lane, now, true, WHOLE_BATCH) {
                            if b.tasks.is_empty() {
                                return Err("empty batch emitted".into());
                            }
                            if b.tasks.len() > *c {
                                return Err(format!("batch over size: {}", b.tasks.len()));
                            }
                            for t in &b.tasks {
                                if !seen.insert(t.id) {
                                    return Err(format!("task {} dispatched twice", t.id));
                                }
                                match b.lane {
                                    LaneId::CPU if t.uncertainty <= *tau => {
                                        return Err("non-malicious task on CPU lane".into())
                                    }
                                    LaneId::GPU if t.uncertainty > *tau => {
                                        return Err("malicious task on GPU lane".into())
                                    }
                                    _ => {}
                                }
                            }
                        }
                    }
                }
                if seen.len() != tasks.len() {
                    return Err(format!("lost tasks: {} of {}", seen.len(), tasks.len()));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_consolidated_batches_respect_lambda() {
        prop::check_result(
            "uasched-lambda",
            200,
            |rng| {
                let n = rng.range_usize(2, 40);
                (0..n).map(|i| rand_task(rng, i as u64)).collect::<Vec<_>>()
            },
            |tasks| {
                let p = params(6);
                let lambda = p.lambda;
                let mut s = UaSched::two_lane(p, 0.05, f64::INFINITY, true);
                for t in tasks.clone() {
                    s.push(t);
                }
                let mut guard = 0;
                let mut now = 0.0;
                while s.queue_len() > 0 {
                    guard += 1;
                    now += 1.0;
                    if guard > 1000 {
                        return Err("did not drain".into());
                    }
                    if let Some(b) = s.pop(LaneId::GPU, now, true, WHOLE_BATCH) {
                        // the bounded-deferral rescue batch intentionally
                        // ignores lambda; every ordinary batch must obey it
                        if b.tasks.iter().any(|t| t.deferrals >= 3) {
                            continue;
                        }
                        let mut us: Vec<f64> = b.tasks.iter().map(|t| t.uncertainty).collect();
                        us.sort_by(f64::total_cmp);
                        for w in us.windows(2) {
                            if w[1] > lambda * w[0].max(1e-9) + 1e-9 {
                                return Err(format!("lambda violated: {} > {lambda}*{}", w[1], w[0]));
                            }
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
