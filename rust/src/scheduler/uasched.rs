//! UASCHED (Algorithm 1) — the full RT-LM scheduler: UP priority queue
//! + dynamic consolidation + strategic CPU offloading. The `UP` and
//! `UP+C` ablation arms are the same machine with offloading and/or
//! consolidation disabled.
//!
//! Priorities are *dynamic* (Eq. 2/3's slack is the remaining time until
//! the priority point at scheduling time), so waiting tasks age upward
//! and cannot be starved by a stream of lower-uncertainty arrivals.

use crate::config::SchedParams;

use super::consolidation::{sort_by_uncertainty, split_point};
use super::policy::{Batch, Lane, Policy};
use super::task::Task;
use super::up::up_priority;

pub struct UaSched {
    params: SchedParams,
    /// Output-tokens -> seconds coefficient of the serving model.
    eta: f64,
    /// Malicious threshold tau (Eq. 4); +inf disables offloading.
    tau: f64,
    /// Dynamic consolidation on/off (off = UP with static batching).
    consolidate: bool,
    /// Waiting tasks; priorities are recomputed at pop time.
    queue: Vec<Task>,
    /// Tasks quarantined for the CPU lane (u > tau), FIFO.
    cpu_queue: Vec<Task>,
}

impl UaSched {
    pub fn new(params: SchedParams, eta: f64, tau: f64, consolidate: bool) -> UaSched {
        UaSched { params, eta, tau, consolidate, queue: Vec::new(), cpu_queue: Vec::new() }
    }

    /// Sort the queue by descending UP priority at time `now`
    /// (ties broken by arrival order).
    ///
    /// Keys are computed once per task per pop: a comparator that calls
    /// `up_priority` evaluates it ~2·n·log n times per sort, which
    /// dominated the scheduling hot path (see `benches/hotpath.rs`).
    /// `total_cmp` keeps the sort total even if a broken regressor ever
    /// leaks a NaN uncertainty past the estimator clamp.
    fn sort_queue(&mut self, now: f64) {
        let params = &self.params;
        let eta = self.eta;
        let mut keyed: Vec<(f64, Task)> = self
            .queue
            .drain(..)
            .map(|task| (up_priority(&task, params, eta, now), task))
            .collect();
        keyed.sort_by(|a, b| {
            b.0.total_cmp(&a.0).then(a.1.arrival.total_cmp(&b.1.arrival))
        });
        self.queue.extend(keyed.into_iter().map(|(_, task)| task));
    }

    fn pop_gpu(&mut self, now: f64, force: bool) -> Option<Batch> {
        let c = self.params.batch_size.max(1);
        if self.queue.is_empty() {
            return None;
        }
        if !self.consolidate {
            // UP with static batching: first C by priority.
            if !force && self.queue.len() < c {
                return None;
            }
            self.sort_queue(now);
            let n = self.queue.len().min(c);
            let tasks: Vec<Task> = self.queue.drain(..n).collect();
            return Some(Batch { lane: Lane::Gpu, tasks });
        }

        // Dynamic consolidation: reorder a window of up to b*C tasks by
        // uncertainty and segment by lambda. A full batch C suffices to
        // dispatch — Algorithm 1 "ensures there is always a batch of
        // tasks ready for execution"; b only widens the reorder window
        // when the queue runs deeper.
        let accumulate = self.params.accumulate_len();
        if !force && self.queue.len() < c {
            return None;
        }
        self.sort_queue(now);
        let take = self.queue.len().min(accumulate);
        let mut tmp: Vec<Task> = self.queue.drain(..take).collect();
        sort_by_uncertainty(&mut tmp);

        // Bounded deferral (anti-starvation, see module docs): if the
        // lambda-split has already re-queued some task MAX_DEFERRALS
        // times, this round serves the u-sorted window *ending at* the
        // most-starved task, so it is guaranteed to dispatch.
        const MAX_DEFERRALS: u32 = 3;
        let starved_idx = tmp
            .iter()
            .enumerate()
            .filter(|(_, t)| t.deferrals >= MAX_DEFERRALS)
            .max_by_key(|(_, t)| t.deferrals)
            .map(|(i, _)| i);
        let (batch, rest): (Vec<Task>, Vec<Task>) = if let Some(i) = starved_idx {
            let start = (i + 1).saturating_sub(c);
            let mut batch: Vec<Task> = tmp.drain(start..=i).collect();
            debug_assert!(batch.iter().any(|t| t.deferrals >= MAX_DEFERRALS));
            batch.shrink_to_fit();
            (batch, tmp)
        } else {
            let split = split_point(&tmp, self.params.lambda, c);
            let rest = tmp.split_off(split);
            (tmp, rest)
        };
        for mut task in rest {
            task.deferrals += 1;
            self.queue.push(task); // re-queued; re-prioritised next pop
        }
        Some(Batch { lane: Lane::Gpu, tasks: batch })
    }

    fn pop_cpu(&mut self, force: bool) -> Option<Batch> {
        let c = self.params.batch_size.max(1);
        if self.cpu_queue.is_empty() || (!force && self.cpu_queue.len() < c) {
            return None;
        }
        let n = self.cpu_queue.len().min(c);
        let tasks = self.cpu_queue.drain(..n).collect();
        Some(Batch { lane: Lane::Cpu, tasks })
    }

    pub fn tau(&self) -> f64 {
        self.tau
    }
}

impl Policy for UaSched {
    fn name(&self) -> String {
        match (self.consolidate, self.tau.is_finite()) {
            (false, _) => "UP".into(),
            (true, false) => "UP+C".into(),
            (true, true) => "RT-LM".into(),
        }
    }

    fn push(&mut self, task: Task) {
        if task.uncertainty > self.tau {
            self.cpu_queue.push(task); // strategic offloading (Eq. 4)
        } else {
            self.queue.push(task);
        }
    }

    fn pop_batch(&mut self, lane: Lane, now: f64, force: bool) -> Option<Batch> {
        match lane {
            Lane::Gpu => self.pop_gpu(now, force),
            Lane::Cpu => self.pop_cpu(force),
        }
    }

    fn queue_len(&self) -> usize {
        self.queue.len() + self.cpu_queue.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::task::test_task;
    use crate::util::prop;
    use crate::util::rng::Pcg64;

    fn params(c: usize) -> SchedParams {
        SchedParams { batch_size: c, ..Default::default() }
    }

    fn rand_task(rng: &mut Pcg64, id: u64) -> Task {
        let arrival = rng.f64() * 10.0;
        let u = 4.0 + rng.f64() * 92.0;
        let d = arrival + 0.5 + rng.f64() * 5.0;
        test_task(id, arrival, d, u)
    }

    #[test]
    fn up_static_batching_orders_by_priority() {
        let mut s = UaSched::new(params(2), 0.05, f64::INFINITY, false);
        // same uncertainty, different deadlines -> earliest deadline first
        s.push(test_task(1, 0.0, 9.0, 10.0));
        s.push(test_task(2, 0.0, 1.0, 10.0));
        s.push(test_task(3, 0.0, 4.0, 10.0));
        let b = s.pop_batch(Lane::Gpu, 0.0, true).unwrap();
        assert_eq!(b.tasks.iter().map(|t| t.id).collect::<Vec<_>>(), vec![2, 3]);
    }

    #[test]
    fn offload_quarantines_above_tau() {
        let mut s = UaSched::new(params(2), 0.05, 50.0, true);
        s.push(test_task(1, 0.0, 5.0, 80.0)); // malicious
        s.push(test_task(2, 0.0, 5.0, 10.0));
        s.push(test_task(3, 0.0, 5.0, 60.0)); // malicious
        assert_eq!(s.queue_len(), 3);
        let cpu = s.pop_batch(Lane::Cpu, 0.0, false).unwrap();
        assert_eq!(cpu.lane, Lane::Cpu);
        let mut ids: Vec<u64> = cpu.tasks.iter().map(|t| t.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![1, 3]);
        let gpu = s.pop_batch(Lane::Gpu, 0.0, true).unwrap();
        assert_eq!(gpu.tasks[0].id, 2);
    }

    #[test]
    fn consolidation_returns_leftovers_to_queue() {
        let mut s = UaSched::new(params(4), 0.05, f64::INFINITY, true);
        // 8 tasks: 4 similar-u, 4 much larger u (accumulate = 7 with b=1.8)
        for i in 0..4 {
            s.push(test_task(i, 0.0, 5.0, 10.0 + i as f64));
        }
        for i in 4..8 {
            s.push(test_task(i, 0.0, 5.0, 80.0 + i as f64));
        }
        let b = s.pop_batch(Lane::Gpu, 0.0, false).unwrap();
        // the low-uncertainty group forms the batch
        assert!(b.tasks.iter().all(|t| t.uncertainty < 20.0), "{:?}", b.tasks);
        assert_eq!(b.tasks.len(), 4);
        assert_eq!(s.queue_len(), 4);
    }

    #[test]
    fn waits_for_full_batch_unless_forced() {
        let mut s = UaSched::new(params(4), 0.05, f64::INFINITY, true);
        for i in 0..3 {
            s.push(test_task(i, 0.0, 5.0, 10.0));
        }
        // fewer than C=4 queued -> wait for more arrivals unless forced
        assert!(s.pop_batch(Lane::Gpu, 0.0, false).is_none());
        assert!(s.pop_batch(Lane::Gpu, 0.0, true).is_some());
    }

    #[test]
    fn full_batch_dispatches_without_waiting_for_accumulation() {
        // Algorithm 1 keeps a batch ready: C tasks suffice even though
        // the reorder window b*C is larger.
        let mut s = UaSched::new(params(4), 0.05, f64::INFINITY, true);
        for i in 0..4 {
            s.push(test_task(i, 0.0, 5.0, 10.0));
        }
        let b = s.pop_batch(Lane::Gpu, 0.0, false).unwrap();
        assert_eq!(b.tasks.len(), 4);
    }

    #[test]
    fn aged_task_eventually_dispatches_first() {
        // A high-uncertainty task left waiting long enough must outrank
        // fresh low-uncertainty arrivals (no starvation).
        let mut s = UaSched::new(params(1), 0.05, f64::INFINITY, false);
        s.push(test_task(1, 0.0, 2.0, 90.0)); // old, uncertain
        s.push(test_task(2, 50.0, 60.0, 5.0)); // fresh, certain, far deadline
        let b = s.pop_batch(Lane::Gpu, 50.0, true).unwrap();
        assert_eq!(b.tasks[0].id, 1, "aged task must win");
    }

    #[test]
    fn nan_uncertainty_task_does_not_panic_the_queue() {
        // a broken regressor must degrade gracefully: NaN-uncertainty
        // tasks sort deterministically (total order) and still dispatch
        let mut s = UaSched::new(params(2), 0.05, 50.0, true);
        let mut nan_task = test_task(1, 0.0, 5.0, 10.0);
        nan_task.uncertainty = f64::NAN;
        s.push(nan_task);
        s.push(test_task(2, 0.0, 5.0, 10.0));
        s.push(test_task(3, 0.1, 5.0, 12.0));
        let mut seen = 0;
        let mut guard = 0;
        while s.queue_len() > 0 {
            guard += 1;
            assert!(guard < 100, "queue with NaN task failed to drain");
            for lane in [Lane::Gpu, Lane::Cpu] {
                if let Some(b) = s.pop_batch(lane, guard as f64, true) {
                    seen += b.tasks.len();
                }
            }
        }
        assert_eq!(seen, 3);
    }

    #[test]
    fn prop_conservation_no_loss_no_dup() {
        prop::check_result(
            "uasched-conservation",
            200,
            |rng| {
                let n = rng.range_usize(1, 40);
                let c = rng.range_usize(1, 8);
                let tau = if rng.f64() < 0.5 { 60.0 } else { f64::INFINITY };
                let tasks: Vec<Task> =
                    (0..n).map(|i| rand_task(rng, i as u64)).collect();
                (tasks, c, tau)
            },
            |(tasks, c, tau)| {
                let mut s = UaSched::new(params(*c), 0.05, *tau, true);
                for t in tasks.clone() {
                    s.push(t);
                }
                let mut seen = std::collections::HashSet::new();
                let mut guard = 0;
                let mut now = 0.0;
                while s.queue_len() > 0 {
                    guard += 1;
                    now += 1.0;
                    if guard > 1000 {
                        return Err("scheduler did not drain".into());
                    }
                    for lane in [Lane::Gpu, Lane::Cpu] {
                        if let Some(b) = s.pop_batch(lane, now, true) {
                            if b.tasks.is_empty() {
                                return Err("empty batch emitted".into());
                            }
                            if b.tasks.len() > *c {
                                return Err(format!("batch over size: {}", b.tasks.len()));
                            }
                            for t in &b.tasks {
                                if !seen.insert(t.id) {
                                    return Err(format!("task {} dispatched twice", t.id));
                                }
                                match b.lane {
                                    Lane::Cpu if t.uncertainty <= *tau => {
                                        return Err("non-malicious task on CPU lane".into())
                                    }
                                    Lane::Gpu if t.uncertainty > *tau => {
                                        return Err("malicious task on GPU lane".into())
                                    }
                                    _ => {}
                                }
                            }
                        }
                    }
                }
                if seen.len() != tasks.len() {
                    return Err(format!("lost tasks: {} of {}", seen.len(), tasks.len()));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_consolidated_batches_respect_lambda() {
        prop::check_result(
            "uasched-lambda",
            200,
            |rng| {
                let n = rng.range_usize(2, 40);
                (0..n).map(|i| rand_task(rng, i as u64)).collect::<Vec<_>>()
            },
            |tasks| {
                let p = params(6);
                let lambda = p.lambda;
                let mut s = UaSched::new(p, 0.05, f64::INFINITY, true);
                for t in tasks.clone() {
                    s.push(t);
                }
                let mut guard = 0;
                let mut now = 0.0;
                while s.queue_len() > 0 {
                    guard += 1;
                    now += 1.0;
                    if guard > 1000 {
                        return Err("did not drain".into());
                    }
                    if let Some(b) = s.pop_batch(Lane::Gpu, now, true) {
                        // the bounded-deferral rescue batch intentionally
                        // ignores lambda; every ordinary batch must obey it
                        if b.tasks.iter().any(|t| t.deferrals >= 3) {
                            continue;
                        }
                        let mut us: Vec<f64> = b.tasks.iter().map(|t| t.uncertainty).collect();
                        us.sort_by(f64::total_cmp);
                        for w in us.windows(2) {
                            if w[1] > lambda * w[0].max(1e-9) + 1e-9 {
                                return Err(format!("lambda violated: {} > {lambda}*{}", w[1], w[0]));
                            }
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
