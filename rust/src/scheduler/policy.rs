//! The scheduling-policy interface shared by the real-time server and
//! the discrete-event simulator.
//!
//! Lanes are a runtime table now ([`super::lane::LaneSet`]); policies
//! are built against one and dispatch per [`LaneId`]. The historical
//! `enum Lane { Gpu, Cpu }` is the two-lane instance
//! [`LaneSet::two_lane`], with `LaneId::GPU` / `LaneId::CPU` naming its
//! slots.

use super::lane::{LaneId, LaneSet};
use super::task::Task;
use crate::config::SchedParams;

/// A dispatched batch.
#[derive(Clone, Debug)]
pub struct Batch {
    /// Lane the batch is dispatched to.
    pub lane: LaneId,
    /// Member tasks, in the policy's chosen order.
    pub tasks: Vec<Task>,
}

impl Batch {
    /// Longest ground-truth output length in the batch — an
    /// accelerator-kind lane decodes the whole batch for this many
    /// steps.
    pub fn max_true_len(&self) -> usize {
        self.tasks.iter().map(|t| t.true_len).max().unwrap_or(0)
    }

    /// Longest input length in the batch (prefill bucket selector).
    pub fn max_input_len(&self) -> usize {
        self.tasks.iter().map(|t| t.input_len.max(1)).max().unwrap_or(1)
    }
}

/// Free-capacity sentinel for whole-batch pops: the lane has one batch
/// in flight at a time and the *policy* chooses the batch size. Stepped
/// lanes pass their actual free decode-slot count instead.
pub const WHOLE_BATCH: usize = usize::MAX;

/// A scheduling policy: accepts arrivals, emits batches per lane.
///
/// `pop(lane, now, force, free)` may return `None` to wait for more
/// arrivals (e.g. the queue holds fewer than a full batch); with
/// `force = true` the policy must dispatch whatever it has for that
/// lane (the engine sets this when the lane is idle and the wait
/// interval xi has elapsed). Baselines use only the fleet's primary
/// lane.
pub trait Policy: Send {
    /// Display name, e.g. "FIFO" or "RT-LM" (may depend on the build:
    /// RT-LM degrades to "UP+C" when no lane can claim traffic).
    fn name(&self) -> String;
    /// Admit one arrived task into the waiting queue(s). Policies with
    /// a bounded queue may shed here; the engine collects victims via
    /// [`take_shed`](Policy::take_shed).
    fn push(&mut self, task: Task);
    /// Emit the next batch for `lane`, or `None` to wait for more
    /// arrivals. `free` is the lane's free dispatch capacity:
    /// [`WHOLE_BATCH`] for whole-batch lanes (the historical
    /// `pop_batch`), or the number of free decode slots on a stepped
    /// lane — then the returned batch is a *join group* whose tasks
    /// enter the lane's persistent decode loop at the next step
    /// boundary, and the policy must never return more than `free`
    /// tasks.
    fn pop(&mut self, lane: LaneId, now: f64, force: bool, free: usize) -> Option<Batch>;
    /// Total queued (not yet dispatched) tasks across all lanes.
    fn queue_len(&self) -> usize;
    /// Tasks shed by admission control since the last call, paired with
    /// the lane that shed them. Default: nothing (unbounded queues).
    fn take_shed(&mut self) -> Vec<(LaneId, Task)> {
        Vec::new()
    }
    /// Is nothing queued?
    fn is_empty(&self) -> bool {
        self.queue_len() == 0
    }
    /// A lane's executor is permanently gone (remote node died). The
    /// policy must stop routing to it and re-admit anything it had
    /// queued there through the surviving lanes' admissions. Policies
    /// that cannot re-route (the single-queue baselines) keep the
    /// default, which fails the run with a clear error instead of
    /// silently dropping tasks.
    fn retire_lane(&mut self, lane: LaneId) -> anyhow::Result<()> {
        anyhow::bail!("policy {} cannot retire {lane}: no re-routing support", self.name())
    }
    /// The absolute time at which some queued task's batching window
    /// (ξ) expires, if the policy tracks per-lane windows. `None`
    /// means "use the engine's global `SchedParams::xi` window" — the
    /// historical behaviour, and bit-identical to it. Implementations
    /// must return the *same float expression* the engine compares
    /// against `now`, so a wait that ends exactly at the deadline
    /// observes it as expired (see the rounding note in
    /// `engine/core.rs`).
    fn next_force_deadline(&self, now: f64) -> Option<f64> {
        let _ = now;
        None
    }
}

/// Enumeration of every policy evaluated in the paper, for CLI/bench use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolicyKind {
    /// First-in first-out, static batching.
    Fifo,
    /// Highest (earliest) priority point first — EDF-style.
    Hpf,
    /// Least uncertainty first.
    Luf,
    /// Most uncertainty first.
    Muf,
    /// Slack-based priority (Eq. 2) with static batching — the paper's
    /// "straightforward" variant discussed in Sec. IV-B.
    Slack,
    /// UP only (static batching) — ablation arm.
    Up,
    /// UP + dynamic consolidation — ablation arm.
    UpC,
    /// Full RT-LM: UP + consolidation + strategic offloading.
    RtLm,
}

impl PolicyKind {
    /// The paper's headline comparison set (Figs. 9/11, Tables III/IV).
    pub const ALL_BASELINES: [PolicyKind; 5] =
        [PolicyKind::Fifo, PolicyKind::Hpf, PolicyKind::Luf, PolicyKind::Muf, PolicyKind::RtLm];

    /// The component-ablation arms (Figs. 10/12).
    pub const ABLATION: [PolicyKind; 4] =
        [PolicyKind::Fifo, PolicyKind::Up, PolicyKind::UpC, PolicyKind::RtLm];

    /// Every kind — the N-lane equivalence tests sweep all of them.
    pub const ALL: [PolicyKind; 8] = [
        PolicyKind::Fifo,
        PolicyKind::Hpf,
        PolicyKind::Luf,
        PolicyKind::Muf,
        PolicyKind::Slack,
        PolicyKind::Up,
        PolicyKind::UpC,
        PolicyKind::RtLm,
    ];

    /// Display label, as printed in the paper's tables.
    pub fn label(&self) -> &'static str {
        match self {
            PolicyKind::Fifo => "FIFO",
            PolicyKind::Hpf => "HPF",
            PolicyKind::Luf => "LUF",
            PolicyKind::Muf => "MUF",
            PolicyKind::Slack => "Slack",
            PolicyKind::Up => "UP",
            PolicyKind::UpC => "UP+C",
            PolicyKind::RtLm => "RT-LM",
        }
    }

    /// Parse a CLI policy name (case-insensitive; `rtlm`/`rt-lm`,
    /// `up+c`/`upc` accepted).
    pub fn parse(s: &str) -> anyhow::Result<PolicyKind> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "fifo" => PolicyKind::Fifo,
            "hpf" => PolicyKind::Hpf,
            "luf" => PolicyKind::Luf,
            "muf" => PolicyKind::Muf,
            "slack" => PolicyKind::Slack,
            "up" => PolicyKind::Up,
            "up+c" | "upc" => PolicyKind::UpC,
            "rtlm" | "rt-lm" => PolicyKind::RtLm,
            other => anyhow::bail!("unknown policy '{other}'"),
        })
    }

    /// Instantiate the policy over a lane fleet. `eta` is the primary
    /// serving model's output-length-to-seconds coefficient. The fleet's
    /// admission predicates carry what used to be the `tau` offload
    /// threshold; only RT-LM honours them (the ablation arms and the
    /// baselines ignore offload lanes, like their historical
    /// `tau = +inf` builds).
    pub fn build(&self, params: &SchedParams, eta: f64, lanes: &LaneSet) -> Box<dyn Policy> {
        use super::baselines::*;
        use super::uasched::UaSched;
        let primary = lanes.primary();
        match self {
            PolicyKind::Fifo => {
                Box::new(Fifo::new_on(params.batch_size, primary).with_overload(params))
            }
            PolicyKind::Hpf => {
                Box::new(Hpf::new_on(params.batch_size, primary).with_overload(params))
            }
            PolicyKind::Luf => {
                Box::new(Luf::new_on(params.batch_size, primary).with_overload(params))
            }
            PolicyKind::Muf => {
                Box::new(Muf::new_on(params.batch_size, primary).with_overload(params))
            }
            PolicyKind::Slack => {
                // alpha = 0 turns Eq. 3 into Eq. 2 exactly
                let p = SchedParams { alpha: 0.0, ..params.clone() };
                Box::new(UaSched::new(p, eta, lanes.clone(), false, false))
            }
            PolicyKind::Up => {
                Box::new(UaSched::new(params.clone(), eta, lanes.clone(), false, false))
            }
            PolicyKind::UpC => {
                Box::new(UaSched::new(params.clone(), eta, lanes.clone(), true, false))
            }
            PolicyKind::RtLm => {
                Box::new(UaSched::new(params.clone(), eta, lanes.clone(), true, true))
            }
        }
    }
}
