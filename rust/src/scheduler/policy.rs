//! The scheduling-policy interface shared by the real-time server and
//! the discrete-event simulator.

use super::task::Task;
use crate::config::SchedParams;

/// Which execution lane a batch is dispatched to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Lane {
    /// The accelerator lane (paper: GPU).
    Gpu,
    /// The quarantine lane (paper: CPU cores) used by strategic offloading.
    Cpu,
}

impl Lane {
    /// Every lane, in the engine's fixed dispatch order.
    pub const ALL: [Lane; 2] = [Lane::Gpu, Lane::Cpu];

    /// Dense index for per-lane state arrays (`[T; Lane::ALL.len()]`) —
    /// the single source of the lane→slot convention shared by the
    /// dispatcher core and every execution backend.
    pub fn index(self) -> usize {
        match self {
            Lane::Gpu => 0,
            Lane::Cpu => 1,
        }
    }
}

/// A dispatched batch.
#[derive(Clone, Debug)]
pub struct Batch {
    pub lane: Lane,
    pub tasks: Vec<Task>,
}

impl Batch {
    pub fn max_true_len(&self) -> usize {
        self.tasks.iter().map(|t| t.true_len).max().unwrap_or(0)
    }

    pub fn max_input_len(&self) -> usize {
        self.tasks.iter().map(|t| t.input_len.max(1)).max().unwrap_or(1)
    }
}

/// A scheduling policy: accepts arrivals, emits batches per lane.
///
/// `pop_batch(lane, force)` may return `None` to wait for more arrivals
/// (e.g. the queue holds fewer than a full batch); with `force = true`
/// the policy must dispatch whatever it has for that lane (the engine
/// sets this when the lane is idle and the wait interval xi has
/// elapsed). Baselines never use the CPU lane.
pub trait Policy: Send {
    fn name(&self) -> String;
    fn push(&mut self, task: Task);
    fn pop_batch(&mut self, lane: Lane, now: f64, force: bool) -> Option<Batch>;
    fn queue_len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.queue_len() == 0
    }
}

/// Enumeration of every policy evaluated in the paper, for CLI/bench use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolicyKind {
    Fifo,
    Hpf,
    Luf,
    Muf,
    /// Slack-based priority (Eq. 2) with static batching — the paper's
    /// "straightforward" variant discussed in Sec. IV-B.
    Slack,
    /// UP only (static batching) — ablation arm.
    Up,
    /// UP + dynamic consolidation — ablation arm.
    UpC,
    /// Full RT-LM: UP + consolidation + strategic offloading.
    RtLm,
}

impl PolicyKind {
    pub const ALL_BASELINES: [PolicyKind; 5] =
        [PolicyKind::Fifo, PolicyKind::Hpf, PolicyKind::Luf, PolicyKind::Muf, PolicyKind::RtLm];

    pub const ABLATION: [PolicyKind; 4] =
        [PolicyKind::Fifo, PolicyKind::Up, PolicyKind::UpC, PolicyKind::RtLm];

    pub fn label(&self) -> &'static str {
        match self {
            PolicyKind::Fifo => "FIFO",
            PolicyKind::Hpf => "HPF",
            PolicyKind::Luf => "LUF",
            PolicyKind::Muf => "MUF",
            PolicyKind::Slack => "Slack",
            PolicyKind::Up => "UP",
            PolicyKind::UpC => "UP+C",
            PolicyKind::RtLm => "RT-LM",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<PolicyKind> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "fifo" => PolicyKind::Fifo,
            "hpf" => PolicyKind::Hpf,
            "luf" => PolicyKind::Luf,
            "muf" => PolicyKind::Muf,
            "slack" => PolicyKind::Slack,
            "up" => PolicyKind::Up,
            "up+c" | "upc" => PolicyKind::UpC,
            "rtlm" | "rt-lm" => PolicyKind::RtLm,
            other => anyhow::bail!("unknown policy '{other}'"),
        })
    }

    /// Instantiate the policy. `eta` is the serving model's
    /// output-length-to-seconds coefficient; `tau` the offload threshold
    /// (only RT-LM uses it).
    pub fn build(&self, params: &SchedParams, eta: f64, tau: f64) -> Box<dyn Policy> {
        use super::baselines::*;
        use super::uasched::UaSched;
        match self {
            PolicyKind::Fifo => Box::new(Fifo::new(params.batch_size)),
            PolicyKind::Hpf => Box::new(Hpf::new(params.batch_size)),
            PolicyKind::Luf => Box::new(Luf::new(params.batch_size)),
            PolicyKind::Muf => Box::new(Muf::new(params.batch_size)),
            PolicyKind::Slack => {
                // alpha = 0 turns Eq. 3 into Eq. 2 exactly
                let p = SchedParams { alpha: 0.0, ..params.clone() };
                Box::new(UaSched::new(p, eta, f64::INFINITY, false))
            }
            PolicyKind::Up => Box::new(UaSched::new(params.clone(), eta, f64::INFINITY, false)),
            PolicyKind::UpC => Box::new(UaSched::new(params.clone(), eta, f64::INFINITY, true)),
            PolicyKind::RtLm => Box::new(UaSched::new(params.clone(), eta, tau, true)),
        }
    }
}
